"""L1 — Pallas kernel for the streaming K-Means hot spot.

The paper's workload is MiniBatch K-Means (scikit-learn) processing one
message (a batch of `n` points, d=8 features) per invocation.  Complexity is
O(n*c): the distance phase between all points and all `c` centroids
dominates — that phase is this kernel.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper ran on CPUs
(Lambda containers / KNL nodes), so there is no CUDA to port; we still shape
the kernel for the MXU: squared Euclidean distance is expressed as
``|x|^2 - 2 x @ c^T + |c|^2`` so the O(n*c*d) work is one matmul
contraction, blocked points x centroids for VMEM.  The kernel keeps a
running (min, argmin) carry over centroid tiles so a block never
materializes the full n x c distance matrix.

interpret=True is mandatory here: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that runs on any backend
(including the Rust PJRT client on the request path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  VMEM budget per grid step (f32):
#   points tile   bp x d          = 1024*8*4   =  32 KiB
#   centroids     bc x d (tile)   =  512*8*4   =  16 KiB
#   dist tile     bp x bc         = 1024*512*4 =   2 MiB
#   carries       2 * bp          =            =   8 KiB
# ~2.1 MiB << 16 MiB VMEM; the dist tile is the MXU output tile.
DEFAULT_BLOCK_POINTS = 1024
DEFAULT_BLOCK_CENTROIDS = 512


def _assign_kernel(x_ref, c_ref, idx_ref, dist_ref, *, block_c: int):
    """One grid step: assign a tile of points to the nearest centroid.

    x_ref:    (bp, d)  tile of points (VMEM)
    c_ref:    (c, d)   all centroids (VMEM; c*d is small: 8192*8*4 = 256 KiB)
    idx_ref:  (bp,)    output argmin indices (int32)
    dist_ref: (bp,)    output min squared distances (f32)
    """
    x = x_ref[...]
    n_c = c_ref.shape[0]
    n_tiles = pl.cdiv(n_c, block_c)
    x2 = jnp.sum(x * x, axis=1)

    def body(t, carry):
        best_d, best_i = carry
        c_tile = pl.load(c_ref, (pl.dslice(t * block_c, block_c), slice(None)))
        c2 = jnp.sum(c_tile * c_tile, axis=1)
        # MXU contraction: (bp, d) @ (d, bc) -> (bp, bc)
        prod = jax.lax.dot_general(
            x, c_tile, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        d2 = x2[:, None] - 2.0 * prod + c2[None, :]
        # mask the ragged tail of the last centroid tile
        col = t * block_c + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
        d2 = jnp.where(col < n_c, d2, jnp.inf)
        tile_best = jnp.min(d2, axis=1)
        tile_idx = jnp.argmin(d2, axis=1).astype(jnp.int32) + t * block_c
        take = tile_best < best_d
        return jnp.where(take, tile_best, best_d), jnp.where(take, tile_idx, best_i)

    init = (jnp.full((x.shape[0],), jnp.inf, jnp.float32),
            jnp.zeros((x.shape[0],), jnp.int32))
    best_d, best_i = jax.lax.fori_loop(0, n_tiles, body, init)
    idx_ref[...] = best_i
    dist_ref[...] = jnp.maximum(best_d, 0.0)  # clamp fp cancellation


@functools.partial(jax.jit, static_argnames=("block_p", "block_c"))
def assign(points, centroids, *, block_p: int = DEFAULT_BLOCK_POINTS,
           block_c: int = DEFAULT_BLOCK_CENTROIDS):
    """Nearest-centroid assignment via the Pallas kernel.

    points:    f32[n, d]
    centroids: f32[c, d]
    returns (idx: i32[n], min_sq_dist: f32[n])
    """
    n, d = points.shape
    c = centroids.shape[0]
    bp = min(block_p, n)
    bc = min(block_c, c)
    grid = (pl.cdiv(n, bp),)
    kernel = functools.partial(_assign_kernel, block_c=bc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, d), lambda i: (i, 0)),
            pl.BlockSpec((c, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(points, centroids)
