"""Pure-jnp oracle for the Pallas K-Means kernels (no Pallas, no tiling).

Used by pytest to validate `kernels.kmeans.assign` and by `model.py` tests
to validate the full MiniBatch step against a straightforward
implementation of the scikit-learn MiniBatchKMeans update rule.
"""
from __future__ import annotations

import jax.numpy as jnp


def assign_ref(points, centroids):
    """Brute-force nearest-centroid assignment.

    points:    f32[n, d]
    centroids: f32[c, d]
    returns (idx: i32[n], min_sq_dist: f32[n])
    """
    d2 = (
        jnp.sum(points * points, axis=1)[:, None]
        - 2.0 * points @ centroids.T
        + jnp.sum(centroids * centroids, axis=1)[None, :]
    )
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind = jnp.maximum(jnp.min(d2, axis=1), 0.0)
    return idx, mind


def minibatch_step_ref(points, centroids, counts):
    """One MiniBatch K-Means update, sklearn-style (batch formulation).

    For each centroid j with batch members B_j (|B_j| = b_j) and running
    per-centroid sample count v_j, the batch-folded update is

        v_j' = v_j + b_j
        c_j' = c_j * (v_j / v_j') + sum(B_j) / v_j'

    points:    f32[n, d]
    centroids: f32[c, d]
    counts:    f32[c]     running per-centroid sample counts
    returns (centroids': f32[c,d], counts': f32[c], inertia: f32[])
    """
    c = centroids.shape[0]
    idx, mind = assign_ref(points, centroids)
    onehot = jnp.zeros((points.shape[0], c), points.dtype).at[
        jnp.arange(points.shape[0]), idx
    ].set(1.0)
    bcount = jnp.sum(onehot, axis=0)                     # b_j
    bsum = onehot.T @ points                             # sum(B_j)
    new_counts = counts + bcount
    denom = jnp.maximum(new_counts, 1.0)
    new_centroids = centroids * (counts / denom)[:, None] + bsum / denom[:, None]
    # centroids that have never seen a sample keep their position
    seen = new_counts > 0.0
    new_centroids = jnp.where(seen[:, None], new_centroids, centroids)
    inertia = jnp.sum(mind)
    return new_centroids, new_counts, inertia
