"""L2 — JAX compute graph: one streaming MiniBatch K-Means step.

This is the function that gets AOT-lowered (aot.py) and executed from the
Rust coordinator via PJRT for every message on the request path.  It calls
the L1 Pallas assignment kernel for the O(n*c) hot spot and does the O(n*d)
centroid fold in plain jnp (segment-sum shaped so XLA fuses it).

The update rule matches scikit-learn MiniBatchKMeans (per-centroid counts
as learning-rate denominators) — see kernels/ref.py for the derivation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import kmeans as kmeans_kernels


def minibatch_kmeans_step(points, centroids, counts):
    """points f32[n,d], centroids f32[c,d], counts f32[c]
    -> (centroids' f32[c,d], counts' f32[c], inertia f32[])"""
    c = centroids.shape[0]
    idx, mind = kmeans_kernels.assign(points, centroids)
    bcount = jax.ops.segment_sum(
        jnp.ones_like(mind), idx, num_segments=c
    )
    bsum = jax.ops.segment_sum(points, idx, num_segments=c)
    new_counts = counts + bcount
    denom = jnp.maximum(new_counts, 1.0)
    new_centroids = centroids * (counts / denom)[:, None] + bsum / denom[:, None]
    seen = new_counts > 0.0
    new_centroids = jnp.where(seen[:, None], new_centroids, centroids)
    inertia = jnp.sum(mind)
    return new_centroids, new_counts, inertia


def step_fn(n: int, c: int, d: int):
    """Return (jitted_fn, example_args) for a concrete (n, c, d) variant."""
    args = (
        jax.ShapeDtypeStruct((n, d), jnp.float32),
        jax.ShapeDtypeStruct((c, d), jnp.float32),
        jax.ShapeDtypeStruct((c,), jnp.float32),
    )
    return jax.jit(minibatch_kmeans_step), args
