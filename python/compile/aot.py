"""AOT lowering: jax -> HLO *text* artifacts + manifest for the Rust runtime.

Interchange format is HLO text, NOT `lowered.compile().serialize()` /
serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published `xla` 0.1.6 crate
binds) rejects (`proto.id() <= INT_MAX`).  The HLO text parser reassigns
ids, so text round-trips cleanly.  See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one artifact per (message-size, workload-complexity) shape variant of
the MiniBatch K-Means step plus a manifest.json the Rust loader consumes.
Variants mirror the paper's experiment grid:
  MS (points/message): 8_000, 16_000, 26_000   (~296/592/962 kB messages)
  WC (centroids):      128, 1_024, 8_192
plus a small `tiny` variant (256 points, 16 centroids) for tests/quickstart.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# The paper's experiment grid (d=8 f32 features: 8000*8*4 B = 256 KiB payload,
# ~296 kB on the wire with envelope — matches the paper's message sizes).
DIM = 8
MESSAGE_POINTS = (8_000, 16_000, 26_000)
CENTROIDS = (128, 1_024, 8_192)
TINY = (256, 16)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(n: int, c: int, d: int) -> str:
    fn, args = model.step_fn(n, c, d)
    return to_hlo_text(fn.lower(*args))


def variant_name(n: int, c: int, d: int) -> str:
    return f"kmeans_n{n}_c{c}_d{d}"


def default_variants() -> list[tuple[int, int, int]]:
    variants = [(n, c, DIM) for n in MESSAGE_POINTS for c in CENTROIDS]
    variants.append((TINY[0], TINY[1], DIM))
    return variants


def build(out_dir: str, *, force: bool = False, variants=None) -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    if variants is None:
        variants = default_variants()
    entries = []
    for n, c, d in variants:
        name = variant_name(n, c, d)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        if force or not os.path.exists(path):
            text = lower_variant(n, c, d)
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")
        else:
            print(f"kept  {path}")
        entries.append(
            {
                "name": name,
                "file": fname,
                "points": n,
                "centroids": c,
                "dim": d,
                "inputs": [
                    {"name": "points", "shape": [n, d], "dtype": "f32"},
                    {"name": "centroids", "shape": [c, d], "dtype": "f32"},
                    {"name": "counts", "shape": [c], "dtype": "f32"},
                ],
                "outputs": [
                    {"name": "centroids", "shape": [c, d], "dtype": "f32"},
                    {"name": "counts", "shape": [c], "dtype": "f32"},
                    {"name": "inertia", "shape": [], "dtype": "f32"},
                ],
            }
        )
    manifest = {
        "schema": 1,
        "model": "minibatch_kmeans_step",
        "dim": DIM,
        "variants": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json ({len(entries)} variants)")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true", help="re-lower even if artifact exists")
    args = ap.parse_args()
    build(args.out_dir, force=args.force)


if __name__ == "__main__":
    main()
