"""L2 model tests: MiniBatch K-Means step — shapes, semantics, convergence."""
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _blob_data(rng, n, c, d, spread=0.1):
    """n points drawn around c well-separated blob centers."""
    centers = rng.normal(size=(c, d), scale=10.0).astype(np.float32)
    labels = rng.integers(0, c, size=n)
    pts = centers[labels] + rng.normal(size=(n, d), scale=spread).astype(np.float32)
    return jnp.asarray(pts), jnp.asarray(centers), labels


def test_step_matches_ref():
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.normal(size=(512, 8)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    counts = jnp.zeros(32)
    got = model.minibatch_kmeans_step(pts, cen, counts)
    want = ref.minibatch_step_ref(pts, cen, counts)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-4)


def test_step_shapes():
    for n, c, d in [(256, 16, 8), (100, 7, 3)]:
        pts = jnp.zeros((n, d))
        cen = jnp.ones((c, d))
        counts = jnp.zeros(c)
        nc, ncounts, inertia = model.minibatch_kmeans_step(pts, cen, counts)
        assert nc.shape == (c, d)
        assert ncounts.shape == (c,)
        assert inertia.shape == ()


def test_counts_monotone_and_conserved():
    rng = np.random.default_rng(1)
    pts = jnp.asarray(rng.normal(size=(300, 8)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    counts = jnp.asarray(rng.integers(0, 50, size=16).astype(np.float32))
    _, ncounts, _ = model.minibatch_kmeans_step(pts, cen, counts)
    assert np.all(np.asarray(ncounts) >= np.asarray(counts))
    np.testing.assert_allclose(float(jnp.sum(ncounts - counts)), 300.0, rtol=1e-6)


def test_empty_centroid_unchanged():
    """A centroid far from every point receives no samples and stays put."""
    pts = jnp.zeros((64, 4))
    cen = jnp.asarray(
        np.vstack([np.zeros((1, 4)), 1e6 * np.ones((1, 4))]).astype(np.float32)
    )
    counts = jnp.zeros(2)
    nc, ncounts, _ = model.minibatch_kmeans_step(pts, cen, counts)
    np.testing.assert_allclose(np.asarray(nc[1]), 1e6 * np.ones(4))
    assert float(ncounts[1]) == 0.0


def test_inertia_decreases_over_stream():
    """Streaming repeated batches from fixed blobs: inertia should shrink."""
    rng = np.random.default_rng(2)
    pts, centers, _ = _blob_data(rng, 2000, 8, 8)
    # init centroids at perturbed blob centers
    cen = centers + jnp.asarray(rng.normal(size=centers.shape, scale=2.0).astype(np.float32))
    counts = jnp.zeros(8)
    inertias = []
    for step in range(10):
        batch = pts[(step * 200) % 2000 : (step * 200) % 2000 + 200]
        cen, counts, inertia = model.minibatch_kmeans_step(batch, cen, counts)
        inertias.append(float(inertia) / 200)
    assert inertias[-1] < inertias[0]


def test_sklearn_equivalence_single_point_batches():
    """Feeding one point at a time reproduces the classic per-sample rule
    c' = c + (x - c)/v' exactly."""
    rng = np.random.default_rng(3)
    cen = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
    counts = jnp.zeros(4)
    expect = np.asarray(cen).copy()
    expect_counts = np.zeros(4)
    for _ in range(20):
        x = rng.normal(size=(1, 3)).astype(np.float32)
        d2 = ((expect - x) ** 2).sum(axis=1)
        j = int(np.argmin(d2))
        # run the model step
        cen, counts, _ = model.minibatch_kmeans_step(jnp.asarray(x), cen, counts)
        # classic rule
        expect_counts[j] += 1
        expect[j] += (x[0] - expect[j]) / expect_counts[j]
    np.testing.assert_allclose(np.asarray(cen), expect, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(counts), expect_counts)
