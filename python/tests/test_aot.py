"""AOT path tests: lowering produces loadable HLO text + a sane manifest."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_lower_tiny_variant_hlo_text():
    text = aot.lower_variant(64, 8, 4)
    assert "HloModule" in text
    assert "ENTRY" in text
    # three parameters: points, centroids, counts
    assert "parameter(0)" in text and "parameter(2)" in text


def test_hlo_text_parses_back():
    """The emitted text must parse back into an HloModule with the expected
    entry signature — the same parse the Rust `HloModuleProto::from_text_file`
    loader performs.  (Numeric roundtrip through PJRT is covered by the Rust
    integration test `tests/runtime_roundtrip.rs`, the actual consumer;
    jaxlib >= 0.8 no longer executes classic XlaComputations from Python.)"""
    from jax._src.lib import xla_client as xc

    n, c, d = 64, 8, 4
    text = aot.lower_variant(n, c, d)
    mod = xc._xla.hlo_module_from_text(text)
    proto = mod.as_serialized_hlo_module_proto()
    assert len(proto) > 0
    sig = mod.to_string()
    assert "f32[64,4]" in sig  # points param
    assert "f32[8,4]" in sig   # centroids param/output


SMALL_GRID = [(64, 8, 4), (128, 16, 4)]


def test_build_manifest(tmp_path):
    entries = aot.build(str(tmp_path), variants=SMALL_GRID)
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["schema"] == 1
    assert len(man["variants"]) == len(entries) == len(SMALL_GRID)
    for v in man["variants"]:
        assert os.path.exists(tmp_path / v["file"])
        assert v["inputs"][0]["shape"] == [v["points"], v["dim"]]
        assert v["outputs"][0]["shape"] == [v["centroids"], v["dim"]]


def test_default_grid_matches_paper():
    grid = aot.default_variants()
    assert len(grid) == 10  # 3 MS x 3 WC + tiny
    assert (8_000, 1_024, aot.DIM) in grid  # Fig 3's configuration
    assert (aot.TINY[0], aot.TINY[1], aot.DIM) in grid


def test_build_is_incremental(tmp_path):
    aot.build(str(tmp_path), variants=SMALL_GRID)
    mtimes = {f: os.path.getmtime(tmp_path / f) for f in os.listdir(tmp_path)}
    aot.build(str(tmp_path), variants=SMALL_GRID)  # must not rewrite
    for f, t in mtimes.items():
        if f.endswith(".hlo.txt"):
            assert os.path.getmtime(tmp_path / f) == t
