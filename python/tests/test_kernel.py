"""Pallas assignment kernel vs pure-jnp oracle — the CORE correctness signal.

Sweeps shapes (including ragged tails smaller than the block sizes), dtypes,
block configurations, and degenerate geometries, hypothesis-style via
seeded random draws.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import kmeans as K
from compile.kernels import ref


def _data(rng, n, c, d, scale=1.0, dtype=np.float32):
    pts = rng.normal(size=(n, d), scale=scale).astype(dtype)
    cen = rng.normal(size=(c, d), scale=scale).astype(dtype)
    return jnp.asarray(pts), jnp.asarray(cen)


@pytest.mark.parametrize(
    "n,c,d",
    [
        (8, 4, 2),
        (64, 16, 8),
        (100, 7, 3),      # ragged everything
        (1024, 128, 8),
        (1025, 129, 8),   # one past the block boundary
        (2048, 512, 8),
        (333, 1000, 5),   # more centroids than points
        (1, 1, 1),        # degenerate
        (2, 8192, 4),     # huge centroid count, tiny batch
    ],
)
def test_assign_matches_ref(n, c, d):
    rng = np.random.default_rng(n * 31 + c * 7 + d)
    pts, cen = _data(rng, n, c, d)
    idx, dist = K.assign(pts, cen)
    ridx, rdist = ref.assign_ref(pts, cen)
    np.testing.assert_allclose(dist, rdist, rtol=1e-4, atol=1e-4)
    # argmin ties can differ between tiled and flat evaluation; require the
    # chosen centroid to achieve the minimal distance, not the same index.
    chosen = jnp.sum((pts - cen[idx]) ** 2, axis=1)
    np.testing.assert_allclose(chosen, rdist, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_p,block_c", [(8, 4), (16, 16), (128, 32), (1024, 512)])
def test_assign_block_config_invariance(block_p, block_c):
    rng = np.random.default_rng(42)
    pts, cen = _data(rng, 257, 65, 8)
    idx, dist = K.assign(pts, cen, block_p=block_p, block_c=block_c)
    ridx, rdist = ref.assign_ref(pts, cen)
    np.testing.assert_allclose(dist, rdist, rtol=1e-4, atol=1e-4)


def test_assign_random_shape_sweep():
    """Hypothesis-style: 25 seeded random shape/scale draws."""
    rng = np.random.default_rng(7)
    for trial in range(25):
        n = int(rng.integers(1, 300))
        c = int(rng.integers(1, 200))
        d = int(rng.integers(1, 16))
        scale = float(rng.choice([0.01, 1.0, 100.0]))
        pts, cen = _data(rng, n, c, d, scale=scale)
        idx, dist = K.assign(pts, cen)
        ridx, rdist = ref.assign_ref(pts, cen)
        np.testing.assert_allclose(
            dist, rdist, rtol=1e-3, atol=1e-3 * scale * scale,
            err_msg=f"trial={trial} n={n} c={c} d={d} scale={scale}",
        )


def test_assign_identical_points():
    """All points identical -> all assigned to the same nearest centroid."""
    pts = jnp.ones((64, 8))
    rng = np.random.default_rng(0)
    cen = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    idx, dist = K.assign(pts, cen)
    assert len(set(np.asarray(idx).tolist())) == 1
    ridx, _ = ref.assign_ref(pts, cen)
    assert int(idx[0]) == int(ridx[0])


def test_assign_points_on_centroids():
    """Points exactly at centroid positions -> distance 0, correct index."""
    rng = np.random.default_rng(3)
    cen = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    order = rng.permutation(32)
    pts = cen[order]
    idx, dist = K.assign(pts, cen)
    np.testing.assert_allclose(dist, np.zeros(32), atol=1e-3)
    np.testing.assert_array_equal(np.asarray(idx), order)


def test_assign_nonnegative_distances():
    """The |x|^2-2xc+|c|^2 form can go slightly negative; kernel clamps."""
    rng = np.random.default_rng(9)
    pts, cen = _data(rng, 512, 64, 8, scale=1000.0)
    _, dist = K.assign(pts, cen)
    assert float(jnp.min(dist)) >= 0.0
