//! CLI for the determinism & invariant lint.
//!
//! ```text
//! cargo run -p ps-lint                       # text report, repo-root config
//! cargo run -p ps-lint -- --format json      # machine-readable (CI artifact)
//! cargo run -p ps-lint -- --root DIR --config FILE
//! ```
//!
//! Exit codes: 0 clean, 1 unwaived findings, 2 usage/config/io error.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "\
ps-lint: determinism & invariant static analysis

USAGE:
    ps-lint [--root DIR] [--config FILE] [--format text|json]

OPTIONS:
    --root DIR       directory config paths are relative to (default .)
    --config FILE    rule configuration (default <root>/ps-lint.toml)
    --format FMT     text (default) or json
    --help           print this help
";

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(msg) => {
            eprintln!("ps-lint: {msg}");
            ExitCode::from(2)
        }
    }
}

fn run() -> Result<ExitCode, String> {
    let mut root = PathBuf::from(".");
    let mut config: Option<PathBuf> = None;
    let mut format = String::from("text");

    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => root = PathBuf::from(args.next().ok_or("--root needs a value")?),
            "--config" => config = Some(PathBuf::from(args.next().ok_or("--config needs a value")?)),
            "--format" => format = args.next().ok_or("--format needs a value")?,
            "--help" | "-h" => {
                print!("{USAGE}");
                return Ok(ExitCode::SUCCESS);
            }
            other => return Err(format!("unknown argument {other:?}\n{USAGE}")),
        }
    }
    if format != "text" && format != "json" {
        return Err(format!("--format must be text or json, got {format:?}"));
    }

    let config_path = config.unwrap_or_else(|| root.join("ps-lint.toml"));
    let report = ps_lint::run_from_config_file(&root, &config_path)?;

    if format == "json" {
        println!("{}", report.to_json().pretty());
    } else {
        print!("{}", report.to_text());
    }
    Ok(ExitCode::from(u8::try_from(report.exit_code()).unwrap_or(1)))
}
