//! The rule engine: runs every determinism/invariant rule over one lexed
//! file, resolves inline waivers, and emits findings.
//!
//! Waiver syntax (line comments only, reason mandatory):
//!
//! ```text
//! // ps-lint: allow(<rule>): <reason>
//! ```
//!
//! A waiver on a code line covers that line; a waiver alone on its own
//! line covers the next line that carries code.  Waivers that suppress
//! nothing are themselves findings (`unused-waiver`), as are waivers
//! missing the reason or naming an unknown rule (`bad-waiver`).

use crate::config::{self, Config};
use crate::lexer::{lex, test_mod_ranges, Token};
use crate::report::{Finding, Waived};
use std::collections::BTreeSet;

#[derive(Debug)]
struct Waiver {
    /// Line the waiver is declared on.
    decl_line: usize,
    /// Line whose findings it suppresses.
    covers_line: usize,
    rule: String,
    reason: String,
    used: bool,
}

/// Scan one file's source text.  `rel` is the `/`-separated path relative
/// to the scan root (used for allowlist/module matching and reporting).
pub fn scan_file(rel: &str, src: &str, cfg: &Config) -> (Vec<Finding>, Vec<Waived>) {
    let lexed = lex(src);
    let excluded = if cfg.skip_test_modules {
        test_mod_ranges(&lexed.tokens)
    } else {
        Vec::new()
    };
    let has_test_mod = !excluded.is_empty();
    // tokens outside #[cfg(test)] modules — what the rules look at
    let live: Vec<&Token> = lexed
        .tokens
        .iter()
        .enumerate()
        .filter(|(i, _)| !excluded.iter().any(|(s, e)| i >= s && i < e))
        .map(|(_, t)| t)
        .collect();

    let (mut waivers, mut findings) = parse_waivers(rel, &lexed.comments, &lexed.tokens);

    // candidate findings, deduped per (rule, line)
    let mut seen: BTreeSet<(String, usize)> = BTreeSet::new();
    let mut candidates: Vec<Finding> = Vec::new();
    let mut push = |rule: &str, line: usize, message: String, cands: &mut Vec<Finding>| {
        if seen.insert((rule.to_string(), line)) {
            cands.push(Finding {
                file: rel.to_string(),
                line,
                rule: rule.to_string(),
                message,
            });
        }
    };

    // R1 — wall-clock reads
    if !config::path_in(rel, &cfg.wall_clock_allow) {
        for src_ty in ["Instant", "SystemTime"] {
            for i in find_seq(&live, &[src_ty, ":", ":", "now"]) {
                push(
                    config::WALL_CLOCK,
                    live[i].line,
                    format!("{src_ty}::now() outside the wall-clock allowlist — sim/model code must read time through sim::clock"),
                    &mut candidates,
                );
            }
        }
    }

    // R2 — HashMap/HashSet in deterministic modules
    if config::path_in(rel, &cfg.hash_modules) {
        for ty in ["HashMap", "HashSet"] {
            for t in live.iter().filter(|t| t.text == ty) {
                push(
                    config::HASH_ITERATION,
                    t.line,
                    format!("{ty} in a deterministic module — iteration order can leak into output; use BTreeMap/BTreeSet or sort before iterating"),
                    &mut candidates,
                );
            }
        }
    }

    // R3 — thread spawning outside the deterministic-merge pool
    if !config::path_in(rel, &cfg.thread_allow) {
        for prim in ["spawn", "Builder", "scope"] {
            for i in find_seq(&live, &["thread", ":", ":", prim]) {
                push(
                    config::THREAD_SPAWN,
                    live[i].line,
                    format!("thread::{prim} outside pilot/workers.rs — parallelism must go through the deterministic-merge pool"),
                    &mut candidates,
                );
            }
        }
    }

    // R4 — ambient entropy
    for i in find_seq(&live, &["rand", ":", ":"]) {
        push(
            config::ENTROPY,
            live[i].line,
            "rand:: path — all randomness must come from util::rng seeded constructors".to_string(),
            &mut candidates,
        );
    }
    for t in live.iter().filter(|t| cfg.entropy_banned.contains(&t.text)) {
        push(
            config::ENTROPY,
            t.line,
            format!(
                "{} is entropy-seeded — all randomness must come from util::rng seeded constructors",
                t.text
            ),
            &mut candidates,
        );
    }

    // R5 — locks on hot-path modules
    if config::path_in(rel, &cfg.hot_path_modules) {
        for ty in ["RwLock", "Mutex"] {
            for t in live.iter().filter(|t| t.text == ty) {
                push(
                    config::HOT_PATH_LOCK,
                    t.line,
                    format!("{ty} in a hot-path module — prefer sharded ownership (ROADMAP: sim core at million-user scale)"),
                    &mut candidates,
                );
            }
        }
    }

    // R6 — conserved accounting sites need assertion/test cover
    if config::path_in(rel, &cfg.conserved_modules) {
        let has_debug_assert = live.iter().any(|t| t.text.starts_with("debug_assert"));
        if !has_debug_assert && !has_test_mod {
            for i in find_seq(&live, &["pub", "fn"]) {
                let Some(name) = live.get(i + 2) else { continue };
                if cfg.accounting_fns.contains(&name.text) {
                    push(
                        config::CONSERVED,
                        name.line,
                        format!("accounting fn `{}` in a conserved module with no debug_assert!/test marker in the file", name.text),
                        &mut candidates,
                    );
                }
            }
        }
    }

    // resolve waivers
    let mut waived: Vec<Waived> = Vec::new();
    for cand in candidates {
        let w = waivers
            .iter_mut()
            .find(|w| !w.used && w.rule == cand.rule && w.covers_line == cand.line);
        match w {
            Some(w) => {
                w.used = true;
                waived.push(Waived {
                    file: cand.file,
                    line: cand.line,
                    rule: cand.rule,
                    reason: w.reason.clone(),
                });
            }
            None => findings.push(cand),
        }
    }
    for w in waivers.iter().filter(|w| !w.used) {
        findings.push(Finding {
            file: rel.to_string(),
            line: w.decl_line,
            rule: config::UNUSED_WAIVER.to_string(),
            message: format!(
                "waiver for `{}` suppresses nothing on line {} — remove it",
                w.rule, w.covers_line
            ),
        });
    }
    (findings, waived)
}

/// Extract waivers from comments; malformed ones become `bad-waiver`
/// findings immediately.
fn parse_waivers(
    rel: &str,
    comments: &[crate::lexer::Comment],
    all_tokens: &[Token],
) -> (Vec<Waiver>, Vec<Finding>) {
    let mut waivers = Vec::new();
    let mut findings = Vec::new();
    for c in comments {
        // the directive must open the comment (`// ps-lint: ...`), so prose
        // *mentioning* the syntax — like this file's docs — never parses
        let Some(directive) = c.text.trim_start().strip_prefix("ps-lint:") else {
            continue;
        };
        let directive = directive.trim();
        let mut bad = |why: &str, findings: &mut Vec<Finding>| {
            findings.push(Finding {
                file: rel.to_string(),
                line: c.line,
                rule: config::BAD_WAIVER.to_string(),
                message: format!("malformed waiver ({why}) — expected `ps-lint: allow(<rule>): <reason>`"),
            });
        };
        let Some(rest) = directive.strip_prefix("allow(") else {
            bad("unknown directive", &mut findings);
            continue;
        };
        let Some(close) = rest.find(')') else {
            bad("unclosed rule name", &mut findings);
            continue;
        };
        let rule = rest[..close].trim().to_string();
        if !Config::is_known_rule(&rule) {
            bad(&format!("unknown rule `{rule}`"), &mut findings);
            continue;
        }
        let after = rest[close + 1..].trim_start();
        let reason = after.strip_prefix(':').map(str::trim).unwrap_or("");
        if reason.is_empty() {
            bad("missing reason", &mut findings);
            continue;
        }
        let covers_line = if c.own_line {
            all_tokens
                .iter()
                .find(|t| t.line > c.line)
                .map(|t| t.line)
                .unwrap_or(c.line)
        } else {
            c.line
        };
        waivers.push(Waiver {
            decl_line: c.line,
            covers_line,
            rule,
            reason: reason.to_string(),
            used: false,
        });
    }
    (waivers, findings)
}

/// Indices `i` where `tokens[i..]` matches `pat` textually.
fn find_seq(tokens: &[&Token], pat: &[&str]) -> Vec<usize> {
    if tokens.len() < pat.len() {
        return Vec::new();
    }
    (0..=tokens.len() - pat.len())
        .filter(|&i| pat.iter().enumerate().all(|(k, w)| tokens[i + k].text == *w))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_all() -> Config {
        Config {
            roots: vec![".".into()],
            skip_test_modules: true,
            wall_clock_allow: vec![],
            hash_modules: vec![".".into()],
            thread_allow: vec![],
            entropy_banned: vec!["thread_rng".into(), "OsRng".into()],
            hot_path_modules: vec![".".into()],
            conserved_modules: vec![".".into()],
            accounting_fns: vec!["resize".into()],
        }
    }

    fn rules_of(findings: &[Finding]) -> Vec<&str> {
        findings.iter().map(|f| f.rule.as_str()).collect()
    }

    #[test]
    fn wall_clock_detected_and_allowlisted() {
        let src = "fn f() { let t = Instant::now(); }";
        let (f, _) = scan_file("x.rs", src, &cfg_all());
        assert_eq!(rules_of(&f), vec![config::WALL_CLOCK]);
        let mut cfg = cfg_all();
        cfg.wall_clock_allow = vec!["x.rs".into()];
        let (f, _) = scan_file("x.rs", src, &cfg);
        assert!(f.is_empty());
    }

    #[test]
    fn hash_and_lock_flag_each_line_once() {
        let src = "use std::collections::HashMap;\nstruct S { a: HashMap<u8, u8>, b: HashMap<u8, u8> }";
        let (f, _) = scan_file("x.rs", src, &cfg_all());
        let hash: Vec<_> = f
            .iter()
            .filter(|x| x.rule == config::HASH_ITERATION)
            .collect();
        assert_eq!(hash.len(), 2); // line 1 and line 2, deduped within line 2
    }

    #[test]
    fn spawns_in_test_modules_are_skipped() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n fn t() { std::thread::spawn(|| {}); }\n}";
        let (f, _) = scan_file("x.rs", src, &cfg_all());
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn conserved_requires_cover() {
        let bare = "impl P { pub fn resize(&self, to: usize) {} }";
        let (f, _) = scan_file("x.rs", bare, &cfg_all());
        assert_eq!(rules_of(&f), vec![config::CONSERVED]);
        let covered = "impl P { pub fn resize(&self, to: usize) { debug_assert!(to > 0); } }";
        let (f, _) = scan_file("x.rs", covered, &cfg_all());
        assert!(f.is_empty());
        let tested = "impl P { pub fn resize(&self, to: usize) {} }\n#[cfg(test)]\nmod tests { fn t() {} }";
        let (f, _) = scan_file("x.rs", tested, &cfg_all());
        assert!(f.is_empty());
    }

    #[test]
    fn waiver_same_line_and_own_line() {
        let src = "fn f() { let t = Instant::now(); } // ps-lint: allow(wall-clock): live example timing";
        let (f, w) = scan_file("x.rs", src, &cfg_all());
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].reason, "live example timing");

        let src = "// ps-lint: allow(wall-clock): live example timing\nfn f() { let t = Instant::now(); }";
        let (f, w) = scan_file("x.rs", src, &cfg_all());
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(w[0].line, 2);
    }

    #[test]
    fn waiver_without_reason_is_a_finding() {
        let src = "fn f() { let t = Instant::now(); } // ps-lint: allow(wall-clock)";
        let (f, w) = scan_file("x.rs", src, &cfg_all());
        assert!(w.is_empty());
        let rules = rules_of(&f);
        assert!(rules.contains(&config::BAD_WAIVER));
        assert!(rules.contains(&config::WALL_CLOCK)); // not suppressed
    }

    #[test]
    fn unused_waiver_is_a_finding() {
        let src = "// ps-lint: allow(thread-spawn): nothing spawns here\nfn calm() {}";
        let (f, _) = scan_file("x.rs", src, &cfg_all());
        assert_eq!(rules_of(&f), vec![config::UNUSED_WAIVER]);
    }

    #[test]
    fn entropy_paths_and_idents() {
        let src = "fn f() { let mut r = rand::thread_rng(); }";
        let (f, _) = scan_file("x.rs", src, &cfg_all());
        // rand:: and thread_rng are on the same line — one finding (dedup)
        assert_eq!(rules_of(&f), vec![config::ENTROPY]);
    }
}
