//! `ps-lint` — determinism & invariant static analysis for the
//! pilot-streaming tree.
//!
//! The repo's reproducibility claims rest on invariants the compiler
//! cannot see: parallel sweeps byte-identical to sequential, refit
//! sequences bit-deterministic, conserved accounting through every
//! resize.  One stray `Instant::now()` or `HashMap` iteration in a sim
//! module silently breaks them.  This crate tokenizes every `.rs` file
//! under the configured roots and enforces six rules from `ps-lint.toml`:
//!
//! | rule                   | invariant                                             |
//! |------------------------|-------------------------------------------------------|
//! | `wall-clock`           | no `Instant::now`/`SystemTime::now` outside allowlist |
//! | `hash-iteration`       | no `HashMap`/`HashSet` in deterministic modules       |
//! | `thread-spawn`         | all parallelism through the pilot worker pool         |
//! | `entropy`              | all randomness via `util::rng` seeded constructors    |
//! | `hot-path-lock`        | no `RwLock`/`Mutex` in `hot-path`-tagged modules      |
//! | `conserved-accounting` | accounting fns covered by `debug_assert!`/tests       |
//!
//! Violations are waivable inline with a mandatory reason:
//! `// ps-lint: allow(<rule>): <reason>`.  Reasonless or unused waivers
//! are findings themselves (`bad-waiver`, `unused-waiver`), so the waiver
//! set stays honest.  The pass runs on its own sources too.

pub mod config;
pub mod lexer;
pub mod report;
pub mod rules;

pub use config::Config;
pub use report::{Finding, Report, Waived};

use std::collections::BTreeSet;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Run the configured scan rooted at `root` (the directory `ps-lint.toml`
/// paths are relative to).  Returns a sorted [`Report`].
pub fn run_scan(root: &Path, cfg: &Config) -> io::Result<Report> {
    let mut files: BTreeSet<PathBuf> = BTreeSet::new();
    for scan_root in &cfg.roots {
        let dir = root.join(scan_root);
        if !dir.is_dir() {
            return Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("scan root {} is not a directory", dir.display()),
            ));
        }
        collect_rs(&dir, &mut files)?;
    }
    let mut report = Report::default();
    for path in &files {
        let rel = rel_path(root, path);
        let src = fs::read_to_string(path)?;
        let (findings, waived) = rules::scan_file(&rel, &src, cfg);
        report.findings.extend(findings);
        report.waived.extend(waived);
        report.files_scanned += 1;
    }
    report.sort();
    Ok(report)
}

/// Convenience: load `ps-lint.toml` from `config_path` and scan.
pub fn run_from_config_file(root: &Path, config_path: &Path) -> Result<Report, String> {
    let text = fs::read_to_string(config_path)
        .map_err(|e| format!("cannot read {}: {e}", config_path.display()))?;
    let cfg = Config::from_toml(&text)?;
    run_scan(root, &cfg).map_err(|e| format!("scan failed: {e}"))
}

fn collect_rs(dir: &Path, out: &mut BTreeSet<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.insert(p);
        }
    }
    Ok(())
}

/// `/`-separated path of `path` relative to `root` (falls back to the
/// full path when `path` is not under `root`).
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_paths_are_slash_separated() {
        let root = Path::new("/a/b");
        let p = Path::new("/a/b/rust/src/x.rs");
        assert_eq!(rel_path(root, p), "rust/src/x.rs");
    }

    #[test]
    fn missing_scan_root_errors() {
        let cfg = Config {
            roots: vec!["definitely-not-a-dir".into()],
            ..Config::default()
        };
        assert!(run_scan(Path::new("."), &cfg).is_err());
    }
}
