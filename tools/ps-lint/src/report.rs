//! Finding collection and human/JSON rendering.  JSON output reuses the
//! main crate's deterministic `util::json` writer (sorted object keys),
//! so reports are diffable and golden-testable byte for byte.

use pilot_streaming::util::json::Json;

/// One unwaived rule violation.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub message: String,
}

/// One violation suppressed by a reason-carrying inline waiver.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Waived {
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub reason: String,
}

/// The full result of one scan.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub waived: Vec<Waived>,
}

impl Report {
    /// Canonical ordering: by (file, line, rule).
    pub fn sort(&mut self) {
        self.findings
            .sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
        self.waived
            .sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
    }

    /// Process exit code: clean tree → 0, any unwaived finding → 1.
    pub fn exit_code(&self) -> i32 {
        i32::from(!self.findings.is_empty())
    }

    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("file", Json::from(f.file.as_str())),
                    ("line", Json::from(f.line)),
                    ("message", Json::from(f.message.as_str())),
                    ("rule", Json::from(f.rule.as_str())),
                ])
            })
            .collect();
        let waived: Vec<Json> = self
            .waived
            .iter()
            .map(|w| {
                Json::obj(vec![
                    ("file", Json::from(w.file.as_str())),
                    ("line", Json::from(w.line)),
                    ("reason", Json::from(w.reason.as_str())),
                    ("rule", Json::from(w.rule.as_str())),
                ])
            })
            .collect();
        Json::obj(vec![
            (
                "counts",
                Json::obj(vec![
                    ("findings", Json::from(self.findings.len())),
                    ("waived", Json::from(self.waived.len())),
                ]),
            ),
            ("files_scanned", Json::from(self.files_scanned)),
            ("findings", Json::Arr(findings)),
            ("schema", Json::from(1usize)),
            ("tool", Json::from("ps-lint")),
            ("waived", Json::Arr(waived)),
        ])
    }

    /// Human-readable rendering, one line per finding.
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        for f in &self.findings {
            s.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
        }
        for w in &self.waived {
            s.push_str(&format!(
                "{}:{}: waived [{}] — {}\n",
                w.file, w.line, w.rule, w.reason
            ));
        }
        s.push_str(&format!(
            "ps-lint: {} file(s) scanned, {} finding(s), {} waived\n",
            self.files_scanned,
            self.findings.len(),
            self.waived.len()
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exit_codes() {
        let mut r = Report::default();
        assert_eq!(r.exit_code(), 0);
        r.findings.push(Finding {
            file: "a.rs".into(),
            line: 1,
            rule: "wall-clock".into(),
            message: "m".into(),
        });
        assert_eq!(r.exit_code(), 1);
    }

    #[test]
    fn json_shape_is_stable() {
        let mut r = Report {
            files_scanned: 2,
            findings: vec![
                Finding {
                    file: "b.rs".into(),
                    line: 3,
                    rule: "entropy".into(),
                    message: "m2".into(),
                },
                Finding {
                    file: "a.rs".into(),
                    line: 9,
                    rule: "wall-clock".into(),
                    message: "m1".into(),
                },
            ],
            waived: vec![],
        };
        r.sort();
        assert_eq!(r.findings[0].file, "a.rs");
        let j = r.to_json();
        assert_eq!(j.get("schema").as_i64(), Some(1));
        assert_eq!(j.get("counts").get("findings").as_i64(), Some(2));
        assert_eq!(
            j.get("findings").as_arr().unwrap()[0].get("file").as_str(),
            Some("a.rs")
        );
    }
}
