//! `ps-lint.toml` loading, on top of the main crate's TOML-subset parser
//! (`util::tomlmini`) — no external dependencies.

use pilot_streaming::util::json::Json;
use pilot_streaming::util::tomlmini;

/// Rule identifiers, as they appear in config headers, waiver comments,
/// and reports.
pub const WALL_CLOCK: &str = "wall-clock";
pub const HASH_ITERATION: &str = "hash-iteration";
pub const THREAD_SPAWN: &str = "thread-spawn";
pub const ENTROPY: &str = "entropy";
pub const HOT_PATH_LOCK: &str = "hot-path-lock";
pub const CONSERVED: &str = "conserved-accounting";
/// Meta-rules (always on, never configurable, never waivable).
pub const BAD_WAIVER: &str = "bad-waiver";
pub const UNUSED_WAIVER: &str = "unused-waiver";

/// All real (configurable, waivable) rule names.
pub const RULES: [&str; 6] = [
    WALL_CLOCK,
    HASH_ITERATION,
    THREAD_SPAWN,
    ENTROPY,
    HOT_PATH_LOCK,
    CONSERVED,
];

#[derive(Debug, Clone, Default)]
pub struct Config {
    /// Directories (relative to the scan root) to walk for `.rs` files.
    pub roots: Vec<String>,
    /// Skip `#[cfg(test)] mod` bodies (tests may thread/sleep freely).
    pub skip_test_modules: bool,
    /// R1: path prefixes where wall-clock reads are legitimate.
    pub wall_clock_allow: Vec<String>,
    /// R2: path prefixes of deterministic modules (no HashMap/HashSet).
    pub hash_modules: Vec<String>,
    /// R3: path prefixes allowed to spawn threads directly.
    pub thread_allow: Vec<String>,
    /// R4: identifiers that mean ambient entropy (`thread_rng`, ...).
    pub entropy_banned: Vec<String>,
    /// R5: path prefixes tagged `hot-path` (no RwLock/Mutex).
    pub hot_path_modules: Vec<String>,
    /// R6: path prefixes tagged `conserved`.
    pub conserved_modules: Vec<String>,
    /// R6: exact names of accounting functions needing assertion cover.
    pub accounting_fns: Vec<String>,
}

impl Config {
    /// Parse a `ps-lint.toml` document.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = tomlmini::parse(text).map_err(|e| format!("config: {e}"))?;
        let scan = doc.get("scan");
        let rules = doc.get("rules");
        let cfg = Config {
            roots: str_list(scan.get("roots")),
            skip_test_modules: scan.get("skip_test_modules").as_bool().unwrap_or(true),
            wall_clock_allow: str_list(rules.get(WALL_CLOCK).get("allow")),
            hash_modules: str_list(rules.get(HASH_ITERATION).get("modules")),
            thread_allow: str_list(rules.get(THREAD_SPAWN).get("allow")),
            entropy_banned: str_list(rules.get(ENTROPY).get("banned")),
            hot_path_modules: str_list(rules.get(HOT_PATH_LOCK).get("modules")),
            conserved_modules: str_list(rules.get(CONSERVED).get("modules")),
            accounting_fns: str_list(rules.get(CONSERVED).get("accounting_fns")),
        };
        if cfg.roots.is_empty() {
            return Err("config: [scan] roots must list at least one directory".into());
        }
        Ok(cfg)
    }

    pub fn is_known_rule(name: &str) -> bool {
        RULES.contains(&name)
    }
}

fn str_list(v: &Json) -> Vec<String> {
    v.as_arr()
        .map(|items| {
            items
                .iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect()
        })
        .unwrap_or_default()
}

/// Does `rel` (a `/`-separated path relative to the scan root) fall under
/// `prefix`?  A prefix of `"."` matches everything; otherwise the prefix
/// must equal the path or name one of its ancestor directories.
pub fn path_matches(rel: &str, prefix: &str) -> bool {
    let p = prefix.trim_end_matches('/');
    p == "." || rel == p || rel.starts_with(&format!("{p}/"))
}

/// True when `rel` falls under any of `prefixes`.
pub fn path_in(rel: &str, prefixes: &[String]) -> bool {
    prefixes.iter().any(|p| path_matches(rel, p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = Config::from_toml(
            r#"
[scan]
roots = ["rust/src", "examples"]
skip_test_modules = true

[rules.wall-clock]
allow = ["rust/src/sim/clock.rs"]

[rules.hash-iteration]
modules = ["rust/src/sim"]

[rules.thread-spawn]
allow = ["rust/src/pilot/workers.rs"]

[rules.entropy]
banned = ["thread_rng"]

[rules.hot-path-lock]
modules = ["rust/src/broker/kafka.rs"]

[rules.conserved-accounting]
modules = ["rust/src/pilot/job.rs"]
accounting_fns = ["resize"]
"#,
        )
        .unwrap();
        assert_eq!(cfg.roots.len(), 2);
        assert!(cfg.skip_test_modules);
        assert_eq!(cfg.wall_clock_allow, vec!["rust/src/sim/clock.rs"]);
        assert_eq!(cfg.accounting_fns, vec!["resize"]);
    }

    #[test]
    fn missing_roots_is_an_error() {
        assert!(Config::from_toml("[scan]\n").is_err());
    }

    #[test]
    fn path_matching() {
        assert!(path_matches("rust/src/sim/engine.rs", "rust/src/sim"));
        assert!(path_matches("rust/src/sim/engine.rs", "."));
        assert!(path_matches("a/b.rs", "a/b.rs"));
        assert!(!path_matches("rust/src/simx/e.rs", "rust/src/sim"));
        assert!(!path_matches("rust/src/sim.rs", "rust/src/sim"));
    }
}
