//! A minimal Rust tokenizer: good enough to find identifier/path patterns
//! without being fooled by comments, string literals, or `#[cfg(test)]`
//! modules.
//!
//! The output is a flat stream of [`Token`]s (identifiers keep their text,
//! literals collapse to `"<lit>"`, punctuation is one token per character)
//! plus the line comments (waivers live there).  It is deliberately *not*
//! a full lexer — raw strings, nested block comments, char literals and
//! lifetimes are handled just well enough that nothing inside them leaks
//! into the token stream.

/// One lexed token: identifiers carry their text, literals are `"<lit>"`,
/// punctuation is a single-character string.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    /// 1-based source line the token starts on.
    pub line: usize,
    pub text: String,
}

/// One `//` line comment (block comments never carry waivers).
#[derive(Debug, Clone, PartialEq)]
pub struct Comment {
    /// 1-based source line.
    pub line: usize,
    /// Text after the `//`, untrimmed.
    pub text: String,
    /// True when nothing but whitespace precedes the comment on its line.
    pub own_line: bool,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

/// Tokenize `src`. Never fails: malformed trailing input degrades to
/// punctuation tokens, which no rule pattern matches.
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut saw_token_on_line = false;

    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            saw_token_on_line = false;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            let start = i + 2;
            let mut j = start;
            while j < chars.len() && chars[j] != '\n' {
                j += 1;
            }
            out.comments.push(Comment {
                line,
                text: chars[start..j].iter().collect(),
                own_line: !saw_token_on_line,
            });
            i = j;
            continue;
        }
        // block comment (nested)
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < chars.len() && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                } else if chars[j] == '/' && chars.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 1;
                } else if chars[j] == '*' && chars.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 1;
                }
                j += 1;
            }
            i = j;
            continue;
        }
        // raw / byte string: (r|b|br|rb) #* "
        if c == 'r' || c == 'b' {
            if let Some((end, lines)) = try_string_prefix(&chars, i) {
                out.tokens.push(Token {
                    line,
                    text: "<lit>".into(),
                });
                saw_token_on_line = true;
                line += lines;
                i = end;
                continue;
            }
        }
        // plain string
        if c == '"' {
            let (end, lines) = consume_string(&chars, i + 1, 0, true);
            out.tokens.push(Token {
                line,
                text: "<lit>".into(),
            });
            saw_token_on_line = true;
            line += lines;
            i = end;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if chars.get(i + 1) == Some(&'\\') {
                // escaped char literal: scan to the closing quote
                let mut j = i + 2;
                while j < chars.len() && chars[j] != '\'' {
                    j += 1;
                }
                out.tokens.push(Token {
                    line,
                    text: "<lit>".into(),
                });
                saw_token_on_line = true;
                i = j + 1;
                continue;
            }
            if i + 2 < chars.len() && chars[i + 2] == '\'' {
                out.tokens.push(Token {
                    line,
                    text: "<lit>".into(),
                });
                saw_token_on_line = true;
                i += 3;
                continue;
            }
            // lifetime: consume the quote + identifier, emit nothing
            let mut j = i + 1;
            while j < chars.len() && (chars[j].is_alphanumeric() || chars[j] == '_') {
                j += 1;
            }
            i = j.max(i + 1);
            continue;
        }
        // number literal (suffixes and separators folded in; `.` stays
        // punctuation so `0..6` cannot swallow an identifier)
        if c.is_ascii_digit() {
            let mut j = i;
            while j < chars.len() && (chars[j].is_alphanumeric() || chars[j] == '_') {
                j += 1;
            }
            out.tokens.push(Token {
                line,
                text: "<lit>".into(),
            });
            saw_token_on_line = true;
            i = j;
            continue;
        }
        // identifier / keyword (incl. r#raw idents, caught above only when
        // followed by a quote)
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < chars.len() && (chars[j].is_alphanumeric() || chars[j] == '_') {
                j += 1;
            }
            out.tokens.push(Token {
                line,
                text: chars[i..j].iter().collect(),
            });
            saw_token_on_line = true;
            i = j;
            continue;
        }
        // punctuation: one token per character
        out.tokens.push(Token {
            line,
            text: c.to_string(),
        });
        saw_token_on_line = true;
        i += 1;
    }
    out
}

/// If `chars[i..]` starts a raw/byte string (`r"`, `b"`, `br#"` ...),
/// consume it and return (index past the literal, newlines crossed).
fn try_string_prefix(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    let mut is_raw = false;
    for _ in 0..2 {
        match chars.get(j) {
            Some('r') => {
                is_raw = true;
                j += 1;
            }
            Some('b') => {
                j += 1;
            }
            _ => break,
        }
    }
    let mut hashes = 0usize;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) != Some(&'"') {
        return None;
    }
    if hashes > 0 && !is_raw {
        return None;
    }
    Some(consume_string(chars, j + 1, hashes, !is_raw))
}

/// Consume a string body starting just after the opening quote; returns
/// (index past the closing delimiter, newlines crossed).  `escapes` is
/// false inside raw strings.
fn consume_string(chars: &[char], start: usize, hashes: usize, escapes: bool) -> (usize, usize) {
    let mut j = start;
    let mut lines = 0usize;
    while j < chars.len() {
        let c = chars[j];
        if c == '\n' {
            lines += 1;
            j += 1;
            continue;
        }
        if escapes && c == '\\' {
            j += 2;
            continue;
        }
        if c == '"' {
            // need `hashes` trailing '#'s to close a raw string
            let mut k = j + 1;
            let mut seen = 0usize;
            while seen < hashes && chars.get(k) == Some(&'#') {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return (k, lines);
            }
        }
        j += 1;
    }
    (j, lines)
}

/// Token index ranges `[start, end)` covered by `#[cfg(test)] mod ... { }`
/// blocks.  Intervening attributes between the cfg gate and the `mod`
/// keyword are skipped.
pub fn test_mod_ranges(tokens: &[Token]) -> Vec<(usize, usize)> {
    const GATE: [&str; 7] = ["#", "[", "cfg", "(", "test", ")", "]"];
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i + GATE.len() <= tokens.len() {
        if !GATE
            .iter()
            .zip(&tokens[i..])
            .all(|(want, tok)| *want == tok.text)
        {
            i += 1;
            continue;
        }
        let mut j = i + GATE.len();
        // skip further attributes
        while tokens.get(j).map(|t| t.text.as_str()) == Some("#")
            && tokens.get(j + 1).map(|t| t.text.as_str()) == Some("[")
        {
            let mut depth = 0usize;
            j += 1;
            while j < tokens.len() {
                match tokens[j].text.as_str() {
                    "[" => depth += 1,
                    "]" => {
                        depth -= 1;
                        if depth == 0 {
                            j += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
        }
        if tokens.get(j).map(|t| t.text.as_str()) != Some("mod") {
            i += 1;
            continue;
        }
        // find the opening brace, then its match
        while j < tokens.len() && tokens[j].text != "{" {
            j += 1;
        }
        let mut depth = 0usize;
        while j < tokens.len() {
            match tokens[j].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        ranges.push((i, j));
        i = j;
    }
    ranges
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).tokens.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_and_paths() {
        assert_eq!(
            texts("Instant::now()"),
            vec!["Instant", ":", ":", "now", "(", ")"]
        );
    }

    #[test]
    fn strings_and_comments_do_not_leak() {
        let t = texts("let s = \"Instant::now()\"; // HashMap\n/* SystemTime */ let x = 1;");
        assert!(!t.contains(&"HashMap".to_string()));
        assert!(!t.contains(&"SystemTime".to_string()));
        assert!(!t.contains(&"now".to_string()));
        assert!(t.contains(&"let".to_string()));
    }

    #[test]
    fn raw_strings_and_lifetimes() {
        let t = texts("fn f<'a>(x: &'a str) { let r = r#\"thread::spawn\"#; }");
        assert!(!t.contains(&"spawn".to_string()));
        assert!(t.contains(&"fn".to_string()));
        assert!(!t.contains(&"a".to_string()), "lifetime leaked: {t:?}");
    }

    #[test]
    fn char_literals() {
        let t = texts("let c = 'x'; let n = '\\n'; let q = ','; m.split(',')");
        assert!(t.contains(&"<lit>".to_string()));
        assert!(!t.contains(&"x".to_string()));
        assert!(!t.contains(&"n".to_string()));
    }

    #[test]
    fn comment_lines_and_ownership() {
        let l = lex("let a = 1; // trailing\n  // own line\nlet b = 2;");
        assert_eq!(l.comments.len(), 2);
        assert!(!l.comments[0].own_line);
        assert_eq!(l.comments[0].line, 1);
        assert!(l.comments[1].own_line);
        assert_eq!(l.comments[1].line, 2);
    }

    #[test]
    fn line_numbers_cross_strings() {
        let l = lex("let s = \"a\nb\";\nInstant::now()");
        let inst = l.tokens.iter().find(|t| t.text == "Instant").unwrap();
        assert_eq!(inst.line, 3);
    }

    #[test]
    fn test_mod_range_detection() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n  #[test]\n  fn t() { std::thread::spawn(|| {}); }\n}\nfn b() {}";
        let l = lex(src);
        let ranges = test_mod_ranges(&l.tokens);
        assert_eq!(ranges.len(), 1);
        let (s, e) = ranges[0];
        let inside: Vec<_> = l.tokens[s..e].iter().map(|t| t.text.as_str()).collect();
        assert!(inside.contains(&"spawn"));
        // fn b survives outside
        assert!(l.tokens[e..].iter().any(|t| t.text == "b"));
    }
}
