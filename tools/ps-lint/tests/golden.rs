//! Golden test: the known-bad fixture tree under `tests/fixtures/` must
//! produce exactly the report in `tests/fixtures/expected.json`, and the
//! clean tree under `tests/fixtures_clean/` must exit 0.
//!
//! JSON comparison is structural (parsed via `util::json`), so the golden
//! file stays whitespace-insensitive while field values match exactly.

use pilot_streaming::util::json::Json;
use std::path::{Path, PathBuf};
use std::process::Command;

fn fixtures(which: &str) -> PathBuf {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    root.join("tests").join(which)
}

fn scan(which: &str) -> ps_lint::Report {
    let root = fixtures(which);
    ps_lint::run_from_config_file(&root, &root.join("ps-lint.toml")).expect("scan fixtures")
}

fn expected() -> Json {
    let text = std::fs::read_to_string(fixtures("fixtures").join("expected.json"))
        .expect("read expected.json");
    Json::from_str_slice(&text).expect("parse expected.json")
}

#[test]
fn bad_fixtures_match_golden_report() {
    let report = scan("fixtures");
    let actual = report.to_json();
    let want = expected();
    assert_eq!(
        actual,
        want,
        "fixture report drifted from golden:\n--- actual ---\n{}\n--- expected ---\n{}",
        actual.pretty(),
        want.pretty()
    );
    assert_eq!(report.exit_code(), 1);
}

#[test]
fn every_bad_fixture_contributes_a_finding() {
    let report = scan("fixtures");
    for file in [
        "src/bad_waiver.rs",
        "src/conserved_accounting.rs",
        "src/entropy.rs",
        "src/hash_iteration.rs",
        "src/hot_path_lock.rs",
        "src/thread_spawn.rs",
        "src/unused_waiver.rs",
        "src/wall_clock.rs",
    ] {
        assert!(
            report.findings.iter().any(|f| f.file == file),
            "no finding for {file}"
        );
    }
    // the waived fixture shows up waived, never as a finding
    assert!(report.findings.iter().all(|f| f.file != "src/waived.rs"));
    assert!(report.waived.iter().any(|w| w.file == "src/waived.rs"));
}

#[test]
fn clean_tree_is_clean() {
    let report = scan("fixtures_clean");
    assert!(report.findings.is_empty(), "{:?}", report.findings);
    assert!(report.waived.is_empty());
    assert_eq!(report.exit_code(), 0);
}

#[test]
fn binary_exits_1_on_bad_tree_with_golden_json() {
    let out = Command::new(env!("CARGO_BIN_EXE_ps-lint"))
        .args(["--root", fixtures("fixtures").to_str().unwrap(), "--format", "json"])
        .output()
        .expect("run ps-lint");
    assert_eq!(out.status.code(), Some(1), "{out:?}");
    let actual = Json::from_str_slice(&String::from_utf8(out.stdout).unwrap())
        .expect("binary emitted invalid JSON");
    assert_eq!(actual, expected());
}

#[test]
fn binary_exits_0_on_clean_tree() {
    let out = Command::new(env!("CARGO_BIN_EXE_ps-lint"))
        .args(["--root", fixtures("fixtures_clean").to_str().unwrap()])
        .output()
        .expect("run ps-lint");
    assert_eq!(out.status.code(), Some(0), "{out:?}");
    let text = String::from_utf8(out.stdout).unwrap();
    assert!(text.contains("0 finding(s)"), "{text}");
}

#[test]
fn binary_exits_2_on_usage_and_config_errors() {
    let out = Command::new(env!("CARGO_BIN_EXE_ps-lint"))
        .arg("--definitely-not-a-flag")
        .output()
        .expect("run ps-lint");
    assert_eq!(out.status.code(), Some(2), "{out:?}");

    let out = Command::new(env!("CARGO_BIN_EXE_ps-lint"))
        .args(["--config", "/definitely/not/a/config.toml"])
        .output()
        .expect("run ps-lint");
    assert_eq!(out.status.code(), Some(2), "{out:?}");
}
