//! A file every rule is happy with: BTreeMap instead of HashMap, a
//! covered accounting fn, no locks, no wall clock, no ambient entropy.

use std::collections::BTreeMap;

pub struct Pool {
    slots: BTreeMap<u32, u32>,
    workers: usize,
}

impl Pool {
    pub fn resize(&mut self, to: usize) {
        self.workers = to;
        debug_assert!(self.workers > 0, "pool cannot be emptied");
    }

    pub fn slot_sum(&self) -> u32 {
        self.slots.values().sum()
    }
}
