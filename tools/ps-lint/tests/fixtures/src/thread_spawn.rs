//! Fixture: R3 — direct thread spawning outside the worker pool.
//! The spawn inside the `#[cfg(test)]` module must NOT be flagged.

pub fn fan_out() -> i32 {
    let h = std::thread::spawn(|| 7);
    h.join().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn spawning_in_tests_is_fine() {
        std::thread::spawn(|| ()).join().unwrap();
    }
}
