//! Fixture: R1 — wall-clock reads outside the allowlist.

use std::time::{Instant, SystemTime};

pub fn elapsed() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn stamp() -> SystemTime {
    SystemTime::now()
}
