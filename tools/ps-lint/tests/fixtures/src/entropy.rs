//! Fixture: R4 — ambient entropy instead of util::rng seeded constructors.

pub fn roll() -> u32 {
    let mut rng = rand::thread_rng();
    rng.next_u32()
}
