//! Fixture: R5 — locks in a module tagged hot-path.

use std::sync::{Mutex, RwLock};

pub struct Buffers {
    pub pending: Mutex<Vec<u8>>,
    pub routes: RwLock<Vec<u16>>,
}
