//! Fixture: a correctly waived violation — it appears under `waived`,
//! not `findings`.

pub fn scratch_len() -> usize {
    // ps-lint: allow(hash-iteration): scratch map is read back in sorted order
    let table = std::collections::HashMap::<u32, u32>::new();
    table.len()
}
