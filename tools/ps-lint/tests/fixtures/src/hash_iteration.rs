//! Fixture: R2 — hash collections in a deterministic module.

use std::collections::HashMap;

pub fn histogram(keys: &[u64]) -> usize {
    let mut counts: HashMap<u64, usize> = HashMap::new();
    for k in keys {
        *counts.entry(*k).or_insert(0) += 1;
    }
    counts.len()
}
