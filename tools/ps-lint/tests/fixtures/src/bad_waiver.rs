//! Fixture: waivers missing the reason are findings themselves, and the
//! violation they failed to waive still reports.

pub fn stamp() -> std::time::Instant {
    std::time::Instant::now() // ps-lint: allow(wall-clock)
}
