//! Fixture: waivers that suppress nothing are findings.

// ps-lint: allow(thread-spawn): nothing here actually spawns
pub fn calm() {}
