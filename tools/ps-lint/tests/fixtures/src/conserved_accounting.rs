//! Fixture: R6 — accounting fn with no debug_assert!/test cover.

pub struct Pool {
    workers: usize,
}

impl Pool {
    pub fn resize(&mut self, to: usize) {
        self.workers = to;
    }
}
