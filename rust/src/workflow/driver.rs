//! The workflow driver: execute a [`WorkflowSpec`] end to end by running
//! each stage through the cohort sim core ([`run_sim_opts`]) and routing
//! its delivered messages into downstream stage brokers with the
//! integer-exact fan arithmetic of [`WorkflowSpec::flow_plan`].
//!
//! Every run carries a [`WorkflowAccounting`] proving the end-to-end
//! invariant — `sum(ingested) * ratios == sum(delivered) + in-flight` —
//! re-derived from the routed edge flows and asserted per edge
//! (`debug_assert!`) as the plan is walked.
//!
//! Stage timing composes by critical path: a stage's measurement window is
//! `ingested / throughput` (the sim core may pad the simulated message
//! count up to a partition multiple; the routed counts stay exact), and
//! [`schedule`] places each stage after its last-finishing predecessor.
//! End-to-end throughput is delivered messages over the makespan — the
//! quantity the `insight::workflow` critical-path model predicts from
//! per-stage USL fits.

use super::spec::{schedule, EdgeFlow, FlowPlan, WorkflowSpec};
use crate::engine::StepEngine;
use crate::miniapp::{run_sim_opts, PlatformKind, Scenario, SimOptions};
use crate::util::rng::SplitMix64;
use std::sync::Arc;

/// Extension-parameter name carrying the stage index into each stage's
/// [`Scenario`] (perturbs the engine seed stream per stage, and makes the
/// stage visible to engine factories).
pub const STAGE_PARAM: &str = "workflow_stage";

/// One executed stage.
#[derive(Debug, Clone, PartialEq)]
pub struct StageResult {
    pub stage: usize,
    pub name: String,
    pub platform: PlatformKind,
    /// Effective parallelism: `base parallelism * scale`, clamped by the
    /// platform's device cap (see [`effective_parallelism`]).
    pub parallelism: usize,
    /// Messages routed into this stage (exact).
    pub ingested: u64,
    /// Messages the sim core actually processed (may exceed `ingested` by
    /// ceil-padding to a partition multiple; routing uses `ingested`).
    pub simulated: u64,
    /// Measured stage throughput (msg/s).
    pub throughput: f64,
    /// Time to drain this stage's inflow: `ingested / throughput`.
    pub window_seconds: f64,
    pub service_mean: f64,
    pub service_p95: f64,
    pub service_cv: f64,
    pub warm_mean: f64,
    pub warm_cv: f64,
    pub broker_mean: f64,
    /// Critical-path schedule: this stage starts when its last
    /// predecessor finishes.
    pub start: f64,
    pub finish: f64,
}

/// End-to-end conservation record of one workflow run, re-derived from
/// the routed edge flows (not copied from the plan) so `verify` is a
/// proof, not a tautology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkflowAccounting {
    /// Messages ingested by source stages.
    pub ingested: u64,
    /// Messages delivered by sink stages.
    pub delivered: u64,
    /// Units parked at fan-in boundaries.
    pub in_flight: u64,
}

impl WorkflowAccounting {
    /// Re-check conservation against a spec and its routed flows: every
    /// edge satisfies `consumed * fan_out == emitted * fan_in + residual`,
    /// every stage's inflow is the sum of its incoming emissions (sources:
    /// `source_messages`), and the totals match this record.
    pub fn verify(&self, spec: &WorkflowSpec, flows: &[EdgeFlow]) -> Result<(), String> {
        if flows.len() != spec.edges.len() {
            return Err(format!(
                "accounting: {} flows for {} edges",
                flows.len(),
                spec.edges.len()
            ));
        }
        for (flow, edge) in flows.iter().zip(&spec.edges) {
            if !flow.conserved(edge) {
                return Err(format!(
                    "edge {} -> {}: {} * {} != {} * {} + {}",
                    edge.from, edge.to, flow.consumed, edge.fan_out, flow.emitted, edge.fan_in,
                    flow.residual
                ));
            }
        }
        let mut inflow = vec![0u64; spec.stages.len()];
        for &s in &spec.sources() {
            inflow[s] = spec.source_messages as u64;
        }
        for flow in flows {
            inflow[flow.to] += flow.emitted;
        }
        for (flow, edge) in flows.iter().zip(&spec.edges) {
            if flow.consumed != inflow[edge.from] {
                return Err(format!(
                    "edge {} -> {}: consumed {} != upstream inflow {}",
                    edge.from, edge.to, flow.consumed, inflow[edge.from]
                ));
            }
        }
        let ingested: u64 = spec.sources().iter().map(|&s| inflow[s]).sum();
        let delivered: u64 = spec.sinks().iter().map(|&s| inflow[s]).sum();
        let in_flight: u64 = flows.iter().map(|f| f.residual).sum();
        if (ingested, delivered, in_flight) != (self.ingested, self.delivered, self.in_flight) {
            return Err(format!(
                "totals drifted: recorded ({}, {}, {}) vs re-derived ({ingested}, {delivered}, {in_flight})",
                self.ingested, self.delivered, self.in_flight
            ));
        }
        Ok(())
    }
}

/// Result of one end-to-end workflow run.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkflowRunResult {
    pub workflow: String,
    /// The global scale factor applied to every stage's base parallelism.
    pub scale: usize,
    /// Per-stage measurements, indexed by stage.
    pub stages: Vec<StageResult>,
    /// Routed counts per spec edge.
    pub edges: Vec<EdgeFlow>,
    pub accounting: WorkflowAccounting,
    /// Stage indices on the critical path, source to sink.
    pub critical_path: Vec<usize>,
    /// Latest stage finish time.
    pub makespan: f64,
    /// End-to-end throughput: delivered messages / makespan.
    pub throughput: f64,
}

impl WorkflowRunResult {
    /// The critical-path stage with the largest window — where added
    /// parallelism buys the most end-to-end throughput.
    pub fn bottleneck(&self) -> usize {
        self.critical_path
            .iter()
            .copied()
            .max_by(|&a, &b| {
                self.stages[a]
                    .window_seconds
                    .partial_cmp(&self.stages[b].window_seconds)
                    .unwrap()
                    .then(b.cmp(&a))
            })
            .unwrap_or(0)
    }
}

/// Deterministic per-stage seed: independent streams per stage, stable
/// across scales (the engine factory mixes partitions in separately).
fn stage_seed(workflow_seed: u64, stage: usize) -> u64 {
    SplitMix64::new(workflow_seed ^ 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(stage as u64 + 1))
        .next_u64()
}

/// The parallelism a platform actually grants for a nominal request —
/// the edge device cap is the one built-in clamp.  Stage results, USL
/// fits, and the critical-path model all use effective parallelism so the
/// modeled curve matches what the sim provisioned.
pub fn effective_parallelism(platform: PlatformKind, nominal: usize) -> usize {
    let nominal = nominal.max(1);
    match platform {
        PlatformKind::Edge => nominal.min(crate::serverless::edge::EDGE_MAX_CONCURRENCY),
        _ => nominal,
    }
}

/// The scenario one stage provisions: its platform, its scaled
/// parallelism, the flow plan's routed inflow and message size.
pub fn stage_scenario(
    spec: &WorkflowSpec,
    plan: &FlowPlan,
    stage: usize,
    scale: usize,
) -> Scenario {
    let st = &spec.stages[stage];
    let mut sc = Scenario {
        platform: st.platform,
        partitions: (st.parallelism * scale.max(1)).max(1),
        points_per_message: plan.points[stage].max(1),
        centroids: st.centroids,
        memory_mb: st.memory_mb,
        messages: plan.inflow[stage] as usize,
        seed: stage_seed(spec.seed, stage),
        ..Scenario::default()
    };
    sc.set_extra(STAGE_PARAM, stage as u64);
    sc
}

/// Execute the workflow at a global `scale` factor: run every stage with
/// routed inflow through the sim core in topological order, compose the
/// critical-path schedule, and prove conservation.
pub fn run_workflow<F>(
    spec: &WorkflowSpec,
    scale: usize,
    engine_factory: &F,
    opts: SimOptions,
) -> Result<WorkflowRunResult, String>
where
    F: Fn(&Scenario) -> Arc<dyn StepEngine>,
{
    let plan = spec.flow_plan()?;
    let n = spec.stages.len();
    let mut stages: Vec<StageResult> = Vec::with_capacity(n);
    for (i, st) in spec.stages.iter().enumerate() {
        stages.push(StageResult {
            stage: i,
            name: st.name.clone(),
            platform: st.platform,
            parallelism: effective_parallelism(st.platform, st.parallelism * scale.max(1)),
            ingested: plan.inflow[i],
            simulated: 0,
            throughput: 0.0,
            window_seconds: 0.0,
            service_mean: 0.0,
            service_p95: 0.0,
            service_cv: 0.0,
            warm_mean: 0.0,
            warm_cv: 0.0,
            broker_mean: 0.0,
            start: 0.0,
            finish: 0.0,
        });
    }
    for &i in &plan.order {
        if plan.inflow[i] == 0 {
            // a fan-in boundary starved this stage (all units in flight):
            // nothing to simulate, zero window
            continue;
        }
        let sc = stage_scenario(spec, &plan, i, scale);
        let r = run_sim_opts(&sc, engine_factory(&sc), opts)
            .map_err(|e| format!("stage {:?}: {e}", spec.stages[i].name))?;
        let out = &mut stages[i];
        out.simulated = r.summary.messages as u64;
        debug_assert!(
            out.simulated >= out.ingested,
            "stage {:?}: sim core processed {} of {} routed messages",
            out.name,
            out.simulated,
            out.ingested
        );
        out.throughput = r.summary.throughput;
        out.window_seconds = if r.summary.throughput > 0.0 {
            out.ingested as f64 / r.summary.throughput
        } else {
            0.0
        };
        out.service_mean = r.summary.service.mean;
        out.service_p95 = r.summary.service.p95;
        out.service_cv = r.summary.service.cv();
        out.warm_mean = r.summary.service_warm.mean;
        out.warm_cv = r.summary.service_warm.cv();
        out.broker_mean = r.summary.broker.mean;
    }
    let windows: Vec<f64> = stages.iter().map(|s| s.window_seconds).collect();
    let (start, finish, critical_path, makespan) = schedule(spec, &plan, &windows);
    for (i, st) in stages.iter_mut().enumerate() {
        st.start = start[i];
        st.finish = finish[i];
    }
    let accounting = WorkflowAccounting {
        ingested: spec.sources().iter().map(|&s| plan.inflow[s]).sum(),
        delivered: plan.delivered(spec),
        in_flight: plan.in_flight(),
    };
    debug_assert!(
        accounting.verify(spec, &plan.edges).is_ok(),
        "workflow {:?}: conservation violated: {:?}",
        spec.name,
        accounting.verify(spec, &plan.edges)
    );
    let throughput = if makespan > 0.0 {
        accounting.delivered as f64 / makespan
    } else {
        0.0
    };
    Ok(WorkflowRunResult {
        workflow: spec.name.clone(),
        scale: scale.max(1),
        stages,
        edges: plan.edges,
        accounting,
        critical_path,
        makespan,
        throughput,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::sim::Dist;
    use crate::workflow::spec::PRESETS;

    fn factory(sc: &Scenario) -> Arc<dyn StepEngine> {
        // the analytic O(n·c) fallback covers every (points, centroids)
        // key the preset stages produce
        let mut e = CalibratedEngine::new(sc.seed ^ sc.partitions as u64);
        e.insert((256, 16), Dist::Const(0.05));
        Arc::new(e)
    }

    #[test]
    fn every_preset_runs_with_conserved_accounting() {
        for name in PRESETS {
            let wf = WorkflowSpec::preset(name).unwrap().with_source_messages(16);
            let r = run_workflow(&wf, 1, &factory, SimOptions::default()).unwrap();
            r.accounting.verify(&wf, &r.edges).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(r.makespan > 0.0, "{name}");
            assert!(r.throughput > 0.0, "{name}");
            assert!(!r.critical_path.is_empty(), "{name}");
            for st in r.stages.iter().filter(|s| s.ingested > 0) {
                assert!(st.throughput > 0.0, "{name}/{}", st.name);
                assert!(st.simulated >= st.ingested, "{name}/{}", st.name);
            }
        }
    }

    #[test]
    fn run_is_deterministic() {
        let wf = WorkflowSpec::ml_inference().with_source_messages(12).with_seed(7);
        let a = run_workflow(&wf, 2, &factory, SimOptions::default()).unwrap();
        let b = run_workflow(&wf, 2, &factory, SimOptions::default()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn scale_raises_end_to_end_throughput() {
        let wf = WorkflowSpec::word_count().with_source_messages(16);
        let t1 = run_workflow(&wf, 1, &factory, SimOptions::default()).unwrap().throughput;
        let t4 = run_workflow(&wf, 4, &factory, SimOptions::default()).unwrap().throughput;
        assert!(t4 > t1, "scale 4 {t4} must beat scale 1 {t1}");
    }

    #[test]
    fn starved_stages_are_skipped_not_failed() {
        // one source message cannot satisfy word-count's 16-way shuffle
        let wf = WorkflowSpec::word_count().with_source_messages(1);
        let r = run_workflow(&wf, 1, &factory, SimOptions::default()).unwrap();
        assert_eq!(r.accounting.delivered, 0);
        assert!(r.accounting.in_flight > 0);
        assert_eq!(r.stages[2].throughput, 0.0);
        r.accounting.verify(&wf, &r.edges).unwrap();
    }

    #[test]
    fn bottleneck_sits_on_the_critical_path() {
        let wf = WorkflowSpec::ml_training().with_source_messages(16);
        let r = run_workflow(&wf, 2, &factory, SimOptions::default()).unwrap();
        let b = r.bottleneck();
        assert!(r.critical_path.contains(&b));
        let w = r.stages[b].window_seconds;
        for &s in &r.critical_path {
            assert!(r.stages[s].window_seconds <= w + 1e-12);
        }
    }
}
