//! Workflow graphs: a [`WorkflowSpec`] is a DAG of streaming stages, each
//! stage a pilot on any registered platform, each edge carrying a message
//! transform plus a fan-out/fan-in ratio.
//!
//! The flow arithmetic is **integer-exact** so conservation is provable,
//! not approximate.  For an edge `from -> to` with ratio `fan_out :
//! fan_in`, every message the upstream stage delivers expands into
//! `fan_out` units, and every `fan_in` units coalesce into one downstream
//! message:
//!
//! ```text
//! units    = consumed * fan_out
//! emitted  = units / fan_in          (integer division)
//! residual = units % fan_in          (units buffered at the edge, awaiting fan-in)
//! =>  consumed * fan_out == emitted * fan_in + residual      (per edge, exactly)
//! ```
//!
//! Summed over a topological order this gives the end-to-end invariant the
//! driver asserts on every run: ingested messages, multiplied through the
//! edge ratios, equal delivered messages plus the in-flight units parked
//! at fan-in boundaries.
//!
//! Four ground-truth graphs from the serverless-workflow literature ship
//! as named presets — [`WorkflowSpec::finra`],
//! [`WorkflowSpec::ml_training`], [`WorkflowSpec::ml_inference`],
//! [`WorkflowSpec::word_count`] — mixing serverless, HPC, and edge stages,
//! reachable from `run --workflow <name>`, `sweep --grid workflow`, and
//! TOML (`workflows = [...]`).

use crate::miniapp::PlatformKind;

/// How an edge reshapes the payload of the messages it carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MessageTransform {
    /// Downstream messages keep the upstream point count.
    Identity,
    /// Scale the point count by `num / den` (ceiling, floor of 1 point).
    Scale { num: u32, den: u32 },
    /// Replace the point count outright (re-encode, re-sample).
    Resize { points: usize },
}

impl MessageTransform {
    /// Points per downstream message given `points` per upstream message.
    pub fn apply(self, points: usize) -> usize {
        match self {
            Self::Identity => points.max(1),
            Self::Scale { num, den } => {
                let den = den.max(1) as usize;
                (points * num as usize).div_ceil(den).max(1)
            }
            Self::Resize { points } => points.max(1),
        }
    }
}

/// One stage of the workflow: a streaming pilot on a registered platform.
#[derive(Debug, Clone, PartialEq)]
pub struct StageSpec {
    pub name: String,
    pub platform: PlatformKind,
    /// Base parallelism at workflow scale 1; the driver provisions
    /// `parallelism * scale` partitions (platform caps still apply).
    pub parallelism: usize,
    /// Points per message this stage *generates* when it is a source;
    /// non-source stages derive their message size from incoming edges.
    pub points_per_message: usize,
    pub centroids: usize,
    pub memory_mb: u32,
}

impl StageSpec {
    pub fn new(name: impl Into<String>, platform: PlatformKind, parallelism: usize) -> Self {
        Self {
            name: name.into(),
            platform,
            parallelism: parallelism.max(1),
            points_per_message: 1_024,
            centroids: 128,
            memory_mb: 1_024,
        }
    }

    pub fn with_workload(mut self, points_per_message: usize, centroids: usize) -> Self {
        self.points_per_message = points_per_message.max(1);
        self.centroids = centroids.max(1);
        self
    }

    pub fn with_memory(mut self, memory_mb: u32) -> Self {
        self.memory_mb = memory_mb;
        self
    }
}

/// One directed edge: messages delivered by `from` are routed into the
/// broker of `to`, expanded `fan_out`-fold and coalesced `fan_in`-fold.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EdgeSpec {
    pub from: usize,
    pub to: usize,
    /// Units produced per consumed upstream message (>= 1).
    pub fan_out: u64,
    /// Units coalesced per emitted downstream message (>= 1).
    pub fan_in: u64,
    pub transform: MessageTransform,
}

impl EdgeSpec {
    pub fn new(from: usize, to: usize) -> Self {
        Self {
            from,
            to,
            fan_out: 1,
            fan_in: 1,
            transform: MessageTransform::Identity,
        }
    }

    pub fn with_ratio(mut self, fan_out: u64, fan_in: u64) -> Self {
        self.fan_out = fan_out.max(1);
        self.fan_in = fan_in.max(1);
        self
    }

    pub fn with_transform(mut self, transform: MessageTransform) -> Self {
        self.transform = transform;
        self
    }
}

/// The exact routed flow of one edge for a given source load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EdgeFlow {
    pub from: usize,
    pub to: usize,
    /// Upstream messages consumed by this edge.
    pub consumed: u64,
    /// Downstream messages emitted into `to`'s broker.
    pub emitted: u64,
    /// Units left buffered at the fan-in boundary (in-flight).
    pub residual: u64,
}

impl EdgeFlow {
    /// The per-edge conservation identity, exactly.
    pub fn conserved(&self, edge: &EdgeSpec) -> bool {
        self.consumed * edge.fan_out == self.emitted * edge.fan_in + self.residual
    }
}

/// The resolved flow of a workflow at a given source load: per-stage
/// inflow and message size, per-edge routed counts, in topological order.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowPlan {
    /// Stage indices in deterministic topological order (Kahn, smallest
    /// index first among ready stages).
    pub order: Vec<usize>,
    /// Messages ingested by each stage (sources: `source_messages`).
    pub inflow: Vec<u64>,
    /// Points per message entering each stage.
    pub points: Vec<usize>,
    /// Routed counts, one per spec edge (spec edge order).
    pub edges: Vec<EdgeFlow>,
}

impl FlowPlan {
    /// Total messages delivered by sink stages.
    pub fn delivered(&self, spec: &WorkflowSpec) -> u64 {
        spec.sinks().iter().map(|&s| self.inflow[s]).sum()
    }

    /// Total units parked at fan-in boundaries.
    pub fn in_flight(&self) -> u64 {
        self.edges.iter().map(|e| e.residual).sum()
    }
}

/// A DAG of streaming stages with ratio-carrying edges.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkflowSpec {
    pub name: String,
    pub stages: Vec<StageSpec>,
    pub edges: Vec<EdgeSpec>,
    /// Messages ingested by *each* source stage.
    pub source_messages: usize,
    pub seed: u64,
}

/// The preset workflow names, in preset-id order (`workflow` axis levels).
pub const PRESETS: [&str; 4] = ["finra", "ml-training", "ml-inference", "word-count"];

impl WorkflowSpec {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            stages: Vec::new(),
            edges: Vec::new(),
            source_messages: 64,
            seed: 42,
        }
    }

    pub fn with_source_messages(mut self, messages: usize) -> Self {
        self.source_messages = messages.max(1);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Append a stage, returning its index.
    pub fn stage(&mut self, stage: StageSpec) -> usize {
        self.stages.push(stage);
        self.stages.len() - 1
    }

    pub fn edge(&mut self, edge: EdgeSpec) {
        self.edges.push(edge);
    }

    /// Stage indices with no incoming edges.
    pub fn sources(&self) -> Vec<usize> {
        (0..self.stages.len())
            .filter(|&s| self.edges.iter().all(|e| e.to != s))
            .collect()
    }

    /// Stage indices with no outgoing edges.
    pub fn sinks(&self) -> Vec<usize> {
        (0..self.stages.len())
            .filter(|&s| self.edges.iter().all(|e| e.from != s))
            .collect()
    }

    /// Structural validation: index bounds, positive ratios, unique stage
    /// names, acyclicity, at least one source.
    pub fn validate(&self) -> Result<(), String> {
        if self.stages.is_empty() {
            return Err(format!("workflow {:?}: no stages", self.name));
        }
        if self.source_messages == 0 {
            return Err(format!("workflow {:?}: source_messages must be >= 1", self.name));
        }
        for (i, st) in self.stages.iter().enumerate() {
            if st.name.is_empty() {
                return Err(format!("workflow {:?}: stage {i} has an empty name", self.name));
            }
            if st.parallelism == 0 {
                return Err(format!("stage {:?}: parallelism must be >= 1", st.name));
            }
            if self.stages[..i].iter().any(|o| o.name == st.name) {
                return Err(format!("workflow {:?}: duplicate stage {:?}", self.name, st.name));
            }
        }
        for e in &self.edges {
            if e.from >= self.stages.len() || e.to >= self.stages.len() {
                return Err(format!(
                    "workflow {:?}: edge {} -> {} out of bounds",
                    self.name, e.from, e.to
                ));
            }
            if e.from == e.to {
                return Err(format!("workflow {:?}: self-edge on stage {}", self.name, e.from));
            }
            if e.fan_out == 0 || e.fan_in == 0 {
                return Err(format!(
                    "workflow {:?}: edge {} -> {} has a zero ratio",
                    self.name, e.from, e.to
                ));
            }
        }
        if self.sources().is_empty() {
            return Err(format!("workflow {:?}: no source stage", self.name));
        }
        self.topo_order().map(|_| ())
    }

    /// Deterministic topological order (Kahn's algorithm; among ready
    /// stages the smallest index goes first), or the cycle error.
    pub fn topo_order(&self) -> Result<Vec<usize>, String> {
        let n = self.stages.len();
        let mut indegree = vec![0usize; n];
        for e in &self.edges {
            indegree[e.to] += 1;
        }
        let mut order = Vec::with_capacity(n);
        let mut done = vec![false; n];
        while order.len() < n {
            let Some(next) = (0..n).find(|&s| !done[s] && indegree[s] == 0) else {
                return Err(format!("workflow {:?}: cycle among stages", self.name));
            };
            done[next] = true;
            order.push(next);
            for e in self.edges.iter().filter(|e| e.from == next) {
                indegree[e.to] -= 1;
            }
        }
        Ok(order)
    }

    /// Resolve the exact routed flow: walk the topological order, feed
    /// each source `source_messages`, and route every edge with the
    /// integer-exact fan arithmetic.  Message sizes propagate along edges
    /// (a stage fed by several edges processes the largest incoming
    /// payload).
    pub fn flow_plan(&self) -> Result<FlowPlan, String> {
        self.validate()?;
        let order = self.topo_order()?;
        let n = self.stages.len();
        let mut inflow = vec![0u64; n];
        let mut points = vec![0usize; n];
        for &s in &self.sources() {
            inflow[s] = self.source_messages as u64;
            points[s] = self.stages[s].points_per_message.max(1);
        }
        let mut edges = vec![
            EdgeFlow {
                from: 0,
                to: 0,
                consumed: 0,
                emitted: 0,
                residual: 0
            };
            self.edges.len()
        ];
        for &s in &order {
            for (i, e) in self.edges.iter().enumerate().filter(|(_, e)| e.from == s) {
                let consumed = inflow[s];
                let units = consumed * e.fan_out;
                let emitted = units / e.fan_in;
                let residual = units % e.fan_in;
                debug_assert_eq!(
                    consumed * e.fan_out,
                    emitted * e.fan_in + residual,
                    "edge {} -> {}: fan arithmetic must conserve units",
                    e.from,
                    e.to
                );
                edges[i] = EdgeFlow {
                    from: e.from,
                    to: e.to,
                    consumed,
                    emitted,
                    residual,
                };
                inflow[e.to] += emitted;
                let incoming = e.transform.apply(points[s]);
                points[e.to] = points[e.to].max(incoming);
            }
        }
        Ok(FlowPlan {
            order,
            inflow,
            points,
            edges,
        })
    }

    /// Resolve a preset by name (the `--workflow` / TOML vocabulary).
    pub fn preset(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().replace('_', "-").as_str() {
            "finra" => Some(Self::finra()),
            "ml-training" => Some(Self::ml_training()),
            "ml-inference" => Some(Self::ml_inference()),
            "word-count" => Some(Self::word_count()),
            _ => None,
        }
    }

    /// Resolve a preset by its `workflow` axis level (sweep grids bind
    /// integer levels; the id is the index into [`PRESETS`]).
    pub fn preset_by_id(id: u64) -> Option<Self> {
        PRESETS.get(id as usize).and_then(|n| Self::preset(n))
    }

    /// The `workflow` axis level of a preset name — inverse of
    /// [`preset_by_id`](Self::preset_by_id).
    pub fn preset_id(name: &str) -> Option<u64> {
        let canon = name.to_ascii_lowercase().replace('_', "-");
        PRESETS.iter().position(|&p| p == canon).map(|i| i as u64)
    }

    /// FINRA data validation (AWS case study): two ingest feeds — trade
    /// records from the cloud, market data from an edge gateway — merged
    /// and validated, each record fanned out against four audit-rule sets,
    /// results coalesced into one aggregate stream on HPC.
    pub fn finra() -> Self {
        let mut wf = Self::new("finra");
        let trades = wf.stage(
            StageSpec::new("fetch-trades", PlatformKind::Lambda, 2).with_workload(2_048, 64),
        );
        let market = wf.stage(
            StageSpec::new("fetch-market", PlatformKind::Edge, 1).with_workload(1_024, 32),
        );
        let validate = wf.stage(
            StageSpec::new("validate", PlatformKind::Lambda, 2)
                .with_workload(2_048, 128)
                .with_memory(1_792),
        );
        let audit = wf.stage(
            StageSpec::new("audit", PlatformKind::Lambda, 4)
                .with_workload(512, 256)
                .with_memory(3_008),
        );
        let aggregate = wf.stage(
            StageSpec::new("aggregate", PlatformKind::DaskWrangler, 2)
                .with_workload(512, 64)
                .with_memory(3_008),
        );
        wf.edge(EdgeSpec::new(trades, validate));
        wf.edge(EdgeSpec::new(market, validate).with_transform(MessageTransform::Resize {
            points: 2_048,
        }));
        // every validated record is checked against four audit-rule sets
        wf.edge(
            EdgeSpec::new(validate, audit)
                .with_ratio(4, 1)
                .with_transform(MessageTransform::Scale { num: 1, den: 4 }),
        );
        wf.edge(EdgeSpec::new(audit, aggregate).with_ratio(1, 8));
        wf
    }

    /// ML training (Orion / RMMap): ingest → preprocess → mini-batch
    /// training on HPC (4 preprocessed records per batch) → validation.
    pub fn ml_training() -> Self {
        let mut wf = Self::new("ml-training");
        let ingest = wf.stage(
            StageSpec::new("ingest", PlatformKind::Lambda, 2)
                .with_workload(4_096, 128)
                .with_memory(1_792),
        );
        let preprocess = wf.stage(
            StageSpec::new("preprocess", PlatformKind::Lambda, 2)
                .with_workload(2_048, 256)
                .with_memory(3_008),
        );
        let train = wf.stage(
            StageSpec::new("train", PlatformKind::DaskWrangler, 4).with_workload(8_000, 1_024),
        );
        let validate = wf.stage(
            StageSpec::new("validate", PlatformKind::Lambda, 1).with_workload(1_000, 128),
        );
        wf.edge(
            EdgeSpec::new(ingest, preprocess)
                .with_transform(MessageTransform::Scale { num: 1, den: 2 }),
        );
        wf.edge(
            EdgeSpec::new(preprocess, train)
                .with_ratio(1, 4)
                .with_transform(MessageTransform::Resize { points: 8_000 }),
        );
        wf.edge(
            EdgeSpec::new(train, validate)
                .with_ratio(1, 2)
                .with_transform(MessageTransform::Scale { num: 1, den: 8 }),
        );
        wf
    }

    /// ML inference (RMMap): the diamond — an API gateway fans requests
    /// through edge preprocessing into two parallel model branches
    /// (serverless CNN, HPC ensemble) whose scores re-join at a ranker.
    pub fn ml_inference() -> Self {
        let mut wf = Self::new("ml-inference");
        let gateway = wf.stage(
            StageSpec::new("gateway", PlatformKind::Lambda, 2).with_workload(1_024, 32),
        );
        let preprocess = wf.stage(
            StageSpec::new("preprocess", PlatformKind::Edge, 1).with_workload(2_048, 128),
        );
        let infer_a = wf.stage(
            StageSpec::new("infer-serverless", PlatformKind::Lambda, 2)
                .with_workload(2_048, 1_024)
                .with_memory(3_008),
        );
        let infer_b = wf.stage(
            StageSpec::new("infer-hpc", PlatformKind::DaskWrangler, 2).with_workload(1_024, 512),
        );
        let rank =
            wf.stage(StageSpec::new("rank", PlatformKind::Lambda, 1).with_workload(1_024, 64));
        wf.edge(EdgeSpec::new(gateway, preprocess).with_transform(MessageTransform::Resize {
            points: 2_048,
        }));
        wf.edge(EdgeSpec::new(preprocess, infer_a));
        wf.edge(
            EdgeSpec::new(preprocess, infer_b)
                .with_transform(MessageTransform::Scale { num: 1, den: 2 }),
        );
        wf.edge(EdgeSpec::new(infer_a, rank).with_ratio(1, 2));
        wf.edge(EdgeSpec::new(infer_b, rank).with_ratio(1, 2));
        wf
    }

    /// MapReduce word count (FunctionBench): each document splits into 8
    /// chunks mapped in parallel, 16 map outputs shuffle into one reduce
    /// record on HPC, reduce outputs coalesce at a collector.
    pub fn word_count() -> Self {
        let mut wf = Self::new("word-count");
        let split = wf.stage(
            StageSpec::new("split", PlatformKind::Lambda, 2)
                .with_workload(8_000, 64)
                .with_memory(1_792),
        );
        let map = wf.stage(
            StageSpec::new("map", PlatformKind::Lambda, 4).with_workload(1_000, 128),
        );
        let reduce = wf.stage(
            StageSpec::new("reduce", PlatformKind::DaskWrangler, 2).with_workload(4_000, 256),
        );
        let collect = wf.stage(
            StageSpec::new("collect", PlatformKind::Lambda, 1).with_workload(1_000, 32),
        );
        wf.edge(
            EdgeSpec::new(split, map)
                .with_ratio(8, 1)
                .with_transform(MessageTransform::Scale { num: 1, den: 8 }),
        );
        wf.edge(
            EdgeSpec::new(map, reduce)
                .with_ratio(1, 16)
                .with_transform(MessageTransform::Resize { points: 4_000 }),
        );
        wf.edge(
            EdgeSpec::new(reduce, collect)
                .with_ratio(1, 4)
                .with_transform(MessageTransform::Scale { num: 1, den: 4 }),
        );
        wf
    }
}

/// Critical-path schedule over per-stage windows: each stage starts when
/// its last predecessor finishes.  Returns `(start, finish)` per stage,
/// the critical path (sink with the latest finish, predecessors
/// backtracked by latest finish, ties to the smallest index), and the
/// makespan.  Shared by the driver (measured windows) and the model
/// (predicted windows) so the two sides are comparable by construction.
pub fn schedule(
    spec: &WorkflowSpec,
    plan: &FlowPlan,
    windows: &[f64],
) -> (Vec<f64>, Vec<f64>, Vec<usize>, f64) {
    let n = spec.stages.len();
    let mut start = vec![0.0f64; n];
    let mut finish = vec![0.0f64; n];
    for &s in &plan.order {
        let ready = spec
            .edges
            .iter()
            .filter(|e| e.to == s)
            .map(|e| finish[e.from])
            .fold(0.0f64, f64::max);
        start[s] = ready;
        finish[s] = ready + windows[s];
    }
    let last = (0..n)
        .filter(|&s| plan.inflow[s] > 0)
        .max_by(|&a, &b| {
            finish[a]
                .partial_cmp(&finish[b])
                .unwrap()
                .then(b.cmp(&a)) // tie -> smallest index
        })
        .unwrap_or(0);
    let mut path = vec![last];
    let mut cur = last;
    loop {
        let pred = spec
            .edges
            .iter()
            .filter(|e| e.to == cur && plan.inflow[e.from] > 0)
            .map(|e| e.from)
            .max_by(|&a, &b| finish[a].partial_cmp(&finish[b]).unwrap().then(b.cmp(&a)));
        match pred {
            Some(p) => {
                path.push(p);
                cur = p;
            }
            None => break,
        }
    }
    path.reverse();
    let makespan = finish[last];
    (start, finish, path, makespan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate_and_are_dags() {
        for name in PRESETS {
            let wf = WorkflowSpec::preset(name).unwrap();
            wf.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(wf.name, name);
            assert!(!wf.sources().is_empty(), "{name}");
            assert!(!wf.sinks().is_empty(), "{name}");
            // id round-trip
            let id = WorkflowSpec::preset_id(name).unwrap();
            assert_eq!(WorkflowSpec::preset_by_id(id).unwrap().name, wf.name);
        }
        assert!(WorkflowSpec::preset("unknown").is_none());
    }

    #[test]
    fn every_preset_edge_conserves_units() {
        for name in PRESETS {
            // include loads that do NOT divide the fan ratios evenly
            for messages in [1usize, 7, 16, 33] {
                let wf = WorkflowSpec::preset(name).unwrap().with_source_messages(messages);
                let plan = wf.flow_plan().unwrap();
                for (flow, edge) in plan.edges.iter().zip(&wf.edges) {
                    assert!(
                        flow.conserved(edge),
                        "{name} m={messages}: edge {} -> {}",
                        edge.from,
                        edge.to
                    );
                }
            }
        }
    }

    #[test]
    fn cycle_is_rejected() {
        let mut wf = WorkflowSpec::new("loop");
        let a = wf.stage(StageSpec::new("a", PlatformKind::Lambda, 1));
        let b = wf.stage(StageSpec::new("b", PlatformKind::Lambda, 1));
        wf.edge(EdgeSpec::new(a, b));
        wf.edge(EdgeSpec::new(b, a));
        assert!(wf.validate().is_err());
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(WorkflowSpec::new("empty").validate().is_err());
        let mut dup = WorkflowSpec::new("dup");
        dup.stage(StageSpec::new("x", PlatformKind::Lambda, 1));
        dup.stage(StageSpec::new("x", PlatformKind::Lambda, 1));
        assert!(dup.validate().is_err());
        let mut oob = WorkflowSpec::new("oob");
        oob.stage(StageSpec::new("a", PlatformKind::Lambda, 1));
        oob.edge(EdgeSpec::new(0, 5));
        assert!(oob.validate().is_err());
    }

    #[test]
    fn transforms_shape_points() {
        assert_eq!(MessageTransform::Identity.apply(100), 100);
        assert_eq!(MessageTransform::Scale { num: 1, den: 4 }.apply(100), 25);
        assert_eq!(MessageTransform::Scale { num: 1, den: 3 }.apply(100), 34); // ceil
        assert_eq!(MessageTransform::Scale { num: 1, den: 1000 }.apply(10), 1); // floor of 1
        assert_eq!(MessageTransform::Resize { points: 512 }.apply(9), 512);
    }

    #[test]
    fn finra_flow_is_exact() {
        let wf = WorkflowSpec::finra().with_source_messages(16);
        let plan = wf.flow_plan().unwrap();
        // two sources feed validate: 16 + 16
        assert_eq!(plan.inflow[2], 32);
        // audit: 32 * 4 = 128; aggregate: 128 / 8 = 16
        assert_eq!(plan.inflow[3], 128);
        assert_eq!(plan.inflow[4], 16);
        assert_eq!(plan.delivered(&wf), 16);
        assert_eq!(plan.in_flight(), 0);
        // market feed is re-encoded up to the trade record size
        assert_eq!(plan.points[2], 2_048);
        // audit payloads shrink 4x
        assert_eq!(plan.points[3], 512);
    }

    #[test]
    fn word_count_residuals_stay_in_flight() {
        let wf = WorkflowSpec::word_count().with_source_messages(7);
        let plan = wf.flow_plan().unwrap();
        // split 7 -> 56 map chunks -> 56/16 = 3 reduce records, 8 units in flight
        assert_eq!(plan.inflow[1], 56);
        assert_eq!(plan.inflow[2], 3);
        assert_eq!(plan.edges[1].residual, 8);
        // reduce 3 -> 3/4 = 0 collected, 3 units in flight
        assert_eq!(plan.inflow[3], 0);
        assert_eq!(plan.in_flight(), 8 + 3);
    }

    #[test]
    fn schedule_respects_dependencies() {
        let wf = WorkflowSpec::ml_inference().with_source_messages(8);
        let plan = wf.flow_plan().unwrap();
        let windows: Vec<f64> = (0..wf.stages.len()).map(|i| 1.0 + i as f64).collect();
        let (start, finish, path, makespan) = schedule(&wf, &plan, &windows);
        for e in &wf.edges {
            assert!(start[e.to] >= finish[e.from] - 1e-12, "{} -> {}", e.from, e.to);
        }
        // the critical path ends at the latest-finishing stage
        let last = *path.last().unwrap();
        assert!(finish.iter().all(|&f| f <= finish[last] + 1e-12));
        assert!((makespan - finish[last]).abs() < 1e-12);
        // the path is connected source -> sink
        assert!(wf.sources().contains(&path[0]));
        for w in path.windows(2) {
            assert!(wf.edges.iter().any(|e| e.from == w[0] && e.to == w[1]));
        }
    }
}
