//! Workflow-graph applications: multi-stage streaming DAGs spanning
//! serverless, HPC, and edge platforms (the EILC pipelines the source
//! paper motivates but never models).
//!
//! - [`spec`] — [`WorkflowSpec`]: stages ([`StageSpec`]) on any registered
//!   platform, edges ([`EdgeSpec`]) carrying a [`MessageTransform`] and a
//!   fan-out/fan-in ratio, integer-exact flow resolution
//!   ([`WorkflowSpec::flow_plan`]), and the four ground-truth preset
//!   graphs (FINRA, ML training, ML inference, MapReduce word count).
//! - [`driver`] — [`run_workflow`]: execute each stage through the cohort
//!   sim core, route delivered messages into downstream brokers, compose
//!   the critical-path schedule, and prove end-to-end conservation
//!   ([`WorkflowAccounting`]).
//!
//! The modeling layer on top — per-stage USL fits composed into an
//! end-to-end critical-path prediction, the workflow sweep grid, and the
//! cross-stage rebalancing [`WorkflowTarget`](crate::insight::workflow::WorkflowTarget)
//! — lives in [`crate::insight::workflow`].

pub mod driver;
pub mod spec;

pub use driver::{
    effective_parallelism, run_workflow, stage_scenario, StageResult, WorkflowAccounting,
    WorkflowRunResult, STAGE_PARAM,
};
pub use spec::{
    schedule, EdgeFlow, EdgeSpec, FlowPlan, MessageTransform, StageSpec, WorkflowSpec, PRESETS,
};
