//! Universal Scalability Law modeling — StreamInsight's analytical core
//! (paper §IV-A): model, fitting (linearized + Levenberg–Marquardt),
//! held-out evaluation, and Amdahl/linear baselines.

pub mod baselines;
pub mod eval;
pub mod fit;
pub mod model;

pub use baselines::{fit_amdahl, fit_linear};
pub use eval::{rmse_vs_train_size, EvalPoint};
pub use fit::{fit, fit_linearized, fit_lm, fit_weighted, FitError, Obs, UslFit};
pub use model::UslParams;
