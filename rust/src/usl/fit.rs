//! Fitting USL to (N, T) observations.
//!
//! Two fitters, composed:
//! 1. **Linearized** (Gunther's quadratic transform): with
//!    `y = N/T`, `x1 = N−1`, `x2 = N(N−1)`,
//!    `y = 1/λ + (σ/λ)·x1 + (κ/λ)·x2` — ordinary least squares with
//!    intercept. Fast, closed-form, good starting point.
//! 2. **Levenberg–Marquardt** refinement on the nonlinear model in
//!    throughput space (the linearized fit minimizes error in 1/T space,
//!    which over-weights small-T points — the same reason the USL R
//!    package uses `nls`).
//!
//! Both enforce σ, κ ≥ 0 by clamping, and both accept per-observation
//! weights ([`fit_weighted`]) — the online recalibrator
//! (`insight::recalibrate`) feeds EWMA-recency weights so a drifting live
//! platform's newest samples dominate the re-fit.

use super::model::UslParams;
use crate::util::stats;

/// An observation: parallelism N with measured throughput T.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Obs {
    pub n: f64,
    pub t: f64,
}

impl Obs {
    pub fn new(n: f64, t: f64) -> Self {
        Self { n, t }
    }
}

/// Fit outcome.
#[derive(Debug, Clone)]
pub struct UslFit {
    pub params: UslParams,
    /// R² in throughput space over the training data.
    pub r2: f64,
    /// RMSE in throughput space over the training data.
    pub rmse: f64,
    /// Which fitter produced the final params ("linearized" | "lm").
    pub method: &'static str,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum FitError {
    #[error("need at least {0} observations, got {1}")]
    TooFew(usize, usize),
    #[error("observations must have N >= 1 and T > 0")]
    BadData,
}

fn validate(obs: &[Obs], min: usize) -> Result<(), FitError> {
    if obs.len() < min {
        return Err(FitError::TooFew(min, obs.len()));
    }
    if obs.iter().any(|o| o.n < 1.0 || o.t <= 0.0 || !o.t.is_finite()) {
        return Err(FitError::BadData);
    }
    Ok(())
}

fn validate_weights(obs: &[Obs], weights: &[f64]) -> Result<(), FitError> {
    if weights.len() != obs.len() {
        return Err(FitError::BadData);
    }
    if weights.iter().any(|w| !w.is_finite() || *w <= 0.0) {
        return Err(FitError::BadData);
    }
    Ok(())
}

fn metrics(params: &UslParams, obs: &[Obs]) -> (f64, f64) {
    let pred: Vec<f64> = obs.iter().map(|o| params.throughput(o.n)).collect();
    let actual: Vec<f64> = obs.iter().map(|o| o.t).collect();
    (
        stats::r_squared(&pred, &actual),
        stats::rmse(&pred, &actual),
    )
}

/// Weighted OLS with intercept on two regressors: minimize
/// Σ w (y − b0 − b1 x1 − b2 x2)².  Uniform weights reduce to plain OLS.
fn ols3(x1: &[f64], x2: &[f64], y: &[f64], w: &[f64]) -> (f64, f64, f64) {
    // weighted normal equations, 3x3 symmetric
    let sw: f64 = w.iter().sum();
    let s1: f64 = x1.iter().zip(w).map(|(a, w)| a * w).sum();
    let s2: f64 = x2.iter().zip(w).map(|(a, w)| a * w).sum();
    let s11: f64 = x1.iter().zip(w).map(|(a, w)| a * a * w).sum();
    let s22: f64 = x2.iter().zip(w).map(|(a, w)| a * a * w).sum();
    let s12: f64 = x1.iter().zip(x2).zip(w).map(|((a, b), w)| a * b * w).sum();
    let sy: f64 = y.iter().zip(w).map(|(a, w)| a * w).sum();
    let sy1: f64 = y.iter().zip(x1).zip(w).map(|((a, b), w)| a * b * w).sum();
    let sy2: f64 = y.iter().zip(x2).zip(w).map(|((a, b), w)| a * b * w).sum();

    // solve [sw s1 s2; s1 s11 s12; s2 s12 s22] b = [sy sy1 sy2]
    let a = [[sw, s1, s2], [s1, s11, s12], [s2, s12, s22]];
    let rhs = [sy, sy1, sy2];
    solve3(a, rhs).unwrap_or((sy / sw, 0.0, 0.0).into()).into()
}

struct Triple(f64, f64, f64);
impl From<(f64, f64, f64)> for Triple {
    fn from(t: (f64, f64, f64)) -> Self {
        Triple(t.0, t.1, t.2)
    }
}
impl From<Triple> for (f64, f64, f64) {
    fn from(t: Triple) -> Self {
        (t.0, t.1, t.2)
    }
}

/// Gaussian elimination for a 3x3 system.
fn solve3(mut a: [[f64; 3]; 3], mut b: [f64; 3]) -> Option<Triple> {
    for col in 0..3 {
        // partial pivot
        let piv = (col..3).max_by(|&i, &j| {
            a[i][col]
                .abs()
                .partial_cmp(&a[j][col].abs())
                .unwrap()
        })?;
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in (col + 1)..3 {
            let f = a[row][col] / a[col][col];
            for k in col..3 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0; 3];
    for row in (0..3).rev() {
        let mut acc = b[row];
        for k in (row + 1)..3 {
            acc -= a[row][k] * x[k];
        }
        x[row] = acc / a[row][row];
    }
    Some(Triple(x[0], x[1], x[2]))
}

/// Gunther's linearized least-squares fit.
pub fn fit_linearized(obs: &[Obs]) -> Result<UslFit, FitError> {
    fit_linearized_w(obs, &vec![1.0; obs.len()])
}

fn fit_linearized_w(obs: &[Obs], weights: &[f64]) -> Result<UslFit, FitError> {
    validate(obs, 3)?;
    validate_weights(obs, weights)?;
    let x1: Vec<f64> = obs.iter().map(|o| o.n - 1.0).collect();
    let x2: Vec<f64> = obs.iter().map(|o| o.n * (o.n - 1.0)).collect();
    let y: Vec<f64> = obs.iter().map(|o| o.n / o.t).collect();
    let (b0, b1, b2) = ols3(&x1, &x2, &y, weights);
    // y = 1/λ + (σ/λ) x1 + (κ/λ) x2
    let lambda = if b0 > 1e-12 {
        1.0 / b0
    } else {
        // degenerate intercept: fall back to λ from the N=1-ish point
        obs.iter()
            .min_by(|a, b| a.n.partial_cmp(&b.n).unwrap())
            .map(|o| o.t / o.n)
            .unwrap_or(1.0)
    };
    let params = UslParams::new(b1 * lambda, b2 * lambda, lambda);
    let (r2, rmse) = metrics(&params, obs);
    Ok(UslFit {
        params,
        r2,
        rmse,
        method: "linearized",
    })
}

/// Levenberg–Marquardt refinement in throughput space, seeded by the
/// linearized fit.
pub fn fit_lm(obs: &[Obs]) -> Result<UslFit, FitError> {
    fit_lm_w(obs, &vec![1.0; obs.len()])
}

fn fit_lm_w(obs: &[Obs], weights: &[f64]) -> Result<UslFit, FitError> {
    let seed = fit_linearized_w(obs, weights)?;
    let seed_p = [seed.params.sigma, seed.params.kappa, seed.params.lambda];
    let seed_sse = sse(seed_p, obs, weights);
    let mut p = [
        seed.params.sigma.max(1e-9),
        seed.params.kappa.max(1e-12),
        seed.params.lambda,
    ];
    let mut mu = 1e-3;
    let mut last_sse = sse(p, obs, weights);

    for _iter in 0..200 {
        // Jacobian (residual = T_pred - T_obs) via analytic partials
        let mut jtj = [[0.0f64; 3]; 3];
        let mut jtr = [0.0f64; 3];
        for (o, w) in obs.iter().zip(weights) {
            let n = o.n;
            let d = 1.0 + p[0] * (n - 1.0) + p[1] * n * (n - 1.0);
            let tp = p[2] * n / d;
            let r = tp - o.t;
            // ∂T/∂σ = -λ n (n-1) / d², ∂T/∂κ = -λ n² (n-1) / d², ∂T/∂λ = n/d
            let g = [
                -p[2] * n * (n - 1.0) / (d * d),
                -p[2] * n * n * (n - 1.0) / (d * d),
                n / d,
            ];
            for i in 0..3 {
                jtr[i] += w * g[i] * r;
                for j in 0..3 {
                    jtj[i][j] += w * g[i] * g[j];
                }
            }
        }
        // (JtJ + mu diag(JtJ)) delta = -Jtr
        let mut a = jtj;
        for i in 0..3 {
            a[i][i] += mu * jtj[i][i].max(1e-12);
        }
        let Some(Triple(d0, d1, d2)) = solve3(a, [-jtr[0], -jtr[1], -jtr[2]]) else {
            break;
        };
        let cand = [
            (p[0] + d0).max(0.0),
            (p[1] + d1).max(0.0),
            (p[2] + d2).max(1e-12),
        ];
        let cand_sse = sse(cand, obs, weights);
        if cand_sse < last_sse {
            let rel = (last_sse - cand_sse) / last_sse.max(1e-300);
            p = cand;
            last_sse = cand_sse;
            mu = (mu * 0.5).max(1e-12);
            if rel < 1e-12 {
                break;
            }
        } else {
            mu *= 4.0;
            if mu > 1e12 {
                break;
            }
        }
    }
    let params = UslParams::new(p[0], p[1], p[2]);
    let (r2, rmse) = metrics(&params, obs);
    // keep whichever fit is better in (weighted) throughput space — LM
    // should win; the reported r2/rmse stay unweighted for comparability.
    // `last_sse` already tracks the final candidate's weighted SSE, so no
    // extra passes over the window are needed here.
    if last_sse <= seed_sse {
        Ok(UslFit {
            params,
            r2,
            rmse,
            method: "lm",
        })
    } else {
        Ok(seed)
    }
}

fn sse(p: [f64; 3], obs: &[Obs], weights: &[f64]) -> f64 {
    obs.iter()
        .zip(weights)
        .map(|(o, w)| {
            let d = 1.0 + p[0] * (o.n - 1.0) + p[1] * o.n * (o.n - 1.0);
            let tp = p[2] * o.n / d;
            w * (tp - o.t) * (tp - o.t)
        })
        .sum()
}

/// Default fit = LM with linearized seeding (the USL R package approach).
pub fn fit(obs: &[Obs]) -> Result<UslFit, FitError> {
    fit_lm(obs)
}

/// Weighted default fit: both stages minimize the `weights`-scaled error.
/// Weights must be positive and finite, one per observation — the online
/// recalibrator passes recency weights so the newest live samples
/// dominate.  Uniform weights reproduce [`fit`] exactly.
pub fn fit_weighted(obs: &[Obs], weights: &[f64]) -> Result<UslFit, FitError> {
    fit_lm_w(obs, weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn synth(params: UslParams, ns: &[f64], noise_cv: f64, seed: u64) -> Vec<Obs> {
        let mut rng = Pcg32::seeded(seed);
        ns.iter()
            .map(|&n| {
                let t = params.throughput(n) * rng.normal_with(1.0, noise_cv).max(0.5);
                Obs::new(n, t)
            })
            .collect()
    }

    const NS: [f64; 7] = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0];

    #[test]
    fn exact_recovery_noise_free() {
        let truth = UslParams::new(0.08, 0.002, 120.0);
        let obs = synth(truth, &NS, 0.0, 1);
        for f in [fit_linearized(&obs).unwrap(), fit_lm(&obs).unwrap()] {
            assert!((f.params.sigma - truth.sigma).abs() < 1e-6, "{f:?}");
            assert!((f.params.kappa - truth.kappa).abs() < 1e-8, "{f:?}");
            assert!((f.params.lambda - truth.lambda).abs() < 1e-3, "{f:?}");
            assert!(f.r2 > 0.999999);
        }
    }

    #[test]
    fn recovery_with_noise() {
        let truth = UslParams::new(0.3, 0.01, 50.0);
        let obs = synth(truth, &NS, 0.03, 2);
        let f = fit(&obs).unwrap();
        assert!((f.params.sigma - truth.sigma).abs() < 0.1, "{:?}", f.params);
        assert!((f.params.kappa - truth.kappa).abs() < 0.005, "{:?}", f.params);
        assert!(f.r2 > 0.95, "r2={}", f.r2);
    }

    #[test]
    fn lm_beats_or_matches_linearized_under_noise() {
        let truth = UslParams::new(0.6, 0.05, 10.0);
        let mut lin_worse = 0;
        for seed in 0..10 {
            let obs = synth(truth, &NS, 0.05, seed);
            let lin = fit_linearized(&obs).unwrap();
            let lm = fit_lm(&obs).unwrap();
            assert!(lm.rmse <= lin.rmse + 1e-12);
            if lm.rmse < lin.rmse - 1e-12 {
                lin_worse += 1;
            }
        }
        assert!(lin_worse >= 5, "LM should usually improve: {lin_worse}/10");
    }

    #[test]
    fn near_linear_data_yields_tiny_coefficients() {
        // the Lambda regime: σ, κ ≈ 0
        let truth = UslParams::new(0.005, 0.00001, 30.0);
        let obs = synth(truth, &NS, 0.02, 3);
        let f = fit(&obs).unwrap();
        assert!(f.params.sigma < 0.05, "σ={}", f.params.sigma);
        assert!(f.params.kappa < 0.001, "κ={}", f.params.kappa);
    }

    #[test]
    fn retrograde_data_finds_peak() {
        let truth = UslParams::new(0.7, 0.06, 8.0);
        let obs = synth(truth, &[1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0], 0.0, 4);
        let f = fit(&obs).unwrap();
        let peak = f.params.peak_n().expect("retrograde must have a peak");
        assert!((peak - truth.peak_n().unwrap()).abs() < 0.5);
    }

    #[test]
    fn too_few_points_rejected() {
        let obs = vec![Obs::new(1.0, 10.0), Obs::new(2.0, 15.0)];
        assert!(matches!(fit(&obs), Err(FitError::TooFew(3, 2))));
    }

    #[test]
    fn bad_data_rejected() {
        let obs = vec![
            Obs::new(1.0, 10.0),
            Obs::new(2.0, 0.0),
            Obs::new(4.0, 20.0),
        ];
        assert!(matches!(fit(&obs), Err(FitError::BadData)));
    }

    #[test]
    fn uniform_weights_reproduce_the_unweighted_fit() {
        let truth = UslParams::new(0.3, 0.01, 50.0);
        let obs = synth(truth, &NS, 0.03, 2);
        let plain = fit(&obs).unwrap();
        let weighted = fit_weighted(&obs, &vec![1.0; obs.len()]).unwrap();
        assert_eq!(plain.params.sigma.to_bits(), weighted.params.sigma.to_bits());
        assert_eq!(plain.params.kappa.to_bits(), weighted.params.kappa.to_bits());
        assert_eq!(
            plain.params.lambda.to_bits(),
            weighted.params.lambda.to_bits()
        );
    }

    #[test]
    fn recency_weights_favor_the_recent_regime() {
        // two regimes at every N: stale observations from a λ=40 platform,
        // then fresh ones from the λ=20 platform it degraded into.  Heavy
        // weights on the fresh half must pull λ to the recent regime.
        let old = UslParams::new(0.05, 0.001, 40.0);
        let new = UslParams::new(0.05, 0.001, 20.0);
        let mut obs = Vec::new();
        let mut weights = Vec::new();
        for &n in &NS {
            obs.push(Obs::new(n, old.throughput(n)));
            weights.push(0.01);
        }
        for &n in &NS {
            obs.push(Obs::new(n, new.throughput(n)));
            weights.push(1.0);
        }
        let f = fit_weighted(&obs, &weights).unwrap();
        assert!(
            (f.params.lambda - 20.0).abs() < 2.0,
            "λ must track the heavily-weighted regime: {:?}",
            f.params
        );
    }

    #[test]
    fn bad_weights_rejected() {
        let truth = UslParams::new(0.1, 0.001, 10.0);
        let obs = synth(truth, &NS, 0.0, 1);
        assert!(matches!(
            fit_weighted(&obs, &[1.0]),
            Err(FitError::BadData)
        ));
        let mut w = vec![1.0; obs.len()];
        w[2] = 0.0;
        assert!(matches!(fit_weighted(&obs, &w), Err(FitError::BadData)));
        w[2] = f64::NAN;
        assert!(matches!(fit_weighted(&obs, &w), Err(FitError::BadData)));
    }

    #[test]
    fn coefficients_never_negative() {
        // superlinear data would push σ negative; fit must clamp
        let obs = vec![
            Obs::new(1.0, 10.0),
            Obs::new(2.0, 25.0),
            Obs::new(4.0, 60.0),
            Obs::new(8.0, 130.0),
        ];
        let f = fit(&obs).unwrap();
        assert!(f.params.sigma >= 0.0 && f.params.kappa >= 0.0);
    }
}
