//! Model evaluation on unseen data (paper §IV-D, Fig 7).
//!
//! "We utilize a different number of training configurations to create a
//! performance model. We investigate the root mean squared error of the
//! predictions on the unseen test data of the remaining configurations."

use super::fit::{fit, FitError, Obs};
use crate::util::rng::Pcg32;
use crate::util::stats;

/// One point of the Fig 7 curve.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub train_size: usize,
    /// Mean test RMSE over the resampled splits.
    pub rmse_mean: f64,
    pub rmse_std: f64,
    /// Number of splits that produced a valid fit.
    pub splits_ok: usize,
}

/// Evaluate fit quality vs training-set size: for each `train_size`,
/// repeatedly sample that many configurations as the training set, fit USL,
/// and measure RMSE on the held-out rest.
pub fn rmse_vs_train_size(
    obs: &[Obs],
    train_sizes: &[usize],
    resamples: usize,
    seed: u64,
) -> Result<Vec<EvalPoint>, FitError> {
    if obs.len() < 4 {
        return Err(FitError::TooFew(4, obs.len()));
    }
    let mut rng = Pcg32::seeded(seed);
    let mut out = Vec::new();
    for &k in train_sizes {
        let k = k.min(obs.len() - 1).max(3);
        let mut rmses = Vec::new();
        for _ in 0..resamples {
            let idx = rng.sample_indices(obs.len(), k);
            let train: Vec<Obs> = idx.iter().map(|&i| obs[i]).collect();
            let test: Vec<Obs> = (0..obs.len())
                .filter(|i| !idx.contains(i))
                .map(|i| obs[i])
                .collect();
            if test.is_empty() {
                continue;
            }
            let Ok(f) = fit(&train) else { continue };
            let pred: Vec<f64> = test.iter().map(|o| f.params.throughput(o.n)).collect();
            let actual: Vec<f64> = test.iter().map(|o| o.t).collect();
            rmses.push(stats::rmse(&pred, &actual));
        }
        let s = stats::Summary::of(&rmses);
        out.push(EvalPoint {
            train_size: k,
            rmse_mean: s.as_ref().map(|s| s.mean).unwrap_or(f64::NAN),
            rmse_std: s.as_ref().map(|s| s.std).unwrap_or(f64::NAN),
            splits_ok: rmses.len(),
        });
    }
    Ok(out)
}

/// Normalized RMSE (relative to the mean observed throughput) — lets Fig 7
/// compare scenarios with very different absolute throughputs.
pub fn normalized(points: &[EvalPoint], obs: &[Obs]) -> Vec<(usize, f64)> {
    let mean_t = stats::mean(&obs.iter().map(|o| o.t).collect::<Vec<_>>()).max(1e-12);
    points
        .iter()
        .map(|p| (p.train_size, p.rmse_mean / mean_t))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::usl::model::UslParams;

    fn synth(params: UslParams, noise_cv: f64, seed: u64) -> Vec<Obs> {
        let mut rng = Pcg32::seeded(seed);
        [1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0]
            .iter()
            .map(|&n| {
                Obs::new(
                    n,
                    params.throughput(n) * rng.normal_with(1.0, noise_cv).max(0.5),
                )
            })
            .collect()
    }

    #[test]
    fn rmse_decreases_with_more_training_data() {
        let obs = synth(UslParams::new(0.3, 0.01, 40.0), 0.05, 1);
        let pts = rmse_vs_train_size(&obs, &[3, 5, 7, 9], 40, 2).unwrap();
        assert_eq!(pts.len(), 4);
        // paper finding: 2-3 configs are "enough"; RMSE shouldn't blow up,
        // and more data should not make it dramatically worse
        assert!(
            pts[3].rmse_mean <= pts[0].rmse_mean * 1.5,
            "{:?}",
            pts.iter().map(|p| p.rmse_mean).collect::<Vec<_>>()
        );
        for p in &pts {
            assert!(p.splits_ok > 0);
        }
    }

    #[test]
    fn small_training_sets_suffice_on_clean_data() {
        // the paper's headline Fig 7 claim, on near-noise-free data
        let obs = synth(UslParams::new(0.1, 0.001, 100.0), 0.01, 3);
        let pts = rmse_vs_train_size(&obs, &[3], 40, 4).unwrap();
        let mean_t = stats::mean(&obs.iter().map(|o| o.t).collect::<Vec<_>>());
        assert!(
            pts[0].rmse_mean / mean_t < 0.2,
            "3-config normalized RMSE {} too large (mean T {mean_t})",
            pts[0].rmse_mean
        );
    }

    #[test]
    fn noisy_scenarios_have_higher_rmse() {
        // paper: "For Dask, we observe a higher RMSE for short-running
        // tasks" (higher relative noise)
        let quiet = synth(UslParams::new(0.1, 0.001, 50.0), 0.02, 5);
        let noisy = synth(UslParams::new(0.1, 0.001, 50.0), 0.25, 6);
        let pq = rmse_vs_train_size(&quiet, &[5], 40, 7).unwrap();
        let pn = rmse_vs_train_size(&noisy, &[5], 40, 7).unwrap();
        assert!(pn[0].rmse_mean > pq[0].rmse_mean * 2.0);
    }

    #[test]
    fn too_few_observations_rejected() {
        let obs = vec![Obs::new(1.0, 1.0); 3];
        assert!(rmse_vs_train_size(&obs, &[3], 5, 1).is_err());
    }

    #[test]
    fn normalized_scaling() {
        let obs = synth(UslParams::new(0.1, 0.001, 100.0), 0.02, 8);
        let pts = rmse_vs_train_size(&obs, &[4], 20, 9).unwrap();
        let norm = normalized(&pts, &obs);
        assert_eq!(norm[0].0, 4);
        assert!(norm[0].1 > 0.0 && norm[0].1 < 1.0);
    }
}
