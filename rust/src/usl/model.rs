//! The Universal Scalability Law (Gunther 1993).
//!
//! ```text
//! T(N) = λ·N / (1 + σ(N−1) + κ·N(N−1))
//! ```
//!
//! σ — *contention*: serialization on shared resources (queueing);
//! κ — *coherency*: pairwise/all-to-all synchronization cost;
//! λ — capacity scale: throughput of one unit at N = 1.
//!
//! Special cases: κ=0 reduces to Amdahl's law; σ=κ=0 is linear scaling.
//! USL's superpower for the paper: with κ>0 throughput *retrogrades* past
//! the peak N* = √((1−σ)/κ) — exactly the Dask-on-Lustre behaviour.

/// USL parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UslParams {
    /// Contention coefficient σ ≥ 0.
    pub sigma: f64,
    /// Coherency coefficient κ ≥ 0.
    pub kappa: f64,
    /// Capacity scale λ > 0 (throughput at N=1).
    pub lambda: f64,
}

impl UslParams {
    pub fn new(sigma: f64, kappa: f64, lambda: f64) -> Self {
        Self {
            sigma: sigma.max(0.0),
            kappa: kappa.max(0.0),
            lambda: lambda.max(f64::MIN_POSITIVE),
        }
    }

    /// Predicted throughput at parallelism `n`.
    pub fn throughput(&self, n: f64) -> f64 {
        debug_assert!(n >= 1.0);
        self.lambda * n / (1.0 + self.sigma * (n - 1.0) + self.kappa * n * (n - 1.0))
    }

    /// Relative capacity (speedup over N=1).
    pub fn speedup(&self, n: f64) -> f64 {
        self.throughput(n) / self.throughput(1.0)
    }

    /// Parallelism that maximizes throughput: N* = √((1−σ)/κ).
    /// `None` when throughput is monotone nondecreasing (κ = 0, σ ≤ 1).
    pub fn peak_n(&self) -> Option<f64> {
        if self.kappa <= 0.0 {
            return None;
        }
        let inner = (1.0 - self.sigma) / self.kappa;
        if inner <= 1.0 {
            Some(1.0) // already past peak at N=1
        } else {
            Some(inner.sqrt())
        }
    }

    /// Maximum achievable throughput.
    pub fn peak_throughput(&self) -> f64 {
        match self.peak_n() {
            Some(n) => self.throughput(n.max(1.0)),
            None => self.lambda / self.sigma.max(1e-12), // asymptote 1/σ
        }
    }

    /// Scalability classification for reports.
    pub fn regime(&self) -> &'static str {
        if self.sigma < 0.02 && self.kappa < 1e-4 {
            "near-linear"
        } else if self.kappa < 1e-6 {
            "contention-limited (Amdahl)"
        } else {
            "retrograde (contention + coherency)"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_when_no_overheads() {
        let p = UslParams::new(0.0, 0.0, 10.0);
        assert!((p.throughput(1.0) - 10.0).abs() < 1e-12);
        assert!((p.throughput(8.0) - 80.0).abs() < 1e-12);
        assert_eq!(p.peak_n(), None);
        assert_eq!(p.regime(), "near-linear");
    }

    #[test]
    fn amdahl_asymptote() {
        let p = UslParams::new(0.1, 0.0, 1.0);
        // speedup bounded by 1/σ = 10
        assert!(p.speedup(1e6) < 10.0);
        assert!(p.speedup(1e6) > 9.9);
        assert_eq!(p.regime(), "contention-limited (Amdahl)");
    }

    #[test]
    fn retrograde_peak() {
        let p = UslParams::new(0.1, 0.01, 1.0);
        let n_star = p.peak_n().unwrap();
        assert!((n_star - (0.9f64 / 0.01).sqrt()).abs() < 1e-9); // ≈ 9.49
        // throughput falls past the peak
        assert!(p.throughput(n_star) > p.throughput(n_star * 2.0));
        assert!(p.throughput(n_star) > p.throughput(1.0));
        assert_eq!(p.regime(), "retrograde (contention + coherency)");
    }

    #[test]
    fn paper_dask_regime_peaks_at_one() {
        // Dask on Lustre: σ∈[0.6,1], κ>0 → "peak scalability ... already
        // reached with a single partition"
        let p = UslParams::new(0.8, 0.2, 5.0);
        let n_star = p.peak_n().unwrap();
        assert!(n_star <= 1.01, "n*={n_star}");
        assert!(p.throughput(1.0) >= p.throughput(2.0));
    }

    #[test]
    fn negative_inputs_clamped() {
        let p = UslParams::new(-0.5, -1.0, 2.0);
        assert_eq!(p.sigma, 0.0);
        assert_eq!(p.kappa, 0.0);
    }

    #[test]
    fn throughput_at_one_is_lambda() {
        for (s, k) in [(0.0, 0.0), (0.5, 0.1), (0.9, 0.0)] {
            let p = UslParams::new(s, k, 3.5);
            assert!((p.throughput(1.0) - 3.5).abs() < 1e-12);
        }
    }
}
