//! Baseline scalability models for the model-selection ablation:
//! Amdahl's law (USL with κ=0) and pure linear scaling.  Gunther (2005)
//! showed USL generalizes Amdahl; the ablation quantifies what the
//! coherency term buys on retrograde data (DESIGN.md ablations).

use super::fit::{FitError, Obs, UslFit};
use super::model::UslParams;
use crate::util::stats;

/// Fit Amdahl's law T(N) = λN / (1 + σ(N−1)) by linearized OLS.
pub fn fit_amdahl(obs: &[Obs]) -> Result<UslFit, FitError> {
    if obs.len() < 2 {
        return Err(FitError::TooFew(2, obs.len()));
    }
    if obs.iter().any(|o| o.n < 1.0 || o.t <= 0.0) {
        return Err(FitError::BadData);
    }
    // y = N/T = 1/λ + (σ/λ)(N−1)
    let x: Vec<f64> = obs.iter().map(|o| o.n - 1.0).collect();
    let y: Vec<f64> = obs.iter().map(|o| o.n / o.t).collect();
    let (b0, b1) = stats::linreg(&x, &y);
    let lambda = if b0 > 1e-12 { 1.0 / b0 } else { 1.0 };
    let params = UslParams::new(b1 * lambda, 0.0, lambda);
    let pred: Vec<f64> = obs.iter().map(|o| params.throughput(o.n)).collect();
    let actual: Vec<f64> = obs.iter().map(|o| o.t).collect();
    Ok(UslFit {
        params,
        r2: stats::r_squared(&pred, &actual),
        rmse: stats::rmse(&pred, &actual),
        method: "amdahl",
    })
}

/// Fit pure linear scaling T(N) = λN.
pub fn fit_linear(obs: &[Obs]) -> Result<UslFit, FitError> {
    if obs.is_empty() {
        return Err(FitError::TooFew(1, 0));
    }
    if obs.iter().any(|o| o.n < 1.0 || o.t <= 0.0) {
        return Err(FitError::BadData);
    }
    // least squares through origin in (N, T)
    let num: f64 = obs.iter().map(|o| o.n * o.t).sum();
    let den: f64 = obs.iter().map(|o| o.n * o.n).sum();
    let lambda = num / den.max(1e-12);
    let params = UslParams::new(0.0, 0.0, lambda);
    let pred: Vec<f64> = obs.iter().map(|o| params.throughput(o.n)).collect();
    let actual: Vec<f64> = obs.iter().map(|o| o.t).collect();
    Ok(UslFit {
        params,
        r2: stats::r_squared(&pred, &actual),
        rmse: stats::rmse(&pred, &actual),
        method: "linear",
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::usl::fit::fit;

    fn retrograde_data() -> Vec<Obs> {
        let truth = UslParams::new(0.5, 0.04, 20.0);
        [1.0, 2.0, 4.0, 8.0, 16.0, 32.0]
            .iter()
            .map(|&n| Obs::new(n, truth.throughput(n)))
            .collect()
    }

    #[test]
    fn amdahl_recovers_amdahl_data() {
        let truth = UslParams::new(0.2, 0.0, 10.0);
        let obs: Vec<Obs> = [1.0, 2.0, 4.0, 8.0, 16.0]
            .iter()
            .map(|&n| Obs::new(n, truth.throughput(n)))
            .collect();
        let f = fit_amdahl(&obs).unwrap();
        assert!((f.params.sigma - 0.2).abs() < 1e-6);
        assert!(f.r2 > 0.99999);
    }

    #[test]
    fn usl_beats_amdahl_on_retrograde_data() {
        let obs = retrograde_data();
        let usl = fit(&obs).unwrap();
        let amdahl = fit_amdahl(&obs).unwrap();
        let linear = fit_linear(&obs).unwrap();
        assert!(usl.rmse < amdahl.rmse * 0.5, "usl={} amdahl={}", usl.rmse, amdahl.rmse);
        assert!(amdahl.rmse < linear.rmse, "amdahl={} linear={}", amdahl.rmse, linear.rmse);
    }

    #[test]
    fn amdahl_cannot_model_retrograde() {
        // Amdahl is monotone nondecreasing: it must miss the downturn
        let obs = retrograde_data();
        let f = fit_amdahl(&obs).unwrap();
        assert!(f.params.throughput(32.0) >= f.params.throughput(16.0) * 0.999);
        // whereas the data itself retrogrades
        assert!(obs.last().unwrap().t < obs[3].t);
    }

    #[test]
    fn linear_fit_on_linear_data() {
        let obs: Vec<Obs> = (1..=8).map(|n| Obs::new(n as f64, 5.0 * n as f64)).collect();
        let f = fit_linear(&obs).unwrap();
        assert!((f.params.lambda - 5.0).abs() < 1e-9);
        assert!(f.r2 > 0.99999);
    }
}
