//! `pilot-streaming` — the leader binary: CLI over the Pilot-Streaming +
//! StreamInsight stack.  See `pilot-streaming --help`.

use pilot_streaming::engine::StepEngine;
use pilot_streaming::insight::{self, figures, ExperimentSpec};
use pilot_streaming::miniapp::{run_live, run_sim_opts, PlatformKind, Scenario, SimOptions};
use pilot_streaming::pilot::PriceModel;
use pilot_streaming::runtime::{calibrate, Manifest, PjrtEngine};
use pilot_streaming::sim::{FaultEvent, FaultPlan, RecoveryMetrics, FAULTS_PARAM, FAULT_PRESET_IDS};
use pilot_streaming::util::cli::{App, Args, CliError, CommandSpec};
use pilot_streaming::util::logging;
use std::sync::Arc;

fn app() -> App {
    App::new(
        "pilot-streaming",
        "Pilot-Streaming + StreamInsight: serverless/HPC streaming performance characterization (Luckow & Jha 2019)",
    )
    .command(CommandSpec::new("vars", "print Table I (model variables)"))
    .command(
        CommandSpec::new("calibrate", "measure PJRT execution times per artifact variant")
            .opt("reps", "5", "measured repetitions per variant")
            .opt("seed", "42", "rng seed")
            .opt("out", "artifacts/calibration.json", "output file")
            .opt("pool", "1", "PJRT runtime threads"),
    )
    .command(
        CommandSpec::new("run", "run one scenario and print its summary")
            .opt("platform", "lambda", "lambda | dask | stampede2 | edge | flink | any registered plugin")
            .opt("partitions", "4", "N^px(p)")
            .opt("points", "8000", "points per message (MS)")
            .opt("centroids", "1024", "centroids (WC)")
            .opt("memory", "3008", "lambda memory MB")
            .opt("messages", "64", "messages to process")
            .opt("seed", "42", "rng seed")
            .opt("edge-sites", "1", "edge fleet size (multi-site placement; platform edge)")
            .opt("lanes", "1", "parallel sim lanes per scenario (0 = one per core; sim only)")
            .opt(
                "workflow",
                "",
                "run a preset workflow DAG instead of a single stage: finra | ml-training | ml-inference | word-count (--partitions scales every stage)",
            )
            .opt(
                "faults",
                "",
                "inject a fault plan: none | site-outage | cold-storm | hot-key | straggler | partition (or a numeric plan id; sim only)",
            )
            .flag("live", "run live (threads + real PJRT) instead of simulated time"),
    )
    .command(
        CommandSpec::new("sweep", "run an experiment grid sweep, fit USL, print analysis")
            .opt("messages", "64", "messages per configuration")
            .opt("seed", "42", "rng seed")
            .opt(
                "grid",
                "paper",
                "preset grid: paper | edge | edge-fleet | memory | tiny | cost | workflow",
            )
            .opt("jobs", "0", "parallel sweep workers (0 = one per core)")
            .opt("lanes", "1", "parallel sim lanes per scenario (0 = one per core)")
            .opt("csv", "", "write per-config CSV to this path")
            .opt("config", "", "TOML experiment file (overrides the preset grid)")
            .opt(
                "faults",
                "",
                "compose a fault axis onto the grid: comma list of plans/ids, or \"all\" for fair weather + every preset",
            ),
    )
    .command(
        CommandSpec::new("autoscale", "run the predictive autoscaler: replay a rate trace against the USL model, or close the loop on a live pilot (--live)")
            .opt("sigma", "0.02", "platform contention coefficient")
            .opt("kappa", "0.0001", "platform coherency coefficient")
            .opt("lambda", "10", "throughput at N=1 (msg/s)")
            .opt("trace", "diurnal", "diurnal | burst")
            .opt("intervals", "120", "control intervals to replay")
            .opt("peak", "200", "peak offered rate (msg/s)")
            .opt("objective", "goodput", "what the loop optimizes: goodput | cost | slo (cost/slo print a comparison against the goodput-only loop)")
            .opt("budget", "0", "dollars-per-hour budget (with --objective cost)")
            .opt("slo-p99", "0", "p99 sojourn target in seconds (with --objective slo)")
            .opt("platform", "lambda", "pilot platform — prices the loop via the plugin's PriceModel (kafka | kinesis close the live loop over the broker's shard count)")
            .opt("partitions", "2", "initial parallelism of the live pilot")
            .opt("points", "8000", "points per message (live)")
            .opt("centroids", "1024", "centroids (live)")
            .opt("seed", "42", "rng seed (live)")
            .opt("edge-sites", "1", "edge fleet size (platform edge)")
            .opt("refit-window", "64", "recalibration sample window (with --recalibrate)")
            .opt("drift-band", "0.25", "relative throughput band before a re-fit triggers (with --recalibrate)")
            .opt(
                "faults",
                "",
                "inject a fault plan into the live loop (with --live): site-outage | cold-storm | hot-key | straggler | partition (or id); reports per-fault recovery metrics",
            )
            .flag("live", "actuate decisions on a real pilot via resize_pilot instead of replaying the model")
            .flag("recalibrate", "stream online USL re-fits from observed goodput back into the live loop, and report static fit vs recalibrated side by side (with --live)"),
    )
    .command(
        CommandSpec::new("figs", "regenerate all tables/figures (fig3..fig7, table1)")
            .opt("messages", "64", "messages per configuration")
            .opt("seed", "42", "rng seed")
            .opt("only", "", "comma list, e.g. fig3,fig6"),
    )
    .command(
        CommandSpec::new("predict", "USL prediction / config recommendation from sigma,kappa,lambda")
            .req("sigma", "contention coefficient")
            .req("kappa", "coherency coefficient")
            .req("lambda", "throughput at N=1 (msg/s)")
            .opt("target", "0", "target ingest rate to size for (msg/s)")
            .opt("max", "64", "max parallelism considered"),
    )
}

fn engine_for_scenario(live: bool, pool: usize) -> Result<Arc<dyn StepEngine>, String> {
    if live {
        let manifest = Manifest::load(&Manifest::default_dir())
            .map_err(|e| format!("{e} (run `make artifacts`)"))?;
        Ok(Arc::new(PjrtEngine::new(manifest, pool)))
    } else {
        let rows = figures::default_calibration();
        Ok(Arc::new(calibrate::calibrated_engine(&rows, 42)))
    }
}

fn cmd_calibrate(args: &Args) -> Result<(), String> {
    let manifest = Manifest::load(&Manifest::default_dir())
        .map_err(|e| format!("{e} (run `make artifacts`)"))?;
    let pool = args.get_usize("pool").map_err(|e| e.to_string())?;
    let engine = PjrtEngine::new(manifest, pool.max(1));
    let reps = args.get_usize("reps").map_err(|e| e.to_string())?;
    let seed = args.get_u64("seed").map_err(|e| e.to_string())?;
    let rows = calibrate::calibrate(&engine, reps, seed);
    println!("{:<28} {:>10} {:>10}", "variant", "mean_s", "samples");
    for r in &rows {
        println!(
            "kmeans_n{:<6}_c{:<6}       {:>10.4} {:>10}",
            r.key.0,
            r.key.1,
            r.dist.mean(),
            r.samples.len()
        );
    }
    let out = args.get_or("out", "artifacts/calibration.json");
    std::fs::write(out, calibrate::to_json(&rows).pretty()).map_err(|e| e.to_string())?;
    println!("wrote {out}");
    Ok(())
}

fn scenario_from(args: &Args) -> Result<Scenario, String> {
    let platform = PlatformKind::parse(args.get_or("platform", "lambda"))
        .ok_or_else(|| format!("unknown platform {:?}", args.get("platform")))?;
    let mut sc = Scenario {
        platform,
        partitions: args.get_usize("partitions").map_err(|e| e.to_string())?,
        points_per_message: args.get_usize("points").map_err(|e| e.to_string())?,
        centroids: args.get_usize("centroids").map_err(|e| e.to_string())?,
        memory_mb: args.get_usize("memory").map_err(|e| e.to_string())? as u32,
        messages: args.get_usize("messages").map_err(|e| e.to_string())?,
        seed: args.get_u64("seed").map_err(|e| e.to_string())?,
        ..Default::default()
    };
    let sites = args.get_u64("edge-sites").map_err(|e| e.to_string())?;
    if sites > 1 {
        sc.set_extra("edge_sites", sites);
    }
    if let Some(plan) = fault_plan_from(args)? {
        sc.set_extra(FAULTS_PARAM, plan.id);
    }
    Ok(sc)
}

/// `--faults`: parse a single fault plan; `None` when absent or fair
/// weather ("none" / "off" / 0).
fn fault_plan_from(args: &Args) -> Result<Option<FaultPlan>, String> {
    let spec = args.get_or("faults", "");
    if spec.is_empty() {
        return Ok(None);
    }
    let plan = FaultPlan::parse(spec).ok_or_else(|| {
        format!(
            "unknown fault plan {spec:?} (none | site-outage | cold-storm | hot-key | straggler | partition | <numeric id>)"
        )
    })?;
    Ok(plan.is_active().then_some(plan))
}

fn print_summary(label: &str, s: &pilot_streaming::miniapp::RunSummary) {
    println!("-- {label} --");
    println!("messages           {}", s.messages);
    println!("window             {:.3} s", s.window_seconds);
    println!("throughput T^px    {:.3} msg/s", s.throughput);
    println!(
        "service time       mean {:.4} s  p95 {:.4} s  cv {:.3}",
        s.service.mean,
        s.service.p95,
        s.service.cv()
    );
    println!("broker latency     mean {:.4} s", s.broker.mean);
    println!(
        "breakdown          compute {:.4} s  io {:.4} s",
        s.compute_mean, s.io_mean
    );
}

fn cmd_run(args: &Args) -> Result<(), String> {
    if let Some(name) = args.get("workflow").filter(|s| !s.is_empty()) {
        return cmd_run_workflow(args, name);
    }
    let sc = scenario_from(args)?;
    if args.has_flag("live") {
        if sc.extra_param(FAULTS_PARAM).is_some() {
            return Err("--faults runs in simulated time only (drop --live, or use autoscale --live --faults)".into());
        }
        let engine = engine_for_scenario(true, sc.partitions.min(4))?;
        let r = run_live(&sc, engine, 50.0)?;
        print_summary(&format!("live {}", sc.platform.label()), &r.summary);
        println!("backoff events     {}", r.backoff_events);
        println!("final rate         {:.2} msg/s", r.final_rate);
    } else {
        let engine = engine_for_scenario(false, 1)?;
        let opts = SimOptions {
            lanes: lanes_from(args)?,
            ..Default::default()
        };
        let r = run_sim_opts(&sc, engine, opts)?;
        print_summary(&format!("sim {}", sc.platform.label()), &r.summary);
        println!("des events         {}", r.des_events);
        if let Some(fa) = &r.faults {
            println!(
                "fault accounting   offered {}  served clean {}  delayed {}  dropped {}  denied attempts {}  (conserved: {})",
                fa.offered,
                fa.served_clean,
                fa.delayed,
                fa.dropped,
                fa.denied_attempts,
                fa.conserved()
            );
        }
    }
    Ok(())
}

/// `--lanes`: parallel sim lanes per scenario (0 = one per core).
fn lanes_from(args: &Args) -> Result<usize, String> {
    Ok(match args.get_usize("lanes").map_err(|e| e.to_string())? {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        n => n,
    })
}

fn cmd_run_workflow(args: &Args, name: &str) -> Result<(), String> {
    use pilot_streaming::workflow::{run_workflow, WorkflowSpec};
    if args.has_flag("live") {
        return Err("--workflow runs in simulated time only (drop --live)".into());
    }
    if !args.get_or("faults", "").is_empty() {
        return Err("--faults applies to single-stage runs; the workflow driver does not thread fault plans yet".into());
    }
    let wf = WorkflowSpec::preset(name)
        .ok_or_else(|| {
            format!("unknown workflow {name:?} (finra | ml-training | ml-inference | word-count)")
        })?
        .with_source_messages(args.get_usize("messages").map_err(|e| e.to_string())?)
        .with_seed(args.get_u64("seed").map_err(|e| e.to_string())?);
    let scale = args
        .get_usize("partitions")
        .map_err(|e| e.to_string())?
        .max(1);
    let opts = SimOptions {
        lanes: lanes_from(args)?,
        ..Default::default()
    };
    let factory = figures::engine_factory(figures::default_calibration());
    let r = run_workflow(&wf, scale, &factory, opts)?;
    println!("-- workflow {} (scale x{scale}) --", wf.name);
    println!(
        "{:>2}  {:<14}{:<11}{:>5}  {:>9}  {:>12}  {:>10}",
        "#", "stage", "platform", "N", "ingested", "T msg/s", "window s"
    );
    for s in &r.stages {
        println!(
            "{:>2}  {:<14}{:<11}{:>5}  {:>9}  {:>12.3}  {:>10.3}",
            s.stage,
            s.name,
            s.platform.label(),
            s.parallelism,
            s.ingested,
            s.throughput,
            s.window_seconds
        );
    }
    for e in &r.edges {
        println!(
            "edge {} -> {}: consumed {}  emitted {}  residual {}",
            e.from, e.to, e.consumed, e.emitted, e.residual
        );
    }
    let a = &r.accounting;
    println!(
        "accounting         ingested {}  delivered {}  in-flight {} (conserved)",
        a.ingested, a.delivered, a.in_flight
    );
    let path: Vec<String> = r.critical_path.iter().map(|s| s.to_string()).collect();
    println!("critical path      {}", path.join(" -> "));
    println!("makespan           {:.3} s", r.makespan);
    println!("throughput e2e     {:.3} msg/s", r.throughput);
    let b = r.bottleneck();
    println!("bottleneck         stage {} ({})", b, r.stages[b].name);
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let messages = args.get_usize("messages").map_err(|e| e.to_string())?;
    let seed = args.get_u64("seed").map_err(|e| e.to_string())?;
    let spec = match args.get("config").filter(|s| !s.is_empty()) {
        Some(path) => insight::spec_from_file(path).map_err(|e| e.to_string())?,
        None => match args.get_or("grid", "paper") {
            "paper" => ExperimentSpec::paper_grid(messages, seed),
            "edge" => ExperimentSpec::edge_grid(messages, seed),
            "edge-fleet" => ExperimentSpec::edge_fleet_grid(messages, seed),
            "memory" => ExperimentSpec::lambda_memory_sweep(messages, seed),
            "tiny" => ExperimentSpec::tiny_grid(messages, seed),
            "cost" => ExperimentSpec::cost_grid(messages, seed),
            "workflow" => ExperimentSpec::workflow_grid(messages, seed),
            other => {
                return Err(format!(
                    "unknown grid {other:?} (paper | edge | edge-fleet | memory | tiny | cost | workflow)"
                ))
            }
        },
    };
    let spec = match fault_axis_from(args)? {
        Some(ids) => {
            if spec.axis(insight::AXIS_WORKFLOW).is_some() {
                return Err(
                    "--faults composes with single-stage grids; the workflow grid does not thread fault plans yet".into(),
                );
            }
            spec.with_axis(insight::Axis::ints(insight::AXIS_FAULTS, ids))
        }
        None => spec,
    };
    let jobs = match args.get_usize("jobs").map_err(|e| e.to_string())? {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        n => n,
    };
    if spec.axis(insight::AXIS_WORKFLOW).is_some() {
        return cmd_sweep_workflow(args, &spec, jobs);
    }
    eprintln!(
        "running {} configurations on {jobs} worker(s) (simulated time)...",
        spec.size()
    );
    // progress and incremental fits stream to stderr in completion order;
    // the final table/CSV below are reassembled in spec order and are
    // byte-identical for every --jobs value
    let mut inc = insight::IncrementalAnalysis::new(&spec);
    let opts = SimOptions {
        lanes: lanes_from(args)?,
        ..Default::default()
    };
    let rows = insight::run_sweep_jobs_opts(
        &spec,
        figures::engine_factory(figures::default_calibration()),
        jobs,
        opts,
        |p| {
            eprintln!(
                "[{}/{}] {} {}={} -> {:.2} msg/s",
                p.done,
                p.total,
                p.row.key.label(),
                p.row.scale_axis,
                p.row.scale,
                p.row.throughput
            );
            if let Some(a) = inc.observe(p.row) {
                eprintln!(
                    "  fit {}: sigma {:.4} kappa {:.5} lambda {:.2} R2 {:.3}",
                    a.key.label(),
                    a.fit.params.sigma,
                    a.fit.params.kappa,
                    a.fit.params.lambda,
                    a.fit.r2
                );
            }
        },
    );
    if rows.is_empty() {
        return Err("sweep produced no rows (every configuration failed)".into());
    }
    let analysis = insight::analyze(&rows);
    println!("{}", insight::table(&analysis));
    let costed = spec
        .axis(insight::AXIS_PRICE)
        .is_some()
        .then(|| insight::cost_rows(&rows));
    if let Some(costed) = &costed {
        print_pareto_front(costed);
    }
    if let Some(path) = args.get("csv").filter(|s| !s.is_empty()) {
        std::fs::write(path, insight::to_csv(&rows)).map_err(|e| e.to_string())?;
        println!("wrote {path}");
        if let Some(costed) = &costed {
            let pareto_path = format!("{path}.pareto.csv");
            std::fs::write(&pareto_path, insight::pareto_csv(costed))
                .map_err(|e| e.to_string())?;
            println!("wrote {pareto_path}");
        }
    }
    Ok(())
}

/// The goodput-vs-$/msg trade of a priced sweep: every configuration on
/// the Pareto front (no other config has both more throughput and a
/// lower $/msg), ordered as the sweep emitted them.
fn print_pareto_front(costed: &[insight::CostedRow]) {
    println!("\nPareto front (maximize msg/s, minimize $/kmsg):");
    println!(
        "{:<40} {:>6} {:>7} {:>10} {:>12} {:>12}",
        "configuration", "price%", "N", "msg/s", "$/hour", "$/kmsg"
    );
    for c in costed.iter().filter(|c| c.pareto) {
        println!(
            "{:<40} {:>6} {:>7} {:>10.2} {:>12.4} {:>12.6}",
            c.row.key.label(),
            c.price_percent,
            c.row.scale,
            c.row.throughput,
            c.dollars_per_hour,
            c.dollars_per_kmsg
        );
    }
    let on = costed.iter().filter(|c| c.pareto).count();
    println!("{on} of {} configurations on the front", costed.len());
}

/// `sweep --faults`: expand a comma list of fault plans (or "all") into
/// the id levels of a [`FaultPlan`] axis.  Fair weather (id 0) rides
/// along with "all" so every fit has its undisturbed reference curve.
fn fault_axis_from(args: &Args) -> Result<Option<Vec<u64>>, String> {
    let spec = args.get_or("faults", "");
    if spec.is_empty() {
        return Ok(None);
    }
    if spec == "all" {
        let mut ids = vec![0];
        ids.extend(FAULT_PRESET_IDS);
        return Ok(Some(ids));
    }
    let mut ids = Vec::new();
    for part in spec.split(',') {
        let plan = FaultPlan::parse(part)
            .ok_or_else(|| format!("unknown fault plan {part:?} in --faults"))?;
        ids.push(plan.id);
    }
    Ok(Some(ids))
}

/// `sweep --grid workflow` (or a TOML `workflows = [...]` campaign): run
/// whole-DAG configurations, fit every stage's USL curve, and report the
/// composed critical-path model against the simulated end-to-end
/// throughput.
fn cmd_sweep_workflow(args: &Args, spec: &ExperimentSpec, jobs: usize) -> Result<(), String> {
    use pilot_streaming::workflow::WorkflowSpec;
    let opts = SimOptions {
        lanes: lanes_from(args)?,
        ..Default::default()
    };
    eprintln!(
        "running {} workflow configurations on {jobs} worker(s) (simulated time)...",
        spec.size()
    );
    let (rows, stage_rows) = insight::run_workflow_sweep_jobs(
        spec,
        figures::engine_factory(figures::default_calibration()),
        jobs,
        opts,
        |p| {
            eprintln!(
                "[{}/{}] {} {}={} -> {:.2} msg/s",
                p.done,
                p.total,
                p.row.key.label(),
                p.row.scale_axis,
                p.row.scale,
                p.row.throughput
            );
        },
    );
    if rows.is_empty() {
        return Err("sweep produced no rows (every configuration failed)".into());
    }
    let analysis = insight::analyze(&rows);
    println!("{}", insight::table(&analysis));
    let fits = insight::fit_stages(&stage_rows);
    println!("per-stage USL fits:");
    for f in &fits {
        println!(
            "  {:<12} [{}] {:<14} sigma {:.4}  kappa {:.5}  lambda {:.2}  R2 {:.3}",
            f.workflow,
            f.stage,
            f.name,
            f.fit.params.sigma,
            f.fit.params.kappa,
            f.fit.params.lambda,
            f.fit.r2
        );
    }
    println!("critical-path model vs simulated end-to-end throughput:");
    let axis = spec
        .axis(insight::AXIS_WORKFLOW)
        .expect("workflow sweep without workflow axis");
    for level in &axis.levels {
        let Some(id) = level.as_int() else { continue };
        let wf = WorkflowSpec::preset_by_id(id)
            .ok_or_else(|| format!("unknown workflow preset id {id}"))?
            .with_source_messages(spec.messages)
            .with_seed(spec.seed);
        let name = wf.name.clone();
        let model = insight::CriticalPathModel::new(wf, &fits)?;
        let mut worst: f64 = 0.0;
        for row in rows.iter().filter(|r| {
            r.key.pairs().iter().any(|(n, v)| {
                n.as_str() == insight::AXIS_WORKFLOW
                    && matches!(v, insight::AxisValue::Int(i) if *i == id)
            })
        }) {
            let pred = model.predict(row.scale)?;
            let err = (pred.throughput - row.throughput).abs() / row.throughput.max(1e-12);
            worst = worst.max(err);
            println!(
                "  {name:<12} x{:<2}  sim {:>10.3}  model {:>10.3}  err {:>5.1}%  bottleneck {}",
                row.scale,
                row.throughput,
                pred.throughput,
                err * 100.0,
                pred.bottleneck
            );
        }
        println!("  {name:<12} worst model error {:.1}%", worst * 100.0);
    }
    if let Some(path) = args.get("csv").filter(|s| !s.is_empty()) {
        std::fs::write(path, insight::to_csv(&rows)).map_err(|e| e.to_string())?;
        let stage_path = format!("{path}.stages.csv");
        std::fs::write(&stage_path, insight::stage_csv(&stage_rows))
            .map_err(|e| e.to_string())?;
        println!("wrote {path} and {stage_path}");
    }
    Ok(())
}

fn cmd_figs(args: &Args) -> Result<(), String> {
    let messages = args.get_usize("messages").map_err(|e| e.to_string())?;
    let seed = args.get_u64("seed").map_err(|e| e.to_string())?;
    let only = args.get_or("only", "").to_string();
    let wanted: Vec<&str> = if only.is_empty() {
        vec!["table1", "fig3", "fig4", "fig5", "fig6", "fig7"]
    } else {
        only.split(',').map(str::trim).collect()
    };
    let mut all_ok = true;
    for name in wanted {
        let result = match name {
            "table1" => figures::table1(),
            "fig3" => figures::fig3(messages, seed),
            "fig4" => figures::fig4(messages, seed),
            "fig5" => figures::fig5(messages, seed),
            "fig6" => figures::fig6(messages, seed),
            "fig7" => figures::fig7(messages, seed),
            other => return Err(format!("unknown figure {other:?}")),
        };
        println!("{}", result.render());
        all_ok &= result.all_pass();
    }
    if !all_ok {
        return Err("some figure shape checks FAILED".into());
    }
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<(), String> {
    let sigma = args.get_f64("sigma").map_err(|e| e.to_string())?;
    let kappa = args.get_f64("kappa").map_err(|e| e.to_string())?;
    let lambda = args.get_f64("lambda").map_err(|e| e.to_string())?;
    let max = args.get_usize("max").map_err(|e| e.to_string())?;
    let target = args.get_f64("target").map_err(|e| e.to_string())?;
    let p = insight::Predictor {
        params: pilot_streaming::usl::UslParams::new(sigma, kappa, lambda),
    };
    println!("{:>4}  {:>12}  {:>8}", "N", "T(N) msg/s", "speedup");
    for n in [1usize, 2, 4, 8, 16, 32, 64] {
        if n > max {
            break;
        }
        println!(
            "{:>4}  {:>12.3}  {:>8.2}",
            n,
            p.throughput(n),
            p.throughput(n) / p.throughput(1)
        );
    }
    println!("regime: {}", p.params.regime());
    println!(
        "optimal parallelism (<= {max}): {}",
        p.optimal_parallelism(max)
    );
    if target > 0.0 {
        match p.required_parallelism(target, 1.25, max) {
            Some(n) => println!("to sustain {target} msg/s (+25% headroom): N = {n}"),
            None => println!(
                "target {target} msg/s unreachable; throttle source to {:.2} msg/s at N = {}",
                p.sustainable_rate(p.optimal_parallelism(max), 1.25),
                p.optimal_parallelism(max)
            ),
        }
    }
    Ok(())
}

fn print_autoscale_ticks(report: &insight::AutoscaleReport, intervals: usize) {
    println!(
        "{:>5} {:>10} {:>6} {:>10} {:>10} {:>10}",
        "t", "rate", "N", "capacity", "backlog", "decision"
    );
    for tick in report.ticks.iter().step_by((intervals / 24).max(1)) {
        println!(
            "{:>5.0} {:>10.1} {:>6} {:>10.1} {:>10.1} {:>10}",
            tick.t,
            tick.offered_rate,
            tick.parallelism,
            tick.capacity,
            tick.backlog,
            tick.decision
        );
    }
    println!(
        "
goodput {:.1}%  scale events {}  max backlog {:.0}  throttled {:.0} msgs",
        report.goodput() * 100.0,
        report.scale_events,
        report.max_backlog,
        report.throttled_total
    );
    if let Some(msgs_per_dollar) = report.msgs_per_dollar() {
        println!(
            "spend ${:.4} (run ${:.4} + transitions ${:.4})  {:.0} msgs/$",
            report.dollars_total(),
            report.run_dollars,
            report.transition_dollars,
            msgs_per_dollar
        );
    }
}

/// `--objective` with its `--budget` / `--slo-p99` riders.
fn objective_from(args: &Args) -> Result<insight::Objective, String> {
    insight::Objective::parse(
        args.get_or("objective", "goodput"),
        args.get_f64("budget").map_err(|e| e.to_string())?,
        args.get_f64("slo-p99").map_err(|e| e.to_string())?,
    )
}

/// The cost-normalized comparison `--objective cost|slo` prints: the
/// shaped loop against the goodput-only loop serving the same trace at
/// the same platform price.
fn print_objective_comparison(
    objective: insight::Objective,
    shaped: &insight::AutoscaleReport,
    goodput_only: &insight::AutoscaleReport,
) {
    let p99 = objective.slo_p99();
    println!(
        "\n-- objective {} vs goodput-only (same trace, same price) --",
        objective.label()
    );
    print!(
        "{:<14} {:>9} {:>10} {:>9} {:>10}",
        "loop", "goodput", "$ total", "$/hour", "msgs/$"
    );
    match p99 {
        Some(p) => println!(" {:>12}", format!("p99<={p}s")),
        None => println!(),
    }
    for (label, report) in [
        (objective.label(), shaped),
        ("goodput-only", goodput_only),
    ] {
        let hours = report.ticks.len() as f64 / 3600.0;
        let per_hour = if hours > 0.0 {
            report.dollars_total() / hours
        } else {
            0.0
        };
        let msgs_per_dollar = report
            .msgs_per_dollar()
            .map(|m| format!("{m:.0}"))
            .unwrap_or_else(|| "-".into());
        print!(
            "{label:<14} {:>8.1}% {:>10.4} {:>9.4} {:>10}",
            report.goodput() * 100.0,
            report.dollars_total(),
            per_hour,
            msgs_per_dollar
        );
        match p99 {
            Some(p) => println!(" {:>11.1}%", report.slo_attainment(p) * 100.0),
            None => println!(),
        }
    }
}

fn cmd_autoscale(args: &Args) -> Result<(), String> {
    let predictor = insight::Predictor {
        params: pilot_streaming::usl::UslParams::new(
            args.get_f64("sigma").map_err(|e| e.to_string())?,
            args.get_f64("kappa").map_err(|e| e.to_string())?,
            args.get_f64("lambda").map_err(|e| e.to_string())?,
        ),
    };
    let intervals = args.get_usize("intervals").map_err(|e| e.to_string())?;
    let peak = args.get_f64("peak").map_err(|e| e.to_string())?;
    let trace = match args.get_or("trace", "diurnal") {
        "burst" => insight::trace_burst(intervals, peak * 0.1, peak, intervals / 3),
        _ => insight::trace_diurnal(intervals, peak * 0.05, peak, 42),
    };
    if args.has_flag("live") {
        return cmd_autoscale_live(args, predictor, &trace, intervals);
    }
    if args.has_flag("recalibrate") {
        return Err("--recalibrate needs a live pilot to learn from: pass --live".into());
    }
    if !args.get_or("faults", "").is_empty() {
        return Err("--faults needs a live loop to degrade: pass --live".into());
    }
    let objective = objective_from(args)?;
    let platform = PlatformKind::parse(args.get_or("platform", "lambda"))
        .ok_or_else(|| format!("unknown platform {:?}", args.get("platform")))?;
    let price = insight::platform_price(platform);
    let config = insight::AutoscaleConfig::default();
    let report = insight::replay_objective(
        predictor.clone(),
        config.clone(),
        objective,
        price,
        &trace,
        1.0,
        1,
    );
    if objective != insight::Objective::Goodput {
        println!(
            "-- replay: objective {} on {} (${:.4}/{}/h) --",
            objective.label(),
            platform.label(),
            price.unit_dollars_per_hour,
            price.billing_unit
        );
    }
    print_autoscale_ticks(&report, intervals);
    if objective != insight::Objective::Goodput {
        let goodput_only = insight::replay_objective(
            predictor,
            config,
            insight::Objective::Goodput,
            price,
            &trace,
            1.0,
            1,
        );
        print_objective_comparison(objective, &report, &goodput_only);
    }
    Ok(())
}

/// The closed loop, end to end: provision a real pilot, let the
/// autoscaler's decisions actuate `resize_pilot`, and report against a
/// fixed-parallelism baseline serving the same trace.
fn cmd_autoscale_live(
    args: &Args,
    predictor: insight::Predictor,
    trace: &[f64],
    intervals: usize,
) -> Result<(), String> {
    let platform = PlatformKind::parse(args.get_or("platform", "lambda"))
        .ok_or_else(|| format!("unknown platform {:?}", args.get("platform")))?;
    let sites = args
        .get_u64("edge-sites")
        .map_err(|e| e.to_string())?
        .max(1);
    let mut scenario = Scenario {
        platform,
        partitions: args.get_usize("partitions").map_err(|e| e.to_string())?,
        points_per_message: args.get_usize("points").map_err(|e| e.to_string())?,
        centroids: args.get_usize("centroids").map_err(|e| e.to_string())?,
        seed: args.get_u64("seed").map_err(|e| e.to_string())?,
        ..Default::default()
    };
    if sites > 1 {
        scenario.set_extra("edge_sites", sites);
    }
    // the platform's declared elasticity caps the search space (the edge
    // device envelope becomes throttling instead of futile scale-ups).
    // The edge cap is per reference site: a multi-site fleet raises the
    // bound to sites x cap and its Throttle plans teach the loop the
    // exact heterogeneous sum at runtime.  Other platforms keep their
    // declared cap untouched.
    let mut config = insight::AutoscaleConfig::default();
    let processing = platform.processing_platform();
    if let Some(plugin) = pilot_streaming::pilot::default_registry().get(processing) {
        if let Some(cap) = plugin.elasticity().max_parallelism {
            let fleet_factor = if processing == pilot_streaming::pilot::Platform::EDGE {
                sites as usize
            } else {
                1
            };
            config.max_parallelism = config.max_parallelism.min(cap * fleet_factor);
        }
    }
    let factory = figures::engine_factory(figures::default_calibration());
    if args.has_flag("recalibrate") {
        return run_recalibrate_comparison(
            args, predictor, config, &scenario, trace, intervals, &factory,
        );
    }
    let plan = fault_plan_from(args)?;
    let objective = objective_from(args)?;
    let price = insight::platform_price(platform);
    let scaler = insight::Autoscaler::new(predictor.clone(), config.clone(), scenario.partitions)
        .with_objective(objective, price);

    eprintln!(
        "provisioning live {} pilot (N={}, objective {}) and closing the loop over {} intervals...",
        platform.label(),
        scenario.partitions,
        objective.label(),
        intervals
    );
    if let Some(p) = &plan {
        eprintln!("injecting fault plan {:?} ({} event(s))", p.name, p.events.len());
    }
    let (report, recovery, status) =
        run_live_loop(&scenario, &factory, Some(scaler), None, plan.as_ref(), price, trace)?;
    let (baseline, base_recovery, _) =
        run_live_loop(&scenario, &factory, None, None, plan.as_ref(), price, trace)?;

    let suffix = plan
        .as_ref()
        .map(|p| format!(", faults: {}", p.name))
        .unwrap_or_default();
    println!("-- live {} (closed loop{suffix}) --", platform.label());
    print_autoscale_ticks(&report, intervals);
    if objective != insight::Objective::Goodput {
        let goodput_scaler =
            insight::Autoscaler::new(predictor, config, scenario.partitions)
                .with_objective(insight::Objective::Goodput, price);
        let (goodput_only, _, _) = run_live_loop(
            &scenario,
            &factory,
            Some(goodput_scaler),
            None,
            plan.as_ref(),
            price,
            trace,
        )?;
        print_objective_comparison(objective, &report, &goodput_only);
    }
    println!("\nresize transitions:");
    for ev in &report.resizes {
        println!(
            "  t={:>5.0}  {:>3} -> {:<3} transition {:.2}s  {:?}",
            ev.t, ev.plan.from, ev.plan.to, ev.plan.transition_s, ev.plan.semantics
        );
    }
    println!("{status}");
    if let Some(rec) = &recovery {
        println!("\nper-fault recovery (closed loop vs fixed baseline):");
        print_recovery("autoscaled", rec);
        if let Some(base_rec) = &base_recovery {
            print_recovery("fixed", base_rec);
        }
    }
    println!(
        "\nlive goodput {:.1}%  vs fixed N={} baseline {:.1}%  ({:+.1} pts)",
        report.goodput() * 100.0,
        scenario.partitions,
        baseline.goodput() * 100.0,
        (report.goodput() - baseline.goodput()) * 100.0
    );
    Ok(())
}

type RecoveryReport = Vec<(FaultEvent, RecoveryMetrics)>;

/// Run one control loop (or a fixed-parallelism baseline when `scaler` is
/// `None`) on a fresh live pilot, optionally degraded by a fault plan.
/// Returns the report, the per-fault recovery metrics (when a plan is
/// injected), and the pilot's final status line.
fn run_live_loop<F>(
    scenario: &Scenario,
    factory: &F,
    scaler: Option<insight::Autoscaler>,
    fitter: Option<insight::OnlineUslFitter>,
    plan: Option<&FaultPlan>,
    price: PriceModel,
    trace: &[f64],
) -> Result<(insight::AutoscaleReport, Option<RecoveryReport>, String), String>
where
    F: Fn(&Scenario) -> Arc<dyn StepEngine>,
{
    let inner = insight::PilotTarget::new(pilot_streaming::miniapp::LivePilot::provision(
        scenario,
        factory(scenario),
    )?);
    match plan {
        Some(plan) => {
            let mut target = insight::FaultyTarget::new(inner, plan.clone(), trace.len(), 1.0);
            let report = run_loop_on(&mut target, scaler, fitter, price, trace)?;
            let recovery = target.recovery_report();
            let inner = target.into_inner();
            let status = pilot_status_line(&inner);
            inner.shutdown();
            Ok((report, Some(recovery), status))
        }
        None => {
            let mut target = inner;
            let report = run_loop_on(&mut target, scaler, fitter, price, trace)?;
            let status = pilot_status_line(&target);
            target.shutdown();
            Ok((report, None, status))
        }
    }
}

fn run_loop_on(
    target: &mut dyn insight::ScalingTarget,
    scaler: Option<insight::Autoscaler>,
    fitter: Option<insight::OnlineUslFitter>,
    price: PriceModel,
    trace: &[f64],
) -> Result<insight::AutoscaleReport, String> {
    match scaler {
        Some(scaler) => {
            let mut control = insight::ControlLoop::new(scaler, 1.0);
            if let Some(f) = fitter {
                control = control.with_recalibration(f);
            }
            control.run(target, trace)
        }
        None => insight::run_fixed_priced(target, trace, 1.0, price),
    }
}

fn pilot_status_line(target: &insight::PilotTarget) -> String {
    let s = target.pilot().status();
    format!(
        "final pilot_state: {} at N={} after {} resize(s)",
        s.state, s.parallelism, s.resize_events
    )
}

fn print_recovery(label: &str, metrics: &RecoveryReport) {
    for (ev, m) in metrics {
        println!(
            "  {label:<13} {:<12} detect {:>7}  restore {:>7}  backlog area {:.0} msg*s",
            ev.kind.label(),
            fmt_ticks(m.time_to_detect),
            fmt_ticks(m.time_to_restore),
            m.backlog_area
        );
    }
}

fn fmt_ticks(t: f64) -> String {
    if t.is_finite() {
        format!("{t:.0}s")
    } else {
        "never".to_string()
    }
}

/// `autoscale --live --recalibrate`: run the closed loop twice on
/// identical fresh pilots — steering from the static fit vs streaming
/// online USL re-fits into the autoscaler mid-run — plus the
/// fixed-parallelism baseline, and report goodput, backlog, scale events,
/// the re-fit history, and the final fit against a probed ground truth.
fn run_recalibrate_comparison<F>(
    args: &Args,
    predictor: insight::Predictor,
    config: insight::AutoscaleConfig,
    scenario: &Scenario,
    intervals_trace: &[f64],
    intervals: usize,
    factory: &F,
) -> Result<(), String>
where
    F: Fn(&Scenario) -> Arc<dyn StepEngine>,
{
    let window = args.get_usize("refit-window").map_err(|e| e.to_string())?;
    let band = args.get_f64("drift-band").map_err(|e| e.to_string())?;
    let recal_config = insight::RecalibrateConfig {
        window: window.max(1),
        drift_band: band.max(0.01),
        ..Default::default()
    };
    let plan = fault_plan_from(args)?;
    let label = scenario.platform.label();
    eprintln!(
        "closing the loop twice on live {label} ({intervals} intervals): static fit vs online recalibration..."
    );
    if let Some(p) = &plan {
        eprintln!("injecting fault plan {:?} ({} event(s)) into both loops", p.name, p.events.len());
    }
    let price = insight::platform_price(scenario.platform);
    let scaler =
        || insight::Autoscaler::new(predictor.clone(), config.clone(), scenario.partitions);
    let (static_report, static_recovery, _) = run_live_loop(
        scenario,
        factory,
        Some(scaler()),
        None,
        plan.as_ref(),
        price,
        intervals_trace,
    )?;
    let (recal_report, recal_recovery, _) = run_live_loop(
        scenario,
        factory,
        Some(scaler()),
        Some(insight::OnlineUslFitter::new(recal_config)),
        plan.as_ref(),
        price,
        intervals_trace,
    )?;
    let (baseline, _, _) = run_live_loop(
        scenario,
        factory,
        None,
        None,
        plan.as_ref(),
        price,
        intervals_trace,
    )?;

    let recal = recal_report.recalibration.clone().unwrap_or_default();
    println!("-- live {label}: static fit vs online recalibration --");
    println!(
        "{:<14} {:>9} {:>12} {:>13} {:>8} {:>7}",
        "loop", "goodput", "max backlog", "scale events", "resizes", "refits"
    );
    println!(
        "{:<14} {:>8.1}% {:>12.0} {:>13} {:>8} {:>7}",
        "static fit",
        static_report.goodput() * 100.0,
        static_report.max_backlog,
        static_report.scale_events,
        static_report.resizes.len(),
        "-"
    );
    println!(
        "{:<14} {:>8.1}% {:>12.0} {:>13} {:>8} {:>7}",
        "recalibrated",
        recal_report.goodput() * 100.0,
        recal_report.max_backlog,
        recal_report.scale_events,
        recal_report.resizes.len(),
        recal.refits.len()
    );
    if !recal.refits.is_empty() {
        println!("\nrefit events:");
        for r in &recal.refits {
            println!(
                "  t={:>5.0}  {:<8} sigma {:.4}  kappa {:.5}  lambda {:.2}  ({} samples)",
                r.t, r.method, r.params.sigma, r.params.kappa, r.params.lambda, r.samples
            );
        }
    }
    let p0 = predictor.params;
    println!(
        "\nstatic fit:       sigma {:.4}  kappa {:.5}  lambda {:.2}",
        p0.sigma, p0.kappa, p0.lambda
    );
    if let Some(p) = recal.final_params() {
        println!(
            "recalibrated fit: sigma {:.4}  kappa {:.5}  lambda {:.2}",
            p.sigma, p.kappa, p.lambda
        );
    }
    if let (Some(s), Some(r)) = (&static_recovery, &recal_recovery) {
        println!("\nper-fault recovery: stale static fit vs recalibrated");
        print_recovery("static fit", s);
        print_recovery("recalibrated", r);
    }
    match probe_ground_truth(scenario, factory, config.max_parallelism) {
        Some(truth) => println!(
            "ground truth:     sigma {:.4}  kappa {:.5}  lambda {:.2}  (probed fresh pilots, R2 {:.3})",
            truth.params.sigma, truth.params.kappa, truth.params.lambda, truth.r2
        ),
        None => println!("ground truth:     probe unavailable on this platform"),
    }
    println!(
        "\nvs fixed N={} baseline ({:.1}%): static {:+.1} pts, recalibrated {:+.1} pts",
        scenario.partitions,
        baseline.goodput() * 100.0,
        (static_report.goodput() - baseline.goodput()) * 100.0,
        (recal_report.goodput() - baseline.goodput()) * 100.0
    );
    Ok(())
}

/// Measure the platform's true capacity curve — fresh pilots saturated at
/// a few parallelism levels, one USL fit over the measured rates — as the
/// reference the recalibrated fit is judged against.
fn probe_ground_truth<F>(
    scenario: &Scenario,
    factory: &F,
    max_n: usize,
) -> Option<pilot_streaming::usl::UslFit>
where
    F: Fn(&Scenario) -> Arc<dyn StepEngine>,
{
    use pilot_streaming::usl::Obs;
    let mut obs: Vec<Obs> = Vec::new();
    for n in [1usize, 2, 4, 8, 16] {
        if n > max_n {
            break;
        }
        let mut sc = scenario.clone();
        sc.partitions = n;
        let Ok(mut lp) = pilot_streaming::miniapp::LivePilot::provision(&sc, factory(&sc)) else {
            continue;
        };
        let actual_n = lp.parallelism();
        if lp.step(1e9, 1.0).is_err() {
            // warm-up interval: cold starts land out-of-band
            lp.shutdown();
            continue;
        }
        let mut served = 0.0;
        let mut ok = true;
        for _ in 0..3 {
            match lp.step(1e9, 1.0) {
                Ok(s) => served += s,
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        lp.shutdown();
        if !ok || served <= 0.0 {
            continue;
        }
        // platforms that clamp (the edge envelope) collapse levels: keep
        // one observation per realized parallelism
        if obs.iter().all(|o| (o.n - actual_n as f64).abs() > 0.5) {
            obs.push(Obs::new(actual_n as f64, served / 3.0));
        }
    }
    if obs.len() < 3 {
        return None;
    }
    pilot_streaming::usl::fit(&obs).ok()
}

fn main() {
    logging::init();
    let app = app();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, args) = match app.parse(&argv) {
        Ok(x) => x,
        Err(CliError::Help) | Err(CliError::NoCommand) => {
            if let Some(spec) = argv
                .first()
                .and_then(|c| app.commands.iter().find(|s| s.name == *c))
            {
                print!("{}", app.command_usage(spec));
            } else {
                print!("{}", app.usage());
            }
            return;
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "vars" => {
            println!("{}", figures::table1().table);
            Ok(())
        }
        "calibrate" => cmd_calibrate(&args),
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "figs" => cmd_figs(&args),
        "predict" => cmd_predict(&args),
        "autoscale" => cmd_autoscale(&args),
        other => Err(format!("unhandled command {other}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
