//! The closed control loop (the paper's stated future work): feed
//! [`Autoscaler`] decisions back as **live re-provisioning** instead of
//! only replaying them against the USL model.
//!
//! [`ScalingTarget`] is the actuation seam: anything that can report its
//! parallelism, apply a scale decision, and serve one control interval of
//! load.  Two implementations close the design:
//!
//! - [`ModelTarget`] — the USL predictor itself.  Instant transitions,
//!   analytic capacity; `autoscale_sim::replay` is now a thin wrapper over
//!   `ControlLoop::run` with this target, byte-for-byte compatible with
//!   the old replay arithmetic.
//! - [`PilotTarget`] — a real pilot behind
//!   [`LivePilot`](crate::miniapp::LivePilot): decisions actuate
//!   `PilotComputeService::resize_pilot`, transitions ride the `Resizing`
//!   state with platform-true costs, and every served message is a real
//!   `StreamProcessor::process` call — cold starts, Lustre contention and
//!   micro-batch delays all land in the measured goodput.
//!
//! The loop synchronizes belief with reality every tick: whatever the
//! platform actually realized (edge clamps, in-flight transitions) is
//! written back into the autoscaler before the next decision.
//!
//! The replay side of the seam, end to end — every tick's accounting
//! conserves offered load into processed + throttled + backlog:
//!
//! ```rust
//! use pilot_streaming::insight::{
//!     AutoscaleConfig, Autoscaler, ControlLoop, ModelTarget, Predictor,
//! };
//! use pilot_streaming::usl::UslParams;
//!
//! let predictor = Predictor {
//!     params: UslParams::new(0.02, 0.0001, 10.0),
//! };
//! let scaler = Autoscaler::new(predictor.clone(), AutoscaleConfig::default(), 2);
//! let mut target = ModelTarget::new(predictor, 2);
//! let trace = [5.0, 40.0, 80.0, 80.0, 20.0];
//! let report = ControlLoop::new(scaler, 1.0).run(&mut target, &trace).unwrap();
//! assert_eq!(report.ticks.len(), trace.len());
//! let final_backlog = report.ticks.last().unwrap().backlog;
//! assert!(
//!     (report.offered_total - report.processed_total - report.throttled_total - final_backlog)
//!         .abs()
//!         < 1e-9
//! );
//! ```

use super::autoscale::{Autoscaler, ScaleDecision};
use super::autoscale_sim::{AutoscaleReport, Tick};
use super::objective::{
    estimate_p99_s, CostLedger, RUN_BUDGET_FRACTION, TRANSITION_BUDGET_FRACTION,
};
use super::predict::Predictor;
use super::recalibrate::{OnlineUslFitter, UslSample};
use crate::miniapp::LivePilot;
use crate::pilot::{PriceModel, ResizePlan, ResizeSemantics};

/// One committed live-resize transition, stamped with its loop time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResizeEvent {
    pub t: f64,
    pub plan: ResizePlan,
}

/// Anything the autoscaler can actuate: the USL model or a live pilot.
pub trait ScalingTarget {
    /// Short label for reports ("model", "lambda", "dask", ...).
    fn label(&self) -> String;

    /// Effective parallelism right now.
    fn parallelism(&self) -> usize;

    /// Whether a resize transition is currently in flight (the loop
    /// defers decisions — and their accounting — until it lands).
    fn is_resizing(&self) -> bool {
        false
    }

    /// Apply a scale decision.  Returns the committed plan — including
    /// no-op plans whose semantics carry platform push-back (a clamped
    /// edge target) — or `None` when nothing was actuated at all (hold,
    /// mid-transition).
    fn actuate(&mut self, decision: &ScaleDecision) -> Result<Option<ResizePlan>, String>;

    /// Serve up to `demand` messages over one `dt`-second interval;
    /// returns how many were actually served.
    fn serve(&mut self, demand: f64, dt: f64) -> Result<f64, String>;

    /// Nominal capacity (msg/s) at current parallelism, for reporting.
    fn capacity(&self) -> f64;

    /// The observation hook (the online-recalibration seam): after each
    /// serve the loop asks the target for the interval's
    /// [`UslSample`].  The default reports realized parallelism with the
    /// loop-measured rates; targets with platform-true push-back
    /// ([`PilotTarget`] after a `Throttle`/clamp plan) override to mark
    /// the sample as sitting on the platform's real envelope, so the
    /// sample store — and every re-fit — carries what the platform
    /// *actually* did, not what the autoscaler asked for.
    fn observe_interval(&mut self, served_rate: f64, demand_rate: f64) -> UslSample {
        UslSample::new(self.parallelism(), served_rate, demand_rate)
    }
}

/// The USL model as a scaling target: instant transitions, analytic
/// capacity — the replay side of the seam.
pub struct ModelTarget {
    predictor: Predictor,
    parallelism: usize,
}

impl ModelTarget {
    pub fn new(predictor: Predictor, initial_parallelism: usize) -> Self {
        Self {
            predictor,
            parallelism: initial_parallelism.max(1),
        }
    }
}

impl ScalingTarget for ModelTarget {
    fn label(&self) -> String {
        "model".into()
    }

    fn parallelism(&self) -> usize {
        self.parallelism
    }

    fn actuate(&mut self, decision: &ScaleDecision) -> Result<Option<ResizePlan>, String> {
        // a Hold targets nothing, so the model keeps its parallelism
        if let Some(n) = decision.target_parallelism() {
            self.parallelism = n.max(1);
        }
        Ok(None)
    }

    fn serve(&mut self, demand: f64, dt: f64) -> Result<f64, String> {
        Ok(demand.min(self.capacity() * dt))
    }

    fn capacity(&self) -> f64 {
        self.predictor.throughput(self.parallelism)
    }
}

/// A live pilot as a scaling target: the decisions the USL replay only
/// simulates become `resize_pilot` calls on a provisioned backend.
pub struct PilotTarget {
    pilot: LivePilot,
    /// The envelope the platform proved with a `Throttle` plan, once one
    /// was committed: samples served *at* (or beyond) this parallelism
    /// report push-back; samples below it do not — the platform is no
    /// longer the binding constraint there.
    clamp_cap: Option<usize>,
}

impl PilotTarget {
    pub fn new(pilot: LivePilot) -> Self {
        Self {
            pilot,
            clamp_cap: None,
        }
    }

    /// The wrapped live pilot (status inspection, teardown).
    pub fn pilot(&self) -> &LivePilot {
        &self.pilot
    }

    pub fn shutdown(&self) {
        self.pilot.shutdown();
    }
}

impl ScalingTarget for PilotTarget {
    fn label(&self) -> String {
        self.pilot.label().into()
    }

    fn parallelism(&self) -> usize {
        self.pilot.parallelism()
    }

    fn is_resizing(&self) -> bool {
        self.pilot.is_resizing()
    }

    fn actuate(&mut self, decision: &ScaleDecision) -> Result<Option<ResizePlan>, String> {
        let Some(want) = decision.target_parallelism() else {
            return Ok(None); // a hold actuates nothing
        };
        if self.pilot.is_resizing() {
            return Ok(None); // one transition at a time
        }
        if want == self.pilot.parallelism() {
            return Ok(None);
        }
        // no-op plans still flow back: their semantics tell the loop why
        // the platform refused (e.g. the device cap)
        let plan = self.pilot.resize(want)?;
        if plan.semantics == ResizeSemantics::Throttle {
            self.clamp_cap = Some(plan.to);
        }
        Ok(Some(plan))
    }

    fn serve(&mut self, demand: f64, dt: f64) -> Result<f64, String> {
        self.pilot.step(demand, dt)
    }

    fn capacity(&self) -> f64 {
        self.pilot.capacity_estimate()
    }

    fn observe_interval(&mut self, served_rate: f64, demand_rate: f64) -> UslSample {
        let parallelism = self.pilot.parallelism();
        let at_envelope = self.clamp_cap.is_some_and(|cap| parallelism >= cap);
        UslSample::new(parallelism, served_rate, demand_rate).with_pushback(at_envelope)
    }
}

/// The per-tick conservation arithmetic shared by [`ControlLoop::run`]
/// and [`run_fixed`]: offered = processed + throttled + backlog, always —
/// plus the exact dollar ledger (run-rate per interval at the *realized*
/// parallelism, transitions per committed scale-up).
struct LoopAccounting {
    backlog: f64,
    ticks: Vec<Tick>,
    offered_total: f64,
    processed_total: f64,
    throttled_total: f64,
    max_backlog: f64,
    price: PriceModel,
    /// The hard dollars-per-hour bound a cost-objective loop
    /// ([`super::objective::Objective::Cost`]) runs under; every tick
    /// `debug_assert`s cumulative spend against it.
    budget_per_hour: Option<f64>,
    ledger: CostLedger,
}

impl LoopAccounting {
    fn new(intervals: usize, price: PriceModel, budget_per_hour: Option<f64>) -> Self {
        Self {
            backlog: 0.0,
            ticks: Vec::with_capacity(intervals),
            offered_total: 0.0,
            processed_total: 0.0,
            throttled_total: 0.0,
            max_backlog: 0.0,
            price,
            budget_per_hour,
            ledger: CostLedger::new(),
        }
    }

    /// Accrue the one-time charge for a realized parallelism move (the
    /// loop calls this with the pre/post-actuation parallelism; scale-
    /// downs are free by construction).
    fn charge_transition(&mut self, from: usize, to: usize) {
        self.ledger.charge_transition(&self.price, from, to);
    }

    /// Admit one interval's load (throttled to `admitted_rate`), serve it
    /// from the target, and account the tick.  Returns `(served, demand)`
    /// in messages — the recalibration sample for this interval.
    fn tick(
        &mut self,
        target: &mut dyn ScalingTarget,
        t: f64,
        rate: f64,
        admitted_rate: f64,
        decision: ScaleDecision,
        dt: f64,
    ) -> Result<(f64, f64), String> {
        let offered = rate * dt;
        let admitted = admitted_rate.min(rate) * dt;
        let demand = self.backlog + admitted;
        let served = target.serve(demand, dt)?;
        self.backlog = (demand - served).max(0.0);
        self.offered_total += offered;
        self.processed_total += served;
        self.throttled_total += offered - admitted;
        self.max_backlog = self.max_backlog.max(self.backlog);
        let parallelism = target.parallelism();
        let capacity = target.capacity();
        self.ledger.charge_interval(&self.price, parallelism, dt);
        self.assert_within_budget(parallelism);
        self.ticks.push(Tick {
            t,
            offered_rate: rate,
            parallelism,
            capacity,
            backlog: self.backlog,
            throttled: offered - admitted,
            est_p99_s: estimate_p99_s(self.backlog, admitted_rate.min(rate), capacity),
            decision,
        });
        Ok((served, demand))
    }

    /// The cost objective's contract, kept executable: at every tick the
    /// run-rate leg stays within [`RUN_BUDGET_FRACTION`] of the budget
    /// (floored at one unit — parallelism cannot go below 1, so a budget
    /// under one unit's run-rate degenerates to N=1) and the transition
    /// leg within its accrued [`TRANSITION_BUDGET_FRACTION`] allowance.
    /// Together: cumulative spend <= `budget * elapsed_hours` whenever
    /// the budget covers the N=1 floor.
    fn assert_within_budget(&self, _parallelism: usize) {
        #[cfg(debug_assertions)]
        if let Some(budget) = self.budget_per_hour {
            let hours = self.ledger.elapsed_s / 3600.0;
            let run_cap = (RUN_BUDGET_FRACTION * budget)
                .max(self.price.run_rate_dollars_per_hour(1));
            debug_assert!(
                self.ledger.run_dollars <= run_cap * hours + 1e-9,
                "run spend {} exceeds {} $/h over {} h (N={_parallelism})",
                self.ledger.run_dollars,
                run_cap,
                hours
            );
            debug_assert!(
                self.ledger.transition_dollars
                    <= TRANSITION_BUDGET_FRACTION * budget * hours + 1e-9,
                "transition spend {} exceeds its {} $/h allowance over {} h",
                self.ledger.transition_dollars,
                TRANSITION_BUDGET_FRACTION * budget,
                hours
            );
        }
    }

    fn finish(self, scale_events: u64, resizes: Vec<ResizeEvent>) -> AutoscaleReport {
        AutoscaleReport {
            ticks: self.ticks,
            offered_total: self.offered_total,
            processed_total: self.processed_total,
            throttled_total: self.throttled_total,
            scale_events,
            max_backlog: self.max_backlog,
            run_dollars: self.ledger.run_dollars,
            transition_dollars: self.ledger.transition_dollars,
            resizes,
            recalibration: None,
        }
    }
}

/// The closed loop: one autoscaler driving one [`ScalingTarget`] through a
/// rate trace, one control interval at a time.  Attach an
/// [`OnlineUslFitter`] with [`ControlLoop::with_recalibration`] and the
/// loop re-learns its own USL model mid-run: every interval's
/// `(parallelism, observed goodput)` lands in the fitter's sample store,
/// and a drift-triggered re-fit is hot-swapped into the autoscaler before
/// the next decision.
pub struct ControlLoop {
    autoscaler: Autoscaler,
    dt: f64,
    recalibrator: Option<OnlineUslFitter>,
}

impl ControlLoop {
    pub fn new(autoscaler: Autoscaler, dt: f64) -> Self {
        assert!(dt > 0.0, "control interval must be positive");
        Self {
            autoscaler,
            dt,
            recalibrator: None,
        }
    }

    /// Stream online USL re-fits into the loop: observed samples feed
    /// `fitter`, and every re-fit replaces the autoscaler's predictor
    /// mid-run.  The run's report carries the full sample store and
    /// model-swap history in
    /// [`AutoscaleReport::recalibration`](super::autoscale_sim::AutoscaleReport).
    pub fn with_recalibration(mut self, fitter: OnlineUslFitter) -> Self {
        self.recalibrator = Some(fitter);
        self
    }

    /// Run the loop over `trace` (offered msg/s per interval).  Each tick:
    /// observe → decide → actuate → sync belief to the platform's reality
    /// → admit (throttling if decided) → serve → account → sample (and
    /// possibly re-fit and hot-swap the model).
    pub fn run(
        mut self,
        target: &mut dyn ScalingTarget,
        trace: &[f64],
    ) -> Result<AutoscaleReport, String> {
        let dt = self.dt;
        let price = self.autoscaler.price();
        let budget = self.autoscaler.objective().budget_per_hour();
        let mut acct = LoopAccounting::new(trace.len(), price, budget);
        let mut resizes = Vec::new();
        for (i, &rate) in trace.iter().enumerate() {
            let t = i as f64 * dt;
            // mid-transition the pilot cannot actuate anything: keep the
            // EWMA warm but defer decisions (and their scale_events
            // accounting) until the transition lands
            let was_resizing = target.is_resizing();
            let decision = if was_resizing {
                self.autoscaler.observe_rate(rate);
                ScaleDecision::Hold {
                    parallelism: target.parallelism(),
                }
            } else {
                // the objective weighs the proposal against the ledger's
                // budget state (run-rate cap + accrued transition
                // allowance) before committing
                self.autoscaler.observe_costed(rate, &acct.ledger).decision
            };
            let before_actuation = target.parallelism();
            let mut resized_this_tick = false;
            if let Some(plan) = target.actuate(&decision)? {
                // a clamped plan teaches the autoscaler the platform's
                // real envelope: future demand beyond it resolves to
                // source throttling instead of a futile resize per tick
                if plan.semantics == ResizeSemantics::Throttle {
                    self.autoscaler.limit_max_parallelism(plan.to);
                }
                if plan.is_change() {
                    resized_this_tick = true;
                    resizes.push(ResizeEvent { t, plan });
                }
            }
            // the platform's push-back (device caps, clamped transitions)
            // becomes the autoscaler's belief for the next decision
            let parallelism = target.parallelism();
            if parallelism != self.autoscaler.current_parallelism() {
                self.autoscaler.set_parallelism(parallelism);
            }
            // transitions are charged on the *realized* move — what the
            // platform actually committed, clamps included, not what the
            // decision asked for (scale-downs are free by construction)
            acct.charge_transition(before_actuation, parallelism);
            let admitted_rate = match &decision {
                ScaleDecision::Throttle { max_rate, .. } => rate.min(*max_rate),
                _ => rate,
            };
            let (served, demand) = acct.tick(target, t, rate, admitted_rate, decision, dt)?;
            if let Some(fitter) = self.recalibrator.as_mut() {
                // transition intervals stay in the trace (accounting) but
                // are excluded from fitting — their parallelism label lies
                // about the capacity that actually served them.  Steady
                // means no transition touched the interval at all: none in
                // flight at its start, none committed during it (sub-`dt`
                // cold starts land inside the tick), and none still in
                // flight after the serve (the serve advances the clock
                // past resize deadlines, so the post-serve check alone
                // would mislabel a transition's tail interval).
                let steady = !was_resizing && !resized_this_tick && !target.is_resizing();
                let sample = target
                    .observe_interval(served / dt, demand / dt)
                    .with_steady(steady);
                if let Some(refreshed) = fitter.observe(t, sample, self.autoscaler.predictor()) {
                    self.autoscaler.set_predictor(refreshed);
                }
            }
        }
        let mut report = acct.finish(self.autoscaler.scale_events(), resizes);
        report.recalibration = self.recalibrator.map(OnlineUslFitter::into_trace);
        Ok(report)
    }
}

/// Baseline: the same trace served at fixed parallelism — no autoscaler,
/// no throttling.  The comparison `autoscale --live` reports against.
pub fn run_fixed(
    target: &mut dyn ScalingTarget,
    trace: &[f64],
    dt: f64,
) -> Result<AutoscaleReport, String> {
    run_fixed_priced(target, trace, dt, PriceModel::free())
}

/// [`run_fixed`] with the platform's [`PriceModel`], so a fixed-fleet
/// baseline carries comparable dollar columns in objective comparisons.
pub fn run_fixed_priced(
    target: &mut dyn ScalingTarget,
    trace: &[f64],
    dt: f64,
    price: PriceModel,
) -> Result<AutoscaleReport, String> {
    assert!(dt > 0.0, "control interval must be positive");
    let mut acct = LoopAccounting::new(trace.len(), price, None);
    for (i, &rate) in trace.iter().enumerate() {
        let hold = ScaleDecision::Hold {
            parallelism: target.parallelism(),
        };
        acct.tick(target, i as f64 * dt, rate, rate, hold, dt)?;
    }
    Ok(acct.finish(0, Vec::new()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::insight::autoscale::AutoscaleConfig;
    use crate::insight::autoscale_sim::trace_burst;
    use crate::miniapp::{PlatformKind, Scenario};
    use crate::pilot::{Platform, ResizeSemantics};
    use crate::sim::Dist;
    use crate::usl::UslParams;
    use std::sync::Arc;

    fn predictor(sigma: f64, kappa: f64, lambda: f64) -> Predictor {
        Predictor {
            params: UslParams::new(sigma, kappa, lambda),
        }
    }

    fn live_scenario(platform: PlatformKind) -> Scenario {
        Scenario {
            platform,
            partitions: 2,
            points_per_message: 64,
            centroids: 8,
            messages: 0, // unused by the interval driver
            ..Default::default()
        }
    }

    fn engine() -> Arc<dyn crate::engine::StepEngine> {
        let mut e = CalibratedEngine::new(11);
        e.insert((64, 8), Dist::Const(0.05));
        Arc::new(e)
    }

    fn live_target(platform: PlatformKind) -> PilotTarget {
        PilotTarget::new(LivePilot::provision(&live_scenario(platform), engine()).unwrap())
    }

    /// The loop's autoscaler for a ~0.05 s/message platform: λ≈20 msg/s
    /// per lane, near-linear.
    fn autoscaler(initial: usize, max: usize) -> Autoscaler {
        Autoscaler::new(
            predictor(0.02, 0.0001, 18.0),
            AutoscaleConfig {
                max_parallelism: max,
                ..Default::default()
            },
            initial,
        )
    }

    #[test]
    fn model_target_reproduces_the_replay_arithmetic() {
        // the pre-control-plane replay loop, kept inline as the executable
        // specification (replay() itself is now built on ControlLoop, so
        // comparing against it would be circular)
        let trace = trace_burst(60, 20.0, 120.0, 20);
        let p = predictor(0.02, 0.0001, 10.0);
        let mut scaler = Autoscaler::new(p.clone(), AutoscaleConfig::default(), 2);
        let mut backlog = 0.0f64;
        let mut expected = Vec::new(); // (parallelism, backlog) per tick
        let mut processed_total = 0.0;
        for &rate in &trace {
            let decision = scaler.observe(rate);
            let parallelism = scaler.current_parallelism();
            let capacity = p.throughput(parallelism);
            let admitted = match &decision {
                ScaleDecision::Throttle { max_rate, .. } => rate.min(*max_rate),
                _ => rate,
            };
            let processed = (backlog + admitted).min(capacity);
            backlog = (backlog + admitted - processed).max(0.0);
            processed_total += processed;
            expected.push((parallelism, backlog));
        }

        let report =
            crate::insight::autoscale_sim::replay(p, AutoscaleConfig::default(), &trace, 1.0, 2);
        assert_eq!(report.ticks.len(), expected.len());
        for (tick, (parallelism, backlog)) in report.ticks.iter().zip(&expected) {
            assert_eq!(tick.parallelism, *parallelism, "t={}", tick.t);
            assert!((tick.backlog - backlog).abs() < 1e-9, "t={}", tick.t);
        }
        assert!((report.processed_total - processed_total).abs() < 1e-9);
        assert_eq!(report.scale_events, scaler.scale_events());
    }

    #[test]
    fn live_loop_scales_a_real_lambda_pilot() {
        let mut target = live_target(PlatformKind::Lambda);
        let trace = trace_burst(40, 20.0, 200.0, 10);
        let report = ControlLoop::new(autoscaler(2, 16), 1.0)
            .run(&mut target, &trace)
            .unwrap();
        assert!(report.scale_events >= 1, "the burst must trigger scaling");
        assert!(
            !report.resizes.is_empty(),
            "decisions must land as real resize plans"
        );
        assert!(report
            .resizes
            .iter()
            .any(|r| r.plan.semantics == ResizeSemantics::ColdStart));
        // the backend's parallelism actually moved (observable via status)
        let peak = report.ticks.iter().map(|t| t.parallelism).max().unwrap();
        assert!(peak > 2, "peak parallelism {peak}");
        assert_eq!(target.pilot().status().parallelism, target.parallelism());
        target.shutdown();
    }

    #[test]
    fn live_loop_beats_fixed_baseline_under_burst() {
        let trace = trace_burst(40, 20.0, 200.0, 10);
        let mut scaled = live_target(PlatformKind::Lambda);
        let scaled_report = ControlLoop::new(autoscaler(2, 16), 1.0)
            .run(&mut scaled, &trace)
            .unwrap();
        scaled.shutdown();
        let mut fixed = live_target(PlatformKind::Lambda);
        let fixed_report = run_fixed(&mut fixed, &trace, 1.0).unwrap();
        fixed.shutdown();
        assert!(
            scaled_report.goodput() > fixed_report.goodput() + 0.05,
            "autoscaled {} must beat fixed {}",
            scaled_report.goodput(),
            fixed_report.goodput()
        );
    }

    #[test]
    fn live_loop_is_deterministic() {
        let run = || {
            let trace = trace_burst(30, 20.0, 150.0, 8);
            let mut target = live_target(PlatformKind::Lambda);
            let report = ControlLoop::new(autoscaler(2, 16), 1.0)
                .run(&mut target, &trace)
                .unwrap();
            target.shutdown();
            (
                report.goodput(),
                report.scale_events,
                report.resizes.len(),
                report.ticks.iter().map(|t| t.parallelism).collect::<Vec<_>>(),
            )
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn edge_cap_pushes_back_into_the_loop() {
        let mut target = live_target(PlatformKind::Edge);
        let trace = vec![300.0; 20];
        let report = ControlLoop::new(autoscaler(2, 64), 1.0)
            .run(&mut target, &trace)
            .unwrap();
        let peak = report.ticks.iter().map(|t| t.parallelism).max().unwrap();
        assert_eq!(
            peak,
            crate::serverless::edge::EDGE_MAX_CONCURRENCY,
            "the device envelope caps the loop"
        );
        assert!(report
            .resizes
            .iter()
            .any(|r| r.plan.semantics == ResizeSemantics::Throttle));
        // the clamped plan taught the autoscaler the real envelope: the
        // loop settles into source throttling instead of re-issuing a
        // futile scale-up (and a phantom scale event) every tick
        assert!(
            report.throttled_total > 0.0,
            "unreachable demand must throttle the source"
        );
        assert!(
            report.scale_events < trace.len() as u64 / 2,
            "scale events must not inflate once the cap is learned: {}",
            report.scale_events
        );
        target.shutdown();
    }

    #[test]
    fn fleet_cap_is_the_sum_of_site_envelopes() {
        // a two-site fleet (caps 4 + 3) pushes back at 7, not at the
        // single-site envelope of 4 — the Throttle plan carries the
        // heterogeneous sum into the autoscaler's belief
        let mut scenario = live_scenario(PlatformKind::Edge);
        scenario.set_extra("edge_sites", 2);
        let mut target =
            PilotTarget::new(LivePilot::provision(&scenario, engine()).unwrap());
        let trace = vec![400.0; 20];
        let report = ControlLoop::new(autoscaler(2, 64), 1.0)
            .run(&mut target, &trace)
            .unwrap();
        let peak = report.ticks.iter().map(|t| t.parallelism).max().unwrap();
        assert_eq!(peak, 7, "summed per-site caps bound the loop");
        assert!(report
            .resizes
            .iter()
            .any(|r| r.plan.semantics == ResizeSemantics::Throttle));
        assert!(report.throttled_total > 0.0);
        target.shutdown();
    }

    #[test]
    fn every_streaming_platform_closes_the_loop() {
        // the acceptance sweep: lambda, dask, edge, and the flink plugin
        // all run the closed loop end to end with real resizes
        for platform in [
            PlatformKind::Lambda,
            PlatformKind::DaskWrangler,
            PlatformKind::Edge,
            PlatformKind::Plugin(Platform::FLINK),
        ] {
            let mut target = live_target(platform);
            let trace = trace_burst(25, 15.0, 120.0, 6);
            let report = ControlLoop::new(autoscaler(2, 12), 1.0)
                .run(&mut target, &trace)
                .unwrap();
            assert_eq!(report.ticks.len(), 25, "{platform:?}");
            assert!(report.processed_total > 0.0, "{platform:?}");
            assert!(
                report.scale_events >= 1,
                "{platform:?} never scaled under a 8x burst"
            );
            target.shutdown();
        }
    }
}
