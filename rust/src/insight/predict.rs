//! Prediction & configuration recommendation on top of a USL fit
//! (paper: "Due to the small amount of data, it can easily be used to
//! identify optimal configurations for production systems").

use crate::usl::{UslFit, UslParams};

/// A performance predictor for one scenario group.
#[derive(Debug, Clone)]
pub struct Predictor {
    pub params: UslParams,
}

impl Predictor {
    pub fn from_fit(fit: &UslFit) -> Self {
        Self { params: fit.params }
    }

    /// Predicted throughput at parallelism `n`.
    pub fn throughput(&self, n: usize) -> f64 {
        self.params.throughput(n.max(1) as f64)
    }

    /// The parallelism maximizing throughput, clamped to `max_n`.
    pub fn optimal_parallelism(&self, max_n: usize) -> usize {
        match self.params.peak_n() {
            Some(peak) => (peak.round() as usize).clamp(1, max_n),
            None => max_n, // monotone: more is (weakly) better
        }
    }

    /// Minimal parallelism sustaining `target_rate` msg/s with a headroom
    /// factor (>1).  `None` if even the peak cannot sustain it — the caller
    /// must throttle the source instead (paper's future-work knob).
    pub fn required_parallelism(
        &self,
        target_rate: f64,
        headroom: f64,
        max_n: usize,
    ) -> Option<usize> {
        let need = target_rate * headroom.max(1.0);
        for n in 1..=max_n {
            if self.throughput(n) >= need {
                return Some(n);
            }
        }
        None
    }

    /// Max ingest rate a deployment of `n` can sustain (for throttling).
    pub fn sustainable_rate(&self, n: usize, headroom: f64) -> f64 {
        self.throughput(n) / headroom.max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn predictor(sigma: f64, kappa: f64, lambda: f64) -> Predictor {
        Predictor {
            params: UslParams::new(sigma, kappa, lambda),
        }
    }

    #[test]
    fn optimal_for_linear_is_max() {
        let p = predictor(0.01, 0.0, 10.0);
        assert_eq!(p.optimal_parallelism(32), 32);
    }

    #[test]
    fn optimal_for_retrograde_is_peak() {
        let p = predictor(0.1, 0.01, 10.0); // peak ≈ 9.5
        let n = p.optimal_parallelism(64);
        assert!((9..=10).contains(&n), "n={n}");
        // clamped by max
        assert_eq!(p.optimal_parallelism(4), 4);
    }

    #[test]
    fn required_parallelism_found() {
        let p = predictor(0.05, 0.001, 10.0);
        // need 50 msg/s with 20% headroom => 60 msg/s
        let n = p.required_parallelism(50.0, 1.2, 64).unwrap();
        assert!(p.throughput(n) >= 60.0);
        assert!(n == 1 || p.throughput(n - 1) < 60.0, "minimality");
    }

    #[test]
    fn unreachable_target_returns_none() {
        let p = predictor(0.9, 0.1, 5.0); // peaks at ~N=1, T≈5
        assert!(p.required_parallelism(100.0, 1.0, 64).is_none());
        // so the source must be throttled to the sustainable rate
        let cap = p.sustainable_rate(p.optimal_parallelism(64), 1.2);
        assert!(cap < 100.0 && cap > 0.0);
    }
}
