//! Experiment specification: the combinatorial parameter space of a
//! characterization campaign (paper: "the combinatorial space of parameters
//! is ample, and thus, a careful selection of the most significant factors
//! to investigate is critical").
//!
//! The space is described by composable **axes** — a name plus typed
//! levels — instead of a fixed set of struct fields.  An
//! [`ExperimentSpec`] is an ordered list of [`Axis`] values that expands
//! into concrete [`Scenario`]s through one row-major cartesian-product
//! iterator ([`ScenarioIter`]).  Canonical axis names bind to `Scenario`'s
//! typed fields; any other name flows into `Scenario::extra`, so a new
//! sweep dimension (edge site count, micro-batch interval, …) registers
//! like a pilot plugin did in PR 1: build the axis, add it to the spec,
//! and the sweep executor, grouping, USL analysis, and CSV export all pick
//! it up without code changes.

use crate::miniapp::{PlatformKind, Scenario};
use crate::sim::ContentionParams;
use crate::util::json::Json;
use std::fmt;

/// Canonical axis names bound to [`Scenario`]'s typed fields.  Any other
/// axis name becomes an extension parameter (`Scenario::extra`).
pub const AXIS_PLATFORM: &str = "platform";
pub const AXIS_MESSAGE_SIZE: &str = "message_size";
pub const AXIS_CENTROIDS: &str = "centroids";
pub const AXIS_MEMORY_MB: &str = "memory_mb";
pub const AXIS_PARTITIONS: &str = "partitions";
/// Workflow-graph axis: each level is a preset id
/// ([`crate::workflow::WorkflowSpec::preset_by_id`]). When present, the
/// sweep runs whole DAGs through the workflow driver instead of
/// single-stage scenarios.
pub const AXIS_WORKFLOW: &str = "workflow";
/// Fault-plan axis: each level is a [`FaultPlan`] preset id
/// ([`crate::sim::faults::FaultPlan::preset_by_id`]; 0 = fair weather).
/// A non-canonical name, so it rides `Scenario::extra` into the sim
/// driver with zero engine edits and composes with every existing grid.
///
/// [`FaultPlan`]: crate::sim::faults::FaultPlan
pub const AXIS_FAULTS: &str = crate::sim::faults::FAULTS_PARAM;
/// Price axis: each level is an integer *percent of list price* (100 =
/// the plugin's declared [`PriceModel`](crate::pilot::PriceModel), 50 =
/// half price / spot, 200 = peak surcharge).  A non-canonical name, so
/// it rides `Scenario::extra` with zero engine edits — the sim is
/// price-blind; [`cost_rows`](super::objective::cost_rows) reads the
/// level back out of the [`GroupKey`](super::sweep::GroupKey) to price
/// each fitted USL curve and mark the goodput-vs-$/msg Pareto front.
pub const AXIS_PRICE: &str = "price";

/// One typed level of an [`Axis`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AxisValue {
    Platform(PlatformKind),
    Int(u64),
}

impl AxisValue {
    pub fn as_platform(self) -> Option<PlatformKind> {
        match self {
            AxisValue::Platform(p) => Some(p),
            AxisValue::Int(_) => None,
        }
    }

    pub fn as_int(self) -> Option<u64> {
        match self {
            AxisValue::Int(n) => Some(n),
            AxisValue::Platform(_) => None,
        }
    }

    pub fn to_json(self) -> Json {
        match self {
            AxisValue::Platform(p) => Json::from(p.label()),
            AxisValue::Int(n) => Json::from(n as usize),
        }
    }
}

impl fmt::Display for AxisValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AxisValue::Platform(p) => write!(f, "{}", p.label()),
            AxisValue::Int(n) => write!(f, "{n}"),
        }
    }
}

impl From<PlatformKind> for AxisValue {
    fn from(p: PlatformKind) -> Self {
        AxisValue::Platform(p)
    }
}
impl From<u64> for AxisValue {
    fn from(n: u64) -> Self {
        AxisValue::Int(n)
    }
}
impl From<usize> for AxisValue {
    fn from(n: usize) -> Self {
        AxisValue::Int(n as u64)
    }
}
impl From<u32> for AxisValue {
    fn from(n: u32) -> Self {
        AxisValue::Int(n as u64)
    }
}

/// One sweep dimension: a name plus its typed levels.
#[derive(Debug, Clone, PartialEq)]
pub struct Axis {
    pub name: String,
    pub levels: Vec<AxisValue>,
}

impl Axis {
    pub fn new(name: impl Into<String>, levels: Vec<AxisValue>) -> Self {
        Self {
            name: name.into(),
            levels,
        }
    }

    /// The platform axis (name [`AXIS_PLATFORM`]).
    pub fn platforms(levels: &[PlatformKind]) -> Self {
        Self::new(
            AXIS_PLATFORM,
            levels.iter().map(|&p| AxisValue::Platform(p)).collect(),
        )
    }

    /// An integer-valued axis (canonical or extension).
    pub fn ints(name: impl Into<String>, levels: impl IntoIterator<Item = u64>) -> Self {
        Self::new(name, levels.into_iter().map(AxisValue::Int).collect())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::from(self.name.as_str())),
            (
                "levels",
                Json::Arr(self.levels.iter().map(|v| v.to_json()).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let name = v
            .get("name")
            .as_str()
            .ok_or_else(|| "axis: missing name".to_string())?
            .to_string();
        let raw = v
            .get("levels")
            .as_arr()
            .ok_or_else(|| format!("axis {name:?}: missing levels"))?;
        let mut levels = Vec::with_capacity(raw.len());
        for l in raw {
            levels.push(match l {
                Json::Str(s) => AxisValue::Platform(
                    PlatformKind::parse(s).ok_or_else(|| format!("unknown platform {s:?}"))?,
                ),
                other => AxisValue::Int(
                    other
                        .as_i64()
                        .ok_or_else(|| format!("axis {name:?}: non-integer level"))?
                        as u64,
                ),
            });
        }
        Ok(Self { name, levels })
    }
}

/// Bind one axis level into a scenario.  Canonical names hit the typed
/// fields; everything else lands in the scenario's extension bag.
fn bind(sc: &mut Scenario, name: &str, value: AxisValue) {
    match (name, value) {
        (AXIS_PLATFORM, AxisValue::Platform(p)) => sc.platform = p,
        (AXIS_PARTITIONS, AxisValue::Int(n)) => sc.partitions = n as usize,
        (AXIS_MESSAGE_SIZE, AxisValue::Int(n)) => sc.points_per_message = n as usize,
        (AXIS_CENTROIDS, AxisValue::Int(n)) => sc.centroids = n as usize,
        (AXIS_MEMORY_MB, AxisValue::Int(n)) => sc.memory_mb = n as u32,
        (other, AxisValue::Int(n)) => sc.set_extra(other, n),
        (other, AxisValue::Platform(_)) => {
            log::warn!("ignoring platform-typed level on non-platform axis {other:?}")
        }
    }
}

/// Read a scenario's level back for a named axis — the inverse of the
/// binding [`ScenarioIter`] performs (used to derive sweep group keys).
pub fn axis_value_of(sc: &Scenario, name: &str) -> Option<AxisValue> {
    match name {
        AXIS_PLATFORM => Some(AxisValue::Platform(sc.platform)),
        AXIS_PARTITIONS => Some(AxisValue::Int(sc.partitions as u64)),
        AXIS_MESSAGE_SIZE => Some(AxisValue::Int(sc.points_per_message as u64)),
        AXIS_CENTROIDS => Some(AxisValue::Int(sc.centroids as u64)),
        AXIS_MEMORY_MB => Some(AxisValue::Int(sc.memory_mb as u64)),
        other => sc.extra_param(other).map(AxisValue::Int),
    }
}

/// A sweep specification: ordered axes expanded into concrete
/// [`Scenario`]s (last axis varies fastest).
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentSpec {
    pub name: String,
    /// Sweep dimensions, outermost first.
    pub axes: Vec<Axis>,
    /// The axis the USL treats as parallelism N; one throughput curve is
    /// fitted per combination of the remaining axes.
    pub scale_axis: String,
    /// Messages per configuration.
    pub messages: usize,
    pub seed: u64,
    /// Lustre contention for the Dask platforms.
    pub lustre: ContentionParams,
}

impl ExperimentSpec {
    /// An empty spec (no axes → exactly the base scenario).
    pub fn new(name: impl Into<String>, messages: usize, seed: u64) -> Self {
        Self {
            name: name.into(),
            axes: Vec::new(),
            scale_axis: AXIS_PARTITIONS.to_string(),
            messages,
            seed,
            lustre: ContentionParams::ISOLATED,
        }
    }

    /// The paper's main grid (Figs 4-6): both platforms, partitions 1..16,
    /// all three message sizes, three model sizes.
    pub fn paper_grid(messages: usize, seed: u64) -> Self {
        let mut spec = Self::new("paper-grid", messages, seed);
        spec.lustre = ContentionParams::new(
            crate::pilot::plugins::hpc::DEFAULT_LUSTRE_ALPHA,
            crate::pilot::plugins::hpc::DEFAULT_LUSTRE_BETA,
        );
        spec.set_platforms(&[PlatformKind::Lambda, PlatformKind::DaskWrangler]);
        spec.set_ints(AXIS_MESSAGE_SIZE, [8_000, 16_000, 26_000]);
        spec.set_ints(AXIS_CENTROIDS, [128, 1_024, 8_192]);
        spec.set_ints(AXIS_MEMORY_MB, [3_008]);
        spec.set_ints(AXIS_PARTITIONS, [1, 2, 4, 8, 16]);
        spec
    }

    /// The edge extension grid (paper §V): cloud Lambda vs Greengrass-class
    /// edge at the same memory point, sweeping partitions past the edge
    /// device's container capacity so the USL fit captures its saturation.
    /// Memory sits inside the edge envelope so the axis is shared.
    pub fn edge_grid(messages: usize, seed: u64) -> Self {
        let mut spec = Self::new("edge-grid", messages, seed);
        spec.set_platforms(&[PlatformKind::Lambda, PlatformKind::Edge]);
        spec.set_ints(AXIS_MESSAGE_SIZE, [8_000]);
        spec.set_ints(AXIS_CENTROIDS, [128, 1_024]);
        spec.set_ints(AXIS_MEMORY_MB, [1_024]);
        spec.set_ints(AXIS_PARTITIONS, [1, 2, 4, 8, 16]);
        spec
    }

    /// The multi-site fleet grid (ROADMAP "Multi-region / multi-site
    /// edge"): one USL curve per `edge_sites` level, sweeping partitions
    /// past every fleet's summed container capacity so each fit captures
    /// where that fleet saturates and starts spilling over the backhaul —
    /// the backhaul-induced coherency (β) term, quantified per fleet size.
    pub fn edge_fleet_grid(messages: usize, seed: u64) -> Self {
        let mut spec = Self::new("edge-fleet-grid", messages, seed);
        spec.set_platforms(&[PlatformKind::Edge]);
        spec.set_ints(AXIS_MESSAGE_SIZE, [8_000]);
        spec.set_ints(AXIS_CENTROIDS, [128]);
        spec.set_ints(AXIS_MEMORY_MB, [1_024]);
        spec.set_ints("edge_sites", [1, 2, 4]);
        spec.set_ints(AXIS_PARTITIONS, [1, 2, 4, 8, 16]);
        spec
    }

    /// Fig 3's memory sweep: Lambda, 8,000 points, 1,024 centroids.
    pub fn lambda_memory_sweep(messages: usize, seed: u64) -> Self {
        let mut spec = Self::new("lambda-memory", messages, seed);
        spec.set_platforms(&[PlatformKind::Lambda]);
        spec.set_ints(AXIS_MESSAGE_SIZE, [8_000]);
        spec.set_ints(AXIS_CENTROIDS, [1_024]);
        spec.set_ints(AXIS_MEMORY_MB, [256, 512, 1_024, 1_792, 2_240, 3_008]);
        spec.set_ints(AXIS_PARTITIONS, [8]);
        spec
    }

    /// A minimal smoke grid (CI, determinism tests): both cloud platforms,
    /// one light workload point, three partition levels.
    pub fn tiny_grid(messages: usize, seed: u64) -> Self {
        let mut spec = Self::new("tiny-grid", messages, seed);
        spec.lustre = ContentionParams::new(
            crate::pilot::plugins::hpc::DEFAULT_LUSTRE_ALPHA,
            crate::pilot::plugins::hpc::DEFAULT_LUSTRE_BETA,
        );
        spec.set_platforms(&[PlatformKind::Lambda, PlatformKind::DaskWrangler]);
        spec.set_ints(AXIS_MESSAGE_SIZE, [256]);
        spec.set_ints(AXIS_CENTROIDS, [16]);
        spec.set_ints(AXIS_MEMORY_MB, [3_008]);
        spec.set_ints(AXIS_PARTITIONS, [1, 2, 4]);
        spec
    }

    /// The cost grid: the tiny-grid workload swept over price levels
    /// ([`AXIS_PRICE`], percent of list price), so every (platform,
    /// price) pair yields its own USL fit and the analysis can report
    /// the goodput-vs-$/msg Pareto front across pricing regimes.
    pub fn cost_grid(messages: usize, seed: u64) -> Self {
        let mut spec = Self::new("cost-grid", messages, seed);
        spec.lustre = ContentionParams::new(
            crate::pilot::plugins::hpc::DEFAULT_LUSTRE_ALPHA,
            crate::pilot::plugins::hpc::DEFAULT_LUSTRE_BETA,
        );
        spec.set_platforms(&[PlatformKind::Lambda, PlatformKind::DaskWrangler]);
        spec.set_ints(AXIS_MESSAGE_SIZE, [256]);
        spec.set_ints(AXIS_CENTROIDS, [16]);
        spec.set_ints(AXIS_MEMORY_MB, [3_008]);
        spec.set_ints(AXIS_PRICE, [50, 100, 200]);
        spec.set_ints(AXIS_PARTITIONS, [1, 2, 4, 8]);
        spec
    }

    /// The workflow-graph grid: every preset DAG
    /// ([`crate::workflow::PRESETS`]) swept over a shared parallelism
    /// budget multiplier. `partitions` scales every stage's base
    /// parallelism, so each workflow yields one end-to-end USL curve and
    /// one critical-path model fit.
    pub fn workflow_grid(messages: usize, seed: u64) -> Self {
        let mut spec = Self::new("workflow-grid", messages, seed);
        spec.lustre = ContentionParams::new(
            crate::pilot::plugins::hpc::DEFAULT_LUSTRE_ALPHA,
            crate::pilot::plugins::hpc::DEFAULT_LUSTRE_BETA,
        );
        spec.set_ints(AXIS_WORKFLOW, [0, 1, 2, 3]);
        spec.set_ints(AXIS_PARTITIONS, [1, 2, 4, 8]);
        spec
    }

    /// Replace the axis with `axis.name` in place, or append it.
    pub fn set_axis(&mut self, axis: Axis) {
        match self.axes.iter_mut().find(|a| a.name == axis.name) {
            Some(slot) => *slot = axis,
            None => self.axes.push(axis),
        }
    }

    /// Builder form of [`set_axis`](Self::set_axis).
    pub fn with_axis(mut self, axis: Axis) -> Self {
        self.set_axis(axis);
        self
    }

    /// Replace an integer axis's levels (append the axis if new).
    pub fn set_ints(&mut self, name: &str, levels: impl IntoIterator<Item = u64>) {
        self.set_axis(Axis::ints(name, levels));
    }

    /// Replace the platform axis's levels.
    pub fn set_platforms(&mut self, platforms: &[PlatformKind]) {
        self.set_axis(Axis::platforms(platforms));
    }

    pub fn axis(&self, name: &str) -> Option<&Axis> {
        self.axes.iter().find(|a| a.name == name)
    }

    /// Number of levels on the scale axis (observations per USL curve).
    pub fn scale_levels(&self) -> usize {
        self.axis(&self.scale_axis).map_or(1, |a| a.levels.len())
    }

    /// Number of concrete scenarios this spec expands to.
    pub fn size(&self) -> usize {
        self.axes.iter().map(|a| a.levels.len()).product()
    }

    fn base_scenario(&self) -> Scenario {
        Scenario {
            messages: self.messages,
            seed: self.seed,
            lustre: self.lustre,
            ..Scenario::default()
        }
    }

    /// Row-major cartesian-product expansion (deterministic order; the
    /// last axis varies fastest).
    pub fn iter(&self) -> ScenarioIter<'_> {
        ScenarioIter {
            spec: self,
            odometer: vec![0; self.axes.len()],
            exhausted: self.axes.iter().any(|a| a.levels.is_empty()),
        }
    }

    /// Expand to concrete scenarios (deterministic order).
    pub fn scenarios(&self) -> Vec<Scenario> {
        self.iter().collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::from(self.name.as_str())),
            (
                "axes",
                Json::Arr(self.axes.iter().map(Axis::to_json).collect()),
            ),
            ("scale_axis", Json::from(self.scale_axis.as_str())),
            ("messages", Json::from(self.messages)),
            ("seed", Json::from(self.seed as i64)),
            (
                "lustre",
                Json::obj(vec![
                    ("alpha", Json::from(self.lustre.alpha)),
                    ("beta", Json::from(self.lustre.beta)),
                ]),
            ),
            ("size", Json::from(self.size())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let mut spec = ExperimentSpec::new(
            v.get("name").as_str().unwrap_or("spec"),
            v.get("messages")
                .as_usize()
                .ok_or_else(|| "messages: expected integer".to_string())?,
            v.get("seed")
                .as_i64()
                .ok_or_else(|| "seed: expected integer".to_string())? as u64,
        );
        if let Some(s) = v.get("scale_axis").as_str() {
            spec.scale_axis = s.to_string();
        }
        let axes = v
            .get("axes")
            .as_arr()
            .ok_or_else(|| "axes: expected array".to_string())?;
        for a in axes {
            let axis = Axis::from_json(a)?;
            spec.axes.push(axis);
        }
        let lustre = v.get("lustre");
        if lustre.as_obj().is_some() {
            spec.lustre = ContentionParams::new(
                lustre.get("alpha").as_f64().unwrap_or(0.0),
                lustre.get("beta").as_f64().unwrap_or(0.0),
            );
        }
        Ok(spec)
    }
}

/// Iterator over a spec's cartesian product of axis levels.
pub struct ScenarioIter<'a> {
    spec: &'a ExperimentSpec,
    odometer: Vec<usize>,
    exhausted: bool,
}

impl Iterator for ScenarioIter<'_> {
    type Item = Scenario;

    fn next(&mut self) -> Option<Scenario> {
        if self.exhausted {
            return None;
        }
        let mut sc = self.spec.base_scenario();
        for (axis, &i) in self.spec.axes.iter().zip(&self.odometer) {
            bind(&mut sc, &axis.name, axis.levels[i]);
        }
        // advance the odometer (last axis fastest)
        self.exhausted = true;
        for pos in (0..self.odometer.len()).rev() {
            self.odometer[pos] += 1;
            if self.odometer[pos] < self.spec.axes[pos].levels.len() {
                self.exhausted = false;
                break;
            }
            self.odometer[pos] = 0;
        }
        Some(sc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_grid_dimensions() {
        let spec = ExperimentSpec::paper_grid(32, 1);
        // 2 platforms x 3 MS x 3 WC x 1 memory x 5 partitions = 90
        assert_eq!(spec.size(), 90);
        let scenarios = spec.scenarios();
        assert_eq!(scenarios.len(), 90);
        // the scale axis varies fastest (row-major expansion)
        assert_eq!(scenarios[0].partitions, 1);
        assert_eq!(scenarios[1].partitions, 2);
        assert_eq!(scenarios[0].platform, PlatformKind::Lambda);
    }

    #[test]
    fn edge_grid_dimensions() {
        let spec = ExperimentSpec::edge_grid(16, 1);
        // 2 platforms x 1 MS x 2 WC x 1 memory x 5 partitions = 20
        assert_eq!(spec.size(), 20);
        let platform_axis = spec.axis(AXIS_PLATFORM).unwrap();
        assert!(platform_axis
            .levels
            .contains(&AxisValue::Platform(PlatformKind::Edge)));
        for s in spec.scenarios() {
            assert!(
                s.memory_mb <= crate::serverless::edge::EDGE_MAX_MEMORY_MB,
                "edge grid stays inside the device envelope"
            );
        }
    }

    #[test]
    fn edge_fleet_grid_dimensions() {
        let spec = ExperimentSpec::edge_fleet_grid(16, 1);
        // 1 platform x 1 MS x 1 WC x 1 memory x 3 fleet sizes x 5 partitions
        assert_eq!(spec.size(), 15);
        let sites = spec.axis("edge_sites").unwrap();
        assert_eq!(sites.levels.len(), 3);
        for sc in spec.scenarios() {
            assert_eq!(sc.platform, PlatformKind::Edge);
            assert!(matches!(sc.extra_param("edge_sites"), Some(1 | 2 | 4)));
        }
    }

    #[test]
    fn memory_sweep_dimensions() {
        let spec = ExperimentSpec::lambda_memory_sweep(32, 1);
        assert_eq!(spec.size(), 6);
        for s in spec.scenarios() {
            assert_eq!(s.points_per_message, 8_000);
            assert_eq!(s.centroids, 1_024);
        }
    }

    #[test]
    fn scenarios_deterministic() {
        let a = ExperimentSpec::paper_grid(8, 3).scenarios();
        let b = ExperimentSpec::paper_grid(8, 3).scenarios();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.partitions, y.partitions);
            assert_eq!(x.platform, y.platform);
        }
    }

    #[test]
    fn set_ints_replaces_in_place() {
        let mut spec = ExperimentSpec::paper_grid(8, 3);
        let names: Vec<String> = spec.axes.iter().map(|a| a.name.clone()).collect();
        spec.set_ints(AXIS_MESSAGE_SIZE, [16_000]);
        let after: Vec<String> = spec.axes.iter().map(|a| a.name.clone()).collect();
        assert_eq!(names, after, "axis order preserved");
        assert_eq!(spec.axis(AXIS_MESSAGE_SIZE).unwrap().levels.len(), 1);
        assert_eq!(spec.size(), 30);
    }

    #[test]
    fn custom_axis_flows_into_scenarios() {
        let spec = ExperimentSpec::tiny_grid(8, 3).with_axis(Axis::ints("edge_sites", [1, 2]));
        assert_eq!(spec.size(), 12); // tiny grid (6) x 2 site levels
        let mut seen = Vec::new();
        for sc in spec.scenarios() {
            let sites = sc.extra_param("edge_sites").unwrap();
            assert_eq!(axis_value_of(&sc, "edge_sites"), Some(AxisValue::Int(sites)));
            seen.push(sites);
        }
        assert!(seen.contains(&1) && seen.contains(&2));
    }

    #[test]
    fn fault_axis_composes_with_any_grid() {
        // the chaos axis is just another extra-param axis: no engine edits
        let spec = ExperimentSpec::tiny_grid(8, 3).with_axis(Axis::ints(AXIS_FAULTS, [0, 1, 3]));
        assert_eq!(spec.size(), 18); // tiny grid (6) x 3 fault levels
        let mut keys = Vec::new();
        for sc in spec.scenarios() {
            let id = sc.extra_param(AXIS_FAULTS).unwrap();
            assert!(matches!(id, 0 | 1 | 3));
            assert_eq!(axis_value_of(&sc, AXIS_FAULTS), Some(AxisValue::Int(id)));
            keys.push(sc.run_key());
        }
        keys.sort_unstable();
        let before = keys.len();
        keys.dedup();
        assert_eq!(before, keys.len(), "fault levels must derive distinct run keys");
    }

    #[test]
    fn price_axis_composes_with_any_grid() {
        // the price axis is just another extra-param axis: no engine edits
        let spec = ExperimentSpec::cost_grid(8, 3);
        assert_eq!(spec.size(), 48); // 2 platforms x 3 price levels x 4 partitions
        let mut keys = Vec::new();
        for sc in spec.scenarios() {
            let pct = sc.extra_param(AXIS_PRICE).unwrap();
            assert!(matches!(pct, 50 | 100 | 200));
            assert_eq!(axis_value_of(&sc, AXIS_PRICE), Some(AxisValue::Int(pct)));
            keys.push(sc.run_key());
        }
        keys.sort_unstable();
        let before = keys.len();
        keys.dedup();
        assert_eq!(before, keys.len(), "price levels must derive distinct run keys");
    }

    #[test]
    fn empty_axis_expands_to_nothing() {
        let spec =
            ExperimentSpec::tiny_grid(8, 3).with_axis(Axis::new("dead", Vec::new()));
        assert_eq!(spec.size(), 0);
        assert!(spec.scenarios().is_empty());
    }

    #[test]
    fn json_round_trip_preserves_every_field() {
        let spec = ExperimentSpec::paper_grid(8, 3)
            .with_axis(Axis::ints("edge_sites", [1, 2, 4]));
        let j = spec.to_json();
        assert_eq!(j.get("size").as_usize(), Some(270));
        // the fields the old export silently dropped
        assert_eq!(j.get("seed").as_i64(), Some(3));
        assert!(j.get("lustre").get("alpha").as_f64().unwrap() > 0.0);
        let memory = spec.axis(AXIS_MEMORY_MB).unwrap();
        assert_eq!(memory.levels, vec![AxisValue::Int(3_008)]);
        let back = ExperimentSpec::from_json(&j).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn json_round_trip_all_platform_labels() {
        for platform in [
            PlatformKind::Lambda,
            PlatformKind::DaskWrangler,
            PlatformKind::DaskStampede2,
            PlatformKind::Edge,
        ] {
            let mut spec = ExperimentSpec::tiny_grid(8, 1);
            spec.set_platforms(&[platform]);
            let back = ExperimentSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back, spec, "{platform:?}");
        }
    }
}
