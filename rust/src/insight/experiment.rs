//! Experiment specification: the full factorial parameter space of a
//! characterization campaign (paper: "the combinatorial space of parameters
//! is ample, and thus, a careful selection of the most significant factors
//! to investigate is critical").

use crate::miniapp::{PlatformKind, Scenario};
use crate::sim::ContentionParams;
use crate::util::json::Json;

/// A sweep specification, expanded into concrete [`Scenario`]s.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    pub name: String,
    pub platforms: Vec<PlatformKind>,
    /// N^px(p) values to sweep.
    pub partitions: Vec<usize>,
    /// MS axis (points per message).
    pub message_sizes: Vec<usize>,
    /// WC axis (centroids).
    pub centroids: Vec<usize>,
    /// Lambda memory sizes (Fig 3 axis; single value for other figures).
    pub memory_mb: Vec<u32>,
    /// Messages per configuration.
    pub messages: usize,
    pub seed: u64,
    /// Lustre contention for the Dask platforms.
    pub lustre: ContentionParams,
}

impl ExperimentSpec {
    /// The paper's main grid (Figs 4-6): both platforms, partitions 1..16,
    /// all three message sizes, three model sizes.
    pub fn paper_grid(messages: usize, seed: u64) -> Self {
        Self {
            name: "paper-grid".into(),
            platforms: vec![PlatformKind::Lambda, PlatformKind::DaskWrangler],
            partitions: vec![1, 2, 4, 8, 16],
            message_sizes: vec![8_000, 16_000, 26_000],
            centroids: vec![128, 1_024, 8_192],
            memory_mb: vec![3_008],
            messages,
            seed,
            lustre: ContentionParams::new(
                crate::pilot::plugins::hpc::DEFAULT_LUSTRE_ALPHA,
                crate::pilot::plugins::hpc::DEFAULT_LUSTRE_BETA,
            ),
        }
    }

    /// The edge extension grid (paper §V): cloud Lambda vs Greengrass-class
    /// edge at the same memory point, sweeping partitions past the edge
    /// device's container capacity so the USL fit captures its saturation.
    /// Memory sits inside the edge envelope so the axis is shared.
    pub fn edge_grid(messages: usize, seed: u64) -> Self {
        Self {
            name: "edge-grid".into(),
            platforms: vec![PlatformKind::Lambda, PlatformKind::Edge],
            partitions: vec![1, 2, 4, 8, 16],
            message_sizes: vec![8_000],
            centroids: vec![128, 1_024],
            memory_mb: vec![1_024],
            messages,
            seed,
            lustre: ContentionParams::ISOLATED,
        }
    }

    /// Fig 3's memory sweep: Lambda, 8,000 points, 1,024 centroids.
    pub fn lambda_memory_sweep(messages: usize, seed: u64) -> Self {
        Self {
            name: "lambda-memory".into(),
            platforms: vec![PlatformKind::Lambda],
            partitions: vec![8],
            message_sizes: vec![8_000],
            centroids: vec![1_024],
            memory_mb: vec![256, 512, 1_024, 1_792, 2_240, 3_008],
            messages,
            seed,
            lustre: ContentionParams::ISOLATED,
        }
    }

    /// Number of concrete scenarios this spec expands to.
    pub fn size(&self) -> usize {
        self.platforms.len()
            * self.partitions.len()
            * self.message_sizes.len()
            * self.centroids.len()
            * self.memory_mb.len()
    }

    /// Expand to concrete scenarios (deterministic order).
    pub fn scenarios(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.size());
        for &platform in &self.platforms {
            for &ms in &self.message_sizes {
                for &wc in &self.centroids {
                    for &mem in &self.memory_mb {
                        for &p in &self.partitions {
                            out.push(Scenario {
                                platform,
                                partitions: p,
                                points_per_message: ms,
                                centroids: wc,
                                memory_mb: mem,
                                messages: self.messages,
                                lustre: self.lustre,
                                seed: self.seed,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::from(self.name.as_str())),
            (
                "platforms",
                Json::Arr(
                    self.platforms
                        .iter()
                        .map(|p| Json::from(p.label()))
                        .collect(),
                ),
            ),
            (
                "partitions",
                Json::from(self.partitions.clone()),
            ),
            ("message_sizes", Json::from(self.message_sizes.clone())),
            ("centroids", Json::from(self.centroids.clone())),
            ("messages", Json::from(self.messages)),
            ("size", Json::from(self.size())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_grid_dimensions() {
        let spec = ExperimentSpec::paper_grid(32, 1);
        // 2 platforms x 5 partitions x 3 MS x 3 WC x 1 memory = 90
        assert_eq!(spec.size(), 90);
        assert_eq!(spec.scenarios().len(), 90);
    }

    #[test]
    fn edge_grid_dimensions() {
        let spec = ExperimentSpec::edge_grid(16, 1);
        // 2 platforms x 5 partitions x 1 MS x 2 WC x 1 memory = 20
        assert_eq!(spec.size(), 20);
        assert!(spec.platforms.contains(&PlatformKind::Edge));
        for s in spec.scenarios() {
            assert!(
                s.memory_mb <= crate::serverless::edge::EDGE_MAX_MEMORY_MB,
                "edge grid stays inside the device envelope"
            );
        }
    }

    #[test]
    fn memory_sweep_dimensions() {
        let spec = ExperimentSpec::lambda_memory_sweep(32, 1);
        assert_eq!(spec.size(), 6);
        for s in spec.scenarios() {
            assert_eq!(s.points_per_message, 8_000);
            assert_eq!(s.centroids, 1_024);
        }
    }

    #[test]
    fn scenarios_deterministic() {
        let a = ExperimentSpec::paper_grid(8, 3).scenarios();
        let b = ExperimentSpec::paper_grid(8, 3).scenarios();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.partitions, y.partitions);
            assert_eq!(x.platform, y.platform);
        }
    }

    #[test]
    fn json_export() {
        let j = ExperimentSpec::paper_grid(8, 3).to_json();
        assert_eq!(j.get("size").as_usize(), Some(90));
    }
}
