//! StreamInsight (paper §IV): end-to-end performance experimentation —
//! experiment design ([`experiment`]), automated sweeps ([`sweep`]), USL
//! analysis ([`analysis`]), prediction ([`predict`]), predictive
//! autoscaling ([`autoscale`]), and the Table I variable glossary
//! ([`vars`]).

pub mod analysis;
pub mod autoscale_sim;
pub mod config;
pub mod autoscale;
pub mod experiment;
pub mod figures;
pub mod predict;
pub mod sweep;
pub mod vars;

pub use analysis::{analyze, table, AnalysisRow};
pub use autoscale::{AutoscaleConfig, Autoscaler, ScaleDecision};
pub use autoscale_sim::{replay, trace_burst, trace_diurnal, AutoscaleReport};
pub use config::{spec_from_file, spec_from_toml};
pub use experiment::ExperimentSpec;
pub use predict::Predictor;
pub use sweep::{group_keys, group_observations, run_sweep, to_csv, SweepRow};
