//! StreamInsight (paper §IV): the **campaign engine** — end-to-end
//! performance experimentation over a composable parameter space.
//!
//! # Architecture: axes → scenarios → parallel sweep → incremental fits
//!
//! A characterization *campaign* is described by an [`ExperimentSpec`]:
//! an ordered list of [`Axis`] values (name + typed levels) expanded into
//! concrete scenarios by one cartesian-product iterator
//! ([`experiment::ScenarioIter`]).  Canonical names (`platform`,
//! `message_size`, `centroids`, `memory_mb`, `partitions`) bind to
//! `Scenario`'s typed fields; any other name flows into
//! `Scenario::extra`, so a new sweep dimension — edge site count,
//! micro-batch interval — registers like a pilot plugin did in PR 1:
//! construct the axis, attach it to the spec, and *nothing else changes*:
//!
//! - [`sweep::run_sweep_jobs`] executes the grid on a scoped worker pool
//!   (scenarios are independent; RNG is seeded per configuration), streams
//!   [`SweepRow`]s back in completion order for progress reporting, and
//!   reassembles deterministic spec order — `--jobs N` output is
//!   byte-identical to `--jobs 1`.
//! - Rows group into USL curves by [`GroupKey`], the row's assignment on
//!   every non-scale axis, derived from the axes themselves.
//! - [`analysis::analyze`] fits USL per group;
//!   [`analysis::IncrementalAnalysis`] produces the same fits while the
//!   sweep is still running, as each group's last scale level lands.
//! - [`config`] loads specs declaratively from TOML (including custom
//!   `[axes]`), [`figures`] regenerates the paper's tables/figures,
//!   [`predict`] and [`autoscale`] consume the fitted models, and
//!   [`vars`] renders the Table I variable glossary.

pub mod analysis;
pub mod autoscale;
pub mod autoscale_sim;
pub mod config;
pub mod experiment;
pub mod figures;
pub mod predict;
pub mod sweep;
pub mod vars;

pub use analysis::{analyze, table, AnalysisRow, IncrementalAnalysis};
pub use autoscale::{AutoscaleConfig, Autoscaler, ScaleDecision};
pub use autoscale_sim::{replay, trace_burst, trace_diurnal, AutoscaleReport};
pub use config::{spec_from_file, spec_from_toml};
pub use experiment::{
    axis_value_of, Axis, AxisValue, ExperimentSpec, AXIS_CENTROIDS, AXIS_MEMORY_MB,
    AXIS_MESSAGE_SIZE, AXIS_PARTITIONS, AXIS_PLATFORM,
};
pub use sweep::{
    group_keys, group_observations, paper_key, run_sweep, run_sweep_jobs, to_csv, GroupKey,
    SweepProgress, SweepRow,
};
