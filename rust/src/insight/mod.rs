//! StreamInsight (paper §IV): the **campaign engine** — end-to-end
//! performance experimentation over a composable parameter space — and,
//! since the elastic-control-plane PR, the **closed scaling loop** the
//! paper's conclusion calls for.
//!
//! # Architecture: axes → scenarios → parallel sweep → incremental fits
//!
//! A characterization *campaign* is described by an [`ExperimentSpec`]:
//! an ordered list of [`Axis`] values (name + typed levels) expanded into
//! concrete scenarios by one cartesian-product iterator
//! ([`experiment::ScenarioIter`]).  Canonical names (`platform`,
//! `message_size`, `centroids`, `memory_mb`, `partitions`) bind to
//! `Scenario`'s typed fields; any other name flows into
//! `Scenario::extra`, so a new sweep dimension — edge site count,
//! micro-batch interval — registers like a pilot plugin did in PR 1:
//! construct the axis, attach it to the spec, and *nothing else changes*:
//!
//! - [`sweep::run_sweep_jobs`] executes the grid on a scoped worker pool
//!   (scenarios are independent; RNG is seeded per configuration), streams
//!   [`SweepRow`]s back in completion order for progress reporting, and
//!   reassembles deterministic spec order — `--jobs N` output is
//!   byte-identical to `--jobs 1`.
//! - Rows group into USL curves by [`GroupKey`]; [`analysis::analyze`]
//!   fits USL per group, [`analysis::IncrementalAnalysis`] streams the
//!   same fits mid-sweep, [`config`] loads specs from TOML, [`figures`]
//!   regenerates the paper's tables/figures, and [`vars`] renders the
//!   Table I glossary.
//!
//! # The control plane: decisions that re-provision live pilots
//!
//! [`predict`] turns a USL fit into capacity questions; [`autoscale`]
//! turns observed rates into [`ScaleDecision`]s.  What happens to a
//! decision is the [`control::ScalingTarget`] seam:
//!
//! - [`control::ModelTarget`] replays decisions against the USL model —
//!   [`autoscale_sim::replay`] is now a thin wrapper over
//!   [`control::ControlLoop`] with this target.
//! - [`control::PilotTarget`] actuates them on a **live pilot** through
//!   `PilotComputeService::resize_pilot` (via `miniapp::LivePilot`):
//!   transitions ride the pilot `Resizing` state with platform-true costs
//!   (cold starts, batch queues, savepoints, device caps), and every
//!   served message is a real `StreamProcessor::process` call.
//!
//! `autoscale --live --platform <p>` runs the closed loop end to end and
//! reports goodput/backlog/scale-events against a fixed-parallelism
//! baseline ([`control::run_fixed`]).  Broker platforms close the same
//! loop over their shard count: `--platform kafka|kinesis` actuates
//! `set_partitions`/`set_shards` repartition plans with the consumer
//! fleet tracking the shards.
//!
//! # Online recalibration: the loop re-learns its own model
//!
//! The static fit the loop starts from goes stale the moment the live
//! platform drifts (cold starts, edge throttling, reshard costs).  The
//! [`recalibrate`] module closes the remaining gap:
//! [`control::ScalingTarget::observe_interval`] reports every interval's
//! `(parallelism, observed goodput)` — platform push-back included — into
//! an [`recalibrate::OnlineUslFitter`] (windowed, recency-weighted sample
//! store), whose drift detector triggers streaming USL re-fits
//! ([`crate::usl::fit_weighted`]) that are hot-swapped into the live
//! [`Autoscaler`] mid-run ([`Autoscaler::set_predictor`]).
//! `autoscale --live --recalibrate` reports the recalibrated loop against
//! the static-fit loop side by side.
//!
//! # Objectives & pricing: goodput per dollar under a latency SLO
//!
//! Every platform plugin declares a [`PriceModel`](crate::pilot::PriceModel)
//! next to its transition times, and the [`objective`] module gives the
//! loop a multi-objective head: [`Objective::Cost`] maximizes goodput
//! under a hard dollars-per-hour budget (run-rate capped, scale-up
//! transitions drawn from an accrued allowance — a re-fit's
//! recommendation is weighed against transition *and* run-rate cost
//! before committing), [`Objective::Slo`] holds an estimated p99 sojourn
//! target whenever the fit says capacity exists, and
//! [`Objective::Goodput`] (the default) reproduces the pre-objective
//! loop bit for bit.  `autoscale --objective cost|slo|goodput` compares
//! the shaped loop against the goodput-only loop with dollar totals and
//! SLO-attainment columns; a `price` axis ([`AXIS_PRICE`]) rides
//! `Scenario::extra` through the campaign engine so `sweep --grid cost`
//! fits USL curves per price point and [`cost_rows`]/[`pareto_csv`]
//! report the goodput-vs-$/msg Pareto front.
//!
//! # Workflow graphs: per-stage fits composed along the critical path
//!
//! The [`workflow`] module models whole DAG campaigns
//! ([`crate::workflow::WorkflowSpec`]): a `workflow` axis level stands for
//! an entire graph, the sweep runs each stage through the cohort sim core
//! ([`workflow::run_workflow_sweep_jobs`] keeps per-stage rows),
//! [`workflow::fit_stages`] fits one USL curve per stage over the shared
//! parallelism budget, and [`workflow::CriticalPathModel`] composes the
//! fits into an end-to-end throughput prediction with bottleneck
//! identification.  [`workflow::WorkflowTarget`] plugs the composed model
//! into [`ControlLoop`]: one worker budget, water-filled across stages so
//! the allocation follows the bottleneck as load shifts between stages.

pub mod analysis;
pub mod autoscale;
pub mod autoscale_sim;
pub mod chaos;
pub mod config;
pub mod control;
pub mod experiment;
pub mod figures;
pub mod objective;
pub mod predict;
pub mod recalibrate;
pub mod sweep;
pub mod vars;
pub mod workflow;

pub use analysis::{analyze, table, AnalysisRow, IncrementalAnalysis};
pub use autoscale::{AutoscaleConfig, Autoscaler, ScaleDecision};
pub use autoscale_sim::{
    replay, replay_objective, trace_burst, trace_diurnal, AutoscaleReport,
};
pub use chaos::FaultyTarget;
pub use config::{spec_from_file, spec_from_toml};
pub use control::{
    run_fixed, run_fixed_priced, ControlLoop, ModelTarget, PilotTarget, ResizeEvent,
    ScalingTarget,
};
pub use experiment::{
    axis_value_of, Axis, AxisValue, ExperimentSpec, AXIS_CENTROIDS, AXIS_FAULTS,
    AXIS_MEMORY_MB, AXIS_MESSAGE_SIZE, AXIS_PARTITIONS, AXIS_PLATFORM, AXIS_PRICE,
    AXIS_WORKFLOW,
};
pub use objective::{
    cost_rows, pareto_csv, platform_price, CostLedger, CostedDecision, CostedRow, Objective,
};
pub use predict::Predictor;
pub use recalibrate::{
    OnlineUslFitter, RecalibrateConfig, RecalibrationTrace, RefitEvent, UslSample,
};
pub use sweep::{
    group_keys, group_observations, paper_key, run_sweep, run_sweep_jobs, run_sweep_jobs_opts,
    to_csv, GroupKey, SweepProgress, SweepRow,
};
pub use workflow::{
    fit_stages, measure_workflow_row, run_workflow_sweep_jobs, stage_csv, CriticalPathModel,
    LoadShift, RebalanceEvent, RebalancePolicy, StageFit, StageRow, WorkflowPrediction,
    WorkflowTarget,
};
