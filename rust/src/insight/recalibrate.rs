//! Online USL recalibration — the subsystem that lets the live control
//! loop *re-learn its own model mid-run*.
//!
//! The paper's workflow fits USL offline and steers from that static fit.
//! A live platform drifts away from any offline characterization: cold
//! starts stretch service times, an edge fleet throttles past its
//! envelope, a broker pays reshard costs the offline sweep never saw.
//! [`OnlineUslFitter`] closes that gap: the
//! [`ControlLoop`](super::control::ControlLoop) records one
//! [`UslSample`] per serve interval (through the
//! [`ScalingTarget::observe_interval`](super::control::ScalingTarget::observe_interval)
//! hook), the fitter keeps a windowed, recency-weighted store of the
//! *capacity-bound* samples, and a drift detector triggers a re-fit when
//! observed throughput departs the current model envelope — the refreshed
//! [`Predictor`] is hot-swapped into the autoscaler for the next decision.
//!
//! Two re-fit paths, chosen by how much of the parallelism axis the run
//! has actually visited:
//!
//! - **`"fit"`** — a full recency-weighted USL fit
//!   ([`crate::usl::fit_weighted`]) once the window covers at least
//!   [`RecalibrateConfig::min_distinct_n`] distinct parallelism levels.
//! - **`"rescale"`** — with fewer levels the curve shape is unidentifiable,
//!   so only λ is corrected by the weighted observed/predicted ratio
//!   (σ, κ keep their offline values).  This is what repairs a stale
//!   capacity estimate within a handful of saturated intervals.
//!
//! Everything is deterministic: same trace + same seed ⇒ bit-identical
//! fit sequence (asserted in `rust/tests/recalibrate.rs`).

use super::predict::Predictor;
use crate::usl::{fit_weighted, Obs, UslParams};
use std::collections::VecDeque;

/// One control interval's observation of the scaling target, as reported
/// through [`ScalingTarget::observe_interval`](super::control::ScalingTarget::observe_interval).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UslSample {
    /// Parallelism in effect while the interval was served.
    pub n: usize,
    /// Messages actually served, per second.
    pub served_rate: f64,
    /// Messages asked for (admitted load + backlog), per second.
    pub demand_rate: f64,
    /// The interval ran at the platform's *proven* envelope: a
    /// `Throttle`/clamp plan established a hard cap earlier and this
    /// sample was served at (or beyond) it.  Intervals below the cap do
    /// not report push-back — the platform was not the binding
    /// constraint there.
    pub pushback: bool,
    /// The target was in steady state (no resize transition in flight).
    /// Mid-transition intervals stay in the trace for accounting but are
    /// excluded from fitting — their parallelism label lies.
    pub steady: bool,
}

impl UslSample {
    pub fn new(n: usize, served_rate: f64, demand_rate: f64) -> Self {
        Self {
            n: n.max(1),
            served_rate,
            demand_rate,
            pushback: false,
            steady: true,
        }
    }

    pub fn with_pushback(mut self, pushback: bool) -> Self {
        self.pushback = pushback;
        self
    }

    pub fn with_steady(mut self, steady: bool) -> Self {
        self.steady = steady;
        self
    }

    /// Capacity-bound: the target served less than it was asked for, so
    /// `served_rate` is a true throughput reading at parallelism `n`
    /// (demand-bound intervals only bound capacity from below).
    pub fn saturated(&self) -> bool {
        self.demand_rate > self.served_rate + 1e-9
    }

    /// Eligible for the fit window: steady, capacity-bound, nonzero.
    fn fit_eligible(&self) -> bool {
        self.steady && self.saturated() && self.served_rate > 0.0
    }
}

/// Tuning of the online recalibrator.
#[derive(Debug, Clone)]
pub struct RecalibrateConfig {
    /// Capacity-bound samples kept in the sliding fit window.
    pub window: usize,
    /// Minimum samples in the window before any re-fit.
    pub min_samples: usize,
    /// Distinct parallelism levels required for a full USL fit; below
    /// this only λ is rescaled.
    pub min_distinct_n: usize,
    /// Relative band around the model envelope: a capacity-bound sample
    /// further than this from the predicted throughput counts as drift.
    pub drift_band: f64,
    /// Consecutive out-of-band samples that trigger a re-fit.
    pub drift_ticks: usize,
    /// Minimum ticks between re-fits (keeps the model from flapping on
    /// the noise right after a swap).
    pub cooldown_ticks: usize,
    /// Per-sample-age weight decay for the recency-weighted fit (newest
    /// sample weight 1.0, each older sample multiplied by this).
    pub decay: f64,
}

impl Default for RecalibrateConfig {
    fn default() -> Self {
        Self {
            window: 64,
            min_samples: 6,
            min_distinct_n: 3,
            drift_band: 0.25,
            drift_ticks: 3,
            cooldown_ticks: 8,
            decay: 0.97,
        }
    }
}

/// One committed model swap, stamped with its loop time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RefitEvent {
    pub t: f64,
    /// The refreshed parameters hot-swapped into the autoscaler.
    pub params: UslParams,
    /// `"fit"` (full weighted USL fit) or `"rescale"` (λ correction).
    pub method: &'static str,
    /// Capacity-bound samples the re-fit consumed.
    pub samples: usize,
}

/// What a recalibrated run leaves behind: every interval's sample (the
/// conservation surface — served rates sum to the report's processed
/// total) plus the model-swap history.
#[derive(Debug, Clone, Default)]
pub struct RecalibrationTrace {
    pub samples: Vec<UslSample>,
    pub refits: Vec<RefitEvent>,
}

impl RecalibrationTrace {
    /// The last swapped-in parameters, if any re-fit happened.
    pub fn final_params(&self) -> Option<UslParams> {
        self.refits.last().map(|r| r.params)
    }

    /// Samples where the platform pushed back (`Throttle`/clamp).
    pub fn pushback_samples(&self) -> usize {
        self.samples.iter().filter(|s| s.pushback).count()
    }
}

/// The streaming re-fitter: windowed sample store + drift detector +
/// weighted USL fit, producing hot-swappable [`Predictor`]s.
pub struct OnlineUslFitter {
    config: RecalibrateConfig,
    /// Fit-eligible samples, oldest first (bounded by `config.window`).
    window: VecDeque<UslSample>,
    /// Every observed sample, for the run's trace/accounting.
    all: Vec<UslSample>,
    out_of_band: usize,
    since_refit: usize,
    refits: Vec<RefitEvent>,
}

impl OnlineUslFitter {
    pub fn new(config: RecalibrateConfig) -> Self {
        assert!(config.window >= 1, "window must hold at least one sample");
        assert!(config.drift_band > 0.0, "drift band must be positive");
        let since_refit = config.cooldown_ticks;
        Self {
            config,
            window: VecDeque::new(),
            all: Vec::new(),
            out_of_band: 0,
            since_refit,
            refits: Vec::new(),
        }
    }

    /// Feed one interval's sample.  Returns a refreshed [`Predictor`] when
    /// drift triggered a re-fit — the caller hot-swaps it into the
    /// decision path; `None` means the current model stands.
    pub fn observe(&mut self, t: f64, sample: UslSample, current: &Predictor) -> Option<Predictor> {
        self.all.push(sample);
        self.since_refit = self.since_refit.saturating_add(1);
        if !sample.fit_eligible() {
            return None;
        }
        self.window.push_back(sample);
        while self.window.len() > self.config.window {
            self.window.pop_front();
        }
        let predicted = current.throughput(sample.n);
        let deviation = (sample.served_rate - predicted).abs() / predicted.max(1e-12);
        if deviation > self.config.drift_band {
            self.out_of_band += 1;
        } else {
            self.out_of_band = 0;
        }
        if self.out_of_band < self.config.drift_ticks
            || self.window.len() < self.config.min_samples
            || self.since_refit < self.config.cooldown_ticks
        {
            return None;
        }
        let refreshed = self.refit(t, current)?;
        self.out_of_band = 0;
        self.since_refit = 0;
        Some(refreshed)
    }

    /// Distinct parallelism levels currently in the fit window.
    pub fn distinct_levels(&self) -> usize {
        let mut ns: Vec<usize> = self.window.iter().map(|s| s.n).collect();
        ns.sort_unstable();
        ns.dedup();
        ns.len()
    }

    /// Re-fit history so far.
    pub fn refits(&self) -> &[RefitEvent] {
        &self.refits
    }

    /// Consume the fitter into the run's trace (the loop calls this when
    /// the run finishes).
    pub fn into_trace(self) -> RecalibrationTrace {
        RecalibrationTrace {
            samples: self.all,
            refits: self.refits,
        }
    }

    fn recency_weights(&self) -> Vec<f64> {
        let k = self.window.len();
        let decay = self.config.decay;
        (0..k).map(|i| decay.powi((k - 1 - i) as i32)).collect()
    }

    fn refit(&mut self, t: f64, current: &Predictor) -> Option<Predictor> {
        let weights = self.recency_weights();
        let (params, method) = if self.distinct_levels() >= self.config.min_distinct_n {
            let obs: Vec<Obs> = self
                .window
                .iter()
                .map(|s| Obs::new(s.n as f64, s.served_rate))
                .collect();
            match fit_weighted(&obs, &weights) {
                Ok(f) if f.params.lambda.is_finite() && f.params.lambda > 0.0 => {
                    (f.params, "fit")
                }
                // degenerate fit (collinear window): fall back to rescale
                _ => (self.rescaled(current, &weights)?, "rescale"),
            }
        } else {
            (self.rescaled(current, &weights)?, "rescale")
        };
        self.refits.push(RefitEvent {
            t,
            params,
            method,
            samples: self.window.len(),
        });
        Some(Predictor { params })
    }

    /// λ-only correction: the weighted mean of observed/predicted ratios
    /// over the window, applied to the current λ with σ, κ untouched.
    fn rescaled(&self, current: &Predictor, weights: &[f64]) -> Option<UslParams> {
        let mut num = 0.0;
        let mut den = 0.0;
        for (s, w) in self.window.iter().zip(weights) {
            let predicted = current.throughput(s.n);
            if predicted > 0.0 {
                num += w * (s.served_rate / predicted);
                den += w;
            }
        }
        if den <= 0.0 {
            return None;
        }
        let ratio = num / den;
        if !ratio.is_finite() || ratio <= 0.0 {
            return None;
        }
        let p = current.params;
        Some(UslParams::new(p.sigma, p.kappa, p.lambda * ratio))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::usl::UslParams;

    fn predictor(sigma: f64, kappa: f64, lambda: f64) -> Predictor {
        Predictor {
            params: UslParams::new(sigma, kappa, lambda),
        }
    }

    /// Feed `ticks` saturated samples at parallelism `n` whose observed
    /// rate follows `truth`, against a fitter believing `belief`.
    fn drive(
        fitter: &mut OnlineUslFitter,
        belief: &mut Predictor,
        truth: &UslParams,
        n: usize,
        ticks: usize,
    ) -> usize {
        let mut swaps = 0;
        for i in 0..ticks {
            let observed = truth.throughput(n as f64);
            let sample = UslSample::new(n, observed, observed * 2.0);
            if let Some(p) = fitter.observe(i as f64, sample, belief) {
                *belief = p;
                swaps += 1;
            }
        }
        swaps
    }

    #[test]
    fn in_band_samples_never_refit() {
        let truth = UslParams::new(0.02, 0.0001, 20.0);
        let mut belief = predictor(0.02, 0.0001, 20.0);
        let mut fitter = OnlineUslFitter::new(RecalibrateConfig::default());
        let swaps = drive(&mut fitter, &mut belief, &truth, 4, 50);
        assert_eq!(swaps, 0, "a calibrated model must not be touched");
        assert!(fitter.refits().is_empty());
    }

    #[test]
    fn drift_triggers_a_lambda_rescale_with_one_level() {
        // belief 3x optimistic, all samples at one parallelism level:
        // only λ is identifiable, so the re-fit must be a rescale
        let truth = UslParams::new(0.02, 0.0001, 20.0);
        let mut belief = predictor(0.02, 0.0001, 60.0);
        let mut fitter = OnlineUslFitter::new(RecalibrateConfig::default());
        let swaps = drive(&mut fitter, &mut belief, &truth, 4, 30);
        assert!(swaps >= 1, "3x drift must trigger");
        assert_eq!(fitter.refits()[0].method, "rescale");
        let lambda = belief.params.lambda;
        assert!(
            (lambda - 20.0).abs() / 20.0 < 0.05,
            "rescaled λ must land on the truth: {lambda}"
        );
        assert!((belief.params.sigma - 0.02).abs() < 1e-12, "σ untouched");
    }

    #[test]
    fn three_levels_earn_a_full_fit() {
        let truth = UslParams::new(0.3, 0.01, 25.0);
        let mut belief = predictor(0.02, 0.0001, 60.0);
        let mut fitter = OnlineUslFitter::new(RecalibrateConfig::default());
        // visit three parallelism levels, saturated at each
        for (i, n) in [2usize, 2, 4, 4, 8, 8, 8, 8, 8].iter().enumerate() {
            let observed = truth.throughput(*n as f64);
            let sample = UslSample::new(*n, observed, observed * 2.0);
            if let Some(p) = fitter.observe(i as f64, sample, &belief) {
                belief = p;
            }
        }
        let last = fitter.refits().last().expect("drift must refit");
        assert_eq!(last.method, "fit", "3 distinct levels ⇒ full USL fit");
        assert!(
            (belief.params.lambda - 25.0).abs() / 25.0 < 0.1,
            "noise-free samples recover λ: {:?}",
            belief.params
        );
        assert!((belief.params.sigma - 0.3).abs() < 0.1, "{:?}", belief.params);
    }

    #[test]
    fn unsteady_and_demand_bound_samples_stay_out_of_the_window() {
        let mut fitter = OnlineUslFitter::new(RecalibrateConfig::default());
        let belief = predictor(0.02, 0.0001, 20.0);
        // demand-bound: served == demand
        fitter.observe(0.0, UslSample::new(2, 10.0, 10.0), &belief);
        // mid-transition
        fitter.observe(1.0, UslSample::new(2, 10.0, 99.0).with_steady(false), &belief);
        assert_eq!(fitter.window.len(), 0);
        assert_eq!(fitter.all.len(), 2, "the trace still records everything");
        // capacity-bound and steady: admitted
        fitter.observe(2.0, UslSample::new(2, 10.0, 99.0), &belief);
        assert_eq!(fitter.window.len(), 1);
    }

    #[test]
    fn window_evicts_oldest() {
        let config = RecalibrateConfig {
            window: 4,
            ..Default::default()
        };
        let mut fitter = OnlineUslFitter::new(config);
        let belief = predictor(0.02, 0.0001, 20.0);
        for i in 0..10 {
            fitter.observe(i as f64, UslSample::new(2, 30.0 + i as f64, 99.0), &belief);
        }
        assert_eq!(fitter.window.len(), 4);
        assert!((fitter.window.front().unwrap().served_rate - 36.0).abs() < 1e-12);
    }

    #[test]
    fn cooldown_spaces_refits() {
        let truth = UslParams::new(0.02, 0.0001, 20.0);
        let mut belief = predictor(0.02, 0.0001, 200.0); // absurdly stale
        let config = RecalibrateConfig {
            cooldown_ticks: 10,
            ..Default::default()
        };
        let mut fitter = OnlineUslFitter::new(config);
        // keep the observations 10x off the *original* belief but let the
        // belief update: after the first swap the model is right and no
        // further refits should fire at all
        let swaps = drive(&mut fitter, &mut belief, &truth, 4, 40);
        assert_eq!(swaps, 1, "one swap repairs a pure λ error");
    }

    #[test]
    fn refit_sequence_is_bit_deterministic() {
        let run = || {
            let truth = UslParams::new(0.3, 0.01, 25.0);
            let mut belief = predictor(0.02, 0.0001, 60.0);
            let mut fitter = OnlineUslFitter::new(RecalibrateConfig::default());
            for i in 0..40 {
                let n = 2 + (i % 3) * 3; // levels 2, 5, 8
                let observed = truth.throughput(n as f64) * (1.0 + 0.01 * (i % 5) as f64);
                let sample = UslSample::new(n, observed, observed * 2.0);
                if let Some(p) = fitter.observe(i as f64, sample, &belief) {
                    belief = p;
                }
            }
            fitter
                .into_trace()
                .refits
                .iter()
                .map(|r| {
                    (
                        r.t.to_bits(),
                        r.params.sigma.to_bits(),
                        r.params.kappa.to_bits(),
                        r.params.lambda.to_bits(),
                        r.method,
                    )
                })
                .collect::<Vec<_>>()
        };
        let a = run();
        assert!(!a.is_empty());
        assert_eq!(a, run(), "same inputs ⇒ bit-identical fit sequence");
    }
}
