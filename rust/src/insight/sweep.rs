//! Sweep execution: run every scenario of an [`ExperimentSpec`] through the
//! simulated-time driver and collect one row per configuration.

use super::experiment::ExperimentSpec;
use crate::engine::StepEngine;
use crate::miniapp::{run_sim, PlatformKind, Scenario};
use crate::usl::Obs;
use std::sync::Arc;

/// One measured configuration.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub platform: PlatformKind,
    pub partitions: usize,
    pub message_size: usize,
    pub centroids: usize,
    pub memory_mb: u32,
    /// T^px (messages/second).
    pub throughput: f64,
    /// Mean service time per message (Fig 4).
    pub service_mean: f64,
    pub service_p95: f64,
    pub service_cv: f64,
    /// Warm-path (cold-start-free) service stats — Fig 3's quantities.
    pub warm_mean: f64,
    pub warm_cv: f64,
    /// Mean L^br.
    pub broker_mean: f64,
    pub messages: usize,
}

impl SweepRow {
    /// Group key for USL fitting: one throughput curve per
    /// (platform, MS, WC, memory).
    pub fn group_key(&self) -> (PlatformKind, usize, usize, u32) {
        (
            self.platform,
            self.message_size,
            self.centroids,
            self.memory_mb,
        )
    }
}

/// Run the full sweep (simulated time).  `engine_factory` builds a fresh
/// engine per scenario so RNG streams don't interleave across configs.
pub fn run_sweep<F>(spec: &ExperimentSpec, engine_factory: F) -> Vec<SweepRow>
where
    F: Fn(&Scenario) -> Arc<dyn StepEngine>,
{
    let scenarios = spec.scenarios();
    let mut rows = Vec::with_capacity(scenarios.len());
    for (i, sc) in scenarios.iter().enumerate() {
        match run_sim(sc, engine_factory(sc)) {
            Ok(r) => {
                log::debug!(
                    "sweep {}/{}: {} p={} ms={} wc={} -> T={:.2} msg/s",
                    i + 1,
                    scenarios.len(),
                    sc.platform.label(),
                    sc.partitions,
                    sc.points_per_message,
                    sc.centroids,
                    r.summary.throughput
                );
                rows.push(SweepRow {
                    platform: sc.platform,
                    partitions: sc.partitions,
                    message_size: sc.points_per_message,
                    centroids: sc.centroids,
                    memory_mb: sc.memory_mb,
                    throughput: r.summary.throughput,
                    service_mean: r.summary.service.mean,
                    service_p95: r.summary.service.p95,
                    service_cv: r.summary.service.cv(),
                    warm_mean: r.summary.service_warm.mean,
                    warm_cv: r.summary.service_warm.cv(),
                    broker_mean: r.summary.broker.mean,
                    messages: r.summary.messages,
                });
            }
            Err(e) => log::error!("sweep config failed ({sc:?}): {e}"),
        }
    }
    rows
}

/// Extract the (N, T) observations of one group, sorted by N.
pub fn group_observations(
    rows: &[SweepRow],
    key: (PlatformKind, usize, usize, u32),
) -> Vec<Obs> {
    let mut obs: Vec<Obs> = rows
        .iter()
        .filter(|r| r.group_key() == key)
        .map(|r| Obs::new(r.partitions as f64, r.throughput))
        .collect();
    obs.sort_by(|a, b| a.n.partial_cmp(&b.n).unwrap());
    obs
}

/// All distinct group keys in sweep order.
pub fn group_keys(rows: &[SweepRow]) -> Vec<(PlatformKind, usize, usize, u32)> {
    let mut keys = Vec::new();
    for r in rows {
        let k = r.group_key();
        if !keys.contains(&k) {
            keys.push(k);
        }
    }
    keys
}

/// CSV export (one row per configuration) for external plotting.
pub fn to_csv(rows: &[SweepRow]) -> String {
    let mut s = String::from(
        "platform,partitions,message_size,centroids,memory_mb,throughput,service_mean,service_p95,service_cv,broker_mean,messages\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{},{},{},{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{}\n",
            r.platform.label(),
            r.partitions,
            r.message_size,
            r.centroids,
            r.memory_mb,
            r.throughput,
            r.service_mean,
            r.service_p95,
            r.service_cv,
            r.broker_mean,
            r.messages
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::sim::{ContentionParams, Dist};

    fn tiny_spec() -> ExperimentSpec {
        ExperimentSpec {
            name: "tiny".into(),
            platforms: vec![PlatformKind::Lambda, PlatformKind::DaskWrangler],
            partitions: vec![1, 2, 4],
            message_sizes: vec![256],
            centroids: vec![16],
            memory_mb: vec![3_008],
            messages: 24,
            seed: 5,
            lustre: ContentionParams::new(0.5, 0.03),
        }
    }

    fn factory(sc: &crate::miniapp::Scenario) -> Arc<dyn StepEngine> {
        let mut e = CalibratedEngine::new(sc.seed ^ sc.partitions as u64);
        e.insert((256, 16), Dist::Const(0.05));
        Arc::new(e)
    }

    #[test]
    fn sweep_covers_all_configs() {
        let spec = tiny_spec();
        let rows = run_sweep(&spec, factory);
        assert_eq!(rows.len(), spec.size());
        let keys = group_keys(&rows);
        assert_eq!(keys.len(), 2); // one per platform
        for k in keys {
            let obs = group_observations(&rows, k);
            assert_eq!(obs.len(), 3);
            assert!(obs.windows(2).all(|w| w[0].n < w[1].n));
        }
    }

    #[test]
    fn lambda_scales_dask_does_not() {
        let rows = run_sweep(&tiny_spec(), factory);
        let lam = group_observations(&rows, (PlatformKind::Lambda, 256, 16, 3_008));
        let dask = group_observations(&rows, (PlatformKind::DaskWrangler, 256, 16, 3_008));
        let lam_speedup = lam.last().unwrap().t / lam[0].t;
        let dask_speedup = dask.last().unwrap().t / dask[0].t;
        assert!(
            lam_speedup > dask_speedup,
            "lambda {lam_speedup} vs dask {dask_speedup}"
        );
    }

    #[test]
    fn csv_has_all_rows() {
        let rows = run_sweep(&tiny_spec(), factory);
        let csv = to_csv(&rows);
        assert_eq!(csv.lines().count(), rows.len() + 1);
        assert!(csv.contains("kinesis/lambda"));
        assert!(csv.contains("kafka/dask"));
    }
}
