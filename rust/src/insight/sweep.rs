//! Sweep execution: expand an [`ExperimentSpec`] into scenarios, run each
//! through the simulated-time driver — across cores when asked — and
//! collect one [`SweepRow`] per configuration.
//!
//! Scenarios are independent (each `run_sim` owns its DES, stores, and
//! per-config RNG streams), so [`run_sweep_jobs`] farms them out to a
//! scoped worker pool ([`parallel_indexed_map`]) and streams rows back in
//! completion order for progress reporting and incremental USL fits, while
//! the returned vector is reassembled in spec order: `jobs = N` produces
//! output byte-identical to `jobs = 1`.
//!
//! Rows are grouped for USL fitting by [`GroupKey`] — the row's assignment
//! on every axis *except* the spec's scale axis — derived from the axes
//! themselves, so new sweep dimensions change grouping, analysis, and CSV
//! export without any code edits here.

use super::experiment::{axis_value_of, AxisValue, ExperimentSpec};
use super::experiment::{
    AXIS_CENTROIDS, AXIS_MEMORY_MB, AXIS_MESSAGE_SIZE, AXIS_PLATFORM, AXIS_WORKFLOW,
};
use crate::engine::StepEngine;
use crate::miniapp::{run_sim_opts, PlatformKind, Scenario, SimOptions};
use crate::pilot::workers::parallel_indexed_map;
use crate::usl::Obs;
// ps-lint: allow(hash-iteration): HashSet used for membership/dedup only below; GroupKey has no Ord (AxisValue) so BTreeSet cannot replace it
use std::collections::HashSet;
use std::sync::Arc;

/// A sweep group: the (axis name, level) pairs shared by every row of one
/// throughput curve, in spec axis order.  Also usable as a *query*: a key
/// holding a subset of the axes selects every group containing those
/// pairs (see [`GroupKey::selects`]).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GroupKey(Vec<(String, AxisValue)>);

impl GroupKey {
    pub fn new(pairs: Vec<(String, AxisValue)>) -> Self {
        Self(pairs)
    }

    pub fn pairs(&self) -> &[(String, AxisValue)] {
        &self.0
    }

    pub fn get(&self, name: &str) -> Option<AxisValue> {
        self.0.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn platform(&self) -> Option<PlatformKind> {
        self.get(AXIS_PLATFORM).and_then(AxisValue::as_platform)
    }

    pub fn int(&self, name: &str) -> Option<u64> {
        self.get(name).and_then(AxisValue::as_int)
    }

    /// True when every pair of `self` appears in `other` — query keys
    /// select row groups by any axis subset.
    pub fn selects(&self, other: &GroupKey) -> bool {
        self.0.iter().all(|(n, v)| other.get(n) == Some(*v))
    }

    /// Human-readable label: the platform level bare, every other axis as
    /// `name=value`, in axis order.
    pub fn label(&self) -> String {
        self.0
            .iter()
            .map(|(n, v)| {
                if n == AXIS_PLATFORM {
                    v.to_string()
                } else {
                    format!("{n}={v}")
                }
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Query key over the canonical paper axes (platform, MS, WC, memory).
/// Selection is subset-based: on grids with *additional* multi-level
/// axes this matches every group sharing these four coordinates (and
/// [`group_observations`] warns about the blend) — pass a full key from
/// [`group_keys`] to pin one curve on such grids.
pub fn paper_key(
    platform: PlatformKind,
    message_size: usize,
    centroids: usize,
    memory_mb: u32,
) -> GroupKey {
    GroupKey::new(vec![
        (AXIS_PLATFORM.to_string(), AxisValue::Platform(platform)),
        (
            AXIS_MESSAGE_SIZE.to_string(),
            AxisValue::Int(message_size as u64),
        ),
        (AXIS_CENTROIDS.to_string(), AxisValue::Int(centroids as u64)),
        (AXIS_MEMORY_MB.to_string(), AxisValue::Int(memory_mb as u64)),
    ])
}

/// One measured configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRow {
    /// Non-scale axis assignment — one USL curve per distinct key.
    pub key: GroupKey,
    /// Name of the axis `scale` belongs to (usually `partitions`).
    pub scale_axis: String,
    /// Scale-axis level: N^px(p).
    pub scale: usize,
    /// T^px (messages/second).
    pub throughput: f64,
    /// Mean service time per message (Fig 4).
    pub service_mean: f64,
    pub service_p95: f64,
    pub service_cv: f64,
    /// Warm-path (cold-start-free) service stats — Fig 3's quantities.
    pub warm_mean: f64,
    pub warm_cv: f64,
    /// Mean L^br.
    pub broker_mean: f64,
    pub messages: usize,
}

impl SweepRow {
    /// Group key for USL fitting, derived from the spec's axes.
    pub fn group_key(&self) -> &GroupKey {
        &self.key
    }

    pub fn platform(&self) -> Option<PlatformKind> {
        self.key.platform()
    }

    /// This row's level on a non-scale axis.
    pub fn axis_int(&self, name: &str) -> Option<u64> {
        self.key.int(name)
    }

    /// The scale-axis level (partition count on the canonical grids).
    pub fn partitions(&self) -> usize {
        self.scale
    }
}

/// Progress event streamed by [`run_sweep_jobs`]: rows arrive in
/// completion order on the caller's thread.
pub struct SweepProgress<'a> {
    /// Configurations finished so far (including this one).
    pub done: usize,
    pub total: usize,
    pub row: &'a SweepRow,
}

fn measure<F>(
    spec: &ExperimentSpec,
    sc: &Scenario,
    engine_factory: &F,
    opts: SimOptions,
) -> Result<SweepRow, String>
where
    F: Fn(&Scenario) -> Arc<dyn StepEngine>,
{
    if sc.extra_param(AXIS_WORKFLOW).is_some() {
        // Workflow-axis scenarios stand for whole DAGs: route them through
        // the workflow driver so the row carries end-to-end metrics.
        return super::workflow::measure_workflow_sweep_row(spec, sc, engine_factory, opts);
    }
    let r = run_sim_opts(sc, engine_factory(sc), opts)?;
    let key = GroupKey::new(
        spec.axes
            .iter()
            .filter(|a| a.name != spec.scale_axis)
            .map(|a| {
                let v = axis_value_of(sc, &a.name).unwrap_or(AxisValue::Int(0));
                (a.name.clone(), v)
            })
            .collect(),
    );
    let scale = match axis_value_of(sc, &spec.scale_axis) {
        Some(AxisValue::Int(n)) => n as usize,
        _ => sc.partitions,
    };
    Ok(SweepRow {
        key,
        scale_axis: spec.scale_axis.clone(),
        scale,
        throughput: r.summary.throughput,
        service_mean: r.summary.service.mean,
        service_p95: r.summary.service.p95,
        service_cv: r.summary.service.cv(),
        warm_mean: r.summary.service_warm.mean,
        warm_cv: r.summary.service_warm.cv(),
        broker_mean: r.summary.broker.mean,
        messages: r.summary.messages,
    })
}

/// Run the full sweep sequentially (simulated time).  `engine_factory`
/// builds a fresh engine per scenario so RNG streams don't interleave
/// across configs.
pub fn run_sweep<F>(spec: &ExperimentSpec, engine_factory: F) -> Vec<SweepRow>
where
    F: Fn(&Scenario) -> Arc<dyn StepEngine> + Sync,
{
    run_sweep_jobs(spec, engine_factory, 1, |_| {})
}

/// Run the sweep on `jobs` worker threads.  Independent scenarios run
/// concurrently with per-config seeded RNG; `progress` observes rows in
/// completion order (progress bars, incremental fits), and the returned
/// vector is reassembled in deterministic spec order — the output is
/// byte-identical for every `jobs` value.
pub fn run_sweep_jobs<F, C>(
    spec: &ExperimentSpec,
    engine_factory: F,
    jobs: usize,
    progress: C,
) -> Vec<SweepRow>
where
    F: Fn(&Scenario) -> Arc<dyn StepEngine> + Sync,
    C: FnMut(SweepProgress<'_>),
{
    run_sweep_jobs_opts(spec, engine_factory, jobs, SimOptions::default(), progress)
}

/// [`run_sweep_jobs`] with explicit sim-core options (production mode,
/// per-scenario lanes, trace retention).  Every combination of `jobs`,
/// `opts.lanes`, and `opts.mode` yields byte-identical rows — the
/// determinism tests pin this.
pub fn run_sweep_jobs_opts<F, C>(
    spec: &ExperimentSpec,
    engine_factory: F,
    jobs: usize,
    opts: SimOptions,
    mut progress: C,
) -> Vec<SweepRow>
where
    F: Fn(&Scenario) -> Arc<dyn StepEngine> + Sync,
    C: FnMut(SweepProgress<'_>),
{
    let scenarios = spec.scenarios();
    let total = scenarios.len();
    let mut slots: Vec<Option<SweepRow>> = Vec::with_capacity(total);
    slots.resize_with(total, || None);
    let mut done = 0usize;
    let scenarios_ref = &scenarios;
    let factory_ref = &engine_factory;
    parallel_indexed_map(
        jobs.max(1),
        total,
        move |_worker, i| measure(spec, &scenarios_ref[i], factory_ref, opts),
        |i, outcome| match outcome {
            Ok(row) => {
                done += 1;
                log::debug!(
                    "sweep {done}/{total}: {} {}={} -> T={:.2} msg/s",
                    row.key.label(),
                    row.scale_axis,
                    row.scale,
                    row.throughput
                );
                progress(SweepProgress {
                    done,
                    total,
                    row: &row,
                });
                slots[i] = Some(row);
            }
            Err(e) => log::error!("sweep config failed ({:?}): {e}", scenarios[i]),
        },
    );
    slots.into_iter().flatten().collect()
}

/// Extract the (N, T) observations of the groups `query` selects,
/// sorted by N.
///
/// A query naming a strict subset of the axes can match *several* groups;
/// feeding such a blend to `usl::fit` is almost never intended, so
/// spanning more than one distinct group logs a warning.  Pass a full key
/// (e.g. one returned by [`group_keys`]) to select exactly one curve.
pub fn group_observations(rows: &[SweepRow], query: &GroupKey) -> Vec<Obs> {
    let selected: Vec<&SweepRow> = rows.iter().filter(|r| query.selects(&r.key)).collect();
    // ps-lint: allow(hash-iteration): only len() is read — a distinct-count, never iterated
    let distinct: HashSet<&GroupKey> = selected.iter().map(|r| &r.key).collect();
    if distinct.len() > 1 {
        log::warn!(
            "query {} selects {} distinct sweep groups — the observations blend multiple curves",
            query.label(),
            distinct.len()
        );
    }
    let mut obs: Vec<Obs> = selected
        .iter()
        .map(|r| Obs::new(r.scale as f64, r.throughput))
        .collect();
    obs.sort_by(|a, b| a.n.partial_cmp(&b.n).unwrap());
    obs
}

/// All distinct group keys in sweep order (order-preserving set — the
/// scan is O(n), not O(n²)).
pub fn group_keys(rows: &[SweepRow]) -> Vec<GroupKey> {
    // ps-lint: allow(hash-iteration): membership test only — output order comes from the rows scan, not the set
    let mut seen: HashSet<&GroupKey> = HashSet::with_capacity(rows.len().min(1024));
    let mut keys = Vec::new();
    for r in rows {
        if seen.insert(&r.key) {
            keys.push(r.key.clone());
        }
    }
    keys
}

/// CSV export (one row per configuration) for external plotting.  Columns
/// derive from the axes: one per group axis, then the scale axis, then
/// every measured quantity `SweepRow` carries — including the warm-path
/// stats Fig 3 plots.
pub fn to_csv(rows: &[SweepRow]) -> String {
    const METRICS: &str =
        "throughput,service_mean,service_p95,service_cv,warm_mean,warm_cv,broker_mean,messages";
    let Some(first) = rows.first() else {
        return format!("{METRICS}\n");
    };
    let mut s = String::new();
    for (name, _) in first.key.pairs() {
        s.push_str(name);
        s.push(',');
    }
    s.push_str(&first.scale_axis);
    s.push(',');
    s.push_str(METRICS);
    s.push('\n');
    for r in rows {
        for (_, v) in r.key.pairs() {
            s.push_str(&v.to_string());
            s.push(',');
        }
        s.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{}\n",
            r.scale,
            r.throughput,
            r.service_mean,
            r.service_p95,
            r.service_cv,
            r.warm_mean,
            r.warm_cv,
            r.broker_mean,
            r.messages
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::sim::{ContentionParams, Dist};

    fn tiny_spec() -> ExperimentSpec {
        let mut spec = ExperimentSpec::tiny_grid(24, 5);
        spec.lustre = ContentionParams::new(0.5, 0.03);
        spec
    }

    fn factory(sc: &crate::miniapp::Scenario) -> Arc<dyn StepEngine> {
        let mut e = CalibratedEngine::new(sc.seed ^ sc.partitions as u64);
        e.insert((256, 16), Dist::Const(0.05));
        Arc::new(e)
    }

    #[test]
    fn sweep_covers_all_configs() {
        let spec = tiny_spec();
        let rows = run_sweep(&spec, factory);
        assert_eq!(rows.len(), spec.size());
        let keys = group_keys(&rows);
        assert_eq!(keys.len(), 2); // one per platform
        for k in keys {
            let obs = group_observations(&rows, &k);
            assert_eq!(obs.len(), 3);
            assert!(obs.windows(2).all(|w| w[0].n < w[1].n));
        }
    }

    #[test]
    fn lambda_scales_dask_does_not() {
        let rows = run_sweep(&tiny_spec(), factory);
        let lam = group_observations(&rows, &paper_key(PlatformKind::Lambda, 256, 16, 3_008));
        let dask =
            group_observations(&rows, &paper_key(PlatformKind::DaskWrangler, 256, 16, 3_008));
        let lam_speedup = lam.last().unwrap().t / lam[0].t;
        let dask_speedup = dask.last().unwrap().t / dask[0].t;
        assert!(
            lam_speedup > dask_speedup,
            "lambda {lam_speedup} vs dask {dask_speedup}"
        );
    }

    #[test]
    fn csv_has_all_rows_and_warm_columns() {
        let rows = run_sweep(&tiny_spec(), factory);
        let csv = to_csv(&rows);
        assert_eq!(csv.lines().count(), rows.len() + 1);
        let header = csv.lines().next().unwrap();
        // axis-derived columns, group axes first, scale axis last
        assert_eq!(
            header,
            "platform,message_size,centroids,memory_mb,partitions,throughput,service_mean,service_p95,service_cv,warm_mean,warm_cv,broker_mean,messages"
        );
        assert!(csv.contains("kinesis/lambda"));
        assert!(csv.contains("kafka/dask(wrangler)"));
    }

    #[test]
    fn parallel_jobs_match_sequential_exactly() {
        let spec = tiny_spec();
        let seq = run_sweep(&spec, factory);
        let mut events = 0usize;
        let par = run_sweep_jobs(&spec, factory, 4, |p| {
            events += 1;
            assert_eq!(p.done, events);
            assert_eq!(p.total, spec.size());
        });
        assert_eq!(events, seq.len());
        assert_eq!(seq, par, "rows identical in value and order");
        assert_eq!(to_csv(&seq), to_csv(&par), "byte-identical CSV");
    }

    #[test]
    fn cohort_and_per_message_sweeps_are_byte_identical() {
        // satellite determinism gate: the batched sim core (cohorts,
        // cells, lanes) must reproduce the per-message oracle's CSV to
        // the byte, across seeds, sweep workers, and sim lanes
        use crate::miniapp::SimMode;
        for seed in [5u64, 11] {
            let mut spec = ExperimentSpec::tiny_grid(24, seed);
            spec.lustre = ContentionParams::new(0.5, 0.03);
            let base = to_csv(&run_sweep_jobs_opts(
                &spec,
                factory,
                1,
                SimOptions {
                    mode: SimMode::PerMessage,
                    ..Default::default()
                },
                |_| {},
            ));
            assert_eq!(base.lines().count(), spec.size() + 1, "header + one row per config");
            for jobs in [1usize, 2, 8] {
                for (mode, lanes) in [
                    (SimMode::Cohort, 1),
                    (SimMode::Cohort, 4),
                    (SimMode::PerMessage, 2),
                ] {
                    let rows = run_sweep_jobs_opts(
                        &spec,
                        factory,
                        jobs,
                        SimOptions {
                            mode,
                            lanes,
                            ..Default::default()
                        },
                        |_| {},
                    );
                    assert_eq!(
                        to_csv(&rows),
                        base,
                        "seed={seed} jobs={jobs} lanes={lanes} {mode:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn edge_fleet_sweep_matches_across_modes() {
        // the co-located edge stack exercises the default put_cohort
        // (materialize + put) — still byte-identical to per-message
        use crate::miniapp::SimMode;
        let edge_factory = |sc: &crate::miniapp::Scenario| -> Arc<dyn StepEngine> {
            let mut e = CalibratedEngine::new(sc.seed ^ sc.partitions as u64);
            e.insert((8_000, 128), Dist::Const(0.01));
            Arc::new(e)
        };
        let spec = ExperimentSpec::edge_fleet_grid(8, 7);
        let base = to_csv(&run_sweep_jobs_opts(
            &spec,
            edge_factory,
            1,
            SimOptions {
                mode: SimMode::PerMessage,
                ..Default::default()
            },
            |_| {},
        ));
        assert_eq!(base.lines().count(), spec.size() + 1, "header + one row per config");
        for jobs in [2usize, 8] {
            let rows =
                run_sweep_jobs_opts(&spec, edge_factory, jobs, SimOptions::default(), |_| {});
            assert_eq!(to_csv(&rows), base, "jobs={jobs}");
        }
    }

    #[test]
    fn query_keys_select_subsets() {
        let rows = run_sweep(&tiny_spec(), factory);
        let by_platform = GroupKey::new(vec![(
            "platform".to_string(),
            AxisValue::Platform(PlatformKind::Lambda),
        )]);
        let obs = group_observations(&rows, &by_platform);
        assert_eq!(obs.len(), 3, "subset query selects the whole lambda curve");
    }

    #[test]
    fn group_keys_dedup_is_order_preserving_on_large_sweeps() {
        // synthetic sweep: 5,000 rows over 250 interleaved groups
        let template = run_sweep(&tiny_spec(), factory).remove(0);
        let rows: Vec<SweepRow> = (0..5_000)
            .map(|i| {
                let mut r = template.clone();
                r.key = GroupKey::new(vec![(
                    "centroids".to_string(),
                    AxisValue::Int((i % 250) as u64),
                )]);
                r.scale = i / 250 + 1;
                r
            })
            .collect();
        let keys = group_keys(&rows);
        assert_eq!(keys.len(), 250);
        // first-appearance order: group i appeared at row i
        for (i, k) in keys.iter().enumerate() {
            assert_eq!(k.int("centroids"), Some(i as u64));
        }
    }
}
