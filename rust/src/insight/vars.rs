//! Table I — the model's dependent, independent and control variables.

/// One glossary row.
#[derive(Debug, Clone, Copy)]
pub struct Variable {
    pub symbol: &'static str,
    pub description: &'static str,
    pub role: &'static str,
}

/// The paper's Table I.
pub const TABLE_I: &[Variable] = &[
    Variable { symbol: "L", description: "Overall Latency", role: "dependent" },
    Variable { symbol: "L^px", description: "Latency Processing System", role: "dependent" },
    Variable { symbol: "L^br", description: "Latency Broker System", role: "dependent" },
    Variable { symbol: "T", description: "Overall Throughput", role: "dependent" },
    Variable { symbol: "T^px", description: "Throughput Processing System", role: "dependent" },
    Variable { symbol: "T^br", description: "Throughput Broker System", role: "dependent" },
    Variable { symbol: "N^px(n)", description: "Number Nodes Processing System", role: "independent" },
    Variable { symbol: "N^px(p)", description: "Number Partitions Processing System", role: "independent" },
    Variable { symbol: "N^br(n)", description: "Number Nodes Broker System", role: "independent" },
    Variable { symbol: "N^br(p)", description: "Number Partitions Broker System", role: "independent" },
    Variable { symbol: "M", description: "Machine and Infrastructure", role: "control" },
    Variable { symbol: "WC", description: "Workload Complexity", role: "control" },
    Variable { symbol: "MS", description: "Message Size", role: "control" },
];

/// Render Table I as fixed-width text.
pub fn render() -> String {
    let mut s = String::new();
    s.push_str(&format!("{:<10} {:<42} {}\n", "Symbol", "Description", "Role"));
    s.push_str(&"-".repeat(66));
    s.push('\n');
    for v in TABLE_I {
        s.push_str(&format!("{:<10} {:<42} {}\n", v.symbol, v.description, v.role));
    }
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn table_complete() {
        assert_eq!(super::TABLE_I.len(), 13);
        let r = super::render();
        assert!(r.contains("N^px(p)"));
        assert!(r.contains("Workload Complexity"));
    }
}
