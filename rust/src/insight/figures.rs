//! Figure regeneration: one function per table/figure of the paper's
//! evaluation (DESIGN.md experiment index).  Shared by the CLI
//! (`pilot-streaming fig3` …) and the bench harness
//! (`cargo bench --bench fig3_lambda_memory` …).
//!
//! Each function returns a [`FigureResult`]: a printable table plus the
//! qualitative *shape checks* the paper's claims imply.  Benches print the
//! table and assert the checks — reproducing who wins, by roughly what
//! factor, and where crossovers fall (not the authors' absolute numbers;
//! our substrate is a simulator calibrated to this machine's PJRT).

use super::analysis::{analyze, AnalysisRow};
use super::experiment::{ExperimentSpec, AXIS_CENTROIDS, AXIS_MESSAGE_SIZE, AXIS_PARTITIONS};
use super::sweep::{group_observations, paper_key, run_sweep};
use crate::engine::{CalibratedEngine, StepEngine};
use crate::miniapp::{PlatformKind, Scenario};
use crate::runtime::calibrate::{calibrated_engine, load_or_fallback, CalibrationRow};
use crate::usl::{rmse_vs_train_size, Obs};
use crate::util::rng::SplitMix64;
use crate::util::stats::mean;
use std::fmt::Write as _;
use std::sync::Arc;

/// Output of one figure regeneration.
#[derive(Debug, Clone)]
pub struct FigureResult {
    pub id: &'static str,
    pub title: &'static str,
    /// Fixed-width table, ready to print.
    pub table: String,
    /// Shape checks: (claim, holds).
    pub checks: Vec<(String, bool)>,
}

impl FigureResult {
    pub fn all_pass(&self) -> bool {
        self.checks.iter().all(|(_, ok)| *ok)
    }

    pub fn render(&self) -> String {
        let mut s = format!("== {} — {} ==\n{}\n", self.id, self.title, self.table);
        for (claim, ok) in &self.checks {
            let _ = writeln!(s, "  [{}] {}", if *ok { "PASS" } else { "FAIL" }, claim);
        }
        s
    }
}

/// Calibration rows for figure runs: artifacts/calibration.json if present,
/// else the built-in fallback.
pub fn default_calibration() -> Vec<CalibrationRow> {
    let path = crate::runtime::Manifest::default_dir().join("calibration.json");
    load_or_fallback(&path)
}

/// Engine factory used by all figure sweeps.
pub fn engine_factory(rows: Vec<CalibrationRow>) -> impl Fn(&Scenario) -> Arc<dyn StepEngine> {
    move |sc: &Scenario| {
        // derive a per-config seed so configs don't share RNG streams
        let mut seed = sc.seed ^ (sc.partitions as u64)
            | ((sc.centroids as u64) << 20)
            | ((sc.points_per_message as u64) << 40)
            ^ ((sc.memory_mb as u64) << 8);
        // extension axes perturb the stream too, so every level of a
        // custom axis gets an independent (still deterministic) stream
        for (name, value) in &sc.extra {
            let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a over the axis name
            for b in name.bytes() {
                h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
            seed ^= SplitMix64::new(h ^ *value).next_u64();
        }
        let eng: CalibratedEngine = calibrated_engine(&rows, seed);
        Arc::new(eng)
    }
}

// ---------------------------------------------------------------- Fig 3

/// Fig 3: Lambda container memory vs function runtime (8,000 points,
/// 1,024 centroids).
pub fn fig3(messages: usize, seed: u64) -> FigureResult {
    let spec = ExperimentSpec::lambda_memory_sweep(messages, seed);
    let rows = run_sweep(&spec, engine_factory(default_calibration()));
    // warm-path stats: the paper's Fig 3 box plots show steady-state
    // function runtimes; one-off cold starts are provisioning, not runtime
    let mut table = String::from("memory_mb  runtime_mean_s  runtime_cv\n");
    for r in &rows {
        let _ = writeln!(
            table,
            "{:>9}  {:>14.3}  {:>10.3}",
            r.axis_int("memory_mb").unwrap_or(0),
            r.warm_mean,
            r.warm_cv
        );
    }
    let first = rows.first();
    let last = rows.last();
    let (lo, hi) = match (first, last) {
        (Some(a), Some(b)) => (a.clone(), b.clone()),
        _ => {
            return FigureResult {
                id: "fig3",
                title: "Lambda container memory vs runtime",
                table,
                checks: vec![("sweep produced data".into(), false)],
            }
        }
    };
    let monotone = rows.windows(2).all(|w| w[1].warm_mean <= w[0].warm_mean * 1.10);
    FigureResult {
        id: "fig3",
        title: "Lambda container memory vs runtime (8k pts, 1024 centroids)",
        table,
        checks: vec![
            (
                format!(
                    "larger memory → shorter runtime ({}MB {:.2}s vs {}MB {:.2}s)",
                    lo.axis_int("memory_mb").unwrap_or(0),
                    lo.warm_mean,
                    hi.axis_int("memory_mb").unwrap_or(0),
                    hi.warm_mean
                ),
                lo.warm_mean > hi.warm_mean * 1.5,
            ),
            (
                format!(
                    "fluctuation shrinks with memory (warm cv {:.3} → {:.3})",
                    lo.warm_cv, hi.warm_cv
                ),
                lo.warm_cv > hi.warm_cv,
            ),
            ("runtime non-increasing across the sweep".into(), monotone),
        ],
    }
}

// ---------------------------------------------------------------- Fig 4

/// Fig 4: message processing time L^px by partitions x MS x WC,
/// Lambda vs Dask.
pub fn fig4(messages: usize, seed: u64) -> FigureResult {
    let spec = ExperimentSpec::paper_grid(messages, seed);
    let rows = run_sweep(&spec, engine_factory(default_calibration()));
    let mut table =
        String::from("platform               MS      WC      P  service_mean_s\n");
    for r in &rows {
        let _ = writeln!(
            table,
            "{:<22} {:>6} {:>6} {:>6}  {:>13.3}",
            r.platform().map(|p| p.label()).unwrap_or("?"),
            r.axis_int(AXIS_MESSAGE_SIZE).unwrap_or(0),
            r.axis_int(AXIS_CENTROIDS).unwrap_or(0),
            r.scale,
            r.service_mean
        );
    }
    let svc = |pf: PlatformKind, p: usize| {
        mean(
            &rows
                .iter()
                .filter(|r| r.platform() == Some(pf) && r.scale == p)
                .map(|r| r.service_mean)
                .collect::<Vec<_>>(),
        )
    };
    let lam1 = svc(PlatformKind::Lambda, 1);
    let lam16 = svc(PlatformKind::Lambda, 16);
    let dask1 = svc(PlatformKind::DaskWrangler, 1);
    let dask16 = svc(PlatformKind::DaskWrangler, 16);
    // processing time grows with MS and WC on both platforms; compare at
    // P=1 where neither contention nor cold-start amortization mixes in
    let grows = |pf: PlatformKind| {
        let at_p1 = |ms: usize, wc: usize| {
            mean(
                &rows
                    .iter()
                    .filter(|r| {
                        r.platform() == Some(pf)
                            && r.scale == 1
                            && r.axis_int(AXIS_MESSAGE_SIZE) == Some(ms as u64)
                            && r.axis_int(AXIS_CENTROIDS) == Some(wc as u64)
                    })
                    .map(|r| r.service_mean)
                    .collect::<Vec<_>>(),
            )
        };
        let small = at_p1(8_000, 128);
        let big = at_p1(26_000, 8_192);
        big > small * 5.0
    };
    FigureResult {
        id: "fig4",
        title: "Message processing time L^px (Lambda vs Dask)",
        table,
        checks: vec![
            (
                format!(
                    "Lambda stays flat with parallelism ({:.2}s @P1 vs {:.2}s @P16)",
                    lam1, lam16
                ),
                lam16 < lam1 * 1.35,
            ),
            (
                format!(
                    "Dask degrades with parallelism ({:.2}s @P1 vs {:.2}s @P16)",
                    dask1, dask16
                ),
                dask16 > dask1 * 2.0,
            ),
            (
                "processing time grows with points and centroids (both platforms)".into(),
                grows(PlatformKind::Lambda) && grows(PlatformKind::DaskWrangler),
            ),
        ],
    }
}

// ---------------------------------------------------------------- Fig 5

/// Fig 5: throughput T^px and speedup.
pub fn fig5(messages: usize, seed: u64) -> FigureResult {
    let spec = ExperimentSpec::paper_grid(messages, seed);
    let rows = run_sweep(&spec, engine_factory(default_calibration()));
    let mut table = String::from(
        "platform               MS      WC      P  T^px_msg_s   speedup\n",
    );
    let mut checks: Vec<(String, bool)> = Vec::new();
    for key in super::sweep::group_keys(&rows) {
        let obs = group_observations(&rows, &key);
        let t1 = obs.first().map(|o| o.t).unwrap_or(1.0);
        for o in &obs {
            let _ = writeln!(
                table,
                "{:<22} {:>6} {:>6} {:>6}  {:>10.3} {:>9.2}",
                key.platform().map(|p| p.label()).unwrap_or("?"),
                key.int(AXIS_MESSAGE_SIZE).unwrap_or(0),
                key.int(AXIS_CENTROIDS).unwrap_or(0),
                o.n as usize,
                o.t,
                o.t / t1
            );
        }
    }
    // Lambda throughput increases with partitions (all groups)
    let lambda_ok = super::sweep::group_keys(&rows)
        .into_iter()
        .filter(|k| k.platform() == Some(PlatformKind::Lambda))
        .all(|k| {
            let obs = group_observations(&rows, &k);
            obs.last().unwrap().t > obs.first().unwrap().t * 3.0
        });
    checks.push((
        "Lambda: throughput grows with partitions (>3x at P16 vs P1)".into(),
        lambda_ok,
    ));
    // Dask: compute-heavy (8192) shows a small early speedup; overall
    // degradation for larger P
    let dask_heavy = group_observations(
        &rows,
        &paper_key(PlatformKind::DaskWrangler, 16_000, 8_192, 3_008),
    );
    if !dask_heavy.is_empty() {
        let t1 = dask_heavy[0].t;
        let early_peak = dask_heavy
            .iter()
            .filter(|o| o.n <= 4.0)
            .map(|o| o.t / t1)
            .fold(0.0f64, f64::max);
        checks.push((
            format!(
                "Dask compute-heavy: early speedup up to {:.2}x by P<=4 (paper ~1.2x, small)",
                early_peak
            ),
            early_peak > 1.05 && early_peak < 2.5,
        ));
        // compute-heavy: gains must flatten out — speedup at P=16 no better
        // than ~10% above P=8 (paper: degradation for larger N^px(p))
        let at = |n: f64| dask_heavy.iter().find(|o| o.n == n).map(|o| o.t);
        if let (Some(t8), Some(t16)) = (at(8.0), at(16.0)) {
            checks.push((
                format!(
                    "Dask compute-heavy gains exhausted by P8-16 (T8 {:.2}, T16 {:.2})",
                    t8, t16
                ),
                t16 <= t8 * 1.10,
            ));
        }
        // light groups retrograde strictly by P=16
        for wc in [128usize, 1_024] {
            let obs = group_observations(
                &rows,
                &paper_key(PlatformKind::DaskWrangler, 16_000, wc, 3_008),
            );
            if obs.is_empty() {
                continue;
            }
            let peak = obs.iter().map(|o| o.t).fold(0.0f64, f64::max);
            let last = obs.last().unwrap().t;
            checks.push((
                format!("Dask WC={wc} throughput degrades past its peak ({last:.2} < {peak:.2})"),
                last < peak,
            ));
        }
    }
    // Lambda vs Dask absolute: HPC wins at P=1 for compute-heavy workloads
    let lam_heavy = group_observations(
        &rows,
        &paper_key(PlatformKind::Lambda, 16_000, 8_192, 3_008),
    );
    if let (Some(d1), Some(l1)) = (dask_heavy.first(), lam_heavy.first()) {
        checks.push((
            format!(
                "HPC better absolute performance at P=1 (dask {:.2} vs lambda {:.2} msg/s)",
                d1.t, l1.t
            ),
            d1.t > l1.t * 0.8, // wrangler cores ≈ reference speed, lambda ≤ 1.68 cpu
        ));
    }
    FigureResult {
        id: "fig5",
        title: "Throughput T^px and speedup (Lambda vs Dask)",
        table,
        checks,
    }
}

// ---------------------------------------------------------------- Fig 6

/// Fig 6: USL fit per scenario at MS = 16,000 points.
pub fn fig6(messages: usize, seed: u64) -> FigureResult {
    let mut spec = ExperimentSpec::paper_grid(messages, seed);
    spec.set_ints(AXIS_MESSAGE_SIZE, [16_000]); // the figure's fixed MS
    // stay within the 30-container Lambda cap (the paper's Fig 6 x-range)
    spec.set_ints(AXIS_PARTITIONS, [1, 2, 4, 8, 16]);
    let rows = run_sweep(&spec, engine_factory(default_calibration()));
    let analysis = analyze(&rows);
    let table = super::analysis::table(&analysis);
    let lambda_rows: Vec<&AnalysisRow> = analysis
        .iter()
        .filter(|a| a.platform() == Some(PlatformKind::Lambda))
        .collect();
    let dask_rows: Vec<&AnalysisRow> = analysis
        .iter()
        .filter(|a| a.platform() == Some(PlatformKind::DaskWrangler))
        .collect();
    let lam_sigma = mean(&lambda_rows.iter().map(|a| a.fit.params.sigma).collect::<Vec<_>>());
    let lam_kappa = mean(&lambda_rows.iter().map(|a| a.fit.params.kappa).collect::<Vec<_>>());
    let dask_sigma = mean(&dask_rows.iter().map(|a| a.fit.params.sigma).collect::<Vec<_>>());
    let dask_kappa = mean(&dask_rows.iter().map(|a| a.fit.params.kappa).collect::<Vec<_>>());
    let r2_ok = analysis.iter().all(|a| a.fit.r2 > 0.85);
    // Paper: "In many cases the peak performance is already reached using a
    // single partition"; only "for the more compute-intensive scenarios,
    // i.e. in particular larger model sizes such as 8,192 clusters, a small
    // speedup ... until 4 partitions" — light groups must peak early, the
    // compute-heavy group may peak later but with a bounded, small gain.
    let dask_peak_small = dask_rows.iter().all(|a| {
        let Some(peak) = a.fit.params.peak_n() else {
            return false;
        };
        if a.axis_int(AXIS_CENTROIDS).unwrap_or(0) <= 128 {
            peak <= 5.0
        } else {
            let max_speedup = a.fit.params.speedup(peak.max(1.0));
            peak <= 12.0 && max_speedup < 2.5
        }
    });
    FigureResult {
        id: "fig6",
        title: "USL model fit (MS=16k): sigma/kappa per platform x WC",
        table,
        checks: vec![
            (
                format!(
                    "Lambda near-optimal scalability: sigma {:.3} (<0.1), kappa {:.5} (≈0)",
                    lam_sigma, lam_kappa
                ),
                lam_sigma < 0.1 && lam_kappa < 0.002,
            ),
            (
                format!(
                    "Dask contention-dominated: sigma {:.2} in [0.4, 1.0], kappa {:.4} > 0",
                    dask_sigma, dask_kappa
                ),
                (0.4..=1.0).contains(&dask_sigma) && dask_kappa > 0.001,
            ),
            (
                "Dask peaks early: <=5 partitions for light WC; compute-heavy WC only a small bounded speedup".into(),
                dask_peak_small,
            ),
            (
                "training R^2 in the paper's 0.85-0.98 band (all groups)".into(),
                r2_ok,
            ),
        ],
    }
}

// ---------------------------------------------------------------- Fig 7

/// Fig 7: prediction RMSE vs number of training configurations.
pub fn fig7(messages: usize, seed: u64) -> FigureResult {
    let mut spec = ExperimentSpec::paper_grid(messages, seed);
    spec.set_ints(AXIS_MESSAGE_SIZE, [16_000]);
    spec.set_ints(AXIS_CENTROIDS, [128, 8_192]);
    // the paper's x-range (its figures stop at 12-16 partitions); beyond
    // ~24 the 30-container Lambda cap introduces a kink USL cannot model
    spec.set_ints(AXIS_PARTITIONS, [1, 2, 3, 4, 6, 8, 10, 12, 16]);
    // steady-state windows: at P=16 each shard must still amortize its
    // one-off cold start, or the tail configurations bias the fit
    spec.messages = spec.messages.max(12 * 16);
    let rows = run_sweep(&spec, engine_factory(default_calibration()));
    let mut table = String::from(
        "platform               WC     train_configs  rmse_norm\n",
    );
    let mut checks: Vec<(String, bool)> = Vec::new();
    let train_sizes = [3usize, 4, 5, 6, 8];
    let mut lambda_norm = Vec::new();
    let mut dask_norm = Vec::new();
    for key in super::sweep::group_keys(&rows) {
        let obs: Vec<Obs> = group_observations(&rows, &key);
        let Ok(points) = rmse_vs_train_size(&obs, &train_sizes, 30, seed) else {
            continue;
        };
        let mean_t = mean(&obs.iter().map(|o| o.t).collect::<Vec<_>>()).max(1e-12);
        for p in &points {
            let norm = p.rmse_mean / mean_t;
            let _ = writeln!(
                table,
                "{:<22} {:>6} {:>13} {:>10.4}",
                key.platform().map(|pf| pf.label()).unwrap_or("?"),
                key.int(AXIS_CENTROIDS).unwrap_or(0),
                p.train_size,
                norm
            );
            if key.platform() == Some(PlatformKind::Lambda) {
                lambda_norm.push(norm);
            } else {
                dask_norm.push(norm);
            }
        }
    }
    let lam = mean(&lambda_norm);
    let dask = mean(&dask_norm);
    checks.push((
        format!(
            "Lambda/Kinesis more predictable than Dask/Kafka (norm RMSE {:.3} vs {:.3})",
            lam, dask
        ),
        lam < dask,
    ));
    checks.push((
        format!("small training sets suffice (3-config norm RMSE {:.3} < 0.35)", {
            let threes: Vec<f64> = lambda_norm.iter().step_by(train_sizes.len()).copied().collect();
            mean(&threes)
        }),
        {
            let threes: Vec<f64> = lambda_norm.iter().step_by(train_sizes.len()).copied().collect();
            mean(&threes) < 0.35
        },
    ));
    FigureResult {
        id: "fig7",
        title: "RMSE vs number of training configurations",
        table,
        checks,
    }
}

// ---------------------------------------------------------------- Table I

/// Table I: the variable glossary (rendered from `vars`).
pub fn table1() -> FigureResult {
    FigureResult {
        id: "table1",
        title: "Model variables",
        table: super::vars::render(),
        checks: vec![("13 variables documented".into(), super::vars::TABLE_I.len() == 13)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The figure functions are exercised end-to-end by the bench targets
    // (cargo bench) with larger message counts; these tests use tiny runs
    // to keep `cargo test` fast while verifying the plumbing end to end.

    #[test]
    fn fig3_shape_holds_on_small_run() {
        let r = fig3(24, 11);
        assert!(r.all_pass(), "\n{}", r.render());
    }

    #[test]
    fn fig6_shape_holds_on_small_run() {
        let r = fig6(24, 13);
        assert!(r.all_pass(), "\n{}", r.render());
    }

    #[test]
    fn table1_renders() {
        assert!(table1().all_pass());
    }

    #[test]
    fn engine_seed_distinguishes_extension_axis_levels() {
        // two scenarios differing only in a custom axis level must draw
        // from different (but individually deterministic) RNG streams
        let factory = engine_factory(default_calibration());
        let mut a = Scenario::default();
        a.set_extra("edge_sites", 1);
        let mut b = Scenario::default();
        b.set_extra("edge_sites", 2);
        let model = crate::store::ModelState::new_random(16, 8, 1);
        let pts = vec![0.0f32; 800];
        let cost = |sc: &Scenario| {
            factory(sc)
                .execute_step(&pts, 8, &model)
                .unwrap()
                .cpu_seconds
        };
        assert_ne!(cost(&a), cost(&b), "streams must differ across levels");
        let c1 = cost(&a);
        let c2 = cost(&a);
        assert_eq!(c1, c2, "same level, same stream");
    }
}
