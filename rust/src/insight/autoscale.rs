//! Predictive autoscaling — the paper's future-work feature, built on the
//! USL predictor: "integrate StreamInsight into the resource management
//! algorithm of Pilot-Streaming so as to support predictive scaling, viz.,
//! the ability to adapt the resource allocations ... to changes in the
//! incoming data rate(s). This will also enable the determination of the
//! amount of throttling of data sources to guarantee processing."

use super::objective::{shape, CostLedger, CostedDecision, Objective, Shaped};
use super::predict::Predictor;
use crate::pilot::PriceModel;
use crate::util::json::Json;
use crate::util::stats::Ewma;

/// Autoscaler decision for one control interval.
#[derive(Debug, Clone, PartialEq)]
pub enum ScaleDecision {
    /// Keep the current parallelism.
    Hold { parallelism: usize },
    /// Change parallelism.
    Scale { from: usize, to: usize },
    /// Even the optimal deployment cannot absorb the rate: throttle the
    /// source to `max_rate` while running at `parallelism`.
    Throttle { parallelism: usize, max_rate: f64 },
}

impl ScaleDecision {
    /// The parallelism this decision steers the platform toward: `None`
    /// for a hold (keep whatever is running), the destination for a
    /// scale, the capped fleet for a throttle.  Every decision decoder —
    /// both live targets, the chaos wrapper, the replay model — goes
    /// through this one accessor.
    pub fn target_parallelism(&self) -> Option<usize> {
        match self {
            Self::Hold { .. } => None,
            Self::Scale { to, .. } => Some(*to),
            Self::Throttle { parallelism, .. } => Some(*parallelism),
        }
    }

    /// The canonical machine representation, round-trippable through
    /// [`ScaleDecision::from_json`] (floats survive via Rust's
    /// shortest-repr `Display`).
    pub fn to_json(&self) -> Json {
        match self {
            Self::Hold { parallelism } => Json::obj(vec![
                ("kind", Json::Str("hold".into())),
                ("parallelism", Json::Num(*parallelism as f64)),
            ]),
            Self::Scale { from, to } => Json::obj(vec![
                ("kind", Json::Str("scale".into())),
                ("from", Json::Num(*from as f64)),
                ("to", Json::Num(*to as f64)),
            ]),
            Self::Throttle {
                parallelism,
                max_rate,
            } => Json::obj(vec![
                ("kind", Json::Str("throttle".into())),
                ("parallelism", Json::Num(*parallelism as f64)),
                ("max_rate", Json::Num(*max_rate)),
            ]),
        }
    }

    /// Parse the [`ScaleDecision::to_json`] representation.
    pub fn from_json(json: &Json) -> Option<Self> {
        match json.get("kind").as_str()? {
            "hold" => Some(Self::Hold {
                parallelism: json.get("parallelism").as_usize()?,
            }),
            "scale" => Some(Self::Scale {
                from: json.get("from").as_usize()?,
                to: json.get("to").as_usize()?,
            }),
            "throttle" => Some(Self::Throttle {
                parallelism: json.get("parallelism").as_usize()?,
                max_rate: json.get("max_rate").as_f64()?,
            }),
            _ => None,
        }
    }
}

/// The canonical human representation — what the CLI tick table, report
/// summaries, and benches print.
impl std::fmt::Display for ScaleDecision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Hold { .. } => write!(f, "hold"),
            Self::Scale { from, to } => write!(f, "{from}->{to}"),
            Self::Throttle { max_rate, .. } => write!(f, "throttle@{max_rate:.1}"),
        }
    }
}

/// Configuration of the predictive autoscaler.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Capacity headroom kept above the observed rate.
    pub headroom: f64,
    /// EWMA smoothing for the observed rate.
    pub alpha: f64,
    /// Hysteresis: don't scale unless the target differs by this factor
    /// in required capacity (prevents flapping).
    pub hysteresis: f64,
    /// Hard parallelism cap (shards available / budget).
    pub max_parallelism: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        Self {
            headroom: 1.25,
            alpha: 0.3,
            hysteresis: 1.15,
            max_parallelism: 64,
        }
    }
}

/// The predictive autoscaler: feeds observed ingest rates into an EWMA,
/// consults the USL predictor, shapes the proposal under its
/// [`Objective`], and recommends scale/hold/throttle.
pub struct Autoscaler {
    predictor: Predictor,
    config: AutoscaleConfig,
    rate: Ewma,
    current: usize,
    decisions: u64,
    scale_events: u64,
    objective: Objective,
    price: PriceModel,
}

impl Autoscaler {
    pub fn new(predictor: Predictor, config: AutoscaleConfig, initial_parallelism: usize) -> Self {
        let alpha = config.alpha;
        Self {
            predictor,
            config,
            rate: Ewma::new(alpha),
            current: initial_parallelism.max(1),
            decisions: 0,
            scale_events: 0,
            objective: Objective::Goodput,
            price: PriceModel::free(),
        }
    }

    /// Steer decisions by `objective`, pricing them with the platform's
    /// declared model (builder leg; the default is goodput, unpriced —
    /// the exact pre-objective behavior).
    pub fn with_objective(mut self, objective: Objective, price: PriceModel) -> Self {
        self.objective = objective;
        self.price = price;
        self
    }

    pub fn objective(&self) -> Objective {
        self.objective
    }

    pub fn price(&self) -> PriceModel {
        self.price
    }

    pub fn current_parallelism(&self) -> usize {
        self.current
    }

    /// The model currently steering decisions.
    pub fn predictor(&self) -> &Predictor {
        &self.predictor
    }

    /// Hot-swap the model mid-run — the online recalibration seam.  The
    /// smoothed-rate EWMA, parallelism belief, learned caps, and event
    /// counters all survive the swap; only the capacity curve changes, so
    /// the very next decision steers from the refreshed fit.
    pub fn set_predictor(&mut self, predictor: Predictor) {
        self.predictor = predictor;
    }

    /// Clamp the autoscaler's belief of current parallelism to what the
    /// platform actually realized.  The control loop calls this after
    /// actuation so device caps (the edge envelope) and clamped
    /// transitions feed back into the next decision instead of letting
    /// belief and reality drift.
    pub fn set_parallelism(&mut self, n: usize) {
        self.current = n.max(1);
    }

    /// Tighten the search cap to what the platform proved reachable (a
    /// clamped resize plan).  Once the cap equals the platform's real
    /// envelope, unreachable rates resolve to [`ScaleDecision::Throttle`]
    /// instead of a futile scale-up every interval.
    pub fn limit_max_parallelism(&mut self, cap: usize) {
        self.config.max_parallelism = self.config.max_parallelism.min(cap.max(1));
    }

    pub fn scale_events(&self) -> u64 {
        self.scale_events
    }

    /// Feed one rate observation into the EWMA *without* deciding — used
    /// by the control loop while a resize transition is in flight, so the
    /// smoothed rate stays warm but no phantom scale decisions (or
    /// `scale_events`) accrue against a pilot that cannot actuate them.
    pub fn observe_rate(&mut self, incoming_rate: f64) -> f64 {
        self.rate.observe(incoming_rate.max(0.0))
    }

    /// Feed one control-interval observation of the incoming rate (msg/s)
    /// and get a decision.  Equivalent to [`Autoscaler::observe_costed`]
    /// with an unmetered ledger — under the default goodput objective
    /// this is the exact pre-objective decision sequence.
    pub fn observe(&mut self, incoming_rate: f64) -> ScaleDecision {
        self.observe_costed(incoming_rate, &CostLedger::unmetered())
            .decision
    }

    /// Observe one interval under the configured objective, weighing the
    /// proposal against the budget state in `ledger` (run-rate cap +
    /// accrued transition allowance) before committing — the decision and
    /// its price tag come back together as a [`CostedDecision`].
    pub fn observe_costed(&mut self, incoming_rate: f64, ledger: &CostLedger) -> CostedDecision {
        self.decisions += 1;
        let smoothed = self.observe_rate(incoming_rate);
        let goodput_target = self.predictor.required_parallelism(
            smoothed,
            self.config.headroom,
            self.config.max_parallelism,
        );
        let shaping = shape(
            self.objective,
            &self.predictor,
            &self.price,
            ledger,
            smoothed,
            self.config.headroom,
            self.config.max_parallelism,
            self.current,
        );
        let from = self.current;
        let decision = match shaping.shaped {
            Shaped::Throttle { n, max_rate } => {
                if n != self.current {
                    self.scale_events += 1;
                    self.current = n;
                }
                ScaleDecision::Throttle {
                    parallelism: n,
                    max_rate,
                }
            }
            Shaped::Reach { n, urgent } => {
                if n == self.current {
                    ScaleDecision::Hold {
                        parallelism: self.current,
                    }
                } else {
                    // hysteresis: require a meaningful capacity delta
                    // (urgent SLO reaches skip it — a latency breach with
                    // capacity available must not flap-guard itself)
                    let cur_cap = self.predictor.throughput(self.current);
                    let new_cap = self.predictor.throughput(n);
                    let ratio = if new_cap > cur_cap {
                        new_cap / cur_cap.max(1e-12)
                    } else {
                        cur_cap / new_cap.max(1e-12)
                    };
                    if !urgent && ratio < self.config.hysteresis {
                        ScaleDecision::Hold {
                            parallelism: self.current,
                        }
                    } else {
                        self.current = n;
                        self.scale_events += 1;
                        ScaleDecision::Scale { from, to: n }
                    }
                }
            }
        };
        let committed = decision.target_parallelism().unwrap_or(from);
        CostedDecision {
            run_rate_dollars_per_hour: self.price.run_rate_dollars_per_hour(committed),
            transition_dollars: self.price.transition_dollars(from, committed),
            capped_by_budget: shaping.capped,
            goodput_target,
            decision,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::usl::UslParams;

    fn autoscaler(sigma: f64, kappa: f64, lambda: f64, start: usize) -> Autoscaler {
        Autoscaler::new(
            Predictor {
                params: UslParams::new(sigma, kappa, lambda),
            },
            AutoscaleConfig::default(),
            start,
        )
    }

    #[test]
    fn scales_up_on_rate_increase() {
        let mut a = autoscaler(0.02, 0.0001, 10.0, 1);
        // rate well above 1-partition capacity (λ=10)
        let mut scaled = false;
        for _ in 0..10 {
            if let ScaleDecision::Scale { from, to } = a.observe(50.0) {
                assert!(to > from);
                scaled = true;
            }
        }
        assert!(scaled);
        assert!(a.current_parallelism() >= 6);
    }

    #[test]
    fn scales_down_when_rate_drops() {
        let mut a = autoscaler(0.02, 0.0001, 10.0, 32);
        for _ in 0..20 {
            a.observe(5.0);
        }
        assert!(a.current_parallelism() <= 2);
    }

    #[test]
    fn holds_within_hysteresis() {
        let mut a = autoscaler(0.02, 0.0001, 10.0, 4);
        // capacity at 4 ≈ 37.7; rate needing exactly ~4 partitions
        let mut holds = 0;
        for _ in 0..20 {
            if matches!(a.observe(28.0), ScaleDecision::Hold { .. }) {
                holds += 1;
            }
        }
        assert!(holds >= 18, "holds={holds}");
        assert_eq!(a.current_parallelism(), 4);
    }

    #[test]
    fn throttles_unreachable_rates() {
        // heavily retrograde platform: peak near N=1
        let mut a = autoscaler(0.9, 0.1, 5.0, 2);
        let d = (0..10).map(|_| a.observe(500.0)).last().unwrap();
        match d {
            ScaleDecision::Throttle {
                parallelism,
                max_rate,
            } => {
                assert!(parallelism >= 1);
                assert!(max_rate < 500.0);
            }
            other => panic!("expected throttle, got {other:?}"),
        }
    }

    #[test]
    fn decision_display_and_json_round_trip() {
        let decisions = [
            ScaleDecision::Hold { parallelism: 3 },
            ScaleDecision::Scale { from: 2, to: 7 },
            ScaleDecision::Throttle {
                parallelism: 4,
                max_rate: 37.25,
            },
        ];
        // the canonical strings the CLI/benches print
        assert_eq!(decisions[0].to_string(), "hold");
        assert_eq!(decisions[1].to_string(), "2->7");
        assert_eq!(decisions[2].to_string(), "throttle@37.2");
        // lossless machine representation
        for d in &decisions {
            let json = d.to_json().to_string();
            let parsed = crate::util::json::parse(&json).unwrap();
            assert_eq!(ScaleDecision::from_json(&parsed).as_ref(), Some(d), "{json}");
        }
        assert!(ScaleDecision::from_json(&crate::util::json::Json::Null).is_none());
    }

    #[test]
    fn target_parallelism_decodes_every_variant() {
        assert_eq!(
            ScaleDecision::Hold { parallelism: 3 }.target_parallelism(),
            None
        );
        assert_eq!(
            ScaleDecision::Scale { from: 2, to: 7 }.target_parallelism(),
            Some(7)
        );
        assert_eq!(
            ScaleDecision::Throttle {
                parallelism: 4,
                max_rate: 1.0
            }
            .target_parallelism(),
            Some(4)
        );
    }

    #[test]
    fn goodput_objective_is_the_default_and_changes_nothing() {
        // observe() and observe_costed(unmetered) must agree decision for
        // decision — the objective head is a no-op until opted into
        let mut plain = autoscaler(0.02, 0.0001, 10.0, 1);
        let mut costed = autoscaler(0.02, 0.0001, 10.0, 1);
        assert_eq!(costed.objective(), super::Objective::Goodput);
        for rate in [5.0, 50.0, 120.0, 80.0, 10.0, 10.0] {
            let d = plain.observe(rate);
            let c = costed.observe_costed(rate, &super::CostLedger::unmetered());
            assert_eq!(d, c.decision);
            // unpriced platform: every dollar figure is zero
            assert_eq!(c.run_rate_dollars_per_hour, 0.0);
            assert_eq!(c.transition_dollars, 0.0);
            assert!(!c.capped_by_budget);
        }
        assert_eq!(plain.scale_events(), costed.scale_events());
    }

    #[test]
    fn cost_objective_prices_committed_decisions() {
        let price = crate::pilot::PriceModel::per_unit_hour(0.10, "unit-hour");
        let mut a = autoscaler(0.02, 0.0001, 10.0, 1).with_objective(
            super::Objective::Cost {
                budget_per_hour: 0.50,
            },
            price,
        );
        let mut peak = 0;
        for _ in 0..10 {
            let c = a.observe_costed(100.0, &super::CostLedger::unmetered());
            peak = peak.max(a.current_parallelism());
            let run_fraction = crate::insight::objective::RUN_BUDGET_FRACTION;
            assert!(c.run_rate_dollars_per_hour <= 0.50 * run_fraction + 1e-9);
        }
        // 0.9 * 0.50 / 0.10 affords 4 units; demand wanted far more
        assert_eq!(peak, 4);
    }

    #[test]
    fn ewma_smooths_spikes() {
        let mut a = autoscaler(0.02, 0.0001, 10.0, 2);
        a.observe(15.0);
        // a single spike shouldn't jump straight to the spike's demand
        let d = a.observe(500.0);
        if let ScaleDecision::Scale { to, .. } = d {
            assert!(to < 40, "single spike over-reacted: {to}");
        }
    }
}
