//! Predictive autoscaling — the paper's future-work feature, built on the
//! USL predictor: "integrate StreamInsight into the resource management
//! algorithm of Pilot-Streaming so as to support predictive scaling, viz.,
//! the ability to adapt the resource allocations ... to changes in the
//! incoming data rate(s). This will also enable the determination of the
//! amount of throttling of data sources to guarantee processing."

use super::predict::Predictor;
use crate::util::stats::Ewma;

/// Autoscaler decision for one control interval.
#[derive(Debug, Clone, PartialEq)]
pub enum ScaleDecision {
    /// Keep the current parallelism.
    Hold { parallelism: usize },
    /// Change parallelism.
    Scale { from: usize, to: usize },
    /// Even the optimal deployment cannot absorb the rate: throttle the
    /// source to `max_rate` while running at `parallelism`.
    Throttle { parallelism: usize, max_rate: f64 },
}

/// Configuration of the predictive autoscaler.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Capacity headroom kept above the observed rate.
    pub headroom: f64,
    /// EWMA smoothing for the observed rate.
    pub alpha: f64,
    /// Hysteresis: don't scale unless the target differs by this factor
    /// in required capacity (prevents flapping).
    pub hysteresis: f64,
    /// Hard parallelism cap (shards available / budget).
    pub max_parallelism: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        Self {
            headroom: 1.25,
            alpha: 0.3,
            hysteresis: 1.15,
            max_parallelism: 64,
        }
    }
}

/// The predictive autoscaler: feeds observed ingest rates into an EWMA,
/// consults the USL predictor, and recommends scale/hold/throttle.
pub struct Autoscaler {
    predictor: Predictor,
    config: AutoscaleConfig,
    rate: Ewma,
    current: usize,
    decisions: u64,
    scale_events: u64,
}

impl Autoscaler {
    pub fn new(predictor: Predictor, config: AutoscaleConfig, initial_parallelism: usize) -> Self {
        let alpha = config.alpha;
        Self {
            predictor,
            config,
            rate: Ewma::new(alpha),
            current: initial_parallelism.max(1),
            decisions: 0,
            scale_events: 0,
        }
    }

    pub fn current_parallelism(&self) -> usize {
        self.current
    }

    /// The model currently steering decisions.
    pub fn predictor(&self) -> &Predictor {
        &self.predictor
    }

    /// Hot-swap the model mid-run — the online recalibration seam.  The
    /// smoothed-rate EWMA, parallelism belief, learned caps, and event
    /// counters all survive the swap; only the capacity curve changes, so
    /// the very next decision steers from the refreshed fit.
    pub fn set_predictor(&mut self, predictor: Predictor) {
        self.predictor = predictor;
    }

    /// Clamp the autoscaler's belief of current parallelism to what the
    /// platform actually realized.  The control loop calls this after
    /// actuation so device caps (the edge envelope) and clamped
    /// transitions feed back into the next decision instead of letting
    /// belief and reality drift.
    pub fn set_parallelism(&mut self, n: usize) {
        self.current = n.max(1);
    }

    /// Tighten the search cap to what the platform proved reachable (a
    /// clamped resize plan).  Once the cap equals the platform's real
    /// envelope, unreachable rates resolve to [`ScaleDecision::Throttle`]
    /// instead of a futile scale-up every interval.
    pub fn limit_max_parallelism(&mut self, cap: usize) {
        self.config.max_parallelism = self.config.max_parallelism.min(cap.max(1));
    }

    pub fn scale_events(&self) -> u64 {
        self.scale_events
    }

    /// Feed one rate observation into the EWMA *without* deciding — used
    /// by the control loop while a resize transition is in flight, so the
    /// smoothed rate stays warm but no phantom scale decisions (or
    /// `scale_events`) accrue against a pilot that cannot actuate them.
    pub fn observe_rate(&mut self, incoming_rate: f64) -> f64 {
        self.rate.observe(incoming_rate.max(0.0))
    }

    /// Feed one control-interval observation of the incoming rate (msg/s)
    /// and get a decision.
    pub fn observe(&mut self, incoming_rate: f64) -> ScaleDecision {
        self.decisions += 1;
        let smoothed = self.observe_rate(incoming_rate);
        let target =
            self.predictor
                .required_parallelism(smoothed, self.config.headroom, self.config.max_parallelism);
        match target {
            None => {
                // cap at the optimum and throttle the source
                let best = self.predictor.optimal_parallelism(self.config.max_parallelism);
                if best != self.current {
                    self.scale_events += 1;
                    self.current = best;
                }
                ScaleDecision::Throttle {
                    parallelism: best,
                    max_rate: self.predictor.sustainable_rate(best, self.config.headroom),
                }
            }
            Some(n) if n == self.current => ScaleDecision::Hold {
                parallelism: self.current,
            },
            Some(n) => {
                // hysteresis: require a meaningful capacity delta
                let cur_cap = self.predictor.throughput(self.current);
                let new_cap = self.predictor.throughput(n);
                let ratio = if new_cap > cur_cap {
                    new_cap / cur_cap.max(1e-12)
                } else {
                    cur_cap / new_cap.max(1e-12)
                };
                if ratio < self.config.hysteresis {
                    return ScaleDecision::Hold {
                        parallelism: self.current,
                    };
                }
                let from = self.current;
                self.current = n;
                self.scale_events += 1;
                ScaleDecision::Scale { from, to: n }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::usl::UslParams;

    fn autoscaler(sigma: f64, kappa: f64, lambda: f64, start: usize) -> Autoscaler {
        Autoscaler::new(
            Predictor {
                params: UslParams::new(sigma, kappa, lambda),
            },
            AutoscaleConfig::default(),
            start,
        )
    }

    #[test]
    fn scales_up_on_rate_increase() {
        let mut a = autoscaler(0.02, 0.0001, 10.0, 1);
        // rate well above 1-partition capacity (λ=10)
        let mut scaled = false;
        for _ in 0..10 {
            if let ScaleDecision::Scale { from, to } = a.observe(50.0) {
                assert!(to > from);
                scaled = true;
            }
        }
        assert!(scaled);
        assert!(a.current_parallelism() >= 6);
    }

    #[test]
    fn scales_down_when_rate_drops() {
        let mut a = autoscaler(0.02, 0.0001, 10.0, 32);
        for _ in 0..20 {
            a.observe(5.0);
        }
        assert!(a.current_parallelism() <= 2);
    }

    #[test]
    fn holds_within_hysteresis() {
        let mut a = autoscaler(0.02, 0.0001, 10.0, 4);
        // capacity at 4 ≈ 37.7; rate needing exactly ~4 partitions
        let mut holds = 0;
        for _ in 0..20 {
            if matches!(a.observe(28.0), ScaleDecision::Hold { .. }) {
                holds += 1;
            }
        }
        assert!(holds >= 18, "holds={holds}");
        assert_eq!(a.current_parallelism(), 4);
    }

    #[test]
    fn throttles_unreachable_rates() {
        // heavily retrograde platform: peak near N=1
        let mut a = autoscaler(0.9, 0.1, 5.0, 2);
        let d = (0..10).map(|_| a.observe(500.0)).last().unwrap();
        match d {
            ScaleDecision::Throttle {
                parallelism,
                max_rate,
            } => {
                assert!(parallelism >= 1);
                assert!(max_rate < 500.0);
            }
            other => panic!("expected throttle, got {other:?}"),
        }
    }

    #[test]
    fn ewma_smooths_spikes() {
        let mut a = autoscaler(0.02, 0.0001, 10.0, 2);
        a.observe(15.0);
        // a single spike shouldn't jump straight to the spike's demand
        let d = a.observe(500.0);
        if let ScaleDecision::Scale { to, .. } = d {
            assert!(to < 40, "single spike over-reacted: {to}");
        }
    }
}
