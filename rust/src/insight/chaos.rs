//! Fault injection for the live control loop: wrap any
//! [`ScalingTarget`] in a [`FaultyTarget`] and the [`FaultPlan`]'s
//! windows degrade its goodput envelope — a site outage drops its share
//! of traffic, a cold-start storm slows the whole fleet, a hot key
//! bounds throughput at the hot shard, stragglers drag the affected
//! fraction, a partition walls off its shards.
//!
//! The wrapper sits on the *serve* seam, so the loop's conservation
//! identity (`offered == processed + throttled + backlog`) holds
//! untouched: whatever the fault withholds stays in the loop's backlog
//! and drains after the window closes.  Every tick is recorded as a
//! [`RecoverySample`], and [`FaultyTarget::recovery_report`] turns the
//! trajectory into per-fault [`RecoveryMetrics`] (time-to-detect,
//! time-to-restore-goodput, backlog area) — the evidence
//! `autoscale --live --faults <plan>` uses to prove the recalibrating
//! loop beats a stale static fit under every fault shape.

use super::control::ScalingTarget;
use super::recalibrate::UslSample;
use crate::insight::autoscale::ScaleDecision;
use crate::pilot::ResizePlan;
use crate::sim::faults::{FaultEvent, FaultPlan, RecoveryMetrics, RecoverySample};

/// A [`ScalingTarget`] decorator that injects a [`FaultPlan`] into the
/// serve path.  Fault windows are fractions of the loop's total length
/// (`intervals`), mirroring how the sim driver measures them in run
/// progress; the goodput multiplier of each active window comes from
/// [`FaultKind::capacity_multiplier`](crate::sim::faults::FaultKind).
pub struct FaultyTarget<T: ScalingTarget> {
    inner: T,
    plan: FaultPlan,
    intervals: usize,
    dt: f64,
    tick: usize,
    series: Vec<RecoverySample>,
}

impl<T: ScalingTarget> FaultyTarget<T> {
    pub fn new(inner: T, plan: FaultPlan, intervals: usize, dt: f64) -> Self {
        assert!(dt > 0.0, "control interval must be positive");
        Self {
            inner,
            plan,
            intervals: intervals.max(1),
            dt,
            tick: 0,
            series: Vec::with_capacity(intervals),
        }
    }

    /// The wrapped target (status inspection, teardown).
    pub fn inner(&self) -> &T {
        &self.inner
    }

    pub fn into_inner(self) -> T {
        self.inner
    }

    /// The injected plan.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// The recorded per-tick trajectory.
    pub fn series(&self) -> &[RecoverySample] {
        &self.series
    }

    fn progress(&self) -> f64 {
        self.tick as f64 / self.intervals as f64
    }

    /// Goodput multiplier of every fault window active at `progress`.
    fn multiplier(&self, progress: f64) -> f64 {
        let n = self.inner.parallelism();
        self.plan
            .events
            .iter()
            .filter(|ev| progress >= ev.start && progress < ev.end)
            .map(|ev| ev.kind.capacity_multiplier(n))
            .product()
    }

    /// Per-fault recovery metrics from the recorded trajectory: each
    /// event's window is mapped to loop time and analyzed with
    /// [`RecoveryMetrics::from_series`].
    pub fn recovery_report(&self) -> Vec<(FaultEvent, RecoveryMetrics)> {
        let horizon = self.intervals as f64 * self.dt;
        self.plan
            .events
            .iter()
            .map(|ev| {
                let m = RecoveryMetrics::from_series(
                    &self.series,
                    ev.start * horizon,
                    ev.end * horizon,
                );
                (*ev, m)
            })
            .collect()
    }
}

impl<T: ScalingTarget> ScalingTarget for FaultyTarget<T> {
    fn label(&self) -> String {
        format!("{}+{}", self.inner.label(), self.plan.name)
    }

    fn parallelism(&self) -> usize {
        self.inner.parallelism()
    }

    fn is_resizing(&self) -> bool {
        self.inner.is_resizing()
    }

    fn actuate(&mut self, decision: &ScaleDecision) -> Result<Option<ResizePlan>, String> {
        self.inner.actuate(decision)
    }

    fn serve(&mut self, demand: f64, dt: f64) -> Result<f64, String> {
        let mult = self.multiplier(self.progress());
        let raw = self.inner.serve(demand, dt)?;
        // hash routing keeps feeding the fault its share of the traffic,
        // so the multiplier applies to whatever the fleet realized; the
        // withheld remainder stays in the loop's backlog (conserved)
        let served = raw * mult;
        let t = self.tick as f64 * self.dt;
        self.series.push(RecoverySample {
            t,
            offered_rate: demand / dt,
            served_rate: served / dt,
            backlog: (demand - served).max(0.0),
        });
        self.tick += 1;
        Ok(served)
    }

    fn capacity(&self) -> f64 {
        self.inner.capacity() * self.multiplier(self.progress())
    }

    fn observe_interval(&mut self, served_rate: f64, demand_rate: f64) -> UslSample {
        // the inner target keeps its push-back semantics; the rates the
        // loop measured already carry the fault, so the sample store (and
        // every re-fit) sees the degraded envelope — that is exactly the
        // drift the recalibrating loop re-learns through
        self.inner.observe_interval(served_rate, demand_rate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::insight::autoscale::{AutoscaleConfig, Autoscaler};
    use crate::insight::control::{run_fixed, ControlLoop, ModelTarget};
    use crate::insight::predict::Predictor;
    use crate::usl::UslParams;

    fn predictor(lambda: f64) -> Predictor {
        Predictor {
            params: UslParams::new(0.02, 0.0001, lambda),
        }
    }

    #[test]
    fn fair_weather_wrapper_is_transparent() {
        let trace = vec![40.0; 30];
        let mut plain = ModelTarget::new(predictor(20.0), 4);
        let base = run_fixed(&mut plain, &trace, 1.0).unwrap();
        let inner = ModelTarget::new(predictor(20.0), 4);
        let mut wrapped = FaultyTarget::new(inner, FaultPlan::none(), trace.len(), 1.0);
        let faulted = run_fixed(&mut wrapped, &trace, 1.0).unwrap();
        assert_eq!(
            base.processed_total.to_bits(),
            faulted.processed_total.to_bits()
        );
        assert_eq!(wrapped.series().len(), trace.len());
    }

    #[test]
    fn outage_window_dents_goodput_then_backlog_drains() {
        // fixed parallelism with headroom: the fault window halves served
        // throughput, the backlog drains after rejoin
        let trace = vec![40.0; 40];
        let inner = ModelTarget::new(predictor(30.0), 4); // cap ~112
        let mut target =
            FaultyTarget::new(inner, FaultPlan::preset_by_id(1), trace.len(), 1.0);
        let report = run_fixed(&mut target, &trace, 1.0).unwrap();
        let final_backlog = report.ticks.last().unwrap().backlog;
        assert!(
            (report.offered_total - report.processed_total - report.throttled_total
                - final_backlog)
                .abs()
                < 1e-9,
            "loop conservation must hold through the fault"
        );
        let during = &report.ticks[13]; // inside [0.3, 0.6) * 40
        assert!(during.backlog > 1.0, "the outage must build a backlog");
        let metrics = target.recovery_report();
        assert_eq!(metrics.len(), 1);
        let (_, m) = metrics[0];
        assert!(m.time_to_detect.is_finite());
        assert!(m.restored(), "headroom must drain the backlog after rejoin");
        assert!(m.backlog_area > 0.0);
    }

    #[test]
    fn autoscaled_loop_survives_every_preset() {
        for id in crate::sim::faults::FAULT_PRESET_IDS {
            let trace = vec![60.0; 30];
            let scaler = Autoscaler::new(
                predictor(20.0),
                AutoscaleConfig {
                    max_parallelism: 16,
                    ..Default::default()
                },
                2,
            );
            let inner = ModelTarget::new(predictor(20.0), 2);
            let mut target =
                FaultyTarget::new(inner, FaultPlan::preset_by_id(id), trace.len(), 1.0);
            let report = ControlLoop::new(scaler, 1.0).run(&mut target, &trace).unwrap();
            let final_backlog = report.ticks.last().unwrap().backlog;
            assert!(
                (report.offered_total
                    - report.processed_total
                    - report.throttled_total
                    - final_backlog)
                    .abs()
                    < 1e-9,
                "fault id {id}: conservation violated"
            );
            assert!(report.processed_total > 0.0, "fault id {id}");
        }
    }
}
