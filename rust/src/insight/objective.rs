//! Multi-objective scaling: goodput per dollar under a latency SLO.
//!
//! The paper's EILC motivation is *resource selection* — serverless vs
//! HPC vs edge is ultimately a cost/latency trade, not a throughput race
//! (PAPERS.md: Malawski & Balis' serverless-for-scientific-applications
//! cost analyses).  This module gives the control plane that head:
//!
//! - [`Objective`] names what the loop optimizes: raw [`Objective::Goodput`]
//!   (the PR 3/5 behavior, still the default), [`Objective::Cost`] (maximize
//!   goodput subject to a hard dollars-per-hour budget), or
//!   [`Objective::Slo`] (hold an estimated p99 sojourn target whenever the
//!   USL fit says capacity exists).
//! - [`CostLedger`] is the loop's exact dollar accounting: run-rate charged
//!   per interval at the realized parallelism, transitions charged per
//!   committed scale-up, both from the platform's declared
//!   [`PriceModel`](crate::pilot::PriceModel).
//! - [`CostedDecision`] is what [`Autoscaler::observe_costed`]
//!   (crate::insight::Autoscaler::observe_costed) returns: the committed
//!   [`ScaleDecision`] plus the dollars it moves and whether the objective
//!   capped a goodput-wanted scale-up — the carried PR 5 follow-on, where a
//!   re-fit's recommendation is weighed against transition *and* run-rate
//!   cost before committing.
//!
//! The campaign side reuses ARCHITECTURE seam 3: a `price` axis (integer
//! percent of list price) rides `Scenario::extra` with zero engine edits,
//! and [`cost_rows`]/[`pareto_csv`] turn any priced sweep into a goodput
//! vs $/msg Pareto front.

use super::autoscale::ScaleDecision;
use super::predict::Predictor;
use super::sweep::SweepRow;
use crate::miniapp::PlatformKind;
use crate::pilot::{default_registry, PriceModel};

/// `-ln(0.01)`: the p99 tail factor of an exponential sojourn
/// distribution.  With smoothed arrival rate λ and service capacity C
/// (both msg/s), the M/M/1 sojourn p99 is `ln(100) / (C - λ)`; clearing
/// an existing backlog adds `backlog / C` in front of it.
pub const P99_TAIL_FACTOR: f64 = 4.605_170_185_988_091;

/// Fraction of a [`Objective::Cost`] budget reserved for run-rate spend.
pub const RUN_BUDGET_FRACTION: f64 = 0.9;
/// Fraction reserved for transition spend — `RUN + TRANSITION == 1`, so
/// the two caps together bound cumulative spend by `budget * elapsed_h`
/// at every tick (the `debug_assert` in the control loop).
pub const TRANSITION_BUDGET_FRACTION: f64 = 1.0 - RUN_BUDGET_FRACTION;

/// What the autoscaler optimizes.  [`Objective::Goodput`] reproduces the
/// pre-objective loop bit for bit; the other two reshape its proposals.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Track demand at minimum sufficient parallelism (the default).
    Goodput,
    /// Maximize goodput subject to a hard budget in dollars per hour:
    /// run-rate is capped at [`RUN_BUDGET_FRACTION`] of the budget and
    /// scale-up transitions draw from the remaining
    /// [`TRANSITION_BUDGET_FRACTION`], accrued over elapsed time.
    Cost { budget_per_hour: f64 },
    /// Hold estimated p99 sojourn at or below `p_latency_s` whenever the
    /// fit says capacity exists, bypassing scale-up hysteresis to get
    /// there; when no parallelism reaches the target, throttle admission
    /// to the rate the optimum *can* serve within the SLO.
    Slo { p_latency_s: f64 },
}

impl Objective {
    /// Parse the CLI surface: `--objective goodput|cost|slo` with
    /// `--budget` (dollars/hour) and `--slo-p99` (seconds) riders.
    pub fn parse(name: &str, budget_per_hour: f64, slo_p99_s: f64) -> Result<Self, String> {
        match name.to_ascii_lowercase().as_str() {
            "goodput" => Ok(Self::Goodput),
            "cost" => {
                if budget_per_hour > 0.0 {
                    Ok(Self::Cost { budget_per_hour })
                } else {
                    Err("--objective cost needs --budget <dollars/hour> > 0".into())
                }
            }
            "slo" => {
                if slo_p99_s > 0.0 {
                    Ok(Self::Slo {
                        p_latency_s: slo_p99_s,
                    })
                } else {
                    Err("--objective slo needs --slo-p99 <seconds> > 0".into())
                }
            }
            other => Err(format!(
                "unknown objective {other:?} (expected goodput, cost, or slo)"
            )),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Goodput => "goodput",
            Self::Cost { .. } => "cost",
            Self::Slo { .. } => "slo",
        }
    }

    /// The budget rider, when this is a cost objective.
    pub fn budget_per_hour(&self) -> Option<f64> {
        match self {
            Self::Cost { budget_per_hour } => Some(*budget_per_hour),
            _ => None,
        }
    }

    /// The p99 target, when this is an SLO objective.
    pub fn slo_p99(&self) -> Option<f64> {
        match self {
            Self::Slo { p_latency_s } => Some(*p_latency_s),
            _ => None,
        }
    }
}

/// Exact dollar accounting for one control-loop run.  The loop owns one;
/// the autoscaler reads it when gating transitions against the budget.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CostLedger {
    /// Wall seconds accounted so far.
    pub elapsed_s: f64,
    /// Dollars accrued keeping units running.
    pub run_dollars: f64,
    /// Dollars accrued on committed scale-up transitions.
    pub transition_dollars: f64,
}

impl CostLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// A ledger that never constrains anything: infinite elapsed time
    /// means every accrued budget allowance is already infinite.  This is
    /// what plain `observe` hands the objective, so unmetered callers
    /// keep the exact pre-objective decision sequence.
    pub fn unmetered() -> Self {
        Self {
            elapsed_s: f64::INFINITY,
            run_dollars: 0.0,
            transition_dollars: 0.0,
        }
    }

    pub fn total_dollars(&self) -> f64 {
        self.run_dollars + self.transition_dollars
    }

    /// Accrue one interval of run-rate spend at `parallelism` units.
    pub fn charge_interval(&mut self, price: &PriceModel, parallelism: usize, dt_s: f64) {
        self.run_dollars += price.interval_dollars(parallelism, dt_s);
        self.elapsed_s += dt_s;
    }

    /// Accrue the one-time charge for a realized `from -> to` move
    /// (scale-downs are free by [`PriceModel::transition_dollars`]).
    pub fn charge_transition(&mut self, price: &PriceModel, from: usize, to: usize) -> f64 {
        let d = price.transition_dollars(from, to);
        self.transition_dollars += d;
        d
    }
}

/// A [`ScaleDecision`] with its price tag: what the committed decision
/// costs to run, what the transition moves, and whether the objective
/// overrode the goodput-only recommendation to stay within budget.
#[derive(Debug, Clone, PartialEq)]
pub struct CostedDecision {
    /// The committed decision (identical to what `observe` returns).
    pub decision: ScaleDecision,
    /// Parallelism the goodput-only policy wanted this interval (`None`
    /// when even the optimum cannot absorb the smoothed rate).
    pub goodput_target: Option<usize>,
    /// Run-rate in dollars/hour at the committed parallelism.
    pub run_rate_dollars_per_hour: f64,
    /// One-time dollars this decision's scale-up moves (0 for holds,
    /// scale-downs, and unpriced platforms).
    pub transition_dollars: f64,
    /// True when the objective reduced or deferred a wanted scale-up
    /// (budget cap or transition-allowance gate).
    pub capped_by_budget: bool,
}

/// The objective's reshaped proposal, before hysteresis/commit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum Shaped {
    /// Track toward `n`; `urgent` bypasses scale-up hysteresis (SLO
    /// breach with capacity available).
    Reach { n: usize, urgent: bool },
    /// Run at `n` and throttle admission to `max_rate`.
    Throttle { n: usize, max_rate: f64 },
}

pub(crate) struct Shaping {
    pub(crate) shaped: Shaped,
    /// A goodput-wanted scale-up was reduced or deferred by the budget.
    pub(crate) capped: bool,
}

/// Reshape the goodput proposal under `objective`.  Pure: all state the
/// decision needs arrives as arguments, which keeps double runs
/// bit-identical.
pub(crate) fn shape(
    objective: Objective,
    predictor: &Predictor,
    price: &PriceModel,
    ledger: &CostLedger,
    smoothed: f64,
    headroom: f64,
    max_parallelism: usize,
    current: usize,
) -> Shaping {
    let goal = predictor.required_parallelism(smoothed, headroom, max_parallelism);
    match objective {
        Objective::Goodput => Shaping {
            shaped: match goal {
                Some(n) => Shaped::Reach { n, urgent: false },
                None => {
                    let best = predictor.optimal_parallelism(max_parallelism);
                    Shaped::Throttle {
                        n: best,
                        max_rate: predictor.sustainable_rate(best, headroom),
                    }
                }
            },
            capped: false,
        },
        Objective::Cost { budget_per_hour } => {
            let wanted = goal.unwrap_or_else(|| predictor.optimal_parallelism(max_parallelism));
            // Run-rate cap: the largest fleet whose $/h fits the run
            // fraction of the budget.  A budget below one unit's run-rate
            // is infeasible (parallelism floors at 1) and degenerates to
            // N=1 — the loop's debug_assert bounds spend accordingly.
            let affordable = if price.unit_dollars_per_hour > 0.0 {
                ((RUN_BUDGET_FRACTION * budget_per_hour / price.unit_dollars_per_hour).floor()
                    as usize)
                    .max(1)
            } else {
                max_parallelism
            };
            let mut n = wanted.min(affordable).min(max_parallelism).max(1);
            // Transition gate: scale-ups draw from the transition
            // allowance accrued over elapsed hours; commit only the step
            // the allowance affords right now, deferring the rest.
            let mut deferred = false;
            if n > current && price.transition_dollars_per_unit > 0.0 {
                let allowance = TRANSITION_BUDGET_FRACTION * budget_per_hour * ledger.elapsed_s
                    / 3600.0
                    - ledger.transition_dollars;
                let affordable_units =
                    (allowance / price.transition_dollars_per_unit).floor() as i64;
                let step = (n - current) as i64;
                if affordable_units < step {
                    n = current + affordable_units.max(0) as usize;
                    deferred = true;
                }
            }
            // Below demand, deferred, or currently *over* the affordable
            // fleet (initial conditions): commit the move immediately via
            // Throttle — hysteresis must never hold the loop above what
            // the budget affords.
            let capped = deferred || goal.map_or(true, |g| n < g) || current > affordable;
            if capped {
                // Under-provisioned relative to demand: throttle admission
                // to what the affordable fleet sustains, so backlog (and
                // spend) stay bounded instead of growing with the queue.
                Shaping {
                    shaped: Shaped::Throttle {
                        n,
                        max_rate: predictor.sustainable_rate(n, headroom),
                    },
                    capped: true,
                }
            } else {
                Shaping {
                    shaped: Shaped::Reach { n, urgent: false },
                    capped: false,
                }
            }
        }
        Objective::Slo { p_latency_s } => {
            // Capacity that keeps the M/M/1 p99 sojourn at the target:
            // C >= λ + ln(100)/p.  Find the smallest fleet providing it.
            let need = smoothed + P99_TAIL_FACTOR / p_latency_s.max(1e-9);
            let n_slo = (1..=max_parallelism).find(|&n| predictor.throughput(n) >= need);
            match n_slo {
                Some(n_slo) => {
                    // Never run below the goodput target either — the SLO
                    // objective is goodput plus a latency floor.
                    let n = n_slo.max(goal.unwrap_or(n_slo));
                    let urgent = n > current && predictor.throughput(current) < need;
                    Shaping {
                        shaped: Shaped::Reach { n, urgent },
                        capped: false,
                    }
                }
                None => {
                    // No fleet reaches the target at this rate: run the
                    // optimum and admit only what it can serve within the
                    // SLO tail budget.
                    let best = predictor.optimal_parallelism(max_parallelism);
                    let max_rate =
                        (predictor.throughput(best) - P99_TAIL_FACTOR / p_latency_s.max(1e-9))
                            .max(0.0);
                    Shaping {
                        shaped: Shaped::Throttle { n: best, max_rate },
                        capped: false,
                    }
                }
            }
        }
    }
}

/// Estimated p99 sojourn for one control interval: drain the standing
/// backlog at capacity `c`, then ride the M/M/1 tail at utilization
/// `admitted/c`.  Infinite when the interval is overloaded.
pub fn estimate_p99_s(backlog: f64, admitted_rate: f64, capacity: f64) -> f64 {
    if capacity > admitted_rate && capacity > 0.0 {
        backlog.max(0.0) / capacity + P99_TAIL_FACTOR / (capacity - admitted_rate)
    } else {
        f64::INFINITY
    }
}

/// The list-price model for a mini-app platform: the processing
/// plugin's declared [`PriceModel`] from the default registry.
pub fn platform_price(platform: PlatformKind) -> PriceModel {
    default_registry()
        .get(platform.processing_platform())
        .map(|p| p.elasticity().price)
        .unwrap_or_default()
}

/// One sweep row with its dollar columns (the `sweep --grid cost`
/// analysis).  `price_percent` is the `price` axis level — an integer
/// percent of the platform's list price, so spot discounts (50) and
/// on-demand surcharges (200) sweep as ordinary axis levels.
#[derive(Debug, Clone, PartialEq)]
pub struct CostedRow {
    pub row: SweepRow,
    /// The `price` axis level (percent of list price; 100 = list).
    pub price_percent: u64,
    /// Run-rate at this row's scale, dollars per hour.
    pub dollars_per_hour: f64,
    /// Dollars per 1000 messages at this row's throughput.
    pub dollars_per_kmsg: f64,
    /// On the goodput-vs-$/msg Pareto front of its sweep.
    pub pareto: bool,
}

/// Price every row of a sweep and mark the Pareto front (maximize
/// throughput, minimize $/msg).  Row order is preserved, so the derived
/// CSV inherits the sweep's deterministic ordering.
pub fn cost_rows(rows: &[SweepRow]) -> Vec<CostedRow> {
    let mut costed: Vec<CostedRow> = rows
        .iter()
        .map(|row| {
            let price = row
                .platform()
                .map(platform_price)
                .unwrap_or_else(PriceModel::free);
            let price_percent = row.key.int(super::experiment::AXIS_PRICE).unwrap_or(100);
            let dollars_per_hour = price.run_rate_dollars_per_hour(row.scale)
                * (price_percent as f64 / 100.0);
            let dollars_per_kmsg = if row.throughput > 0.0 {
                dollars_per_hour / 3600.0 / row.throughput * 1000.0
            } else {
                f64::INFINITY
            };
            CostedRow {
                row: row.clone(),
                price_percent,
                dollars_per_hour,
                dollars_per_kmsg,
                pareto: false,
            }
        })
        .collect();
    for i in 0..costed.len() {
        let dominated = costed.iter().enumerate().any(|(j, other)| {
            j != i
                && other.row.throughput >= costed[i].row.throughput
                && other.dollars_per_kmsg <= costed[i].dollars_per_kmsg
                && (other.row.throughput > costed[i].row.throughput
                    || other.dollars_per_kmsg < costed[i].dollars_per_kmsg)
        });
        costed[i].pareto = !dominated;
    }
    costed
}

/// CSV of a priced sweep: the sweep's group columns plus the dollar
/// columns and the Pareto marker.  Deterministic: row order is the
/// sweep's spec order, floats print with fixed precision.
pub fn pareto_csv(costed: &[CostedRow]) -> String {
    let mut out = String::new();
    let mut cols: Vec<String> = Vec::new();
    if let Some(first) = costed.first() {
        cols = first
            .row
            .key
            .pairs()
            .iter()
            .map(|(n, _)| n.clone())
            .collect();
        cols.push(first.row.scale_axis.clone());
    }
    out.push_str(&cols.join(","));
    if !cols.is_empty() {
        out.push(',');
    }
    out.push_str("throughput,dollars_per_hour,dollars_per_kmsg,pareto\n");
    for c in costed {
        for (_, v) in c.row.key.pairs() {
            out.push_str(&v.to_string());
            out.push(',');
        }
        out.push_str(&format!(
            "{},{:.3},{:.6},{:.8},{}\n",
            c.row.scale,
            c.row.throughput,
            c.dollars_per_hour,
            c.dollars_per_kmsg,
            u8::from(c.pareto)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::usl::UslParams;

    fn predictor(sigma: f64, kappa: f64, lambda: f64) -> Predictor {
        Predictor {
            params: UslParams::new(sigma, kappa, lambda),
        }
    }

    #[test]
    fn parse_covers_the_cli_surface() {
        assert_eq!(Objective::parse("goodput", 0.0, 0.0), Ok(Objective::Goodput));
        assert_eq!(
            Objective::parse("Cost", 2.5, 0.0),
            Ok(Objective::Cost {
                budget_per_hour: 2.5
            })
        );
        assert_eq!(
            Objective::parse("slo", 0.0, 0.25),
            Ok(Objective::Slo { p_latency_s: 0.25 })
        );
        assert!(Objective::parse("cost", 0.0, 0.0).is_err());
        assert!(Objective::parse("slo", 0.0, 0.0).is_err());
        assert!(Objective::parse("latency", 0.0, 0.0).is_err());
    }

    #[test]
    fn goodput_shaping_mirrors_required_parallelism() {
        let p = predictor(0.02, 0.0001, 10.0);
        let s = shape(
            Objective::Goodput,
            &p,
            &PriceModel::free(),
            &CostLedger::unmetered(),
            50.0,
            1.25,
            64,
            1,
        );
        let expect = p.required_parallelism(50.0, 1.25, 64).unwrap();
        assert_eq!(
            s.shaped,
            Shaped::Reach {
                n: expect,
                urgent: false
            }
        );
        assert!(!s.capped);
    }

    #[test]
    fn cost_shaping_caps_at_the_affordable_fleet() {
        let p = predictor(0.02, 0.0001, 10.0);
        let price = PriceModel::per_unit_hour(0.10, "unit-hour");
        // 0.9 * $1/h budget affords 9 units; demand wants ~8+ at rate 60
        let s = shape(
            Objective::Cost {
                budget_per_hour: 0.50,
            },
            &p,
            &price,
            &CostLedger::unmetered(),
            60.0,
            1.25,
            64,
            1,
        );
        // 0.9 * 0.50 / 0.10 = 4.5 -> 4 affordable units < goodput target
        match s.shaped {
            Shaped::Throttle { n, max_rate } => {
                assert_eq!(n, 4);
                assert!(max_rate < 60.0);
            }
            other => panic!("expected budget throttle, got {other:?}"),
        }
        assert!(s.capped);
    }

    #[test]
    fn cost_transition_gate_defers_unaffordable_jumps() {
        let p = predictor(0.02, 0.0001, 10.0);
        let price = PriceModel::per_unit_hour(0.01, "unit-hour").with_transition(0.05);
        // plenty of run budget, but at t=0 the transition allowance is 0
        let fresh = CostLedger::new();
        let s = shape(
            Objective::Cost {
                budget_per_hour: 10.0,
            },
            &p,
            &price,
            &fresh,
            60.0,
            1.25,
            64,
            2,
        );
        match s.shaped {
            Shaped::Throttle { n, .. } => assert_eq!(n, 2, "no allowance accrued yet"),
            other => panic!("expected deferred scale-up, got {other:?}"),
        }
        assert!(s.capped);
        // after an hour of accrual the same jump is affordable
        let warm = CostLedger {
            elapsed_s: 3600.0,
            run_dollars: 0.0,
            transition_dollars: 0.0,
        };
        let s = shape(
            Objective::Cost {
                budget_per_hour: 10.0,
            },
            &p,
            &price,
            &warm,
            60.0,
            1.25,
            64,
            2,
        );
        assert!(matches!(s.shaped, Shaped::Reach { .. }));
    }

    #[test]
    fn slo_shaping_reaches_tail_capacity_urgently() {
        let p = predictor(0.02, 0.0001, 10.0);
        // rate 50, p99 0.5s => need 50 + 9.2 = 59.2 capacity
        let s = shape(
            Objective::Slo { p_latency_s: 0.5 },
            &p,
            &PriceModel::free(),
            &CostLedger::unmetered(),
            50.0,
            1.25,
            64,
            2,
        );
        match s.shaped {
            Shaped::Reach { n, urgent } => {
                assert!(p.throughput(n) >= 50.0 + P99_TAIL_FACTOR / 0.5);
                assert!(urgent, "current capacity misses the tail target");
            }
            other => panic!("expected reach, got {other:?}"),
        }
    }

    #[test]
    fn slo_shaping_throttles_unreachable_targets() {
        let p = predictor(0.9, 0.1, 5.0); // peaks near N=1
        let s = shape(
            Objective::Slo { p_latency_s: 0.1 },
            &p,
            &PriceModel::free(),
            &CostLedger::unmetered(),
            500.0,
            1.25,
            64,
            2,
        );
        match s.shaped {
            Shaped::Throttle { max_rate, .. } => assert!(max_rate < 500.0),
            other => panic!("expected throttle, got {other:?}"),
        }
    }

    #[test]
    fn p99_estimate_blows_up_at_saturation() {
        assert!(estimate_p99_s(0.0, 10.0, 20.0).is_finite());
        assert!(estimate_p99_s(0.0, 20.0, 20.0).is_infinite());
        assert!(estimate_p99_s(0.0, 30.0, 20.0).is_infinite());
        // backlog adds drain time in front of the tail
        let clean = estimate_p99_s(0.0, 10.0, 20.0);
        let backlogged = estimate_p99_s(40.0, 10.0, 20.0);
        assert!((backlogged - clean - 2.0).abs() < 1e-9);
    }

    #[test]
    fn every_builtin_platform_prices_the_cost_axis() {
        for kind in [
            PlatformKind::Lambda,
            PlatformKind::DaskWrangler,
            PlatformKind::Edge,
        ] {
            assert!(platform_price(kind).is_priced(), "{kind:?}");
        }
    }
}
