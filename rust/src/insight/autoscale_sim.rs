//! Closed-loop autoscaling simulation: replay a time-varying ingest-rate
//! trace against the USL-driven [`Autoscaler`] and account for processed,
//! backlogged and throttled messages per control interval — the
//! "predictive scaling" capability the paper's conclusion calls for,
//! exercised end to end.

use super::autoscale::{AutoscaleConfig, Autoscaler, ScaleDecision};
use super::predict::Predictor;
use crate::util::rng::Pcg32;

/// One control-interval record.
#[derive(Debug, Clone)]
pub struct Tick {
    pub t: f64,
    pub offered_rate: f64,
    pub parallelism: usize,
    pub capacity: f64,
    pub backlog: f64,
    pub throttled: f64,
    pub decision: ScaleDecision,
}

/// Aggregate outcome of a trace replay.
#[derive(Debug, Clone)]
pub struct AutoscaleReport {
    pub ticks: Vec<Tick>,
    pub offered_total: f64,
    pub processed_total: f64,
    pub throttled_total: f64,
    pub scale_events: u64,
    pub max_backlog: f64,
}

impl AutoscaleReport {
    /// Fraction of offered messages processed (not throttled away).
    pub fn goodput(&self) -> f64 {
        if self.offered_total <= 0.0 {
            return 1.0;
        }
        self.processed_total / self.offered_total
    }
}

/// Standard rate traces for experiments.
pub fn trace_diurnal(intervals: usize, base: f64, peak: f64, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    (0..intervals)
        .map(|i| {
            let phase = i as f64 / intervals as f64 * std::f64::consts::TAU;
            let level = base + (peak - base) * 0.5 * (1.0 - phase.cos());
            (level * rng.normal_with(1.0, 0.05)).max(0.0)
        })
        .collect()
}

pub fn trace_burst(intervals: usize, base: f64, burst: f64, burst_at: usize) -> Vec<f64> {
    (0..intervals)
        .map(|i| {
            if (burst_at..burst_at + intervals / 10).contains(&i) {
                burst
            } else {
                base
            }
        })
        .collect()
}

/// Replay `trace` (msg/s per control interval of `dt` seconds) against an
/// autoscaler built on `predictor`.
pub fn replay(
    predictor: Predictor,
    config: AutoscaleConfig,
    trace: &[f64],
    dt: f64,
    initial_parallelism: usize,
) -> AutoscaleReport {
    let mut scaler = Autoscaler::new(predictor.clone(), config, initial_parallelism);
    let mut backlog = 0.0f64;
    let mut ticks = Vec::with_capacity(trace.len());
    let mut offered_total = 0.0;
    let mut processed_total = 0.0;
    let mut throttled_total = 0.0;
    let mut max_backlog = 0.0f64;

    for (i, &rate) in trace.iter().enumerate() {
        let decision = scaler.observe(rate);
        let parallelism = scaler.current_parallelism();
        let capacity = predictor.throughput(parallelism);
        // throttle admission when the decision says the source must slow
        let admitted_rate = match &decision {
            ScaleDecision::Throttle { max_rate, .. } => rate.min(*max_rate),
            _ => rate,
        };
        let offered = rate * dt;
        let admitted = admitted_rate * dt;
        let processed = (backlog + admitted).min(capacity * dt);
        backlog = (backlog + admitted - processed).max(0.0);
        offered_total += offered;
        processed_total += processed;
        throttled_total += offered - admitted;
        max_backlog = max_backlog.max(backlog);
        ticks.push(Tick {
            t: i as f64 * dt,
            offered_rate: rate,
            parallelism,
            capacity,
            backlog,
            throttled: offered - admitted,
            decision,
        });
    }
    AutoscaleReport {
        ticks,
        offered_total,
        processed_total,
        throttled_total,
        scale_events: scaler.scale_events(),
        max_backlog,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::usl::UslParams;

    fn predictor() -> Predictor {
        // near-linear platform (the Lambda regime), λ = 10 msg/s per shard
        Predictor {
            params: UslParams::new(0.02, 0.0001, 10.0),
        }
    }

    #[test]
    fn diurnal_trace_tracks_load() {
        let trace = trace_diurnal(200, 10.0, 200.0, 1);
        let report = replay(predictor(), AutoscaleConfig::default(), &trace, 1.0, 2);
        // processes nearly everything without unbounded backlog
        assert!(report.goodput() > 0.95, "goodput {}", report.goodput());
        assert!(report.scale_events >= 2, "must scale up and back down");
        let peak_p = report.ticks.iter().map(|t| t.parallelism).max().unwrap();
        let min_p = report.ticks.iter().map(|t| t.parallelism).min().unwrap();
        assert!(peak_p >= 20, "peak parallelism {peak_p}");
        assert!(min_p <= 4, "valley parallelism {min_p}");
        // backlog stays bounded relative to per-interval load
        assert!(report.max_backlog < 400.0, "max backlog {}", report.max_backlog);
    }

    #[test]
    fn burst_is_absorbed() {
        let trace = trace_burst(100, 20.0, 150.0, 40);
        let report = replay(predictor(), AutoscaleConfig::default(), &trace, 1.0, 2);
        assert!(report.goodput() > 0.9, "goodput {}", report.goodput());
        // backlog spikes during the burst but drains afterwards
        let final_backlog = report.ticks.last().unwrap().backlog;
        assert!(final_backlog < 1.0, "backlog must drain, got {final_backlog}");
    }

    #[test]
    fn retrograde_platform_forces_throttling() {
        // Dask-like: peak ≈ 2 partitions, capacity ~6 msg/s
        let p = Predictor {
            params: UslParams::new(0.8, 0.1, 5.0),
        };
        let trace = vec![50.0; 50];
        let report = replay(p, AutoscaleConfig::default(), &trace, 1.0, 1);
        assert!(
            report.throttled_total > report.offered_total * 0.5,
            "most of a 50 msg/s load must be throttled on this platform"
        );
        // and what is admitted is actually processed (stability)
        let final_backlog = report.ticks.last().unwrap().backlog;
        assert!(final_backlog < 50.0, "admitted load stays processable");
    }

    #[test]
    fn deterministic_given_seed() {
        let t1 = trace_diurnal(50, 5.0, 50.0, 9);
        let t2 = trace_diurnal(50, 5.0, 50.0, 9);
        assert_eq!(t1, t2);
    }
}
