//! Closed-loop autoscaling *simulation*: replay a time-varying ingest-rate
//! trace against the USL-driven [`Autoscaler`] and account for processed,
//! backlogged and throttled messages per control interval.
//!
//! Since the elastic control plane landed, [`replay`] is a thin wrapper
//! over [`ControlLoop`](super::control::ControlLoop) with a
//! [`ModelTarget`](super::control::ModelTarget): the same loop that
//! re-provisions a *live* pilot (`autoscale --live`) runs here against the
//! USL model — one decision path, two actuation seams.

use super::autoscale::{AutoscaleConfig, Autoscaler, ScaleDecision};
use super::control::{ControlLoop, ModelTarget, ResizeEvent};
use super::objective::Objective;
use super::predict::Predictor;
use super::recalibrate::RecalibrationTrace;
use crate::pilot::PriceModel;
use crate::util::rng::Pcg32;

/// One control-interval record.
#[derive(Debug, Clone)]
pub struct Tick {
    pub t: f64,
    pub offered_rate: f64,
    pub parallelism: usize,
    pub capacity: f64,
    pub backlog: f64,
    pub throttled: f64,
    /// Estimated p99 sojourn this interval
    /// ([`super::objective::estimate_p99_s`]): backlog drain + M/M/1
    /// tail.  Infinite while the interval is overloaded.
    pub est_p99_s: f64,
    pub decision: ScaleDecision,
}

/// Aggregate outcome of a control-loop run (model replay or live).
#[derive(Debug, Clone)]
pub struct AutoscaleReport {
    pub ticks: Vec<Tick>,
    pub offered_total: f64,
    pub processed_total: f64,
    pub throttled_total: f64,
    pub scale_events: u64,
    pub max_backlog: f64,
    /// Run-rate dollars accrued over the run (0 on unpriced loops).
    pub run_dollars: f64,
    /// One-time scale-up transition dollars accrued over the run.
    pub transition_dollars: f64,
    /// Committed live-resize transitions (empty for model replays, whose
    /// transitions are instantaneous).
    pub resizes: Vec<ResizeEvent>,
    /// Sample store + model-swap history, when the loop ran with
    /// [`ControlLoop::with_recalibration`](super::control::ControlLoop::with_recalibration).
    pub recalibration: Option<RecalibrationTrace>,
}

impl AutoscaleReport {
    /// Fraction of offered messages processed (not throttled away).
    pub fn goodput(&self) -> f64 {
        if self.offered_total <= 0.0 {
            return 1.0;
        }
        self.processed_total / self.offered_total
    }

    /// Total dollars the run moved (run-rate + transitions).
    pub fn dollars_total(&self) -> f64 {
        self.run_dollars + self.transition_dollars
    }

    /// Messages processed per dollar spent — the cost-normalized goodput
    /// the objective comparison ranks loops by.  `None` on unpriced runs
    /// (no denominator to normalize with).
    pub fn msgs_per_dollar(&self) -> Option<f64> {
        let d = self.dollars_total();
        (d > 0.0).then(|| self.processed_total / d)
    }

    /// Fraction of intervals whose estimated p99 sojourn met `p99_s`
    /// (1.0 on empty runs) — the SLO-attainment column.
    pub fn slo_attainment(&self, p99_s: f64) -> f64 {
        if self.ticks.is_empty() {
            return 1.0;
        }
        let met = self.ticks.iter().filter(|t| t.est_p99_s <= p99_s).count();
        met as f64 / self.ticks.len() as f64
    }
}

/// Standard rate traces for experiments.
pub fn trace_diurnal(intervals: usize, base: f64, peak: f64, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    (0..intervals)
        .map(|i| {
            let phase = i as f64 / intervals as f64 * std::f64::consts::TAU;
            let level = base + (peak - base) * 0.5 * (1.0 - phase.cos());
            (level * rng.normal_with(1.0, 0.05)).max(0.0)
        })
        .collect()
}

pub fn trace_burst(intervals: usize, base: f64, burst: f64, burst_at: usize) -> Vec<f64> {
    (0..intervals)
        .map(|i| {
            if (burst_at..burst_at + intervals / 10).contains(&i) {
                burst
            } else {
                base
            }
        })
        .collect()
}

/// Replay `trace` (msg/s per control interval of `dt` seconds) against an
/// autoscaler built on `predictor` — [`ControlLoop`] with the USL model as
/// its [`ScalingTarget`](super::control::ScalingTarget).
pub fn replay(
    predictor: Predictor,
    config: AutoscaleConfig,
    trace: &[f64],
    dt: f64,
    initial_parallelism: usize,
) -> AutoscaleReport {
    let scaler = Autoscaler::new(predictor.clone(), config, initial_parallelism);
    let mut target = ModelTarget::new(predictor, initial_parallelism);
    ControlLoop::new(scaler, dt)
        .run(&mut target, trace)
        .expect("the model target cannot fail")
}

/// [`replay`] under an [`Objective`] with the platform's [`PriceModel`]:
/// the same model-target loop, with decisions shaped by the objective
/// and every dollar accounted.  `replay` is this with
/// `(Objective::Goodput, PriceModel::free())`.
pub fn replay_objective(
    predictor: Predictor,
    config: AutoscaleConfig,
    objective: Objective,
    price: PriceModel,
    trace: &[f64],
    dt: f64,
    initial_parallelism: usize,
) -> AutoscaleReport {
    let scaler = Autoscaler::new(predictor.clone(), config, initial_parallelism)
        .with_objective(objective, price);
    let mut target = ModelTarget::new(predictor, initial_parallelism);
    ControlLoop::new(scaler, dt)
        .run(&mut target, trace)
        .expect("the model target cannot fail")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::usl::UslParams;

    fn predictor() -> Predictor {
        // near-linear platform (the Lambda regime), λ = 10 msg/s per shard
        Predictor {
            params: UslParams::new(0.02, 0.0001, 10.0),
        }
    }

    #[test]
    fn diurnal_trace_tracks_load() {
        let trace = trace_diurnal(200, 10.0, 200.0, 1);
        let report = replay(predictor(), AutoscaleConfig::default(), &trace, 1.0, 2);
        // processes nearly everything without unbounded backlog
        assert!(report.goodput() > 0.95, "goodput {}", report.goodput());
        assert!(report.scale_events >= 2, "must scale up and back down");
        let peak_p = report.ticks.iter().map(|t| t.parallelism).max().unwrap();
        let min_p = report.ticks.iter().map(|t| t.parallelism).min().unwrap();
        assert!(peak_p >= 20, "peak parallelism {peak_p}");
        assert!(min_p <= 4, "valley parallelism {min_p}");
        // backlog stays bounded relative to per-interval load
        assert!(report.max_backlog < 400.0, "max backlog {}", report.max_backlog);
    }

    #[test]
    fn burst_is_absorbed() {
        let trace = trace_burst(100, 20.0, 150.0, 40);
        let report = replay(predictor(), AutoscaleConfig::default(), &trace, 1.0, 2);
        assert!(report.goodput() > 0.9, "goodput {}", report.goodput());
        // backlog spikes during the burst but drains afterwards
        let final_backlog = report.ticks.last().unwrap().backlog;
        assert!(final_backlog < 1.0, "backlog must drain, got {final_backlog}");
    }

    #[test]
    fn retrograde_platform_forces_throttling() {
        // Dask-like: peak ≈ 2 partitions, capacity ~6 msg/s
        let p = Predictor {
            params: UslParams::new(0.8, 0.1, 5.0),
        };
        let trace = vec![50.0; 50];
        let report = replay(p, AutoscaleConfig::default(), &trace, 1.0, 1);
        assert!(
            report.throttled_total > report.offered_total * 0.5,
            "most of a 50 msg/s load must be throttled on this platform"
        );
        // and what is admitted is actually processed (stability)
        let final_backlog = report.ticks.last().unwrap().backlog;
        assert!(final_backlog < 50.0, "admitted load stays processable");
    }

    #[test]
    fn deterministic_given_seed() {
        let t1 = trace_diurnal(50, 5.0, 50.0, 9);
        let t2 = trace_diurnal(50, 5.0, 50.0, 9);
        assert_eq!(t1, t2);
    }

    #[test]
    fn diurnal_trace_shape() {
        let (base, peak) = (10.0, 200.0);
        let trace = trace_diurnal(200, base, peak, 7);
        assert_eq!(trace.len(), 200);
        assert!(trace.iter().all(|&r| r >= 0.0));
        // the cosine phase puts the trough at the ends, the crest mid-way;
        // 5% multiplicative noise cannot move them far
        let ends = (trace[0] + trace[199]) / 2.0;
        let mid = trace[100];
        assert!(ends < base * 1.3, "trough near base: {ends}");
        assert!(mid > peak * 0.8, "crest near peak: {mid}");
        let max = trace.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max <= peak * 1.3, "noise stays bounded: {max}");
    }

    #[test]
    fn burst_trace_shape() {
        let trace = trace_burst(100, 20.0, 150.0, 40);
        assert_eq!(trace.len(), 100);
        // exactly intervals/10 burst ticks, exactly at [burst_at, burst_at+10)
        for (i, &r) in trace.iter().enumerate() {
            if (40..50).contains(&i) {
                assert_eq!(r, 150.0, "tick {i}");
            } else {
                assert_eq!(r, 20.0, "tick {i}");
            }
        }
    }

    #[test]
    fn forced_throttle_accounting_is_conservative() {
        // heavily retrograde platform: every decision is a Throttle, so
        // offered = processed + throttled + final backlog must balance
        let p = Predictor {
            params: UslParams::new(0.9, 0.1, 5.0),
        };
        let trace = vec![80.0; 40];
        let report = replay(p, AutoscaleConfig::default(), &trace, 1.0, 1);
        assert!(
            report
                .ticks
                .iter()
                .skip(3) // EWMA warm-up
                .all(|t| matches!(t.decision, ScaleDecision::Throttle { .. })),
            "an 80 msg/s load on a ~5 msg/s platform must throttle"
        );
        assert!(report.throttled_total > 0.0);
        assert!(report.goodput() < 0.2, "goodput {}", report.goodput());
        let final_backlog = report.ticks.last().unwrap().backlog;
        let accounted = report.processed_total + report.throttled_total + final_backlog;
        assert!(
            (accounted - report.offered_total).abs() < 1e-6,
            "conservation: {accounted} vs {}",
            report.offered_total
        );
        // throttled admission stays processable: backlog bounded by one
        // interval of admitted load
        assert!(report.max_backlog < 80.0, "max backlog {}", report.max_backlog);
        // model replays never commit live transitions
        assert!(report.resizes.is_empty());
    }
}
