//! Workflow modeling and control: per-stage USL fits composed into an
//! end-to-end critical-path prediction, plus cross-stage rebalancing.
//!
//! The sweep layer treats a workflow-axis scenario as a whole DAG:
//! [`measure_workflow_row`] runs the graph through
//! [`crate::workflow::run_workflow`] and reports one end-to-end
//! [`SweepRow`] plus one [`StageRow`] per stage.  [`fit_stages`] fits each
//! stage's throughput curve over the shared parallelism-budget axis, and
//! [`CriticalPathModel`] composes those fits back into an end-to-end
//! throughput prediction by replaying the DAG schedule with *modeled*
//! stage windows — the acceptance gate holds the composed prediction
//! within 10% of the simulated end-to-end throughput.
//!
//! [`WorkflowTarget`] closes the loop: a [`ScalingTarget`] whose
//! parallelism is a *budget* water-filled across stages by modeled
//! effective rate, so when a load shift moves the bottleneck the
//! allocation follows it — the cross-stage question the source paper
//! never asked.

use super::autoscale::ScaleDecision;
use super::control::ScalingTarget;
use super::experiment::{axis_value_of, AxisValue, ExperimentSpec, AXIS_WORKFLOW};
use super::predict::Predictor;
use super::sweep::{GroupKey, SweepProgress, SweepRow};
use crate::engine::StepEngine;
use crate::miniapp::{PlatformKind, Scenario, SimOptions};
use crate::pilot::workers::parallel_indexed_map;
use crate::pilot::{ResizePlan, ResizeSemantics};
use crate::usl::{fit, Obs, UslFit, UslParams};
use crate::workflow::{effective_parallelism, run_workflow, schedule, StageResult, WorkflowSpec};
use std::sync::Arc;

/// One stage's measurement at one sweep configuration — the raw material
/// for the per-stage USL fits.
#[derive(Debug, Clone, PartialEq)]
pub struct StageRow {
    pub workflow: String,
    pub stage: usize,
    pub name: String,
    pub platform: PlatformKind,
    /// The shared budget multiplier (the sweep's scale-axis level).
    pub scale: usize,
    /// Nominal stage parallelism (`base * scale`).
    pub parallelism: usize,
    pub ingested: u64,
    pub throughput: f64,
    pub window_seconds: f64,
}

/// Run the workflow a scenario stands for and collapse it into one
/// end-to-end [`SweepRow`] (grouped like any other sweep row) plus the
/// per-stage rows behind it.
///
/// The end-to-end row reports the DAG's delivered-per-makespan throughput;
/// latency-style columns are composed along the critical path (sums for
/// means/quantiles of the serial chain, ingest-weighted means for CVs) so
/// the analysis layer can fit and tabulate workflows unchanged.
pub fn measure_workflow_row<F>(
    spec: &ExperimentSpec,
    sc: &Scenario,
    engine_factory: &F,
    opts: SimOptions,
) -> Result<(SweepRow, Vec<StageRow>), String>
where
    F: Fn(&Scenario) -> Arc<dyn StepEngine>,
{
    let id = sc
        .extra_param(AXIS_WORKFLOW)
        .ok_or_else(|| format!("scenario carries no {AXIS_WORKFLOW:?} axis"))?;
    let wf = WorkflowSpec::preset_by_id(id)
        .ok_or_else(|| format!("unknown workflow preset id {id}"))?
        .with_source_messages(sc.messages)
        .with_seed(sc.seed);
    let scale = sc.partitions.max(1);
    let run = run_workflow(&wf, scale, engine_factory, opts)?;

    let key = GroupKey::new(
        spec.axes
            .iter()
            .filter(|a| a.name != spec.scale_axis)
            .map(|a| {
                let v = axis_value_of(sc, &a.name).unwrap_or(AxisValue::Int(0));
                (a.name.clone(), v)
            })
            .collect(),
    );
    let row_scale = match axis_value_of(sc, &spec.scale_axis) {
        Some(AxisValue::Int(n)) => n as usize,
        _ => sc.partitions,
    };

    // Compose latency columns over the critical path's active stages.
    let path: Vec<&StageResult> = run
        .critical_path
        .iter()
        .filter_map(|&s| run.stages.iter().find(|r| r.stage == s && r.ingested > 0))
        .collect();
    let sum = |f: fn(&StageResult) -> f64| path.iter().map(|r| f(r)).sum::<f64>();
    let path_ingest: f64 = path.iter().map(|r| r.ingested as f64).sum();
    let weighted = |f: fn(&StageResult) -> f64| {
        if path_ingest > 0.0 {
            path.iter().map(|r| f(r) * r.ingested as f64).sum::<f64>() / path_ingest
        } else {
            0.0
        }
    };

    let e2e = SweepRow {
        key,
        scale_axis: spec.scale_axis.clone(),
        scale: row_scale,
        throughput: run.throughput,
        service_mean: sum(|r| r.service_mean),
        service_p95: sum(|r| r.service_p95),
        service_cv: weighted(|r| r.service_cv),
        warm_mean: sum(|r| r.warm_mean),
        warm_cv: weighted(|r| r.warm_cv),
        broker_mean: sum(|r| r.broker_mean),
        messages: run.accounting.delivered as usize,
    };

    let stage_rows = run
        .stages
        .iter()
        .map(|r| StageRow {
            workflow: wf.name.clone(),
            stage: r.stage,
            name: r.name.clone(),
            platform: r.platform,
            scale,
            parallelism: r.parallelism,
            ingested: r.ingested,
            throughput: r.throughput,
            window_seconds: r.window_seconds,
        })
        .collect();
    Ok((e2e, stage_rows))
}

/// The [`measure_workflow_row`] entry the generic sweep dispatcher calls —
/// end-to-end row only, stage rows discarded (use
/// [`run_workflow_sweep_jobs`] to keep them).
pub fn measure_workflow_sweep_row<F>(
    spec: &ExperimentSpec,
    sc: &Scenario,
    engine_factory: &F,
    opts: SimOptions,
) -> Result<SweepRow, String>
where
    F: Fn(&Scenario) -> Arc<dyn StepEngine>,
{
    measure_workflow_row(spec, sc, engine_factory, opts).map(|(row, _)| row)
}

/// Run a workflow sweep on `jobs` workers, keeping both the end-to-end
/// rows and every per-stage row (in spec order, stages in topo order
/// within each configuration).  Mirrors
/// [`run_sweep_jobs_opts`](super::sweep::run_sweep_jobs_opts): output is
/// byte-identical for every `jobs` value.
pub fn run_workflow_sweep_jobs<F, C>(
    spec: &ExperimentSpec,
    engine_factory: F,
    jobs: usize,
    opts: SimOptions,
    mut progress: C,
) -> (Vec<SweepRow>, Vec<StageRow>)
where
    F: Fn(&Scenario) -> Arc<dyn StepEngine> + Sync,
    C: FnMut(SweepProgress<'_>),
{
    let scenarios = spec.scenarios();
    let total = scenarios.len();
    let mut slots: Vec<Option<(SweepRow, Vec<StageRow>)>> = Vec::with_capacity(total);
    slots.resize_with(total, || None);
    let mut done = 0usize;
    let scenarios_ref = &scenarios;
    let factory_ref = &engine_factory;
    parallel_indexed_map(
        jobs.max(1),
        total,
        move |_worker, i| measure_workflow_row(spec, &scenarios_ref[i], factory_ref, opts),
        |i, outcome| match outcome {
            Ok(pair) => {
                done += 1;
                progress(SweepProgress {
                    done,
                    total,
                    row: &pair.0,
                });
                slots[i] = Some(pair);
            }
            Err(e) => log::error!("workflow sweep config failed ({:?}): {e}", scenarios[i]),
        },
    );
    let mut rows = Vec::with_capacity(total);
    let mut stage_rows = Vec::new();
    for slot in slots.into_iter().flatten() {
        rows.push(slot.0);
        stage_rows.extend(slot.1);
    }
    (rows, stage_rows)
}

/// Render per-stage rows as CSV (deterministic, spec order).
pub fn stage_csv(rows: &[StageRow]) -> String {
    let mut s = String::from(
        "workflow,stage,name,platform,scale,parallelism,ingested,throughput,window_seconds\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{:.6},{:.6}\n",
            r.workflow,
            r.stage,
            r.name,
            r.platform.label(),
            r.scale,
            r.parallelism,
            r.ingested,
            r.throughput,
            r.window_seconds
        ));
    }
    s
}

/// One stage's fitted USL curve over the budget sweep.
#[derive(Debug, Clone)]
pub struct StageFit {
    pub workflow: String,
    pub stage: usize,
    pub name: String,
    pub platform: PlatformKind,
    pub fit: UslFit,
}

/// Fit each (workflow, stage) group's throughput curve over nominal
/// parallelism.  Starved configurations (zero throughput) are skipped;
/// groups with fewer than three usable observations are dropped with a
/// warning.
pub fn fit_stages(rows: &[StageRow]) -> Vec<StageFit> {
    // First-appearance group scan (no hash maps: deterministic order).
    let mut groups: Vec<(String, usize)> = Vec::new();
    for r in rows {
        if !groups.iter().any(|(w, s)| *w == r.workflow && *s == r.stage) {
            groups.push((r.workflow.clone(), r.stage));
        }
    }
    let mut fits = Vec::new();
    for (wf, stage) in groups {
        let members: Vec<&StageRow> = rows
            .iter()
            .filter(|r| r.workflow == wf && r.stage == stage)
            .collect();
        let mut obs: Vec<Obs> = members
            .iter()
            .filter(|r| r.throughput > 0.0)
            .map(|r| Obs::new(r.parallelism as f64, r.throughput))
            .collect();
        obs.sort_by(|a, b| a.n.partial_cmp(&b.n).unwrap_or(std::cmp::Ordering::Equal));
        if obs.len() < 3 {
            log::warn!("stage {wf}/{stage}: {} usable observations, skipping fit", obs.len());
            continue;
        }
        match fit(&obs) {
            Ok(f) => fits.push(StageFit {
                workflow: wf,
                stage,
                name: members[0].name.clone(),
                platform: members[0].platform,
                fit: f,
            }),
            Err(e) => log::warn!("stage {wf}/{stage}: USL fit failed: {e}"),
        }
    }
    fits
}

/// End-to-end throughput predicted by composing per-stage USL fits along
/// the DAG's critical path.
#[derive(Debug, Clone)]
pub struct WorkflowPrediction {
    pub workflow: String,
    pub scale: usize,
    /// Modeled per-stage windows (0 for starved stages).
    pub windows: Vec<f64>,
    pub critical_path: Vec<usize>,
    pub makespan: f64,
    /// Predicted end-to-end throughput: delivered / makespan.
    pub throughput: f64,
    /// The critical-path stage with the widest modeled window.
    pub bottleneck: usize,
}

/// Composes per-stage USL fits into an end-to-end model: each active
/// stage's window is `inflow / T_fit(base * scale)`, the DAG schedule is
/// replayed with those modeled windows, and the prediction is
/// delivered-per-makespan — directly comparable to the simulated
/// end-to-end throughput at any budget level.
#[derive(Debug, Clone)]
pub struct CriticalPathModel {
    spec: WorkflowSpec,
    predictors: Vec<Option<Predictor>>,
}

impl CriticalPathModel {
    /// Build from fitted stages; every stage the flow plan feeds must have
    /// a fit (starved stages may go unfitted).
    pub fn new(spec: WorkflowSpec, fits: &[StageFit]) -> Result<Self, String> {
        let plan = spec.flow_plan()?;
        let mut predictors = Vec::with_capacity(spec.stages.len());
        for (s, st) in spec.stages.iter().enumerate() {
            let fit = fits
                .iter()
                .find(|f| f.workflow == spec.name && f.stage == s)
                .map(|f| Predictor::from_fit(&f.fit));
            if fit.is_none() && plan.inflow[s] > 0 {
                return Err(format!(
                    "workflow {:?}: active stage {s} ({:?}) has no USL fit",
                    spec.name, st.name
                ));
            }
            predictors.push(fit);
        }
        Ok(Self { spec, predictors })
    }

    pub fn spec(&self) -> &WorkflowSpec {
        &self.spec
    }

    /// Predict end-to-end throughput at budget multiplier `scale`.
    pub fn predict(&self, scale: usize) -> Result<WorkflowPrediction, String> {
        let plan = self.spec.flow_plan()?;
        let n = self.spec.stages.len();
        let mut windows = vec![0.0f64; n];
        for s in 0..n {
            if plan.inflow[s] == 0 {
                continue;
            }
            let p = self.predictors[s]
                .as_ref()
                .ok_or_else(|| format!("stage {s}: no predictor"))?;
            let st = &self.spec.stages[s];
            let nominal = effective_parallelism(st.platform, st.parallelism * scale.max(1));
            let t = p.throughput(nominal);
            if t <= 0.0 {
                return Err(format!("stage {s}: modeled throughput {t} not positive"));
            }
            windows[s] = plan.inflow[s] as f64 / t;
        }
        let (_, _, critical_path, makespan) = schedule(&self.spec, &plan, &windows);
        if makespan <= 0.0 {
            return Err(format!("workflow {:?}: modeled makespan is zero", self.spec.name));
        }
        let throughput = plan.delivered(&self.spec) as f64 / makespan;
        let bottleneck = critical_path
            .iter()
            .copied()
            .max_by(|&a, &b| {
                windows[a]
                    .partial_cmp(&windows[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(b.cmp(&a))
            })
            .unwrap_or(0);
        Ok(WorkflowPrediction {
            workflow: self.spec.name.clone(),
            scale: scale.max(1),
            windows,
            critical_path,
            makespan,
            throughput,
            bottleneck,
        })
    }
}

/// How [`WorkflowTarget`] splits its budget across stages.
#[derive(Debug, Clone, PartialEq)]
pub enum RebalancePolicy {
    /// Water-fill by modeled effective rate: the slowest stage gets the
    /// next worker, so the allocation tracks the bottleneck as it moves.
    Adaptive,
    /// Fixed per-stage weights (largest-remainder split, min 1 per active
    /// stage) — the baseline the adaptive policy must beat.
    Static(Vec<f64>),
}

/// A deterministic bottleneck-shifting load: per-stage demand multipliers
/// cycled phase by phase on the sim clock.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadShift {
    pub ticks_per_phase: usize,
    /// One multiplier vector per phase (len = stage count).
    pub phases: Vec<Vec<f64>>,
}

/// One allocation change, recorded when the controller's budget or the
/// load phase moves the bottleneck.
#[derive(Debug, Clone, PartialEq)]
pub struct RebalanceEvent {
    pub tick: usize,
    /// The allocation in effect *after* the event.
    pub alloc: Vec<usize>,
    /// The stage the policy was feeding (slowest modeled effective rate).
    pub bottleneck: usize,
}

/// A [`ScalingTarget`] over a whole workflow: its "parallelism" is a
/// worker *budget* split across stages, re-balanced on every actuation by
/// modeled per-stage effective rate.  End-to-end capacity is the min over
/// stages of `T_fit(alloc) / (relative load * phase multiplier)` — the
/// pipeline drains only as fast as its slowest stage.
#[derive(Debug, Clone)]
pub struct WorkflowTarget {
    name: String,
    predictors: Vec<Predictor>,
    /// Per-stage relative load: stage inflow per delivered message.
    load: Vec<f64>,
    /// Stages with nonzero load, in index order.
    active: Vec<usize>,
    alloc: Vec<usize>,
    policy: RebalancePolicy,
    shift: Option<LoadShift>,
    tick: usize,
    rebalances: Vec<RebalanceEvent>,
}

impl WorkflowTarget {
    pub fn new(
        name: impl Into<String>,
        predictors: Vec<Predictor>,
        load: Vec<f64>,
        initial_budget: usize,
        policy: RebalancePolicy,
    ) -> Result<Self, String> {
        if predictors.len() != load.len() {
            return Err(format!(
                "predictors ({}) and load ({}) must cover the same stages",
                predictors.len(),
                load.len()
            ));
        }
        let active: Vec<usize> = (0..load.len()).filter(|&s| load[s] > 0.0).collect();
        if active.is_empty() {
            return Err("workflow target: no stage carries load".to_string());
        }
        if let RebalancePolicy::Static(w) = &policy {
            if w.len() != load.len() {
                return Err(format!(
                    "static weights ({}) must cover all {} stages",
                    w.len(),
                    load.len()
                ));
            }
        }
        let mut target = Self {
            name: name.into(),
            predictors,
            load,
            active,
            alloc: Vec::new(),
            policy,
            shift: None,
            tick: 0,
            rebalances: Vec::new(),
        };
        target.alloc = target.target_alloc(initial_budget.max(1));
        Ok(target)
    }

    /// Build from a fitted workflow: relative loads from the flow plan,
    /// one predictor per stage (placeholder for starved stages).
    pub fn for_workflow(
        spec: &WorkflowSpec,
        fits: &[StageFit],
        initial_budget: usize,
        policy: RebalancePolicy,
    ) -> Result<Self, String> {
        let plan = spec.flow_plan()?;
        let delivered = plan.delivered(spec);
        if delivered == 0 {
            return Err(format!("workflow {:?}: nothing delivered", spec.name));
        }
        let mut predictors = Vec::with_capacity(spec.stages.len());
        let mut load = Vec::with_capacity(spec.stages.len());
        for s in 0..spec.stages.len() {
            load.push(plan.inflow[s] as f64 / delivered as f64);
            let p = fits
                .iter()
                .find(|f| f.workflow == spec.name && f.stage == s)
                .map(|f| Predictor::from_fit(&f.fit));
            match p {
                Some(p) => predictors.push(p),
                None if plan.inflow[s] > 0 => {
                    return Err(format!(
                        "workflow {:?}: active stage {s} has no USL fit",
                        spec.name
                    ))
                }
                None => predictors.push(Predictor {
                    params: UslParams::new(0.0, 0.0, 1.0),
                }),
            }
        }
        Self::new(spec.name.clone(), predictors, load, initial_budget, policy)
    }

    /// Attach a deterministic bottleneck-shifting load schedule.
    pub fn with_shift(mut self, shift: LoadShift) -> Self {
        self.shift = Some(shift);
        self
    }

    pub fn alloc(&self) -> &[usize] {
        &self.alloc
    }

    pub fn rebalances(&self) -> &[RebalanceEvent] {
        &self.rebalances
    }

    fn phase_multipliers(&self) -> Vec<f64> {
        match &self.shift {
            Some(shift) if !shift.phases.is_empty() => {
                let phase = (self.tick / shift.ticks_per_phase.max(1)) % shift.phases.len();
                shift.phases[phase].clone()
            }
            _ => vec![1.0; self.load.len()],
        }
    }

    /// Modeled end-to-end messages/s stage `s` sustains at `n` workers
    /// under the current load phase.
    fn effective_rate(&self, s: usize, n: usize, mults: &[f64]) -> f64 {
        let demand = self.load[s] * mults.get(s).copied().unwrap_or(1.0);
        if demand <= 0.0 {
            return f64::INFINITY;
        }
        self.predictors[s].throughput(n.max(1)) / demand
    }

    /// The stage with the smallest modeled effective rate (first wins
    /// ties) — where the next worker goes, and what a rebalance reports.
    fn bottleneck_stage(&self, alloc: &[usize], mults: &[f64]) -> usize {
        let mut best = self.active[0];
        let mut best_rate = self.effective_rate(best, alloc[best], mults);
        for &s in &self.active[1..] {
            let rate = self.effective_rate(s, alloc[s], mults);
            if rate < best_rate {
                best = s;
                best_rate = rate;
            }
        }
        best
    }

    /// Split `budget` workers across active stages under the current
    /// policy and load phase.
    fn target_alloc(&self, budget: usize) -> Vec<usize> {
        let n = self.load.len();
        let budget = budget.max(self.active.len());
        let mut alloc = vec![0usize; n];
        match &self.policy {
            RebalancePolicy::Adaptive => {
                let mults = self.phase_multipliers();
                for &s in &self.active {
                    alloc[s] = 1;
                }
                let mut spare = budget - self.active.len();
                while spare > 0 {
                    let slow = self.bottleneck_stage(&alloc, &mults);
                    alloc[slow] += 1;
                    spare -= 1;
                }
            }
            RebalancePolicy::Static(weights) => {
                // Largest-remainder proportional split, min 1 per stage.
                let spare = budget - self.active.len();
                let total: f64 = self.active.iter().map(|&s| weights[s].max(0.0)).sum();
                let mut shares: Vec<(usize, f64)> = Vec::with_capacity(self.active.len());
                for &s in &self.active {
                    let w = if total > 0.0 {
                        weights[s].max(0.0) / total
                    } else {
                        0.0
                    };
                    let exact = w * spare as f64;
                    alloc[s] = 1 + exact.floor() as usize;
                    shares.push((s, exact - exact.floor()));
                }
                let mut assigned: usize = self.active.iter().map(|&s| alloc[s] - 1).sum();
                while assigned < spare {
                    let (winner, _) = shares
                        .iter()
                        .copied()
                        .max_by(|a, b| {
                            a.1.partial_cmp(&b.1)
                                .unwrap_or(std::cmp::Ordering::Equal)
                                .then(b.0.cmp(&a.0))
                        })
                        .unwrap_or((self.active[0], 0.0));
                    alloc[winner] += 1;
                    if let Some(slot) = shares.iter_mut().find(|(s, _)| *s == winner) {
                        slot.1 = -1.0;
                    }
                    assigned += 1;
                }
            }
        }
        alloc
    }
}

impl ScalingTarget for WorkflowTarget {
    fn label(&self) -> String {
        format!("workflow:{}", self.name)
    }

    fn parallelism(&self) -> usize {
        self.alloc.iter().sum()
    }

    fn actuate(&mut self, decision: &ScaleDecision) -> Result<Option<ResizePlan>, String> {
        let budget = match *decision {
            ScaleDecision::Hold { parallelism } => parallelism,
            ScaleDecision::Scale { to, .. } => to,
            ScaleDecision::Throttle { parallelism, .. } => parallelism,
        }
        .max(1);
        let next = self.target_alloc(budget);
        if next == self.alloc {
            return Ok(None);
        }
        let from: usize = self.alloc.iter().sum();
        let to: usize = next.iter().sum();
        self.alloc = next;
        let mults = self.phase_multipliers();
        self.rebalances.push(RebalanceEvent {
            tick: self.tick,
            alloc: self.alloc.clone(),
            bottleneck: self.bottleneck_stage(&self.alloc, &mults),
        });
        if from == to {
            // Pure rebalance: workers moved between stages, total intact.
            return Ok(None);
        }
        Ok(Some(ResizePlan {
            from,
            to,
            transition_s: 0.0,
            semantics: ResizeSemantics::Repartition,
        }))
    }

    fn serve(&mut self, demand: f64, dt: f64) -> Result<f64, String> {
        let served = demand.min(self.capacity() * dt.max(0.0));
        self.tick += 1;
        Ok(served)
    }

    fn capacity(&self) -> f64 {
        let mults = self.phase_multipliers();
        let mut cap = f64::INFINITY;
        for &s in &self.active {
            cap = cap.min(self.effective_rate(s, self.alloc[s], &mults));
        }
        if cap.is_finite() { cap } else { 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::usl::UslFit;

    fn predictor(sigma: f64, lambda: f64) -> Predictor {
        Predictor {
            params: UslParams::new(sigma, 0.0, lambda),
        }
    }

    fn two_stage_target(policy: RebalancePolicy) -> WorkflowTarget {
        WorkflowTarget::new(
            "pair",
            vec![predictor(0.02, 10.0), predictor(0.02, 10.0)],
            vec![1.0, 1.0],
            8,
            policy,
        )
        .expect("valid target")
    }

    #[test]
    fn static_split_is_proportional_with_min_one() {
        let t = two_stage_target(RebalancePolicy::Static(vec![1.0, 3.0]));
        assert_eq!(t.alloc(), &[3, 5]);
        let even = two_stage_target(RebalancePolicy::Static(vec![1.0, 1.0]));
        assert_eq!(even.alloc(), &[4, 4]);
    }

    #[test]
    fn adaptive_waterfill_follows_the_loaded_stage() {
        let shift = LoadShift {
            ticks_per_phase: 10,
            phases: vec![vec![2.0, 0.5], vec![0.5, 2.0]],
        };
        let mut t = two_stage_target(RebalancePolicy::Adaptive).with_shift(shift);
        t.actuate(&ScaleDecision::Hold { parallelism: 8 }).unwrap();
        assert_eq!(t.alloc(), &[6, 2], "phase A loads stage 0");
        for _ in 0..10 {
            t.serve(60.0, 1.0).unwrap();
        }
        t.actuate(&ScaleDecision::Hold { parallelism: 8 }).unwrap();
        assert_eq!(t.alloc(), &[2, 6], "phase B moves the bottleneck");
        let last = t.rebalances().last().unwrap();
        assert_eq!(last.bottleneck, 1, "rebalance reports the fed stage");
    }

    #[test]
    fn adaptive_beats_every_static_split_under_shifting_load() {
        let shift = LoadShift {
            ticks_per_phase: 10,
            phases: vec![vec![2.0, 0.5], vec![0.5, 2.0]],
        };
        let ticks = 40;
        let run = |mut t: WorkflowTarget, adapt: bool| -> f64 {
            let mut served = 0.0;
            for _ in 0..ticks {
                if adapt {
                    t.actuate(&ScaleDecision::Hold { parallelism: 8 }).unwrap();
                }
                served += t.serve(60.0, 1.0).unwrap();
            }
            served
        };
        let adaptive = run(
            two_stage_target(RebalancePolicy::Adaptive).with_shift(shift.clone()),
            true,
        );
        let mut best_static = 0.0f64;
        for a in 1..8usize {
            let t = two_stage_target(RebalancePolicy::Static(vec![a as f64, (8 - a) as f64]))
                .with_shift(shift.clone());
            best_static = best_static.max(run(t, false));
        }
        assert!(
            adaptive > best_static * 1.1,
            "adaptive {adaptive:.1} must beat best static {best_static:.1} by >10%"
        );
    }

    #[test]
    fn rebalancing_is_deterministic() {
        let mk = || {
            let shift = LoadShift {
                ticks_per_phase: 5,
                phases: vec![vec![3.0, 1.0], vec![1.0, 3.0]],
            };
            let mut t = two_stage_target(RebalancePolicy::Adaptive).with_shift(shift);
            let mut trace = Vec::new();
            for _ in 0..20 {
                t.actuate(&ScaleDecision::Hold { parallelism: 8 }).unwrap();
                trace.push((t.alloc().to_vec(), t.serve(60.0, 1.0).unwrap().to_bits()));
            }
            (trace, t.rebalances().to_vec())
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn critical_path_model_recovers_exact_stage_curves() {
        use crate::workflow::{EdgeSpec, StageSpec};
        let mut spec = WorkflowSpec::new("pair");
        let a = spec.stage(StageSpec::new("ingest", PlatformKind::Lambda, 1));
        let b = spec.stage(StageSpec::new("train", PlatformKind::DaskWrangler, 1));
        spec.edge(EdgeSpec::new(a, b));
        let spec = spec.with_source_messages(64);
        let params = [UslParams::new(0.05, 0.0, 8.0), UslParams::new(0.01, 0.0, 2.0)];
        let fits: Vec<StageFit> = params
            .iter()
            .enumerate()
            .map(|(s, p)| StageFit {
                workflow: "pair".to_string(),
                stage: s,
                name: spec.stages[s].name.clone(),
                platform: spec.stages[s].platform,
                fit: UslFit {
                    params: *p,
                    r2: 1.0,
                    rmse: 0.0,
                    method: "exact",
                },
            })
            .collect();
        let model = CriticalPathModel::new(spec, &fits).unwrap();
        for scale in [1usize, 2, 4] {
            let pred = model.predict(scale).unwrap();
            // Chain of two stages: makespan is the sum of both windows.
            let expect = 64.0 / (64.0 / params[0].throughput(scale as f64)
                + 64.0 / params[1].throughput(scale as f64));
            assert!(
                (pred.throughput - expect).abs() < 1e-9,
                "scale {scale}: {} vs {expect}",
                pred.throughput
            );
            assert_eq!(pred.bottleneck, 1, "slower stage is the bottleneck");
        }
    }
}
