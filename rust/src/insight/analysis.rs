//! Analysis: fit USL per sweep group and build the Fig 6-style report
//! (σ, κ, λ, R², peak N per scenario).

use super::sweep::{group_keys, group_observations, SweepRow};
use crate::miniapp::PlatformKind;
use crate::usl::{fit, UslFit};
use crate::util::json::Json;

/// One analyzed scenario group.
#[derive(Debug, Clone)]
pub struct AnalysisRow {
    pub platform: PlatformKind,
    pub message_size: usize,
    pub centroids: usize,
    pub memory_mb: u32,
    pub fit: UslFit,
    pub observations: usize,
}

impl AnalysisRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("platform", Json::from(self.platform.label())),
            ("message_size", Json::from(self.message_size)),
            ("centroids", Json::from(self.centroids)),
            ("memory_mb", Json::from(self.memory_mb as usize)),
            ("sigma", Json::from(self.fit.params.sigma)),
            ("kappa", Json::from(self.fit.params.kappa)),
            ("lambda", Json::from(self.fit.params.lambda)),
            ("r2", Json::from(self.fit.r2)),
            ("rmse", Json::from(self.fit.rmse)),
            (
                "peak_n",
                self.fit
                    .params
                    .peak_n()
                    .map(Json::from)
                    .unwrap_or(Json::Null),
            ),
            ("regime", Json::from(self.fit.params.regime())),
        ])
    }
}

/// Fit USL for every group in the sweep.
pub fn analyze(rows: &[SweepRow]) -> Vec<AnalysisRow> {
    let mut out = Vec::new();
    for key in group_keys(rows) {
        let obs = group_observations(rows, key);
        match fit(&obs) {
            Ok(f) => out.push(AnalysisRow {
                platform: key.0,
                message_size: key.1,
                centroids: key.2,
                memory_mb: key.3,
                fit: f,
                observations: obs.len(),
            }),
            Err(e) => log::warn!("USL fit failed for {key:?}: {e}"),
        }
    }
    out
}

/// Render the analysis as a fixed-width text table (Fig 6's numbers).
pub fn table(rows: &[AnalysisRow]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<22} {:>7} {:>6} {:>8} {:>8} {:>9} {:>6} {:>7}  {}\n",
        "platform", "MS", "WC", "sigma", "kappa", "lambda", "R2", "peakN", "regime"
    ));
    s.push_str(&"-".repeat(100));
    s.push('\n');
    for r in rows {
        s.push_str(&format!(
            "{:<22} {:>7} {:>6} {:>8.4} {:>8.5} {:>9.2} {:>6.3} {:>7}  {}\n",
            r.platform.label(),
            r.message_size,
            r.centroids,
            r.fit.params.sigma,
            r.fit.params.kappa,
            r.fit.params.lambda,
            r.fit.r2,
            r.fit
                .params
                .peak_n()
                .map(|n| format!("{n:.1}"))
                .unwrap_or_else(|| "-".into()),
            r.fit.params.regime()
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::usl::UslParams;

    fn synth_rows(platform: PlatformKind, params: UslParams) -> Vec<SweepRow> {
        [1, 2, 4, 8, 16]
            .iter()
            .map(|&p| SweepRow {
                platform,
                partitions: p,
                message_size: 16_000,
                centroids: 1_024,
                memory_mb: 3_008,
                throughput: params.throughput(p as f64),
                service_mean: 0.1,
                service_p95: 0.12,
                service_cv: 0.05,
                warm_mean: 0.1,
                warm_cv: 0.04,
                broker_mean: 0.01,
                messages: 64,
            })
            .collect()
    }

    #[test]
    fn analyze_recovers_generating_params() {
        let truth = UslParams::new(0.6, 0.03, 9.0);
        let rows = synth_rows(PlatformKind::DaskWrangler, truth);
        let analysis = analyze(&rows);
        assert_eq!(analysis.len(), 1);
        let f = &analysis[0].fit;
        assert!((f.params.sigma - 0.6).abs() < 0.05, "{:?}", f.params);
        assert!((f.params.kappa - 0.03).abs() < 0.01, "{:?}", f.params);
        assert!(f.r2 > 0.999);
    }

    #[test]
    fn table_renders() {
        let rows = synth_rows(PlatformKind::Lambda, UslParams::new(0.01, 0.0001, 5.0));
        let analysis = analyze(&rows);
        let t = table(&analysis);
        assert!(t.contains("kinesis/lambda"));
        assert!(t.contains("sigma"));
    }

    #[test]
    fn json_export() {
        let rows = synth_rows(PlatformKind::Lambda, UslParams::new(0.1, 0.001, 5.0));
        let j = analyze(&rows)[0].to_json();
        assert!(j.get("sigma").as_f64().unwrap() > 0.0);
        assert_eq!(j.get("platform").as_str(), Some("kinesis/lambda"));
    }
}
