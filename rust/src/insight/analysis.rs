//! Analysis: fit USL per sweep group and build the Fig 6-style report
//! (σ, κ, λ, R², peak N per scenario).
//!
//! Groups are identified by [`GroupKey`] — derived from the spec's axes —
//! so new sweep dimensions flow through fitting, tables, and JSON export
//! without any changes here.  [`IncrementalAnalysis`] produces the same
//! fits *while* a parallel sweep is still running: feed it rows as they
//! complete and each group's fit pops out the moment its last scale level
//! lands.

use super::experiment::ExperimentSpec;
use super::sweep::{group_keys, group_observations, GroupKey, SweepRow};
use crate::miniapp::PlatformKind;
use crate::usl::{fit, Obs, UslFit};
use crate::util::json::Json;

/// One analyzed scenario group.
#[derive(Debug, Clone)]
pub struct AnalysisRow {
    pub key: GroupKey,
    pub fit: UslFit,
    pub observations: usize,
}

impl AnalysisRow {
    pub fn platform(&self) -> Option<PlatformKind> {
        self.key.platform()
    }

    /// This group's level on a named axis.
    pub fn axis_int(&self, name: &str) -> Option<u64> {
        self.key.int(name)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = self
            .key
            .pairs()
            .iter()
            .map(|(n, v)| (n.as_str(), v.to_json()))
            .collect();
        pairs.push(("sigma", Json::from(self.fit.params.sigma)));
        pairs.push(("kappa", Json::from(self.fit.params.kappa)));
        pairs.push(("lambda", Json::from(self.fit.params.lambda)));
        pairs.push(("r2", Json::from(self.fit.r2)));
        pairs.push(("rmse", Json::from(self.fit.rmse)));
        pairs.push((
            "peak_n",
            self.fit
                .params
                .peak_n()
                .map(Json::from)
                .unwrap_or(Json::Null),
        ));
        pairs.push(("regime", Json::from(self.fit.params.regime())));
        Json::obj(pairs)
    }
}

fn fit_group(key: GroupKey, obs: &[Obs]) -> Option<AnalysisRow> {
    match fit(obs) {
        Ok(f) => Some(AnalysisRow {
            key,
            fit: f,
            observations: obs.len(),
        }),
        Err(e) => {
            log::warn!("USL fit failed for {}: {e}", key.label());
            None
        }
    }
}

/// Fit USL for every group in the sweep.
pub fn analyze(rows: &[SweepRow]) -> Vec<AnalysisRow> {
    let mut out = Vec::new();
    for key in group_keys(rows) {
        let obs = group_observations(rows, &key);
        if let Some(row) = fit_group(key, &obs) {
            out.push(row);
        }
    }
    out
}

/// Render the analysis as a fixed-width text table (Fig 6's numbers).
pub fn table(rows: &[AnalysisRow]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<56} {:>8} {:>8} {:>9} {:>6} {:>7}  {}\n",
        "group", "sigma", "kappa", "lambda", "R2", "peakN", "regime"
    ));
    s.push_str(&"-".repeat(108));
    s.push('\n');
    for r in rows {
        s.push_str(&format!(
            "{:<56} {:>8.4} {:>8.5} {:>9.2} {:>6.3} {:>7}  {}\n",
            r.key.label(),
            r.fit.params.sigma,
            r.fit.params.kappa,
            r.fit.params.lambda,
            r.fit.r2,
            r.fit
                .params
                .peak_n()
                .map(|n| format!("{n:.1}"))
                .unwrap_or_else(|| "-".into()),
            r.fit.params.regime()
        ));
    }
    s
}

/// Streaming USL fitting for in-flight sweeps: rows arrive in completion
/// order (any worker, any order); a group's fit is returned the moment
/// all of its scale levels have been observed.
pub struct IncrementalAnalysis {
    expected: usize,
    partial: Vec<(GroupKey, Vec<Obs>)>,
}

impl IncrementalAnalysis {
    pub fn new(spec: &ExperimentSpec) -> Self {
        Self {
            expected: spec.scale_levels().max(1),
            partial: Vec::new(),
        }
    }

    /// Feed one completed row; returns the group's fit when this row was
    /// its final observation.
    pub fn observe(&mut self, row: &SweepRow) -> Option<AnalysisRow> {
        let idx = match self.partial.iter().position(|(k, _)| *k == row.key) {
            Some(i) => i,
            None => {
                self.partial.push((row.key.clone(), Vec::new()));
                self.partial.len() - 1
            }
        };
        let entry = &mut self.partial[idx].1;
        entry.push(Obs::new(row.scale as f64, row.throughput));
        if entry.len() == self.expected {
            let mut obs = entry.clone();
            obs.sort_by(|a, b| a.n.partial_cmp(&b.n).unwrap());
            return fit_group(row.key.clone(), &obs);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::insight::sweep::paper_key;
    use crate::usl::UslParams;

    fn synth_rows(platform: PlatformKind, params: UslParams) -> Vec<SweepRow> {
        [1, 2, 4, 8, 16]
            .iter()
            .map(|&p| SweepRow {
                key: paper_key(platform, 16_000, 1_024, 3_008),
                scale_axis: "partitions".to_string(),
                scale: p,
                throughput: params.throughput(p as f64),
                service_mean: 0.1,
                service_p95: 0.12,
                service_cv: 0.05,
                warm_mean: 0.1,
                warm_cv: 0.04,
                broker_mean: 0.01,
                messages: 64,
            })
            .collect()
    }

    #[test]
    fn analyze_recovers_generating_params() {
        let truth = UslParams::new(0.6, 0.03, 9.0);
        let rows = synth_rows(PlatformKind::DaskWrangler, truth);
        let analysis = analyze(&rows);
        assert_eq!(analysis.len(), 1);
        let f = &analysis[0].fit;
        assert!((f.params.sigma - 0.6).abs() < 0.05, "{:?}", f.params);
        assert!((f.params.kappa - 0.03).abs() < 0.01, "{:?}", f.params);
        assert!(f.r2 > 0.999);
    }

    #[test]
    fn table_renders() {
        let rows = synth_rows(PlatformKind::Lambda, UslParams::new(0.01, 0.0001, 5.0));
        let analysis = analyze(&rows);
        let t = table(&analysis);
        assert!(t.contains("kinesis/lambda"));
        assert!(t.contains("sigma"));
    }

    #[test]
    fn json_export() {
        let rows = synth_rows(PlatformKind::Lambda, UslParams::new(0.1, 0.001, 5.0));
        let j = analyze(&rows)[0].to_json();
        assert!(j.get("sigma").as_f64().unwrap() > 0.0);
        // axis pairs are exported generically, one field per axis
        assert_eq!(j.get("platform").as_str(), Some("kinesis/lambda"));
        assert_eq!(j.get("centroids").as_usize(), Some(1_024));
        assert_eq!(j.get("memory_mb").as_usize(), Some(3_008));
    }

    #[test]
    fn incremental_fit_completes_exactly_once_per_group() {
        let mut spec = ExperimentSpec::paper_grid(8, 3);
        spec.set_ints("partitions", [1, 2, 4, 8, 16]);
        let mut inc = IncrementalAnalysis::new(&spec);
        let rows = synth_rows(PlatformKind::DaskWrangler, UslParams::new(0.6, 0.03, 9.0));
        // out-of-completion-order arrival, as a parallel sweep produces
        let mut fits = Vec::new();
        for r in rows.iter().rev() {
            if let Some(a) = inc.observe(r) {
                fits.push(a);
            }
        }
        assert_eq!(fits.len(), 1, "one fit, on the group's final row");
        assert!((fits[0].fit.params.sigma - 0.6).abs() < 0.05);
        assert_eq!(fits[0].observations, 5);
    }
}
