//! Config-file-driven experiments: load an [`ExperimentSpec`] from a
//! TOML(-subset) file, so characterization campaigns are declarative —
//! `pilot-streaming sweep --config experiments/paper.toml`.
//!
//! ```toml
//! name = "paper-grid"
//! platforms = ["lambda", "dask"]
//! partitions = [1, 2, 4, 8, 16]
//! message_sizes = [8000, 16000, 26000]
//! centroids = [128, 1024, 8192]
//! messages = 64
//! seed = 42
//!
//! [lustre]
//! alpha = 0.9
//! beta = 0.05
//!
//! # extension axes compose declaratively, too: each entry becomes a
//! # custom sweep dimension bound into Scenario::extra by name
//! [axes]
//! edge_sites = [1, 2, 4]
//! ```

use super::experiment::{
    Axis, ExperimentSpec, AXIS_CENTROIDS, AXIS_MEMORY_MB, AXIS_MESSAGE_SIZE, AXIS_PARTITIONS,
    AXIS_WORKFLOW,
};
use crate::miniapp::PlatformKind;
use crate::sim::ContentionParams;
use crate::util::json::Json;
use crate::util::tomlmini;

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("cannot read {0}: {1}")]
    Io(String, std::io::Error),
    #[error("toml parse: {0}")]
    Toml(#[from] tomlmini::TomlError),
    #[error("invalid config: {0}")]
    Invalid(String),
}

fn usize_list(v: &Json, key: &str) -> Result<Option<Vec<usize>>, ConfigError> {
    match v.get(key) {
        Json::Null => Ok(None),
        Json::Arr(items) => items
            .iter()
            .map(|x| {
                x.as_usize()
                    .ok_or_else(|| ConfigError::Invalid(format!("{key}: non-integer entry")))
            })
            .collect::<Result<Vec<_>, _>>()
            .map(Some),
        _ => Err(ConfigError::Invalid(format!("{key}: expected an array"))),
    }
}

/// Parse an ExperimentSpec from TOML text. Unspecified fields keep the
/// paper-grid defaults; `[axes]` entries append custom sweep dimensions.
pub fn spec_from_toml(text: &str) -> Result<ExperimentSpec, ConfigError> {
    let v = tomlmini::parse(text)?;
    let mut spec = ExperimentSpec::paper_grid(64, 42);
    if let Some(name) = v.get("name").as_str() {
        spec.name = name.to_string();
    }
    if let Json::Arr(platforms) = v.get("platforms") {
        let mut parsed = Vec::new();
        for p in platforms {
            let s = p
                .as_str()
                .ok_or_else(|| ConfigError::Invalid("platforms: expected strings".into()))?;
            parsed.push(
                PlatformKind::parse(s)
                    .ok_or_else(|| ConfigError::Invalid(format!("unknown platform {s:?}")))?,
            );
        }
        if parsed.is_empty() {
            return Err(ConfigError::Invalid("platforms: empty".into()));
        }
        spec.set_platforms(&parsed);
    }
    // plural TOML keys map onto the canonical singular axis names
    for (key, axis) in [
        ("partitions", AXIS_PARTITIONS),
        ("message_sizes", AXIS_MESSAGE_SIZE),
        ("centroids", AXIS_CENTROIDS),
        ("memory_mb", AXIS_MEMORY_MB),
    ] {
        if let Some(xs) = usize_list(&v, key)? {
            spec.set_ints(axis, xs.into_iter().map(|x| x as u64));
        }
    }
    if let Some(m) = v.get("messages").as_usize() {
        spec.messages = m;
    }
    if let Some(s) = v.get("seed").as_i64() {
        spec.seed = s as u64;
    }
    let lustre = v.get("lustre");
    if lustre.as_obj().is_some() {
        let alpha = lustre.get("alpha").as_f64().unwrap_or(0.9);
        let beta = lustre.get("beta").as_f64().unwrap_or(0.05);
        if alpha < 0.0 || beta < 0.0 {
            return Err(ConfigError::Invalid("lustre: negative coefficient".into()));
        }
        spec.lustre = ContentionParams::new(alpha, beta);
    }
    let axes = v.get("axes");
    if let Some(table) = axes.as_obj() {
        for name in table.keys() {
            let xs = usize_list(axes, name)?
                .ok_or_else(|| ConfigError::Invalid(format!("axes.{name}: expected an array")))?;
            spec.set_axis(Axis::ints(name.as_str(), xs.into_iter().map(|x| x as u64)));
        }
    }
    if let Json::Arr(workflows) = v.get("workflows") {
        let mut ids = Vec::new();
        for w in workflows {
            let s = w
                .as_str()
                .ok_or_else(|| ConfigError::Invalid("workflows: expected strings".into()))?;
            ids.push(
                crate::workflow::WorkflowSpec::preset_id(s)
                    .ok_or_else(|| ConfigError::Invalid(format!("unknown workflow {s:?}")))?,
            );
        }
        if ids.is_empty() {
            return Err(ConfigError::Invalid("workflows: empty".into()));
        }
        // A workflow campaign sweeps whole DAGs over a shared budget: the
        // single-stage axes don't apply, so the grid is rebuilt as
        // workflow x partitions (partitions = the budget multiplier).
        let scales = usize_list(&v, "partitions")?.unwrap_or_else(|| vec![1, 2, 4, 8]);
        if scales.is_empty() {
            return Err(ConfigError::Invalid("partitions: empty".into()));
        }
        spec.axes.clear();
        spec.set_ints(AXIS_WORKFLOW, ids);
        spec.set_ints(AXIS_PARTITIONS, scales.into_iter().map(|x| x as u64));
    }
    if spec.messages == 0 {
        return Err(ConfigError::Invalid("messages must be non-zero".into()));
    }
    for axis in &spec.axes {
        if axis.levels.is_empty() {
            return Err(ConfigError::Invalid(format!(
                "axis {:?}: no levels",
                axis.name
            )));
        }
    }
    if spec.axis(&spec.scale_axis).is_none() {
        return Err(ConfigError::Invalid(format!(
            "missing scale axis {:?}",
            spec.scale_axis
        )));
    }
    Ok(spec)
}

/// Load a spec from a TOML file.
pub fn spec_from_file(path: &str) -> Result<ExperimentSpec, ConfigError> {
    let text =
        std::fs::read_to_string(path).map_err(|e| ConfigError::Io(path.to_string(), e))?;
    spec_from_toml(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_parses() {
        let spec = spec_from_toml(
            r#"
name = "custom"
platforms = ["lambda", "stampede2"]
partitions = [1, 2, 4]
message_sizes = [8_000]
centroids = [128, 1024]
messages = 32
seed = 7

[lustre]
alpha = 1.2
beta = 0.1
"#,
        )
        .unwrap();
        assert_eq!(spec.name, "custom");
        let platform_levels = &spec.axis("platform").unwrap().levels;
        assert_eq!(platform_levels.len(), 2);
        assert_eq!(
            platform_levels[1].as_platform(),
            Some(PlatformKind::DaskStampede2)
        );
        assert_eq!(
            spec.axis(AXIS_PARTITIONS).unwrap().levels.len(),
            3
        );
        assert_eq!(spec.axis(AXIS_CENTROIDS).unwrap().levels.len(), 2);
        assert_eq!(spec.messages, 32);
        assert_eq!(spec.seed, 7);
        assert!((spec.lustre.alpha - 1.2).abs() < 1e-12);
        assert_eq!(spec.size(), 12); // 2 platforms x 1 MS x 2 WC x 1 mem x 3 P
    }

    #[test]
    fn edge_platform_parses_in_configs() {
        // the edge scenario axis is reachable declaratively, too
        let spec = spec_from_toml("platforms = [\"edge\", \"lambda\"]\n").unwrap();
        let levels = &spec.axis("platform").unwrap().levels;
        assert_eq!(levels[0].as_platform(), Some(PlatformKind::Edge));
        assert_eq!(levels[1].as_platform(), Some(PlatformKind::Lambda));
    }

    #[test]
    fn custom_axes_compose_declaratively() {
        let spec = spec_from_toml(
            "messages = 8\n\n[axes]\nedge_sites = [1, 2, 4]\n",
        )
        .unwrap();
        let axis = spec.axis("edge_sites").unwrap();
        assert_eq!(axis.levels.len(), 3);
        assert_eq!(spec.size(), 90 * 3);
        assert!(spec
            .scenarios()
            .iter()
            .all(|sc| sc.extra_param("edge_sites").is_some()));
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let spec = spec_from_toml("messages = 16\n").unwrap();
        assert_eq!(spec.messages, 16);
        assert_eq!(spec.axis("platform").unwrap().levels.len(), 2); // paper grid default
        let ms = spec.axis(AXIS_MESSAGE_SIZE).unwrap();
        assert_eq!(ms.levels.len(), 3);
    }

    #[test]
    fn bad_configs_rejected() {
        assert!(spec_from_toml("platforms = [\"heron\"]\n").is_err());
        assert!(spec_from_toml("partitions = [\"x\"]\n").is_err());
        assert!(spec_from_toml("partitions = []\n").is_err());
        assert!(spec_from_toml("[lustre]\nalpha = -1\n").is_err());
        assert!(spec_from_toml("[axes]\nedge_sites = []\n").is_err());
    }

    #[test]
    fn registered_plugins_parse_in_configs_with_no_config_changes() {
        // the unified-naming payoff, declaratively: the flink plugin
        // registered itself and is immediately sweepable from TOML
        let spec = spec_from_toml("platforms = [\"flink\", \"lambda\"]\n").unwrap();
        let levels = &spec.axis("platform").unwrap().levels;
        assert_eq!(
            levels[0].as_platform(),
            Some(PlatformKind::Plugin(crate::pilot::Platform::FLINK))
        );
        assert_eq!(levels[1].as_platform(), Some(PlatformKind::Lambda));
    }

    #[test]
    fn workflow_campaigns_parse_declaratively() {
        let spec = spec_from_toml(
            "messages = 16\nworkflows = [\"word_count\", \"finra\"]\npartitions = [1, 2]\n",
        )
        .unwrap();
        let wf = spec.axis(AXIS_WORKFLOW).unwrap();
        assert_eq!(wf.levels.len(), 2);
        assert_eq!(wf.levels[0].as_int(), Some(3)); // word-count preset id
        assert_eq!(wf.levels[1].as_int(), Some(0)); // finra preset id
        assert_eq!(spec.size(), 4); // 2 workflows x 2 budget levels
        assert!(spec
            .scenarios()
            .iter()
            .all(|sc| sc.extra_param(AXIS_WORKFLOW).is_some()));
    }

    #[test]
    fn bad_workflow_configs_rejected() {
        assert!(spec_from_toml("workflows = [\"heron-dag\"]\n").is_err());
        assert!(spec_from_toml("workflows = []\n").is_err());
        assert!(spec_from_toml("workflows = [1]\n").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ps-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("exp.toml");
        std::fs::write(&p, "name = \"from-file\"\nmessages = 8\n").unwrap();
        let spec = spec_from_file(p.to_str().unwrap()).unwrap();
        assert_eq!(spec.name, "from-file");
        std::fs::remove_dir_all(&dir).ok();
    }
}
