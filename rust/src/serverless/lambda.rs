//! The Lambda-like FaaS runtime: container pool, invocation lifecycle,
//! walltime enforcement, billing — the serverless processing platform of
//! the paper's AWS experiments.
//!
//! One invocation = get model (S3-like store) → compute step (engine,
//! scaled by the container's CPU factor and multi-tenancy jitter) → put
//! model.  Containers are strongly isolated: no cross-container contention
//! term anywhere, which is precisely why the fitted USL σ, κ ≈ 0.

use super::container::{Container, FunctionConfig};
use crate::engine::{EngineError, StepEngine};
use crate::sim::SharedClock;
use crate::store::{ModelStore, StoreError};
use crate::util::rng::Pcg32;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Why an invocation failed.
#[derive(Debug, thiserror::Error)]
pub enum InvokeError {
    /// Function exceeded its configured walltime (Lambda kills it).
    #[error("function timed out after {0:.1}s")]
    TimedOut(f64),
    #[error(transparent)]
    Engine(#[from] EngineError),
    #[error(transparent)]
    Store(#[from] StoreError),
    /// All containers busy and the concurrency cap is reached.
    #[error("throttled: concurrency limit {0} reached")]
    ConcurrencyLimit(usize),
}

/// Timing breakdown of one invocation (modeled seconds).
#[derive(Debug, Clone)]
pub struct InvocationReport {
    pub container_id: u64,
    /// Time spent waiting for a container to free up (only nonzero on
    /// fleets configured with `queue_when_saturated`, e.g. edge boxes).
    pub queue_wait: f64,
    pub cold_start: f64,
    pub io_get: f64,
    pub compute: f64,
    pub io_put: f64,
    pub inertia: f64,
    pub billed_gb_seconds: f64,
    /// True if this invocation created a new container.
    pub was_cold: bool,
}

impl InvocationReport {
    /// End-to-end function duration (what Lambda bills and Fig 3 plots).
    pub fn duration(&self) -> f64 {
        self.cold_start + self.io_get + self.compute + self.io_put
    }
}

/// Tear down idle sandboxes beyond `cap` (the shared half of scale-down
/// enforcement: busy ones drain and are caught by the next booking).
fn evict_idle_over_cap(pool: &mut Vec<Container>, cap: usize, now: f64) {
    while pool.len() > cap {
        match pool.iter().position(|c| c.busy_until <= now) {
            Some(idx) => {
                pool.remove(idx);
            }
            None => break,
        }
    }
}

/// The function runtime ("Function Pilot" backend).
pub struct LambdaFleet {
    config: FunctionConfig,
    /// Live concurrency cap.  Starts at `config.max_concurrency`; the
    /// elastic control plane moves it at runtime via
    /// [`LambdaFleet::set_concurrency`].
    concurrency: AtomicUsize,
    engine: Arc<dyn StepEngine>,
    store: Arc<dyn ModelStore>,
    clock: SharedClock,
    containers: Mutex<Vec<Container>>,
    next_container_id: AtomicU64,
    rng: Mutex<Pcg32>,
    /// Idle container reuse window (AWS keeps sandboxes warm ~5–15 min).
    pub keep_alive_s: f64,
    invocations: AtomicU64,
    cold_starts: AtomicU64,
}

impl LambdaFleet {
    pub fn new(
        config: FunctionConfig,
        engine: Arc<dyn StepEngine>,
        store: Arc<dyn ModelStore>,
        clock: SharedClock,
        seed: u64,
    ) -> Result<Self, String> {
        config.validate()?;
        Ok(Self {
            concurrency: AtomicUsize::new(config.max_concurrency),
            config,
            engine,
            store,
            clock,
            containers: Mutex::new(Vec::new()),
            next_container_id: AtomicU64::new(1),
            rng: Mutex::new(Pcg32::seeded(seed)),
            keep_alive_s: 600.0,
            invocations: AtomicU64::new(0),
            cold_starts: AtomicU64::new(0),
        })
    }

    pub fn config(&self) -> &FunctionConfig {
        &self.config
    }

    /// The live concurrency cap (reserved concurrency, AWS terms).
    pub fn concurrency(&self) -> usize {
        self.concurrency.load(Ordering::Relaxed)
    }

    /// Move the live concurrency cap — the serverless resize primitive.
    ///
    /// Scale-up is free here: new containers are created lazily by the
    /// next invocations and pay their cold starts in-band.  Scale-down is
    /// instant: idle sandboxes beyond the new cap are torn down now; busy
    /// ones finish their in-flight invocation and are never rebooked
    /// (the next booking evicts them as they go idle).
    pub fn set_concurrency(&self, n: usize) {
        assert!(n > 0, "concurrency must be > 0");
        self.concurrency.store(n, Ordering::Relaxed);
        let mut pool = self.containers.lock().unwrap();
        evict_idle_over_cap(&mut pool, n, self.clock.now());
    }

    pub fn invocation_count(&self) -> u64 {
        self.invocations.load(Ordering::Relaxed)
    }

    /// Whether every bookable slot is occupied right now: the pool is at
    /// the live concurrency cap with no idle container.  An invocation
    /// arriving now would queue (or throttle, per
    /// `FunctionConfig::queue_when_saturated`) — the edge fleet's
    /// placement router consults this to spill work to the cloud region
    /// instead of queueing it on a full site.
    pub fn is_saturated(&self) -> bool {
        let now = self.clock.now();
        let cap = self.concurrency();
        let pool = self.containers.lock().unwrap();
        pool.iter().filter(|c| c.busy_until > now).count() >= cap
    }

    pub fn cold_start_count(&self) -> u64 {
        self.cold_starts.load(Ordering::Relaxed)
    }

    /// Containers currently alive (warm or busy).
    pub fn container_count(&self) -> usize {
        self.containers.lock().unwrap().len()
    }

    /// Book a container for `work` modeled seconds of (cold-start-free)
    /// function runtime starting at `now`: reuse a warm idle one, create a
    /// new one under the concurrency cap, and at the cap either throttle
    /// (cloud) or queue on the first container to free up (edge).
    ///
    /// The busy window is settled here, atomically under the pool lock —
    /// the caller has already computed `work`, so a booking never exists
    /// in a half-open state.  Concurrent invokes (threaded live driver)
    /// therefore serialize exactly like the single-threaded DES: a second
    /// queuer sees the first queuer's extended window and waits behind it,
    /// keeping modeled concurrency capped at `max_concurrency`.
    ///
    /// Returns (container id, queue-wait s, cold-start s, was_cold).
    fn book(&self, now: f64, work: f64) -> Result<(u64, f64, f64, bool), InvokeError> {
        let cap = self.concurrency.load(Ordering::Relaxed);
        let mut pool = self.containers.lock().unwrap();
        // expire stale sandboxes
        pool.retain(|c| c.busy_until > now || c.is_warm(now, self.keep_alive_s));
        // enforce a lowered concurrency cap *before* any reuse: idle
        // sandboxes beyond it are torn down now, busy ones finish their
        // in-flight invocation and get evicted here as they go idle — so
        // a down-scaled fleet converges to the cap instead of warm-reusing
        // retired capacity forever
        evict_idle_over_cap(&mut pool, cap, now);
        // the busy window never exceeds the walltime (Lambda kills the run)
        let occupy = |cold: f64| (cold + work).min(self.config.timeout_s);
        // a warm, idle container?
        if let Some(c) = pool
            .iter_mut()
            .filter(|c| c.busy_until <= now && c.is_warm(now, self.keep_alive_s))
            .min_by(|a, b| b.last_used.partial_cmp(&a.last_used).unwrap())
        {
            c.invocations += 1;
            c.busy_until = now + occupy(0.0);
            c.last_used = c.busy_until;
            return Ok((c.id, 0.0, 0.0, false));
        }
        if pool.len() >= cap {
            if !self.config.queue_when_saturated {
                return Err(InvokeError::ConcurrencyLimit(cap));
            }
            // every remaining container is busy (idle+warm ones were caught
            // above, stale ones expired): queue on the earliest to free up
            let c = pool
                .iter_mut()
                .min_by(|a, b| a.busy_until.partial_cmp(&b.busy_until).unwrap())
                .expect("max_concurrency > 0");
            let wait = (c.busy_until - now).max(0.0);
            c.invocations += 1;
            c.busy_until = (now + wait) + occupy(0.0);
            c.last_used = c.busy_until;
            return Ok((c.id, wait, 0.0, false));
        }
        let id = self.next_container_id.fetch_add(1, Ordering::Relaxed);
        let cold = {
            let mut rng = self.rng.lock().unwrap();
            self.config.cold_start_dist().sample(&mut rng)
        };
        pool.push(Container {
            id,
            busy_until: now + occupy(cold),
            last_used: now + occupy(cold),
            invocations: 1,
        });
        self.cold_starts.fetch_add(1, Ordering::Relaxed);
        Ok((id, 0.0, cold, true))
    }

    /// Invoke the function on one message's points.
    ///
    /// `model_key` names the shared model object in the store; if absent, a
    /// fresh model with `centroids` centroids is initialized first (the
    /// deploy step does this in practice).
    pub fn invoke(
        &self,
        points: &[f32],
        dim: usize,
        model_key: &str,
        centroids: usize,
    ) -> Result<InvocationReport, InvokeError> {
        // model the function's own work first — it does not depend on
        // container placement — so book() can settle the busy window in
        // one atomic step
        if !self.store.contains(model_key) {
            let init = crate::store::ModelState::new_random(centroids, dim, 42);
            let _ = self.store.put(model_key, init);
        }
        let (model, io_get) = self.store.get(model_key)?;

        let step = self.engine.execute_step(points, dim, &model)?;
        // CPU share + multi-tenancy jitter
        let noise = {
            let mut rng = self.rng.lock().unwrap();
            rng.normal_with(1.0, self.config.jitter_cv()).max(0.3)
        };
        let compute =
            step.cpu_seconds / (self.config.cpu_factor() * self.config.cpu_efficiency) * noise;

        let (_, io_put) = self.store.put(model_key, step.model)?;
        let work = io_get.seconds + compute + io_put.seconds;

        let now = self.clock.now();
        let (container_id, queue_wait, cold_start, was_cold) = self.book(now, work)?;
        self.invocations.fetch_add(1, Ordering::Relaxed);

        // the function's own runtime; queueing happens before it starts and
        // is neither billed nor counted against the walltime
        let duration = cold_start + work;
        if duration > self.config.timeout_s {
            return Err(InvokeError::TimedOut(self.config.timeout_s));
        }
        Ok(InvocationReport {
            container_id,
            queue_wait,
            cold_start,
            io_get: io_get.seconds,
            compute,
            io_put: io_put.seconds,
            inertia: step.inertia,
            billed_gb_seconds: self.config.billed_gb_seconds(duration),
            was_cold,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::sim::{Dist, SimClock};
    use crate::store::ObjectStore;

    fn fleet(memory_mb: u32, clock: Arc<SimClock>) -> LambdaFleet {
        let mut eng = CalibratedEngine::new(5);
        eng.insert((100, 16), Dist::Const(0.1));
        LambdaFleet::new(
            FunctionConfig {
                memory_mb,
                ..Default::default()
            },
            Arc::new(eng),
            Arc::new(ObjectStore::default()),
            clock as SharedClock,
            11,
        )
        .unwrap()
    }

    fn pts() -> Vec<f32> {
        vec![0.5; 100 * 8]
    }

    #[test]
    fn invoke_reports_breakdown() {
        let clock = Arc::new(SimClock::new());
        let f = fleet(1792, clock);
        let r = f.invoke(&pts(), 8, "m", 16).unwrap();
        assert!(r.was_cold);
        assert!(r.cold_start > 0.0);
        assert!(r.io_get > 0.0 && r.io_put > 0.0);
        assert!(r.compute > 0.0);
        assert!(r.billed_gb_seconds > 0.0);
        assert_eq!(f.invocation_count(), 1);
        assert_eq!(f.cold_start_count(), 1);
    }

    #[test]
    fn warm_reuse_skips_cold_start() {
        let clock = Arc::new(SimClock::new());
        let f = fleet(1792, clock.clone());
        let r1 = f.invoke(&pts(), 8, "m", 16).unwrap();
        clock.advance_to(r1.duration() + 0.1);
        let r2 = f.invoke(&pts(), 8, "m", 16).unwrap();
        assert!(!r2.was_cold);
        assert_eq!(r2.cold_start, 0.0);
        assert_eq!(f.cold_start_count(), 1);
        assert_eq!(f.container_count(), 1);
    }

    #[test]
    fn more_memory_runs_faster_and_steadier() {
        // Fig 3's mechanism: larger containers → shorter, less noisy runtimes
        let run = |mb: u32| {
            let clock = Arc::new(SimClock::new());
            let f = fleet(mb, clock.clone());
            let mut times = Vec::new();
            let mut t = 0.0;
            for _ in 0..40 {
                let r = f.invoke(&pts(), 8, "m", 16).unwrap();
                t += r.duration() + 0.01;
                clock.advance_to(t);
                times.push(r.compute);
            }
            crate::util::stats::Summary::of(&times).unwrap()
        };
        let small = run(256);
        let large = run(3008);
        assert!(small.mean > large.mean * 2.0, "small={} large={}", small.mean, large.mean);
        assert!(small.cv() > large.cv());
    }

    #[test]
    fn concurrency_cap_throttles() {
        let clock = Arc::new(SimClock::new());
        let mut cfg = FunctionConfig::default();
        cfg.max_concurrency = 2;
        let f = LambdaFleet::new(
            cfg,
            Arc::new(CalibratedEngine::new(1)),
            Arc::new(ObjectStore::default()),
            clock as SharedClock,
            3,
        )
        .unwrap();
        // both containers end up busy at t=0 (busy_until > now)
        f.invoke(&pts(), 8, "m", 16).unwrap();
        f.invoke(&pts(), 8, "m", 16).unwrap();
        let err = f.invoke(&pts(), 8, "m", 16).unwrap_err();
        assert!(matches!(err, InvokeError::ConcurrencyLimit(2)));
    }

    #[test]
    fn saturated_fleet_queues_when_configured() {
        // the edge policy: a full device queues invocations instead of
        // throttling the caller, charging the wait to the report
        let clock = Arc::new(SimClock::new());
        let mut eng = CalibratedEngine::new(1);
        eng.insert((100, 16), Dist::Const(0.1));
        let cfg = FunctionConfig {
            max_concurrency: 2,
            queue_when_saturated: true,
            ..Default::default()
        };
        let f = LambdaFleet::new(
            cfg,
            Arc::new(eng),
            Arc::new(ObjectStore::default()),
            clock as SharedClock,
            3,
        )
        .unwrap();
        let r1 = f.invoke(&pts(), 8, "m", 16).unwrap();
        let r2 = f.invoke(&pts(), 8, "m", 16).unwrap();
        assert_eq!(r1.queue_wait, 0.0);
        assert_eq!(r2.queue_wait, 0.0);
        let r3 = f.invoke(&pts(), 8, "m", 16).unwrap();
        assert!(
            r3.queue_wait > 0.0,
            "third concurrent invocation must wait for a container"
        );
        assert!(!r3.was_cold);
        assert_eq!(f.container_count(), 2, "no container beyond the cap");
    }

    #[test]
    fn cpu_efficiency_scales_compute() {
        let run = |eff: f64| {
            let clock = Arc::new(SimClock::new());
            let mut eng = CalibratedEngine::new(5);
            eng.insert((100, 16), Dist::Const(0.1));
            let cfg = FunctionConfig {
                cpu_efficiency: eff,
                ..Default::default()
            };
            let f = LambdaFleet::new(
                cfg,
                Arc::new(eng),
                Arc::new(ObjectStore::default()),
                clock as SharedClock,
                11,
            )
            .unwrap();
            f.invoke(&pts(), 8, "m", 16).unwrap().compute
        };
        let cloud = run(super::super::container::LAMBDA_CPU_EFFICIENCY);
        let edge = run(crate::serverless::edge::EDGE_CPU_EFFICIENCY);
        // identical seed and jitter stream: the ratio is exactly the
        // efficiency ratio
        assert!(
            edge > cloud * 1.3,
            "edge silicon must run slower: cloud {cloud} edge {edge}"
        );
    }

    #[test]
    fn saturation_is_observable() {
        let clock = Arc::new(SimClock::new());
        let mut eng = CalibratedEngine::new(1);
        eng.insert((100, 16), Dist::Const(0.1));
        let cfg = FunctionConfig {
            max_concurrency: 2,
            queue_when_saturated: true,
            ..Default::default()
        };
        let f = LambdaFleet::new(
            cfg,
            Arc::new(eng),
            Arc::new(ObjectStore::default()),
            clock.clone() as SharedClock,
            3,
        )
        .unwrap();
        assert!(!f.is_saturated(), "empty fleet has free slots");
        f.invoke(&pts(), 8, "m", 16).unwrap();
        assert!(!f.is_saturated(), "one of two slots busy");
        f.invoke(&pts(), 8, "m", 16).unwrap();
        assert!(f.is_saturated(), "both slots busy at t=0");
        clock.advance_to(100.0);
        assert!(!f.is_saturated(), "containers went idle");
    }

    #[test]
    fn walltime_enforced() {
        let clock = Arc::new(SimClock::new());
        let mut eng = CalibratedEngine::new(1);
        eng.insert((100, 16), Dist::Const(2000.0)); // way past 900 s
        let f = LambdaFleet::new(
            FunctionConfig::default(),
            Arc::new(eng),
            Arc::new(ObjectStore::default()),
            clock as SharedClock,
            3,
        )
        .unwrap();
        assert!(matches!(
            f.invoke(&pts(), 8, "m", 16),
            Err(InvokeError::TimedOut(_))
        ));
    }

    #[test]
    fn concurrency_moves_at_runtime() {
        let clock = Arc::new(SimClock::new());
        let mut cfg = FunctionConfig::default();
        cfg.max_concurrency = 1;
        let mut eng = CalibratedEngine::new(1);
        eng.insert((100, 16), Dist::Const(0.1));
        let f = LambdaFleet::new(
            cfg,
            Arc::new(eng),
            Arc::new(ObjectStore::default()),
            clock.clone() as SharedClock,
            3,
        )
        .unwrap();
        f.invoke(&pts(), 8, "m", 16).unwrap();
        assert!(matches!(
            f.invoke(&pts(), 8, "m", 16),
            Err(InvokeError::ConcurrencyLimit(1))
        ));
        // scale up: the second container cold-starts in-band
        f.set_concurrency(2);
        let r = f.invoke(&pts(), 8, "m", 16).unwrap();
        assert!(r.was_cold, "new capacity pays its cold start");
        assert_eq!(f.container_count(), 2);
        // scale down once idle: instant teardown to the new cap
        clock.advance_to(100.0);
        f.set_concurrency(1);
        assert_eq!(f.container_count(), 1);
        assert_eq!(f.concurrency(), 1);
    }

    #[test]
    fn lowered_cap_is_enforced_against_warm_reuse() {
        // regression: a cap lowered while every container was busy must
        // still bite once they go idle — retired capacity is evicted at
        // booking time, never warm-reused
        let clock = Arc::new(SimClock::new());
        let mut cfg = FunctionConfig::default();
        cfg.max_concurrency = 3;
        let mut eng = CalibratedEngine::new(1);
        eng.insert((100, 16), Dist::Const(0.1));
        let f = LambdaFleet::new(
            cfg,
            Arc::new(eng),
            Arc::new(ObjectStore::default()),
            clock.clone() as SharedClock,
            3,
        )
        .unwrap();
        for _ in 0..3 {
            f.invoke(&pts(), 8, "m", 16).unwrap();
        }
        f.set_concurrency(1); // all three busy: nothing evictable yet
        assert_eq!(f.container_count(), 3);
        clock.advance_to(10.0); // everyone idle (and still warm)
        let r = f.invoke(&pts(), 8, "m", 16).unwrap();
        assert!(!r.was_cold, "the one surviving sandbox is reused warm");
        assert_eq!(f.container_count(), 1, "over-cap sandboxes evicted");
        assert!(matches!(
            f.invoke(&pts(), 8, "m", 16),
            Err(InvokeError::ConcurrencyLimit(1))
        ));
    }

    #[test]
    fn model_persists_across_invocations() {
        let clock = Arc::new(SimClock::new());
        let f = fleet(1792, clock.clone());
        let r1 = f.invoke(&pts(), 8, "model-a", 16).unwrap();
        clock.advance_to(r1.duration() + 1.0);
        f.invoke(&pts(), 8, "model-a", 16).unwrap();
        let (m, _) = f.store.get("model-a").unwrap();
        assert_eq!(m.version, 3); // init + 2 step writes
    }
}
