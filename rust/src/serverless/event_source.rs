//! Event-source mapping: broker shards → function invocations.
//!
//! Reproduces the AWS Lambda/Kinesis integration semantics the paper relies
//! on: *per shard*, records are delivered in order to at most one
//! concurrent invocation — "AWS never starts more containers than Kinesis
//! partitions" (§IV-B2) — so processing parallelism equals the shard count
//! (bounded additionally by the function's concurrency cap).

use crate::broker::Broker;
use std::sync::{Arc, Mutex};

/// Per-shard iterator/commit state.
#[derive(Debug, Clone, Default)]
pub struct ShardCursor {
    /// Next offset to read.
    pub offset: u64,
    /// Records successfully processed.
    pub processed: u64,
    /// A batch is currently in flight (enforces one invocation per shard).
    pub in_flight: bool,
}

/// The mapping between a stream and a function.
pub struct EventSourceMapping {
    broker: Arc<dyn Broker>,
    cursors: Vec<Mutex<ShardCursor>>,
    /// Max records handed to one invocation (Lambda batch size).
    pub batch_size: usize,
}

impl EventSourceMapping {
    pub fn new(broker: Arc<dyn Broker>, batch_size: usize) -> Self {
        assert!(batch_size > 0);
        let n = broker.num_partitions();
        Self {
            broker,
            cursors: (0..n).map(|_| Mutex::new(ShardCursor::default())).collect(),
            batch_size,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.cursors.len()
    }

    /// Try to lease the next batch from `shard` at time `now`.  Returns
    /// `None` if the shard is empty or already has an invocation in flight.
    pub fn poll(&self, shard: usize, now: f64) -> Option<Lease> {
        let mut cur = self.cursors[shard].lock().unwrap();
        if cur.in_flight {
            return None;
        }
        let records = self
            .broker
            .fetch(shard, cur.offset, self.batch_size, now)
            .ok()?;
        if records.is_empty() {
            return None;
        }
        cur.in_flight = true;
        Some(Lease {
            shard,
            next_offset: records.last().unwrap().offset + 1,
            records,
        })
    }

    /// Commit a finished lease, advancing the shard cursor.
    pub fn commit(&self, lease: Lease) {
        let mut cur = self.cursors[lease.shard].lock().unwrap();
        debug_assert!(cur.in_flight);
        cur.processed += lease.records.len() as u64;
        cur.offset = lease.next_offset;
        cur.in_flight = false;
    }

    /// Abort a lease without advancing (retry semantics).
    pub fn abort(&self, lease: Lease) {
        let mut cur = self.cursors[lease.shard].lock().unwrap();
        cur.in_flight = false;
    }

    /// Total records processed across shards.
    pub fn processed(&self) -> u64 {
        self.cursors
            .iter()
            .map(|c| c.lock().unwrap().processed)
            .sum()
    }

    /// Total unprocessed backlog.
    pub fn lag(&self) -> u64 {
        (0..self.cursors.len())
            .map(|s| {
                let off = self.cursors[s].lock().unwrap().offset;
                self.broker
                    .latest_offset(s)
                    .map(|l| l.saturating_sub(off))
                    .unwrap_or(0)
            })
            .sum()
    }
}

/// A leased batch: exclusive right to process these records for one shard.
pub struct Lease {
    pub shard: usize,
    pub records: Vec<crate::broker::StoredRecord>,
    next_offset: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::kinesis::{KinesisStream, ShardLimits};
    use crate::broker::Message;
    use crate::sim::{SharedClock, SimClock};

    fn setup(shards: usize) -> (Arc<KinesisStream>, Arc<SimClock>, EventSourceMapping) {
        let clock = Arc::new(SimClock::new());
        let broker = Arc::new(KinesisStream::new(
            "s",
            shards,
            ShardLimits {
                bytes_per_sec: 1e12,
                records_per_sec: 1e9,
                put_latency: 0.0,
            },
            clock.clone() as SharedClock,
        ));
        let esm = EventSourceMapping::new(broker.clone() as Arc<dyn Broker>, 2);
        (broker, clock, esm)
    }

    fn put(broker: &KinesisStream, key: u64) {
        broker
            .put(Message::new(1, key, vec![0.0; 8].into(), 2, 0.0))
            .unwrap();
    }

    #[test]
    fn poll_commit_advances() {
        let (broker, clock, esm) = setup(1);
        for k in 0..5 {
            put(&broker, k);
        }
        clock.advance_to(1.0);
        let lease = esm.poll(0, 1.0).unwrap();
        assert_eq!(lease.records.len(), 2); // batch_size
        esm.commit(lease);
        assert_eq!(esm.processed(), 2);
        assert_eq!(esm.lag(), 3);
    }

    #[test]
    fn one_invocation_per_shard() {
        let (broker, clock, esm) = setup(1);
        for k in 0..10 {
            put(&broker, k);
        }
        clock.advance_to(1.0);
        let lease = esm.poll(0, 1.0).unwrap();
        // second poll on the same shard while in flight yields nothing
        assert!(esm.poll(0, 1.0).is_none());
        esm.commit(lease);
        assert!(esm.poll(0, 1.0).is_some());
    }

    #[test]
    fn abort_retries_same_records() {
        let (broker, clock, esm) = setup(1);
        for k in 0..3 {
            put(&broker, k);
        }
        clock.advance_to(1.0);
        let l1 = esm.poll(0, 1.0).unwrap();
        let first_ids: Vec<u64> = l1.records.iter().map(|r| r.message.id).collect();
        esm.abort(l1);
        let l2 = esm.poll(0, 1.0).unwrap();
        let retry_ids: Vec<u64> = l2.records.iter().map(|r| r.message.id).collect();
        assert_eq!(first_ids, retry_ids);
        assert_eq!(esm.processed(), 0);
    }

    #[test]
    fn empty_shard_polls_none() {
        let (_, _, esm) = setup(2);
        assert!(esm.poll(0, 1.0).is_none());
        assert!(esm.poll(1, 1.0).is_none());
    }

    #[test]
    fn multiple_shards_independent() {
        let (broker, clock, esm) = setup(4);
        for k in 0..50 {
            put(&broker, k);
        }
        clock.advance_to(1.0);
        let leases: Vec<_> = (0..4).filter_map(|s| esm.poll(s, 1.0)).collect();
        assert!(leases.len() >= 2, "keys should spread across shards");
        for l in leases {
            esm.commit(l);
        }
        assert!(esm.processed() > 0);
    }
}
