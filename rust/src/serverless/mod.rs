//! Serverless platform substrate: a Lambda-like FaaS runtime with
//! memory-proportional CPU scaling, cold starts, per-shard event-source
//! mapping, walltime enforcement and billing — everything the paper's
//! AWS Lambda/Kinesis experiments depend on.  See DESIGN.md §Substitutions.

pub mod container;
pub mod edge;
pub mod edge_fleet;
pub mod event_source;
pub mod lambda;

pub use container::{Container, FunctionConfig, FULL_VCPU_MB, LAMBDA_CPU_EFFICIENCY, MAX_MEMORY_MB, MAX_WALLTIME_S, MIN_MEMORY_MB};
pub use edge::EdgeSite;
pub use edge_fleet::{
    EdgeFleet, MessageClass, Placement, PlacementPolicy, PlacementSnapshot, PlacementStats,
    CLOUD_SPILLOVER_CONCURRENCY,
};
pub use event_source::{EventSourceMapping, Lease};
pub use lambda::{InvocationReport, InvokeError, LambdaFleet};
