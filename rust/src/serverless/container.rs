//! Lambda container model: memory-proportional CPU, cold starts, lifecycle.
//!
//! AWS allocates CPU share proportional to configured memory (~1 vCPU at
//! 1,792 MB); the paper's Fig 3 observes exactly this — runtimes shrink as
//! container memory grows even though the function's *used* memory stays
//! constant, and runtime variance shrinks too (bigger slices mean less
//! multi-tenant interference).

use crate::sim::Dist;

/// Lambda platform limits as of the paper (2019).
pub const MIN_MEMORY_MB: u32 = 128;
pub const MAX_MEMORY_MB: u32 = 3_008;
pub const FULL_VCPU_MB: f64 = 1_792.0;
/// Throughput of one full Lambda vCPU relative to a dedicated HPC Xeon
/// core (Wrangler reference).  Lambda vCPUs are shares of multi-tenant,
/// older-generation silicon; the paper observes HPC delivering better
/// absolute per-task performance, which this factor reproduces.
pub const LAMBDA_CPU_EFFICIENCY: f64 = 0.5;
pub const MAX_WALLTIME_S: f64 = 900.0; // 15 minutes

/// Function configuration (the knobs `PilotDescription` exposes).
#[derive(Debug, Clone)]
pub struct FunctionConfig {
    pub memory_mb: u32,
    pub timeout_s: f64,
    /// Deployment package size (drives cold-start duration).
    pub package_mb: f64,
    /// Hard cap on concurrent containers (paper observed at most 30).
    pub max_concurrency: usize,
    /// Per-core speed of the hosting silicon vs a dedicated HPC core.
    /// Cloud Lambda: [`LAMBDA_CPU_EFFICIENCY`]; edge devices lower still.
    pub cpu_efficiency: f64,
    /// Saturated-fleet policy: cloud Lambda throttles the caller (error);
    /// a fixed edge box queues the invocation on the first free container.
    pub queue_when_saturated: bool,
}

impl Default for FunctionConfig {
    fn default() -> Self {
        Self {
            memory_mb: 3_008,
            timeout_s: MAX_WALLTIME_S,
            package_mb: 50.0,
            max_concurrency: 30,
            cpu_efficiency: LAMBDA_CPU_EFFICIENCY,
            queue_when_saturated: false,
        }
    }
}

impl FunctionConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !(MIN_MEMORY_MB..=MAX_MEMORY_MB).contains(&self.memory_mb) {
            return Err(format!(
                "memory {} MB outside [{MIN_MEMORY_MB}, {MAX_MEMORY_MB}]",
                self.memory_mb
            ));
        }
        if self.timeout_s <= 0.0 || self.timeout_s > MAX_WALLTIME_S {
            return Err(format!(
                "timeout {}s outside (0, {MAX_WALLTIME_S}]",
                self.timeout_s
            ));
        }
        if self.max_concurrency == 0 {
            return Err("max_concurrency must be > 0".into());
        }
        if self.cpu_efficiency <= 0.0 {
            return Err("cpu_efficiency must be > 0".into());
        }
        Ok(())
    }

    /// CPU share relative to one reference vCPU.  Linear in memory; above
    /// 1,792 MB AWS hands out a second core — a single-threaded function
    /// only benefits partially, modeled with a 0.55 efficiency on the
    /// second core (fits the paper's Fig 3 continuing but flattening gains).
    pub fn cpu_factor(&self) -> f64 {
        let m = self.memory_mb as f64;
        if m <= FULL_VCPU_MB {
            m / FULL_VCPU_MB
        } else {
            1.0 + 0.55 * (m - FULL_VCPU_MB) / FULL_VCPU_MB
        }
    }

    /// Runtime jitter (coefficient of variation).  Small containers share
    /// cores with more tenants: the paper's Fig 3 shows visibly noisier
    /// runtimes at small sizes.
    pub fn jitter_cv(&self) -> f64 {
        let m = (self.memory_mb as f64).min(FULL_VCPU_MB);
        0.02 + 0.10 * (1.0 - m / FULL_VCPU_MB)
    }

    /// Cold-start duration distribution: sandbox setup + package fetch.
    pub fn cold_start_dist(&self) -> Dist {
        let mean = 0.25 + 0.004 * self.package_mb;
        Dist::Normal {
            mean,
            std: mean * 0.2,
            min: mean * 0.4,
        }
    }

    /// Billed GB-seconds for a run of `seconds`, rounded up to 1 ms
    /// (AWS billed 100 ms granularity in 2019; 1 ms since 2020 — we use
    /// the modern rule and note it).
    pub fn billed_gb_seconds(&self, seconds: f64) -> f64 {
        let rounded = (seconds * 1000.0).ceil() / 1000.0;
        rounded * self.memory_mb as f64 / 1024.0
    }
}

/// A pooled container instance.
#[derive(Debug, Clone)]
pub struct Container {
    pub id: u64,
    /// Time the container becomes idle again (busy until then).
    pub busy_until: f64,
    /// Last moment the container finished work (for expiry).
    pub last_used: f64,
    /// Number of invocations served (first one paid the cold start).
    pub invocations: u64,
}

impl Container {
    pub fn is_warm(&self, now: f64, keep_alive: f64) -> bool {
        self.invocations > 0 && now - self.last_used <= keep_alive
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_factor_linear_then_flattens() {
        let at = |mb: u32| FunctionConfig {
            memory_mb: mb,
            ..Default::default()
        }
        .cpu_factor();
        assert!((at(1792) - 1.0).abs() < 1e-12);
        assert!((at(896) - 0.5).abs() < 1e-12);
        // monotone increasing all the way to 3008
        let mut prev = 0.0;
        for mb in (128..=3008).step_by(64) {
            let f = at(mb);
            assert!(f > prev);
            prev = f;
        }
        // second-core gain flattens: slope above 1792 < slope below
        let below = at(1792) - at(1728);
        let above = at(1856) - at(1792);
        assert!(above < below);
    }

    #[test]
    fn jitter_shrinks_with_memory() {
        let cv = |mb: u32| FunctionConfig {
            memory_mb: mb,
            ..Default::default()
        }
        .jitter_cv();
        assert!(cv(128) > cv(1024));
        assert!(cv(1024) > cv(1792));
        assert!((cv(1792) - cv(3008)).abs() < 1e-12); // floor above 1 vCPU
    }

    #[test]
    fn validation() {
        let mut c = FunctionConfig::default();
        assert!(c.validate().is_ok());
        c.memory_mb = 64;
        assert!(c.validate().is_err());
        c.memory_mb = 4096;
        assert!(c.validate().is_err());
        c = FunctionConfig {
            timeout_s: 1000.0,
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn billing_rounds_up() {
        let c = FunctionConfig {
            memory_mb: 1024,
            ..Default::default()
        };
        assert!((c.billed_gb_seconds(1.0) - 1.0).abs() < 1e-12);
        assert!((c.billed_gb_seconds(0.0001) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn warm_expiry() {
        let c = Container {
            id: 1,
            busy_until: 0.0,
            last_used: 100.0,
            invocations: 3,
        };
        assert!(c.is_warm(200.0, 600.0));
        assert!(!c.is_warm(1000.0, 600.0));
        let fresh = Container {
            invocations: 0,
            ..c
        };
        assert!(!fresh.is_warm(100.0, 600.0));
    }

    #[test]
    fn cold_start_grows_with_package() {
        let small = FunctionConfig {
            package_mb: 10.0,
            ..Default::default()
        };
        let big = FunctionConfig {
            package_mb: 250.0,
            ..Default::default()
        };
        assert!(big.cold_start_dist().mean() > small.cold_start_dist().mean());
    }
}
