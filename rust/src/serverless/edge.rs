//! Edge / Greengrass extension (the paper's §V future work): "With
//! Greengrass, AWS supports the execution of Lambda functions on the edge.
//! By moving serverless functions to the edge and thus, closer to the data,
//! further optimizations are possible."
//!
//! An [`EdgeSite`] hosts Lambda-compatible functions on constrained
//! edge hardware next to the data source: broker hops are local-network
//! cheap (~2 ms instead of ~15 ms WAN), but CPU is weaker, memory is
//! capped, and only a handful of containers fit on the box.
//!
//! Multiple sites compose into an
//! [`EdgeFleet`](super::edge_fleet::EdgeFleet) with heterogeneous
//! envelopes and a message-class placement layer (see
//! [`edge_fleet`](super::edge_fleet)); this module stays the single-site
//! device model that fleet builds on.

use super::container::FunctionConfig;

/// Greengrass-class device limits.
pub const EDGE_MAX_MEMORY_MB: u32 = 1_536;
/// Edge cores vs the cloud Lambda reference vCPU (embedded-class silicon).
pub const EDGE_CPU_EFFICIENCY: f64 = 0.35;
/// Containers that fit on one edge box.
pub const EDGE_MAX_CONCURRENCY: usize = 4;
/// Local-network put latency to the on-site broker, seconds.
pub const EDGE_BROKER_LATENCY: f64 = 0.002;
/// Cloud put latency (the Kinesis WAN default), for comparison.
pub const CLOUD_BROKER_LATENCY: f64 = 0.015;
/// One-way backhaul latency of the reference site to the cloud region,
/// seconds.
pub const EDGE_BACKHAUL_LATENCY: f64 = 0.040;

/// One edge deployment site.
#[derive(Debug, Clone)]
pub struct EdgeSite {
    pub name: String,
    /// Device memory available to function containers.
    pub memory_mb: u32,
    /// Max concurrent containers on the device.
    pub max_concurrency: usize,
    /// Per-core speed vs the cloud Lambda reference.
    pub cpu_efficiency: f64,
    /// One-way latency to the site-local broker, seconds.
    pub broker_latency: f64,
    /// Backhaul latency to the cloud region, seconds (for model sync to
    /// S3 when the model store stays in the region).
    pub backhaul_latency: f64,
}

impl Default for EdgeSite {
    fn default() -> Self {
        Self {
            name: "edge-site".into(),
            memory_mb: EDGE_MAX_MEMORY_MB,
            max_concurrency: EDGE_MAX_CONCURRENCY,
            cpu_efficiency: EDGE_CPU_EFFICIENCY,
            broker_latency: EDGE_BROKER_LATENCY,
            backhaul_latency: EDGE_BACKHAUL_LATENCY,
        }
    }
}

impl EdgeSite {
    /// Validate and clamp a function config to this device's envelope.
    pub fn admit(&self, mut config: FunctionConfig) -> Result<FunctionConfig, String> {
        if config.memory_mb > self.memory_mb {
            return Err(format!(
                "function wants {} MB; edge site {} has {} MB",
                config.memory_mb, self.name, self.memory_mb
            ));
        }
        config.max_concurrency = config.max_concurrency.min(self.max_concurrency);
        config.validate()?;
        Ok(config)
    }

    /// End-to-end data latency advantage vs processing in-region: the
    /// message skips the WAN hop to the cloud broker.
    pub fn ingest_latency_saving(&self) -> f64 {
        (CLOUD_BROKER_LATENCY - self.broker_latency).max(0.0)
    }

    /// Compute-time ratio edge/cloud for the same function memory: how
    /// much slower one step runs on the edge device.
    pub fn compute_slowdown(&self, config: &FunctionConfig) -> f64 {
        (config.cpu_factor() * super::container::LAMBDA_CPU_EFFICIENCY)
            / (config.cpu_factor() * self.cpu_efficiency)
    }

    /// Break-even compute time: for steps shorter than this, the edge's
    /// ingest saving beats its compute penalty and the function should run
    /// at the edge (the paper's "further optimizations are possible").
    pub fn breakeven_compute_seconds(&self, config: &FunctionConfig) -> f64 {
        // saving >= cloud_compute * (slowdown - 1)
        let slowdown = self.compute_slowdown(config);
        if slowdown <= 1.0 {
            return f64::INFINITY;
        }
        self.ingest_latency_saving() / (slowdown - 1.0)
    }

    /// Placement decision for a step with known cloud-side compute cost.
    pub fn should_run_at_edge(&self, config: &FunctionConfig, cloud_compute_s: f64) -> bool {
        cloud_compute_s <= self.breakeven_compute_seconds(config)
    }

    /// Round-trip backhaul cost of shipping one message to the cloud
    /// region and syncing the model state back — what a message pays when
    /// the fleet's placement layer spills it off this site.
    pub fn backhaul_round_trip(&self) -> f64 {
        2.0 * self.backhaul_latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(memory_mb: u32) -> FunctionConfig {
        FunctionConfig {
            memory_mb,
            ..Default::default()
        }
    }

    #[test]
    fn admission_clamps_and_rejects() {
        let site = EdgeSite::default();
        let ok = site.admit(cfg(1024)).unwrap();
        assert!(ok.max_concurrency <= EDGE_MAX_CONCURRENCY);
        assert!(site.admit(cfg(3008)).is_err(), "exceeds device memory");
    }

    #[test]
    fn edge_is_slower_but_closer() {
        let site = EdgeSite::default();
        let c = cfg(1024);
        assert!(site.compute_slowdown(&c) > 1.0);
        assert!(site.ingest_latency_saving() > 0.01);
    }

    #[test]
    fn placement_prefers_edge_for_short_steps() {
        // short pre-processing steps (the paper's event-detection use case)
        // go to the edge; heavy model updates stay in the region
        let site = EdgeSite::default();
        let c = cfg(1024);
        let breakeven = site.breakeven_compute_seconds(&c);
        assert!(breakeven > 0.0 && breakeven.is_finite());
        assert!(site.should_run_at_edge(&c, breakeven * 0.5));
        assert!(!site.should_run_at_edge(&c, breakeven * 2.0));
    }

    #[test]
    fn faster_edge_hardware_always_wins() {
        let site = EdgeSite {
            cpu_efficiency: super::super::container::LAMBDA_CPU_EFFICIENCY * 2.0,
            ..Default::default()
        };
        assert_eq!(
            site.breakeven_compute_seconds(&cfg(1024)),
            f64::INFINITY
        );
        assert!(site.should_run_at_edge(&cfg(1024), 1e9));
    }
}
