//! Multi-site edge fleet + message-class placement (the "from the edge to
//! the cloud and HPC" half of the paper's §V vision).
//!
//! One [`EdgeSite`] models a single Greengrass-class box; an [`EdgeFleet`]
//! is an ordered set of *heterogeneous* sites — each with its own device
//! envelope (CPU efficiency, container cap, LAN broker latency, backhaul
//! latency to the cloud region).  The fleet owns the arithmetic the edge
//! plugin's substrate wiring builds on:
//!
//! - [`EdgeFleet::distribute`] — deterministic waterfill of a parallelism
//!   target over the per-site container caps (every live site keeps at
//!   least one container: the data source is on the box).
//! - [`PlacementPolicy`] — routes each **message class** per site using
//!   [`EdgeSite::should_run_at_edge`]: classes whose learned cloud-side
//!   compute cost sits under the site's break-even are *edge-pinned*
//!   (latency-bound; they queue locally when the box is full), heavier
//!   classes are *spillable* — they run data-local while the site has
//!   capacity and overflow to a cloud fallback over the backhaul when the
//!   site saturates.  Cloud costs are learned: a class starts data-local
//!   and every measured invocation feeds an EWMA of its cloud-equivalent
//!   compute cost.
//! - [`PlacementStats`] — conserved message accounting: every routed
//!   message is exactly one of edge-served or spilled, so
//!   `edge_total + spilled == total` always.
//!
//! ```rust
//! use pilot_streaming::serverless::edge_fleet::EdgeFleet;
//!
//! let fleet = EdgeFleet::provision(4);
//! assert_eq!(fleet.len(), 4);
//! // heterogeneous envelopes: per-site caps differ...
//! let caps: Vec<usize> = fleet.sites().iter().map(|s| s.max_concurrency).collect();
//! assert_eq!(fleet.total_capacity(), caps.iter().sum::<usize>());
//! // ...and a parallelism target waterfills across them, floored at one
//! // container per site and clamped at the fleet-wide capacity
//! let alloc = fleet.distribute(6);
//! assert_eq!(alloc.iter().sum::<usize>(), 6);
//! assert!(alloc.iter().all(|&a| a >= 1));
//! assert_eq!(
//!     fleet.distribute(1_000).iter().sum::<usize>(),
//!     fleet.total_capacity()
//! );
//! ```

use super::container::{FunctionConfig, LAMBDA_CPU_EFFICIENCY};
use super::edge::{
    EdgeSite, EDGE_BACKHAUL_LATENCY, EDGE_BROKER_LATENCY, EDGE_CPU_EFFICIENCY,
    EDGE_MAX_CONCURRENCY,
};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Cloud-region containers available to a fleet's spillover path (the
/// paper's observed Lambda concurrency ceiling).
pub const CLOUD_SPILLOVER_CONCURRENCY: usize = 30;

/// Largest fleet [`EdgeFleet::provision`] will build.  A per-site
/// `LambdaFleet` is provisioned for every site, so the count must stay
/// sane; the edge plugin's `validate` rejects descriptions beyond it and
/// `provision` clamps defensively.
pub const MAX_EDGE_SITES: usize = 64;

/// The deterministic heterogeneity table [`EdgeFleet::provision`] cycles
/// through: (cpu_efficiency, max_concurrency, broker_latency, backhaul).
/// Site 0 is always the reference `EdgeSite::default()` envelope (built
/// from the same named constants), so a one-site fleet is exactly the
/// pre-fleet edge platform.
const SITE_ENVELOPES: [(f64, usize, f64, f64); 4] = [
    // reference Greengrass-class box == EdgeSite::default()
    (
        EDGE_CPU_EFFICIENCY,
        EDGE_MAX_CONCURRENCY,
        EDGE_BROKER_LATENCY,
        EDGE_BACKHAUL_LATENCY,
    ),
    (0.30, 3, 0.003, 0.060),  // older silicon, farther from the region
    (0.45, 4, 0.0015, 0.035), // newer box on a better uplink
    (0.25, 2, 0.0025, 0.080), // battery-class device, worst backhaul
];

/// An ordered set of heterogeneous edge sites — the unit the edge plugin
/// provisions from `Scenario::extra_param("edge_sites")`.
#[derive(Debug, Clone)]
pub struct EdgeFleet {
    sites: Vec<EdgeSite>,
}

impl EdgeFleet {
    /// A fleet over explicit site envelopes.
    pub fn new(sites: Vec<EdgeSite>) -> Result<Self, String> {
        if sites.is_empty() {
            return Err("an edge fleet needs at least one site".into());
        }
        for s in &sites {
            if s.max_concurrency == 0 {
                return Err(format!("site {} has zero container capacity", s.name));
            }
            if s.cpu_efficiency <= 0.0 {
                return Err(format!("site {} has non-positive cpu efficiency", s.name));
            }
        }
        Ok(Self { sites })
    }

    /// The canonical heterogeneous fleet of `n` sites: site 0 is the
    /// reference envelope, later sites cycle a fixed table of weaker /
    /// stronger boxes.  Deterministic — the same `n` always provisions the
    /// same fleet, so sweeps over the `edge_sites` axis are reproducible.
    /// `n` is clamped to `[1, MAX_EDGE_SITES]`.
    pub fn provision(n: usize) -> Self {
        let n = n.clamp(1, MAX_EDGE_SITES);
        let sites = (0..n)
            .map(|i| {
                let (eff, cap, lan, backhaul) = SITE_ENVELOPES[i % SITE_ENVELOPES.len()];
                EdgeSite {
                    name: format!("edge-site-{i}"),
                    cpu_efficiency: eff,
                    max_concurrency: cap,
                    broker_latency: lan,
                    backhaul_latency: backhaul,
                    ..EdgeSite::default()
                }
            })
            .collect();
        Self { sites }
    }

    pub fn sites(&self) -> &[EdgeSite] {
        &self.sites
    }

    pub fn len(&self) -> usize {
        self.sites.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sites.is_empty()
    }

    /// The site a broker partition is pinned to (round-robin striping —
    /// the same rule the plugin's router uses, so placement is stable).
    pub fn site_of_partition(&self, partition: usize) -> &EdgeSite {
        &self.sites[partition % self.sites.len()]
    }

    /// Fleet-wide container capacity: the sum of per-site caps.  Resize
    /// targets beyond it surface as `ResizeSemantics::Throttle`.
    pub fn total_capacity(&self) -> usize {
        self.sites.iter().map(|s| s.max_concurrency).sum()
    }

    /// Waterfill `target` containers over the per-site caps: every site
    /// keeps at least one container (the data source lives on the box),
    /// then spare units land round-robin on sites with headroom.  The
    /// result is clamped to `[len(), total_capacity()]` and deterministic.
    pub fn distribute(&self, target: usize) -> Vec<usize> {
        let mut alloc = vec![1usize; self.sites.len()];
        let target = target.clamp(self.sites.len(), self.total_capacity());
        let mut remaining = target - self.sites.len();
        while remaining > 0 {
            let mut progressed = false;
            for (a, site) in alloc.iter_mut().zip(&self.sites) {
                if remaining == 0 {
                    break;
                }
                if *a < site.max_concurrency {
                    *a += 1;
                    remaining -= 1;
                    progressed = true;
                }
            }
            debug_assert!(progressed, "target was clamped to total_capacity");
            if !progressed {
                break;
            }
        }
        alloc
    }
}

/// A message class: the workload coordinates placement keys on.  Two
/// messages of the same (points, centroids) shape cost the same compute
/// and are routed identically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct MessageClass {
    /// Points per message (the paper's MS axis).
    pub points: usize,
    /// Model size (the paper's WC axis).
    pub centroids: usize,
}

impl MessageClass {
    pub fn of(points: usize, centroids: usize) -> Self {
        Self { points, centroids }
    }
}

/// How the placement layer routes one message class on one site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// The class passes the site's break-even: latency-bound, it stays on
    /// the box even when that means queueing for a container.
    EdgePinned,
    /// The class's cloud-side compute exceeds the site's break-even: it
    /// runs data-local while the site has free containers and spills to
    /// the cloud fallback (paying the backhaul round trip) on saturation.
    Spillable,
}

/// Per-class placement over heterogeneous sites, built on
/// [`EdgeSite::should_run_at_edge`].
///
/// Cloud-side compute costs are not known a priori: a class starts
/// data-local and every measured invocation feeds an EWMA of its
/// *cloud-equivalent* compute seconds.  Once the estimate crosses a
/// site's break-even, that site treats the class as [`Placement::Spillable`].
#[derive(Debug, Default)]
pub struct PlacementPolicy {
    // BTreeMap: estimate iteration order is the class order (ps-lint R2)
    estimates: BTreeMap<MessageClass, f64>,
}

impl PlacementPolicy {
    /// EWMA smoothing of the cloud-compute estimates.
    const ALPHA: f64 = 0.5;

    pub fn new() -> Self {
        Self::default()
    }

    /// The learned cloud-side compute estimate for `class`, if any
    /// invocation of it has been measured yet.
    pub fn cloud_compute_estimate(&self, class: MessageClass) -> Option<f64> {
        self.estimates.get(&class).copied()
    }

    /// Fold one measured cloud-side compute cost (seconds) into the
    /// class's estimate.
    pub fn observe_cloud_compute(&mut self, class: MessageClass, seconds: f64) {
        self.estimates
            .entry(class)
            .and_modify(|e| *e += Self::ALPHA * (seconds - *e))
            .or_insert(seconds);
    }

    /// Convert a compute cost measured on `site` silicon into its
    /// cloud-equivalent (same memory config, so only the per-core
    /// efficiency ratio differs) and fold it in.
    pub fn observe_edge_compute(&mut self, class: MessageClass, site: &EdgeSite, seconds: f64) {
        self.observe_cloud_compute(class, seconds * site.cpu_efficiency / LAMBDA_CPU_EFFICIENCY);
    }

    /// Route `class` on `site`: [`Placement::Spillable`] once the learned
    /// cloud cost exceeds the site's break-even, [`Placement::EdgePinned`]
    /// otherwise (including unmeasured classes — they start data-local).
    pub fn place(&self, site: &EdgeSite, config: &FunctionConfig, class: MessageClass) -> Placement {
        match self.cloud_compute_estimate(class) {
            Some(est) if !site.should_run_at_edge(config, est) => Placement::Spillable,
            _ => Placement::EdgePinned,
        }
    }
}

/// Conserved placement accounting: every routed message increments exactly
/// one counter, so `edge_total + spilled == total` always.
#[derive(Debug)]
pub struct PlacementStats {
    edge: Vec<AtomicU64>,
    spilled: AtomicU64,
    /// Total backhaul seconds charged to spilled messages, in nanoseconds
    /// (atomic-friendly fixed point).
    backhaul_ns: AtomicU64,
}

impl PlacementStats {
    pub fn new(sites: usize) -> Self {
        Self {
            edge: (0..sites).map(|_| AtomicU64::new(0)).collect(),
            spilled: AtomicU64::new(0),
            backhaul_ns: AtomicU64::new(0),
        }
    }

    pub fn record_edge(&self, site: usize) {
        self.edge[site].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one spill and the backhaul seconds it was charged.
    pub fn record_spill(&self, backhaul_s: f64) {
        self.spilled.fetch_add(1, Ordering::Relaxed);
        self.backhaul_ns
            .fetch_add((backhaul_s * 1e9).round() as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> PlacementSnapshot {
        PlacementSnapshot {
            edge_per_site: self.edge.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            spilled: self.spilled.load(Ordering::Relaxed),
            backhaul_seconds: self.backhaul_ns.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }
}

/// A point-in-time copy of [`PlacementStats`].
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementSnapshot {
    /// Messages served on each site's own containers.
    pub edge_per_site: Vec<u64>,
    /// Messages that overflowed a saturated site onto the backhaul.
    pub spilled: u64,
    /// Total backhaul seconds those spills were charged.
    pub backhaul_seconds: f64,
}

impl PlacementSnapshot {
    /// Messages served at the edge, across all sites.
    pub fn edge_total(&self) -> u64 {
        self.edge_per_site.iter().sum()
    }

    /// Every message routed — the conservation check's right-hand side.
    pub fn total(&self) -> u64 {
        self.edge_total() + self.spilled
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn provision_is_deterministic_and_heterogeneous() {
        let a = EdgeFleet::provision(4);
        let b = EdgeFleet::provision(4);
        assert_eq!(a.len(), 4);
        for (x, y) in a.sites().iter().zip(b.sites()) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.max_concurrency, y.max_concurrency);
            assert_eq!(x.cpu_efficiency, y.cpu_efficiency);
        }
        // genuinely heterogeneous: envelopes differ across sites
        let caps: Vec<usize> = a.sites().iter().map(|s| s.max_concurrency).collect();
        assert!(caps.windows(2).any(|w| w[0] != w[1]));
        let backhauls: Vec<f64> = a.sites().iter().map(|s| s.backhaul_latency).collect();
        assert!(backhauls.windows(2).any(|w| w[0] != w[1]));
        // site 0 is the reference envelope (one-site fleet == old edge)
        let reference = EdgeSite::default();
        assert_eq!(a.sites()[0].max_concurrency, reference.max_concurrency);
        assert_eq!(a.sites()[0].cpu_efficiency, reference.cpu_efficiency);
        assert_eq!(a.sites()[0].broker_latency, reference.broker_latency);
        assert_eq!(a.sites()[0].backhaul_latency, reference.backhaul_latency);
    }

    #[test]
    fn fleet_validation() {
        assert!(EdgeFleet::new(Vec::new()).is_err());
        let bad = EdgeSite {
            max_concurrency: 0,
            ..EdgeSite::default()
        };
        assert!(EdgeFleet::new(vec![bad]).is_err());
        assert_eq!(EdgeFleet::provision(0).len(), 1, "floored at one site");
        assert_eq!(
            EdgeFleet::provision(usize::MAX).len(),
            MAX_EDGE_SITES,
            "absurd site counts clamp instead of exhausting memory"
        );
    }

    #[test]
    fn distribute_waterfills_with_floor_and_cap() {
        let fleet = EdgeFleet::provision(3); // caps 4, 3, 4 = 11
        assert_eq!(fleet.total_capacity(), 11);
        assert_eq!(fleet.distribute(1), vec![1, 1, 1], "one container per site");
        assert_eq!(fleet.distribute(5), vec![2, 2, 1], "round-robin spare units");
        assert_eq!(fleet.distribute(11), vec![4, 3, 4]);
        assert_eq!(fleet.distribute(1_000), vec![4, 3, 4], "clamped at capacity");
        for target in 1..=14 {
            let alloc = fleet.distribute(target);
            assert_eq!(
                alloc.iter().sum::<usize>(),
                target.clamp(3, 11),
                "target {target}"
            );
            for (a, s) in alloc.iter().zip(fleet.sites()) {
                assert!((1..=s.max_concurrency).contains(a));
            }
        }
    }

    #[test]
    fn partitions_stripe_round_robin() {
        let fleet = EdgeFleet::provision(2);
        assert_eq!(fleet.site_of_partition(0).name, "edge-site-0");
        assert_eq!(fleet.site_of_partition(1).name, "edge-site-1");
        assert_eq!(fleet.site_of_partition(2).name, "edge-site-0");
    }

    #[test]
    fn placement_learns_per_class_and_per_site() {
        let fleet = EdgeFleet::provision(4);
        let strong = &fleet.sites()[2]; // 0.45 efficiency
        let weak = &fleet.sites()[3]; // 0.25 efficiency
        let config = FunctionConfig {
            memory_mb: 1_024,
            ..Default::default()
        };
        let light = MessageClass::of(256, 16);
        let heavy = MessageClass::of(26_000, 8_192);

        let mut policy = PlacementPolicy::new();
        // unmeasured classes start data-local on every site
        assert_eq!(policy.place(weak, &config, heavy), Placement::EdgePinned);

        // a light class stays pinned even once measured
        policy.observe_cloud_compute(light, 0.001);
        assert_eq!(policy.place(strong, &config, light), Placement::EdgePinned);
        assert_eq!(policy.place(weak, &config, light), Placement::EdgePinned);

        // a heavy class turns spillable — on the weaker site too
        policy.observe_cloud_compute(heavy, 0.5);
        assert_eq!(policy.place(strong, &config, heavy), Placement::Spillable);
        assert_eq!(policy.place(weak, &config, heavy), Placement::Spillable);

        // break-even heterogeneity: there is a cost band the strong site
        // keeps pinned while the weak site marks spillable
        let band = MessageClass::of(1_000, 64);
        let strong_be = strong.breakeven_compute_seconds(&config);
        let weak_be = weak.breakeven_compute_seconds(&config);
        assert!(weak_be < strong_be, "weaker silicon breaks even sooner");
        policy.observe_cloud_compute(band, (strong_be + weak_be) / 2.0);
        assert_eq!(policy.place(strong, &config, band), Placement::EdgePinned);
        assert_eq!(policy.place(weak, &config, band), Placement::Spillable);
    }

    #[test]
    fn edge_measurements_convert_to_cloud_equivalents() {
        let fleet = EdgeFleet::provision(1);
        let site = &fleet.sites()[0];
        let class = MessageClass::of(8_000, 1_024);
        let mut policy = PlacementPolicy::new();
        // 2 s measured on 0.35-efficiency silicon ≙ 1.4 s on cloud silicon
        policy.observe_edge_compute(class, site, 2.0);
        let est = policy.cloud_compute_estimate(class).unwrap();
        assert!((est - 2.0 * site.cpu_efficiency / LAMBDA_CPU_EFFICIENCY).abs() < 1e-12);
        // EWMA folds further observations instead of replacing them
        policy.observe_cloud_compute(class, 0.0);
        assert!((policy.cloud_compute_estimate(class).unwrap() - est / 2.0).abs() < 1e-12);
    }

    #[test]
    fn stats_conserve_messages() {
        let stats = PlacementStats::new(2);
        for _ in 0..5 {
            stats.record_edge(0);
        }
        for _ in 0..3 {
            stats.record_edge(1);
        }
        stats.record_spill(0.08);
        stats.record_spill(0.16);
        let snap = stats.snapshot();
        assert_eq!(snap.edge_per_site, vec![5, 3]);
        assert_eq!(snap.edge_total(), 8);
        assert_eq!(snap.total(), 10);
        assert_eq!(snap.total(), snap.edge_total() + snap.spilled);
        assert!((snap.backhaul_seconds - 0.24).abs() < 1e-9);
    }
}
