//! Log-scale latency histogram (HDR-style, base-10 sub-decades).
//!
//! Buckets span 1 µs .. ~1000 s with ~5% relative resolution, constant
//! memory, O(1) record.  Quantiles interpolate within the winning bucket.

/// Number of sub-buckets per decade (resolution ~ 10^(1/SUB) ≈ 5%).
const SUB: usize = 48;
/// Decades covered: 1e-6 .. 1e+3 seconds.
const DECADES: usize = 9;
const NBUCKETS: usize = SUB * DECADES + 2; // + underflow + overflow
const MIN_VALUE: f64 = 1e-6;
/// log2(1e-6), precomputed for the fast bucket path.
const LOG2_MIN_VALUE: f64 = -19.931568569324174;

/// log2(1 + m/128) for the top 7 mantissa bits (midpoint of each cell).
fn log2_lut() -> &'static [f64; 128] {
    use once_cell::sync::Lazy;
    static LUT: Lazy<[f64; 128]> = Lazy::new(|| {
        let mut t = [0.0; 128];
        for (i, slot) in t.iter_mut().enumerate() {
            *slot = (1.0 + (i as f64 + 0.5) / 128.0).log2();
        }
        t
    });
    &LUT
}

/// A fixed-memory log-scale histogram over positive values (seconds).
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; NBUCKETS],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_of(v: f64) -> usize {
        if v < MIN_VALUE {
            return 0; // underflow
        }
        // hot path: log10 via exponent extraction + a mantissa log2 LUT
        // (≈0.1% worst-case log error ≪ the 1/SUB bucket width); see
        // EXPERIMENTS.md §Perf — ~10x faster than f64::log10 here.
        let bits = v.to_bits();
        let exp = ((bits >> 52) & 0x7ff) as i64 - 1023;
        let mant = ((bits >> 45) & 0x7f) as usize; // top 7 mantissa bits
        let log2v = exp as f64 + log2_lut()[mant];
        // pos = (log2(v) - log2(MIN_VALUE)) * SUB * log10(2)
        const K: f64 = SUB as f64 * std::f64::consts::LOG10_2;
        let pos = (log2v - LOG2_MIN_VALUE) * K;
        let idx = pos.floor().max(0.0) as usize + 1;
        idx.min(NBUCKETS - 1)
    }

    /// Lower edge of bucket `i` (for interpolation/reporting).
    fn bucket_floor(i: usize) -> f64 {
        if i == 0 {
            return 0.0;
        }
        MIN_VALUE * 10f64.powf((i - 1) as f64 / SUB as f64)
    }

    pub fn record(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return;
        }
        self.counts[Self::bucket_of(v)] += 1;
        self.total += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Quantile in [0, 1] with intra-bucket linear interpolation.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = (q * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lo = Self::bucket_floor(i).max(self.min);
                let hi = Self::bucket_floor(i + 1).min(self.max.max(lo));
                let frac = (target - seen) as f64 / c as f64;
                return lo + (hi - lo) * frac;
            }
            seen += c;
        }
        self.max
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn mean_min_max_exact() {
        let mut h = Histogram::new();
        for v in [0.001, 0.002, 0.003] {
            h.record(v);
        }
        assert!((h.mean() - 0.002).abs() < 1e-12);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 0.003);
    }

    #[test]
    fn quantiles_within_resolution() {
        let mut h = Histogram::new();
        let mut rng = Pcg32::seeded(1);
        let mut vals: Vec<f64> = (0..100_000).map(|_| rng.lognormal(-4.0, 1.0)).collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.5, 0.9, 0.95, 0.99] {
            let exact = vals[((q * vals.len() as f64) as usize).min(vals.len() - 1)];
            let approx = h.quantile(q);
            assert!(
                (approx - exact).abs() / exact < 0.08,
                "q={q}: approx={approx} exact={exact}"
            );
        }
    }

    #[test]
    fn ignores_garbage() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
        h.record(-1.0);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn overflow_and_underflow_clamped() {
        let mut h = Histogram::new();
        h.record(1e-9); // underflow bucket
        h.record(1e6); // overflow bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) >= 1e3);
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut both = Histogram::new();
        let mut rng = Pcg32::seeded(2);
        for i in 0..10_000 {
            let v = rng.exponential(10.0);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert!((a.mean() - both.mean()).abs() < 1e-12);
        assert!((a.quantile(0.9) - both.quantile(0.9)).abs() / both.quantile(0.9) < 0.01);
    }
}
