//! Metrics: counters, gauges, latency histograms, and a registry with
//! JSON/CSV export — the Mini-App's "modular instrumentation system"
//! (paper §IV): components register metrics; the collector exports them
//! uniformly.

pub mod histogram;
pub mod registry;

pub use histogram::Histogram;
pub use registry::{MetricRegistry, Snapshot};
