//! Metric registry: named counters, gauges and histograms with JSON and
//! CSV export.  Components register metrics by dotted name
//! (`broker.put.latency`, `lambda.invocations`).

use super::histogram::Histogram;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Thread-safe metric registry (cheap to clone — shared state).
#[derive(Clone, Default)]
pub struct MetricRegistry {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicI64>>>,
    histograms: Mutex<BTreeMap<String, Arc<Mutex<Histogram>>>>,
}

impl MetricRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut g = self.inner.counters.lock().unwrap();
        Arc::clone(g.entry(name.to_string()).or_default())
    }

    pub fn inc(&self, name: &str) {
        self.counter(name).fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, name: &str, v: u64) {
        self.counter(name).fetch_add(v, Ordering::Relaxed);
    }

    pub fn gauge(&self, name: &str) -> Arc<AtomicI64> {
        let mut g = self.inner.gauges.lock().unwrap();
        Arc::clone(g.entry(name.to_string()).or_default())
    }

    pub fn set_gauge(&self, name: &str, v: i64) {
        self.gauge(name).store(v, Ordering::Relaxed);
    }

    pub fn histogram(&self, name: &str) -> Arc<Mutex<Histogram>> {
        let mut g = self.inner.histograms.lock().unwrap();
        Arc::clone(
            g.entry(name.to_string())
                .or_insert_with(|| Arc::new(Mutex::new(Histogram::new()))),
        )
    }

    pub fn observe(&self, name: &str, v: f64) {
        self.histogram(name).lock().unwrap().record(v);
    }

    /// Snapshot all metrics.
    pub fn snapshot(&self) -> Snapshot {
        let counters = self
            .inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let gauges = self
            .inner
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let histograms = self
            .inner
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.lock().unwrap().clone()))
            .collect();
        Snapshot {
            counters,
            gauges,
            histograms,
        }
    }
}

/// Point-in-time view of all metrics.
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, Histogram>,
}

impl Snapshot {
    pub fn to_json(&self) -> Json {
        let mut obj = Vec::new();
        for (k, v) in &self.counters {
            obj.push((k.as_str(), Json::from(*v as usize)));
        }
        for (k, v) in &self.gauges {
            obj.push((k.as_str(), Json::from(*v)));
        }
        let mut hmap: Vec<(String, Json)> = Vec::new();
        for (k, h) in &self.histograms {
            hmap.push((
                k.clone(),
                Json::obj(vec![
                    ("count", Json::from(h.count() as usize)),
                    ("mean", Json::from(h.mean())),
                    ("p50", Json::from(h.quantile(0.5))),
                    ("p95", Json::from(h.quantile(0.95))),
                    ("p99", Json::from(h.quantile(0.99))),
                    ("min", Json::from(h.min())),
                    ("max", Json::from(h.max())),
                ]),
            ));
        }
        let mut out: Vec<(&str, Json)> = obj;
        let hkeys: Vec<(String, Json)> = hmap;
        for (k, v) in &hkeys {
            out.push((k.as_str(), v.clone()));
        }
        Json::obj(out)
    }

    /// CSV with one row per histogram: name,count,mean,p50,p95,p99.
    pub fn histograms_csv(&self) -> String {
        let mut s = String::from("name,count,mean,p50,p95,p99,min,max\n");
        for (k, h) in &self.histograms {
            s.push_str(&format!(
                "{k},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6}\n",
                h.count(),
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.95),
                h.quantile(0.99),
                h.min(),
                h.max()
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = MetricRegistry::new();
        m.inc("a");
        m.inc("a");
        m.add("a", 3);
        m.set_gauge("g", -7);
        let s = m.snapshot();
        assert_eq!(s.counters["a"], 5);
        assert_eq!(s.gauges["g"], -7);
    }

    #[test]
    fn histograms_observe() {
        let m = MetricRegistry::new();
        for i in 1..=100 {
            m.observe("lat", i as f64 / 1000.0);
        }
        let s = m.snapshot();
        let h = &s.histograms["lat"];
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.0505).abs() < 1e-6);
    }

    #[test]
    fn shared_across_clones_and_threads() {
        let m = MetricRegistry::new();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.inc("hits");
                    m.observe("lat", 0.001);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.counters["hits"], 4000);
        assert_eq!(s.histograms["lat"].count(), 4000);
    }

    #[test]
    fn export_formats() {
        let m = MetricRegistry::new();
        m.inc("c");
        m.observe("h", 0.5);
        let s = m.snapshot();
        let j = s.to_json();
        assert_eq!(j.get("c").as_i64(), Some(1));
        assert_eq!(j.get("h").get("count").as_i64(), Some(1));
        let csv = s.histograms_csv();
        assert!(csv.starts_with("name,count"));
        assert!(csv.contains("h,1,"));
    }
}
