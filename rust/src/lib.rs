//! # Pilot-Streaming + StreamInsight
//!
//! A reproduction of *"Performance Characterization and Modeling of
//! Serverless and HPC Streaming Applications"* (Luckow & Jha, 2019) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the paper's systems: the *pilot
//!   abstraction* for unified resource management ([`pilot`]), built
//!   around a **plugin registry** — each platform (Kinesis, Kafka, Lambda,
//!   Dask, local, edge/Greengrass) is a
//!   [`PlatformPlugin`](pilot::PlatformPlugin) owning its naming,
//!   description validation, and provisioning, so
//!   [`PilotComputeService`](pilot::PilotComputeService) contains no
//!   platform-specific code and new platforms register without touching
//!   the service or drivers.  The platform substrates ([`broker`],
//!   [`serverless`] including the edge-site model, [`hpc`], [`store`]) are
//!   constructed *only* inside `pilot::plugins`.  The *Streaming Mini-App*
//!   measurement harness ([`miniapp`]) provisions its scenarios through
//!   the same Pilot-API, and the *StreamInsight* USL modeling stack
//!   ([`usl`], [`insight`]) characterizes every registered platform —
//!   including the paper's §V edge future work as a first-class scenario
//!   axis: a multi-site [`EdgeFleet`](serverless::EdgeFleet) of
//!   heterogeneous device envelopes with message-class placement and
//!   backhaul spillover (`serverless::edge_fleet`), provisioned from the
//!   `edge_sites` sweep axis.
//! - **Layer 2** — a JAX MiniBatch K-Means step (`python/compile/model.py`),
//!   AOT-lowered to HLO text artifacts loaded by [`runtime`].
//! - **Layer 1** — the Pallas assignment kernel
//!   (`python/compile/kernels/kmeans.py`), the O(n·c) hot spot.
//!
//! Python never runs on the request path: `make artifacts` lowers the model
//! once; the Rust binary executes it via PJRT ([`runtime`]) when built with
//! the `pjrt` feature (without it, live execution is stubbed and the
//! calibrated simulator drives everything).
//!
//! The repository README covers the layer map and quickstart;
//! `docs/ARCHITECTURE.md` documents the four extension seams —
//! [`PlatformPlugin`](pilot::PlatformPlugin) /
//! [`PluginRegistry`](pilot::PluginRegistry),
//! [`ScalingTarget`](insight::ScalingTarget) /
//! [`ControlLoop`](insight::ControlLoop),
//! [`Axis`](insight::Axis) / `Scenario::extra`, and
//! [`OnlineUslFitter`](insight::OnlineUslFitter) /
//! [`ScalingTarget::observe_interval`](insight::ScalingTarget::observe_interval)
//! (the online-recalibration feedback path) — with recipes and the
//! conformance tests that enforce them.

pub mod broker;
pub mod engine;
pub mod hpc;
pub mod insight;
pub mod kmeans;
pub mod metrics;
pub mod miniapp;
pub mod pilot;
pub mod runtime;
pub mod serverless;
pub mod sim;
pub mod store;
pub mod usl;
pub mod util;
pub mod workflow;
