//! # Pilot-Streaming + StreamInsight
//!
//! A reproduction of *"Performance Characterization and Modeling of
//! Serverless and HPC Streaming Applications"* (Luckow & Jha, 2019) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the paper's systems: the *pilot abstraction*
//!   for unified resource management across serverless/HPC ([`pilot`]), the
//!   platform substrates it manages ([`broker`], [`serverless`], [`hpc`],
//!   [`store`]), the *Streaming Mini-App* measurement harness ([`miniapp`]),
//!   and the *StreamInsight* USL-based performance modeling stack ([`usl`],
//!   [`insight`]).
//! - **Layer 2** — a JAX MiniBatch K-Means step (`python/compile/model.py`),
//!   AOT-lowered to HLO text artifacts loaded by [`runtime`].
//! - **Layer 1** — the Pallas assignment kernel
//!   (`python/compile/kernels/kmeans.py`), the O(n·c) hot spot.
//!
//! Python never runs on the request path: `make artifacts` lowers the model
//! once; the Rust binary executes it via PJRT ([`runtime`]).

pub mod broker;
pub mod engine;
pub mod hpc;
pub mod insight;
pub mod kmeans;
pub mod metrics;
pub mod miniapp;
pub mod pilot;
pub mod runtime;
pub mod serverless;
pub mod sim;
pub mod store;
pub mod usl;
pub mod util;
