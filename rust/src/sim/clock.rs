//! Time sources. All timestamps in the system are `f64` seconds since an
//! arbitrary epoch, so the same broker/consumer/metrics code runs in
//! *live* mode (wall clock, real threads, real PJRT executions per message)
//! and in *sim* mode (virtual clock advanced by the discrete-event engine).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A source of "now" in seconds.
pub trait Clock: Send + Sync {
    fn now(&self) -> f64;
}

/// Wall-clock time relative to creation.
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Virtual time, advanced explicitly by the simulation engine.
/// Stored as u64 nanoseconds in an atomic so threads may read it too.
#[derive(Default)]
pub struct SimClock {
    nanos: AtomicU64,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance_to(&self, t: f64) {
        let target = (t.max(0.0) * 1e9) as u64;
        // monotone: never move backwards
        self.nanos.fetch_max(target, Ordering::SeqCst);
    }
}

impl Clock for SimClock {
    fn now(&self) -> f64 {
        self.nanos.load(Ordering::SeqCst) as f64 / 1e9
    }
}

/// Shared, clonable clock handle.
pub type SharedClock = Arc<dyn Clock>;

pub fn wall() -> SharedClock {
    Arc::new(WallClock::new())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_monotone() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn sim_clock_advances_and_is_monotone() {
        let c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(1.5);
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance_to(1.0); // ignored, monotone
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance_to(2.0);
        assert!((c.now() - 2.0).abs() < 1e-9);
    }
}
