//! Event cohorts: the batched production unit of the sim core.
//!
//! A million-user scenario does not need a million distinct payload
//! allocations — every message in a (scenario, shard) production lane
//! carries the same-shaped minibatch, so the producer emits one **cohort**
//! per lane: a count, one shared payload slab (`Arc<[f32]>`), one
//! partitioning key and a contiguous id range.  Brokers admit cohort
//! records one at a time (so token-bucket/throttle timing is bit-identical
//! to the per-message path) but store them in struct-of-arrays
//! [`crate::broker::shard::RecordBatch`]es: the payload slab plus parallel
//! timestamp arrays, ~16 bytes per record instead of a `Message` clone.
//!
//! Cohorts also carry the answer to "where do ids come from": [`IdAlloc`]
//! derives the id stream from the run id, so two same-seed scenarios see
//! identical id sequences no matter what else ran in the process.

use crate::broker::{wire_bytes_for_flat, Message};
use crate::util::rng::SplitMix64;
use std::sync::Arc;

/// A batched production lane: `count` messages sharing one payload slab,
/// one key, and the contiguous id range `base_id .. base_id + count`.
#[derive(Debug, Clone)]
pub struct Cohort {
    /// Run the cohort belongs to (propagated into every record).
    pub run_id: u64,
    /// First message id; record `seq` has id `base_id + seq`.
    pub base_id: u64,
    /// Number of records in the cohort.
    pub count: usize,
    /// Partitioning key shared by every record (all records of a lane land
    /// on the same shard by construction).
    pub key: u64,
    /// Shared payload slab, row-major `[n_points, dim]`.
    pub points: Arc<[f32]>,
    /// Points per record.
    pub n_points: usize,
    /// Feature dimension.
    pub dim: usize,
}

impl Cohort {
    pub fn new(
        run_id: u64,
        base_id: u64,
        count: usize,
        key: u64,
        points: Arc<[f32]>,
        dim: usize,
    ) -> Self {
        assert!(dim > 0 && points.len() % dim == 0, "ragged payload");
        let n_points = points.len() / dim;
        Self {
            run_id,
            base_id,
            count,
            key,
            points,
            n_points,
            dim,
        }
    }

    /// Materialize record `seq` as a plain [`Message`] produced at
    /// `produced_at` (the slab is shared, not copied).
    pub fn message_at(&self, seq: usize, produced_at: f64) -> Message {
        debug_assert!(seq < self.count, "cohort seq {seq} out of {}", self.count);
        Message::with_id(
            self.base_id + seq as u64,
            self.run_id,
            self.key,
            Arc::clone(&self.points),
            self.dim,
            produced_at,
        )
    }

    /// Wire bytes of one record — identical to the per-message accounting,
    /// so broker rate limits see the same traffic either way.
    pub fn wire_bytes(&self) -> usize {
        wire_bytes_for_flat(self.points.len(), self.n_points)
    }
}

/// Per-run message-id allocator, seeded from the run id.
///
/// The high bit is set so sim-run ids never collide with the process-global
/// [`crate::broker::next_message_id`] counter used by live paths.
#[derive(Debug, Clone)]
pub struct IdAlloc {
    next: u64,
}

impl IdAlloc {
    /// Deterministic id stream for `run_id` (optionally salted per lane).
    pub fn for_run(run_id: u64, lane: u64) -> Self {
        let base = SplitMix64::new(run_id ^ lane.wrapping_mul(0x9e37_79b9_7f4a_7c15)).next_u64();
        Self {
            // leave headroom below u64::MAX for contiguous reservations
            next: (base >> 16) | (1 << 63),
        }
    }

    /// Allocate one id.
    pub fn next(&mut self) -> u64 {
        let id = self.next;
        self.next += 1;
        id
    }

    /// Reserve a contiguous range of `n` ids, returning the first.
    pub fn reserve(&mut self, n: usize) -> u64 {
        let base = self.next;
        self.next += n as u64;
        base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cohort_materializes_contiguous_ids() {
        let c = Cohort::new(7, 100, 4, 9, vec![0.0; 16].into(), 8);
        assert_eq!(c.n_points, 2);
        let m0 = c.message_at(0, 1.0);
        let m3 = c.message_at(3, 2.0);
        assert_eq!(m0.id, 100);
        assert_eq!(m3.id, 103);
        assert_eq!(m0.key, 9);
        assert!((m3.produced_at - 2.0).abs() < 1e-12);
        // the slab is shared, not copied
        assert!(Arc::ptr_eq(&m0.points, &c.points));
        assert_eq!(c.wire_bytes(), m0.wire_bytes());
    }

    #[test]
    fn id_alloc_is_deterministic_per_run() {
        let mut a = IdAlloc::for_run(42, 0);
        let mut b = IdAlloc::for_run(42, 0);
        let ids_a: Vec<u64> = (0..8).map(|_| a.next()).collect();
        let ids_b: Vec<u64> = (0..8).map(|_| b.next()).collect();
        assert_eq!(ids_a, ids_b);
        // different runs and lanes get different streams
        assert_ne!(IdAlloc::for_run(43, 0).next, ids_a[0]);
        assert_ne!(IdAlloc::for_run(42, 1).next, ids_a[0]);
        // sim ids sit above the process-global counter's range
        assert!(ids_a[0] & (1 << 63) != 0);
    }

    #[test]
    fn reserve_is_contiguous() {
        let mut a = IdAlloc::for_run(1, 2);
        let base = a.reserve(10);
        assert_eq!(a.next(), base + 10);
    }
}
