//! Service-time distributions for the simulator.
//!
//! Simulated executions draw task durations from these distributions; the
//! parameters are *calibrated* from live PJRT runs of the same HLO artifact
//! (see `runtime::calibrate`), so simulated compute cost tracks the real
//! kernel rather than made-up constants.

use crate::util::rng::Pcg32;

/// A positive duration distribution (seconds).
#[derive(Debug, Clone, PartialEq)]
pub enum Dist {
    /// Always the same value.
    Const(f64),
    /// Normal(mean, std) truncated at `min`.
    Normal { mean: f64, std: f64, min: f64 },
    /// LogNormal with underlying N(mu, sigma).
    LogNormal { mu: f64, sigma: f64 },
    /// Exponential with the given mean.
    Exponential { mean: f64 },
    /// Gamma(shape, scale).
    Gamma { shape: f64, scale: f64 },
    /// Uniform in [lo, hi).
    Uniform { lo: f64, hi: f64 },
}

impl Dist {
    pub fn sample(&self, rng: &mut Pcg32) -> f64 {
        match *self {
            Dist::Const(x) => x,
            Dist::Normal { mean, std, min } => rng.normal_with(mean, std).max(min),
            Dist::LogNormal { mu, sigma } => rng.lognormal(mu, sigma),
            Dist::Exponential { mean } => rng.exponential(1.0 / mean.max(1e-12)),
            Dist::Gamma { shape, scale } => rng.gamma(shape, scale),
            Dist::Uniform { lo, hi } => rng.uniform(lo, hi),
        }
    }

    pub fn mean(&self) -> f64 {
        match *self {
            Dist::Const(x) => x,
            Dist::Normal { mean, .. } => mean, // truncation bias ignored
            Dist::LogNormal { mu, sigma } => (mu + sigma * sigma / 2.0).exp(),
            Dist::Exponential { mean } => mean,
            Dist::Gamma { shape, scale } => shape * scale,
            Dist::Uniform { lo, hi } => 0.5 * (lo + hi),
        }
    }

    /// Build a distribution from an observed sample: a truncated normal
    /// matching the sample's mean/std (the calibration path).
    pub fn from_observations(xs: &[f64]) -> Dist {
        match crate::util::stats::Summary::of(xs) {
            None => Dist::Const(0.0),
            Some(s) if s.n == 1 || s.std == 0.0 => Dist::Const(s.mean),
            Some(s) => Dist::Normal {
                mean: s.mean,
                std: s.std,
                min: (s.mean - 3.0 * s.std).max(s.min * 0.5).max(0.0),
            },
        }
    }

    /// Scale the distribution by a multiplicative factor (e.g. the Lambda
    /// memory→CPU slowdown or a contention inflation).
    pub fn scaled(&self, k: f64) -> Dist {
        match *self {
            Dist::Const(x) => Dist::Const(x * k),
            Dist::Normal { mean, std, min } => Dist::Normal {
                mean: mean * k,
                std: std * k,
                min: min * k,
            },
            Dist::LogNormal { mu, sigma } => Dist::LogNormal {
                mu: mu + k.ln(),
                sigma,
            },
            Dist::Exponential { mean } => Dist::Exponential { mean: mean * k },
            Dist::Gamma { shape, scale } => Dist::Gamma {
                shape,
                scale: scale * k,
            },
            Dist::Uniform { lo, hi } => Dist::Uniform {
                lo: lo * k,
                hi: hi * k,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_mean(d: &Dist, n: usize, seed: u64) -> f64 {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64
    }

    #[test]
    fn const_dist() {
        let d = Dist::Const(2.5);
        assert_eq!(sample_mean(&d, 10, 1), 2.5);
        assert_eq!(d.mean(), 2.5);
    }

    #[test]
    fn normal_truncated_at_min() {
        let d = Dist::Normal {
            mean: 1.0,
            std: 10.0,
            min: 0.5,
        };
        let mut rng = Pcg32::seeded(2);
        for _ in 0..10_000 {
            assert!(d.sample(&mut rng) >= 0.5);
        }
    }

    #[test]
    fn means_match_analytic() {
        for (d, expect, tol) in [
            (Dist::Exponential { mean: 2.0 }, 2.0, 0.05),
            (Dist::Gamma { shape: 4.0, scale: 0.5 }, 2.0, 0.05),
            (Dist::Uniform { lo: 1.0, hi: 3.0 }, 2.0, 0.02),
            (Dist::LogNormal { mu: 0.0, sigma: 0.5 }, (0.125f64).exp(), 0.05),
        ] {
            let m = sample_mean(&d, 100_000, 3);
            assert!(
                (m - expect).abs() < tol,
                "{d:?}: sample mean {m} vs {expect}"
            );
            assert!((d.mean() - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn from_observations_matches_moments() {
        let xs = [1.0, 1.1, 0.9, 1.05, 0.95];
        let d = Dist::from_observations(&xs);
        match d {
            Dist::Normal { mean, .. } => assert!((mean - 1.0).abs() < 1e-9),
            _ => panic!("expected Normal"),
        }
        assert_eq!(Dist::from_observations(&[3.0]), Dist::Const(3.0));
        assert_eq!(Dist::from_observations(&[]), Dist::Const(0.0));
    }

    #[test]
    fn scaling_scales_mean() {
        for d in [
            Dist::Const(2.0),
            Dist::Exponential { mean: 2.0 },
            Dist::Gamma { shape: 2.0, scale: 1.0 },
            Dist::LogNormal { mu: 0.3, sigma: 0.4 },
            Dist::Uniform { lo: 1.0, hi: 3.0 },
        ] {
            let k = 2.5;
            let scaled = d.scaled(k);
            assert!(
                (scaled.mean() - d.mean() * k).abs() < 1e-9,
                "{d:?} scaled mean mismatch"
            );
        }
    }
}
