//! Shared-resource contention models.
//!
//! The paper attributes the HPC scalability collapse to *contention* on
//! shared resources (Lustre filesystem, network) and *coherency* cost from
//! all-to-all model synchronization — exactly the two USL terms.  This
//! module models the mechanism rather than curve-fitting the outcome:
//!
//! - [`SharedResource`] inflates service time as a function of concurrent
//!   users: `inflation(n) = 1 + alpha*(n-1) + beta*n*(n-1)`.  With
//!   `alpha = beta = 0` the resource is perfectly isolated (the serverless
//!   case); positive values reproduce the Dask/Kafka-on-Lustre behaviour.
//! - [`Bandwidth`] models a shared pipe: `n` concurrent transfers each get
//!   `capacity/n`.
//!
//! Contention state is tracked by *virtual* concurrency counters so the same
//! model works in live (threaded) and simulated executions.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Parameters of a contended resource.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ContentionParams {
    /// Linear (queueing/serialization) coefficient — the USL sigma mechanism.
    pub alpha: f64,
    /// Quadratic (all-to-all coherency) coefficient — the USL kappa mechanism.
    pub beta: f64,
}

impl ContentionParams {
    pub const ISOLATED: ContentionParams = ContentionParams {
        alpha: 0.0,
        beta: 0.0,
    };

    pub fn new(alpha: f64, beta: f64) -> Self {
        assert!(alpha >= 0.0 && beta >= 0.0);
        Self { alpha, beta }
    }

    /// Multiplicative service-time inflation for `n` concurrent users.
    pub fn inflation(&self, n: usize) -> f64 {
        if n <= 1 {
            return 1.0;
        }
        let nf = n as f64;
        1.0 + self.alpha * (nf - 1.0) + self.beta * nf * (nf - 1.0)
    }
}

/// A shared resource with a live concurrency counter.
pub struct SharedResource {
    name: String,
    params: ContentionParams,
    users: AtomicUsize,
    peak: AtomicUsize,
}

impl SharedResource {
    pub fn new(name: &str, params: ContentionParams) -> Arc<Self> {
        Arc::new(Self {
            name: name.to_string(),
            params,
            users: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn params(&self) -> ContentionParams {
        self.params
    }

    pub fn current_users(&self) -> usize {
        self.users.load(Ordering::SeqCst)
    }

    pub fn peak_users(&self) -> usize {
        self.peak.load(Ordering::SeqCst)
    }

    /// Enter the resource; returns a guard whose `inflation()` reflects the
    /// concurrency *including* this user. Dropping the guard leaves.
    pub fn enter(self: &Arc<Self>) -> ResourceGuard {
        let n = self.users.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak.fetch_max(n, Ordering::SeqCst);
        ResourceGuard {
            resource: Arc::clone(self),
            entered_with: n,
        }
    }

    /// Inflation if `n` users were active (pure function of the params).
    pub fn inflation_at(&self, n: usize) -> f64 {
        self.params.inflation(n)
    }
}

/// RAII guard for resource occupancy.
pub struct ResourceGuard {
    resource: Arc<SharedResource>,
    entered_with: usize,
}

impl ResourceGuard {
    /// Concurrency observed on entry (including self).
    pub fn concurrency(&self) -> usize {
        self.entered_with
    }

    /// Service-time inflation at entry concurrency.
    pub fn inflation(&self) -> f64 {
        self.resource.params.inflation(self.entered_with)
    }
}

impl Drop for ResourceGuard {
    fn drop(&mut self) {
        self.resource.users.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A shared bandwidth pipe: `n` concurrent transfers share `capacity`
/// bytes/second equally (processor-sharing approximation).
#[derive(Debug)]
pub struct Bandwidth {
    capacity_bps: f64,
    users: AtomicUsize,
}

impl Bandwidth {
    pub fn new(capacity_bps: f64) -> Arc<Self> {
        assert!(capacity_bps > 0.0);
        Arc::new(Self {
            capacity_bps,
            users: AtomicUsize::new(0),
        })
    }

    pub fn capacity(&self) -> f64 {
        self.capacity_bps
    }

    /// Transfer time for `bytes` at the *current* sharing level, counting
    /// this transfer.
    pub fn transfer_time(self: &Arc<Self>, bytes: f64) -> f64 {
        let n = (self.users.load(Ordering::SeqCst) + 1) as f64;
        bytes / (self.capacity_bps / n)
    }

    pub fn begin(self: &Arc<Self>) -> BandwidthGuard {
        self.users.fetch_add(1, Ordering::SeqCst);
        BandwidthGuard {
            bw: Arc::clone(self),
        }
    }
}

pub struct BandwidthGuard {
    bw: Arc<Bandwidth>,
}

impl Drop for BandwidthGuard {
    fn drop(&mut self) {
        self.bw.users.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isolated_never_inflates() {
        let p = ContentionParams::ISOLATED;
        for n in 1..100 {
            assert_eq!(p.inflation(n), 1.0);
        }
    }

    #[test]
    fn inflation_is_usl_shaped() {
        let p = ContentionParams::new(0.1, 0.01);
        assert_eq!(p.inflation(1), 1.0);
        assert!((p.inflation(2) - (1.0 + 0.1 + 0.02)).abs() < 1e-12);
        // superlinear growth: ratio of increments increases
        let d1 = p.inflation(3) - p.inflation(2);
        let d2 = p.inflation(10) - p.inflation(9);
        assert!(d2 > d1);
    }

    #[test]
    fn guards_track_concurrency() {
        let r = SharedResource::new("lustre", ContentionParams::new(0.5, 0.0));
        assert_eq!(r.current_users(), 0);
        let g1 = r.enter();
        let g2 = r.enter();
        assert_eq!(g1.concurrency(), 1);
        assert_eq!(g2.concurrency(), 2);
        assert_eq!(r.current_users(), 2);
        assert!((g2.inflation() - 1.5).abs() < 1e-12);
        drop(g1);
        assert_eq!(r.current_users(), 1);
        drop(g2);
        assert_eq!(r.current_users(), 0);
        assert_eq!(r.peak_users(), 2);
    }

    #[test]
    fn guards_are_thread_safe() {
        let r = SharedResource::new("net", ContentionParams::new(0.1, 0.0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let r = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    let _g = r.enter();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.current_users(), 0);
        assert!(r.peak_users() >= 1);
    }

    #[test]
    fn bandwidth_sharing() {
        let bw = Bandwidth::new(100.0);
        assert!((bw.transfer_time(100.0) - 1.0).abs() < 1e-12);
        let _g = bw.begin();
        // a second transfer sees half the capacity
        assert!((bw.transfer_time(100.0) - 2.0).abs() < 1e-12);
    }
}
