//! Discrete-event simulation substrate: virtual clock, event engine,
//! calibrated service-time distributions, and shared-resource contention
//! models.  See DESIGN.md "Execution modes" — large parameter sweeps run on
//! this engine with service times calibrated from live PJRT executions.

pub mod clock;
pub mod cohort;
pub mod contention;
pub mod dist;
pub mod engine;
pub mod faults;

pub use clock::{Clock, SharedClock, SimClock, WallClock};
pub use cohort::{Cohort, IdAlloc};
pub use faults::{
    FaultAccounting, FaultEvent, FaultKind, FaultPlan, FaultSchedule, RecoveryMetrics,
    RecoverySample, FAULTS_PARAM, FAULT_PRESET_IDS,
};

pub use contention::{Bandwidth, ContentionParams, SharedResource};
pub use dist::Dist;
pub use engine::Engine;
