//! Discrete-event simulation engine.
//!
//! A minimal, deterministic DES core: events are boxed closures scheduled at
//! virtual times; ties break by insertion sequence so runs are exactly
//! reproducible.  The engine owns a [`SimClock`] that passive components
//! (broker shards, metrics) share, so the same code observes consistent
//! timestamps in live and simulated executions.

use super::clock::SimClock;
use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::sync::Arc;

/// An event handler. Receives the engine so it can schedule follow-ups.
pub type Handler = Box<dyn FnOnce(&mut Engine)>;

struct Scheduled {
    time: f64,
    seq: u64,
    handler: Handler,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // min-heap: earlier time first; ties by lower sequence number
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(CmpOrdering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The DES engine.
pub struct Engine {
    queue: BinaryHeap<Scheduled>,
    clock: Arc<SimClock>,
    seq: u64,
    executed: u64,
    limit: Option<u64>,
}

impl Engine {
    pub fn new() -> Self {
        Self {
            queue: BinaryHeap::new(),
            clock: Arc::new(SimClock::new()),
            seq: 0,
            executed: 0,
            limit: None,
        }
    }

    /// Cap the number of events executed (runaway protection for tests).
    pub fn with_event_limit(mut self, limit: u64) -> Self {
        self.limit = Some(limit);
        self
    }

    /// The engine's shared virtual clock.
    pub fn clock(&self) -> Arc<SimClock> {
        Arc::clone(&self.clock)
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        use super::clock::Clock;
        self.clock.now()
    }

    /// Schedule `handler` to run at absolute virtual time `t` (>= now).
    pub fn schedule_at(&mut self, t: f64, handler: Handler) {
        let t = t.max(self.now());
        self.seq += 1;
        self.queue.push(Scheduled {
            time: t,
            seq: self.seq,
            handler,
        });
    }

    /// Schedule `handler` after a delay relative to now.
    pub fn schedule_in(&mut self, delay: f64, handler: Handler) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        let now = self.now();
        self.schedule_at(now + delay.max(0.0), handler);
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Number of pending events.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Run until the queue is empty or `until` (virtual seconds) is reached.
    /// Returns the final virtual time.
    pub fn run_until(&mut self, until: f64) -> f64 {
        while let Some(ev) = self.queue.peek() {
            if ev.time > until {
                break;
            }
            if let Some(limit) = self.limit {
                if self.executed >= limit {
                    log::warn!("sim event limit {limit} reached at t={}", self.now());
                    break;
                }
            }
            let ev = self.queue.pop().unwrap();
            self.clock.advance_to(ev.time);
            self.executed += 1;
            (ev.handler)(self);
        }
        if until.is_finite() {
            self.clock.advance_to(until.max(self.now()));
        }
        self.now()
    }

    /// Run to exhaustion.
    pub fn run(&mut self) -> f64 {
        self.run_until(f64::INFINITY)
    }
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn executes_in_time_order() {
        let mut e = Engine::new();
        let order = Rc::new(RefCell::new(Vec::new()));
        for (t, tag) in [(3.0, 'c'), (1.0, 'a'), (2.0, 'b')] {
            let o = Rc::clone(&order);
            e.schedule_at(t, Box::new(move |_| o.borrow_mut().push(tag)));
        }
        e.run();
        assert_eq!(*order.borrow(), vec!['a', 'b', 'c']);
        assert_eq!(e.executed(), 3);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut e = Engine::new();
        let order = Rc::new(RefCell::new(Vec::new()));
        for tag in 0..10 {
            let o = Rc::clone(&order);
            e.schedule_at(1.0, Box::new(move |_| o.borrow_mut().push(tag)));
        }
        e.run();
        assert_eq!(*order.borrow(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn handlers_can_schedule_followups() {
        let mut e = Engine::new();
        let hits = Rc::new(RefCell::new(0u32));
        fn tick(e: &mut Engine, hits: Rc<RefCell<u32>>, remaining: u32) {
            *hits.borrow_mut() += 1;
            if remaining > 0 {
                e.schedule_in(
                    1.0,
                    Box::new(move |e| tick(e, hits, remaining - 1)),
                );
            }
        }
        let h = Rc::clone(&hits);
        e.schedule_at(0.0, Box::new(move |e| tick(e, h, 4)));
        let end = e.run();
        assert_eq!(*hits.borrow(), 5);
        assert!((end - 4.0).abs() < 1e-9);
    }

    #[test]
    fn run_until_stops_early() {
        let mut e = Engine::new();
        let hits = Rc::new(RefCell::new(0u32));
        for t in 1..=10 {
            let h = Rc::clone(&hits);
            e.schedule_at(t as f64, Box::new(move |_| *h.borrow_mut() += 1));
        }
        e.run_until(5.0);
        assert_eq!(*hits.borrow(), 5);
        assert_eq!(e.pending(), 5);
        assert!((e.now() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn clock_visible_during_events() {
        let mut e = Engine::new();
        let seen = Rc::new(RefCell::new(0.0));
        let s = Rc::clone(&seen);
        e.schedule_at(2.5, Box::new(move |e| *s.borrow_mut() = e.now()));
        e.run();
        assert!((*seen.borrow() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn event_limit_guards_runaway() {
        let mut e = Engine::new().with_event_limit(100);
        fn forever(e: &mut Engine) {
            e.schedule_in(0.001, Box::new(forever));
        }
        e.schedule_at(0.0, Box::new(forever));
        e.run_until(1e9);
        assert_eq!(e.executed(), 100);
    }
}
