//! Seed-deterministic fault injection: the chaos axis.
//!
//! A [`FaultPlan`] is an injectable schedule of faults — edge-site
//! outage/rejoin, cold-start storm, broker hot-key skew, straggler
//! consumers, backhaul partition — that rides the campaign engine's
//! `[axes] faults = [...]` into [`Scenario::extra`] as a preset id, with
//! zero engine edits (the PR 2 extra-param seam).  The sim driver and the
//! live control loop both materialize the plan into a [`FaultSchedule`]:
//! every affected-shard draw and retry delay comes from [`crate::util::rng`]
//! seeded by `(scenario seed, plan id)`, so a fault campaign is
//! bit-reproducible — double-run and parallel-vs-sequential byte-identical,
//! gated in CI.
//!
//! # The accounting identity
//!
//! Faults may *delay* work, never lose it silently:
//!
//! ```text
//! dropped + delayed + served_clean == offered
//! ```
//!
//! [`FaultAccounting::verify`] backs the identity with `debug_assert!`s and
//! every fault test asserts it at every scale.  In the closed-loop sim
//! `dropped == 0` by construction: a produce attempt denied by an outage or
//! partition window counts a `denied_attempts` retry and the message lands
//! later as `delayed`.
//!
//! [`Scenario::extra`]: crate::miniapp::Scenario
//!
//! Recovery is measured, not assumed: [`RecoveryMetrics::from_series`]
//! computes time-to-detect, time-to-restore-goodput, and backlog area from
//! a per-tick trajectory, so `autoscale --live --faults <plan>` can prove
//! the recalibrating loop beats a stale static fit under every fault shape.

use crate::util::rng::Pcg32;

/// `Scenario::extra` key carrying the fault-plan preset id.
pub const FAULTS_PARAM: &str = "faults";

/// Mixing salt decorrelating fault draws from every other consumer of the
/// scenario seed (generator content, cold-start draws, cell derivation).
const FAULT_SEED_SALT: u64 = 0xFA17_5EED_0C4A_0517;

/// One fault shape.  Shares and factors are fixed at plan construction;
/// *which* shards a fault hits is drawn per run from the scenario seed
/// when the plan is materialized into a [`FaultSchedule`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// A fraction `share` of sites/shards goes dark, then rejoins.  Work
    /// routed to a dark shard is denied at produce time and retried.
    SiteOutage { share: f64 },
    /// Cold-start storm: every warm container is evicted, so each
    /// invocation pays the cold path — a fleet-wide service slowdown.
    ColdStorm { slowdown: f64 },
    /// Broker hot-key skew: one shard takes `share` of the traffic.
    HotKey { share: f64 },
    /// A fraction `share` of consumers runs `factor`x slower.
    Straggler { share: f64, factor: f64 },
    /// Backhaul partition: a fraction `share` of shards is unreachable
    /// behind the partition; their traffic is denied and retried.
    Partition { share: f64 },
}

impl FaultKind {
    /// Short stable label (CLI, CSV, bench reports).
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::SiteOutage { .. } => "site-outage",
            FaultKind::ColdStorm { .. } => "cold-storm",
            FaultKind::HotKey { .. } => "hot-key",
            FaultKind::Straggler { .. } => "straggler",
            FaultKind::Partition { .. } => "partition",
        }
    }

    /// Whether the fault denies produce attempts (vs slowing service).
    pub fn denies(&self) -> bool {
        matches!(
            self,
            FaultKind::SiteOutage { .. } | FaultKind::Partition { .. }
        )
    }

    /// Envelope-level goodput multiplier while the fault is active, as
    /// seen by the live control loop at parallelism `n`.  Hash routing
    /// keeps sending the affected share of traffic into the fault, so the
    /// multiplier applies even when the fleet is not capacity-bound.
    pub fn capacity_multiplier(&self, n: usize) -> f64 {
        let n = n.max(1) as f64;
        match *self {
            FaultKind::SiteOutage { share } => 1.0 - share,
            FaultKind::ColdStorm { slowdown } => 1.0 / slowdown.max(1.0),
            // the hot shard bounds throughput at (lane rate)/share; adding
            // lanes does not cool the key
            FaultKind::HotKey { share } => (1.0 / (share * n)).min(1.0),
            FaultKind::Straggler { share, factor } => {
                (1.0 - share) + share / factor.max(1.0)
            }
            FaultKind::Partition { share } => 1.0 - share,
        }
    }
}

/// One scheduled fault: a kind plus an active window expressed as
/// fractions of run progress in `[0, 1)` — sim runs measure progress in
/// committed messages, live loops in elapsed ticks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub kind: FaultKind,
    pub start: f64,
    pub end: f64,
}

impl FaultEvent {
    fn contains(&self, progress: f64) -> bool {
        progress >= self.start && progress < self.end
    }
}

/// A named, id-addressable schedule of [`FaultEvent`]s.  Id 0 is the
/// fair-weather plan; ids 1–5 are the named presets; any other id derives
/// a pseudo-random (but fully deterministic) plan from the id itself —
/// the property tests fuzz conservation across that unbounded space.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub id: u64,
    pub name: String,
    pub events: Vec<FaultEvent>,
}

/// The named preset ids, in menu order.
pub const FAULT_PRESET_IDS: [u64; 5] = [1, 2, 3, 4, 5];

impl FaultPlan {
    /// The fair-weather plan: no faults.
    pub fn none() -> Self {
        Self {
            id: 0,
            name: "none".to_string(),
            events: Vec::new(),
        }
    }

    /// Resolve a preset id: 0 = none, 1–5 = the named menu, anything else
    /// = a derived pseudo-random plan (see [`FaultPlan::derived`]).
    pub fn preset_by_id(id: u64) -> Self {
        let window = (0.3, 0.6);
        let (name, kind) = match id {
            0 => return Self::none(),
            1 => ("site-outage", FaultKind::SiteOutage { share: 0.5 }),
            2 => ("cold-storm", FaultKind::ColdStorm { slowdown: 2.5 }),
            3 => ("hot-key", FaultKind::HotKey { share: 0.6 }),
            4 => (
                "straggler",
                FaultKind::Straggler {
                    share: 0.5,
                    factor: 4.0,
                },
            ),
            5 => ("partition", FaultKind::Partition { share: 0.4 }),
            other => return Self::derived(other),
        };
        Self {
            id,
            name: name.to_string(),
            events: vec![FaultEvent {
                kind,
                start: window.0,
                end: window.1,
            }],
        }
    }

    /// Derive a deterministic pseudo-random plan from an arbitrary id:
    /// 1–3 events with random kinds, shares, and non-degenerate windows.
    /// Same id → same plan, always.
    pub fn derived(id: u64) -> Self {
        let mut rng = Pcg32::seeded(id ^ FAULT_SEED_SALT);
        let n = 1 + rng.gen_range(3) as usize;
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let kind = match rng.gen_range(5) {
                0 => FaultKind::SiteOutage {
                    share: rng.uniform(0.2, 0.8),
                },
                1 => FaultKind::ColdStorm {
                    slowdown: rng.uniform(1.5, 4.0),
                },
                2 => FaultKind::HotKey {
                    share: rng.uniform(0.4, 0.9),
                },
                3 => FaultKind::Straggler {
                    share: rng.uniform(0.2, 0.8),
                    factor: rng.uniform(2.0, 8.0),
                },
                _ => FaultKind::Partition {
                    share: rng.uniform(0.2, 0.7),
                },
            };
            let start = rng.uniform(0.1, 0.6);
            let end = (start + rng.uniform(0.1, 0.3)).min(0.95);
            events.push(FaultEvent { kind, start, end });
        }
        Self {
            id,
            name: format!("derived-{id}"),
            events,
        }
    }

    /// Parse a CLI spelling: a preset name (`site-outage`, `cold-storm`,
    /// `hot-key`, `straggler`, `partition`, `none`) or a numeric plan id.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        match s {
            "none" | "off" => Some(Self::none()),
            "site-outage" => Some(Self::preset_by_id(1)),
            "cold-storm" => Some(Self::preset_by_id(2)),
            "hot-key" => Some(Self::preset_by_id(3)),
            "straggler" => Some(Self::preset_by_id(4)),
            "partition" => Some(Self::preset_by_id(5)),
            other => other.parse::<u64>().ok().map(Self::preset_by_id),
        }
    }

    /// Whether the plan injects anything at all.
    pub fn is_active(&self) -> bool {
        !self.events.is_empty()
    }
}

/// A [`FaultPlan`] materialized against one run: the per-event affected
/// shard sets and retry delays, drawn once at construction from the
/// scenario seed.  Everything downstream is a pure function of
/// `(shard, progress)`, so the cohort and per-message sim paths see
/// identical fault decisions and stay bit-identical.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSchedule {
    plan: FaultPlan,
    partitions: usize,
    /// Affected local shard indices per event (sorted).
    affected: Vec<Vec<usize>>,
    /// Retry delay (seconds) a denied produce waits before re-presenting.
    retry: Vec<f64>,
}

impl FaultSchedule {
    pub fn new(plan: &FaultPlan, seed: u64, partitions: usize) -> Self {
        let p = partitions.max(1);
        let mut rng =
            Pcg32::seeded(seed ^ plan.id.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ FAULT_SEED_SALT);
        let mut affected = Vec::with_capacity(plan.events.len());
        let mut retry = Vec::with_capacity(plan.events.len());
        for ev in &plan.events {
            let shards = match ev.kind {
                // a deny-type fault must leave at least one shard serving,
                // or the closed loop would deadlock: with p == 1 the fault
                // degrades to a no-op (accounting still conserved)
                FaultKind::SiteOutage { share } | FaultKind::Partition { share } => {
                    if p < 2 {
                        Vec::new()
                    } else {
                        let k = ((share * p as f64).round() as usize).clamp(1, p - 1);
                        rng.sample_indices(p, k)
                    }
                }
                FaultKind::Straggler { share, .. } => {
                    let k = ((share * p as f64).round() as usize).clamp(1, p);
                    rng.sample_indices(p, k)
                }
                FaultKind::ColdStorm { .. } => (0..p).collect(),
                FaultKind::HotKey { .. } => vec![rng.gen_range(p as u64) as usize],
            };
            affected.push(shards);
            retry.push(rng.uniform(0.02, 0.08));
        }
        Self {
            plan: plan.clone(),
            partitions: p,
            affected,
            retry,
        }
    }

    /// Whether any fault is scheduled at all.
    pub fn is_active(&self) -> bool {
        self.plan.is_active()
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Affected local shard set of event `i` (sorted).
    pub fn affected_shards(&self, i: usize) -> &[usize] {
        &self.affected[i]
    }

    /// If `shard` is denied at `progress` (an active outage or partition
    /// window), the retry delay the producer must wait before
    /// re-presenting the message.  `None` means the put may proceed.
    pub fn deny_delay(&self, shard: usize, progress: f64) -> Option<f64> {
        for (i, ev) in self.plan.events.iter().enumerate() {
            if ev.kind.denies() && ev.contains(progress) && self.affected[i].contains(&shard) {
                return Some(self.retry[i]);
            }
        }
        None
    }

    /// Service-time multiplier for `shard` at `progress`: cold storms slow
    /// every shard, stragglers slow the affected subset.  Multiplicative
    /// across overlapping events; 1.0 in fair weather.
    pub fn service_multiplier(&self, shard: usize, progress: f64) -> f64 {
        let mut m = 1.0;
        for (i, ev) in self.plan.events.iter().enumerate() {
            if !ev.contains(progress) {
                continue;
            }
            match ev.kind {
                FaultKind::ColdStorm { slowdown } => m *= slowdown.max(1.0),
                FaultKind::Straggler { factor, .. } => {
                    if self.affected[i].contains(&shard) {
                        m *= factor.max(1.0);
                    }
                }
                _ => {}
            }
        }
        m
    }

    /// Apply hot-key skew to the per-shard message totals: the hot shard
    /// takes `share` of the run's traffic, the rest splits the remainder
    /// evenly.  The message count is conserved exactly.
    pub fn distribute(&self, totals: &mut [usize]) {
        let p = totals.len();
        if p < 2 {
            return;
        }
        let before: usize = totals.iter().sum();
        for (i, ev) in self.plan.events.iter().enumerate() {
            let FaultKind::HotKey { share } = ev.kind else {
                continue;
            };
            let sum: usize = totals.iter().sum();
            let hot = self.affected[i][0];
            let hot_take = (((share * sum as f64).round() as usize).max(1)).min(sum - (p - 1));
            let rest = sum - hot_take;
            let base = rest / (p - 1);
            let mut leftover = rest % (p - 1);
            for (s, t) in totals.iter_mut().enumerate() {
                if s == hot {
                    *t = hot_take;
                } else {
                    *t = base + usize::from(leftover > 0);
                    leftover = leftover.saturating_sub(1);
                }
            }
        }
        let after: usize = totals.iter().sum();
        debug_assert_eq!(
            before, after,
            "hot-key redistribution must conserve the message count"
        );
    }
}

/// Conserved per-run fault accounting.  Every offered message ends in
/// exactly one bucket: `served_clean` (untouched by any fault), `delayed`
/// (denied at least once, or served through a slowdown window), or
/// `dropped` (permanently lost — zero in the closed-loop sim, where every
/// denial retries).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultAccounting {
    pub offered: u64,
    pub served_clean: u64,
    pub delayed: u64,
    pub dropped: u64,
    /// Produce attempts rejected by an active fault window (each retried;
    /// an attempt is not a message, so this sits outside the identity).
    pub denied_attempts: u64,
}

impl FaultAccounting {
    /// The identity: `dropped + delayed + served_clean == offered`.
    pub fn conserved(&self) -> bool {
        self.dropped + self.delayed + self.served_clean == self.offered
    }

    /// `debug_assert!` the identity (call once the run has drained).
    pub fn verify(&self) {
        debug_assert!(
            self.conserved(),
            "fault accounting violated: dropped {} + delayed {} + served_clean {} != offered {}",
            self.dropped,
            self.delayed,
            self.served_clean,
            self.offered
        );
    }
}

/// One control-loop tick as seen by the recovery analyzer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoverySample {
    pub t: f64,
    pub offered_rate: f64,
    pub served_rate: f64,
    pub backlog: f64,
}

/// Per-fault recovery metrics, computed from a tick trajectory and the
/// fault's active window `[start, end)` in loop time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryMetrics {
    /// Seconds from fault start until served goodput visibly dips below
    /// the pre-fault baseline (`f64::INFINITY` if the fault never bites).
    pub time_to_detect: f64,
    /// Seconds from fault clear until the backlog drains back to steady
    /// state (`f64::INFINITY` if goodput is never restored).
    pub time_to_restore: f64,
    /// Integrated backlog (message-seconds) from fault start to restore —
    /// the total delay debt the fault incurred.
    pub backlog_area: f64,
}

impl RecoveryMetrics {
    /// Whether goodput came back at all.
    pub fn restored(&self) -> bool {
        self.time_to_restore.is_finite()
    }

    /// Analyze one fault window against a per-tick trajectory (samples
    /// must be in time order; uniform spacing is assumed for the area).
    pub fn from_series(series: &[RecoverySample], start: f64, end: f64) -> Self {
        let dt = if series.len() >= 2 {
            (series[1].t - series[0].t).max(1e-9)
        } else {
            1.0
        };
        let pre: Vec<&RecoverySample> = series.iter().filter(|s| s.t < start).collect();
        let baseline = if pre.is_empty() {
            series.first().map_or(0.0, |s| s.served_rate)
        } else {
            pre.iter().map(|s| s.served_rate).sum::<f64>() / pre.len() as f64
        };
        let time_to_detect = series
            .iter()
            .filter(|s| s.t >= start)
            .find(|s| s.served_rate < 0.9 * baseline)
            .map_or(f64::INFINITY, |s| s.t - start);
        let restore_at = series
            .iter()
            .filter(|s| s.t >= end)
            .find(|s| s.backlog <= (0.05 * s.offered_rate).max(1.0))
            .map(|s| s.t);
        let time_to_restore = restore_at.map_or(f64::INFINITY, |t| t - end);
        let horizon = restore_at.unwrap_or(f64::INFINITY);
        let backlog_area = series
            .iter()
            .filter(|s| s.t >= start && s.t <= horizon)
            .map(|s| s.backlog * dt)
            .sum();
        Self {
            time_to_detect,
            time_to_restore,
            backlog_area,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_menu_is_stable() {
        for id in FAULT_PRESET_IDS {
            let plan = FaultPlan::preset_by_id(id);
            assert_eq!(plan.id, id);
            assert_eq!(plan.events.len(), 1);
            let back = FaultPlan::parse(&plan.name).unwrap();
            assert_eq!(back, plan, "name {} must round-trip", plan.name);
        }
        assert!(!FaultPlan::none().is_active());
        assert_eq!(FaultPlan::parse("none").unwrap().id, 0);
        assert_eq!(FaultPlan::parse("7").unwrap().id, 7);
        assert!(FaultPlan::parse("no-such-fault").is_none());
    }

    #[test]
    fn derived_plans_are_deterministic_and_well_formed() {
        for id in [6u64, 99, 0xDEAD_BEEF, u64::MAX] {
            let a = FaultPlan::derived(id);
            let b = FaultPlan::derived(id);
            assert_eq!(a, b);
            assert!(a.is_active());
            for ev in &a.events {
                assert!(ev.start >= 0.0 && ev.end <= 1.0 && ev.start < ev.end);
            }
        }
    }

    #[test]
    fn schedule_is_deterministic_under_fixed_seed() {
        let plan = FaultPlan::preset_by_id(1);
        let a = FaultSchedule::new(&plan, 42, 8);
        let b = FaultSchedule::new(&plan, 42, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn deny_faults_always_leave_a_serving_shard() {
        for id in [1u64, 5] {
            let plan = FaultPlan::preset_by_id(id);
            for p in 2..=16 {
                let sched = FaultSchedule::new(&plan, 7, p);
                let denied = sched.affected_shards(0).len();
                assert!(denied >= 1 && denied < p, "p={p} denied={denied}");
                let free = (0..p).filter(|s| sched.deny_delay(*s, 0.45).is_none());
                assert!(free.count() >= 1);
            }
            // single shard: the fault degrades to a no-op, never a deadlock
            let sched = FaultSchedule::new(&plan, 7, 1);
            assert!(sched.deny_delay(0, 0.45).is_none());
        }
    }

    #[test]
    fn deny_windows_open_and_close() {
        let plan = FaultPlan::preset_by_id(1); // window [0.3, 0.6)
        let sched = FaultSchedule::new(&plan, 11, 4);
        let dark = sched.affected_shards(0)[0];
        assert!(sched.deny_delay(dark, 0.1).is_none(), "before the window");
        assert!(sched.deny_delay(dark, 0.45).is_some(), "inside the window");
        assert!(sched.deny_delay(dark, 0.7).is_none(), "after rejoin");
    }

    #[test]
    fn service_multiplier_composes() {
        let storm = FaultPlan::preset_by_id(2);
        let sched = FaultSchedule::new(&storm, 3, 4);
        assert_eq!(sched.service_multiplier(0, 0.1), 1.0);
        assert!(sched.service_multiplier(0, 0.45) > 2.0, "storm slows all");
        let strag = FaultPlan::preset_by_id(4);
        let sched = FaultSchedule::new(&strag, 3, 4);
        let slow = sched.affected_shards(0)[0];
        let fast = (0..4).find(|s| !sched.affected_shards(0).contains(s)).unwrap();
        assert!(sched.service_multiplier(slow, 0.45) >= 4.0);
        assert_eq!(sched.service_multiplier(fast, 0.45), 1.0);
    }

    #[test]
    fn hot_key_distribute_conserves_and_skews() {
        let plan = FaultPlan::preset_by_id(3); // share 0.6
        let sched = FaultSchedule::new(&plan, 21, 4);
        let mut totals = vec![25usize; 4];
        sched.distribute(&mut totals);
        assert_eq!(totals.iter().sum::<usize>(), 100);
        let hot = sched.affected_shards(0)[0];
        assert_eq!(totals[hot], 60);
        for (s, t) in totals.iter().enumerate() {
            if s != hot {
                assert!(*t >= 13 && *t <= 14, "cold shard {s} got {t}");
            }
        }
    }

    #[test]
    fn accounting_identity_holds() {
        let ok = FaultAccounting {
            offered: 10,
            served_clean: 7,
            delayed: 3,
            dropped: 0,
            denied_attempts: 5,
        };
        assert!(ok.conserved());
        ok.verify();
        let bad = FaultAccounting {
            offered: 10,
            served_clean: 7,
            delayed: 2,
            ..Default::default()
        };
        assert!(!bad.conserved());
    }

    #[test]
    fn capacity_multiplier_shapes() {
        assert!((FaultKind::SiteOutage { share: 0.5 }.capacity_multiplier(4) - 0.5).abs() < 1e-12);
        assert!((FaultKind::ColdStorm { slowdown: 2.0 }.capacity_multiplier(4) - 0.5).abs() < 1e-12);
        // hot key: adding lanes does not cool the key
        let hk = FaultKind::HotKey { share: 0.5 };
        assert!(hk.capacity_multiplier(2) >= hk.capacity_multiplier(8));
        assert!(hk.capacity_multiplier(1) <= 1.0);
        let st = FaultKind::Straggler { share: 0.5, factor: 4.0 };
        assert!((st.capacity_multiplier(4) - 0.625).abs() < 1e-12);
    }

    #[test]
    fn recovery_metrics_from_a_synthetic_dip() {
        // steady 100 msg/s; fault [10, 20) halves goodput; backlog grows
        // then drains by t=25
        let mut series = Vec::new();
        let mut backlog = 0.0f64;
        for t in 0..40 {
            let tf = t as f64;
            let served = if (10.0..20.0).contains(&tf) {
                50.0
            } else {
                (100.0 + backlog).min(200.0) // spare capacity drains backlog
            };
            backlog = (backlog + 100.0 - served).max(0.0);
            series.push(RecoverySample {
                t: tf,
                offered_rate: 100.0,
                served_rate: served,
                backlog,
            });
        }
        let m = RecoveryMetrics::from_series(&series, 10.0, 20.0);
        assert_eq!(m.time_to_detect, 0.0);
        assert!(m.restored());
        assert!(m.time_to_restore > 0.0 && m.time_to_restore < 15.0);
        assert!(m.backlog_area > 0.0);
        // a loop that never recovers
        let flat: Vec<RecoverySample> = (0..40)
            .map(|t| RecoverySample {
                t: t as f64,
                offered_rate: 100.0,
                served_rate: 50.0,
                backlog: 50.0 * t as f64,
            })
            .collect();
        let never = RecoveryMetrics::from_series(&flat, 10.0, 20.0);
        assert!(!never.restored());
    }
}
