//! PJRT runtime: loads the AOT-lowered HLO artifacts (`make artifacts`)
//! and executes them from the Rust request path — Python never runs here.
//!
//! - [`artifact`] — manifest contract with `python/compile/aot.py`
//! - [`server`] — runtime threads owning the (non-Send) PJRT client
//! - [`engine`] — [`PjrtEngine`], the live [`crate::engine::StepEngine`]
//! - [`calibrate`] — measure real exec times → simulator distributions

pub mod artifact;
pub mod calibrate;
pub mod engine;
pub mod server;

pub use artifact::{ArtifactError, Manifest, VariantMeta};
pub use calibrate::{calibrate, calibrated_engine, CalibrationRow};
pub use engine::PjrtEngine;
