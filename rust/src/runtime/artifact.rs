//! AOT artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust loader.  `artifacts/manifest.json` lists one HLO-text file per
//! (message-size, workload-complexity) shape variant of the K-Means step.

use crate::util::json::{self, Json};
use std::path::{Path, PathBuf};

#[derive(Debug, thiserror::Error)]
pub enum ArtifactError {
    #[error("cannot read {path}: {source}")]
    Io {
        path: PathBuf,
        source: std::io::Error,
    },
    #[error("manifest parse error: {0}")]
    Parse(String),
    #[error("unsupported manifest schema {0}")]
    Schema(i64),
}

/// One model variant's metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct VariantMeta {
    pub name: String,
    pub file: String,
    pub points: usize,
    pub centroids: usize,
    pub dim: usize,
}

impl VariantMeta {
    /// Absolute path of the HLO text file.
    pub fn path(&self, dir: &Path) -> PathBuf {
        dir.join(&self.file)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: Vec<VariantMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, ArtifactError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|source| ArtifactError::Io {
            path: path.clone(),
            source,
        })?;
        Self::parse(dir, &text)
    }

    /// Parse manifest JSON (separated for testability).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, ArtifactError> {
        let v = json::parse(text).map_err(|e| ArtifactError::Parse(e.to_string()))?;
        let schema = v.get("schema").as_i64().unwrap_or(-1);
        if schema != 1 {
            return Err(ArtifactError::Schema(schema));
        }
        let raw = v
            .get("variants")
            .as_arr()
            .ok_or_else(|| ArtifactError::Parse("missing variants".into()))?;
        let mut variants = Vec::with_capacity(raw.len());
        for (i, item) in raw.iter().enumerate() {
            let get_usize = |key: &str| {
                item.get(key)
                    .as_usize()
                    .ok_or_else(|| ArtifactError::Parse(format!("variant {i}: bad {key}")))
            };
            let get_str = |key: &str| {
                item.get(key)
                    .as_str()
                    .map(str::to_string)
                    .ok_or_else(|| ArtifactError::Parse(format!("variant {i}: bad {key}")))
            };
            variants.push(VariantMeta {
                name: get_str("name")?,
                file: get_str("file")?,
                points: get_usize("points")?,
                centroids: get_usize("centroids")?,
                dim: get_usize("dim")?,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            variants,
        })
    }

    /// Exact-match lookup by workload shape.
    pub fn find(&self, points: usize, centroids: usize) -> Option<&VariantMeta> {
        self.variants
            .iter()
            .find(|v| v.points == points && v.centroids == centroids)
    }

    pub fn by_name(&self, name: &str) -> Option<&VariantMeta> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// Default artifacts directory: `$PS_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("PS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Make sure every listed HLO file exists on disk.
    pub fn verify_files(&self) -> Result<(), ArtifactError> {
        for v in &self.variants {
            let p = v.path(&self.dir);
            if !p.exists() {
                return Err(ArtifactError::Io {
                    path: p,
                    source: std::io::Error::new(std::io::ErrorKind::NotFound, "missing artifact"),
                });
            }
        }
        Ok(())
    }
}

/// Convenience: load a manifest by conventional name for the Json value.
impl From<&VariantMeta> for Json {
    fn from(v: &VariantMeta) -> Json {
        Json::obj(vec![
            ("name", Json::from(v.name.as_str())),
            ("file", Json::from(v.file.as_str())),
            ("points", Json::from(v.points)),
            ("centroids", Json::from(v.centroids)),
            ("dim", Json::from(v.dim)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "schema": 1,
        "model": "minibatch_kmeans_step",
        "dim": 8,
        "variants": [
            {"name": "kmeans_n256_c16_d8", "file": "kmeans_n256_c16_d8.hlo.txt",
             "points": 256, "centroids": 16, "dim": 8,
             "inputs": [], "outputs": []},
            {"name": "kmeans_n8000_c1024_d8", "file": "kmeans_n8000_c1024_d8.hlo.txt",
             "points": 8000, "centroids": 1024, "dim": 8,
             "inputs": [], "outputs": []}
        ]
    }"#;

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.variants.len(), 2);
        let v = m.find(8000, 1024).unwrap();
        assert_eq!(v.name, "kmeans_n8000_c1024_d8");
        assert_eq!(v.path(&m.dir), Path::new("/tmp/a/kmeans_n8000_c1024_d8.hlo.txt"));
        assert!(m.find(9999, 1).is_none());
        assert!(m.by_name("kmeans_n256_c16_d8").is_some());
    }

    #[test]
    fn bad_schema_rejected() {
        let bad = SAMPLE.replace("\"schema\": 1", "\"schema\": 2");
        assert!(matches!(
            Manifest::parse(Path::new("."), &bad),
            Err(ArtifactError::Schema(2))
        ));
    }

    #[test]
    fn parse_real_manifest_if_present() {
        // integration sanity: when `make artifacts` has run, the real
        // manifest must parse and reference existing files.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find(8000, 1024).is_some(), "paper grid variant missing");
            m.verify_files().unwrap();
        }
    }
}
