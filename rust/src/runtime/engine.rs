//! [`PjrtEngine`]: the live [`StepEngine`] — every call executes the AOT
//! K-Means artifact on the PJRT CPU client.  Requests round-robin over a
//! small pool of runtime threads (see `server.rs` for why threads own the
//! clients).

use super::artifact::Manifest;
use super::server::{ExecReply, ExecRequest, RuntimeThread};
use crate::engine::{EngineError, StepEngine, StepResult};
use crate::store::ModelState;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// Live PJRT-backed step engine.
pub struct PjrtEngine {
    manifest: Manifest,
    threads: Vec<RuntimeThread>,
    next: AtomicUsize,
}

impl PjrtEngine {
    /// Start `pool_size` runtime threads serving `manifest`'s artifacts.
    pub fn new(manifest: Manifest, pool_size: usize) -> Self {
        assert!(pool_size > 0);
        let threads = (0..pool_size)
            .map(|_| RuntimeThread::spawn(manifest.clone()))
            .collect();
        Self {
            manifest,
            threads,
            next: AtomicUsize::new(0),
        }
    }

    /// Load from the default artifacts directory with one thread.
    pub fn from_default_dir() -> Result<Self, super::artifact::ArtifactError> {
        let manifest = Manifest::load(&Manifest::default_dir())?;
        Ok(Self::new(manifest, 1))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Raw variant execution (used by calibration, which needs the pure
    /// exec time without store/ModelState plumbing).
    pub fn execute_variant(
        &self,
        points: Arc<Vec<f32>>,
        centroids: Arc<Vec<f32>>,
        counts: Arc<Vec<f32>>,
        n_points: usize,
        n_centroids: usize,
    ) -> Result<ExecReply, EngineError> {
        let variant = self
            .manifest
            .find(n_points, n_centroids)
            .ok_or(EngineError::NoVariant {
                n_points,
                centroids: n_centroids,
            })?
            .clone();
        let (tx, rx) = mpsc::channel();
        let idx = self.next.fetch_add(1, Ordering::Relaxed) % self.threads.len();
        self.threads[idx]
            .sender()
            .send(ExecRequest {
                variant,
                points,
                centroids,
                counts,
                reply: tx,
            })
            .map_err(|_| EngineError::ExecutionFailed("runtime thread gone".into()))?;
        rx.recv()
            .map_err(|_| EngineError::ExecutionFailed("runtime reply dropped".into()))?
            .map_err(EngineError::ExecutionFailed)
    }
}

impl StepEngine for PjrtEngine {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn execute_step(
        &self,
        points: &[f32],
        dim: usize,
        model: &ModelState,
    ) -> Result<StepResult, EngineError> {
        if dim == 0 || points.len() % dim != 0 {
            return Err(EngineError::ShapeMismatch(format!(
                "len {} not divisible by dim {dim}",
                points.len()
            )));
        }
        let n_points = points.len() / dim;
        let reply = self.execute_variant(
            Arc::new(points.to_vec()),
            Arc::clone(&model.centroids),
            Arc::clone(&model.counts),
            n_points,
            model.num_centroids(),
        )?;
        Ok(StepResult {
            model: ModelState {
                centroids: Arc::new(reply.centroids),
                counts: Arc::new(reply.counts),
                dim,
                version: model.version,
            },
            inertia: reply.inertia,
            cpu_seconds: reply.exec_seconds,
        })
    }
}
