//! Calibration: measure real PJRT execution times per artifact variant and
//! turn them into the service-time distributions the simulator uses.
//!
//! This is the bridge between the live and simulated execution modes
//! (DESIGN.md §Execution modes): simulated compute cost is whatever the
//! real compiled kernel costs on this machine, not a made-up constant.

use super::engine::PjrtEngine;
use crate::engine::{CalibratedEngine, WorkloadKey};
use crate::sim::Dist;
use crate::util::rng::Pcg32;
use std::sync::Arc;

/// Measured calibration for one variant.
#[derive(Debug, Clone)]
pub struct CalibrationRow {
    pub key: WorkloadKey,
    pub samples: Vec<f64>,
    pub dist: Dist,
}

/// Run `reps` executions per variant (after one warm-up compile+run) and
/// fit service-time distributions.
pub fn calibrate(engine: &PjrtEngine, reps: usize, seed: u64) -> Vec<CalibrationRow> {
    let mut rng = Pcg32::seeded(seed);
    let mut rows = Vec::new();
    let variants: Vec<_> = engine.manifest().variants.clone();
    for v in variants {
        let points: Arc<Vec<f32>> = Arc::new(
            (0..v.points * v.dim)
                .map(|_| rng.normal() as f32)
                .collect(),
        );
        let centroids: Arc<Vec<f32>> = Arc::new(
            (0..v.centroids * v.dim)
                .map(|_| rng.normal() as f32 * 5.0)
                .collect(),
        );
        let counts: Arc<Vec<f32>> = Arc::new(vec![0.0; v.centroids]);

        // warm-up: compile + first run
        let warm = engine.execute_variant(
            Arc::clone(&points),
            Arc::clone(&centroids),
            Arc::clone(&counts),
            v.points,
            v.centroids,
        );
        if let Err(e) = warm {
            log::warn!("calibration skip {}: {e}", v.name);
            continue;
        }

        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps {
            match engine.execute_variant(
                Arc::clone(&points),
                Arc::clone(&centroids),
                Arc::clone(&counts),
                v.points,
                v.centroids,
            ) {
                Ok(r) => samples.push(r.exec_seconds),
                Err(e) => log::warn!("calibration rep failed for {}: {e}", v.name),
            }
        }
        if samples.is_empty() {
            continue;
        }
        let dist = Dist::from_observations(&samples);
        log::info!(
            "calibrated {}: mean {:.4}s over {} reps",
            v.name,
            dist.mean(),
            samples.len()
        );
        rows.push(CalibrationRow {
            key: (v.points, v.centroids),
            samples,
            dist,
        });
    }
    rows
}

/// Build a simulation engine from calibration rows.
pub fn calibrated_engine(rows: &[CalibrationRow], seed: u64) -> CalibratedEngine {
    let mut eng = CalibratedEngine::new(seed);
    for row in rows {
        eng.insert(row.key, row.dist.clone());
    }
    eng
}

/// Serialize rows for reuse (EXPERIMENTS.md provenance + offline sim runs).
pub fn to_json(rows: &[CalibrationRow]) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("points", Json::from(r.key.0)),
                    ("centroids", Json::from(r.key.1)),
                    ("mean_s", Json::from(r.dist.mean())),
                    (
                        "samples",
                        Json::Arr(r.samples.iter().map(|&s| Json::from(s)).collect()),
                    ),
                ])
            })
            .collect(),
    )
}

/// Load calibration rows back from JSON.
pub fn from_json(v: &crate::util::json::Json) -> Vec<CalibrationRow> {
    let mut rows = Vec::new();
    if let Some(arr) = v.as_arr() {
        for item in arr {
            let (Some(p), Some(c)) = (
                item.get("points").as_usize(),
                item.get("centroids").as_usize(),
            ) else {
                continue;
            };
            let samples: Vec<f64> = item
                .get("samples")
                .as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
                .unwrap_or_default();
            if samples.is_empty() {
                continue;
            }
            let dist = Dist::from_observations(&samples);
            rows.push(CalibrationRow {
                key: (p, c),
                samples,
                dist,
            });
        }
    }
    rows
}

/// A built-in fallback calibration (measured on the reference dev box, see
/// EXPERIMENTS.md §Perf) used when artifacts haven't been built — keeps the
/// simulation benches runnable standalone.
pub fn fallback_rows() -> Vec<CalibrationRow> {
    let table: &[(usize, usize, f64)] = &[
        (8_000, 128, 0.004),
        (8_000, 1_024, 0.022),
        (8_000, 8_192, 0.165),
        (16_000, 128, 0.008),
        (16_000, 1_024, 0.044),
        (16_000, 8_192, 0.330),
        (26_000, 128, 0.013),
        (26_000, 1_024, 0.072),
        (26_000, 8_192, 0.540),
        (256, 16, 0.0006),
    ];
    table
        .iter()
        .map(|&(p, c, mean)| {
            let samples = vec![mean * 0.97, mean, mean * 1.03];
            CalibrationRow {
                key: (p, c),
                dist: Dist::from_observations(&samples),
                samples,
            }
        })
        .collect()
}

/// Calibration rows from a JSON file if it exists, else the fallback.
pub fn load_or_fallback(path: &std::path::Path) -> Vec<CalibrationRow> {
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(v) = crate::util::json::parse(&text) {
            let rows = from_json(&v);
            if !rows.is_empty() {
                return rows;
            }
        }
    }
    fallback_rows()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fallback_covers_paper_grid() {
        let rows = fallback_rows();
        for p in [8_000, 16_000, 26_000] {
            for c in [128, 1_024, 8_192] {
                assert!(rows.iter().any(|r| r.key == (p, c)), "missing {p}x{c}");
            }
        }
    }

    #[test]
    fn json_roundtrip() {
        let rows = fallback_rows();
        let j = to_json(&rows);
        let back = from_json(&j);
        assert_eq!(back.len(), rows.len());
        for (a, b) in rows.iter().zip(&back) {
            assert_eq!(a.key, b.key);
            assert!((a.dist.mean() - b.dist.mean()).abs() < 1e-9);
        }
    }

    #[test]
    fn calibrated_engine_built_from_rows() {
        let rows = fallback_rows();
        let eng = calibrated_engine(&rows, 1);
        assert_eq!(eng.calibrated_keys().len(), rows.len());
    }

    #[test]
    fn fallback_costs_scale_with_work() {
        let rows = fallback_rows();
        let mean_of = |p: usize, c: usize| {
            rows.iter()
                .find(|r| r.key == (p, c))
                .unwrap()
                .dist
                .mean()
        };
        assert!(mean_of(8_000, 8_192) > mean_of(8_000, 128) * 10.0);
        assert!(mean_of(26_000, 1_024) > mean_of(8_000, 1_024) * 2.0);
    }
}
