//! PJRT execution threads.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`/`Sync`), so all
//! PJRT work happens on dedicated runtime threads that own their client and
//! compiled-executable cache; the rest of the system talks to them through
//! channels.  One request = one K-Means step on one message.

use super::artifact::{Manifest, VariantMeta};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
#[cfg(feature = "pjrt")]
use std::time::Instant;

/// A step-execution request.
pub struct ExecRequest {
    pub variant: VariantMeta,
    pub points: Arc<Vec<f32>>,
    pub centroids: Arc<Vec<f32>>,
    pub counts: Arc<Vec<f32>>,
    pub reply: mpsc::Sender<Result<ExecReply, String>>,
}

/// A step-execution result.
#[derive(Debug)]
pub struct ExecReply {
    pub centroids: Vec<f32>,
    pub counts: Vec<f32>,
    pub inertia: f64,
    /// Pure PJRT execute time (excludes channel/queueing overhead).
    pub exec_seconds: f64,
}

/// Handle to one runtime thread.
pub struct RuntimeThread {
    sender: mpsc::Sender<ExecRequest>,
    handle: Option<JoinHandle<()>>,
}

impl RuntimeThread {
    /// Spawn a runtime thread serving executions for `manifest`'s artifacts.
    pub fn spawn(manifest: Manifest) -> Self {
        let (tx, rx) = mpsc::channel::<ExecRequest>();
        // ps-lint: allow(thread-spawn): the PJRT runtime thread is a live OS resource, not sim concurrency; workers.rs owns sim-side threading
        let handle = std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || runtime_main(manifest, rx))
            .expect("spawn pjrt runtime thread");
        Self {
            sender: tx,
            handle: Some(handle),
        }
    }

    pub fn sender(&self) -> mpsc::Sender<ExecRequest> {
        self.sender.clone()
    }
}

impl Drop for RuntimeThread {
    fn drop(&mut self) {
        // closing the channel ends the thread's recv loop
        let (tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.sender, tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Without the `pjrt` cargo feature (which binds the `xla` crate), the
/// runtime thread drains requests with a clear error: tests and examples
/// that need artifacts skip themselves, and the calibrated simulator
/// covers everything else.
#[cfg(not(feature = "pjrt"))]
fn runtime_main(_manifest: Manifest, rx: mpsc::Receiver<ExecRequest>) {
    log::warn!("built without the `pjrt` feature; live artifact execution unavailable");
    for req in rx {
        let _ = req
            .reply
            .send(Err("built without the `pjrt` cargo feature".into()));
    }
}

#[cfg(feature = "pjrt")]
fn runtime_main(manifest: Manifest, rx: mpsc::Receiver<ExecRequest>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            log::error!("PJRT CPU client failed: {e}");
            // drain requests with errors so callers unblock
            for req in rx {
                let _ = req.reply.send(Err(format!("no PJRT client: {e}")));
            }
            return;
        }
    };
    log::debug!(
        "pjrt runtime up: platform={} devices={}",
        client.platform_name(),
        client.device_count()
    );
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    for req in rx {
        let result = serve_one(&client, &mut cache, &manifest, &req);
        let _ = req.reply.send(result);
    }
}

#[cfg(feature = "pjrt")]
fn serve_one(
    client: &xla::PjRtClient,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: &Manifest,
    req: &ExecRequest,
) -> Result<ExecReply, String> {
    let v = &req.variant;
    if !cache.contains_key(&v.name) {
        let path = v.path(&manifest.dir);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| format!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| format!("compile {}: {e}", v.name))?;
        log::info!(
            "compiled {} in {:.2}s",
            v.name,
            t0.elapsed().as_secs_f64()
        );
        cache.insert(v.name.clone(), exe);
    }
    let exe = cache.get(&v.name).unwrap();

    // shape checks before handing to XLA
    if req.points.len() != v.points * v.dim {
        return Err(format!(
            "points len {} != {}x{}",
            req.points.len(),
            v.points,
            v.dim
        ));
    }
    if req.centroids.len() != v.centroids * v.dim || req.counts.len() != v.centroids {
        return Err(format!(
            "model shape mismatch for {} (got {} centroids x {} dim)",
            v.name,
            req.counts.len(),
            if req.counts.is_empty() {
                0
            } else {
                req.centroids.len() / req.counts.len()
            },
        ));
    }

    let points = xla::Literal::vec1(req.points.as_slice())
        .reshape(&[v.points as i64, v.dim as i64])
        .map_err(|e| e.to_string())?;
    let centroids = xla::Literal::vec1(req.centroids.as_slice())
        .reshape(&[v.centroids as i64, v.dim as i64])
        .map_err(|e| e.to_string())?;
    let counts = xla::Literal::vec1(req.counts.as_slice());

    let t0 = Instant::now();
    let outs = exe
        .execute::<xla::Literal>(&[points, centroids, counts])
        .map_err(|e| format!("execute {}: {e}", v.name))?;
    let tuple = outs[0][0].to_literal_sync().map_err(|e| e.to_string())?;
    let exec_seconds = t0.elapsed().as_secs_f64();

    let (c_lit, n_lit, i_lit) = tuple.to_tuple3().map_err(|e| e.to_string())?;
    Ok(ExecReply {
        centroids: c_lit.to_vec::<f32>().map_err(|e| e.to_string())?,
        counts: n_lit.to_vec::<f32>().map_err(|e| e.to_string())?,
        inertia: i_lit
            .get_first_element::<f32>()
            .map_err(|e| e.to_string())? as f64,
        exec_seconds,
    })
}
