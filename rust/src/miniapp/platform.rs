//! The platform-under-test: one object bundling broker + processing
//! system for a benchmark scenario, so the sim and live drivers can treat
//! Kinesis/Lambda and Kafka/Dask uniformly.

use crate::broker::kafka::KafkaConfig;
use crate::broker::kinesis::ShardLimits;
use crate::broker::{Broker, KafkaTopic, KinesisStream};
use crate::engine::StepEngine;
use crate::hpc::DaskPool;
use crate::pilot::MachineKind;
use crate::serverless::{FunctionConfig, LambdaFleet};
use crate::sim::{ContentionParams, SharedClock, SharedResource};
use crate::store::shared_fs::{SharedFsParams, SharedFsStore};
use crate::store::ObjectStore;
use std::sync::Arc;

/// Which stack a scenario runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlatformKind {
    /// Kinesis broker + Lambda processing (AWS serverless).
    Lambda,
    /// Kafka broker + Dask processing on Wrangler.
    DaskWrangler,
    /// Kafka broker + Dask processing on Stampede2 KNL.
    DaskStampede2,
}

impl PlatformKind {
    pub fn label(self) -> &'static str {
        match self {
            Self::Lambda => "kinesis/lambda",
            Self::DaskWrangler => "kafka/dask(wrangler)",
            Self::DaskStampede2 => "kafka/dask(stampede2)",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "lambda" | "kinesis/lambda" | "serverless" => Some(Self::Lambda),
            "dask" | "wrangler" | "kafka/dask" => Some(Self::DaskWrangler),
            "stampede2" | "knl" => Some(Self::DaskStampede2),
            _ => None,
        }
    }

    pub fn is_serverless(self) -> bool {
        matches!(self, Self::Lambda)
    }
}

/// One benchmark configuration (a point in the paper's parameter space).
#[derive(Debug, Clone)]
pub struct Scenario {
    pub platform: PlatformKind,
    /// N^px(p): partitions == max processing parallelism.
    pub partitions: usize,
    /// MS axis: points per message.
    pub points_per_message: usize,
    /// WC axis: number of centroids.
    pub centroids: usize,
    /// Lambda container memory (ignored on Dask).
    pub memory_mb: u32,
    /// Messages to process in the measurement window.
    pub messages: usize,
    /// Lustre contention (Dask only; Lambda is isolated by construction).
    pub lustre: ContentionParams,
    pub seed: u64,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            platform: PlatformKind::Lambda,
            partitions: 4,
            points_per_message: 8_000,
            centroids: 1_024,
            memory_mb: 3_008,
            messages: 64,
            lustre: ContentionParams::new(
                crate::pilot::plugins::hpc::DEFAULT_LUSTRE_ALPHA,
                crate::pilot::plugins::hpc::DEFAULT_LUSTRE_BETA,
            ),
            seed: 42,
        }
    }
}

/// The instantiated platform: broker + processor.
pub enum PlatformUnderTest {
    Lambda {
        stream: Arc<KinesisStream>,
        fleet: Arc<LambdaFleet>,
    },
    Dask {
        topic: Arc<KafkaTopic>,
        pool: Arc<DaskPool>,
    },
}

/// Breakdown of one processed message.
#[derive(Debug, Clone, Copy)]
pub struct ProcessCost {
    pub compute: f64,
    pub io: f64,
    pub overhead: f64,
}

impl ProcessCost {
    pub fn total(&self) -> f64 {
        self.compute + self.io + self.overhead
    }
}

impl PlatformUnderTest {
    /// Build the platform for `scenario` on `clock` with `engine`.
    pub fn build(
        scenario: &Scenario,
        engine: Arc<dyn StepEngine>,
        clock: SharedClock,
    ) -> Result<Self, String> {
        match scenario.platform {
            PlatformKind::Lambda => {
                let stream = Arc::new(KinesisStream::new(
                    "mini-app",
                    scenario.partitions,
                    ShardLimits::default(),
                    Arc::clone(&clock),
                ));
                let config = FunctionConfig {
                    memory_mb: scenario.memory_mb,
                    timeout_s: crate::serverless::MAX_WALLTIME_S,
                    package_mb: 50.0,
                    // AWS never runs more containers than shards; the paper
                    // additionally observed at most 30 concurrent containers
                    max_concurrency: scenario.partitions.min(30),
                };
                let fleet = Arc::new(LambdaFleet::new(
                    config,
                    engine,
                    Arc::new(ObjectStore::default()),
                    clock,
                    scenario.seed,
                )?);
                Ok(Self::Lambda { stream, fleet })
            }
            PlatformKind::DaskWrangler | PlatformKind::DaskStampede2 => {
                let machine = match scenario.platform {
                    PlatformKind::DaskStampede2 => MachineKind::Stampede2,
                    _ => MachineKind::Wrangler,
                }
                .machine(64);
                if scenario.partitions > machine.max_workers() {
                    return Err(format!(
                        "{} workers exceed machine capacity {}",
                        scenario.partitions,
                        machine.max_workers()
                    ));
                }
                // the broker log and the model store share the same Lustre
                let fs = SharedResource::new("lustre", scenario.lustre);
                let topic = Arc::new(KafkaTopic::new(
                    "mini-app",
                    scenario.partitions,
                    KafkaConfig::default(),
                    clock,
                    Arc::clone(&fs),
                ));
                let store = Arc::new(SharedFsStore::new(SharedFsParams::default(), fs));
                let pool = Arc::new(DaskPool::new(
                    machine,
                    scenario.partitions,
                    engine,
                    store,
                    scenario.seed,
                ));
                Ok(Self::Dask { topic, pool })
            }
        }
    }

    pub fn broker(&self) -> Arc<dyn Broker> {
        match self {
            Self::Lambda { stream, .. } => Arc::clone(stream) as Arc<dyn Broker>,
            Self::Dask { topic, .. } => Arc::clone(topic) as Arc<dyn Broker>,
        }
    }

    /// Process one message's points on `partition`; returns the modeled
    /// cost breakdown.
    pub fn process(
        &self,
        partition: usize,
        points: &[f32],
        dim: usize,
        model_key: &str,
        centroids: usize,
    ) -> Result<ProcessCost, String> {
        match self {
            Self::Lambda { fleet, .. } => {
                let r = fleet
                    .invoke(points, dim, model_key, centroids)
                    .map_err(|e| e.to_string())?;
                Ok(ProcessCost {
                    compute: r.compute,
                    io: r.io_get + r.io_put,
                    overhead: r.cold_start,
                })
            }
            Self::Dask { pool, .. } => {
                let r = pool
                    .process(partition, points, dim, model_key, centroids)
                    .map_err(|e| e.to_string())?;
                Ok(ProcessCost {
                    compute: r.compute,
                    io: r.io_get + r.io_put,
                    overhead: r.sync,
                })
            }
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Lambda { .. } => "kinesis/lambda",
            Self::Dask { .. } => "kafka/dask",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::sim::SimClock;

    fn engine() -> Arc<dyn StepEngine> {
        Arc::new(CalibratedEngine::new(1))
    }

    #[test]
    fn builds_both_platforms() {
        let clock = Arc::new(SimClock::new()) as SharedClock;
        let s = Scenario::default();
        let lambda = PlatformUnderTest::build(&s, engine(), Arc::clone(&clock)).unwrap();
        assert_eq!(lambda.broker().kind(), "kinesis");
        let s2 = Scenario {
            platform: PlatformKind::DaskWrangler,
            ..s
        };
        let dask = PlatformUnderTest::build(&s2, engine(), clock).unwrap();
        assert_eq!(dask.broker().kind(), "kafka");
    }

    #[test]
    fn process_works_on_both() {
        let clock = Arc::new(SimClock::new()) as SharedClock;
        let pts = vec![0.1f32; 100 * 8];
        for platform in [PlatformKind::Lambda, PlatformKind::DaskWrangler] {
            let s = Scenario {
                platform,
                centroids: 16,
                ..Default::default()
            };
            let p = PlatformUnderTest::build(&s, engine(), Arc::clone(&clock)).unwrap();
            let cost = p.process(0, &pts, 8, "m", 16).unwrap();
            assert!(cost.total() > 0.0, "{platform:?}");
        }
    }

    #[test]
    fn platform_kind_parsing() {
        assert_eq!(PlatformKind::parse("lambda"), Some(PlatformKind::Lambda));
        assert_eq!(PlatformKind::parse("DASK"), Some(PlatformKind::DaskWrangler));
        assert_eq!(
            PlatformKind::parse("stampede2"),
            Some(PlatformKind::DaskStampede2)
        );
        assert_eq!(PlatformKind::parse("flink"), None);
    }

    #[test]
    fn dask_capacity_checked() {
        let clock = Arc::new(SimClock::new()) as SharedClock;
        let s = Scenario {
            platform: PlatformKind::DaskWrangler,
            partitions: 10_000,
            ..Default::default()
        };
        assert!(PlatformUnderTest::build(&s, engine(), clock).is_err());
    }
}
