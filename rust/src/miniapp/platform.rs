//! The platform-under-test: one object bundling broker + processing
//! system for a benchmark scenario, so the sim and live drivers can treat
//! Kinesis/Lambda, Kafka/Dask, and edge/Greengrass uniformly.
//!
//! Provisioning goes through the **Pilot-API**: a [`Scenario`] expands into
//! [`PilotDescription`]s ([`Scenario::pilot_descriptions`]) and one
//! [`PilotComputeService`] provisions them via the plugin registry.  The
//! mini-app holds only the resulting capability handles — the broker and
//! the [`StreamProcessor`] — and contains no platform-specific
//! construction code (that lives in `pilot::plugins`).

use crate::broker::Broker;
use crate::engine::StepEngine;
use crate::pilot::processor::StreamProcessor;
use crate::pilot::{PilotComputeService, PilotDescription, PilotJob, Platform};
use crate::sim::{ContentionParams, SharedClock, SharedResource};
use std::sync::Arc;

// Re-exported through `miniapp` for driver/backwards compatibility.
pub use crate::pilot::processor::ProcessCost;

/// Which stack a scenario runs on.
///
/// The four named stacks are the paper's measured deployments; any *other*
/// registered streaming plugin is addressable through
/// [`PlatformKind::Plugin`] — naming is owned by the pilot layer's
/// [`PluginRegistry`](crate::pilot::PluginRegistry) (the single source of
/// truth [`PlatformKind::parse`] consults), so registering a plugin is all
/// it takes to reach it from scenarios, sweeps, and TOML configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlatformKind {
    /// Kinesis broker + Lambda processing (AWS serverless).
    Lambda,
    /// Kafka broker + Dask processing on Wrangler.
    DaskWrangler,
    /// Kafka broker + Dask processing on Stampede2 KNL.
    DaskStampede2,
    /// Greengrass-class edge site: co-located local broker + constrained
    /// Lambda-compatible fleet (paper §V future work).
    Edge,
    /// Any other registered streaming plugin (e.g. the flink micro-batch
    /// platform): provisioned as a Kinesis broker + that platform's
    /// processing pilot.
    Plugin(Platform),
    /// A **broker-driven** stack: the named broker pilot (kinesis, kafka)
    /// fronts its ecosystem's default processing platform (kinesis →
    /// lambda, kafka → dask), and the *broker's* shard count is the
    /// control loop's resize target — `autoscale --live --platform
    /// kafka|kinesis` turns the broker plugins' `set_shards` /
    /// `set_partitions` repartition plans into first-class loop
    /// actuations, with the compute fleet tracking the shard count
    /// (consumers == shards).
    Broker(Platform),
}

impl PlatformKind {
    pub fn label(self) -> &'static str {
        match self {
            Self::Lambda => "kinesis/lambda",
            Self::DaskWrangler => "kafka/dask(wrangler)",
            Self::DaskStampede2 => "kafka/dask(stampede2)",
            Self::Edge => "edge/greengrass",
            Self::Plugin(p) | Self::Broker(p) => p.name(),
        }
    }

    /// Resolve a user-facing stack name.  Only the composite stack labels
    /// (and the HPC machine variants) are matched here; *platform* naming
    /// — canonical names and every alias — delegates to the plugin
    /// registry, so a newly registered streaming plugin parses with zero
    /// edits to this module.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "kinesis/lambda" => return Some(Self::Lambda),
            "wrangler" | "kafka/dask" | "kafka/dask(wrangler)" => {
                return Some(Self::DaskWrangler)
            }
            "stampede2" | "knl" | "kafka/dask(stampede2)" => return Some(Self::DaskStampede2),
            "edge/greengrass" => return Some(Self::Edge),
            _ => {}
        }
        let registry = crate::pilot::default_registry();
        let platform = registry.parse(s)?;
        Some(match platform {
            Platform::LAMBDA => Self::Lambda,
            Platform::DASK => Self::DaskWrangler,
            Platform::EDGE => Self::Edge,
            other if registry.get(other).is_some_and(|p| p.streams()) => Self::Plugin(other),
            // pure broker plugins anchor a broker-driven stack: the
            // broker's shard count becomes the loop's resize target
            other if registry.get(other).is_some_and(|p| p.provisions_broker()) => {
                Self::Broker(other)
            }
            _ => return None, // bag-of-tasks pools don't stream
        })
    }

    /// The processing platform this stack provisions.
    pub fn processing_platform(self) -> Platform {
        match self {
            Self::Lambda => Platform::LAMBDA,
            Self::DaskWrangler | Self::DaskStampede2 => Platform::DASK,
            Self::Edge => Platform::EDGE,
            Self::Plugin(p) => p,
            Self::Broker(b) => {
                if b == Platform::KAFKA {
                    Platform::DASK
                } else {
                    Platform::LAMBDA
                }
            }
        }
    }

    /// For broker-driven stacks, the broker platform whose shard count the
    /// control loop reshards; `None` for every compute-anchored stack.
    pub fn broker_driven(self) -> Option<Platform> {
        match self {
            Self::Broker(b) => Some(b),
            _ => None,
        }
    }

    pub fn is_serverless(self) -> bool {
        matches!(self, Self::Lambda | Self::Edge)
    }
}

/// One benchmark configuration (a point in the paper's parameter space).
#[derive(Debug, Clone)]
pub struct Scenario {
    pub platform: PlatformKind,
    /// N^px(p): partitions == max processing parallelism.
    pub partitions: usize,
    /// MS axis: points per message.
    pub points_per_message: usize,
    /// WC axis: number of centroids.
    pub centroids: usize,
    /// Lambda container memory (ignored on Dask; clamped to the device
    /// envelope on the edge so the axis stays shared across platforms).
    pub memory_mb: u32,
    /// Messages to process in the measurement window.
    pub messages: usize,
    /// Lustre contention (Dask only; Lambda is isolated by construction).
    pub lustre: ContentionParams,
    pub seed: u64,
    /// Extension parameters bound by non-canonical sweep axes (see
    /// `insight::experiment`).  Platform plugins and custom analyses look
    /// their axis up by name; the core fields above stay typed.
    pub extra: Vec<(String, u64)>,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            platform: PlatformKind::Lambda,
            partitions: 4,
            points_per_message: 8_000,
            centroids: 1_024,
            memory_mb: 3_008,
            messages: 64,
            lustre: ContentionParams::new(
                crate::pilot::plugins::hpc::DEFAULT_LUSTRE_ALPHA,
                crate::pilot::plugins::hpc::DEFAULT_LUSTRE_BETA,
            ),
            seed: 42,
            extra: Vec::new(),
        }
    }
}

impl Scenario {
    /// Look up an extension parameter bound by a non-canonical sweep axis.
    pub fn extra_param(&self, name: &str) -> Option<u64> {
        self.extra.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    /// Set (or replace) an extension parameter.
    pub fn set_extra(&mut self, name: &str, value: u64) {
        match self.extra.iter_mut().find(|(n, _)| n == name) {
            Some(slot) => slot.1 = value,
            None => self.extra.push((name.to_string(), value)),
        }
    }

    /// Deterministic 64-bit key of this configuration — the sim driver's
    /// run id.  Derived from every field (FNV-1a over a canonical
    /// serialization), so two same-seed runs of the same scenario share a
    /// run id — and therefore identical message-id streams — no matter
    /// what else ran in the process, while any config change moves it.
    pub fn run_key(&self) -> u64 {
        fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
            h
        }
        let mut h = fnv(0xcbf2_9ce4_8422_2325, self.platform.label().as_bytes());
        for v in [
            self.partitions as u64,
            self.points_per_message as u64,
            self.centroids as u64,
            self.memory_mb as u64,
            self.messages as u64,
            self.seed,
            self.lustre.alpha.to_bits(),
            self.lustre.beta.to_bits(),
        ] {
            h = fnv(h, &v.to_le_bytes());
        }
        for (name, v) in &self.extra {
            h = fnv(h, name.as_bytes());
            h = fnv(h, &v.to_le_bytes());
        }
        h | 1 // run ids are nonzero
    }

    /// Expand into the pilot descriptions this scenario provisions:
    /// broker + processing pilots for the cloud/HPC stacks, one co-located
    /// pilot for the edge (its broker lives on the device).
    pub fn pilot_descriptions(&self) -> Vec<PilotDescription> {
        match self.platform {
            PlatformKind::Lambda => vec![
                PilotDescription::new(Platform::KINESIS)
                    .with_parallelism(self.partitions)
                    .with_seed(self.seed),
                // AWS never runs more containers than shards; the paper
                // additionally observed at most 30 concurrent containers
                PilotDescription::new(Platform::LAMBDA)
                    .with_parallelism(self.partitions.min(30))
                    .with_memory_mb(self.memory_mb)
                    .with_seed(self.seed),
            ],
            PlatformKind::DaskWrangler | PlatformKind::DaskStampede2 => {
                let machine = match self.platform {
                    PlatformKind::DaskStampede2 => crate::pilot::MachineKind::Stampede2,
                    _ => crate::pilot::MachineKind::Wrangler,
                };
                vec![
                    PilotDescription::new(Platform::KAFKA)
                        .with_parallelism(self.partitions)
                        .with_seed(self.seed),
                    PilotDescription::new(Platform::DASK)
                        .with_parallelism(self.partitions)
                        .with_machine(machine)
                        .with_max_nodes(64)
                        .with_seed(self.seed),
                ]
            }
            PlatformKind::Edge => {
                // shared memory axis: the edge plugin normalizes memory
                // into the device envelope and clamps concurrency itself
                let mut d = PilotDescription::new(Platform::EDGE)
                    .with_parallelism(self.partitions)
                    .with_memory_mb(self.memory_mb)
                    .with_seed(self.seed);
                // the edge_sites sweep axis provisions a multi-site fleet
                if let Some(sites) = self.extra_param("edge_sites") {
                    d = d.with_extra("edge_sites", sites);
                }
                vec![d]
            }
            PlatformKind::Plugin(platform) => vec![
                PilotDescription::new(Platform::KINESIS)
                    .with_parallelism(self.partitions)
                    .with_seed(self.seed),
                PilotDescription::new(platform)
                    .with_parallelism(self.partitions)
                    .with_memory_mb(self.memory_mb)
                    .with_seed(self.seed),
            ],
            PlatformKind::Broker(b) => {
                // the broker pilot is the loop's resize target; its
                // ecosystem's default processing platform consumes the
                // shards at matching parallelism (consumers == shards)
                let compute = if b == Platform::KAFKA {
                    PilotDescription::new(Platform::DASK)
                        .with_parallelism(self.partitions)
                        .with_machine(crate::pilot::MachineKind::Wrangler)
                        .with_max_nodes(64)
                        .with_seed(self.seed)
                } else {
                    PilotDescription::new(Platform::LAMBDA)
                        .with_parallelism(self.partitions.min(30))
                        .with_memory_mb(self.memory_mb)
                        .with_seed(self.seed)
                };
                vec![
                    PilotDescription::new(b)
                        .with_parallelism(self.partitions)
                        .with_seed(self.seed),
                    compute,
                ]
            }
        }
    }
}

/// The instantiated platform: the service that provisioned it plus the
/// two capability handles the drivers pump messages through.
pub struct PlatformUnderTest {
    service: PilotComputeService,
    broker: Arc<dyn Broker>,
    processor: Arc<dyn StreamProcessor>,
    /// The pilot whose backend exposed the processor — the control plane's
    /// resize target.
    processing: PilotJob,
    /// The pilot that stood up the broker (on co-located stacks this is
    /// the processing pilot itself) — the co-actuated resize handle of a
    /// broker-driven stack.
    broker_job: PilotJob,
}

impl PlatformUnderTest {
    /// Provision the platform for `scenario` through the Pilot-API on
    /// `clock` with `engine`.
    pub fn build(
        scenario: &Scenario,
        engine: Arc<dyn StepEngine>,
        clock: SharedClock,
    ) -> Result<Self, String> {
        // the broker log and the model store share the same Lustre on the
        // HPC stacks; serverless pilots simply never touch it
        let service = PilotComputeService::new(clock, engine)
            .with_shared_fs(SharedResource::new("lustre", scenario.lustre));
        let mut broker: Option<(Arc<dyn Broker>, PilotJob)> = None;
        let mut processing: Option<(PilotJob, Arc<dyn StreamProcessor>)> = None;
        for desc in scenario.pilot_descriptions() {
            let job = service.submit_pilot(desc).map_err(|e| e.to_string())?;
            if broker.is_none() {
                if let Some(b) = job.broker() {
                    broker = Some((b, job.clone()));
                }
            }
            if processing.is_none() {
                if let Some(p) = job.processor() {
                    processing = Some((job, p));
                }
            }
        }
        let (processing, processor) =
            processing.ok_or("scenario provisioned no processing pilot")?;
        let (broker, broker_job) = broker.ok_or("scenario provisioned no broker pilot")?;
        Ok(Self {
            service,
            broker,
            processor,
            processing,
            broker_job,
        })
    }

    pub fn broker(&self) -> Arc<dyn Broker> {
        Arc::clone(&self.broker)
    }

    /// The *dedicated* broker pilot — a broker-driven stack's co-actuated
    /// resize handle.  `None` on co-located stacks (the edge), where the
    /// broker lives inside the processing pilot and resizing it
    /// separately would double-actuate the same backend.
    pub fn broker_pilot(&self) -> Option<&PilotJob> {
        (self.broker_job.id != self.processing.id).then_some(&self.broker_job)
    }

    /// The service that provisioned this platform — the control plane
    /// (`resize_pilot` / `pilot_state`) for everything it runs.
    pub fn service(&self) -> &PilotComputeService {
        &self.service
    }

    /// The processing pilot (the autoscaler's resize target).
    pub fn processing_pilot(&self) -> &PilotJob {
        &self.processing
    }

    /// The pilots backing this platform (diagnostics, teardown).
    pub fn pilots(&self) -> Vec<PilotJob> {
        self.service.pilots()
    }

    /// Process one message's points on `partition`; returns the modeled
    /// cost breakdown.
    pub fn process(
        &self,
        partition: usize,
        points: &[f32],
        dim: usize,
        model_key: &str,
        centroids: usize,
    ) -> Result<ProcessCost, String> {
        self.processor
            .process(partition, points, dim, model_key, centroids)
    }

    pub fn label(&self) -> &'static str {
        self.processor.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::sim::SimClock;

    fn engine() -> Arc<dyn StepEngine> {
        Arc::new(CalibratedEngine::new(1))
    }

    #[test]
    fn builds_all_platforms_through_the_pilot_api() {
        let clock = Arc::new(SimClock::new()) as SharedClock;
        let s = Scenario::default();
        let lambda = PlatformUnderTest::build(&s, engine(), Arc::clone(&clock)).unwrap();
        assert_eq!(lambda.broker().kind(), "kinesis");
        assert_eq!(lambda.label(), "lambda");
        assert_eq!(lambda.pilots().len(), 2, "broker + processing pilot");
        let s2 = Scenario {
            platform: PlatformKind::DaskWrangler,
            ..s.clone()
        };
        let dask = PlatformUnderTest::build(&s2, engine(), Arc::clone(&clock)).unwrap();
        assert_eq!(dask.broker().kind(), "kafka");
        assert_eq!(dask.label(), "dask");
        let s3 = Scenario {
            platform: PlatformKind::Edge,
            ..s
        };
        let edge = PlatformUnderTest::build(&s3, engine(), clock).unwrap();
        assert_eq!(edge.label(), "edge");
        assert_eq!(edge.pilots().len(), 1, "co-located broker + fleet");
    }

    #[test]
    fn process_works_on_all_platforms() {
        let clock = Arc::new(SimClock::new()) as SharedClock;
        let pts = vec![0.1f32; 100 * 8];
        for platform in [
            PlatformKind::Lambda,
            PlatformKind::DaskWrangler,
            PlatformKind::Edge,
        ] {
            let s = Scenario {
                platform,
                centroids: 16,
                ..Default::default()
            };
            let p = PlatformUnderTest::build(&s, engine(), Arc::clone(&clock)).unwrap();
            let cost = p.process(0, &pts, 8, "m", 16).unwrap();
            assert!(cost.total() > 0.0, "{platform:?}");
        }
    }

    #[test]
    fn platform_kind_parsing() {
        assert_eq!(PlatformKind::parse("lambda"), Some(PlatformKind::Lambda));
        assert_eq!(PlatformKind::parse("DASK"), Some(PlatformKind::DaskWrangler));
        assert_eq!(
            PlatformKind::parse("stampede2"),
            Some(PlatformKind::DaskStampede2)
        );
        assert_eq!(PlatformKind::parse("edge"), Some(PlatformKind::Edge));
        assert_eq!(PlatformKind::parse("heron"), None);
        assert!(PlatformKind::Edge.is_serverless());
        assert!(!PlatformKind::Plugin(Platform::FLINK).is_serverless());
    }

    #[test]
    fn platform_naming_is_owned_by_the_plugin_registry() {
        // every registry alias resolves with zero edits here...
        assert_eq!(PlatformKind::parse("serverless"), Some(PlatformKind::Lambda));
        assert_eq!(PlatformKind::parse("faas"), Some(PlatformKind::Lambda));
        assert_eq!(PlatformKind::parse("hpc"), Some(PlatformKind::DaskWrangler));
        assert_eq!(PlatformKind::parse("greengrass"), Some(PlatformKind::Edge));
        // ...including platforms this module predates: registering the
        // flink plugin made it addressable as a scenario stack
        assert_eq!(
            PlatformKind::parse("flink"),
            Some(PlatformKind::Plugin(Platform::FLINK))
        );
        assert_eq!(
            PlatformKind::parse("microbatch"),
            Some(PlatformKind::Plugin(Platform::FLINK))
        );
        // pure brokers anchor broker-driven stacks: the broker's shard
        // count is the control loop's resize target
        assert_eq!(
            PlatformKind::parse("kinesis"),
            Some(PlatformKind::Broker(Platform::KINESIS))
        );
        assert_eq!(
            PlatformKind::parse("kafka"),
            Some(PlatformKind::Broker(Platform::KAFKA))
        );
        // bag-of-tasks pools still don't stream
        assert_eq!(PlatformKind::parse("local"), None);
    }

    #[test]
    fn platform_labels_parse_back() {
        // spec JSON round-trips serialize platforms by label
        for kind in [
            PlatformKind::Lambda,
            PlatformKind::DaskWrangler,
            PlatformKind::DaskStampede2,
            PlatformKind::Edge,
            PlatformKind::Plugin(Platform::FLINK),
            PlatformKind::Broker(Platform::KINESIS),
            PlatformKind::Broker(Platform::KAFKA),
        ] {
            assert_eq!(PlatformKind::parse(kind.label()), Some(kind), "{kind:?}");
        }
    }

    #[test]
    fn broker_driven_stack_builds_with_a_co_actuated_broker_pilot() {
        // `--platform kafka`: kafka broker pilot (the resize target) +
        // dask consumers at matching parallelism; `--platform kinesis`:
        // kinesis + lambda
        let clock = Arc::new(SimClock::new()) as SharedClock;
        let s = Scenario {
            platform: PlatformKind::Broker(Platform::KAFKA),
            centroids: 16,
            ..Scenario::default()
        };
        assert_eq!(s.platform.broker_driven(), Some(Platform::KAFKA));
        assert_eq!(s.platform.processing_platform(), Platform::DASK);
        let p = PlatformUnderTest::build(&s, engine(), Arc::clone(&clock)).unwrap();
        assert_eq!(p.broker().kind(), "kafka");
        assert_eq!(p.label(), "dask");
        let bp = p.broker_pilot().expect("broker pilot handle");
        assert_eq!(bp.platform(), Platform::KAFKA);
        assert_eq!(bp.parallelism(), s.partitions);
        assert_eq!(p.processing_pilot().parallelism(), s.partitions);

        let s2 = Scenario {
            platform: PlatformKind::Broker(Platform::KINESIS),
            centroids: 16,
            ..Scenario::default()
        };
        assert_eq!(s2.platform.processing_platform(), Platform::LAMBDA);
        let p2 = PlatformUnderTest::build(&s2, engine(), clock).unwrap();
        assert_eq!(p2.broker().kind(), "kinesis");
        assert_eq!(p2.label(), "lambda");
        assert_eq!(p2.broker_pilot().unwrap().platform(), Platform::KINESIS);
    }

    #[test]
    fn plugin_stack_builds_through_the_pilot_api() {
        // the unified-naming payoff: a registered plugin platform is a
        // first-class scenario stack with no mini-app construction code
        let clock = Arc::new(SimClock::new()) as SharedClock;
        let s = Scenario {
            platform: PlatformKind::Plugin(Platform::FLINK),
            centroids: 16,
            ..Scenario::default()
        };
        let p = PlatformUnderTest::build(&s, engine(), clock).unwrap();
        assert_eq!(p.broker().kind(), "kinesis");
        assert_eq!(p.label(), "flink");
        let pts = vec![0.1f32; 100 * 8];
        let cost = p.process(0, &pts, 8, "m", 16).unwrap();
        assert!(
            cost.overhead > 0.0,
            "micro-batch scheduling delay must surface"
        );
        assert_eq!(p.processing_pilot().platform(), Platform::FLINK);
    }

    #[test]
    fn scenario_extension_params() {
        let mut s = Scenario::default();
        assert_eq!(s.extra_param("edge_sites"), None);
        s.set_extra("edge_sites", 4);
        s.set_extra("edge_sites", 8);
        assert_eq!(s.extra_param("edge_sites"), Some(8));
        assert_eq!(s.extra.len(), 1, "set_extra replaces in place");
    }

    #[test]
    fn run_key_is_stable_and_config_sensitive() {
        let s = Scenario::default();
        assert_eq!(s.run_key(), s.run_key());
        assert_ne!(s.run_key(), 0);
        for other in [
            Scenario { seed: 43, ..s.clone() },
            Scenario { partitions: 5, ..s.clone() },
            Scenario { messages: 65, ..s.clone() },
            Scenario { platform: PlatformKind::Edge, ..s.clone() },
        ] {
            assert_ne!(s.run_key(), other.run_key(), "{other:?}");
        }
        let mut extra = s.clone();
        extra.set_extra("edge_sites", 4);
        assert_ne!(s.run_key(), extra.run_key());
    }

    #[test]
    fn dask_capacity_checked() {
        let clock = Arc::new(SimClock::new()) as SharedClock;
        let s = Scenario {
            platform: PlatformKind::DaskWrangler,
            partitions: 10_000,
            ..Default::default()
        };
        assert!(PlatformUnderTest::build(&s, engine(), clock).is_err());
    }

    #[test]
    fn edge_sites_axis_flows_into_the_pilot_description() {
        // the campaign engine's edge_sites extension parameter reaches the
        // plugin as a description extra — drivers untouched
        let mut s = Scenario {
            platform: PlatformKind::Edge,
            ..Default::default()
        };
        assert_eq!(s.pilot_descriptions()[0].extra_param("edge_sites"), None);
        s.set_extra("edge_sites", 4);
        let descs = s.pilot_descriptions();
        assert_eq!(descs.len(), 1, "co-located broker + fleet");
        assert_eq!(descs[0].extra_param("edge_sites"), Some(4));
        // ...and the provisioned platform carries a 4-site fleet: the
        // parallelism floor is one container per site
        let clock = Arc::new(SimClock::new()) as SharedClock;
        let s4 = Scenario {
            partitions: 1,
            ..s
        };
        let p = PlatformUnderTest::build(&s4, engine(), clock).unwrap();
        assert_eq!(p.processing_pilot().parallelism(), 4);
    }

    #[test]
    fn edge_memory_is_clamped_into_the_device_envelope() {
        // the default 3,008 MB cloud memory exceeds the 1,536 MB device;
        // the edge plugin's normalize keeps the shared memory axis usable,
        // and the provisioned pilot carries the normalized description
        let clock = Arc::new(SimClock::new()) as SharedClock;
        let s = Scenario {
            platform: PlatformKind::Edge,
            ..Default::default()
        };
        assert_eq!(s.memory_mb, 3_008, "cloud default flows through as-is");
        let p = PlatformUnderTest::build(&s, engine(), clock).unwrap();
        assert_eq!(
            p.pilots()[0].description.memory_mb,
            crate::serverless::edge::EDGE_MAX_MEMORY_MB
        );
    }
}
