//! The Streaming Mini-App framework (paper §IV): synthetic data generation,
//! end-to-end run-id tracing, and benchmark drivers that run a scenario to
//! completion in simulated time ([`sim_driver`], large sweeps) or live
//! wall-clock time with real PJRT execution ([`live_driver`], e2e +
//! calibration).
//!
//! Scenarios provision through the Pilot-API: a [`Scenario`] expands into
//! pilot descriptions and one `PilotComputeService` builds the platform
//! under test from registered plugins — Kinesis/Lambda, Kafka/Dask, or the
//! edge/Greengrass stack — with no platform-specific construction here.

pub mod generator;
pub mod live_driver;
pub mod platform;
pub mod sim_driver;
pub mod trace;

pub use generator::{DataGenerator, GeneratorConfig};
pub use live_driver::{run_live, LivePilot, LiveRunResult};
pub use platform::{PlatformKind, PlatformUnderTest, ProcessCost, Scenario};
pub use sim_driver::{run_sim, run_sim_opts, SimMode, SimOptions, SimRunResult};
pub use trace::{next_run_id, MessageTrace, RunSummary, RunTrace, TraceMode};
