//! End-to-end tracing across producer → broker → processing.
//!
//! Paper: "the framework assigns a unique run id, which is propagated to
//! all involved components. This way events can be attributed to a
//! specific benchmark run."  One [`MessageTrace`] per processed message;
//! a [`RunTrace`] aggregates a benchmark run and computes the paper's
//! metrics: L^br, L^px, T^px.
//!
//! Multi-million-message runs must not buffer one `MessageTrace` per
//! message, so a trace has a [`TraceMode`]: `Full` keeps every trace (the
//! sim default — determinism tests compare full traces bit-for-bit),
//! `Sampled` streams exact moment statistics (Welford) plus a retained
//! sample subset for percentiles, and `Off` streams the moments only.

use crate::util::stats::{percentile, Summary};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

static NEXT_RUN_ID: AtomicU64 = AtomicU64::new(1);

/// Process-global run-id allocator used by live/interactive paths.  Sim
/// runs derive their run id from the scenario instead
/// ([`super::platform::Scenario::run_key`]), so same-seed sim runs are
/// identical no matter what ran before them in the process.
pub fn next_run_id() -> u64 {
    NEXT_RUN_ID.fetch_add(1, Ordering::Relaxed)
}

/// How much per-message trace data a run retains.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceMode {
    /// Keep every `MessageTrace` (exact percentiles, byte-identical
    /// summaries — the reference mode).
    #[default]
    Full,
    /// Stream exact counts/means/stds; keep 1-in-`every` traces for
    /// percentile estimation.
    Sampled { every: usize },
    /// Stream exact counts/means/stds only; percentiles degrade to the
    /// mean.
    Off,
}

/// Per-message timing record (all timestamps from the run's shared clock).
#[derive(Debug, Clone)]
pub struct MessageTrace {
    pub run_id: u64,
    pub message_id: u64,
    pub partition: usize,
    /// Producer timestamp.
    pub produced_at: f64,
    /// Broker availability timestamp.
    pub available_at: f64,
    /// Processing start (lease acquired).
    pub proc_start: f64,
    /// Processing end (commit).
    pub proc_end: f64,
    /// Breakdown of the processing duration.
    pub compute: f64,
    pub io: f64,
    pub overhead: f64,
}

impl MessageTrace {
    /// L^br — "time between message production and its availability at the
    /// broker".
    pub fn broker_latency(&self) -> f64 {
        self.available_at - self.produced_at
    }

    /// Message processing (service) time — what Fig 4 plots.
    pub fn service_time(&self) -> f64 {
        self.proc_end - self.proc_start
    }

    /// L^px — "time between arrival and processing of message in the
    /// processing system" (includes queueing behind earlier messages).
    pub fn processing_latency(&self) -> f64 {
        self.proc_end - self.available_at
    }

    /// Overall latency L (production → fully processed).
    pub fn total_latency(&self) -> f64 {
        self.proc_end - self.produced_at
    }
}

/// Streaming exact moments (Welford) with min/max, mergeable across sim
/// lanes in deterministic (cell) order.
#[derive(Debug, Clone)]
struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Moments {
    fn default() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Moments {
    fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Chan's parallel combine — exact for counts/means, numerically stable
    /// for variance.
    fn absorb(&mut self, other: &Moments) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let (na, nb) = (self.n as f64, other.n as f64);
        let delta = other.mean - self.mean;
        let n = na + nb;
        self.mean += delta * nb / n;
        self.m2 += other.m2 + delta * delta * na * nb / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Summary with percentiles estimated from `samples` (falls back to the
    /// mean when no samples were retained).
    fn summary(&self, samples: &[f64]) -> Option<Summary> {
        if self.n == 0 {
            return None;
        }
        let var = self.m2 / if self.n > 1 { (self.n - 1) as f64 } else { 1.0 };
        let (p50, p95, p99) = if samples.is_empty() {
            (self.mean, self.mean, self.mean)
        } else {
            (
                percentile(samples, 0.50),
                percentile(samples, 0.95),
                percentile(samples, 0.99),
            )
        };
        Some(Summary {
            n: self.n as usize,
            mean: self.mean,
            std: var.sqrt(),
            min: self.min,
            max: self.max,
            p50,
            p95,
            p99,
        })
    }
}

/// Streaming aggregate of a run (Sampled/Off modes).
#[derive(Debug, Clone, Default)]
struct RunAgg {
    service: Moments,
    warm: Moments,
    sojourn: Moments,
    broker: Moments,
    compute_sum: f64,
    io_sum: f64,
    start: Option<f64>,
    end: Option<f64>,
}

impl RunAgg {
    fn push(&mut self, t: &MessageTrace) {
        let service = t.service_time();
        self.service.push(service);
        if t.overhead == 0.0 {
            self.warm.push(service);
        }
        self.sojourn.push(t.processing_latency());
        self.broker.push(t.broker_latency());
        self.compute_sum += t.compute;
        self.io_sum += t.io;
        self.start = Some(self.start.map_or(t.produced_at, |s| s.min(t.produced_at)));
        self.end = Some(self.end.map_or(t.proc_end, |e| e.max(t.proc_end)));
    }

    fn absorb(&mut self, other: &RunAgg) {
        self.service.absorb(&other.service);
        self.warm.absorb(&other.warm);
        self.sojourn.absorb(&other.sojourn);
        self.broker.absorb(&other.broker);
        self.compute_sum += other.compute_sum;
        self.io_sum += other.io_sum;
        if let Some(s) = other.start {
            self.start = Some(self.start.map_or(s, |x| x.min(s)));
        }
        if let Some(e) = other.end {
            self.end = Some(self.end.map_or(e, |x| x.max(e)));
        }
    }
}

#[derive(Debug, Default)]
struct TraceStore {
    /// Every trace (`Full`) or the retained 1-in-N subset (`Sampled`).
    kept: Vec<MessageTrace>,
    /// Streaming aggregate (`Sampled`/`Off` modes).
    agg: RunAgg,
    /// Traces recorded (all modes).
    seen: u64,
}

/// Collected traces for one benchmark run.
#[derive(Debug, Default)]
pub struct RunTrace {
    pub run_id: u64,
    mode: TraceMode,
    // One lane owns one RunTrace in the sim (no contention); the lock
    // exists for the live driver's producer/consumer threads.
    inner: Mutex<TraceStore>,
}

impl RunTrace {
    pub fn new(run_id: u64) -> Self {
        Self::with_mode(run_id, TraceMode::Full)
    }

    pub fn with_mode(run_id: u64, mode: TraceMode) -> Self {
        if let TraceMode::Sampled { every } = mode {
            assert!(every > 0, "sampling stride must be positive");
        }
        Self {
            run_id,
            mode,
            inner: Mutex::new(TraceStore::default()),
        }
    }

    pub fn mode(&self) -> TraceMode {
        self.mode
    }

    pub fn record(&self, t: MessageTrace) {
        debug_assert_eq!(t.run_id, self.run_id, "trace from another run");
        let mut g = self.inner.lock().unwrap();
        g.seen += 1;
        match self.mode {
            TraceMode::Full => g.kept.push(t),
            TraceMode::Sampled { every } => {
                g.agg.push(&t);
                if (g.seen - 1) % every as u64 == 0 {
                    g.kept.push(t);
                }
            }
            TraceMode::Off => g.agg.push(&t),
        }
    }

    /// Messages recorded (not the retained subset size).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().seen as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Retained traces: everything in `Full` mode, the sample subset in
    /// `Sampled`, empty in `Off`.
    pub fn traces(&self) -> Vec<MessageTrace> {
        self.inner.lock().unwrap().kept.clone()
    }

    /// Merge per-lane traces into one run, in lane order — lane boundaries
    /// then sim-clock (`proc_end`) order, so any lane count produces the
    /// same merged run.
    pub fn merged<'a>(
        run_id: u64,
        mode: TraceMode,
        lanes: impl IntoIterator<Item = &'a RunTrace>,
    ) -> RunTrace {
        let out = RunTrace::with_mode(run_id, mode);
        {
            let mut g = out.inner.lock().unwrap();
            let mut kept: Vec<MessageTrace> = Vec::new();
            for lane in lanes {
                let lg = lane.inner.lock().unwrap();
                g.seen += lg.seen;
                g.agg.absorb(&lg.agg);
                kept.extend(lg.kept.iter().cloned());
            }
            kept.sort_by(|a, b| a.proc_end.partial_cmp(&b.proc_end).unwrap());
            g.kept = kept;
        }
        out
    }

    /// Aggregate the run into the paper's metrics.
    pub fn summarize(&self) -> Option<RunSummary> {
        let g = self.inner.lock().unwrap();
        match self.mode {
            TraceMode::Full => Self::summarize_full(self.run_id, &g.kept),
            TraceMode::Sampled { .. } | TraceMode::Off => {
                Self::summarize_agg(self.run_id, &g.agg, &g.kept, g.seen)
            }
        }
    }

    /// Reference path: identical arithmetic (and float-sum order) to the
    /// historical all-traces summarize, so `Full` runs are bit-stable.
    fn summarize_full(run_id: u64, ts: &[MessageTrace]) -> Option<RunSummary> {
        if ts.is_empty() {
            return None;
        }
        let service: Vec<f64> = ts.iter().map(|t| t.service_time()).collect();
        // warm-path service times: exclude invocations that paid a one-off
        // platform overhead (Lambda cold starts).  Fig 3's runtime/variance
        // claims are about the warm steady state.
        let warm: Vec<f64> = ts
            .iter()
            .filter(|t| t.overhead == 0.0)
            .map(|t| t.service_time())
            .collect();
        let sojourn: Vec<f64> = ts.iter().map(|t| t.processing_latency()).collect();
        let broker: Vec<f64> = ts.iter().map(|t| t.broker_latency()).collect();
        let compute: Vec<f64> = ts.iter().map(|t| t.compute).collect();
        let io: Vec<f64> = ts.iter().map(|t| t.io).collect();
        let start = ts.iter().map(|t| t.produced_at).fold(f64::INFINITY, f64::min);
        let end = ts.iter().map(|t| t.proc_end).fold(0.0f64, f64::max);
        let window = (end - start).max(1e-9);
        Some(RunSummary {
            run_id,
            messages: ts.len(),
            window_seconds: window,
            throughput: ts.len() as f64 / window,
            service_warm: if warm.is_empty() {
                Summary::of(&service)?
            } else {
                Summary::of(&warm)?
            },
            service: Summary::of(&service)?,
            sojourn: Summary::of(&sojourn)?,
            broker: Summary::of(&broker)?,
            compute_mean: crate::util::stats::mean(&compute),
            io_mean: crate::util::stats::mean(&io),
        })
    }

    /// Streaming path: exact n/mean/std/min/max from the moment
    /// aggregates, percentiles from the retained subset.
    fn summarize_agg(
        run_id: u64,
        agg: &RunAgg,
        kept: &[MessageTrace],
        seen: u64,
    ) -> Option<RunSummary> {
        if seen == 0 {
            return None;
        }
        let window = (agg.end? - agg.start?).max(1e-9);
        let service_samples: Vec<f64> = kept.iter().map(MessageTrace::service_time).collect();
        let sojourn_samples: Vec<f64> =
            kept.iter().map(MessageTrace::processing_latency).collect();
        let broker_samples: Vec<f64> = kept.iter().map(MessageTrace::broker_latency).collect();
        let service = agg.service.summary(&service_samples)?;
        let service_warm = if agg.warm.n == 0 {
            service.clone()
        } else {
            let warm_samples: Vec<f64> = kept
                .iter()
                .filter(|t| t.overhead == 0.0)
                .map(MessageTrace::service_time)
                .collect();
            agg.warm.summary(&warm_samples)?
        };
        Some(RunSummary {
            run_id,
            messages: seen as usize,
            window_seconds: window,
            throughput: seen as f64 / window,
            service,
            service_warm,
            sojourn: agg.sojourn.summary(&sojourn_samples)?,
            broker: agg.broker.summary(&broker_samples)?,
            compute_mean: agg.compute_sum / seen as f64,
            io_mean: agg.io_sum / seen as f64,
        })
    }
}

/// The paper's measured quantities for one configuration run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub run_id: u64,
    pub messages: usize,
    pub window_seconds: f64,
    /// T^px: messages/second over the run window.
    pub throughput: f64,
    /// Service time stats (Fig 4's "message processing time").
    pub service: Summary,
    /// Warm-path service stats (cold-start invocations excluded; equals
    /// `service` when no overhead-free messages exist, e.g. on Dask).
    pub service_warm: Summary,
    /// Sojourn (arrival → done, includes queueing).
    pub sojourn: Summary,
    /// L^br stats.
    pub broker: Summary,
    pub compute_mean: f64,
    pub io_mean: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(i: u64, t0: f64) -> MessageTrace {
        MessageTrace {
            run_id: 1,
            message_id: i,
            partition: 0,
            produced_at: t0,
            available_at: t0 + 0.01,
            proc_start: t0 + 0.02,
            proc_end: t0 + 0.12,
            compute: 0.08,
            io: 0.02,
            overhead: 0.0,
        }
    }

    #[test]
    fn per_message_metrics() {
        let t = trace(1, 10.0);
        assert!((t.broker_latency() - 0.01).abs() < 1e-12);
        assert!((t.service_time() - 0.10).abs() < 1e-12);
        assert!((t.processing_latency() - 0.11).abs() < 1e-12);
        assert!((t.total_latency() - 0.12).abs() < 1e-12);
    }

    #[test]
    fn run_summary() {
        let run = RunTrace::new(1);
        for i in 0..10 {
            run.record(trace(i, i as f64));
        }
        let s = run.summarize().unwrap();
        assert_eq!(s.messages, 10);
        // window: first produced at 0, last ends at 9.12
        assert!((s.window_seconds - 9.12).abs() < 1e-9);
        assert!((s.throughput - 10.0 / 9.12).abs() < 1e-9);
        assert!((s.service.mean - 0.10).abs() < 1e-12);
        assert!((s.broker.mean - 0.01).abs() < 1e-12);
    }

    #[test]
    fn empty_run_summarizes_none() {
        assert!(RunTrace::new(1).summarize().is_none());
        assert!(RunTrace::with_mode(1, TraceMode::Off).summarize().is_none());
    }

    #[test]
    fn run_ids_unique() {
        assert_ne!(next_run_id(), next_run_id());
    }

    #[test]
    fn sampled_and_off_match_full_moments() {
        let (full, sampled, off) = (
            RunTrace::new(1),
            RunTrace::with_mode(1, TraceMode::Sampled { every: 3 }),
            RunTrace::with_mode(1, TraceMode::Off),
        );
        for i in 0..100 {
            let t = trace(i, i as f64 * 0.37);
            full.record(t.clone());
            sampled.record(t.clone());
            off.record(t);
        }
        // bounded memory: the sampled store keeps ~1/3 of the traces
        assert_eq!(sampled.traces().len(), 34);
        assert!(off.traces().is_empty());
        let (f, s, o) = (
            full.summarize().unwrap(),
            sampled.summarize().unwrap(),
            off.summarize().unwrap(),
        );
        for x in [&s, &o] {
            assert_eq!(x.messages, f.messages);
            assert!((x.throughput - f.throughput).abs() < 1e-9);
            assert!((x.service.mean - f.service.mean).abs() < 1e-12);
            assert!((x.service.std - f.service.std).abs() < 1e-9);
            assert!((x.service.min - f.service.min).abs() < 1e-12);
            assert!((x.broker.mean - f.broker.mean).abs() < 1e-12);
            assert!((x.compute_mean - f.compute_mean).abs() < 1e-12);
        }
        // percentiles: exact in Full, estimated from the subset in Sampled,
        // mean-degenerate in Off
        assert!((s.service.p50 - f.service.p50).abs() < 1e-9);
        assert!((o.service.p50 - f.service.mean).abs() < 1e-12);
    }

    #[test]
    fn merged_lanes_equal_one_big_run() {
        let whole = RunTrace::new(1);
        let lanes: Vec<RunTrace> = (0..4).map(|_| RunTrace::new(1)).collect();
        for i in 0..40u64 {
            let t = trace(i, i as f64);
            whole.record(t.clone());
            lanes[(i % 4) as usize].record(t);
        }
        let merged = RunTrace::merged(1, TraceMode::Full, &lanes);
        let (a, b) = (whole.summarize().unwrap(), merged.summarize().unwrap());
        assert_eq!(a.messages, b.messages);
        assert_eq!(a.service.mean.to_bits(), b.service.mean.to_bits());
        assert_eq!(a.window_seconds.to_bits(), b.window_seconds.to_bits());
        // merged order is proc_end (sim-clock) order
        let ts = merged.traces();
        assert!(ts.windows(2).all(|w| w[0].proc_end <= w[1].proc_end));
    }
}
