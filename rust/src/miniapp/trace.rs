//! End-to-end tracing across producer → broker → processing.
//!
//! Paper: "the framework assigns a unique run id, which is propagated to
//! all involved components. This way events can be attributed to a
//! specific benchmark run."  One [`MessageTrace`] per processed message;
//! a [`RunTrace`] aggregates a benchmark run and computes the paper's
//! metrics: L^br, L^px, T^px.

use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

static NEXT_RUN_ID: AtomicU64 = AtomicU64::new(1);

pub fn next_run_id() -> u64 {
    NEXT_RUN_ID.fetch_add(1, Ordering::Relaxed)
}

/// Per-message timing record (all timestamps from the run's shared clock).
#[derive(Debug, Clone)]
pub struct MessageTrace {
    pub run_id: u64,
    pub message_id: u64,
    pub partition: usize,
    /// Producer timestamp.
    pub produced_at: f64,
    /// Broker availability timestamp.
    pub available_at: f64,
    /// Processing start (lease acquired).
    pub proc_start: f64,
    /// Processing end (commit).
    pub proc_end: f64,
    /// Breakdown of the processing duration.
    pub compute: f64,
    pub io: f64,
    pub overhead: f64,
}

impl MessageTrace {
    /// L^br — "time between message production and its availability at the
    /// broker".
    pub fn broker_latency(&self) -> f64 {
        self.available_at - self.produced_at
    }

    /// Message processing (service) time — what Fig 4 plots.
    pub fn service_time(&self) -> f64 {
        self.proc_end - self.proc_start
    }

    /// L^px — "time between arrival and processing of message in the
    /// processing system" (includes queueing behind earlier messages).
    pub fn processing_latency(&self) -> f64 {
        self.proc_end - self.available_at
    }

    /// Overall latency L (production → fully processed).
    pub fn total_latency(&self) -> f64 {
        self.proc_end - self.produced_at
    }
}

/// Collected traces for one benchmark run.
#[derive(Default)]
pub struct RunTrace {
    pub run_id: u64,
    traces: Mutex<Vec<MessageTrace>>,
}

impl RunTrace {
    pub fn new(run_id: u64) -> Self {
        Self {
            run_id,
            traces: Mutex::new(Vec::new()),
        }
    }

    pub fn record(&self, t: MessageTrace) {
        debug_assert_eq!(t.run_id, self.run_id, "trace from another run");
        self.traces.lock().unwrap().push(t);
    }

    pub fn len(&self) -> usize {
        self.traces.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn traces(&self) -> Vec<MessageTrace> {
        self.traces.lock().unwrap().clone()
    }

    /// Aggregate the run into the paper's metrics.
    pub fn summarize(&self) -> Option<RunSummary> {
        let ts = self.traces.lock().unwrap();
        if ts.is_empty() {
            return None;
        }
        let service: Vec<f64> = ts.iter().map(|t| t.service_time()).collect();
        // warm-path service times: exclude invocations that paid a one-off
        // platform overhead (Lambda cold starts).  Fig 3's runtime/variance
        // claims are about the warm steady state.
        let warm: Vec<f64> = ts
            .iter()
            .filter(|t| t.overhead == 0.0)
            .map(|t| t.service_time())
            .collect();
        let sojourn: Vec<f64> = ts.iter().map(|t| t.processing_latency()).collect();
        let broker: Vec<f64> = ts.iter().map(|t| t.broker_latency()).collect();
        let compute: Vec<f64> = ts.iter().map(|t| t.compute).collect();
        let io: Vec<f64> = ts.iter().map(|t| t.io).collect();
        let start = ts.iter().map(|t| t.produced_at).fold(f64::INFINITY, f64::min);
        let end = ts.iter().map(|t| t.proc_end).fold(0.0f64, f64::max);
        let window = (end - start).max(1e-9);
        Some(RunSummary {
            run_id: self.run_id,
            messages: ts.len(),
            window_seconds: window,
            throughput: ts.len() as f64 / window,
            service_warm: if warm.is_empty() {
                Summary::of(&service)?
            } else {
                Summary::of(&warm)?
            },
            service: Summary::of(&service)?,
            sojourn: Summary::of(&sojourn)?,
            broker: Summary::of(&broker)?,
            compute_mean: crate::util::stats::mean(&compute),
            io_mean: crate::util::stats::mean(&io),
        })
    }
}

/// The paper's measured quantities for one configuration run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub run_id: u64,
    pub messages: usize,
    pub window_seconds: f64,
    /// T^px: messages/second over the run window.
    pub throughput: f64,
    /// Service time stats (Fig 4's "message processing time").
    pub service: Summary,
    /// Warm-path service stats (cold-start invocations excluded; equals
    /// `service` when no overhead-free messages exist, e.g. on Dask).
    pub service_warm: Summary,
    /// Sojourn (arrival → done, includes queueing).
    pub sojourn: Summary,
    /// L^br stats.
    pub broker: Summary,
    pub compute_mean: f64,
    pub io_mean: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(i: u64, t0: f64) -> MessageTrace {
        MessageTrace {
            run_id: 1,
            message_id: i,
            partition: 0,
            produced_at: t0,
            available_at: t0 + 0.01,
            proc_start: t0 + 0.02,
            proc_end: t0 + 0.12,
            compute: 0.08,
            io: 0.02,
            overhead: 0.0,
        }
    }

    #[test]
    fn per_message_metrics() {
        let t = trace(1, 10.0);
        assert!((t.broker_latency() - 0.01).abs() < 1e-12);
        assert!((t.service_time() - 0.10).abs() < 1e-12);
        assert!((t.processing_latency() - 0.11).abs() < 1e-12);
        assert!((t.total_latency() - 0.12).abs() < 1e-12);
    }

    #[test]
    fn run_summary() {
        let run = RunTrace::new(1);
        for i in 0..10 {
            run.record(trace(i, i as f64));
        }
        let s = run.summarize().unwrap();
        assert_eq!(s.messages, 10);
        // window: first produced at 0, last ends at 9.12
        assert!((s.window_seconds - 9.12).abs() < 1e-9);
        assert!((s.throughput - 10.0 / 9.12).abs() < 1e-9);
        assert!((s.service.mean - 0.10).abs() < 1e-12);
        assert!((s.broker.mean - 0.01).abs() < 1e-12);
    }

    #[test]
    fn empty_run_summarizes_none() {
        assert!(RunTrace::new(1).summarize().is_none());
    }

    #[test]
    fn run_ids_unique() {
        assert_ne!(next_run_id(), next_run_id());
    }
}
