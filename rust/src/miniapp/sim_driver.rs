//! Simulated-time benchmark driver.
//!
//! Runs one [`Scenario`] on the DES engine in *closed-loop saturation*:
//! each shard always has the next message ready the moment the previous
//! one commits, so the measured throughput is the **maximum sustained
//! throughput** — the operating point the paper's intelligent-backoff
//! producer converges to, reached here deterministically.
//!
//! Event chain per shard:
//!   produce → (throttled? retry after backoff) → available → process
//!   (platform cost model; compute calibrated from live PJRT runs) →
//!   commit → produce next …
//!
//! [`run_sim`] is **safely spawnable per worker thread**: every call owns
//! its DES, clock, generator, stores, and engine (the caller's factory
//! builds a fresh one per scenario), and the only cross-run state is the
//! atomic run-id counter — which stamps traces but never feeds a cost
//! model.  The insight campaign engine relies on this to run independent
//! sweep configurations concurrently with bit-identical results.

use super::generator::{DataGenerator, GeneratorConfig};
use super::platform::{PlatformUnderTest, Scenario};
use super::trace::{next_run_id, MessageTrace, RunSummary, RunTrace};
use crate::broker::BrokerError;
use crate::engine::StepEngine;
use crate::serverless::EventSourceMapping;
use crate::sim::{Engine as Des, SharedClock};
use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

/// Result of one simulated configuration run.
#[derive(Debug, Clone)]
pub struct SimRunResult {
    pub summary: RunSummary,
    /// Producer throttle/backoff events observed.
    pub backoff_events: u64,
    /// Total simulated events executed.
    pub des_events: u64,
}

struct ShardLoop {
    platform: Arc<PlatformUnderTest>,
    esm: Arc<EventSourceMapping>,
    generator: RefCell<DataGenerator>,
    run: Arc<RunTrace>,
    scenario: Scenario,
    run_id: u64,
    remaining: RefCell<Vec<usize>>,
    backoffs: RefCell<u64>,
    clock: SharedClock,
}

impl ShardLoop {
    fn produce(self: &Rc<Self>, des: &mut Des, shard: usize) {
        {
            let rem = self.remaining.borrow();
            if rem[shard] == 0 {
                return;
            }
        }
        let now = des.now();
        let msg = self.generator.borrow_mut().next_message_for_partition(
            self.run_id,
            now,
            shard,
            self.scenario.partitions,
        );
        match self.platform.broker().put(msg) {
            Ok(put) => {
                debug_assert_eq!(put.partition, shard);
                let this = Rc::clone(self);
                // visible strictly after availability
                let at = now + put.broker_latency + 1e-9;
                des.schedule_at(at, Box::new(move |des| this.process(des, shard)));
            }
            Err(BrokerError::Throttled { retry_after, .. }) => {
                *self.backoffs.borrow_mut() += 1;
                let this = Rc::clone(self);
                des.schedule_in(
                    retry_after.max(1e-4),
                    Box::new(move |des| this.produce(des, shard)),
                );
            }
            Err(e) => log::error!("sim put failed: {e}"),
        }
    }

    fn process(self: &Rc<Self>, des: &mut Des, shard: usize) {
        let now = des.now();
        let Some(lease) = self.esm.poll(shard, now) else {
            // record not yet visible (shouldn't happen) — retry shortly
            let this = Rc::clone(self);
            des.schedule_in(1e-3, Box::new(move |des| this.process(des, shard)));
            return;
        };
        let rec = &lease.records[0];
        let msg = rec.message.clone();
        let cost = match self.platform.process(
            shard,
            &msg.points,
            msg.dim,
            &format!("model-{}", self.run_id),
            self.scenario.centroids,
        ) {
            Ok(c) => c,
            Err(e) => {
                log::error!("sim process failed: {e}");
                self.esm.abort(lease);
                return;
            }
        };
        let this = Rc::clone(self);
        des.schedule_in(
            cost.total(),
            Box::new(move |des| {
                let end = des.now();
                this.esm.commit(lease);
                this.run.record(MessageTrace {
                    run_id: msg.run_id,
                    message_id: msg.id,
                    partition: shard,
                    produced_at: msg.produced_at,
                    available_at: msg.available_at,
                    proc_start: now,
                    proc_end: end,
                    compute: cost.compute,
                    io: cost.io,
                    overhead: cost.overhead,
                });
                {
                    let mut rem = this.remaining.borrow_mut();
                    rem[shard] = rem[shard].saturating_sub(1);
                }
                // closed loop: next message for this shard immediately
                this.produce(des, shard);
            }),
        );
        let _ = self.clock.now(); // keep clock captured (diagnostics)
    }
}

/// Run one scenario in simulated time.
pub fn run_sim(scenario: &Scenario, engine: Arc<dyn StepEngine>) -> Result<SimRunResult, String> {
    let mut des = Des::new().with_event_limit(20_000_000);
    let clock = des.clock() as SharedClock;
    let platform = Arc::new(PlatformUnderTest::build(
        scenario,
        engine,
        Arc::clone(&clock),
    )?);
    let esm = Arc::new(EventSourceMapping::new(platform.broker(), 1));
    let run_id = next_run_id();
    let run = Arc::new(RunTrace::new(run_id));

    let per_shard = scenario.messages.div_ceil(scenario.partitions);
    let state = Rc::new(ShardLoop {
        platform,
        esm,
        generator: RefCell::new(DataGenerator::new(GeneratorConfig {
            points_per_message: scenario.points_per_message,
            seed: scenario.seed,
            ..Default::default()
        })),
        run: Arc::clone(&run),
        scenario: scenario.clone(),
        run_id,
        remaining: RefCell::new(vec![per_shard; scenario.partitions]),
        backoffs: RefCell::new(0),
        clock,
    });

    for shard in 0..scenario.partitions {
        let st = Rc::clone(&state);
        des.schedule_at(0.0, Box::new(move |des| st.produce(des, shard)));
    }
    des.run();

    let summary = run
        .summarize()
        .ok_or_else(|| "no messages processed".to_string())?;
    let backoff_events = *state.backoffs.borrow();
    Ok(SimRunResult {
        summary,
        backoff_events,
        des_events: des.executed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::miniapp::platform::PlatformKind;
    use crate::sim::Dist;

    fn engine_with(key: (usize, usize), secs: f64) -> Arc<dyn StepEngine> {
        let mut e = CalibratedEngine::new(7);
        e.insert(key, Dist::Const(secs));
        Arc::new(e)
    }

    fn scenario(platform: PlatformKind, partitions: usize) -> Scenario {
        Scenario {
            platform,
            partitions,
            points_per_message: 256,
            centroids: 16,
            messages: 32,
            ..Default::default()
        }
    }

    #[test]
    fn lambda_sim_processes_all_messages() {
        let s = scenario(PlatformKind::Lambda, 4);
        let r = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        assert_eq!(r.summary.messages, 32);
        assert!(r.summary.throughput > 0.0);
        assert!(r.summary.service.mean > 0.05); // at least the compute time
        assert!(r.des_events > 64);
    }

    #[test]
    fn dask_sim_processes_all_messages() {
        let s = scenario(PlatformKind::DaskWrangler, 4);
        let r = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        assert_eq!(r.summary.messages, 32);
        assert!(r.summary.service.mean > 0.05);
    }

    #[test]
    fn lambda_throughput_scales_with_partitions() {
        // Fig 5's serverless panel: more shards → proportionally more T
        let t = |p: usize| {
            // enough messages per shard to amortize the one-time cold start
            let s = Scenario {
                messages: 240,
                ..scenario(PlatformKind::Lambda, p)
            };
            run_sim(&s, engine_with((256, 16), 0.1))
                .unwrap()
                .summary
                .throughput
        };
        let t1 = t(1);
        let t4 = t(4);
        let t8 = t(8);
        assert!(t4 > t1 * 3.0, "t1={t1} t4={t4}");
        assert!(t8 > t1 * 5.5, "t1={t1} t8={t8}");
    }

    #[test]
    fn dask_latency_grows_with_partitions() {
        // Fig 4's HPC panel: service time inflates with P
        let svc = |p: usize| {
            let s = Scenario {
                messages: 48,
                ..scenario(PlatformKind::DaskWrangler, p)
            };
            run_sim(&s, engine_with((256, 16), 0.02))
                .unwrap()
                .summary
                .service
                .mean
        };
        let s1 = svc(1);
        let s16 = svc(16);
        assert!(s16 > s1 * 1.5, "s1={s1} s16={s16}");
    }

    #[test]
    fn edge_sim_has_local_broker_latency() {
        // the edge's whole advantage: the broker hop is LAN (~2 ms), not
        // the Kinesis WAN put (~15 ms)
        let s = scenario(PlatformKind::Edge, 2);
        let r = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        assert_eq!(r.summary.messages, 32);
        assert!(
            r.summary.broker.mean < 0.005,
            "L^br mean {}",
            r.summary.broker.mean
        );
    }

    #[test]
    fn edge_pinned_throughput_saturates_at_device_capacity() {
        // a light message class sits under the break-even, so placement
        // pins it to the box: only EDGE_MAX_CONCURRENCY containers fit,
        // saturated invocations queue, and throughput flattens past 4
        // partitions — the USL signature of the latency-bound edge class
        let t = |p: usize| {
            let s = Scenario {
                messages: 240,
                ..scenario(PlatformKind::Edge, p)
            };
            run_sim(&s, engine_with((256, 16), 0.002))
                .unwrap()
                .summary
                .throughput
        };
        let t1 = t(1);
        let t4 = t(4);
        let t8 = t(8);
        assert!(t4 > t1 * 2.0, "scales to the container cap: t1={t1} t4={t4}");
        assert!(t8 < t4 * 1.25, "no gain past 4 containers: t4={t4} t8={t8}");
    }

    #[test]
    fn edge_spillable_throughput_grows_past_device_capacity() {
        // a heavy class exceeds the break-even: once the box saturates,
        // the placement layer spills over the backhaul to the cloud
        // fallback, so throughput keeps growing past the device cap —
        // unlike the pinned class above, which queues
        let t = |p: usize| {
            let s = Scenario {
                messages: 240,
                ..scenario(PlatformKind::Edge, p)
            };
            run_sim(&s, engine_with((256, 16), 0.1))
                .unwrap()
                .summary
                .throughput
        };
        let t4 = t(4);
        let t8 = t(8);
        assert!(
            t8 > t4 * 1.3,
            "spillover must rescue throughput: t4={t4} t8={t8}"
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let s = scenario(PlatformKind::Lambda, 2);
        let a = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        let b = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        assert!((a.summary.throughput - b.summary.throughput).abs() < 1e-9);
        assert!((a.summary.service.mean - b.summary.service.mean).abs() < 1e-12);
    }

    #[test]
    fn concurrent_runs_match_the_sequential_result() {
        // the campaign engine spawns run_sim per worker; interleaving with
        // other runs (and the resulting run-id shuffle) must not move a
        // single measured number
        let s = scenario(PlatformKind::Lambda, 2);
        let base = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let s = s.clone();
                std::thread::spawn(move || run_sim(&s, engine_with((256, 16), 0.05)).unwrap())
            })
            .collect();
        for h in handles {
            let r = h.join().unwrap();
            assert_eq!(r.summary.messages, base.summary.messages);
            assert!((r.summary.throughput - base.summary.throughput).abs() < 1e-12);
            assert!((r.summary.service.mean - base.summary.service.mean).abs() < 1e-12);
            assert!((r.summary.broker.mean - base.summary.broker.mean).abs() < 1e-12);
        }
    }

    #[test]
    fn broker_latency_recorded() {
        let s = scenario(PlatformKind::Lambda, 2);
        let r = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        // Kinesis put latency ~15 ms
        assert!(
            (r.summary.broker.mean - 0.015).abs() < 0.005,
            "L^br mean {}",
            r.summary.broker.mean
        );
    }
}
