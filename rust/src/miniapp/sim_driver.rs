//! Simulated-time benchmark driver.
//!
//! Runs one [`Scenario`] on the DES engine in *closed-loop saturation*:
//! each shard always has the next message ready the moment the previous
//! one commits, so the measured throughput is the **maximum sustained
//! throughput** — the operating point the paper's intelligent-backoff
//! producer converges to, reached here deterministically.
//!
//! Event chain per shard:
//!   produce → (throttled? retry after backoff) → available → process
//!   (platform cost model; compute calibrated from live PJRT runs) →
//!   commit → produce next …
//!
//! # The million-user sim core
//!
//! The hot path is batched and sharded so one scenario scales to tens of
//! millions of messages (see ARCHITECTURE.md "Sim-core data layout"):
//!
//! - **Cohorts** ([`SimMode::Cohort`], the default): each production lane
//!   emits one [`Cohort`] — a count, one shared payload slab, one key, a
//!   contiguous id range — and the broker stores ~16-byte SoA records
//!   instead of `Message` clones.  Admission (token buckets, append
//!   costs) happens per record at the same event times, so the cohort
//!   path is **bit-identical** in every measured quantity to
//!   [`SimMode::PerMessage`], which materializes each message the
//!   historical way.
//! - **Cells**: a serverless scenario whose shards are independent by
//!   construction (Kinesis shard + its own Lambda container, no shared
//!   medium) decomposes into one sub-simulation per shard.  Each cell
//!   owns a DES, a forked engine ([`StepEngine::fork`]), a derived-seed
//!   generator, and a per-lane id stream; cell traces merge in cell
//!   order, then sim-clock order.  Platforms with a shared medium (the
//!   Dask/Lustre stacks, the edge device envelope) keep the exact
//!   single-DES path.
//! - **Lanes** ([`SimOptions::lanes`]): cells are embarrassingly
//!   parallel, so `lanes > 1` farms them to the worker pool
//!   ([`parallel_indexed_map`]) — PR 2's deterministic-reassembly trick
//!   applied *inside* one scenario.  Results are byte-identical for
//!   every lane count.
//!
//! [`run_sim`] remains **safely spawnable per worker thread**: every call
//! owns its DES, clock, generator, stores, and engine, and run/message
//! ids derive from [`Scenario::run_key`] — no process-global state feeds
//! the simulation.

use super::generator::{DataGenerator, GeneratorConfig};
use super::platform::{PlatformKind, PlatformUnderTest, Scenario};
use super::trace::{MessageTrace, RunSummary, RunTrace, TraceMode};
use crate::broker::{Broker, BrokerError};
use crate::engine::StepEngine;
use crate::pilot::workers::parallel_indexed_map;
use crate::serverless::EventSourceMapping;
use crate::sim::faults::{FaultAccounting, FaultPlan, FaultSchedule, FAULTS_PARAM};
use crate::sim::{Cohort, Engine as Des, IdAlloc};
use crate::util::rng::SplitMix64;
use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

/// How the producer hands messages to the broker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimMode {
    /// Batched production: one [`Cohort`] per lane, SoA broker records.
    #[default]
    Cohort,
    /// Historical reference path: one materialized [`crate::broker::Message`]
    /// per produce event.  Kept as the oracle the cohort path is asserted
    /// bit-identical against.
    PerMessage,
}

/// Knobs of the sim core.  `Default` is the reference configuration:
/// cohort production, one lane, full tracing.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    pub mode: SimMode,
    /// Worker threads for cell-decomposable scenarios (1 = in-process,
    /// sequential).  Output is identical for every value.
    pub lanes: usize,
    /// Trace retention; multi-million-message runs want
    /// [`TraceMode::Sampled`] or [`TraceMode::Off`].
    pub trace: TraceMode,
}

impl Default for SimOptions {
    fn default() -> Self {
        Self {
            mode: SimMode::Cohort,
            lanes: 1,
            trace: TraceMode::Full,
        }
    }
}

/// Result of one simulated configuration run.
#[derive(Debug, Clone)]
pub struct SimRunResult {
    pub summary: RunSummary,
    /// Producer throttle/backoff events observed.
    pub backoff_events: u64,
    /// Total simulated events executed (summed over cells).
    pub des_events: u64,
    /// The merged run trace (retention governed by [`SimOptions::trace`]).
    pub trace: Arc<RunTrace>,
    /// Conserved fault accounting when the scenario carries a fault plan
    /// (`Scenario::extra["faults"]`), `None` in fair weather.
    pub faults: Option<FaultAccounting>,
}

struct CellLoop {
    platform: Arc<PlatformUnderTest>,
    broker: Arc<dyn Broker>,
    esm: Arc<EventSourceMapping>,
    generator: RefCell<DataGenerator>,
    ids: RefCell<IdAlloc>,
    /// Lazily built production cohort per local shard (cohort mode).
    cohorts: RefCell<Vec<Option<Rc<Cohort>>>>,
    run: RunTrace,
    mode: SimMode,
    /// Hoisted once per cell — the legacy path formatted it per message.
    model_key: String,
    centroids: usize,
    run_id: u64,
    /// Global index of this cell's local shard 0 (trace partitions and
    /// generator key targeting stay global under cell decomposition).
    shard_base: usize,
    global_partitions: usize,
    total: Vec<usize>,
    remaining: RefCell<Vec<usize>>,
    backoffs: RefCell<u64>,
    /// Materialized fault plan (inactive schedules answer every query
    /// with "fair weather" and the fast-path guards skip them entirely).
    faults: FaultSchedule,
    faults_active: bool,
    /// Committed + denied produce outcomes over `fault_total` — the
    /// run-progress measure fault windows are defined on.  Counting
    /// denials guarantees a deny window always eventually closes even if
    /// only denied shards still hold messages (no deadlock).
    fault_acct: RefCell<FaultAccounting>,
    /// Per-shard flag: the shard's in-flight message was denied or slowed
    /// by a fault and must commit as `delayed` (one in-flight message per
    /// shard in the closed loop, so a flag suffices).
    tainted: RefCell<Vec<bool>>,
    fault_total: f64,
}

struct CellOutcome {
    trace: RunTrace,
    backoffs: u64,
    des_events: u64,
    faults: Option<FaultAccounting>,
}

impl CellLoop {
    /// Run progress in `[0, 1+)` as fault windows measure it: produce
    /// outcomes (commits + fault denials) over the total message count.
    /// A pure function of committed state, identical on the cohort and
    /// per-message paths, so fault decisions never move an event time
    /// between modes.
    fn fault_progress(&self) -> f64 {
        let acct = self.fault_acct.borrow();
        (acct.served_clean + acct.delayed + acct.denied_attempts) as f64 / self.fault_total
    }

    /// The shard's production cohort, drawn from the generator on first
    /// use.  Payload content never feeds a cost model, so sharing one
    /// slab across the lane leaves every event time untouched.
    fn cohort_for(&self, shard: usize, now: f64) -> Rc<Cohort> {
        let mut cohorts = self.cohorts.borrow_mut();
        let slot = &mut cohorts[shard];
        if slot.is_none() {
            let template = self.generator.borrow_mut().next_message_for_partition(
                self.run_id,
                now,
                self.shard_base + shard,
                self.global_partitions,
            );
            let base = self.ids.borrow_mut().reserve(self.total[shard]);
            *slot = Some(Rc::new(Cohort::new(
                self.run_id,
                base,
                self.total[shard],
                template.key,
                template.points,
                template.dim,
            )));
        }
        Rc::clone(slot.as_ref().unwrap())
    }

    fn produce(self: &Rc<Self>, des: &mut Des, shard: usize) {
        let rem = self.remaining.borrow()[shard];
        if rem == 0 {
            return;
        }
        // an active outage/partition window denies the put before any
        // generator or id state is consumed: the attempt is counted,
        // the message marked delayed, and the producer retries — work is
        // deferred, never lost
        if self.faults_active {
            if let Some(delay) = self.faults.deny_delay(shard, self.fault_progress()) {
                self.fault_acct.borrow_mut().denied_attempts += 1;
                self.tainted.borrow_mut()[shard] = true;
                let this = Rc::clone(self);
                des.schedule_in(delay, Box::new(move |des| this.produce(des, shard)));
                return;
            }
        }
        let now = des.now();
        let put = match self.mode {
            SimMode::PerMessage => {
                let mut msg = self.generator.borrow_mut().next_message_for_partition(
                    self.run_id,
                    now,
                    self.shard_base + shard,
                    self.global_partitions,
                );
                msg.id = self.ids.borrow_mut().next();
                self.broker.put(msg)
            }
            SimMode::Cohort => {
                let cohort = self.cohort_for(shard, now);
                // exactly one commit per successful put before the next
                // produce, so this counts successful puts — a throttled
                // retry re-presents the same seq
                let seq = self.total[shard] - rem;
                self.broker.put_cohort(&cohort, seq, now)
            }
        };
        match put {
            Ok(put) => {
                debug_assert_eq!(put.partition, shard);
                let this = Rc::clone(self);
                // visible strictly after availability
                let at = now + put.broker_latency + 1e-9;
                des.schedule_at(at, Box::new(move |des| this.process(des, shard)));
            }
            Err(BrokerError::Throttled { retry_after, .. }) => {
                *self.backoffs.borrow_mut() += 1;
                let this = Rc::clone(self);
                des.schedule_in(
                    retry_after.max(1e-4),
                    Box::new(move |des| this.produce(des, shard)),
                );
            }
            Err(e) => log::error!("sim put failed: {e}"),
        }
    }

    fn process(self: &Rc<Self>, des: &mut Des, shard: usize) {
        let now = des.now();
        let Some(lease) = self.esm.poll(shard, now) else {
            // record not yet visible (shouldn't happen) — retry shortly
            let this = Rc::clone(self);
            des.schedule_in(1e-3, Box::new(move |des| this.process(des, shard)));
            return;
        };
        let rec = &lease.records[0];
        let msg = rec.message.clone();
        let cost =
            match self
                .platform
                .process(shard, &msg.points, msg.dim, &self.model_key, self.centroids)
            {
                Ok(c) => c,
                Err(e) => {
                    log::error!("sim process failed: {e}");
                    self.esm.abort(lease);
                    return;
                }
            };
        // cold-start storms and stragglers stretch service inside their
        // windows; the stretch lands in the trace's overhead component so
        // the per-message timeline still sums exactly
        let penalty = if self.faults_active {
            let mult = self
                .faults
                .service_multiplier(shard, self.fault_progress());
            cost.total() * (mult - 1.0)
        } else {
            0.0
        };
        if penalty > 0.0 {
            self.tainted.borrow_mut()[shard] = true;
        }
        let this = Rc::clone(self);
        let partition = self.shard_base + shard;
        des.schedule_in(
            cost.total() + penalty,
            Box::new(move |des| {
                let end = des.now();
                this.esm.commit(lease);
                this.run.record(MessageTrace {
                    run_id: msg.run_id,
                    message_id: msg.id,
                    partition,
                    produced_at: msg.produced_at,
                    available_at: msg.available_at,
                    proc_start: now,
                    proc_end: end,
                    compute: cost.compute,
                    io: cost.io,
                    overhead: cost.overhead + penalty,
                });
                {
                    let mut rem = this.remaining.borrow_mut();
                    rem[shard] = rem[shard].saturating_sub(1);
                }
                if this.faults_active {
                    let mut acct = this.fault_acct.borrow_mut();
                    let mut tainted = this.tainted.borrow_mut();
                    if tainted[shard] {
                        acct.delayed += 1;
                        tainted[shard] = false;
                    } else {
                        acct.served_clean += 1;
                    }
                }
                // closed loop: next message for this shard immediately
                this.produce(des, shard);
            }),
        );
    }
}

/// One independent sub-simulation: `scenario` is already cell-local (its
/// `partitions`/`messages` describe this cell), while `shard_base` and
/// `global_partitions` keep trace partitions and key targeting global.
fn run_cell(
    scenario: &Scenario,
    engine: Arc<dyn StepEngine>,
    run_id: u64,
    shard_base: usize,
    global_partitions: usize,
    opts: SimOptions,
) -> Result<CellOutcome, String> {
    let mut des = Des::new().with_event_limit(20_000_000);
    let clock = des.clock();
    let platform = Arc::new(PlatformUnderTest::build(scenario, engine, clock)?);
    let broker = platform.broker();
    let esm = Arc::new(EventSourceMapping::new(platform.broker(), 1));
    let per_shard = scenario.messages.div_ceil(scenario.partitions);

    let fault_plan = scenario
        .extra_param(FAULTS_PARAM)
        .map(FaultPlan::preset_by_id)
        .unwrap_or_else(FaultPlan::none);
    let faults = FaultSchedule::new(&fault_plan, scenario.seed, scenario.partitions);
    let faults_active = faults.is_active();
    // hot-key skew is structural: the hot shard owns its share of the
    // whole run's traffic (the message count is conserved exactly)
    let mut total = vec![per_shard; scenario.partitions];
    faults.distribute(&mut total);
    let grand_total: usize = total.iter().sum();

    let state = Rc::new(CellLoop {
        platform,
        broker,
        esm,
        generator: RefCell::new(DataGenerator::new(GeneratorConfig {
            points_per_message: scenario.points_per_message,
            seed: scenario.seed,
            ..Default::default()
        })),
        ids: RefCell::new(IdAlloc::for_run(run_id, shard_base as u64)),
        cohorts: RefCell::new(vec![None; scenario.partitions]),
        run: RunTrace::with_mode(run_id, opts.trace),
        mode: opts.mode,
        model_key: format!("model-{run_id}"),
        centroids: scenario.centroids,
        run_id,
        shard_base,
        global_partitions,
        remaining: RefCell::new(total.clone()),
        total,
        backoffs: RefCell::new(0),
        faults,
        faults_active,
        fault_acct: RefCell::new(FaultAccounting {
            offered: if faults_active { grand_total as u64 } else { 0 },
            ..Default::default()
        }),
        tainted: RefCell::new(vec![false; scenario.partitions]),
        fault_total: (grand_total as f64).max(1.0),
    });

    for shard in 0..scenario.partitions {
        let st = Rc::clone(&state);
        des.schedule_at(0.0, Box::new(move |des| st.produce(des, shard)));
    }
    des.run();
    let des_events = des.executed();
    drop(des); // releases the pending closures' Rc clones
    let state = Rc::try_unwrap(state).map_err(|_| "sim cell leaked its state".to_string())?;
    let faults = if state.faults_active {
        let acct = state.fault_acct.into_inner();
        // the conserved identity: dropped + delayed + served_clean == offered
        acct.verify();
        Some(acct)
    } else {
        None
    };
    Ok(CellOutcome {
        trace: state.run,
        backoffs: state.backoffs.into_inner(),
        des_events,
        faults,
    })
}

/// Derived seed for cell `cell` — decorrelates generator content and
/// platform cold-start draws across cells, deterministically.
fn cell_scenario(base: &Scenario, cell: usize, per_shard: usize) -> Scenario {
    let mut cs = base.clone();
    cs.partitions = 1;
    cs.messages = per_shard;
    cs.seed =
        SplitMix64::new(base.seed ^ (cell as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15))
            .next_u64();
    cs
}

/// Cells the scenario decomposes into: one per shard when the shards are
/// independent by construction — a serverless stack with a 1:1
/// shard→container mapping (≤ the paper's 30-container Lambda cap) and a
/// forkable engine — otherwise 1 (the exact single-DES path).
fn shard_cells(scenario: &Scenario, engine: &dyn StepEngine) -> usize {
    let p = scenario.partitions;
    // a fault plan couples shards (global progress windows, hot-key
    // redistribution), so fault runs keep the exact single-DES path
    if scenario.platform == PlatformKind::Lambda
        && (2..=30).contains(&p)
        && scenario.extra_param(FAULTS_PARAM).unwrap_or(0) == 0
        && engine.fork(0).is_some()
    {
        p
    } else {
        1
    }
}

/// Run one scenario in simulated time with the default [`SimOptions`].
pub fn run_sim(scenario: &Scenario, engine: Arc<dyn StepEngine>) -> Result<SimRunResult, String> {
    run_sim_opts(scenario, engine, SimOptions::default())
}

/// Run one scenario in simulated time.
pub fn run_sim_opts(
    scenario: &Scenario,
    engine: Arc<dyn StepEngine>,
    opts: SimOptions,
) -> Result<SimRunResult, String> {
    let run_id = scenario.run_key();
    let cells = shard_cells(scenario, engine.as_ref());
    if cells == 1 {
        let out = run_cell(scenario, engine, run_id, 0, scenario.partitions, opts)?;
        let summary = out
            .trace
            .summarize()
            .ok_or_else(|| "no messages processed".to_string())?;
        return Ok(SimRunResult {
            summary,
            backoff_events: out.backoffs,
            des_events: out.des_events,
            trace: Arc::new(out.trace),
            faults: out.faults,
        });
    }

    let per_shard = scenario.messages.div_ceil(scenario.partitions);
    let mut slots: Vec<Option<Result<CellOutcome, String>>> = Vec::with_capacity(cells);
    slots.resize_with(cells, || None);
    let engine_ref = &engine;
    parallel_indexed_map(
        opts.lanes.max(1).min(cells),
        cells,
        move |_worker, cell| {
            let forked = engine_ref
                .fork(cell as u64)
                .ok_or_else(|| "engine stopped forking mid-run".to_string())?;
            run_cell(
                &cell_scenario(scenario, cell, per_shard),
                forked,
                run_id,
                cell,
                scenario.partitions,
                opts,
            )
        },
        |i, outcome| slots[i] = Some(outcome),
    );
    let mut outcomes = Vec::with_capacity(cells);
    for slot in slots {
        outcomes.push(slot.ok_or_else(|| "sim lane vanished".to_string())??);
    }
    let trace = RunTrace::merged(run_id, opts.trace, outcomes.iter().map(|o| &o.trace));
    let summary = trace
        .summarize()
        .ok_or_else(|| "no messages processed".to_string())?;
    Ok(SimRunResult {
        summary,
        backoff_events: outcomes.iter().map(|o| o.backoffs).sum(),
        des_events: outcomes.iter().map(|o| o.des_events).sum(),
        trace: Arc::new(trace),
        // cell decomposition is gated off whenever a fault plan is active
        faults: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::miniapp::platform::PlatformKind;
    use crate::sim::Dist;

    fn engine_with(key: (usize, usize), secs: f64) -> Arc<dyn StepEngine> {
        let mut e = CalibratedEngine::new(7);
        e.insert(key, Dist::Const(secs));
        Arc::new(e)
    }

    fn scenario(platform: PlatformKind, partitions: usize) -> Scenario {
        Scenario {
            platform,
            partitions,
            points_per_message: 256,
            centroids: 16,
            messages: 32,
            ..Default::default()
        }
    }

    fn with_mode(mode: SimMode) -> SimOptions {
        SimOptions {
            mode,
            ..Default::default()
        }
    }

    fn ids_of(r: &SimRunResult) -> Vec<u64> {
        r.trace.traces().iter().map(|t| t.message_id).collect()
    }

    #[test]
    fn lambda_sim_processes_all_messages() {
        let s = scenario(PlatformKind::Lambda, 4);
        let r = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        assert_eq!(r.summary.messages, 32);
        assert!(r.summary.throughput > 0.0);
        assert!(r.summary.service.mean > 0.05); // at least the compute time
        assert!(r.des_events > 64);
    }

    #[test]
    fn dask_sim_processes_all_messages() {
        let s = scenario(PlatformKind::DaskWrangler, 4);
        let r = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        assert_eq!(r.summary.messages, 32);
        assert!(r.summary.service.mean > 0.05);
    }

    #[test]
    fn lambda_throughput_scales_with_partitions() {
        // Fig 5's serverless panel: more shards → proportionally more T
        let t = |p: usize| {
            // enough messages per shard to amortize the one-time cold start
            let s = Scenario {
                messages: 240,
                ..scenario(PlatformKind::Lambda, p)
            };
            run_sim(&s, engine_with((256, 16), 0.1))
                .unwrap()
                .summary
                .throughput
        };
        let t1 = t(1);
        let t4 = t(4);
        let t8 = t(8);
        assert!(t4 > t1 * 3.0, "t1={t1} t4={t4}");
        assert!(t8 > t1 * 5.5, "t1={t1} t8={t8}");
    }

    #[test]
    fn dask_latency_grows_with_partitions() {
        // Fig 4's HPC panel: service time inflates with P
        let svc = |p: usize| {
            let s = Scenario {
                messages: 48,
                ..scenario(PlatformKind::DaskWrangler, p)
            };
            run_sim(&s, engine_with((256, 16), 0.02))
                .unwrap()
                .summary
                .service
                .mean
        };
        let s1 = svc(1);
        let s16 = svc(16);
        assert!(s16 > s1 * 1.5, "s1={s1} s16={s16}");
    }

    #[test]
    fn edge_sim_has_local_broker_latency() {
        // the edge's whole advantage: the broker hop is LAN (~2 ms), not
        // the Kinesis WAN put (~15 ms)
        let s = scenario(PlatformKind::Edge, 2);
        let r = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        assert_eq!(r.summary.messages, 32);
        assert!(
            r.summary.broker.mean < 0.005,
            "L^br mean {}",
            r.summary.broker.mean
        );
    }

    #[test]
    fn edge_pinned_throughput_saturates_at_device_capacity() {
        // a light message class sits under the break-even, so placement
        // pins it to the box: only EDGE_MAX_CONCURRENCY containers fit,
        // saturated invocations queue, and throughput flattens past 4
        // partitions — the USL signature of the latency-bound edge class
        let t = |p: usize| {
            let s = Scenario {
                messages: 240,
                ..scenario(PlatformKind::Edge, p)
            };
            run_sim(&s, engine_with((256, 16), 0.002))
                .unwrap()
                .summary
                .throughput
        };
        let t1 = t(1);
        let t4 = t(4);
        let t8 = t(8);
        assert!(t4 > t1 * 2.0, "scales to the container cap: t1={t1} t4={t4}");
        assert!(t8 < t4 * 1.25, "no gain past 4 containers: t4={t4} t8={t8}");
    }

    #[test]
    fn edge_spillable_throughput_grows_past_device_capacity() {
        // a heavy class exceeds the break-even: once the box saturates,
        // the placement layer spills over the backhaul to the cloud
        // fallback, so throughput keeps growing past the device cap —
        // unlike the pinned class above, which queues
        let t = |p: usize| {
            let s = Scenario {
                messages: 240,
                ..scenario(PlatformKind::Edge, p)
            };
            run_sim(&s, engine_with((256, 16), 0.1))
                .unwrap()
                .summary
                .throughput
        };
        let t4 = t(4);
        let t8 = t(8);
        assert!(
            t8 > t4 * 1.3,
            "spillover must rescue throughput: t4={t4} t8={t8}"
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let s = scenario(PlatformKind::Lambda, 2);
        let a = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        let b = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        assert!((a.summary.throughput - b.summary.throughput).abs() < 1e-9);
        assert!((a.summary.service.mean - b.summary.service.mean).abs() < 1e-12);
    }

    #[test]
    fn concurrent_runs_match_the_sequential_result() {
        // the campaign engine spawns run_sim per worker; interleaving with
        // other runs must not move a single measured number
        let s = scenario(PlatformKind::Lambda, 2);
        let base = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let s = s.clone();
                std::thread::spawn(move || run_sim(&s, engine_with((256, 16), 0.05)).unwrap())
            })
            .collect();
        for h in handles {
            let r = h.join().unwrap();
            assert_eq!(r.summary.messages, base.summary.messages);
            assert!((r.summary.throughput - base.summary.throughput).abs() < 1e-12);
            assert!((r.summary.service.mean - base.summary.service.mean).abs() < 1e-12);
            assert!((r.summary.broker.mean - base.summary.broker.mean).abs() < 1e-12);
        }
    }

    #[test]
    fn broker_latency_recorded() {
        let s = scenario(PlatformKind::Lambda, 2);
        let r = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        // Kinesis put latency ~15 ms
        assert!(
            (r.summary.broker.mean - 0.015).abs() < 0.005,
            "L^br mean {}",
            r.summary.broker.mean
        );
    }

    #[test]
    fn cohort_and_per_message_paths_are_bit_identical() {
        // the headline invariant: batching production into cohorts moves
        // no event time — every measured quantity matches to the bit,
        // on the cell-decomposed path (Lambda), the shared-medium path
        // (Dask), and the co-located edge stack (default put_cohort)
        for (platform, p) in [
            (PlatformKind::Lambda, 4),
            (PlatformKind::DaskWrangler, 4),
            (PlatformKind::Edge, 2),
        ] {
            let s = scenario(platform, p);
            let a = run_sim_opts(&s, engine_with((256, 16), 0.05), with_mode(SimMode::Cohort))
                .unwrap();
            let b = run_sim_opts(
                &s,
                engine_with((256, 16), 0.05),
                with_mode(SimMode::PerMessage),
            )
            .unwrap();
            assert_eq!(a.summary.messages, b.summary.messages, "{platform:?}");
            assert_eq!(a.backoff_events, b.backoff_events, "{platform:?}");
            assert_eq!(a.des_events, b.des_events, "{platform:?}");
            for (x, y) in [
                (a.summary.throughput, b.summary.throughput),
                (a.summary.window_seconds, b.summary.window_seconds),
                (a.summary.service.mean, b.summary.service.mean),
                (a.summary.service.std, b.summary.service.std),
                (a.summary.service.p95, b.summary.service.p95),
                (a.summary.sojourn.mean, b.summary.sojourn.mean),
                (a.summary.broker.mean, b.summary.broker.mean),
                (a.summary.compute_mean, b.summary.compute_mean),
            ] {
                assert_eq!(x.to_bits(), y.to_bits(), "{platform:?}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn lane_count_does_not_change_the_result() {
        let s = Scenario {
            messages: 96,
            ..scenario(PlatformKind::Lambda, 8)
        };
        let run = |lanes: usize| {
            run_sim_opts(
                &s,
                engine_with((256, 16), 0.05),
                SimOptions {
                    lanes,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let base = run(1);
        for lanes in [2, 8] {
            let r = run(lanes);
            assert_eq!(ids_of(&base), ids_of(&r), "lanes={lanes}");
            assert_eq!(
                base.summary.throughput.to_bits(),
                r.summary.throughput.to_bits(),
                "lanes={lanes}"
            );
            assert_eq!(
                base.summary.service.mean.to_bits(),
                r.summary.service.mean.to_bits(),
                "lanes={lanes}"
            );
            assert_eq!(base.des_events, r.des_events, "lanes={lanes}");
        }
    }

    #[test]
    fn same_seed_runs_repeat_the_id_sequence() {
        // ids derive from the scenario's run key, not the process-global
        // counter: interleaving unrelated runs (which consume global ids)
        // must not move the sim's id stream
        let s = scenario(PlatformKind::Lambda, 4);
        let a = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        let _ = crate::broker::next_message_id();
        let other = scenario(PlatformKind::DaskWrangler, 2);
        run_sim(&other, engine_with((256, 16), 0.05)).unwrap();
        let b = run_sim(&s, engine_with((256, 16), 0.05)).unwrap();
        let (ia, ib) = (ids_of(&a), ids_of(&b));
        assert!(!ia.is_empty());
        assert_eq!(ia, ib);
        // and the per-message oracle assigns the very same ids
        let c = run_sim_opts(
            &s,
            engine_with((256, 16), 0.05),
            with_mode(SimMode::PerMessage),
        )
        .unwrap();
        assert_eq!(ia, ids_of(&c));
    }

    #[test]
    fn sampled_and_off_tracing_keep_the_exact_moments() {
        let s = Scenario {
            messages: 96,
            ..scenario(PlatformKind::Lambda, 4)
        };
        let run = |trace: TraceMode| {
            run_sim_opts(
                &s,
                engine_with((256, 16), 0.05),
                SimOptions {
                    trace,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let full = run(TraceMode::Full);
        let sampled = run(TraceMode::Sampled { every: 7 });
        let off = run(TraceMode::Off);
        assert!(off.trace.traces().is_empty());
        assert!(sampled.trace.traces().len() < full.trace.traces().len());
        for r in [&sampled, &off] {
            assert_eq!(r.summary.messages, full.summary.messages);
            assert!((r.summary.throughput - full.summary.throughput).abs() < 1e-9);
            assert!((r.summary.service.mean - full.summary.service.mean).abs() < 1e-12);
            assert!((r.summary.broker.mean - full.summary.broker.mean).abs() < 1e-12);
        }
    }
}
