//! Synthetic data generator (paper: "the framework ... can simulate
//! different data rates and characteristics (e.g., message sizes)").
//!
//! Points are drawn from a fixed set of Gaussian blobs so the K-Means
//! workload is *learnable* — per-point inertia falls over the stream,
//! which the e2e example uses as its convergence check.

use crate::broker::Message;
use crate::util::rng::Pcg32;

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    /// Points per message (the paper's MS axis: 8,000 / 16,000 / 26,000).
    pub points_per_message: usize,
    /// Feature dimension (d=8 ≈ the paper's ~37 B/point messages).
    pub dim: usize,
    /// Number of latent blobs the points are drawn from.
    pub blobs: usize,
    /// Blob center spread and intra-blob noise.
    pub center_scale: f64,
    pub noise: f64,
    pub seed: u64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        Self {
            points_per_message: 8_000,
            dim: 8,
            blobs: 32,
            center_scale: 15.0,
            noise: 0.5,
            seed: 42,
        }
    }
}

/// The generator: deterministic, seeded, cheap per message.
pub struct DataGenerator {
    config: GeneratorConfig,
    centers: Vec<f32>,
    rng: Pcg32,
    produced: u64,
    next_key: u64,
}

impl DataGenerator {
    pub fn new(config: GeneratorConfig) -> Self {
        let mut rng = Pcg32::seeded(config.seed);
        let centers = (0..config.blobs * config.dim)
            .map(|_| (rng.normal() * config.center_scale) as f32)
            .collect();
        Self {
            config,
            centers,
            rng,
            produced: 0,
            next_key: 0,
        }
    }

    pub fn config(&self) -> &GeneratorConfig {
        &self.config
    }

    pub fn produced(&self) -> u64 {
        self.produced
    }

    /// The latent blob centers (ground truth for convergence tests).
    pub fn centers(&self) -> &[f32] {
        &self.centers
    }

    /// Generate one message at time `now` for `run_id`.  Keys rotate so
    /// messages spread uniformly over shards.
    pub fn next_message(&mut self, run_id: u64, now: f64) -> Message {
        let d = self.config.dim;
        let n = self.config.points_per_message;
        let mut points = Vec::with_capacity(n * d);
        for _ in 0..n {
            let b = self.rng.gen_range(self.config.blobs as u64) as usize;
            for k in 0..d {
                points.push(
                    self.centers[b * d + k] + (self.rng.normal() * self.config.noise) as f32,
                );
            }
        }
        self.produced += 1;
        self.next_key = self.next_key.wrapping_add(1);
        Message::new(run_id, self.next_key, points.into(), d, now)
    }

    /// Generate a message targeted at a specific partition of a
    /// `partitions`-wide broker (used by the closed-loop sim driver to keep
    /// every shard saturated).
    pub fn next_message_for_partition(
        &mut self,
        run_id: u64,
        now: f64,
        partition: usize,
        partitions: usize,
    ) -> Message {
        let mut msg = self.next_message(run_id, now);
        // find a key mapping to the wanted partition (bounded scan)
        let mut key = msg.key;
        for _ in 0..10_000 {
            if crate::broker::partition_for_key(key, partitions) == partition {
                break;
            }
            key = key.wrapping_add(1);
        }
        self.next_key = key;
        msg.key = key;
        msg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_shape_matches_config() {
        let mut g = DataGenerator::new(GeneratorConfig {
            points_per_message: 100,
            dim: 4,
            ..Default::default()
        });
        let m = g.next_message(1, 0.0);
        assert_eq!(m.n_points, 100);
        assert_eq!(m.dim, 4);
        assert_eq!(m.points.len(), 400);
        assert_eq!(g.produced(), 1);
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = GeneratorConfig {
            points_per_message: 10,
            seed: 9,
            ..Default::default()
        };
        let mut a = DataGenerator::new(cfg.clone());
        let mut b = DataGenerator::new(cfg);
        assert_eq!(a.next_message(1, 0.0).points, b.next_message(1, 0.0).points);
    }

    #[test]
    fn keys_rotate() {
        let mut g = DataGenerator::new(GeneratorConfig::default());
        let k1 = g.next_message(1, 0.0).key;
        let k2 = g.next_message(1, 0.0).key;
        assert_ne!(k1, k2);
    }

    #[test]
    fn partition_targeting() {
        let mut g = DataGenerator::new(GeneratorConfig {
            points_per_message: 4,
            ..Default::default()
        });
        for p in 0..8 {
            let m = g.next_message_for_partition(1, 0.0, p, 8);
            assert_eq!(crate::broker::partition_for_key(m.key, 8), p);
        }
    }

    #[test]
    fn points_cluster_around_centers() {
        let mut g = DataGenerator::new(GeneratorConfig {
            points_per_message: 2000,
            dim: 4,
            blobs: 4,
            center_scale: 50.0,
            noise: 0.1,
            seed: 3,
            ..Default::default()
        });
        let centers = g.centers().to_vec();
        let m = g.next_message(1, 0.0);
        // each point should be within ~1.0 of some blob center
        for i in 0..m.n_points {
            let p = &m.points[i * 4..(i + 1) * 4];
            let mind = (0..4)
                .map(|b| {
                    (0..4)
                        .map(|k| (p[k] - centers[b * 4 + k]).powi(2))
                        .sum::<f32>()
                        .sqrt()
                })
                .fold(f32::INFINITY, f32::min);
            assert!(mind < 1.0, "point {i} too far: {mind}");
        }
    }
}
