//! Live benchmark drivers.
//!
//! [`run_live`] is the wall-clock, threaded pipeline: same stages as the
//! sim driver but with real threads and, when wired with a
//! [`PjrtEngine`](crate::runtime::PjrtEngine), the real AOT K-Means
//! artifact executing on PJRT for every message — the path the e2e example
//! and calibration use.  A producer thread paces itself with the
//! intelligent-backoff controller; one consumer thread per shard drains
//! the broker.
//!
//! [`LivePilot`] is the *control-plane* driver: a provisioned platform
//! advanced one control interval at a time on a virtual clock, whose
//! parallelism the insight `ControlLoop` changes mid-run through the
//! service's `resize_pilot`.  Every message served is a real
//! `StreamProcessor::process` call against the pilot's backend, so
//! cold starts, Lustre contention, micro-batch delays, and resize
//! transitions all surface in measured capacity — deterministically.

use super::generator::{DataGenerator, GeneratorConfig};
use super::platform::{PlatformUnderTest, Scenario};
use super::trace::{next_run_id, MessageTrace, RunSummary, RunTrace};
use crate::broker::{BackoffController, BrokerError};
use crate::engine::StepEngine;
use crate::pilot::{PilotJob, PilotState, PilotStatus, ResizePlan, ResizeSemantics};
use crate::serverless::EventSourceMapping;
use crate::sim::{SharedClock, SimClock, WallClock};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Result of one live configuration run.
#[derive(Debug, Clone)]
pub struct LiveRunResult {
    pub summary: RunSummary,
    pub backoff_events: u64,
    /// Final producer rate the backoff controller converged to (msg/s).
    pub final_rate: f64,
}

/// Run one scenario live.  `initial_rate` seeds the backoff controller.
pub fn run_live(
    scenario: &Scenario,
    engine: Arc<dyn StepEngine>,
    initial_rate: f64,
) -> Result<LiveRunResult, String> {
    let clock: SharedClock = Arc::new(WallClock::new());
    let platform = Arc::new(PlatformUnderTest::build(
        scenario,
        engine,
        Arc::clone(&clock),
    )?);
    let esm = Arc::new(EventSourceMapping::new(platform.broker(), 1));
    let run_id = next_run_id();
    let run = Arc::new(RunTrace::new(run_id));
    let processed = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let target = scenario.messages as u64;

    // consumer threads: one per shard (the AWS invariant)
    let mut consumers = Vec::new();
    for shard in 0..scenario.partitions {
        let esm = Arc::clone(&esm);
        let platform = Arc::clone(&platform);
        let run = Arc::clone(&run);
        let processed = Arc::clone(&processed);
        let stop = Arc::clone(&stop);
        let clock = Arc::clone(&clock);
        let scenario = scenario.clone();
        // ps-lint: allow(thread-spawn): live-mode driver intentionally uses real consumer threads against the real broker; sim paths never reach here
        consumers.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let now = clock.now();
                let Some(lease) = esm.poll(shard, now) else {
                    std::thread::sleep(Duration::from_micros(200));
                    continue;
                };
                let msg = lease.records[0].message.clone();
                let start = clock.now();
                match platform.process(
                    shard,
                    &msg.points,
                    msg.dim,
                    &format!("model-{run_id}"),
                    scenario.centroids,
                ) {
                    Ok(cost) => {
                        let end = clock.now();
                        esm.commit(lease);
                        run.record(MessageTrace {
                            run_id: msg.run_id,
                            message_id: msg.id,
                            partition: shard,
                            produced_at: msg.produced_at,
                            available_at: msg.available_at,
                            proc_start: start,
                            proc_end: end,
                            compute: cost.compute,
                            io: cost.io,
                            overhead: cost.overhead,
                        });
                        processed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        log::warn!("live process failed: {e}");
                        esm.abort(lease);
                    }
                }
            }
        }));
    }

    // producer with intelligent backoff
    let mut generator = DataGenerator::new(GeneratorConfig {
        points_per_message: scenario.points_per_message,
        seed: scenario.seed,
        ..Default::default()
    });
    let mut backoff = BackoffController::new(initial_rate);
    let mut produced = 0u64;
    let mut last_control = clock.now();
    // produce slightly more than target so consumers never starve early
    let produce_target = target + scenario.partitions as u64;
    while processed.load(Ordering::Relaxed) < target {
        if produced < produce_target {
            let msg = generator.next_message(run_id, clock.now());
            match platform.broker().put(msg) {
                Ok(_) => {
                    produced += 1;
                }
                Err(BrokerError::Throttled { retry_after, .. }) => {
                    backoff.on_throttle();
                    std::thread::sleep(Duration::from_secs_f64(retry_after.min(0.05)));
                }
                Err(e) => return Err(e.to_string()),
            }
            std::thread::sleep(Duration::from_secs_f64(backoff.interval().min(0.05)));
        } else {
            std::thread::sleep(Duration::from_micros(300));
        }
        let now = clock.now();
        if now - last_control > 0.1 {
            backoff.on_lag_sample(esm.lag());
            last_control = now;
        }
    }
    stop.store(true, Ordering::Relaxed);
    for c in consumers {
        let _ = c.join();
    }
    let summary = run
        .summarize()
        .ok_or_else(|| "no messages processed".to_string())?;
    Ok(LiveRunResult {
        summary,
        backoff_events: backoff.congestion_events(),
        final_rate: backoff.rate(),
    })
}

/// A provisioned platform driven one control interval at a time — the
/// live actuation side of `insight::control::ControlLoop`.
///
/// Capacity is modeled as `parallelism` serving lanes: each served message
/// runs the pilot's real [`StreamProcessor`](crate::pilot::StreamProcessor)
/// (advancing the shared [`SimClock`] to the message's start time, so the
/// backend's own container/worker bookkeeping stays in sync) and occupies
/// its lane for the *measured* cost.  A resize through the service grows
/// lanes that only become usable after the plan's transition window —
/// scale-up capacity arrives late, exactly like the platform it models.
pub struct LivePilot {
    platform: Arc<PlatformUnderTest>,
    clock: Arc<SimClock>,
    /// Per-lane busy-until time (sim seconds).
    lanes: Vec<f64>,
    points: Arc<[f32]>,
    dim: usize,
    centroids: usize,
    model_key: String,
    now: f64,
    /// The processing pilot's control handle (resize target).
    pilot: PilotJob,
    /// Broker-driven stacks only
    /// ([`PlatformKind::broker_driven`](super::platform::PlatformKind::broker_driven)):
    /// the broker pilot whose shard count follows every resize, so the
    /// loop's decisions become live `set_shards`/`set_partitions`
    /// repartitions.
    broker_pilot: Option<PilotJob>,
    /// Most recent per-message total cost (capacity estimation).
    last_cost: f64,
}

impl LivePilot {
    /// Provision `scenario` on a fresh virtual clock.
    pub fn provision(scenario: &Scenario, engine: Arc<dyn StepEngine>) -> Result<Self, String> {
        let clock = Arc::new(SimClock::new());
        let platform = Arc::new(PlatformUnderTest::build(
            scenario,
            engine,
            clock.clone() as SharedClock,
        )?);
        let mut generator = DataGenerator::new(GeneratorConfig {
            points_per_message: scenario.points_per_message,
            seed: scenario.seed,
            ..Default::default()
        });
        let msg = generator.next_message(next_run_id(), 0.0);
        let pilot = platform.processing_pilot().clone();
        // broker-driven stacks keep shards == consumers through every
        // resize: capture the broker pilot as the co-actuated handle
        let broker_pilot = if scenario.platform.broker_driven().is_some() {
            platform.broker_pilot().cloned()
        } else {
            None
        };
        let parallelism = pilot.parallelism();
        Ok(Self {
            platform,
            clock,
            lanes: vec![0.0; parallelism.max(1)],
            points: msg.points,
            dim: msg.dim,
            centroids: scenario.centroids,
            model_key: format!("autoscale-live-{}", scenario.seed),
            now: 0.0,
            pilot,
            broker_pilot,
            last_cost: 0.0,
        })
    }

    /// Current sim time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// The processing pilot's effective parallelism.
    pub fn parallelism(&self) -> usize {
        self.status().parallelism
    }

    /// Control-plane read side: the processing pilot's live status.
    pub fn status(&self) -> PilotStatus {
        self.pilot.status()
    }

    /// The co-actuated broker pilot of a broker-driven stack.
    pub fn broker_pilot(&self) -> Option<&PilotJob> {
        self.broker_pilot.as_ref()
    }

    /// Whether any backing pilot — the processing pilot, or the broker
    /// pilot of a broker-driven stack — is mid-transition.  The control
    /// loop defers decisions (and fit samples) until every transition
    /// lands.
    pub fn is_resizing(&self) -> bool {
        self.pilot.status().state == PilotState::Resizing
            || self
                .broker_pilot
                .as_ref()
                .is_some_and(|bp| bp.status().state == PilotState::Resizing)
    }

    /// Short label of the platform under test ("lambda", "dask", ...).
    pub fn label(&self) -> &'static str {
        self.platform.label()
    }

    /// Nominal capacity (msg/s) from the last measured per-message cost.
    pub fn capacity_estimate(&self) -> f64 {
        if self.last_cost > 0.0 {
            self.lanes.len() as f64 / self.last_cost
        } else {
            0.0
        }
    }

    /// Actuate a resize through the service (the paper's "integrate
    /// StreamInsight into the resource management algorithm" verb),
    /// honoring the plan's semantics: under
    /// [`ResizeSemantics::Restart`](crate::pilot::ResizeSemantics::Restart)
    /// (savepoint + restore) the *whole* job is down for the transition
    /// window; otherwise new lanes come up busy until the deadline while
    /// the old capacity keeps serving, and on scale-down the least-busy
    /// lanes survive (the rest drain away).
    ///
    /// On a broker-driven stack the compute pilot commits first (it may
    /// clamp), then the broker reshards to the realized parallelism so
    /// shards == consumers survives every transition (the AWS invariant);
    /// the combined plan carries the slower of the two transition windows
    /// and reports [`ResizeSemantics::Repartition`] — or `Throttle`, when
    /// the compute side clamped, so the loop still learns the envelope.
    pub fn resize(&mut self, to: usize) -> Result<ResizePlan, String> {
        let plan = match &self.broker_pilot {
            Some(bp) => {
                let pplan = self.pilot.resize(to).map_err(|e| e.to_string())?;
                let bplan = bp.resize(pplan.to).map_err(|e| e.to_string())?;
                ResizePlan {
                    from: pplan.from,
                    to: pplan.to,
                    transition_s: pplan.transition_s.max(bplan.transition_s),
                    semantics: if pplan.semantics == ResizeSemantics::Throttle {
                        ResizeSemantics::Throttle
                    } else {
                        ResizeSemantics::Repartition
                    },
                }
            }
            None => self.pilot.resize(to).map_err(|e| e.to_string())?,
        };
        if plan.semantics == crate::pilot::ResizeSemantics::Restart && plan.is_change() {
            let ready = self.now + plan.transition_s;
            self.lanes.clear();
            self.lanes.resize(plan.to, ready);
        } else if plan.to > self.lanes.len() {
            let ready = self.now + plan.transition_s;
            while self.lanes.len() < plan.to {
                self.lanes.push(ready);
            }
        } else if plan.to < self.lanes.len() {
            self.lanes
                .sort_by(|a, b| a.partial_cmp(b).expect("lane times are finite"));
            self.lanes.truncate(plan.to);
        }
        Ok(plan)
    }

    /// Serve up to `demand` whole messages in the interval `[now, now+dt)`,
    /// advancing the virtual clock to `now + dt`.  Returns the number of
    /// messages actually started (the rest is the caller's backlog).
    pub fn step(&mut self, demand: f64, dt: f64) -> Result<f64, String> {
        let t0 = self.now;
        let t1 = t0 + dt;
        let budget = demand.floor() as u64;
        let mut served = 0u64;
        while served < budget {
            let (idx, busy) = self
                .lanes
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).expect("lane times are finite"))
                .map(|(i, &b)| (i, b))
                .expect("at least one lane");
            let start = busy.max(t0);
            if start >= t1 {
                break; // every lane is occupied past this interval
            }
            self.clock.advance_to(start);
            match self.platform.process(
                idx,
                &self.points,
                self.dim,
                &self.model_key,
                self.centroids,
            ) {
                Ok(cost) => {
                    self.lanes[idx] = start + cost.total();
                    self.last_cost = cost.total();
                    served += 1;
                }
                Err(e) => {
                    let transient = e.contains("throttled") || e.contains("concurrency");
                    if !transient {
                        return Err(e);
                    }
                    // substrate-level admission pushed back: brief lane
                    // backoff, then retry within the interval
                    self.lanes[idx] = start + 0.01;
                }
            }
        }
        self.clock.advance_to(t1);
        self.now = t1;
        Ok(served as f64)
    }

    /// Tear the platform down.
    pub fn shutdown(&self) {
        self.platform.service().shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{CalibratedEngine, StepEngine};
    use crate::kmeans::NativeEngine;
    use crate::miniapp::platform::PlatformKind;
    use crate::sim::Dist;

    fn fast_engine() -> Arc<dyn StepEngine> {
        let mut e = CalibratedEngine::new(3);
        e.insert((64, 8), Dist::Const(0.001));
        Arc::new(e)
    }

    fn tiny_scenario(platform: PlatformKind) -> Scenario {
        Scenario {
            platform,
            partitions: 2,
            points_per_message: 64,
            centroids: 8,
            messages: 12,
            ..Default::default()
        }
    }

    #[test]
    fn live_lambda_run_completes() {
        let r = run_live(&tiny_scenario(PlatformKind::Lambda), fast_engine(), 200.0).unwrap();
        assert!(r.summary.messages >= 12);
        assert!(r.summary.throughput > 0.0);
        assert!(r.final_rate > 0.0);
    }

    #[test]
    fn live_dask_run_completes() {
        let r = run_live(
            &tiny_scenario(PlatformKind::DaskWrangler),
            fast_engine(),
            200.0,
        )
        .unwrap();
        assert!(r.summary.messages >= 12);
    }

    fn slow_engine() -> Arc<dyn StepEngine> {
        let mut e = CalibratedEngine::new(3);
        e.insert((64, 8), Dist::Const(0.05));
        Arc::new(e)
    }

    #[test]
    fn live_pilot_serves_intervals_and_resizes() {
        use crate::pilot::PilotState;
        let mut lp =
            LivePilot::provision(&tiny_scenario(PlatformKind::Lambda), slow_engine()).unwrap();
        assert_eq!(lp.parallelism(), 2);
        let served = lp.step(1000.0, 1.0).unwrap();
        assert!(served > 0.0, "two lanes serve real messages");
        assert!(lp.capacity_estimate() > 0.0);

        let plan = lp.resize(6).unwrap();
        assert_eq!(plan.to, 6);
        assert_eq!(lp.status().state, PilotState::Resizing);
        assert_eq!(lp.parallelism(), 6, "target visible immediately");
        // idle through the transition window; the state machine lands
        lp.step(0.0, plan.transition_s + 0.1).unwrap();
        assert_eq!(lp.status().state, PilotState::Running);

        let served_wide = lp.step(1000.0, 1.0).unwrap();
        assert!(
            served_wide > served * 1.5,
            "3x lanes must serve materially more: {served} -> {served_wide}"
        );
        lp.shutdown();
    }

    #[test]
    fn live_pilot_is_deterministic() {
        let run = || {
            let mut lp =
                LivePilot::provision(&tiny_scenario(PlatformKind::Lambda), slow_engine())
                    .unwrap();
            let mut served = Vec::new();
            for i in 0..5 {
                if i == 2 {
                    lp.resize(4).unwrap();
                }
                served.push(lp.step(50.0, 1.0).unwrap());
            }
            lp.shutdown();
            served
        };
        assert_eq!(run(), run(), "same seed, same trajectory");
    }

    #[test]
    fn live_run_with_native_engine_computes_real_kmeans() {
        // real numerics through the whole live pipeline (native baseline;
        // the PJRT variant is tests/pipeline_live.rs)
        let s = tiny_scenario(PlatformKind::Lambda);
        let r = run_live(&s, Arc::new(NativeEngine), 500.0).unwrap();
        assert!(r.summary.messages >= 12);
        assert!(r.summary.compute_mean > 0.0);
    }
}
