//! Live (wall-clock, threaded) benchmark driver.
//!
//! Same pipeline as the sim driver but with real threads and, when wired
//! with a [`PjrtEngine`](crate::runtime::PjrtEngine), the real AOT K-Means
//! artifact executing on PJRT for every message — the path the e2e example
//! and calibration use.  A producer thread paces itself with the
//! intelligent-backoff controller; one consumer thread per shard drains
//! the broker.

use super::generator::{DataGenerator, GeneratorConfig};
use super::platform::{PlatformUnderTest, Scenario};
use super::trace::{next_run_id, MessageTrace, RunSummary, RunTrace};
use crate::broker::{BackoffController, BrokerError};
use crate::engine::StepEngine;
use crate::serverless::EventSourceMapping;
use crate::sim::{SharedClock, WallClock};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Result of one live configuration run.
#[derive(Debug, Clone)]
pub struct LiveRunResult {
    pub summary: RunSummary,
    pub backoff_events: u64,
    /// Final producer rate the backoff controller converged to (msg/s).
    pub final_rate: f64,
}

/// Run one scenario live.  `initial_rate` seeds the backoff controller.
pub fn run_live(
    scenario: &Scenario,
    engine: Arc<dyn StepEngine>,
    initial_rate: f64,
) -> Result<LiveRunResult, String> {
    let clock: SharedClock = Arc::new(WallClock::new());
    let platform = Arc::new(PlatformUnderTest::build(
        scenario,
        engine,
        Arc::clone(&clock),
    )?);
    let esm = Arc::new(EventSourceMapping::new(platform.broker(), 1));
    let run_id = next_run_id();
    let run = Arc::new(RunTrace::new(run_id));
    let processed = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let target = scenario.messages as u64;

    // consumer threads: one per shard (the AWS invariant)
    let mut consumers = Vec::new();
    for shard in 0..scenario.partitions {
        let esm = Arc::clone(&esm);
        let platform = Arc::clone(&platform);
        let run = Arc::clone(&run);
        let processed = Arc::clone(&processed);
        let stop = Arc::clone(&stop);
        let clock = Arc::clone(&clock);
        let scenario = scenario.clone();
        consumers.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let now = clock.now();
                let Some(lease) = esm.poll(shard, now) else {
                    std::thread::sleep(Duration::from_micros(200));
                    continue;
                };
                let msg = lease.records[0].message.clone();
                let start = clock.now();
                match platform.process(
                    shard,
                    &msg.points,
                    msg.dim,
                    &format!("model-{run_id}"),
                    scenario.centroids,
                ) {
                    Ok(cost) => {
                        let end = clock.now();
                        esm.commit(lease);
                        run.record(MessageTrace {
                            run_id: msg.run_id,
                            message_id: msg.id,
                            partition: shard,
                            produced_at: msg.produced_at,
                            available_at: msg.available_at,
                            proc_start: start,
                            proc_end: end,
                            compute: cost.compute,
                            io: cost.io,
                            overhead: cost.overhead,
                        });
                        processed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        log::warn!("live process failed: {e}");
                        esm.abort(lease);
                    }
                }
            }
        }));
    }

    // producer with intelligent backoff
    let mut generator = DataGenerator::new(GeneratorConfig {
        points_per_message: scenario.points_per_message,
        seed: scenario.seed,
        ..Default::default()
    });
    let mut backoff = BackoffController::new(initial_rate);
    let mut produced = 0u64;
    let mut last_control = clock.now();
    // produce slightly more than target so consumers never starve early
    let produce_target = target + scenario.partitions as u64;
    while processed.load(Ordering::Relaxed) < target {
        if produced < produce_target {
            let msg = generator.next_message(run_id, clock.now());
            match platform.broker().put(msg) {
                Ok(_) => {
                    produced += 1;
                }
                Err(BrokerError::Throttled { retry_after, .. }) => {
                    backoff.on_throttle();
                    std::thread::sleep(Duration::from_secs_f64(retry_after.min(0.05)));
                }
                Err(e) => return Err(e.to_string()),
            }
            std::thread::sleep(Duration::from_secs_f64(backoff.interval().min(0.05)));
        } else {
            std::thread::sleep(Duration::from_micros(300));
        }
        let now = clock.now();
        if now - last_control > 0.1 {
            backoff.on_lag_sample(esm.lag());
            last_control = now;
        }
    }
    stop.store(true, Ordering::Relaxed);
    for c in consumers {
        let _ = c.join();
    }
    let summary = run
        .summarize()
        .ok_or_else(|| "no messages processed".to_string())?;
    Ok(LiveRunResult {
        summary,
        backoff_events: backoff.congestion_events(),
        final_rate: backoff.rate(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{CalibratedEngine, StepEngine};
    use crate::kmeans::NativeEngine;
    use crate::miniapp::platform::PlatformKind;
    use crate::sim::Dist;

    fn fast_engine() -> Arc<dyn StepEngine> {
        let mut e = CalibratedEngine::new(3);
        e.insert((64, 8), Dist::Const(0.001));
        Arc::new(e)
    }

    fn tiny_scenario(platform: PlatformKind) -> Scenario {
        Scenario {
            platform,
            partitions: 2,
            points_per_message: 64,
            centroids: 8,
            messages: 12,
            ..Default::default()
        }
    }

    #[test]
    fn live_lambda_run_completes() {
        let r = run_live(&tiny_scenario(PlatformKind::Lambda), fast_engine(), 200.0).unwrap();
        assert!(r.summary.messages >= 12);
        assert!(r.summary.throughput > 0.0);
        assert!(r.final_rate > 0.0);
    }

    #[test]
    fn live_dask_run_completes() {
        let r = run_live(
            &tiny_scenario(PlatformKind::DaskWrangler),
            fast_engine(),
            200.0,
        )
        .unwrap();
        assert!(r.summary.messages >= 12);
    }

    #[test]
    fn live_run_with_native_engine_computes_real_kmeans() {
        // real numerics through the whole live pipeline (native baseline;
        // the PJRT variant is tests/pipeline_live.rs)
        let s = tiny_scenario(PlatformKind::Lambda);
        let r = run_live(&s, Arc::new(NativeEngine), 500.0).unwrap();
        assert!(r.summary.messages >= 12);
        assert!(r.summary.compute_mean > 0.0);
    }
}
