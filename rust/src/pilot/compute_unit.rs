//! Compute-units: the task abstraction of the Pilot-API.
//!
//! "compute-unit ... is a task representing a self-contained set of
//! operations and is the key abstraction for expressing the application
//! workload."  A CU carries a [`TaskSpec`]; backends execute it and post a
//! [`CuOutcome`].  Waiters block on a condvar.

use super::state::CuState;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

static NEXT_CU_ID: AtomicU64 = AtomicU64::new(1);

/// What a compute-unit does.
pub enum TaskSpec {
    /// One MiniBatch K-Means step over a batch of points (the paper's
    /// streaming workload).  Points are [n, dim] row-major.
    KMeansStep {
        points: Arc<Vec<f32>>,
        dim: usize,
        model_key: String,
        centroids: usize,
    },
    /// Arbitrary code (the "submission of arbitrary compute tasks" usage
    /// mode; supported by the local backend).
    Custom(Box<dyn FnOnce() -> Result<f64, String> + Send>),
    /// Sleep for a fixed duration (testing, DAG glue).
    Sleep(f64),
}

impl std::fmt::Debug for TaskSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TaskSpec::KMeansStep {
                dim,
                model_key,
                centroids,
                points,
            } => write!(
                f,
                "KMeansStep(n={}, dim={dim}, model={model_key}, c={centroids})",
                points.len() / dim.max(&1)
            ),
            TaskSpec::Custom(_) => write!(f, "Custom"),
            TaskSpec::Sleep(s) => write!(f, "Sleep({s})"),
        }
    }
}

/// Result of a finished compute-unit.
#[derive(Debug, Clone)]
pub struct CuOutcome {
    /// Scalar result (inertia for K-Means steps, custom value otherwise).
    pub value: f64,
    /// Timing breakdown (platform-dependent), modeled seconds.
    pub compute_seconds: f64,
    pub io_seconds: f64,
    pub overhead_seconds: f64,
    /// Which container/worker ran it.
    pub executor: String,
}

impl CuOutcome {
    pub fn total_seconds(&self) -> f64 {
        self.compute_seconds + self.io_seconds + self.overhead_seconds
    }
}

struct CuInner {
    state: Mutex<CuSnapshot>,
    cond: Condvar,
}

#[derive(Debug, Clone)]
struct CuSnapshot {
    state: CuState,
    outcome: Option<CuOutcome>,
    error: Option<String>,
}

/// A handle to a submitted compute-unit (cheap to clone).
#[derive(Clone)]
pub struct ComputeUnit {
    pub id: u64,
    inner: Arc<CuInner>,
}

impl ComputeUnit {
    pub fn new() -> ComputeUnit {
        ComputeUnit {
            id: NEXT_CU_ID.fetch_add(1, Ordering::Relaxed),
            inner: Arc::new(CuInner {
                state: Mutex::new(CuSnapshot {
                    state: CuState::New,
                    outcome: None,
                    error: None,
                }),
                cond: Condvar::new(),
            }),
        }
    }

    pub fn state(&self) -> CuState {
        self.inner.state.lock().unwrap().state
    }

    /// Attempt a state transition; panics on illegal transitions (bug).
    pub fn transition(&self, next: CuState) {
        let mut g = self.inner.state.lock().unwrap();
        assert!(
            g.state.can_transition(next),
            "illegal CU transition {} -> {next}",
            g.state
        );
        g.state = next;
        self.inner.cond.notify_all();
    }

    /// Mark done with an outcome.
    pub fn complete(&self, outcome: CuOutcome) {
        let mut g = self.inner.state.lock().unwrap();
        assert!(g.state.can_transition(CuState::Done));
        g.state = CuState::Done;
        g.outcome = Some(outcome);
        self.inner.cond.notify_all();
    }

    /// Mark failed with an error.
    pub fn fail(&self, error: String) {
        let mut g = self.inner.state.lock().unwrap();
        if g.state.can_transition(CuState::Failed) {
            g.state = CuState::Failed;
            g.error = Some(error);
            self.inner.cond.notify_all();
        }
    }

    /// Cancel if not already terminal. Returns whether it was canceled.
    pub fn cancel(&self) -> bool {
        let mut g = self.inner.state.lock().unwrap();
        if g.state.can_transition(CuState::Canceled) {
            g.state = CuState::Canceled;
            self.inner.cond.notify_all();
            true
        } else {
            false
        }
    }

    /// Block until the CU reaches a terminal state.
    pub fn wait(&self) -> CuState {
        let mut g = self.inner.state.lock().unwrap();
        while !g.state.is_terminal() {
            g = self.inner.cond.wait(g).unwrap();
        }
        g.state
    }

    /// Block with a timeout; returns the state observed at the end.
    pub fn wait_timeout(&self, timeout: std::time::Duration) -> CuState {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.state.lock().unwrap();
        while !g.state.is_terminal() {
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                break;
            }
            let (ng, _) = self.inner.cond.wait_timeout(g, remaining).unwrap();
            g = ng;
        }
        g.state
    }

    pub fn outcome(&self) -> Option<CuOutcome> {
        self.inner.state.lock().unwrap().outcome.clone()
    }

    pub fn error(&self) -> Option<String> {
        self.inner.state.lock().unwrap().error.clone()
    }
}

impl Default for ComputeUnit {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn outcome() -> CuOutcome {
        CuOutcome {
            value: 1.0,
            compute_seconds: 0.1,
            io_seconds: 0.02,
            overhead_seconds: 0.0,
            executor: "t".into(),
        }
    }

    #[test]
    fn lifecycle_and_wait() {
        let cu = ComputeUnit::new();
        assert_eq!(cu.state(), CuState::New);
        cu.transition(CuState::Queued);
        let waiter = {
            let cu = cu.clone();
            std::thread::spawn(move || cu.wait())
        };
        cu.transition(CuState::Running);
        cu.complete(outcome());
        assert_eq!(waiter.join().unwrap(), CuState::Done);
        assert!((cu.outcome().unwrap().total_seconds() - 0.12).abs() < 1e-12);
    }

    #[test]
    fn failure_records_error() {
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        cu.transition(CuState::Running);
        cu.fail("boom".into());
        assert_eq!(cu.state(), CuState::Failed);
        assert_eq!(cu.error().unwrap(), "boom");
        assert!(cu.outcome().is_none());
    }

    #[test]
    fn cancel_before_running() {
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        assert!(cu.cancel());
        assert_eq!(cu.state(), CuState::Canceled);
        // cancel on terminal is a no-op
        assert!(!cu.cancel());
    }

    #[test]
    #[should_panic(expected = "illegal CU transition")]
    fn illegal_transition_panics() {
        let cu = ComputeUnit::new();
        cu.transition(CuState::Running); // must go through Queued
    }

    #[test]
    fn wait_timeout_expires() {
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        let s = cu.wait_timeout(Duration::from_millis(20));
        assert_eq!(s, CuState::Queued); // still not terminal
    }

    #[test]
    fn ids_unique() {
        let a = ComputeUnit::new();
        let b = ComputeUnit::new();
        assert_ne!(a.id, b.id);
    }
}
