//! `PilotJob` — a user-visible handle to an allocated resource container —
//! and the backend interface plugins implement.
//!
//! Since the elastic-control-plane redesign a pilot is not fire-and-forget:
//! [`PilotBackend::resize`] changes a live backend's parallelism with
//! platform-true transition costs, and [`PilotJob`] tracks the resulting
//! `Running ↔ Resizing` excursion on the service clock — deterministic
//! sim-clock durations, observable through [`PilotJob::status`].

use super::compute_unit::{ComputeUnit, TaskSpec};
use super::description::{PilotDescription, Platform};
use super::state::PilotState;
use crate::broker::Broker;
use crate::sim::SharedClock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

static NEXT_PILOT_ID: AtomicU64 = AtomicU64::new(1);

#[derive(Debug, thiserror::Error)]
pub enum PilotError {
    #[error("pilot is not running (state {0})")]
    NotRunning(super::state::PilotState),
    #[error("platform {0} does not accept compute units")]
    NoCompute(&'static str),
    #[error("no plugin registered for platform {0:?}")]
    NoPlugin(String),
    #[error("no pilot with id {0}")]
    NoSuchPilot(u64),
    #[error("provisioning failed: {0}")]
    Provision(String),
    #[error("platform {0} does not support live resizing")]
    ResizeUnsupported(&'static str),
    #[error("a resize transition is already in flight (ready at t={0:.3})")]
    ResizeInProgress(f64),
    #[error("invalid resize target: {0}")]
    BadResize(String),
    #[error(transparent)]
    Description(#[from] super::description::DescriptionError),
}

/// Platform-true mechanics of one capacity transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResizeSemantics {
    /// Serverless: added containers cold-start; removed ones vanish
    /// instantly (the fleet simply stops booking them).
    ColdStart,
    /// HPC: new workers ride batch-queue + node-boot delays; removed
    /// workers drain their in-flight task first.
    WorkerStartup,
    /// Broker: shards/partitions are split or merged and the log
    /// rebalanced across the new layout.
    Repartition,
    /// Micro-batch engines: the job snapshots state and restarts at the
    /// new parallelism (savepoint + restore).
    Restart,
    /// The platform's hard cap kept the pilot below the requested target;
    /// the caller should throttle its source to the capped capacity.
    Throttle,
    /// Target equals current parallelism; nothing to do.
    NoChange,
}

/// The transition a backend committed to when asked to resize.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResizePlan {
    /// Parallelism before the transition.
    pub from: usize,
    /// Parallelism in effect once the transition completes.  May be below
    /// the requested target when the platform caps it (see
    /// [`ResizeSemantics::Throttle`]).
    pub to: usize,
    /// Deterministic sim-clock seconds until the new capacity is fully
    /// effective.  The pilot stays `Resizing` (still serving at the old
    /// capacity) for this long.
    pub transition_s: f64,
    pub semantics: ResizeSemantics,
}

impl ResizePlan {
    /// A no-op plan at parallelism `n`.
    pub fn no_change(n: usize) -> Self {
        Self {
            from: n,
            to: n,
            transition_s: 0.0,
            semantics: ResizeSemantics::NoChange,
        }
    }

    /// Whether the plan changes parallelism at all.
    pub fn is_change(&self) -> bool {
        self.from != self.to
    }
}

/// What a platform plugin provides after provisioning.
pub trait PilotBackend: Send + Sync {
    fn platform(&self) -> Platform;

    /// Submit a compute-unit for execution.  The backend must eventually
    /// drive `cu` to a terminal state.
    fn submit(&self, cu: ComputeUnit, spec: TaskSpec) -> Result<(), PilotError>;

    /// Current effective parallelism (containers / workers / shards).
    fn parallelism(&self) -> usize;

    /// Change the backend's parallelism to `to`, with platform-true
    /// semantics and cost.  Returns the committed [`ResizePlan`]; the
    /// backend's capacity model must reflect `plan.to` from now on (the
    /// job layer keeps the pilot `Resizing` for `plan.transition_s`).
    ///
    /// The default declines: platforms are rigid unless their plugin
    /// implements elasticity.
    fn resize(&self, to: usize) -> Result<ResizePlan, PilotError> {
        let _ = to;
        Err(PilotError::ResizeUnsupported(self.platform().name()))
    }

    /// The broker this pilot provisioned, if it is a broker pilot.
    fn broker(&self) -> Option<Arc<dyn Broker>> {
        None
    }

    /// The synchronous message-processing interface, if this is a
    /// processing pilot (what the mini-app drivers pump records through).
    fn processor(&self) -> Option<Arc<dyn super::processor::StreamProcessor>> {
        None
    }

    /// Graceful shutdown (drain and stop workers).
    fn shutdown(&self);

    /// Executed-task count (diagnostics).
    fn completed(&self) -> u64;
}

/// A point-in-time observation of a pilot (what
/// [`PilotComputeService::pilot_state`](super::service::PilotComputeService::pilot_state)
/// returns): the control plane's read side.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PilotStatus {
    pub id: u64,
    pub state: PilotState,
    /// Effective parallelism the backend reports right now.
    pub parallelism: usize,
    /// Completed resize transitions over the pilot's lifetime.
    pub resize_events: u64,
    /// When the in-flight transition completes (`Resizing` only).
    pub ready_at: Option<f64>,
}

struct PilotShared {
    state: Mutex<PilotState>,
    cond: Condvar,
    /// Sim-clock deadline of the in-flight resize transition.
    ready_at: Mutex<Option<f64>>,
    resize_events: AtomicU64,
}

/// A resource container handle (cheap to clone).
#[derive(Clone)]
pub struct PilotJob {
    pub id: u64,
    pub description: PilotDescription,
    backend: Arc<dyn PilotBackend>,
    shared: Arc<PilotShared>,
    clock: SharedClock,
    cus: Arc<Mutex<Vec<ComputeUnit>>>,
}

impl PilotJob {
    /// Wrap a provisioned backend (called by the service).  `clock` is the
    /// service clock resize transitions are timed on.
    pub fn new(
        description: PilotDescription,
        backend: Arc<dyn PilotBackend>,
        clock: SharedClock,
    ) -> Self {
        let job = Self {
            id: NEXT_PILOT_ID.fetch_add(1, Ordering::Relaxed),
            description,
            backend,
            shared: Arc::new(PilotShared {
                state: Mutex::new(PilotState::New),
                cond: Condvar::new(),
                ready_at: Mutex::new(None),
                resize_events: AtomicU64::new(0),
            }),
            clock,
            cus: Arc::new(Mutex::new(Vec::new())),
        };
        job.set_state(PilotState::Pending);
        job.set_state(PilotState::Running);
        job
    }

    pub fn state(&self) -> PilotState {
        *self.shared.state.lock().unwrap()
    }

    fn set_state(&self, next: PilotState) {
        let mut g = self.shared.state.lock().unwrap();
        assert!(
            g.can_transition(next),
            "illegal pilot transition {} -> {next}",
            *g
        );
        *g = next;
        self.shared.cond.notify_all();
    }

    pub fn platform(&self) -> Platform {
        self.backend.platform()
    }

    /// Effective parallelism (post-resize target while `Resizing`).
    pub fn parallelism(&self) -> usize {
        self.backend.parallelism()
    }

    /// Completed resize transitions.
    pub fn resize_events(&self) -> u64 {
        self.shared.resize_events.load(Ordering::Relaxed)
    }

    /// Finalize a due resize transition: `Resizing → Running` once the
    /// clock passes the transition deadline.  Cheap and idempotent — the
    /// control loop calls this every tick.  (Lock order everywhere:
    /// `ready_at` before `state`, so concurrent pollers serialize.)
    pub fn poll(&self) {
        let mut ready = self.shared.ready_at.lock().unwrap();
        let due = matches!(*ready, Some(t) if self.clock.now() >= t);
        if !due {
            return;
        }
        *ready = None;
        let mut state = self.shared.state.lock().unwrap();
        if *state == PilotState::Resizing {
            *state = PilotState::Running;
            self.shared.cond.notify_all();
        }
    }

    /// Live resize: ask the backend for `to` units of parallelism.  The
    /// pilot enters `Resizing` for the plan's deterministic transition
    /// window (it keeps serving at the old capacity meanwhile) and returns
    /// to `Running` once [`PilotJob::poll`] observes the deadline passed.
    ///
    /// Concurrent resizes on clones of this handle serialize on the
    /// transition lock: exactly one commits, the rest get
    /// [`PilotError::ResizeInProgress`].
    pub fn resize(&self, to: usize) -> Result<ResizePlan, PilotError> {
        if to == 0 {
            return Err(PilotError::BadResize("parallelism must be > 0".into()));
        }
        // hold the transition lock across check → backend commit → state
        // update, so the one-transition-at-a-time contract survives racing
        // callers (lock order: ready_at before state, as in poll())
        let mut ready = self.shared.ready_at.lock().unwrap();
        if matches!(*ready, Some(t) if self.clock.now() >= t) {
            *ready = None;
            let mut state = self.shared.state.lock().unwrap();
            if *state == PilotState::Resizing {
                *state = PilotState::Running;
                self.shared.cond.notify_all();
            }
        }
        if let Some(t) = *ready {
            return Err(PilotError::ResizeInProgress(t));
        }
        let state = self.state();
        if state != PilotState::Running {
            return Err(PilotError::NotRunning(state));
        }
        let plan = self.backend.resize(to)?;
        debug_assert!(
            (1..=to).contains(&plan.to) && plan.transition_s >= 0.0,
            "backend resize plan out of range: {plan:?}"
        );
        if plan.is_change() {
            self.shared.resize_events.fetch_add(1, Ordering::Relaxed);
            if plan.transition_s > 0.0 {
                *ready = Some(self.clock.now() + plan.transition_s);
                self.set_state(PilotState::Resizing);
            }
        }
        Ok(plan)
    }

    /// Point-in-time status (finalizes a due resize first).
    pub fn status(&self) -> PilotStatus {
        self.poll();
        PilotStatus {
            id: self.id,
            state: self.state(),
            parallelism: self.backend.parallelism(),
            resize_events: self.resize_events(),
            ready_at: *self.shared.ready_at.lock().unwrap(),
        }
    }

    /// Submit a task to this pilot's resources.  A `Resizing` pilot still
    /// accepts work — the old capacity serves until the transition lands.
    pub fn submit_compute_unit(&self, spec: TaskSpec) -> Result<ComputeUnit, PilotError> {
        self.poll();
        let state = self.state();
        if !state.is_serving() {
            return Err(PilotError::NotRunning(state));
        }
        let cu = ComputeUnit::new();
        cu.transition(super::state::CuState::Queued);
        self.backend.submit(cu.clone(), spec)?;
        self.cus.lock().unwrap().push(cu.clone());
        Ok(cu)
    }

    /// Wait until every submitted CU reaches a terminal state.
    pub fn wait_all(&self) {
        let cus = self.cus.lock().unwrap().clone();
        for cu in cus {
            cu.wait();
        }
    }

    /// The broker this pilot stood up (broker pilots only).
    pub fn broker(&self) -> Option<Arc<dyn Broker>> {
        self.backend.broker()
    }

    /// The message-processing interface (processing pilots only).
    pub fn processor(&self) -> Option<Arc<dyn super::processor::StreamProcessor>> {
        self.backend.processor()
    }

    /// All compute units submitted so far.
    pub fn compute_units(&self) -> Vec<ComputeUnit> {
        self.cus.lock().unwrap().clone()
    }

    pub fn completed(&self) -> u64 {
        self.backend.completed()
    }

    /// Drain workers and mark the pilot done.
    pub fn cancel(&self) {
        if self.state().is_serving() {
            self.backend.shutdown();
            self.set_state(PilotState::Canceled);
        }
    }

    /// Graceful completion: wait for CUs, stop workers.
    pub fn finish(&self) {
        if self.state().is_serving() {
            self.wait_all();
            self.backend.shutdown();
            self.set_state(PilotState::Done);
        }
    }
}
