//! `PilotJob` — a user-visible handle to an allocated resource container —
//! and the backend interface plugins implement.

use super::compute_unit::{ComputeUnit, TaskSpec};
use super::description::{PilotDescription, Platform};
use super::state::PilotState;
use crate::broker::Broker;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

static NEXT_PILOT_ID: AtomicU64 = AtomicU64::new(1);

#[derive(Debug, thiserror::Error)]
pub enum PilotError {
    #[error("pilot is not running (state {0})")]
    NotRunning(super::state::PilotState),
    #[error("platform {0} does not accept compute units")]
    NoCompute(&'static str),
    #[error("no plugin registered for platform {0:?}")]
    NoPlugin(String),
    #[error("provisioning failed: {0}")]
    Provision(String),
    #[error(transparent)]
    Description(#[from] super::description::DescriptionError),
}

/// What a platform plugin provides after provisioning.
pub trait PilotBackend: Send + Sync {
    fn platform(&self) -> Platform;

    /// Submit a compute-unit for execution.  The backend must eventually
    /// drive `cu` to a terminal state.
    fn submit(&self, cu: ComputeUnit, spec: TaskSpec) -> Result<(), PilotError>;

    /// The broker this pilot provisioned, if it is a broker pilot.
    fn broker(&self) -> Option<Arc<dyn Broker>> {
        None
    }

    /// The synchronous message-processing interface, if this is a
    /// processing pilot (what the mini-app drivers pump records through).
    fn processor(&self) -> Option<Arc<dyn super::processor::StreamProcessor>> {
        None
    }

    /// Graceful shutdown (drain and stop workers).
    fn shutdown(&self);

    /// Executed-task count (diagnostics).
    fn completed(&self) -> u64;
}

struct PilotShared {
    state: Mutex<PilotState>,
    cond: Condvar,
}

/// A resource container handle (cheap to clone).
#[derive(Clone)]
pub struct PilotJob {
    pub id: u64,
    pub description: PilotDescription,
    backend: Arc<dyn PilotBackend>,
    shared: Arc<PilotShared>,
    cus: Arc<Mutex<Vec<ComputeUnit>>>,
}

impl PilotJob {
    /// Wrap a provisioned backend (called by the service).
    pub fn new(description: PilotDescription, backend: Arc<dyn PilotBackend>) -> Self {
        let job = Self {
            id: NEXT_PILOT_ID.fetch_add(1, Ordering::Relaxed),
            description,
            backend,
            shared: Arc::new(PilotShared {
                state: Mutex::new(PilotState::New),
                cond: Condvar::new(),
            }),
            cus: Arc::new(Mutex::new(Vec::new())),
        };
        job.set_state(PilotState::Pending);
        job.set_state(PilotState::Running);
        job
    }

    pub fn state(&self) -> PilotState {
        *self.shared.state.lock().unwrap()
    }

    fn set_state(&self, next: PilotState) {
        let mut g = self.shared.state.lock().unwrap();
        assert!(
            g.can_transition(next),
            "illegal pilot transition {} -> {next}",
            *g
        );
        *g = next;
        self.shared.cond.notify_all();
    }

    pub fn platform(&self) -> Platform {
        self.backend.platform()
    }

    /// Submit a task to this pilot's resources.
    pub fn submit_compute_unit(&self, spec: TaskSpec) -> Result<ComputeUnit, PilotError> {
        let state = self.state();
        if state != PilotState::Running {
            return Err(PilotError::NotRunning(state));
        }
        let cu = ComputeUnit::new();
        cu.transition(super::state::CuState::Queued);
        self.backend.submit(cu.clone(), spec)?;
        self.cus.lock().unwrap().push(cu.clone());
        Ok(cu)
    }

    /// Wait until every submitted CU reaches a terminal state.
    pub fn wait_all(&self) {
        let cus = self.cus.lock().unwrap().clone();
        for cu in cus {
            cu.wait();
        }
    }

    /// The broker this pilot stood up (broker pilots only).
    pub fn broker(&self) -> Option<Arc<dyn Broker>> {
        self.backend.broker()
    }

    /// The message-processing interface (processing pilots only).
    pub fn processor(&self) -> Option<Arc<dyn super::processor::StreamProcessor>> {
        self.backend.processor()
    }

    /// All compute units submitted so far.
    pub fn compute_units(&self) -> Vec<ComputeUnit> {
        self.cus.lock().unwrap().clone()
    }

    pub fn completed(&self) -> u64 {
        self.backend.completed()
    }

    /// Drain workers and mark the pilot done.
    pub fn cancel(&self) {
        if self.state() == PilotState::Running {
            self.backend.shutdown();
            self.set_state(PilotState::Canceled);
        }
    }

    /// Graceful completion: wait for CUs, stop workers.
    pub fn finish(&self) {
        if self.state() == PilotState::Running {
            self.wait_all();
            self.backend.shutdown();
            self.set_state(PilotState::Done);
        }
    }
}
