//! Shared worker-pool plumbing: N threads pulling (ComputeUnit, TaskSpec)
//! pairs from a channel for pilot backends, plus the scoped
//! [`parallel_indexed_map`] primitive the insight campaign engine uses to
//! run independent sweep configurations across cores.

use super::compute_unit::{ComputeUnit, CuOutcome, TaskSpec};
use super::state::CuState;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

type Job = (ComputeUnit, TaskSpec);

/// Executes one task on worker `index`.
pub trait TaskExecutor: Send + Sync + 'static {
    fn execute(&self, worker: usize, spec: TaskSpec) -> Result<CuOutcome, String>;
}

/// A fixed-size pool of task workers.
pub struct WorkerPool {
    sender: Mutex<Option<mpsc::Sender<Job>>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    completed: Arc<AtomicU64>,
}

impl WorkerPool {
    pub fn new(workers: usize, executor: Arc<dyn TaskExecutor>) -> Self {
        assert!(workers > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let completed = Arc::new(AtomicU64::new(0));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let executor = Arc::clone(&executor);
                let completed = Arc::clone(&completed);
                std::thread::Builder::new()
                    .name(format!("pilot-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let g = rx.lock().unwrap();
                            g.recv()
                        };
                        let Ok((cu, spec)) = job else { break };
                        if cu.state() != CuState::Queued {
                            continue; // canceled while queued
                        }
                        cu.transition(CuState::Running);
                        match executor.execute(i, spec) {
                            Ok(outcome) => {
                                cu.complete(outcome);
                                completed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(e) => cu.fail(e),
                        }
                    })
                    .expect("spawn pilot worker")
            })
            .collect();
        Self {
            sender: Mutex::new(Some(tx)),
            handles: Mutex::new(handles),
            completed,
        }
    }

    pub fn submit(&self, cu: ComputeUnit, spec: TaskSpec) -> Result<(), String> {
        let g = self.sender.lock().unwrap();
        match g.as_ref() {
            Some(tx) => tx.send((cu, spec)).map_err(|_| "pool stopped".to_string()),
            None => Err("pool stopped".to_string()),
        }
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Close the queue and join all workers.
    pub fn shutdown(&self) {
        let tx = self.sender.lock().unwrap().take();
        drop(tx);
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// A [`WorkerPool`] that spawns its threads on first submit.
///
/// Pilots always carry a compute path, but many (e.g. every pilot a
/// mini-app sweep provisions) only ever serve broker/processor traffic —
/// eager pools would spawn thousands of idle threads across a 90-config
/// sweep for nothing.  One mutex guards the idle/running/closed state as
/// a unit, so a submit racing a shutdown can never resurrect the pool.
pub struct LazyWorkerPool {
    workers: AtomicUsize,
    executor: Arc<dyn TaskExecutor>,
    state: Mutex<LazyState>,
    /// Completed-task total of pools retired by [`LazyWorkerPool::resize`].
    retired_completed: AtomicU64,
}

enum LazyState {
    Idle,
    Running(WorkerPool),
    /// Shut down; carries the final completed-task count.
    Closed(u64),
}

impl LazyWorkerPool {
    pub fn new(workers: usize, executor: Arc<dyn TaskExecutor>) -> Self {
        assert!(workers > 0);
        Self {
            workers: AtomicUsize::new(workers),
            executor,
            state: Mutex::new(LazyState::Idle),
            retired_completed: AtomicU64::new(0),
        }
    }

    /// The dispatch parallelism the pool (re)spawns with.
    pub fn workers(&self) -> usize {
        self.workers.load(Ordering::Relaxed)
    }

    /// Change the dispatch parallelism.  A running pool drains its queue
    /// and joins its threads first (the elastic control plane's
    /// worker-level drain); the next submit respawns at the new size.  A
    /// closed pool stays closed.
    pub fn resize(&self, workers: usize) {
        assert!(workers > 0);
        let mut state = self.state.lock().unwrap();
        self.workers.store(workers, Ordering::Relaxed);
        if let LazyState::Running(pool) = &*state {
            pool.shutdown();
            self.retired_completed
                .fetch_add(pool.completed(), Ordering::Relaxed);
            *state = LazyState::Idle;
        }
    }

    pub fn submit(&self, cu: ComputeUnit, spec: TaskSpec) -> Result<(), String> {
        let mut state = self.state.lock().unwrap();
        if let LazyState::Idle = *state {
            *state = LazyState::Running(WorkerPool::new(
                self.workers.load(Ordering::Relaxed),
                Arc::clone(&self.executor),
            ));
        }
        match &*state {
            LazyState::Running(pool) => pool.submit(cu, spec),
            LazyState::Closed(_) => Err("pool stopped".to_string()),
            LazyState::Idle => unreachable!("initialized above"),
        }
    }

    pub fn completed(&self) -> u64 {
        let retired = self.retired_completed.load(Ordering::Relaxed);
        retired
            + match &*self.state.lock().unwrap() {
                LazyState::Idle => 0,
                LazyState::Running(pool) => pool.completed(),
                LazyState::Closed(count) => *count,
            }
    }

    /// Drain and join, if threads were ever spawned; further submits fail.
    pub fn shutdown(&self) {
        let mut state = self.state.lock().unwrap();
        let final_count = match &*state {
            LazyState::Running(pool) => {
                pool.shutdown();
                pool.completed()
            }
            LazyState::Idle => 0,
            LazyState::Closed(count) => *count,
        };
        *state = LazyState::Closed(final_count);
    }
}

/// Scoped data-parallel map — the campaign engine's sweep executor.
///
/// `jobs` scoped workers claim indices `0..n` from a shared counter
/// (dynamic load balancing: configurations differ wildly in cost), run
/// `work(worker, index)`, and stream `(index, value)` pairs back to
/// `consume` **on the calling thread** in completion order.  The caller
/// reassembles deterministic order from the indices; with `jobs == 1` no
/// threads are spawned and indices arrive strictly in order.
pub fn parallel_indexed_map<T, W, C>(jobs: usize, n: usize, work: W, mut consume: C)
where
    T: Send,
    W: Fn(usize, usize) -> T + Sync,
    C: FnMut(usize, T),
{
    assert!(jobs > 0, "parallel_indexed_map needs at least one job");
    if jobs == 1 || n <= 1 {
        for i in 0..n {
            consume(i, work(0, i));
        }
        return;
    }
    // declared before the scope so the spawned threads' borrows of the
    // counter (and the moved sender clones) outlive `'scope`
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|scope| {
        for worker in 0..jobs.min(n) {
            let tx = tx.clone();
            let next = &next;
            let work = &work;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n || tx.send((i, work(worker, i))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, value) in rx {
            consume(i, value);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Doubler;
    impl TaskExecutor for Doubler {
        fn execute(&self, worker: usize, spec: TaskSpec) -> Result<CuOutcome, String> {
            match spec {
                TaskSpec::Sleep(s) => Ok(CuOutcome {
                    value: s * 2.0,
                    compute_seconds: s,
                    io_seconds: 0.0,
                    overhead_seconds: 0.0,
                    executor: format!("w{worker}"),
                }),
                TaskSpec::Custom(f) => f().map(|v| CuOutcome {
                    value: v,
                    compute_seconds: 0.0,
                    io_seconds: 0.0,
                    overhead_seconds: 0.0,
                    executor: format!("w{worker}"),
                }),
                _ => Err("unsupported".into()),
            }
        }
    }

    #[test]
    fn executes_tasks_in_parallel() {
        let pool = WorkerPool::new(4, Arc::new(Doubler));
        let cus: Vec<ComputeUnit> = (0..16)
            .map(|i| {
                let cu = ComputeUnit::new();
                cu.transition(CuState::Queued);
                pool.submit(cu.clone(), TaskSpec::Sleep(i as f64)).unwrap();
                cu
            })
            .collect();
        for (i, cu) in cus.iter().enumerate() {
            assert_eq!(cu.wait(), CuState::Done);
            assert_eq!(cu.outcome().unwrap().value, i as f64 * 2.0);
        }
        assert_eq!(pool.completed(), 16);
    }

    #[test]
    fn failures_propagate() {
        let pool = WorkerPool::new(2, Arc::new(Doubler));
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        pool.submit(
            cu.clone(),
            TaskSpec::Custom(Box::new(|| Err("kaput".into()))),
        )
        .unwrap();
        assert_eq!(cu.wait(), CuState::Failed);
        assert_eq!(cu.error().unwrap(), "kaput");
    }

    #[test]
    fn canceled_cus_are_skipped() {
        let pool = WorkerPool::new(1, Arc::new(Doubler));
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        cu.cancel();
        pool.submit(cu.clone(), TaskSpec::Sleep(0.0)).unwrap();
        pool.shutdown();
        assert_eq!(cu.state(), CuState::Canceled);
    }

    #[test]
    fn shutdown_then_submit_errors() {
        let pool = WorkerPool::new(1, Arc::new(Doubler));
        pool.shutdown();
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        assert!(pool.submit(cu, TaskSpec::Sleep(0.0)).is_err());
    }

    #[test]
    fn lazy_pool_spawns_on_first_submit_only() {
        let pool = LazyWorkerPool::new(2, Arc::new(Doubler));
        assert_eq!(pool.completed(), 0);
        pool.shutdown(); // never spawned: nothing to join...
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        // ...and a closed pool refuses late submissions instead of
        // resurrecting threads
        assert!(pool.submit(cu, TaskSpec::Sleep(0.0)).is_err());

        let pool = LazyWorkerPool::new(2, Arc::new(Doubler));
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        pool.submit(cu.clone(), TaskSpec::Sleep(0.0)).unwrap();
        assert_eq!(cu.wait(), CuState::Done);
        assert_eq!(pool.completed(), 1);
        pool.shutdown();
    }

    #[test]
    fn lazy_pool_resize_drains_and_respawns() {
        let pool = LazyWorkerPool::new(2, Arc::new(Doubler));
        assert_eq!(pool.workers(), 2);
        // resize while idle: just a size change
        pool.resize(4);
        assert_eq!(pool.workers(), 4);
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        pool.submit(cu.clone(), TaskSpec::Sleep(0.0)).unwrap();
        assert_eq!(cu.wait(), CuState::Done);
        // resize while running: the old pool drains, counts are preserved
        pool.resize(1);
        assert_eq!(pool.completed(), 1);
        let cu2 = ComputeUnit::new();
        cu2.transition(CuState::Queued);
        pool.submit(cu2.clone(), TaskSpec::Sleep(0.0)).unwrap();
        assert_eq!(cu2.wait(), CuState::Done);
        assert_eq!(pool.completed(), 2, "retired pools keep counting");
        pool.shutdown();
        assert_eq!(pool.completed(), 2);
    }

    #[test]
    fn parallel_indexed_map_reassembles_by_index() {
        let mut out = vec![0usize; 64];
        parallel_indexed_map(4, 64, |_worker, i| i * 3, |i, v| out[i] = v);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * 3);
        }
    }

    #[test]
    fn parallel_indexed_map_single_job_runs_inline_in_order() {
        let mut order = Vec::new();
        parallel_indexed_map(1, 16, |worker, i| {
            assert_eq!(worker, 0);
            i
        }, |i, v| {
            assert_eq!(i, v);
            order.push(i);
        });
        assert_eq!(order, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_indexed_map_handles_empty_and_tiny_inputs() {
        let mut hits = 0;
        parallel_indexed_map(8, 0, |_, i| i, |_, _| hits += 1);
        assert_eq!(hits, 0);
        parallel_indexed_map(8, 1, |_, i| i, |_, _| hits += 1);
        assert_eq!(hits, 1);
    }

    #[test]
    fn custom_closures_return_values() {
        let pool = WorkerPool::new(2, Arc::new(Doubler));
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        pool.submit(cu.clone(), TaskSpec::Custom(Box::new(|| Ok(42.0))))
            .unwrap();
        cu.wait();
        assert_eq!(cu.outcome().unwrap().value, 42.0);
    }
}
