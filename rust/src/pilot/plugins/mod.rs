//! Built-in platform plugins (paper Fig 2's plugin architecture).
//!
//! Each module pairs a [`PlatformPlugin`](super::registry::PlatformPlugin)
//! — naming, description validation, provisioning — with the backend it
//! provisions.  All substrate construction (`KinesisStream`, `LambdaFleet`,
//! `KafkaTopic`, `DaskPool`, edge fleets) lives *only* here: the service,
//! the mini-app, and the drivers provision through the registry.

pub mod broker;
pub mod edge;
pub mod flink;
pub mod hpc;
pub mod local;
pub mod serverless;

pub use broker::{KafkaBrokerBackend, KafkaPlugin, KinesisBrokerBackend, KinesisPlugin};
pub use edge::{EdgeBackend, EdgePlugin};
pub use flink::{FlinkBackend, FlinkPlugin};
pub use hpc::{HpcBackend, HpcPlugin};
pub use local::{LocalBackend, LocalPlugin};
pub use serverless::{ServerlessBackend, ServerlessPlugin};
