//! Platform plugins: provision a [`PilotBackend`](super::job::PilotBackend)
//! for each supported platform (paper Fig 2's plugin architecture).

pub mod broker;
pub mod hpc;
pub mod local;
pub mod serverless;

pub use broker::{KafkaBrokerBackend, KinesisBrokerBackend};
pub use hpc::HpcBackend;
pub use local::LocalBackend;
pub use serverless::ServerlessBackend;
