//! Broker plugins: provision a Kinesis-like stream ("Kinesis Pilot", paper
//! Fig 2 step 1a/b) or a Kafka-like topic.  Broker pilots do not accept
//! compute-units — they expose the provisioned [`Broker`] instead.

use crate::broker::kafka::{KafkaConfig, KafkaTopic};
use crate::broker::kinesis::{KinesisStream, ShardLimits};
use crate::broker::Broker;
use crate::pilot::compute_unit::{ComputeUnit, TaskSpec};
use crate::pilot::description::{PilotDescription, Platform};
use crate::pilot::job::{PilotBackend, PilotError, ResizePlan, ResizeSemantics};
use crate::pilot::registry::{Elasticity, PlatformPlugin, PriceModel, ProvisionContext};
use crate::sim::{SharedClock, SharedResource};
use std::sync::Arc;

/// Seconds to split or merge one shard/partition during a live reshard
/// (Kinesis `UpdateShardCount` and Kafka partition adds both proceed
/// shard-by-shard).
pub const REPARTITION_S_PER_SHARD: f64 = 1.5;

/// Kinesis' 2019 list price per shard-hour (us-east-1).  A shard split
/// bills the child shards from the moment the split starts, so the
/// transition charges the repartition window at the shard-hour rate.
pub const KINESIS_SHARD_HOUR_DOLLARS: f64 = 0.015;
/// Amortized broker-instance cost per Kafka partition-hour: a
/// self-managed 3-broker streaming cluster serving ~32 partitions.
pub const KAFKA_PARTITION_HOUR_DOLLARS: f64 = 0.011;

fn broker_price(unit_hour: f64, unit: &'static str) -> PriceModel {
    PriceModel::per_unit_hour(unit_hour, unit)
        .with_transition(unit_hour * REPARTITION_S_PER_SHARD / 3600.0)
}

/// The repartition plan both broker backends share: cost is linear in the
/// shard delta, in either direction.
fn repartition_plan(from: usize, to: usize) -> ResizePlan {
    ResizePlan {
        from,
        to,
        transition_s: from.abs_diff(to) as f64 * REPARTITION_S_PER_SHARD,
        semantics: ResizeSemantics::Repartition,
    }
}

/// Kinesis broker pilot backend.
pub struct KinesisBrokerBackend {
    stream: Arc<KinesisStream>,
}

impl KinesisBrokerBackend {
    pub fn provision(desc: &PilotDescription, clock: SharedClock) -> Result<Self, PilotError> {
        Ok(Self {
            stream: Arc::new(KinesisStream::new(
                "pilot-stream",
                desc.parallelism,
                ShardLimits::default(),
                clock,
            )),
        })
    }

    pub fn stream(&self) -> Arc<KinesisStream> {
        Arc::clone(&self.stream)
    }
}

impl PilotBackend for KinesisBrokerBackend {
    fn platform(&self) -> Platform {
        Platform::KINESIS
    }

    fn submit(&self, cu: ComputeUnit, _spec: TaskSpec) -> Result<(), PilotError> {
        cu.fail("broker pilots do not execute compute units".into());
        Err(PilotError::NoCompute("kinesis"))
    }

    fn parallelism(&self) -> usize {
        self.stream.num_partitions()
    }

    /// Broker resize: live reshard, paying the per-shard split/merge cost.
    fn resize(&self, to: usize) -> Result<ResizePlan, PilotError> {
        let from = self.stream.num_partitions();
        if to == from {
            return Ok(ResizePlan::no_change(from));
        }
        self.stream.set_shards(to);
        Ok(repartition_plan(from, to))
    }

    fn broker(&self) -> Option<Arc<dyn Broker>> {
        Some(self.stream.clone() as Arc<dyn Broker>)
    }

    fn shutdown(&self) {}

    fn completed(&self) -> u64 {
        0
    }
}

/// Kafka broker pilot backend.  `shared_fs` couples the broker's log to
/// the same Lustre resource the Dask pool syncs models through (HPC
/// co-deployment, the paper's configuration).
pub struct KafkaBrokerBackend {
    topic: Arc<KafkaTopic>,
}

impl KafkaBrokerBackend {
    pub fn provision(
        desc: &PilotDescription,
        clock: SharedClock,
        shared_fs: Arc<SharedResource>,
    ) -> Result<Self, PilotError> {
        Ok(Self {
            topic: Arc::new(KafkaTopic::new(
                "pilot-topic",
                desc.parallelism,
                KafkaConfig::default(),
                clock,
                shared_fs,
            )),
        })
    }

    pub fn topic(&self) -> Arc<KafkaTopic> {
        Arc::clone(&self.topic)
    }
}

impl PilotBackend for KafkaBrokerBackend {
    fn platform(&self) -> Platform {
        Platform::KAFKA
    }

    fn submit(&self, cu: ComputeUnit, _spec: TaskSpec) -> Result<(), PilotError> {
        cu.fail("broker pilots do not execute compute units".into());
        Err(PilotError::NoCompute("kafka"))
    }

    fn parallelism(&self) -> usize {
        self.topic.num_partitions()
    }

    /// Broker resize: live repartition, paying the per-partition cost.
    fn resize(&self, to: usize) -> Result<ResizePlan, PilotError> {
        let from = self.topic.num_partitions();
        if to == from {
            return Ok(ResizePlan::no_change(from));
        }
        self.topic.set_partitions(to);
        Ok(repartition_plan(from, to))
    }

    fn broker(&self) -> Option<Arc<dyn Broker>> {
        Some(self.topic.clone() as Arc<dyn Broker>)
    }

    fn shutdown(&self) {}

    fn completed(&self) -> u64 {
        0
    }
}

/// The Kinesis broker plugin: pure broker, no compute units.
pub struct KinesisPlugin;

impl PlatformPlugin for KinesisPlugin {
    fn platform(&self) -> Platform {
        Platform::KINESIS
    }

    fn provisions_broker(&self) -> bool {
        true
    }

    fn accepts_compute(&self) -> bool {
        false
    }

    /// Resharding cost is symmetric: splits and merges both proceed
    /// shard-by-shard.
    fn elasticity(&self) -> Elasticity {
        Elasticity::elastic(REPARTITION_S_PER_SHARD, REPARTITION_S_PER_SHARD)
            .with_price(broker_price(KINESIS_SHARD_HOUR_DOLLARS, "shard-hour"))
    }

    fn provision(
        &self,
        description: &PilotDescription,
        ctx: &ProvisionContext,
    ) -> Result<Arc<dyn PilotBackend>, PilotError> {
        Ok(Arc::new(KinesisBrokerBackend::provision(
            description,
            Arc::clone(&ctx.clock),
        )?))
    }
}

/// The Kafka broker plugin: pure broker whose log rides the service's
/// shared filesystem (HPC co-deployment).
pub struct KafkaPlugin;

impl PlatformPlugin for KafkaPlugin {
    fn platform(&self) -> Platform {
        Platform::KAFKA
    }

    fn provisions_broker(&self) -> bool {
        true
    }

    fn accepts_compute(&self) -> bool {
        false
    }

    /// Partition adds/rebuilds proceed partition-by-partition.
    fn elasticity(&self) -> Elasticity {
        Elasticity::elastic(REPARTITION_S_PER_SHARD, REPARTITION_S_PER_SHARD)
            .with_price(broker_price(KAFKA_PARTITION_HOUR_DOLLARS, "partition-hour"))
    }

    fn provision(
        &self,
        description: &PilotDescription,
        ctx: &ProvisionContext,
    ) -> Result<Arc<dyn PilotBackend>, PilotError> {
        Ok(Arc::new(KafkaBrokerBackend::provision(
            description,
            Arc::clone(&ctx.clock),
            Arc::clone(&ctx.shared_fs),
        )?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::Message;
    use crate::sim::{ContentionParams, WallClock};

    #[test]
    fn kinesis_pilot_provisions_shards() {
        let desc = PilotDescription::new(Platform::KINESIS).with_parallelism(8);
        let b = KinesisBrokerBackend::provision(&desc, Arc::new(WallClock::new())).unwrap();
        let broker = b.broker().unwrap();
        assert_eq!(broker.num_partitions(), 8);
        assert_eq!(broker.kind(), "kinesis");
        broker
            .put(Message::new(1, 0, vec![0.0; 16].into(), 8, 0.0))
            .unwrap();
    }

    #[test]
    fn kafka_pilot_provisions_partitions() {
        let desc = PilotDescription::new(Platform::KAFKA).with_parallelism(4);
        let fs = SharedResource::new("fs", ContentionParams::ISOLATED);
        let b =
            KafkaBrokerBackend::provision(&desc, Arc::new(WallClock::new()), fs).unwrap();
        assert_eq!(b.broker().unwrap().num_partitions(), 4);
    }

    #[test]
    fn broker_resize_is_a_live_repartition() {
        let desc = PilotDescription::new(Platform::KINESIS).with_parallelism(2);
        let b = KinesisBrokerBackend::provision(&desc, Arc::new(WallClock::new())).unwrap();
        let plan = b.resize(6).unwrap();
        assert_eq!(plan.semantics, ResizeSemantics::Repartition);
        assert!((plan.transition_s - 4.0 * REPARTITION_S_PER_SHARD).abs() < 1e-9);
        assert_eq!(b.broker().unwrap().num_partitions(), 6);
        let plan = b.resize(2).unwrap();
        assert_eq!(b.parallelism(), 2);
        assert!((plan.transition_s - 4.0 * REPARTITION_S_PER_SHARD).abs() < 1e-9);

        let fs = SharedResource::new("fs", ContentionParams::ISOLATED);
        let desc = PilotDescription::new(Platform::KAFKA).with_parallelism(4);
        let k = KafkaBrokerBackend::provision(&desc, Arc::new(WallClock::new()), fs).unwrap();
        let plan = k.resize(8).unwrap();
        assert_eq!(plan.semantics, ResizeSemantics::Repartition);
        assert_eq!(k.broker().unwrap().num_partitions(), 8);
    }

    #[test]
    fn broker_pilots_reject_compute() {
        let desc = PilotDescription::new(Platform::KINESIS);
        let b = KinesisBrokerBackend::provision(&desc, Arc::new(WallClock::new())).unwrap();
        let cu = ComputeUnit::new();
        cu.transition(crate::pilot::state::CuState::Queued);
        // queued CUs fail cleanly rather than hanging
        assert!(b.submit(cu.clone(), TaskSpec::Sleep(0.0)).is_err());
        assert_eq!(cu.state(), crate::pilot::state::CuState::Failed);
    }
}
