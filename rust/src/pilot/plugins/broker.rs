//! Broker plugins: provision a Kinesis-like stream ("Kinesis Pilot", paper
//! Fig 2 step 1a/b) or a Kafka-like topic.  Broker pilots do not accept
//! compute-units — they expose the provisioned [`Broker`] instead.

use crate::broker::kafka::{KafkaConfig, KafkaTopic};
use crate::broker::kinesis::{KinesisStream, ShardLimits};
use crate::broker::Broker;
use crate::pilot::compute_unit::{ComputeUnit, TaskSpec};
use crate::pilot::description::{PilotDescription, Platform};
use crate::pilot::job::{PilotBackend, PilotError};
use crate::pilot::registry::{PlatformPlugin, ProvisionContext};
use crate::sim::{SharedClock, SharedResource};
use std::sync::Arc;

/// Kinesis broker pilot backend.
pub struct KinesisBrokerBackend {
    stream: Arc<KinesisStream>,
}

impl KinesisBrokerBackend {
    pub fn provision(desc: &PilotDescription, clock: SharedClock) -> Result<Self, PilotError> {
        Ok(Self {
            stream: Arc::new(KinesisStream::new(
                "pilot-stream",
                desc.parallelism,
                ShardLimits::default(),
                clock,
            )),
        })
    }

    pub fn stream(&self) -> Arc<KinesisStream> {
        Arc::clone(&self.stream)
    }
}

impl PilotBackend for KinesisBrokerBackend {
    fn platform(&self) -> Platform {
        Platform::KINESIS
    }

    fn submit(&self, cu: ComputeUnit, _spec: TaskSpec) -> Result<(), PilotError> {
        cu.fail("broker pilots do not execute compute units".into());
        Err(PilotError::NoCompute("kinesis"))
    }

    fn broker(&self) -> Option<Arc<dyn Broker>> {
        Some(self.stream.clone() as Arc<dyn Broker>)
    }

    fn shutdown(&self) {}

    fn completed(&self) -> u64 {
        0
    }
}

/// Kafka broker pilot backend.  `shared_fs` couples the broker's log to
/// the same Lustre resource the Dask pool syncs models through (HPC
/// co-deployment, the paper's configuration).
pub struct KafkaBrokerBackend {
    topic: Arc<KafkaTopic>,
}

impl KafkaBrokerBackend {
    pub fn provision(
        desc: &PilotDescription,
        clock: SharedClock,
        shared_fs: Arc<SharedResource>,
    ) -> Result<Self, PilotError> {
        Ok(Self {
            topic: Arc::new(KafkaTopic::new(
                "pilot-topic",
                desc.parallelism,
                KafkaConfig::default(),
                clock,
                shared_fs,
            )),
        })
    }

    pub fn topic(&self) -> Arc<KafkaTopic> {
        Arc::clone(&self.topic)
    }
}

impl PilotBackend for KafkaBrokerBackend {
    fn platform(&self) -> Platform {
        Platform::KAFKA
    }

    fn submit(&self, cu: ComputeUnit, _spec: TaskSpec) -> Result<(), PilotError> {
        cu.fail("broker pilots do not execute compute units".into());
        Err(PilotError::NoCompute("kafka"))
    }

    fn broker(&self) -> Option<Arc<dyn Broker>> {
        Some(self.topic.clone() as Arc<dyn Broker>)
    }

    fn shutdown(&self) {}

    fn completed(&self) -> u64 {
        0
    }
}

/// The Kinesis broker plugin: pure broker, no compute units.
pub struct KinesisPlugin;

impl PlatformPlugin for KinesisPlugin {
    fn platform(&self) -> Platform {
        Platform::KINESIS
    }

    fn provisions_broker(&self) -> bool {
        true
    }

    fn accepts_compute(&self) -> bool {
        false
    }

    fn provision(
        &self,
        description: &PilotDescription,
        ctx: &ProvisionContext,
    ) -> Result<Arc<dyn PilotBackend>, PilotError> {
        Ok(Arc::new(KinesisBrokerBackend::provision(
            description,
            Arc::clone(&ctx.clock),
        )?))
    }
}

/// The Kafka broker plugin: pure broker whose log rides the service's
/// shared filesystem (HPC co-deployment).
pub struct KafkaPlugin;

impl PlatformPlugin for KafkaPlugin {
    fn platform(&self) -> Platform {
        Platform::KAFKA
    }

    fn provisions_broker(&self) -> bool {
        true
    }

    fn accepts_compute(&self) -> bool {
        false
    }

    fn provision(
        &self,
        description: &PilotDescription,
        ctx: &ProvisionContext,
    ) -> Result<Arc<dyn PilotBackend>, PilotError> {
        Ok(Arc::new(KafkaBrokerBackend::provision(
            description,
            Arc::clone(&ctx.clock),
            Arc::clone(&ctx.shared_fs),
        )?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::Message;
    use crate::sim::{ContentionParams, WallClock};

    #[test]
    fn kinesis_pilot_provisions_shards() {
        let desc = PilotDescription::new(Platform::KINESIS).with_parallelism(8);
        let b = KinesisBrokerBackend::provision(&desc, Arc::new(WallClock::new())).unwrap();
        let broker = b.broker().unwrap();
        assert_eq!(broker.num_partitions(), 8);
        assert_eq!(broker.kind(), "kinesis");
        broker
            .put(Message::new(1, 0, Arc::new(vec![0.0; 16]), 8, 0.0))
            .unwrap();
    }

    #[test]
    fn kafka_pilot_provisions_partitions() {
        let desc = PilotDescription::new(Platform::KAFKA).with_parallelism(4);
        let fs = SharedResource::new("fs", ContentionParams::ISOLATED);
        let b =
            KafkaBrokerBackend::provision(&desc, Arc::new(WallClock::new()), fs).unwrap();
        assert_eq!(b.broker().unwrap().num_partitions(), 4);
    }

    #[test]
    fn broker_pilots_reject_compute() {
        let desc = PilotDescription::new(Platform::KINESIS);
        let b = KinesisBrokerBackend::provision(&desc, Arc::new(WallClock::new())).unwrap();
        let cu = ComputeUnit::new();
        cu.transition(crate::pilot::state::CuState::Queued);
        // queued CUs fail cleanly rather than hanging
        assert!(b.submit(cu.clone(), TaskSpec::Sleep(0.0)).is_err());
        assert_eq!(cu.state(), crate::pilot::state::CuState::Failed);
    }
}
