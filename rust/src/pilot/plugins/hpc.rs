//! HPC plugin: allocates nodes through the Slurm-like [`Cluster`], stands
//! up a [`DaskPool`] whose model sync rides the shared Lustre filesystem,
//! and executes compute-units as Dask tasks.

use crate::engine::StepEngine;
use crate::hpc::{Cluster, DaskPool};
use crate::pilot::compute_unit::{ComputeUnit, CuOutcome, TaskSpec};
use crate::pilot::description::{DescriptionError, PilotDescription, Platform};
use crate::pilot::job::{PilotBackend, PilotError, ResizePlan, ResizeSemantics};
use crate::pilot::processor::{ProcessCost, StreamProcessor};
use crate::pilot::registry::{Elasticity, PlatformPlugin, PriceModel, ProvisionContext};
use crate::pilot::workers::{LazyWorkerPool, TaskExecutor};
use crate::sim::{ContentionParams, SharedResource};
use crate::store::shared_fs::{SharedFsParams, SharedFsStore};
use std::sync::{Arc, Mutex};

/// Default Lustre contention coefficients.
///
/// All P workers read/write the *same* model file, so Lustre's distributed
/// lock manager serializes conflicting extent locks — writes are close to
/// fully serialized (alpha ≈ 1), and lock revocation traffic grows with
/// every reader pair (beta).  Chosen so the end-to-end USL fit on the Dask
/// side lands in the paper's observed range (σ ∈ [0.6, 1], κ > 0) — see
/// EXPERIMENTS.md Fig 6 and `tests/usl_repro.rs`.
pub const DEFAULT_LUSTRE_ALPHA: f64 = 0.9;
pub const DEFAULT_LUSTRE_BETA: f64 = 0.05;

/// Seconds for a Dask worker process to register with the scheduler once
/// its node is up (workers spawn in parallel, so scale-up within the
/// current allocation pays this once).
pub const WORKER_SPAWN_S: f64 = 2.0;
/// Seconds to drain a retiring worker's in-flight task on scale-down.
pub const WORKER_DRAIN_S: f64 = 5.0;

/// Dollars per node-hour, an XSEDE-era service-unit conversion for a
/// Wrangler-class node (the paper's testbed machine).
pub const NODE_HOUR_DOLLARS: f64 = 1.20;
/// Allocations bill in whole minutes: growing the worker pool charges at
/// least one minute of worker time per added worker.
pub const ALLOCATION_BILLING_QUANTUM_S: f64 = 60.0;

/// The HPC price model: one unit of parallelism is one Dask worker, 12
/// of which share a Wrangler node ([`crate::hpc::Machine::wrangler`]), so
/// a worker-hour costs `NODE_HOUR_DOLLARS / 12`; each added worker pays
/// the allocation's one-minute billing quantum up front.
pub(crate) fn hpc_price() -> PriceModel {
    let worker_hour = NODE_HOUR_DOLLARS / crate::hpc::Machine::wrangler(1).workers_per_node as f64;
    PriceModel::per_unit_hour(worker_hour, "node-hour")
        .with_transition(worker_hour * ALLOCATION_BILLING_QUANTUM_S / 3600.0)
}

struct DaskExecutor {
    pool: Arc<DaskPool>,
}

impl TaskExecutor for DaskExecutor {
    fn execute(&self, worker: usize, spec: TaskSpec) -> Result<CuOutcome, String> {
        match spec {
            TaskSpec::KMeansStep {
                points,
                dim,
                model_key,
                centroids,
            } => {
                let report = self
                    .pool
                    .process(worker % self.pool.workers(), &points, dim, &model_key, centroids)
                    .map_err(|e| e.to_string())?;
                Ok(CuOutcome {
                    value: report.inertia,
                    compute_seconds: report.compute,
                    io_seconds: report.io_get + report.io_put,
                    overhead_seconds: report.sync,
                    executor: format!("dask-{}", report.worker),
                })
            }
            TaskSpec::Sleep(s) => Ok(CuOutcome {
                value: s,
                compute_seconds: s,
                io_seconds: 0.0,
                overhead_seconds: 0.0,
                executor: "dask".into(),
            }),
            TaskSpec::Custom(_) => Err("HPC backend runs staged tasks, not closures".into()),
        }
    }
}

/// Streams messages through the Dask pool, partition-addressed (worker i
/// owns partition i — the co-deployment the paper measures).
struct DaskProcessor {
    pool: Arc<DaskPool>,
}

impl StreamProcessor for DaskProcessor {
    fn label(&self) -> &'static str {
        "dask"
    }

    fn process(
        &self,
        partition: usize,
        points: &[f32],
        dim: usize,
        model_key: &str,
        centroids: usize,
    ) -> Result<ProcessCost, String> {
        let r = self
            .pool
            .process(
                partition % self.pool.workers(),
                points,
                dim,
                model_key,
                centroids,
            )
            .map_err(|e| e.to_string())?;
        Ok(ProcessCost {
            compute: r.compute,
            io: r.io_get + r.io_put,
            overhead: r.sync,
        })
    }
}

/// The HPC processing backend.
pub struct HpcBackend {
    dask: Arc<DaskPool>,
    cluster: Arc<Cluster>,
    allocation_id: Mutex<u64>,
    pool: LazyWorkerPool,
}

impl HpcBackend {
    pub fn provision(
        desc: &PilotDescription,
        engine: Arc<dyn StepEngine>,
        shared_fs: Option<Arc<SharedResource>>,
    ) -> Result<Self, PilotError> {
        let machine = desc.machine.machine(desc.max_nodes);
        let cluster = Arc::new(Cluster::new(machine.clone(), desc.seed));
        let nodes = machine.nodes_for(desc.parallelism);
        let allocation = cluster
            .allocate(nodes)
            .map_err(|e| PilotError::Provision(e.to_string()))?;
        log::info!(
            "hpc pilot: {} nodes on {} (queue {:.0}s, startup {:.0}s)",
            allocation.nodes,
            machine.node.name,
            allocation.queue_wait,
            allocation.startup
        );
        let fs = shared_fs.unwrap_or_else(|| {
            SharedResource::new(
                "lustre",
                ContentionParams::new(DEFAULT_LUSTRE_ALPHA, DEFAULT_LUSTRE_BETA),
            )
        });
        let store = Arc::new(SharedFsStore::new(SharedFsParams::default(), fs));
        let dask = Arc::new(DaskPool::new(
            machine,
            desc.parallelism,
            engine,
            store,
            desc.seed,
        ));
        let pool = LazyWorkerPool::new(
            desc.parallelism,
            Arc::new(DaskExecutor {
                pool: Arc::clone(&dask),
            }),
        );
        Ok(Self {
            dask,
            cluster,
            allocation_id: Mutex::new(allocation.id),
            pool,
        })
    }

    pub fn dask(&self) -> Arc<DaskPool> {
        Arc::clone(&self.dask)
    }
}

impl PilotBackend for HpcBackend {
    fn platform(&self) -> Platform {
        Platform::DASK
    }

    fn submit(&self, cu: ComputeUnit, spec: TaskSpec) -> Result<(), PilotError> {
        self.pool.submit(cu, spec).map_err(PilotError::Provision)
    }

    fn parallelism(&self) -> usize {
        self.dask.workers()
    }

    /// HPC resize: workers within the current node allocation spawn after
    /// a flat scheduler-registration delay; growing past it means a new
    /// batch allocation — queue wait plus node boot, sampled from the
    /// cluster's seeded model.  Scale-down drains the retiring workers'
    /// in-flight tasks.  Targets beyond the machine are *clamped* at its
    /// capacity (the same cap-push-back contract as the edge plugin), so
    /// the control loop learns the envelope instead of aborting.
    fn resize(&self, to: usize) -> Result<ResizePlan, PilotError> {
        let from = self.dask.workers();
        let machine = self.dask.machine();
        let cap = machine.max_workers();
        let target = to.min(cap);
        if target == from {
            return Ok(ResizePlan {
                from,
                to: from,
                transition_s: 0.0,
                semantics: if to > cap {
                    ResizeSemantics::Throttle
                } else {
                    ResizeSemantics::NoChange
                },
            });
        }
        let clamped = to > cap;
        let to = target;
        let cur_nodes = machine.nodes_for(from);
        let new_nodes = machine.nodes_for(to);
        let mut transition_s = if to > from { WORKER_SPAWN_S } else { WORKER_DRAIN_S };
        if new_nodes != cur_nodes {
            // the batch scheduler has no "grow allocation" verb: release
            // and re-request (a shrink re-request never queues long in
            // practice, so only charge the queue on growth)
            let mut id = self.allocation_id.lock().unwrap();
            self.cluster
                .release(*id)
                .map_err(|e| PilotError::Provision(e.to_string()))?;
            let alloc = match self.cluster.allocate(new_nodes) {
                Ok(a) => a,
                Err(e) => {
                    // roll the old allocation back so the pilot keeps its
                    // nodes rather than ending up resource-less
                    let rollback = self
                        .cluster
                        .allocate(cur_nodes)
                        .map_err(|e2| PilotError::Provision(e2.to_string()))?;
                    *id = rollback.id;
                    return Err(PilotError::Provision(e.to_string()));
                }
            };
            *id = alloc.id;
            if to > from {
                transition_s += alloc.queue_wait + alloc.startup;
            }
        }
        self.dask.set_workers(to);
        self.pool.resize(to);
        Ok(ResizePlan {
            from,
            to,
            transition_s,
            semantics: if clamped {
                ResizeSemantics::Throttle
            } else {
                ResizeSemantics::WorkerStartup
            },
        })
    }

    fn processor(&self) -> Option<Arc<dyn StreamProcessor>> {
        Some(Arc::new(DaskProcessor {
            pool: Arc::clone(&self.dask),
        }))
    }

    fn shutdown(&self) {
        self.pool.shutdown();
        let _ = self.cluster.release(*self.allocation_id.lock().unwrap());
    }

    fn completed(&self) -> u64 {
        self.pool.completed()
    }
}

/// The Dask/HPC platform plugin: owns the "dask" name, the machine-capacity
/// constraint, and HPC provisioning on the service's shared filesystem.
pub struct HpcPlugin;

impl PlatformPlugin for HpcPlugin {
    fn platform(&self) -> Platform {
        Platform::DASK
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["hpc"]
    }

    /// HPC elasticity: new workers pay scheduler registration (plus batch
    /// queue + node boot when the allocation grows); retiring workers
    /// drain their in-flight task first.
    fn elasticity(&self) -> Elasticity {
        Elasticity::elastic(WORKER_SPAWN_S, WORKER_DRAIN_S).with_price(hpc_price())
    }

    fn validate(&self, d: &PilotDescription) -> Result<(), DescriptionError> {
        let machine = d.machine.machine(d.max_nodes);
        if d.parallelism > machine.max_workers() {
            return Err(DescriptionError::invalid(
                "parallelism",
                format!(
                    "{} workers exceed {} ({} nodes x {}/node)",
                    d.parallelism,
                    machine.max_workers(),
                    d.max_nodes,
                    machine.workers_per_node
                ),
            ));
        }
        Ok(())
    }

    fn provision(
        &self,
        description: &PilotDescription,
        ctx: &ProvisionContext,
    ) -> Result<Arc<dyn PilotBackend>, PilotError> {
        Ok(Arc::new(HpcBackend::provision(
            description,
            Arc::clone(&ctx.engine),
            Some(Arc::clone(&ctx.shared_fs)),
        )?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::pilot::description::MachineKind;
    use crate::pilot::state::CuState;

    #[test]
    fn provision_and_run_task() {
        let desc = PilotDescription::new(Platform::DASK)
            .with_parallelism(4)
            .with_machine(MachineKind::Wrangler);
        let backend =
            HpcBackend::provision(&desc, Arc::new(CalibratedEngine::new(1)), None).unwrap();
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        backend
            .submit(
                cu.clone(),
                TaskSpec::KMeansStep {
                    points: Arc::new(vec![0.2; 160]),
                    dim: 8,
                    model_key: "m".into(),
                    centroids: 8,
                },
            )
            .unwrap();
        assert_eq!(cu.wait(), CuState::Done);
        let o = cu.outcome().unwrap();
        assert!(o.io_seconds > 0.0);
        assert!(o.overhead_seconds > 0.0, "coherency sync cost");
        assert!(o.executor.starts_with("dask-"));
    }

    #[test]
    fn resize_scales_workers_and_reallocates_nodes() {
        let desc = PilotDescription::new(Platform::DASK)
            .with_parallelism(2)
            .with_machine(MachineKind::Wrangler)
            .with_max_nodes(4);
        let backend =
            HpcBackend::provision(&desc, Arc::new(CalibratedEngine::new(1)), None).unwrap();
        assert_eq!(backend.parallelism(), 2);
        assert_eq!(backend.cluster.allocated_nodes(), 1);

        // grow within the node: flat worker-spawn delay, no new allocation
        let plan = backend.resize(8).unwrap();
        assert_eq!((plan.from, plan.to), (2, 8));
        assert_eq!(plan.semantics, ResizeSemantics::WorkerStartup);
        assert!((plan.transition_s - WORKER_SPAWN_S).abs() < 1e-9);
        assert_eq!(backend.cluster.allocated_nodes(), 1);

        // grow past the node: batch queue + boot dominate the transition
        let plan = backend.resize(16).unwrap();
        assert_eq!(backend.parallelism(), 16);
        assert_eq!(backend.cluster.allocated_nodes(), 2);
        assert!(
            plan.transition_s > WORKER_SPAWN_S,
            "new allocation must pay queue+boot, got {}",
            plan.transition_s
        );

        // shrink: drain cost, nodes released back
        let plan = backend.resize(4).unwrap();
        assert!((plan.transition_s - WORKER_DRAIN_S).abs() < 1e-9);
        assert_eq!(backend.cluster.allocated_nodes(), 1);

        // targets beyond the machine clamp at its capacity and signal
        // throttling — the loop learns the envelope instead of aborting
        let plan = backend.resize(4 * 12 + 1).unwrap();
        assert_eq!(plan.to, 48);
        assert_eq!(plan.semantics, ResizeSemantics::Throttle);
        assert_eq!(backend.cluster.allocated_nodes(), 4);
        // and once pinned at the cap, over-asks are throttling no-ops
        let plan = backend.resize(4 * 12 + 1).unwrap();
        assert!(!plan.is_change());
        assert_eq!(plan.semantics, ResizeSemantics::Throttle);
        backend.shutdown();
        assert_eq!(backend.cluster.allocated_nodes(), 0);
    }

    #[test]
    fn releases_allocation_on_shutdown() {
        let desc = PilotDescription::new(Platform::DASK).with_parallelism(2);
        let backend =
            HpcBackend::provision(&desc, Arc::new(CalibratedEngine::new(1)), None).unwrap();
        let nodes_before = backend.cluster.allocated_nodes();
        assert!(nodes_before > 0);
        backend.shutdown();
        assert_eq!(backend.cluster.allocated_nodes(), 0);
    }
}
