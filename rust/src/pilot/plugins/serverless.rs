//! Serverless plugin: provisions a [`LambdaFleet`] ("Function Pilot",
//! paper Fig 2 step 2a/b) and executes compute-units as function
//! invocations against the S3-like model store.
//!
//! The edge plugin runs the same [`LambdaFleet`] substrate — one per
//! fleet site plus a cloud spillover fleet — behind its own placement
//! router (see `pilot::plugins::edge`).

use crate::engine::StepEngine;
use crate::pilot::compute_unit::{ComputeUnit, CuOutcome, TaskSpec};
use crate::pilot::description::{DescriptionError, PilotDescription, Platform};
use crate::pilot::job::{PilotBackend, PilotError, ResizePlan, ResizeSemantics};
use crate::pilot::processor::{ProcessCost, StreamProcessor};
use crate::pilot::registry::{Elasticity, PlatformPlugin, PriceModel, ProvisionContext};
use crate::pilot::workers::{LazyWorkerPool, TaskExecutor};
use crate::serverless::{FunctionConfig, LambdaFleet};
use crate::sim::SharedClock;
use crate::store::ObjectStore;
use std::sync::Arc;

/// AWS Lambda's 2019 list price per GB-second (us-east-1), the billing
/// constant behind the paper-era serverless cost analyses in PAPERS.md.
pub const LAMBDA_GB_SECOND_DOLLARS: f64 = 0.000_016_666_7;

/// The serverless price model, derived from the same [`FunctionConfig`]
/// the cold-start transition time uses: one unit of parallelism is one
/// warm container billed `memory_gb * 3600` GB-s per hour, and each
/// scale-up pays the billed cold-start init at that memory size.
pub(crate) fn serverless_price() -> PriceModel {
    let cfg = FunctionConfig::default();
    let gb = cfg.memory_mb as f64 / 1024.0;
    PriceModel::per_unit_hour(gb * 3600.0 * LAMBDA_GB_SECOND_DOLLARS, "GB-s")
        .with_transition(cfg.cold_start_dist().mean() * gb * LAMBDA_GB_SECOND_DOLLARS)
}

/// Runs compute-units as fleet invocations (serverless and edge pilots).
pub(crate) struct FleetExecutor {
    pub(crate) fleet: Arc<LambdaFleet>,
    pub(crate) label: &'static str,
}

impl TaskExecutor for FleetExecutor {
    fn execute(&self, _worker: usize, spec: TaskSpec) -> Result<CuOutcome, String> {
        match spec {
            TaskSpec::KMeansStep {
                points,
                dim,
                model_key,
                centroids,
            } => {
                let report = self
                    .fleet
                    .invoke(&points, dim, &model_key, centroids)
                    .map_err(|e| e.to_string())?;
                Ok(CuOutcome {
                    value: report.inertia,
                    compute_seconds: report.compute,
                    io_seconds: report.io_get + report.io_put,
                    overhead_seconds: report.cold_start + report.queue_wait,
                    executor: format!("{}-{}", self.label, report.container_id),
                })
            }
            TaskSpec::Sleep(s) => Ok(CuOutcome {
                value: s,
                compute_seconds: s,
                io_seconds: 0.0,
                overhead_seconds: 0.0,
                executor: self.label.into(),
            }),
            TaskSpec::Custom(_) => {
                Err("serverless backend runs packaged functions, not closures".into())
            }
        }
    }
}

/// Streams messages through a fleet (serverless and edge pilots).
pub(crate) struct FleetProcessor {
    pub(crate) fleet: Arc<LambdaFleet>,
    pub(crate) label: &'static str,
}

impl StreamProcessor for FleetProcessor {
    fn label(&self) -> &'static str {
        self.label
    }

    fn process(
        &self,
        _partition: usize,
        points: &[f32],
        dim: usize,
        model_key: &str,
        centroids: usize,
    ) -> Result<ProcessCost, String> {
        let r = self
            .fleet
            .invoke(points, dim, model_key, centroids)
            .map_err(|e| e.to_string())?;
        Ok(ProcessCost {
            compute: r.compute,
            io: r.io_get + r.io_put,
            overhead: r.cold_start + r.queue_wait,
        })
    }
}

/// The serverless processing backend.
pub struct ServerlessBackend {
    fleet: Arc<LambdaFleet>,
    pool: LazyWorkerPool,
}

impl ServerlessBackend {
    pub fn provision(
        desc: &PilotDescription,
        engine: Arc<dyn StepEngine>,
        clock: SharedClock,
    ) -> Result<Self, PilotError> {
        let config = FunctionConfig {
            memory_mb: desc.memory_mb,
            timeout_s: desc.walltime_s,
            package_mb: desc.package_mb,
            max_concurrency: desc.parallelism,
            ..Default::default()
        };
        let fleet = Arc::new(
            LambdaFleet::new(
                config,
                engine,
                Arc::new(ObjectStore::default()),
                clock,
                desc.seed,
            )
            .map_err(PilotError::Provision)?,
        );
        // dispatch parallelism mirrors the concurrency cap
        let pool = LazyWorkerPool::new(
            desc.parallelism,
            Arc::new(FleetExecutor {
                fleet: Arc::clone(&fleet),
                label: "lambda",
            }),
        );
        Ok(Self { fleet, pool })
    }

    pub fn fleet(&self) -> Arc<LambdaFleet> {
        Arc::clone(&self.fleet)
    }
}

impl PilotBackend for ServerlessBackend {
    fn platform(&self) -> Platform {
        Platform::LAMBDA
    }

    fn submit(&self, cu: ComputeUnit, spec: TaskSpec) -> Result<(), PilotError> {
        self.pool.submit(cu, spec).map_err(PilotError::Provision)
    }

    fn parallelism(&self) -> usize {
        self.fleet.concurrency()
    }

    /// Serverless resize: scale-up raises the concurrency cap — the new
    /// containers cold-start in-band on their first invocation, so the
    /// transition window is one (mean) cold start; scale-down is instant
    /// (idle sandboxes beyond the cap are torn down immediately).
    fn resize(&self, to: usize) -> Result<ResizePlan, PilotError> {
        let from = self.fleet.concurrency();
        if to == from {
            return Ok(ResizePlan::no_change(from));
        }
        self.fleet.set_concurrency(to);
        self.pool.resize(to);
        let transition_s = if to > from {
            // containers boot in parallel: one cold-start window, not one
            // per container
            self.fleet.config().cold_start_dist().mean()
        } else {
            0.0
        };
        Ok(ResizePlan {
            from,
            to,
            transition_s,
            semantics: ResizeSemantics::ColdStart,
        })
    }

    fn processor(&self) -> Option<Arc<dyn StreamProcessor>> {
        Some(Arc::new(FleetProcessor {
            fleet: Arc::clone(&self.fleet),
            label: "lambda",
        }))
    }

    fn shutdown(&self) {
        self.pool.shutdown();
    }

    fn completed(&self) -> u64 {
        self.pool.completed()
    }
}

/// The Lambda platform plugin: owns the "lambda" name, the Lambda-specific
/// description constraints, and serverless provisioning.
pub struct ServerlessPlugin;

impl PlatformPlugin for ServerlessPlugin {
    fn platform(&self) -> Platform {
        Platform::LAMBDA
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["serverless", "faas"]
    }

    /// Serverless elasticity: scale-up costs one container cold start,
    /// scale-down is instant — the regime that makes FaaS the natural
    /// autoscaling target (arXiv:2603.03089's short-stream argument).
    fn elasticity(&self) -> Elasticity {
        Elasticity::elastic(FunctionConfig::default().cold_start_dist().mean(), 0.0)
            .with_price(serverless_price())
    }

    fn validate(&self, d: &PilotDescription) -> Result<(), DescriptionError> {
        if !(crate::serverless::MIN_MEMORY_MB..=crate::serverless::MAX_MEMORY_MB)
            .contains(&d.memory_mb)
        {
            return Err(DescriptionError::invalid(
                "memory_mb",
                format!(
                    "{} outside Lambda range [{}, {}]",
                    d.memory_mb,
                    crate::serverless::MIN_MEMORY_MB,
                    crate::serverless::MAX_MEMORY_MB
                ),
            ));
        }
        if d.walltime_s > crate::serverless::MAX_WALLTIME_S {
            return Err(DescriptionError::invalid(
                "walltime_s",
                format!("{} exceeds Lambda 15-minute cap", d.walltime_s),
            ));
        }
        Ok(())
    }

    fn provision(
        &self,
        description: &PilotDescription,
        ctx: &ProvisionContext,
    ) -> Result<Arc<dyn PilotBackend>, PilotError> {
        Ok(Arc::new(ServerlessBackend::provision(
            description,
            Arc::clone(&ctx.engine),
            Arc::clone(&ctx.clock),
        )?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::pilot::state::CuState;
    use crate::sim::WallClock;

    #[test]
    fn provision_and_invoke() {
        let desc = PilotDescription::new(Platform::LAMBDA).with_parallelism(2);
        let backend = ServerlessBackend::provision(
            &desc,
            Arc::new(CalibratedEngine::new(1)),
            Arc::new(WallClock::new()),
        )
        .unwrap();
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        backend
            .submit(
                cu.clone(),
                TaskSpec::KMeansStep {
                    points: Arc::new(vec![0.1; 160]),
                    dim: 8,
                    model_key: "m".into(),
                    centroids: 8,
                },
            )
            .unwrap();
        assert_eq!(cu.wait(), CuState::Done);
        let o = cu.outcome().unwrap();
        assert!(o.overhead_seconds > 0.0, "first call pays a cold start");
        assert!(o.executor.starts_with("lambda-"));
        assert_eq!(backend.fleet().invocation_count(), 1);
    }

    #[test]
    fn custom_closures_rejected() {
        let desc = PilotDescription::new(Platform::LAMBDA);
        let backend = ServerlessBackend::provision(
            &desc,
            Arc::new(CalibratedEngine::new(1)),
            Arc::new(WallClock::new()),
        )
        .unwrap();
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        backend
            .submit(cu.clone(), TaskSpec::Custom(Box::new(|| Ok(0.0))))
            .unwrap();
        assert_eq!(cu.wait(), CuState::Failed);
    }

    #[test]
    fn plugin_rejects_invalid_description() {
        let mut desc = PilotDescription::new(Platform::LAMBDA);
        desc.memory_mb = 10;
        let plugin = ServerlessPlugin;
        assert!(plugin.validate(&desc).is_err());
        let ctx = ProvisionContext {
            engine: Arc::new(CalibratedEngine::new(1)),
            clock: Arc::new(WallClock::new()),
            shared_fs: crate::sim::SharedResource::new(
                "fs",
                crate::sim::ContentionParams::ISOLATED,
            ),
        };
        assert!(plugin.provision(&desc, &ctx).is_err());
    }

    #[test]
    fn backend_exposes_a_processor() {
        let desc = PilotDescription::new(Platform::LAMBDA).with_parallelism(2);
        let backend = ServerlessBackend::provision(
            &desc,
            Arc::new(CalibratedEngine::new(1)),
            Arc::new(WallClock::new()),
        )
        .unwrap();
        let p = backend.processor().expect("processing pilot");
        assert_eq!(p.label(), "lambda");
        let pts = vec![0.1; 160];
        let cost = p.process(0, &pts, 8, "m", 8).unwrap();
        assert!(cost.total() > 0.0);
        assert!(cost.overhead > 0.0, "cold start charged to overhead");
    }
}
