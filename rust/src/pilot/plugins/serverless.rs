//! Serverless plugin: provisions a [`LambdaFleet`] ("Function Pilot",
//! paper Fig 2 step 2a/b) and executes compute-units as function
//! invocations against the S3-like model store.

use crate::engine::StepEngine;
use crate::pilot::compute_unit::{ComputeUnit, CuOutcome, TaskSpec};
use crate::pilot::description::{PilotDescription, Platform};
use crate::pilot::job::{PilotBackend, PilotError};
use crate::pilot::workers::{TaskExecutor, WorkerPool};
use crate::serverless::{FunctionConfig, LambdaFleet};
use crate::sim::SharedClock;
use crate::store::ObjectStore;
use std::sync::Arc;

struct LambdaExecutor {
    fleet: Arc<LambdaFleet>,
}

impl TaskExecutor for LambdaExecutor {
    fn execute(&self, _worker: usize, spec: TaskSpec) -> Result<CuOutcome, String> {
        match spec {
            TaskSpec::KMeansStep {
                points,
                dim,
                model_key,
                centroids,
            } => {
                let report = self
                    .fleet
                    .invoke(&points, dim, &model_key, centroids)
                    .map_err(|e| e.to_string())?;
                Ok(CuOutcome {
                    value: report.inertia,
                    compute_seconds: report.compute,
                    io_seconds: report.io_get + report.io_put,
                    overhead_seconds: report.cold_start,
                    executor: format!("lambda-{}", report.container_id),
                })
            }
            TaskSpec::Sleep(s) => Ok(CuOutcome {
                value: s,
                compute_seconds: s,
                io_seconds: 0.0,
                overhead_seconds: 0.0,
                executor: "lambda".into(),
            }),
            TaskSpec::Custom(_) => {
                Err("serverless backend runs packaged functions, not closures".into())
            }
        }
    }
}

/// The serverless processing backend.
pub struct ServerlessBackend {
    fleet: Arc<LambdaFleet>,
    pool: WorkerPool,
}

impl ServerlessBackend {
    pub fn provision(
        desc: &PilotDescription,
        engine: Arc<dyn StepEngine>,
        clock: SharedClock,
    ) -> Result<Self, PilotError> {
        desc.validate()?;
        let config = FunctionConfig {
            memory_mb: desc.memory_mb,
            timeout_s: desc.walltime_s,
            package_mb: desc.package_mb,
            max_concurrency: desc.parallelism,
        };
        let fleet = Arc::new(
            LambdaFleet::new(
                config,
                engine,
                Arc::new(ObjectStore::default()),
                clock,
                desc.seed,
            )
            .map_err(PilotError::Provision)?,
        );
        // dispatch parallelism mirrors the concurrency cap
        let pool = WorkerPool::new(
            desc.parallelism,
            Arc::new(LambdaExecutor {
                fleet: Arc::clone(&fleet),
            }),
        );
        Ok(Self { fleet, pool })
    }

    pub fn fleet(&self) -> Arc<LambdaFleet> {
        Arc::clone(&self.fleet)
    }
}

impl PilotBackend for ServerlessBackend {
    fn platform(&self) -> Platform {
        Platform::Lambda
    }

    fn submit(&self, cu: ComputeUnit, spec: TaskSpec) -> Result<(), PilotError> {
        self.pool.submit(cu, spec).map_err(PilotError::Provision)
    }

    fn shutdown(&self) {
        self.pool.shutdown();
    }

    fn completed(&self) -> u64 {
        self.pool.completed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::pilot::state::CuState;
    use crate::sim::WallClock;

    #[test]
    fn provision_and_invoke() {
        let desc = PilotDescription::new(Platform::Lambda).with_parallelism(2);
        let backend = ServerlessBackend::provision(
            &desc,
            Arc::new(CalibratedEngine::new(1)),
            Arc::new(WallClock::new()),
        )
        .unwrap();
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        backend
            .submit(
                cu.clone(),
                TaskSpec::KMeansStep {
                    points: Arc::new(vec![0.1; 160]),
                    dim: 8,
                    model_key: "m".into(),
                    centroids: 8,
                },
            )
            .unwrap();
        assert_eq!(cu.wait(), CuState::Done);
        let o = cu.outcome().unwrap();
        assert!(o.overhead_seconds > 0.0, "first call pays a cold start");
        assert!(o.executor.starts_with("lambda-"));
        assert_eq!(backend.fleet().invocation_count(), 1);
    }

    #[test]
    fn custom_closures_rejected() {
        let desc = PilotDescription::new(Platform::Lambda);
        let backend = ServerlessBackend::provision(
            &desc,
            Arc::new(CalibratedEngine::new(1)),
            Arc::new(WallClock::new()),
        )
        .unwrap();
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        backend
            .submit(cu.clone(), TaskSpec::Custom(Box::new(|| Ok(0.0))))
            .unwrap();
        assert_eq!(cu.wait(), CuState::Failed);
    }

    #[test]
    fn invalid_description_rejected() {
        let mut desc = PilotDescription::new(Platform::Lambda);
        desc.memory_mb = 10;
        assert!(ServerlessBackend::provision(
            &desc,
            Arc::new(CalibratedEngine::new(1)),
            Arc::new(WallClock::new()),
        )
        .is_err());
    }
}
