//! Edge plugin (paper §V future work): a Greengrass-class [`EdgeSite`]
//! provisioned **purely through the plugin API** — the service and the
//! drivers were not touched to add this platform.
//!
//! One edge pilot is a *co-located* broker + processing pair, because the
//! whole point of the edge is that the broker lives on the same box as the
//! functions: `broker()` returns a site-local Kinesis-like stream with
//! LAN put latency (~2 ms vs ~15 ms WAN), and `processor()` a Lambda-
//! compatible fleet under the device envelope — capped memory, 0.35× CPU,
//! a handful of containers that *queue* (not throttle) when saturated.
//! Throughput therefore saturates at the device's container count: the
//! USL story sweeps and fits pick up as a first-class scenario axis.

use super::serverless::{FleetExecutor, FleetProcessor};
use crate::broker::kinesis::{KinesisStream, ShardLimits};
use crate::broker::Broker;
use crate::pilot::compute_unit::{ComputeUnit, TaskSpec};
use crate::pilot::description::{DescriptionError, PilotDescription, Platform};
use crate::pilot::job::{PilotBackend, PilotError, ResizePlan, ResizeSemantics};
use crate::pilot::processor::StreamProcessor;
use crate::pilot::registry::{Elasticity, PlatformPlugin, ProvisionContext};
use crate::pilot::workers::LazyWorkerPool;
use crate::serverless::edge::{EDGE_MAX_CONCURRENCY, EDGE_MAX_MEMORY_MB};
use crate::serverless::{EdgeSite, FunctionConfig, LambdaFleet};
use crate::store::ObjectStore;
use std::sync::Arc;

/// The provisioned edge pilot: site-local broker + constrained fleet.
pub struct EdgeBackend {
    site: EdgeSite,
    stream: Arc<KinesisStream>,
    fleet: Arc<LambdaFleet>,
    pool: LazyWorkerPool,
}

impl EdgeBackend {
    pub fn provision(desc: &PilotDescription, ctx: &ProvisionContext) -> Result<Self, PilotError> {
        let site = EdgeSite::default();
        // admit() clamps concurrency to the device and rejects over-memory
        let config = site
            .admit(FunctionConfig {
                memory_mb: desc.memory_mb,
                timeout_s: desc.walltime_s,
                package_mb: desc.package_mb,
                max_concurrency: desc.parallelism,
                cpu_efficiency: site.cpu_efficiency,
                queue_when_saturated: true,
            })
            .map_err(PilotError::Provision)?;
        let stream = Arc::new(KinesisStream::new(
            "edge-stream",
            desc.parallelism,
            ShardLimits {
                put_latency: site.broker_latency,
                ..Default::default()
            },
            Arc::clone(&ctx.clock),
        ));
        let fleet = Arc::new(
            LambdaFleet::new(
                config,
                Arc::clone(&ctx.engine),
                Arc::new(ObjectStore::default()),
                Arc::clone(&ctx.clock),
                desc.seed,
            )
            .map_err(PilotError::Provision)?,
        );
        let pool = LazyWorkerPool::new(
            desc.parallelism.min(site.max_concurrency),
            Arc::new(FleetExecutor {
                fleet: Arc::clone(&fleet),
                label: "edge",
            }),
        );
        Ok(Self {
            site,
            stream,
            fleet,
            pool,
        })
    }

    pub fn site(&self) -> &EdgeSite {
        &self.site
    }

    pub fn fleet(&self) -> Arc<LambdaFleet> {
        Arc::clone(&self.fleet)
    }
}

impl PilotBackend for EdgeBackend {
    fn platform(&self) -> Platform {
        Platform::EDGE
    }

    fn submit(&self, cu: ComputeUnit, spec: TaskSpec) -> Result<(), PilotError> {
        self.pool.submit(cu, spec).map_err(PilotError::Provision)
    }

    fn parallelism(&self) -> usize {
        self.fleet.concurrency()
    }

    /// Edge resize: the device envelope is a hard wall.  Targets above
    /// the site's container count are *clamped* — the plan lands at the
    /// cap with [`ResizeSemantics::Throttle`], telling the control loop
    /// the source must slow down rather than the site scale up.
    fn resize(&self, to: usize) -> Result<ResizePlan, PilotError> {
        let cap = self.site.max_concurrency;
        let from = self.fleet.concurrency();
        let target = to.min(cap);
        let semantics = if to > cap {
            ResizeSemantics::Throttle
        } else if target == from {
            ResizeSemantics::NoChange
        } else {
            ResizeSemantics::ColdStart
        };
        if target == from {
            return Ok(ResizePlan {
                from,
                to: from,
                transition_s: 0.0,
                semantics,
            });
        }
        self.fleet.set_concurrency(target);
        self.pool.resize(target);
        let transition_s = if target > from {
            self.fleet.config().cold_start_dist().mean()
        } else {
            0.0
        };
        Ok(ResizePlan {
            from,
            to: target,
            transition_s,
            semantics,
        })
    }

    fn broker(&self) -> Option<Arc<dyn Broker>> {
        Some(Arc::clone(&self.stream) as Arc<dyn Broker>)
    }

    fn processor(&self) -> Option<Arc<dyn StreamProcessor>> {
        Some(Arc::new(FleetProcessor {
            fleet: Arc::clone(&self.fleet),
            label: "edge",
        }))
    }

    fn shutdown(&self) {
        self.pool.shutdown();
    }

    fn completed(&self) -> u64 {
        self.pool.completed()
    }
}

/// The edge platform plugin: owns the "edge" name and the device envelope.
pub struct EdgePlugin;

impl PlatformPlugin for EdgePlugin {
    fn platform(&self) -> Platform {
        Platform::EDGE
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["greengrass"]
    }

    fn provisions_broker(&self) -> bool {
        true
    }

    /// Edge elasticity: containers start locally (one cold start), tear
    /// down instantly — but the device envelope caps parallelism, so
    /// scale-ups past it resolve to throttling the source.
    fn elasticity(&self) -> Elasticity {
        Elasticity::elastic(FunctionConfig::default().cold_start_dist().mean(), 0.0)
            .with_cap(EDGE_MAX_CONCURRENCY)
    }

    /// Clamp container memory into the device envelope, so the cloud
    /// defaults every other platform accepts provision cleanly on the
    /// edge (the device simply deploys at its maximum — the same policy
    /// `EdgeSite::admit` applies to concurrency).
    fn normalize(&self, mut d: PilotDescription) -> PilotDescription {
        d.memory_mb = d.memory_mb.min(EDGE_MAX_MEMORY_MB);
        d
    }

    fn validate(&self, d: &PilotDescription) -> Result<(), DescriptionError> {
        if !(crate::serverless::MIN_MEMORY_MB..=EDGE_MAX_MEMORY_MB).contains(&d.memory_mb) {
            return Err(DescriptionError::invalid(
                "memory_mb",
                format!(
                    "{} outside edge device range [{}, {EDGE_MAX_MEMORY_MB}]",
                    d.memory_mb,
                    crate::serverless::MIN_MEMORY_MB
                ),
            ));
        }
        if d.walltime_s > crate::serverless::MAX_WALLTIME_S {
            return Err(DescriptionError::invalid(
                "walltime_s",
                format!("{} exceeds the 15-minute function cap", d.walltime_s),
            ));
        }
        Ok(())
    }

    fn provision(
        &self,
        description: &PilotDescription,
        ctx: &ProvisionContext,
    ) -> Result<Arc<dyn PilotBackend>, PilotError> {
        Ok(Arc::new(EdgeBackend::provision(description, ctx)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::pilot::state::CuState;
    use crate::serverless::edge::{EDGE_BROKER_LATENCY, EDGE_MAX_CONCURRENCY};
    use crate::sim::{ContentionParams, SharedResource, SimClock, WallClock};

    fn ctx() -> ProvisionContext {
        ProvisionContext {
            engine: Arc::new(CalibratedEngine::new(1)),
            clock: Arc::new(WallClock::new()),
            shared_fs: SharedResource::new("fs", ContentionParams::ISOLATED),
        }
    }

    fn desc() -> PilotDescription {
        PilotDescription::new(Platform::EDGE)
            .with_parallelism(2)
            .with_memory_mb(1024)
    }

    #[test]
    fn provisions_colocated_broker_and_fleet() {
        let b = EdgeBackend::provision(&desc(), &ctx()).unwrap();
        let broker = b.broker().expect("site-local broker");
        assert_eq!(broker.num_partitions(), 2);
        let p = b.processor().expect("edge fleet");
        assert_eq!(p.label(), "edge");
        assert!(b.site().cpu_efficiency < 1.0);
    }

    #[test]
    fn local_broker_has_lan_latency() {
        let clock = Arc::new(SimClock::new());
        let ctx = ProvisionContext {
            engine: Arc::new(CalibratedEngine::new(1)),
            clock: clock.clone(),
            shared_fs: SharedResource::new("fs", ContentionParams::ISOLATED),
        };
        let b = EdgeBackend::provision(&desc(), &ctx).unwrap();
        let r = b
            .broker()
            .unwrap()
            .put(crate::broker::Message::new(
                1,
                0,
                Arc::new(vec![0.0; 16]),
                8,
                0.0,
            ))
            .unwrap();
        assert!(
            (r.broker_latency - EDGE_BROKER_LATENCY).abs() < 1e-9,
            "LAN hop, not WAN: {}",
            r.broker_latency
        );
    }

    #[test]
    fn compute_units_run_on_the_edge_fleet() {
        let b = EdgeBackend::provision(&desc(), &ctx()).unwrap();
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        b.submit(
            cu.clone(),
            TaskSpec::KMeansStep {
                points: Arc::new(vec![0.1; 160]),
                dim: 8,
                model_key: "m".into(),
                centroids: 8,
            },
        )
        .unwrap();
        assert_eq!(cu.wait(), CuState::Done);
        assert!(cu.outcome().unwrap().executor.starts_with("edge-"));
        assert_eq!(b.fleet().invocation_count(), 1);
    }

    #[test]
    fn resize_clamps_at_the_device_cap() {
        let b = EdgeBackend::provision(&desc(), &ctx()).unwrap();
        assert_eq!(b.parallelism(), 2);
        // within the envelope: ordinary cold-start scale-up
        let plan = b.resize(4).unwrap();
        assert_eq!((plan.from, plan.to), (2, 4));
        assert_eq!(plan.semantics, ResizeSemantics::ColdStart);
        assert!(plan.transition_s > 0.0);
        // past the envelope: clamped at the cap, throttle signaled
        let plan = b.resize(64).unwrap();
        assert_eq!(plan.to, EDGE_MAX_CONCURRENCY);
        assert_eq!(plan.semantics, ResizeSemantics::Throttle);
        assert_eq!(b.parallelism(), EDGE_MAX_CONCURRENCY);
        // already at the cap: still a throttle signal, but a no-op
        let plan = b.resize(64).unwrap();
        assert!(!plan.is_change());
        assert_eq!(plan.semantics, ResizeSemantics::Throttle);
        // instant down-scale
        let plan = b.resize(1).unwrap();
        assert_eq!(plan.transition_s, 0.0);
        assert_eq!(b.parallelism(), 1);
    }

    #[test]
    fn device_envelope_enforced() {
        let plugin = EdgePlugin;
        let mut d = desc();
        d.memory_mb = 3008; // cloud default exceeds the device...
        assert!(plugin.validate(&d).is_err());
        // ...but normalize clamps it, so the service-side
        // normalize-then-validate pipeline accepts cloud defaults
        assert_eq!(plugin.normalize(d.clone()).memory_mb, EDGE_MAX_MEMORY_MB);
        assert!(plugin.validate(&plugin.normalize(d.clone())).is_ok());
        d.memory_mb = 1024;
        assert!(plugin.validate(&d).is_ok());
        // concurrency is clamped, not rejected
        let b = EdgeBackend::provision(&d.with_parallelism(64), &ctx()).unwrap();
        assert_eq!(
            b.fleet().config().max_concurrency,
            EDGE_MAX_CONCURRENCY,
            "device cap"
        );
    }
}
