//! Edge plugin (paper §V future work): a multi-site
//! [`EdgeFleet`] of Greengrass-class boxes with a **message-class
//! placement layer**, provisioned purely through the plugin API — the
//! service and the drivers were not touched to add (or to generalize)
//! this platform.
//!
//! One edge pilot is a *co-located* broker + processing pair: `broker()`
//! returns a site-local Kinesis-like stream with LAN put latency (~2 ms
//! vs ~15 ms WAN) and `processor()` a placement router over the fleet.
//! The fleet size comes from the description's `edge_sites` extension
//! parameter (which `Scenario::pilot_descriptions` forwards from the
//! sweep axis of the same name); each site runs its own Lambda-compatible
//! fleet under its device envelope — per-site CPU efficiency, container
//! cap, LAN and backhaul latency.
//!
//! The router stripes broker partitions over sites round-robin and routes
//! each message class with [`PlacementPolicy`]: classes under a site's
//! break-even ([`EdgeSite::should_run_at_edge`]) are pinned to the box
//! (they queue when it is full), heavier classes run data-local while the
//! site has capacity and **spill over the backhaul** to a cloud-region
//! fallback fleet when the site saturates.  Resize targets past the
//! summed per-site caps clamp with [`ResizeSemantics::Throttle`], which
//! the control loop turns into source throttling.
//!
//! ```rust
//! use pilot_streaming::engine::CalibratedEngine;
//! use pilot_streaming::pilot::{PilotComputeService, PilotDescription, Platform, ResizeSemantics};
//! use pilot_streaming::sim::SimClock;
//! use std::sync::Arc;
//!
//! let service = PilotComputeService::new(
//!     Arc::new(SimClock::new()),
//!     Arc::new(CalibratedEngine::new(1)),
//! );
//! // a two-site fleet, provisioned through the plugin registry
//! let pilot = service
//!     .submit_pilot(
//!         PilotDescription::new(Platform::EDGE)
//!             .with_parallelism(2)
//!             .with_memory_mb(1024)
//!             .with_extra("edge_sites", 2),
//!     )
//!     .unwrap();
//! assert_eq!(pilot.parallelism(), 2);
//! // the device envelopes are a hard wall: past the summed per-site caps
//! // the plan clamps and tells the control loop to throttle the source
//! let plan = pilot.resize(64).unwrap();
//! assert_eq!(plan.semantics, ResizeSemantics::Throttle);
//! assert!(plan.to < 64);
//! pilot.cancel();
//! ```

use crate::broker::kinesis::{KinesisStream, ShardLimits};
use crate::broker::Broker;
use crate::pilot::compute_unit::{ComputeUnit, CuOutcome, TaskSpec};
use crate::pilot::description::{DescriptionError, PilotDescription, Platform};
use crate::pilot::job::{PilotBackend, PilotError, ResizePlan, ResizeSemantics};
use crate::pilot::processor::{ProcessCost, StreamProcessor};
use crate::pilot::registry::{Elasticity, PlatformPlugin, PriceModel, ProvisionContext};
use crate::pilot::workers::{LazyWorkerPool, TaskExecutor};
use crate::serverless::edge::{EDGE_MAX_CONCURRENCY, EDGE_MAX_MEMORY_MB};
use crate::serverless::edge_fleet::{
    EdgeFleet, MessageClass, Placement, PlacementPolicy, PlacementSnapshot, PlacementStats,
    CLOUD_SPILLOVER_CONCURRENCY, MAX_EDGE_SITES,
};
use crate::serverless::{
    EdgeSite, FunctionConfig, InvocationReport, LambdaFleet, LAMBDA_CPU_EFFICIENCY,
};
use crate::store::ObjectStore;
use std::sync::{Arc, Mutex};

/// Draw of one active edge container (an SBC-class device running one
/// sandbox) — the per-site energy term of the edge price model.
pub const EDGE_CONTAINER_WATTS: f64 = 7.5;
/// Retail electricity price at the sites, dollars per kWh.
pub const EDGE_KWH_DOLLARS: f64 = 0.14;

/// The edge price model: hardware is owned, so the marginal cost of one
/// unit of parallelism is the site's electricity draw.  Local container
/// starts move no money (no billed init, no data egress).
pub(crate) fn edge_price() -> PriceModel {
    PriceModel::per_unit_hour(EDGE_CONTAINER_WATTS / 1000.0 * EDGE_KWH_DOLLARS, "site-kWh")
}

/// One provisioned site: its envelope, the admitted function config, and
/// the container fleet running under it.
struct SiteRuntime {
    site: EdgeSite,
    config: FunctionConfig,
    fleet: Arc<LambdaFleet>,
}

/// One routed invocation: where it ran and what the backhaul added.
struct RoutedInvocation {
    report: InvocationReport,
    /// Backhaul round trip paid by spilled messages (0 for edge-served).
    backhaul_s: f64,
    /// Executor label for traces: the site name, or "edge-cloud".
    executor_label: String,
}

/// The placement router: stripes partitions over sites, pins light
/// message classes to their box, spills heavy classes to the cloud
/// fallback when a site saturates — with conserved accounting.
struct EdgeFleetRouter {
    sites: Vec<SiteRuntime>,
    cloud: Arc<LambdaFleet>,
    policy: Mutex<PlacementPolicy>,
    stats: PlacementStats,
}

impl EdgeFleetRouter {
    /// Site choice for bag-of-tasks work, where no partition pins the
    /// data: start at the worker's home site and take the first one with
    /// a free container, so heterogeneous per-site allocations (which a
    /// plain modulo stripe cannot saturate) are fully drivable.  Stream
    /// partitions do NOT use this — their data lives on `partition % n`.
    fn site_for_task(&self, worker: usize) -> usize {
        let n = self.sites.len();
        let home = worker % n;
        (0..n)
            .map(|k| (home + k) % n)
            .find(|&i| !self.sites[i].fleet.is_saturated())
            .unwrap_or(home)
    }

    fn route(
        &self,
        partition: usize,
        points: &[f32],
        dim: usize,
        model_key: &str,
        centroids: usize,
    ) -> Result<RoutedInvocation, String> {
        let idx = partition % self.sites.len();
        let rt = &self.sites[idx];
        let class = MessageClass::of(points.len() / dim.max(1), centroids);
        let placement = self
            .policy
            .lock()
            .unwrap()
            .place(&rt.site, &rt.config, class);
        if placement == Placement::Spillable && rt.fleet.is_saturated() {
            // the site is full and the class is not latency-pinned: ship
            // the message to the region and sync the model back over the
            // site's backhaul
            let report = self
                .cloud
                .invoke(points, dim, model_key, centroids)
                .map_err(|e| e.to_string())?;
            self.policy
                .lock()
                .unwrap()
                .observe_cloud_compute(class, report.compute);
            let backhaul_s = rt.site.backhaul_round_trip();
            self.stats.record_spill(backhaul_s);
            return Ok(RoutedInvocation {
                report,
                backhaul_s,
                executor_label: "edge-cloud".into(),
            });
        }
        let report = rt
            .fleet
            .invoke(points, dim, model_key, centroids)
            .map_err(|e| e.to_string())?;
        self.policy
            .lock()
            .unwrap()
            .observe_edge_compute(class, &rt.site, report.compute);
        self.stats.record_edge(idx);
        Ok(RoutedInvocation {
            report,
            backhaul_s: 0.0,
            executor_label: rt.site.name.clone(),
        })
    }
}

impl StreamProcessor for EdgeFleetRouter {
    fn label(&self) -> &'static str {
        "edge"
    }

    fn process(
        &self,
        partition: usize,
        points: &[f32],
        dim: usize,
        model_key: &str,
        centroids: usize,
    ) -> Result<ProcessCost, String> {
        let routed = self.route(partition, points, dim, model_key, centroids)?;
        let r = &routed.report;
        Ok(ProcessCost {
            compute: r.compute,
            io: r.io_get + r.io_put,
            overhead: r.cold_start + r.queue_wait + routed.backhaul_s,
        })
    }
}

/// Runs compute-units through the placement router: bag-of-tasks work
/// has no partition pinning it to a site, so each task takes the first
/// site with a free container (starting from the worker's home site).
struct EdgeFleetExecutor {
    router: Arc<EdgeFleetRouter>,
}

impl TaskExecutor for EdgeFleetExecutor {
    fn execute(&self, worker: usize, spec: TaskSpec) -> Result<CuOutcome, String> {
        match spec {
            TaskSpec::KMeansStep {
                points,
                dim,
                model_key,
                centroids,
            } => {
                let site = self.router.site_for_task(worker);
                let routed = self.router.route(site, &points, dim, &model_key, centroids)?;
                let r = routed.report;
                Ok(CuOutcome {
                    value: r.inertia,
                    compute_seconds: r.compute,
                    io_seconds: r.io_get + r.io_put,
                    overhead_seconds: r.cold_start + r.queue_wait + routed.backhaul_s,
                    executor: format!("{}-{}", routed.executor_label, r.container_id),
                })
            }
            TaskSpec::Sleep(s) => Ok(CuOutcome {
                value: s,
                compute_seconds: s,
                io_seconds: 0.0,
                overhead_seconds: 0.0,
                executor: "edge".into(),
            }),
            TaskSpec::Custom(_) => {
                Err("edge backend runs packaged functions, not closures".into())
            }
        }
    }
}

/// The provisioned edge pilot: site-local broker + fleet + placement
/// router + cloud spillover.
pub struct EdgeBackend {
    fleet: EdgeFleet,
    stream: Arc<KinesisStream>,
    router: Arc<EdgeFleetRouter>,
    pool: LazyWorkerPool,
}

impl EdgeBackend {
    pub fn provision(desc: &PilotDescription, ctx: &ProvisionContext) -> Result<Self, PilotError> {
        // the plugin's validate rejects out-of-range fleet sizes on the
        // service path; clamp defensively for direct callers (a per-site
        // LambdaFleet is provisioned below, so the count must stay sane)
        let sites_n = desc
            .extra_param("edge_sites")
            .unwrap_or(1)
            .clamp(1, MAX_EDGE_SITES as u64) as usize;
        let fleet = EdgeFleet::provision(sites_n);
        let alloc = fleet.distribute(desc.parallelism);
        let mut runtimes = Vec::with_capacity(sites_n);
        for (i, (site, slots)) in fleet.sites().iter().zip(&alloc).enumerate() {
            // admit() clamps concurrency to the device and rejects
            // over-memory; sites pin latency-bound classes, so a full box
            // queues rather than throttles
            let config = site
                .admit(FunctionConfig {
                    memory_mb: desc.memory_mb,
                    timeout_s: desc.walltime_s,
                    package_mb: desc.package_mb,
                    max_concurrency: *slots,
                    cpu_efficiency: site.cpu_efficiency,
                    queue_when_saturated: true,
                })
                .map_err(PilotError::Provision)?;
            let site_fleet = Arc::new(
                LambdaFleet::new(
                    config.clone(),
                    Arc::clone(&ctx.engine),
                    Arc::new(ObjectStore::default()),
                    Arc::clone(&ctx.clock),
                    desc.seed.wrapping_add(i as u64),
                )
                .map_err(PilotError::Provision)?,
            );
            runtimes.push(SiteRuntime {
                site: site.clone(),
                config,
                fleet: site_fleet,
            });
        }
        // the cloud-region fallback spilled messages overflow to: cloud
        // silicon, the paper's observed concurrency ceiling, and queueing
        // (the region absorbs bursts; the backhaul is charged per message
        // by the router)
        let cloud = Arc::new(
            LambdaFleet::new(
                FunctionConfig {
                    memory_mb: desc.memory_mb,
                    timeout_s: desc.walltime_s,
                    package_mb: desc.package_mb,
                    max_concurrency: CLOUD_SPILLOVER_CONCURRENCY,
                    cpu_efficiency: LAMBDA_CPU_EFFICIENCY,
                    queue_when_saturated: true,
                },
                Arc::clone(&ctx.engine),
                Arc::new(ObjectStore::default()),
                Arc::clone(&ctx.clock),
                desc.seed.wrapping_add(0xC10D),
            )
            .map_err(PilotError::Provision)?,
        );
        // one co-located stream; the gateway site's LAN latency applies
        let stream = Arc::new(KinesisStream::new(
            "edge-stream",
            desc.parallelism,
            ShardLimits {
                put_latency: fleet.sites()[0].broker_latency,
                ..Default::default()
            },
            Arc::clone(&ctx.clock),
        ));
        let router = Arc::new(EdgeFleetRouter {
            sites: runtimes,
            cloud,
            policy: Mutex::new(PlacementPolicy::new()),
            stats: PlacementStats::new(sites_n),
        });
        let pool = LazyWorkerPool::new(
            alloc.iter().sum(),
            Arc::new(EdgeFleetExecutor {
                router: Arc::clone(&router),
            }),
        );
        Ok(Self {
            fleet,
            stream,
            router,
            pool,
        })
    }

    /// The fleet's site envelopes.
    pub fn fleet(&self) -> &EdgeFleet {
        &self.fleet
    }

    /// Conserved placement accounting: per-site edge-served counts plus
    /// backhaul spills (`edge_total + spilled == messages routed`).
    pub fn placement(&self) -> PlacementSnapshot {
        self.router.stats.snapshot()
    }

    /// Total messages the cloud fallback absorbed (diagnostics).
    pub fn cloud_invocations(&self) -> u64 {
        self.router.cloud.invocation_count()
    }
}

impl PilotBackend for EdgeBackend {
    fn platform(&self) -> Platform {
        Platform::EDGE
    }

    fn submit(&self, cu: ComputeUnit, spec: TaskSpec) -> Result<(), PilotError> {
        self.pool.submit(cu, spec).map_err(PilotError::Provision)
    }

    fn parallelism(&self) -> usize {
        self.router.sites.iter().map(|rt| rt.fleet.concurrency()).sum()
    }

    /// Fleet resize: waterfill the target over the per-site caps.  The
    /// summed device envelopes are a hard wall — targets above them are
    /// *clamped*, and the plan lands at the fleet capacity with
    /// [`ResizeSemantics::Throttle`], telling the control loop the source
    /// must slow down rather than the fleet scale up.  Targets below one
    /// container per site clamp upward (the data source lives on every
    /// box).
    fn resize(&self, to: usize) -> Result<ResizePlan, PilotError> {
        let cap = self.fleet.total_capacity();
        let from = self.parallelism();
        let target = to.clamp(self.fleet.len(), cap);
        let semantics = if to > cap {
            ResizeSemantics::Throttle
        } else if target == from {
            ResizeSemantics::NoChange
        } else {
            ResizeSemantics::ColdStart
        };
        if target == from {
            return Ok(ResizePlan {
                from,
                to: from,
                transition_s: 0.0,
                semantics,
            });
        }
        let alloc = self.fleet.distribute(target);
        let mut grew = false;
        for (rt, slots) in self.router.sites.iter().zip(&alloc) {
            let current = rt.fleet.concurrency();
            if *slots != current {
                grew |= *slots > current;
                rt.fleet.set_concurrency(*slots);
            }
        }
        self.pool.resize(target);
        // sites grow in parallel: one (mean) cold-start window covers the
        // whole transition, exactly like the single-fleet serverless case
        let transition_s = if grew {
            self.router.sites[0].config.cold_start_dist().mean()
        } else {
            0.0
        };
        Ok(ResizePlan {
            from,
            to: target,
            transition_s,
            semantics,
        })
    }

    fn broker(&self) -> Option<Arc<dyn Broker>> {
        Some(Arc::clone(&self.stream) as Arc<dyn Broker>)
    }

    fn processor(&self) -> Option<Arc<dyn StreamProcessor>> {
        Some(Arc::clone(&self.router) as Arc<dyn StreamProcessor>)
    }

    fn shutdown(&self) {
        self.pool.shutdown();
    }

    fn completed(&self) -> u64 {
        self.pool.completed()
    }
}

/// The edge platform plugin: owns the "edge" name and the device
/// envelopes.
pub struct EdgePlugin;

impl PlatformPlugin for EdgePlugin {
    fn platform(&self) -> Platform {
        Platform::EDGE
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["greengrass"]
    }

    fn provisions_broker(&self) -> bool {
        true
    }

    /// Edge elasticity: containers start locally (one cold start), tear
    /// down instantly — but the device envelopes cap parallelism.  The
    /// declared cap is the *reference site's* container count (the
    /// description-independent envelope); multi-site fleets surface their
    /// true summed cap at runtime through `Throttle` resize plans, which
    /// the control loop learns from.
    fn elasticity(&self) -> Elasticity {
        Elasticity::elastic(FunctionConfig::default().cold_start_dist().mean(), 0.0)
            .with_cap(EDGE_MAX_CONCURRENCY)
            .with_price(edge_price())
    }

    /// Clamp container memory into the device envelope, so the cloud
    /// defaults every other platform accepts provision cleanly on the
    /// edge (the device simply deploys at its maximum — the same policy
    /// `EdgeSite::admit` applies to concurrency).
    fn normalize(&self, mut d: PilotDescription) -> PilotDescription {
        d.memory_mb = d.memory_mb.min(EDGE_MAX_MEMORY_MB);
        d
    }

    fn validate(&self, d: &PilotDescription) -> Result<(), DescriptionError> {
        if !(crate::serverless::MIN_MEMORY_MB..=EDGE_MAX_MEMORY_MB).contains(&d.memory_mb) {
            return Err(DescriptionError::invalid(
                "memory_mb",
                format!(
                    "{} outside edge device range [{}, {EDGE_MAX_MEMORY_MB}]",
                    d.memory_mb,
                    crate::serverless::MIN_MEMORY_MB
                ),
            ));
        }
        if d.walltime_s > crate::serverless::MAX_WALLTIME_S {
            return Err(DescriptionError::invalid(
                "walltime_s",
                format!("{} exceeds the 15-minute function cap", d.walltime_s),
            ));
        }
        if let Some(sites) = d.extra_param("edge_sites") {
            if sites == 0 || sites > MAX_EDGE_SITES as u64 {
                return Err(DescriptionError::invalid(
                    "extra",
                    format!("edge_sites {sites} outside [1, {MAX_EDGE_SITES}]"),
                ));
            }
        }
        Ok(())
    }

    fn provision(
        &self,
        description: &PilotDescription,
        ctx: &ProvisionContext,
    ) -> Result<Arc<dyn PilotBackend>, PilotError> {
        Ok(Arc::new(EdgeBackend::provision(description, ctx)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::pilot::state::CuState;
    use crate::serverless::edge::{EDGE_BROKER_LATENCY, EDGE_MAX_CONCURRENCY};
    use crate::sim::{ContentionParams, Dist, SharedResource, SimClock, WallClock};

    fn ctx() -> ProvisionContext {
        ProvisionContext {
            engine: Arc::new(CalibratedEngine::new(1)),
            clock: Arc::new(WallClock::new()),
            shared_fs: SharedResource::new("fs", ContentionParams::ISOLATED),
        }
    }

    /// A context on a frozen virtual clock with a constant-cost engine:
    /// containers booked at t=0 stay busy, so saturation is exact.
    fn sim_ctx(compute_s: f64) -> (Arc<SimClock>, ProvisionContext) {
        let clock = Arc::new(SimClock::new());
        let mut e = CalibratedEngine::new(1);
        e.insert((20, 16), Dist::Const(compute_s));
        let ctx = ProvisionContext {
            engine: Arc::new(e),
            clock: clock.clone(),
            shared_fs: SharedResource::new("fs", ContentionParams::ISOLATED),
        };
        (clock, ctx)
    }

    fn desc() -> PilotDescription {
        PilotDescription::new(Platform::EDGE)
            .with_parallelism(2)
            .with_memory_mb(1024)
    }

    fn pts() -> Vec<f32> {
        vec![0.1f32; 20 * 8]
    }

    #[test]
    fn provisions_colocated_broker_and_fleet() {
        let b = EdgeBackend::provision(&desc(), &ctx()).unwrap();
        let broker = b.broker().expect("site-local broker");
        assert_eq!(broker.num_partitions(), 2);
        let p = b.processor().expect("edge fleet");
        assert_eq!(p.label(), "edge");
        assert_eq!(b.fleet().len(), 1, "no extension param: one site");
        assert!(b.fleet().sites()[0].cpu_efficiency < 1.0);
    }

    #[test]
    fn extension_param_provisions_a_heterogeneous_fleet() {
        let b = EdgeBackend::provision(&desc().with_extra("edge_sites", 3), &ctx()).unwrap();
        assert_eq!(b.fleet().len(), 3);
        // heterogeneous envelopes straight from the fleet table
        let effs: Vec<f64> = b.fleet().sites().iter().map(|s| s.cpu_efficiency).collect();
        assert!(effs.windows(2).any(|w| w[0] != w[1]));
        // parallelism floors at one container per site
        assert_eq!(b.parallelism(), 3);
    }

    #[test]
    fn local_broker_has_lan_latency() {
        let (_, ctx) = sim_ctx(0.05);
        let b = EdgeBackend::provision(&desc(), &ctx).unwrap();
        let r = b
            .broker()
            .unwrap()
            .put(crate::broker::Message::new(
                1,
                0,
                vec![0.0; 16].into(),
                8,
                0.0,
            ))
            .unwrap();
        assert!(
            (r.broker_latency - EDGE_BROKER_LATENCY).abs() < 1e-9,
            "LAN hop, not WAN: {}",
            r.broker_latency
        );
    }

    #[test]
    fn compute_units_run_on_the_edge_fleet() {
        let b = EdgeBackend::provision(&desc(), &ctx()).unwrap();
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        b.submit(
            cu.clone(),
            TaskSpec::KMeansStep {
                points: Arc::new(vec![0.1; 160]),
                dim: 8,
                model_key: "m".into(),
                centroids: 8,
            },
        )
        .unwrap();
        assert_eq!(cu.wait(), CuState::Done);
        assert!(cu.outcome().unwrap().executor.starts_with("edge-"));
        assert_eq!(b.placement().total(), 1);
    }

    #[test]
    fn saturated_site_spills_heavy_classes_over_the_backhaul() {
        // frozen clock: every booked container stays busy, so the 5th
        // message onward finds site 0 saturated.  0.5 s of cloud compute
        // is far past the break-even, so the class is spillable once the
        // first invocation has been measured.
        let (_, ctx) = sim_ctx(0.5);
        let d = desc().with_parallelism(8); // site cap 4: full allocation
        let b = EdgeBackend::provision(&d, &ctx).unwrap();
        let p = b.processor().unwrap();
        let mut spilled_costs = Vec::new();
        for _ in 0..10 {
            let cost = p.process(0, &pts(), 8, "m", 16).unwrap();
            spilled_costs.push(cost);
        }
        let snap = b.placement();
        assert_eq!(snap.total(), 10, "every message routed exactly once");
        assert_eq!(snap.edge_per_site[0], 4, "one per container, then full");
        assert_eq!(snap.spilled, 6, "overflow went to the region");
        assert_eq!(b.cloud_invocations(), 6);
        // conservation: edge + spilled == total, always
        assert_eq!(snap.edge_total() + snap.spilled, snap.total());
        // each spilled message was charged the site's backhaul round trip
        let backhaul = b.fleet().sites()[0].backhaul_round_trip();
        assert!(
            (snap.backhaul_seconds - 6.0 * backhaul).abs() < 1e-9,
            "charged {} expected {}",
            snap.backhaul_seconds,
            6.0 * backhaul
        );
        // ...and it lands in the processed cost's overhead term (messages
        // 4.. are the spilled ones on the frozen clock)
        assert!(spilled_costs[4..].iter().all(|c| c.overhead >= backhaul));
    }

    #[test]
    fn light_classes_stay_pinned_and_queue() {
        // 1 ms of compute sits under the break-even: the class is pinned,
        // so a saturated site queues instead of spilling
        let (_, ctx) = sim_ctx(0.001);
        let d = desc().with_parallelism(8);
        let b = EdgeBackend::provision(&d, &ctx).unwrap();
        let p = b.processor().unwrap();
        let mut costs = Vec::new();
        for _ in 0..8 {
            costs.push(p.process(0, &pts(), 8, "m", 16).unwrap());
        }
        let snap = b.placement();
        assert_eq!(snap.spilled, 0, "pinned classes never ride the backhaul");
        assert_eq!(snap.backhaul_seconds, 0.0);
        assert_eq!(snap.edge_per_site[0], 8);
        assert!(
            costs[4..].iter().all(|c| c.overhead > 0.0),
            "saturated invocations of a pinned class wait for a container"
        );
    }

    #[test]
    fn partitions_stripe_across_sites() {
        let (_, ctx) = sim_ctx(0.05);
        let d = desc().with_parallelism(4).with_extra("edge_sites", 2);
        let b = EdgeBackend::provision(&d, &ctx).unwrap();
        let p = b.processor().unwrap();
        for partition in 0..4 {
            p.process(partition, &pts(), 8, "m", 16).unwrap();
        }
        let snap = b.placement();
        assert_eq!(snap.edge_per_site, vec![2, 2], "round-robin striping");
    }

    #[test]
    fn resize_clamps_at_the_fleet_capacity() {
        let b = EdgeBackend::provision(&desc(), &ctx()).unwrap();
        assert_eq!(b.parallelism(), 2);
        // within the envelope: ordinary cold-start scale-up
        let plan = b.resize(4).unwrap();
        assert_eq!((plan.from, plan.to), (2, 4));
        assert_eq!(plan.semantics, ResizeSemantics::ColdStart);
        assert!(plan.transition_s > 0.0);
        // past the envelope: clamped at the cap, throttle signaled
        let plan = b.resize(64).unwrap();
        assert_eq!(plan.to, EDGE_MAX_CONCURRENCY);
        assert_eq!(plan.semantics, ResizeSemantics::Throttle);
        assert_eq!(b.parallelism(), EDGE_MAX_CONCURRENCY);
        // already at the cap: still a throttle signal, but a no-op
        let plan = b.resize(64).unwrap();
        assert!(!plan.is_change());
        assert_eq!(plan.semantics, ResizeSemantics::Throttle);
        // instant down-scale
        let plan = b.resize(1).unwrap();
        assert_eq!(plan.transition_s, 0.0);
        assert_eq!(b.parallelism(), 1);
    }

    #[test]
    fn fleet_resize_clamps_at_the_summed_site_caps() {
        let b =
            EdgeBackend::provision(&desc().with_extra("edge_sites", 3), &ctx()).unwrap();
        let cap = b.fleet().total_capacity();
        assert_eq!(cap, 11, "site caps 4 + 3 + 4");
        let plan = b.resize(1_000).unwrap();
        assert_eq!(plan.to, cap, "forced Throttle clamps exactly at the sum");
        assert_eq!(plan.semantics, ResizeSemantics::Throttle);
        assert_eq!(b.parallelism(), cap);
        // scale-down floors at one container per site
        let plan = b.resize(1).unwrap();
        assert_eq!(plan.to, 3);
        assert_eq!(b.parallelism(), 3);
    }

    #[test]
    fn fleet_size_is_validated_and_clamped() {
        let plugin = EdgePlugin;
        // the service path rejects out-of-range fleet sizes up front...
        assert!(plugin.validate(&desc().with_extra("edge_sites", 0)).is_err());
        assert!(plugin
            .validate(&desc().with_extra("edge_sites", MAX_EDGE_SITES as u64 + 1))
            .is_err());
        assert!(plugin
            .validate(&desc().with_extra("edge_sites", MAX_EDGE_SITES as u64))
            .is_ok());
        // ...and a negative JSON value sign-wraps to a huge u64, which the
        // same check catches before any fleet is built
        assert!(plugin
            .validate(&desc().with_extra("edge_sites", u64::MAX))
            .is_err());
        // direct provisioning clamps defensively instead of allocating
        let b = EdgeBackend::provision(&desc().with_extra("edge_sites", u64::MAX), &ctx())
            .unwrap();
        assert_eq!(b.fleet().len(), MAX_EDGE_SITES);
    }

    #[test]
    fn device_envelope_enforced() {
        let plugin = EdgePlugin;
        let mut d = desc();
        d.memory_mb = 3008; // cloud default exceeds the device...
        assert!(plugin.validate(&d).is_err());
        // ...but normalize clamps it, so the service-side
        // normalize-then-validate pipeline accepts cloud defaults
        assert_eq!(plugin.normalize(d.clone()).memory_mb, EDGE_MAX_MEMORY_MB);
        assert!(plugin.validate(&plugin.normalize(d.clone())).is_ok());
        d.memory_mb = 1024;
        assert!(plugin.validate(&d).is_ok());
        // concurrency is clamped, not rejected
        let b = EdgeBackend::provision(&d.with_parallelism(64), &ctx()).unwrap();
        assert_eq!(b.parallelism(), EDGE_MAX_CONCURRENCY, "device cap");
    }
}
