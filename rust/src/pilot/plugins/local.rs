//! Local plugin: an in-process thread pool.  The quickest way to run
//! bag-of-tasks / DAG workloads through the Pilot-API, and the only backend
//! that accepts [`TaskSpec::Custom`] closures.

use crate::engine::StepEngine;
use crate::pilot::compute_unit::{ComputeUnit, CuOutcome, TaskSpec};
use crate::pilot::description::{PilotDescription, Platform};
use crate::pilot::job::{PilotBackend, PilotError, ResizePlan, ResizeSemantics};
use crate::pilot::processor::kmeans_step;
use crate::pilot::registry::{Elasticity, PlatformPlugin, PriceModel, ProvisionContext};
use crate::pilot::workers::{LazyWorkerPool, TaskExecutor};
use crate::store::{ModelStore, ObjectStore};
use std::sync::Arc;

/// Amortized electricity + depreciation of one host core: even the
/// "free" in-process platform declares a real price so cost objectives
/// always have a denominator (and the conformance walk stays uniform).
pub const LOCAL_CORE_HOUR_DOLLARS: f64 = 0.008;

struct LocalExecutor {
    engine: Arc<dyn StepEngine>,
    store: Arc<dyn ModelStore>,
}

impl TaskExecutor for LocalExecutor {
    fn execute(&self, worker: usize, spec: TaskSpec) -> Result<CuOutcome, String> {
        match spec {
            TaskSpec::KMeansStep {
                points,
                dim,
                model_key,
                centroids,
            } => {
                let (inertia, compute, io) = kmeans_step(
                    self.engine.as_ref(),
                    self.store.as_ref(),
                    &points,
                    dim,
                    &model_key,
                    centroids,
                )?;
                Ok(CuOutcome {
                    value: inertia,
                    compute_seconds: compute,
                    io_seconds: io,
                    overhead_seconds: 0.0,
                    executor: format!("local-{worker}"),
                })
            }
            TaskSpec::Custom(f) => f().map(|value| CuOutcome {
                value,
                compute_seconds: 0.0,
                io_seconds: 0.0,
                overhead_seconds: 0.0,
                executor: format!("local-{worker}"),
            }),
            TaskSpec::Sleep(s) => {
                std::thread::sleep(std::time::Duration::from_secs_f64(s.min(1.0)));
                Ok(CuOutcome {
                    value: s,
                    compute_seconds: s,
                    io_seconds: 0.0,
                    overhead_seconds: 0.0,
                    executor: format!("local-{worker}"),
                })
            }
        }
    }
}

/// The local backend.
pub struct LocalBackend {
    pool: LazyWorkerPool,
}

impl LocalBackend {
    pub fn new(workers: usize, engine: Arc<dyn StepEngine>) -> Self {
        Self {
            pool: LazyWorkerPool::new(
                workers,
                Arc::new(LocalExecutor {
                    engine,
                    store: Arc::new(ObjectStore::default()),
                }),
            ),
        }
    }
}

impl PilotBackend for LocalBackend {
    fn platform(&self) -> Platform {
        Platform::LOCAL
    }

    fn submit(&self, cu: ComputeUnit, spec: TaskSpec) -> Result<(), PilotError> {
        self.pool
            .submit(cu, spec)
            .map_err(PilotError::Provision)
    }

    fn parallelism(&self) -> usize {
        self.pool.workers()
    }

    /// Threads are free: the pool drains and respawns at the new size with
    /// no transition window.
    fn resize(&self, to: usize) -> Result<ResizePlan, PilotError> {
        let from = self.pool.workers();
        if to == from {
            return Ok(ResizePlan::no_change(from));
        }
        self.pool.resize(to);
        Ok(ResizePlan {
            from,
            to,
            transition_s: 0.0,
            semantics: ResizeSemantics::ColdStart,
        })
    }

    fn shutdown(&self) {
        self.pool.shutdown();
    }

    fn completed(&self) -> u64 {
        self.pool.completed()
    }
}

/// The local platform plugin: in-process threads, accepts every task kind.
pub struct LocalPlugin;

impl PlatformPlugin for LocalPlugin {
    fn platform(&self) -> Platform {
        Platform::LOCAL
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["threads"]
    }

    /// Local pilots run bags-of-tasks, not message streams.
    fn streams(&self) -> bool {
        false
    }

    /// In-process threads come and go for free (in time — the host core
    /// still draws power, which is the declared run-rate).
    fn elasticity(&self) -> Elasticity {
        Elasticity::elastic(0.0, 0.0)
            .with_price(PriceModel::per_unit_hour(LOCAL_CORE_HOUR_DOLLARS, "core-hour"))
    }

    fn provision(
        &self,
        description: &PilotDescription,
        ctx: &ProvisionContext,
    ) -> Result<Arc<dyn PilotBackend>, PilotError> {
        Ok(Arc::new(LocalBackend::new(
            description.parallelism,
            Arc::clone(&ctx.engine),
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::pilot::state::CuState;

    #[test]
    fn runs_kmeans_and_custom_tasks() {
        let backend = LocalBackend::new(2, Arc::new(CalibratedEngine::new(1)));
        let cu1 = ComputeUnit::new();
        cu1.transition(CuState::Queued);
        backend
            .submit(
                cu1.clone(),
                TaskSpec::KMeansStep {
                    points: Arc::new(vec![0.0; 80]),
                    dim: 8,
                    model_key: "m".into(),
                    centroids: 4,
                },
            )
            .unwrap();
        let cu2 = ComputeUnit::new();
        cu2.transition(CuState::Queued);
        backend
            .submit(cu2.clone(), TaskSpec::Custom(Box::new(|| Ok(7.0))))
            .unwrap();
        assert_eq!(cu1.wait(), CuState::Done);
        assert_eq!(cu2.wait(), CuState::Done);
        assert_eq!(cu2.outcome().unwrap().value, 7.0);
        assert_eq!(backend.completed(), 2);
    }
}
