//! Local plugin: an in-process thread pool.  The quickest way to run
//! bag-of-tasks / DAG workloads through the Pilot-API, and the only backend
//! that accepts [`TaskSpec::Custom`] closures.

use crate::engine::StepEngine;
use crate::pilot::compute_unit::{ComputeUnit, CuOutcome, TaskSpec};
use crate::pilot::description::{PilotDescription, Platform};
use crate::pilot::job::{PilotBackend, PilotError};
use crate::pilot::registry::{PlatformPlugin, ProvisionContext};
use crate::pilot::workers::{LazyWorkerPool, TaskExecutor};
use crate::store::{ModelState, ModelStore, ObjectStore};
use std::sync::Arc;

struct LocalExecutor {
    engine: Arc<dyn StepEngine>,
    store: Arc<dyn ModelStore>,
}

impl TaskExecutor for LocalExecutor {
    fn execute(&self, worker: usize, spec: TaskSpec) -> Result<CuOutcome, String> {
        match spec {
            TaskSpec::KMeansStep {
                points,
                dim,
                model_key,
                centroids,
            } => {
                if !self.store.contains(&model_key) {
                    let init = ModelState::new_random(centroids, dim, 42);
                    let _ = self.store.put(&model_key, init);
                }
                let (model, io_get) = self.store.get(&model_key).map_err(|e| e.to_string())?;
                let step = self
                    .engine
                    .execute_step(&points, dim, &model)
                    .map_err(|e| e.to_string())?;
                let (_, io_put) = self
                    .store
                    .put(&model_key, step.model)
                    .map_err(|e| e.to_string())?;
                Ok(CuOutcome {
                    value: step.inertia,
                    compute_seconds: step.cpu_seconds,
                    io_seconds: io_get.seconds + io_put.seconds,
                    overhead_seconds: 0.0,
                    executor: format!("local-{worker}"),
                })
            }
            TaskSpec::Custom(f) => f().map(|value| CuOutcome {
                value,
                compute_seconds: 0.0,
                io_seconds: 0.0,
                overhead_seconds: 0.0,
                executor: format!("local-{worker}"),
            }),
            TaskSpec::Sleep(s) => {
                std::thread::sleep(std::time::Duration::from_secs_f64(s.min(1.0)));
                Ok(CuOutcome {
                    value: s,
                    compute_seconds: s,
                    io_seconds: 0.0,
                    overhead_seconds: 0.0,
                    executor: format!("local-{worker}"),
                })
            }
        }
    }
}

/// The local backend.
pub struct LocalBackend {
    pool: LazyWorkerPool,
}

impl LocalBackend {
    pub fn new(workers: usize, engine: Arc<dyn StepEngine>) -> Self {
        Self {
            pool: LazyWorkerPool::new(
                workers,
                Arc::new(LocalExecutor {
                    engine,
                    store: Arc::new(ObjectStore::default()),
                }),
            ),
        }
    }
}

impl PilotBackend for LocalBackend {
    fn platform(&self) -> Platform {
        Platform::LOCAL
    }

    fn submit(&self, cu: ComputeUnit, spec: TaskSpec) -> Result<(), PilotError> {
        self.pool
            .submit(cu, spec)
            .map_err(PilotError::Provision)
    }

    fn shutdown(&self) {
        self.pool.shutdown();
    }

    fn completed(&self) -> u64 {
        self.pool.completed()
    }
}

/// The local platform plugin: in-process threads, accepts every task kind.
pub struct LocalPlugin;

impl PlatformPlugin for LocalPlugin {
    fn platform(&self) -> Platform {
        Platform::LOCAL
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["threads"]
    }

    fn provision(
        &self,
        description: &PilotDescription,
        ctx: &ProvisionContext,
    ) -> Result<Arc<dyn PilotBackend>, PilotError> {
        Ok(Arc::new(LocalBackend::new(
            description.parallelism,
            Arc::clone(&ctx.engine),
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::pilot::state::CuState;

    #[test]
    fn runs_kmeans_and_custom_tasks() {
        let backend = LocalBackend::new(2, Arc::new(CalibratedEngine::new(1)));
        let cu1 = ComputeUnit::new();
        cu1.transition(CuState::Queued);
        backend
            .submit(
                cu1.clone(),
                TaskSpec::KMeansStep {
                    points: Arc::new(vec![0.0; 80]),
                    dim: 8,
                    model_key: "m".into(),
                    centroids: 4,
                },
            )
            .unwrap();
        let cu2 = ComputeUnit::new();
        cu2.transition(CuState::Queued);
        backend
            .submit(cu2.clone(), TaskSpec::Custom(Box::new(|| Ok(7.0))))
            .unwrap();
        assert_eq!(cu1.wait(), CuState::Done);
        assert_eq!(cu2.wait(), CuState::Done);
        assert_eq!(cu2.outcome().unwrap().value, 7.0);
        assert_eq!(backend.completed(), 2);
    }
}
