//! Flink/Spark-Streaming-class micro-batch plugin (the ROADMAP follow-on
//! to PR 1): records are grouped into fixed micro-batch windows before the
//! engine sees them, so every message carries a *scheduling-delay*
//! overhead term on top of its compute and model I/O — the signature that
//! separates micro-batch engines from the per-record FaaS path in the
//! paper's latency breakdowns.
//!
//! Elasticity is platform-true too: a running job cannot simply add
//! operators — rescaling snapshots state to a savepoint and restores at
//! the new parallelism ([`ResizeSemantics::Restart`]), in both directions.

use crate::engine::StepEngine;
use crate::pilot::compute_unit::{ComputeUnit, CuOutcome, TaskSpec};
use crate::pilot::description::{PilotDescription, Platform};
use crate::pilot::job::{PilotBackend, PilotError, ResizePlan, ResizeSemantics};
use crate::pilot::processor::{kmeans_step, ProcessCost, StreamProcessor};
use crate::pilot::registry::{Elasticity, PlatformPlugin, PriceModel, ProvisionContext};
use crate::pilot::workers::{LazyWorkerPool, TaskExecutor};
use crate::store::{ModelStore, ObjectStore};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Micro-batch window length (Spark Streaming's classic default ballpark).
pub const MICRO_BATCH_INTERVAL_S: f64 = 0.5;

/// Expected per-message scheduling delay: a record arriving uniformly
/// within a batch window waits half the interval for its batch to fire.
pub const SCHEDULING_DELAY_S: f64 = MICRO_BATCH_INTERVAL_S / 2.0;

/// Savepoint + restore window a running job pays to rescale.
pub const SAVEPOINT_RESTORE_S: f64 = 3.0;

/// Amortized cluster cost per task-slot-hour (a managed-Flink task
/// manager slot; cheaper than an HPC worker, dearer than a broker
/// shard).  Rescaling restarts the *whole* job from a savepoint, so the
/// per-unit transition charges the restore window across a slot.
pub const TASK_SLOT_HOUR_DOLLARS: f64 = 0.07;

pub(crate) fn flink_price() -> PriceModel {
    PriceModel::per_unit_hour(TASK_SLOT_HOUR_DOLLARS, "slot-hour")
        .with_transition(TASK_SLOT_HOUR_DOLLARS * SAVEPOINT_RESTORE_S / 3600.0)
}

/// Shared execution core: one K-Means step against the job's state store.
struct FlinkCore {
    engine: Arc<dyn StepEngine>,
    store: Arc<dyn ModelStore>,
}

impl FlinkCore {
    /// Returns (inertia, compute seconds, io seconds) — the shared
    /// in-process step ([`kmeans_step`]); the micro-batch scheduling
    /// delay is layered on by the caller as overhead.
    fn step(
        &self,
        points: &[f32],
        dim: usize,
        model_key: &str,
        centroids: usize,
    ) -> Result<(f64, f64, f64), String> {
        kmeans_step(
            self.engine.as_ref(),
            self.store.as_ref(),
            points,
            dim,
            model_key,
            centroids,
        )
    }
}

struct FlinkExecutor {
    core: Arc<FlinkCore>,
}

impl TaskExecutor for FlinkExecutor {
    fn execute(&self, worker: usize, spec: TaskSpec) -> Result<CuOutcome, String> {
        match spec {
            TaskSpec::KMeansStep {
                points,
                dim,
                model_key,
                centroids,
            } => {
                let (inertia, compute, io) = self.core.step(&points, dim, &model_key, centroids)?;
                Ok(CuOutcome {
                    value: inertia,
                    compute_seconds: compute,
                    io_seconds: io,
                    overhead_seconds: SCHEDULING_DELAY_S,
                    executor: format!("flink-{worker}"),
                })
            }
            TaskSpec::Sleep(s) => Ok(CuOutcome {
                value: s,
                compute_seconds: s,
                io_seconds: 0.0,
                overhead_seconds: SCHEDULING_DELAY_S,
                executor: format!("flink-{worker}"),
            }),
            TaskSpec::Custom(_) => {
                Err("micro-batch jobs run staged operators, not closures".into())
            }
        }
    }
}

/// Streams messages through the micro-batch job: every message pays the
/// expected batch scheduling delay as overhead.
struct FlinkProcessor {
    core: Arc<FlinkCore>,
}

impl StreamProcessor for FlinkProcessor {
    fn label(&self) -> &'static str {
        "flink"
    }

    fn process(
        &self,
        _partition: usize,
        points: &[f32],
        dim: usize,
        model_key: &str,
        centroids: usize,
    ) -> Result<ProcessCost, String> {
        let (_, compute, io) = self.core.step(points, dim, model_key, centroids)?;
        Ok(ProcessCost {
            compute,
            io,
            overhead: SCHEDULING_DELAY_S,
        })
    }
}

/// The micro-batch processing backend.
pub struct FlinkBackend {
    core: Arc<FlinkCore>,
    pool: LazyWorkerPool,
    parallelism: AtomicUsize,
}

impl FlinkBackend {
    pub fn provision(desc: &PilotDescription, engine: Arc<dyn StepEngine>) -> Self {
        let core = Arc::new(FlinkCore {
            engine,
            store: Arc::new(ObjectStore::default()),
        });
        let pool = LazyWorkerPool::new(
            desc.parallelism,
            Arc::new(FlinkExecutor {
                core: Arc::clone(&core),
            }),
        );
        Self {
            core,
            pool,
            parallelism: AtomicUsize::new(desc.parallelism),
        }
    }
}

impl PilotBackend for FlinkBackend {
    fn platform(&self) -> Platform {
        Platform::FLINK
    }

    fn submit(&self, cu: ComputeUnit, spec: TaskSpec) -> Result<(), PilotError> {
        self.pool.submit(cu, spec).map_err(PilotError::Provision)
    }

    fn parallelism(&self) -> usize {
        self.parallelism.load(Ordering::Relaxed)
    }

    /// Micro-batch rescale: savepoint the job, restore at the new
    /// parallelism — a flat restart window in either direction.
    fn resize(&self, to: usize) -> Result<ResizePlan, PilotError> {
        let from = self.parallelism.load(Ordering::Relaxed);
        if to == from {
            return Ok(ResizePlan::no_change(from));
        }
        self.parallelism.store(to, Ordering::Relaxed);
        self.pool.resize(to);
        Ok(ResizePlan {
            from,
            to,
            transition_s: SAVEPOINT_RESTORE_S,
            semantics: ResizeSemantics::Restart,
        })
    }

    fn processor(&self) -> Option<Arc<dyn StreamProcessor>> {
        Some(Arc::new(FlinkProcessor {
            core: Arc::clone(&self.core),
        }))
    }

    fn shutdown(&self) {
        self.pool.shutdown();
    }

    fn completed(&self) -> u64 {
        self.pool.completed()
    }
}

/// The Flink platform plugin: micro-batch processing, savepoint-based
/// rescaling.  Registering it is all it took to make `flink` addressable
/// from `run --platform`, sweeps, TOML configs, and `autoscale --live`.
pub struct FlinkPlugin;

impl PlatformPlugin for FlinkPlugin {
    fn platform(&self) -> Platform {
        Platform::FLINK
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["spark-streaming", "microbatch"]
    }

    /// Rescaling restarts the job from a savepoint, both ways.
    fn elasticity(&self) -> Elasticity {
        Elasticity::elastic(SAVEPOINT_RESTORE_S, SAVEPOINT_RESTORE_S).with_price(flink_price())
    }

    fn provision(
        &self,
        description: &PilotDescription,
        ctx: &ProvisionContext,
    ) -> Result<Arc<dyn PilotBackend>, PilotError> {
        Ok(Arc::new(FlinkBackend::provision(
            description,
            Arc::clone(&ctx.engine),
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::pilot::state::CuState;

    fn backend() -> FlinkBackend {
        let desc = PilotDescription::new(Platform::FLINK).with_parallelism(2);
        FlinkBackend::provision(&desc, Arc::new(CalibratedEngine::new(5)))
    }

    #[test]
    fn every_message_pays_the_scheduling_delay() {
        let b = backend();
        let p = b.processor().expect("micro-batch processor");
        assert_eq!(p.label(), "flink");
        let pts = vec![0.1f32; 100 * 8];
        let c1 = p.process(0, &pts, 8, "m", 16).unwrap();
        let c2 = p.process(1, &pts, 8, "m", 16).unwrap();
        for c in [c1, c2] {
            assert!((c.overhead - SCHEDULING_DELAY_S).abs() < 1e-12);
            assert!(c.compute > 0.0 && c.io > 0.0);
        }
    }

    #[test]
    fn compute_units_run_as_micro_batches() {
        let b = backend();
        let cu = ComputeUnit::new();
        cu.transition(CuState::Queued);
        b.submit(
            cu.clone(),
            TaskSpec::KMeansStep {
                points: Arc::new(vec![0.1; 160]),
                dim: 8,
                model_key: "m".into(),
                centroids: 8,
            },
        )
        .unwrap();
        assert_eq!(cu.wait(), CuState::Done);
        let o = cu.outcome().unwrap();
        assert!((o.overhead_seconds - SCHEDULING_DELAY_S).abs() < 1e-12);
        assert!(o.executor.starts_with("flink-"));
        // closures are not operators
        let cu2 = ComputeUnit::new();
        cu2.transition(CuState::Queued);
        b.submit(cu2.clone(), TaskSpec::Custom(Box::new(|| Ok(1.0))))
            .unwrap();
        assert_eq!(cu2.wait(), CuState::Failed);
        b.shutdown();
    }

    #[test]
    fn rescale_is_a_savepoint_restart_both_ways() {
        let b = backend();
        let up = b.resize(8).unwrap();
        assert_eq!(up.semantics, ResizeSemantics::Restart);
        assert!((up.transition_s - SAVEPOINT_RESTORE_S).abs() < 1e-12);
        assert_eq!(b.parallelism(), 8);
        let down = b.resize(2).unwrap();
        assert!((down.transition_s - SAVEPOINT_RESTORE_S).abs() < 1e-12);
        assert!(b.resize(2).unwrap().transition_s == 0.0, "no-op is free");
    }
}
