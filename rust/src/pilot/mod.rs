//! The pilot abstraction (paper §III): unified resource management across
//! serverless, cloud, HPC — and, via the plugin registry, any platform a
//! plugin describes.
//!
//! # Architecture: one Pilot-API, pluggable platforms
//!
//! The paper's claim is that Pilot-Streaming "allocates resource containers
//! independent of the application workload, removing the need to write
//! resource-specific code".  This layer enforces that structurally:
//!
//! - [`PilotDescription`] — the normative resource spec (one `parallelism`
//!   attribute covers Kinesis shards, Kafka partitions, Lambda concurrency,
//!   Dask workers, and edge containers).  Only platform-*independent*
//!   invariants are validated here.
//! - [`Platform`] — an interned platform *name*, not an enum: the set of
//!   platforms is owned by the registry, so new platforms never touch this
//!   module.
//! - [`PluginRegistry`] / [`PlatformPlugin`] — each plugin owns its
//!   platform's naming/parsing, description validation, and backend
//!   provisioning ([`plugins`] holds the built-ins: local, lambda, dask,
//!   kinesis, kafka, edge).  Registering a plugin is the *only* step to add
//!   a platform — the service and the drivers resolve by name.
//! - [`PilotComputeService`] — the Pilot-API facade:
//!   `submit_pilot(description)` resolves the plugin and provisions.
//! - [`PilotJob`] — an allocated resource container:
//!   `submit_compute_unit(task)`, plus the capability accessors
//!   [`PilotJob::broker`] (broker pilots) and [`PilotJob::processor`]
//!   (processing pilots — what the mini-app drivers pump messages through).
//! - [`ComputeUnit`] — the task handle: `wait()`, `outcome()`.
//!
//! The mini-app's `PlatformUnderTest` is itself built on this API: a
//! benchmark scenario expands into pilot descriptions and provisions
//! through one service — no platform-specific construction outside
//! [`plugins`].

pub mod compute_unit;
pub mod description;
pub mod job;
pub mod plugins;
pub mod processor;
pub mod registry;
pub mod service;
pub mod state;
pub mod workers;

pub use compute_unit::{ComputeUnit, CuOutcome, TaskSpec};
pub use description::{DescriptionError, MachineKind, PilotDescription, Platform};
pub use job::{PilotBackend, PilotError, PilotJob};
pub use processor::{ProcessCost, StreamProcessor};
pub use registry::{default_registry, PlatformPlugin, PluginRegistry, ProvisionContext};
pub use service::PilotComputeService;
pub use state::{CuState, PilotState};
