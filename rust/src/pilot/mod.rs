//! The pilot abstraction (paper §III): unified resource management across
//! serverless, cloud, HPC — and, via the plugin registry, any platform a
//! plugin describes.  Since the elastic redesign this layer is a
//! **control plane**, not a submit-only API: pilots are provisioned,
//! *live-resized*, observed, and torn down through one service.
//!
//! # Architecture: one elastic Pilot-API, pluggable platforms
//!
//! The paper's claim is that Pilot-Streaming "allocates resource containers
//! independent of the application workload, removing the need to write
//! resource-specific code"; its stated future work is to feed predictive
//! scaling decisions back into that resource management.  This layer
//! enforces both structurally:
//!
//! - [`PilotDescription`] — the normative resource spec (one `parallelism`
//!   attribute covers Kinesis shards, Kafka partitions, Lambda concurrency,
//!   Dask workers, and edge containers).  Only platform-*independent*
//!   invariants are validated here.
//! - [`Platform`] — an interned platform *name*, not an enum: the set of
//!   platforms is owned by the registry, so new platforms never touch this
//!   module.
//! - [`PluginRegistry`] / [`PlatformPlugin`] — each plugin owns its
//!   platform's naming/parsing, description validation, backend
//!   provisioning, **and elasticity**: [`PlatformPlugin::elasticity`]
//!   declares whether live pilots can change parallelism, the per-unit
//!   transition costs, and any hard capacity cap ([`plugins`] holds the
//!   built-ins: local, lambda, dask, kinesis, kafka, edge, flink).
//! - [`PilotComputeService`] — the control-plane facade:
//!   `submit_pilot(description)` provisions,
//!   `resize_pilot(id, parallelism)` re-provisions live, and
//!   `pilot_state(id)` observes ([`PilotStatus`]: state, effective
//!   parallelism, transition deadline).
//! - [`PilotJob`] — an allocated resource container.  Its state machine
//!   gained a `Resizing` state: [`PilotBackend::resize`] commits a
//!   [`ResizePlan`] with platform-true [`ResizeSemantics`] — serverless
//!   cold-starts new containers and down-scales instantly; HPC pays batch
//!   queue + node boot to grow and drains to shrink; brokers repartition;
//!   micro-batch engines savepoint + restart; the edge clamps at its
//!   device envelope and signals `Throttle` — and the pilot keeps serving
//!   at its old capacity for the plan's deterministic sim-clock
//!   `transition_s`.
//! - [`ComputeUnit`] — the task handle: `wait()`, `outcome()`.
//!
//! The mini-app's `PlatformUnderTest` is itself built on this API, and
//! `insight::control` closes the loop the paper asked for: autoscaler
//! decisions actuate `resize_pilot` on a live pilot through the same
//! `ScalingTarget` seam that replays them against the USL model.

pub mod compute_unit;
pub mod description;
pub mod job;
pub mod plugins;
pub mod processor;
pub mod registry;
pub mod service;
pub mod state;
pub mod workers;

pub use compute_unit::{ComputeUnit, CuOutcome, TaskSpec};
pub use description::{DescriptionError, MachineKind, PilotDescription, Platform};
pub use job::{PilotBackend, PilotError, PilotJob, PilotStatus, ResizePlan, ResizeSemantics};
pub use processor::{ProcessCost, StreamProcessor};
pub use registry::{
    default_registry, Elasticity, PlatformPlugin, PluginRegistry, PriceModel, ProvisionContext,
};
pub use service::PilotComputeService;
pub use state::{CuState, PilotState};
