//! The pilot abstraction (paper §III): unified resource management across
//! serverless, cloud, and HPC.
//!
//! - [`PilotDescription`] — normative resource spec (one `parallelism`
//!   attribute covers Kinesis shards, Kafka partitions, Lambda concurrency
//!   and Dask workers)
//! - [`PilotComputeService`] — the Pilot-API: `submit_pilot(description)`
//! - [`PilotJob`] — an allocated resource container:
//!   `submit_compute_unit(task)`
//! - [`ComputeUnit`] — the task handle: `wait()`, `outcome()`
//! - [`plugins`] — per-platform provisioning (Fig 2's plugin architecture)

pub mod compute_unit;
pub mod description;
pub mod job;
pub mod plugins;
pub mod service;
pub mod state;
pub mod workers;

pub use compute_unit::{ComputeUnit, CuOutcome, TaskSpec};
pub use description::{MachineKind, PilotDescription, Platform};
pub use job::{PilotBackend, PilotError, PilotJob};
pub use service::PilotComputeService;
pub use state::{CuState, PilotState};
