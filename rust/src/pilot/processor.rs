//! [`StreamProcessor`] — the synchronous, partition-addressed message
//! processing interface processing pilots expose.
//!
//! The mini-app drivers (sim and live) pump broker records through this
//! interface; backends implement it over their platform substrate
//! (Lambda fleet, Dask pool, edge fleet).  Keeping it synchronous and
//! partition-addressed preserves the deterministic DES semantics the
//! simulated-time driver depends on, while provisioning still flows
//! through the one Pilot-API.

/// Modeled cost breakdown of processing one message.
#[derive(Debug, Clone, Copy, Default)]
pub struct ProcessCost {
    /// CPU time of the K-Means step (platform-scaled).
    pub compute: f64,
    /// Model store get + put.
    pub io: f64,
    /// Platform overhead: cold starts, coherency sync, queueing on a
    /// saturated edge device.
    pub overhead: f64,
}

impl ProcessCost {
    pub fn total(&self) -> f64 {
        self.compute + self.io + self.overhead
    }
}

/// Message processing exposed by a processing pilot (see
/// [`PilotBackend::processor`](super::job::PilotBackend::processor)).
pub trait StreamProcessor: Send + Sync {
    /// Short label for traces ("lambda", "dask", "edge").
    fn label(&self) -> &'static str;

    /// Process one message's points on `partition`; returns the modeled
    /// cost breakdown.
    ///
    /// Error convention: *transient admission push-back* (a saturated
    /// substrate that will accept the message shortly) must mention
    /// `"throttled"` or `"concurrency"` in the error text — the live
    /// interval driver retries those within the control interval and
    /// treats every other error as fatal.
    fn process(
        &self,
        partition: usize,
        points: &[f32],
        dim: usize,
        model_key: &str,
        centroids: usize,
    ) -> Result<ProcessCost, String>;
}

/// One K-Means step against a model store: init-if-absent → get model →
/// execute → put model.  Returns `(inertia, compute seconds, io seconds)`.
/// The shared core of the in-process backends (local threads, flink
/// micro-batch); the fleet and Dask substrates carry their own versions
/// with platform cost terms.
pub fn kmeans_step(
    engine: &dyn crate::engine::StepEngine,
    store: &dyn crate::store::ModelStore,
    points: &[f32],
    dim: usize,
    model_key: &str,
    centroids: usize,
) -> Result<(f64, f64, f64), String> {
    if !store.contains(model_key) {
        let init = crate::store::ModelState::new_random(centroids, dim, 42);
        let _ = store.put(model_key, init);
    }
    let (model, io_get) = store.get(model_key).map_err(|e| e.to_string())?;
    let step = engine
        .execute_step(points, dim, &model)
        .map_err(|e| e.to_string())?;
    let (_, io_put) = store
        .put(model_key, step.model)
        .map_err(|e| e.to_string())?;
    Ok((step.inertia, step.cpu_seconds, io_get.seconds + io_put.seconds))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_totals() {
        let c = ProcessCost {
            compute: 0.1,
            io: 0.02,
            overhead: 0.005,
        };
        assert!((c.total() - 0.125).abs() < 1e-12);
        assert_eq!(ProcessCost::default().total(), 0.0);
    }
}
