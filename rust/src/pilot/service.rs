//! `PilotComputeService` — the Pilot-API facade (paper Fig 2's
//! Pilot-Manager), now an **elastic control plane**: one entry point that
//! provisions pilots on any platform a [`PluginRegistry`] knows
//! ([`PilotComputeService::submit_pilot`]), *re*-provisions them live
//! ([`PilotComputeService::resize_pilot`]), and reports their state
//! ([`PilotComputeService::pilot_state`]).  The service contains **no
//! platform-specific code**: it resolves the description's platform to a
//! plugin and delegates; resize semantics and transition costs live with
//! each plugin's backend.

use super::description::PilotDescription;
use super::job::{PilotError, PilotJob, PilotStatus, ResizePlan};
use super::registry::{default_registry, PluginRegistry, ProvisionContext};
use crate::engine::StepEngine;
use crate::sim::{ContentionParams, SharedClock, SharedResource};
use std::sync::{Arc, Mutex};

/// Service-wide context shared by all pilots it creates.
pub struct PilotComputeService {
    clock: SharedClock,
    engine: Arc<dyn StepEngine>,
    /// The shared filesystem of the "HPC machine" this service fronts;
    /// Kafka pilots and Dask pilots created here contend on it together,
    /// mirroring the paper's co-deployment.
    shared_fs: Arc<SharedResource>,
    registry: Arc<PluginRegistry>,
    pilots: Mutex<Vec<PilotJob>>,
}

impl PilotComputeService {
    /// A service over the default (built-in) plugin registry.
    pub fn new(clock: SharedClock, engine: Arc<dyn StepEngine>) -> Self {
        Self {
            clock,
            engine,
            shared_fs: SharedResource::new(
                "lustre",
                ContentionParams::new(
                    super::plugins::hpc::DEFAULT_LUSTRE_ALPHA,
                    super::plugins::hpc::DEFAULT_LUSTRE_BETA,
                ),
            ),
            registry: default_registry(),
            pilots: Mutex::new(Vec::new()),
        }
    }

    /// Swap in a custom plugin registry (third-party platforms, tests).
    pub fn with_registry(mut self, registry: Arc<PluginRegistry>) -> Self {
        self.registry = registry;
        self
    }

    /// Override the shared-FS contention model (ablations; isolated FS).
    pub fn with_shared_fs(mut self, fs: Arc<SharedResource>) -> Self {
        self.shared_fs = fs;
        self
    }

    pub fn shared_fs(&self) -> Arc<SharedResource> {
        Arc::clone(&self.shared_fs)
    }

    pub fn clock(&self) -> SharedClock {
        Arc::clone(&self.clock)
    }

    pub fn registry(&self) -> Arc<PluginRegistry> {
        Arc::clone(&self.registry)
    }

    /// Provision a pilot for `description` (paper: `submit_pilot`): resolve
    /// the plugin, normalize, run generic + plugin validation, provision
    /// the backend.
    pub fn submit_pilot(&self, description: PilotDescription) -> Result<PilotJob, PilotError> {
        let plugin = self
            .registry
            .get(description.platform)
            .ok_or_else(|| PilotError::NoPlugin(description.platform.name().to_string()))?;
        let description = plugin.normalize(description);
        description.validate()?;
        plugin.validate(&description)?;
        let ctx = ProvisionContext {
            engine: Arc::clone(&self.engine),
            clock: Arc::clone(&self.clock),
            shared_fs: Arc::clone(&self.shared_fs),
        };
        let backend = plugin.provision(&description, &ctx)?;
        let job = PilotJob::new(description, backend, Arc::clone(&self.clock));
        self.pilots.lock().unwrap().push(job.clone());
        Ok(job)
    }

    /// All pilots created through this service.
    pub fn pilots(&self) -> Vec<PilotJob> {
        self.pilots.lock().unwrap().clone()
    }

    /// The pilot with `id`, if this service created it.
    pub fn pilot(&self, id: u64) -> Option<PilotJob> {
        self.pilots
            .lock()
            .unwrap()
            .iter()
            .find(|p| p.id == id)
            .cloned()
    }

    /// Live resize (the control-plane verb the autoscaler actuates):
    /// re-provision pilot `id` to `to` units of parallelism with its
    /// platform's transition semantics.  The pilot serves at the old
    /// capacity while `Resizing`; poll [`PilotComputeService::pilot_state`]
    /// for the transition to land.
    pub fn resize_pilot(&self, id: u64, to: usize) -> Result<ResizePlan, PilotError> {
        self.pilot(id).ok_or(PilotError::NoSuchPilot(id))?.resize(to)
    }

    /// Point-in-time status of pilot `id` — the control plane's read side
    /// (finalizes a due resize transition first).
    pub fn pilot_state(&self, id: u64) -> Option<PilotStatus> {
        self.pilot(id).map(|p| p.status())
    }

    /// Cancel everything (teardown).
    pub fn shutdown(&self) {
        for p in self.pilots() {
            p.cancel();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::pilot::compute_unit::TaskSpec;
    use crate::pilot::description::Platform;
    use crate::pilot::job::PilotBackend;
    use crate::pilot::registry::PlatformPlugin;
    use crate::pilot::state::PilotState;
    use crate::sim::WallClock;

    fn service() -> PilotComputeService {
        PilotComputeService::new(
            Arc::new(WallClock::new()),
            Arc::new(CalibratedEngine::new(1)),
        )
    }

    /// A description valid on every built-in platform (memory within the
    /// edge envelope; parallelism within every capacity bound).
    fn universal(platform: Platform) -> PilotDescription {
        PilotDescription::new(platform)
            .with_parallelism(2)
            .with_memory_mb(1024)
    }

    #[test]
    fn submits_pilots_on_every_registered_platform() {
        let svc = service();
        let platforms = svc.registry().platforms();
        assert_eq!(
            platforms.len(),
            7,
            "local/lambda/dask/kinesis/kafka/edge/flink"
        );
        for platform in platforms {
            let job = svc.submit_pilot(universal(platform)).unwrap();
            assert_eq!(job.state(), PilotState::Running, "{platform}");
            assert_eq!(job.platform(), platform);
        }
        assert_eq!(svc.pilots().len(), 7);
        svc.shutdown();
    }

    #[test]
    fn resize_pilot_walks_the_resizing_state_machine() {
        // deterministic transition timing needs a virtual clock
        let clock = Arc::new(crate::sim::SimClock::new());
        let svc = PilotComputeService::new(
            clock.clone() as crate::sim::SharedClock,
            Arc::new(CalibratedEngine::new(1)),
        );
        let job = svc.submit_pilot(universal(Platform::LAMBDA)).unwrap();
        let id = job.id;
        assert_eq!(svc.pilot_state(id).unwrap().parallelism, 2);

        let plan = svc.resize_pilot(id, 6).unwrap();
        assert_eq!((plan.from, plan.to), (2, 6));
        assert!(plan.transition_s > 0.0, "scale-up pays a cold start");
        let st = svc.pilot_state(id).unwrap();
        assert_eq!(st.state, PilotState::Resizing);
        assert_eq!(st.parallelism, 6, "new target visible immediately");
        assert_eq!(st.ready_at, Some(plan.transition_s));

        // a second resize mid-transition is refused, not queued
        assert!(matches!(
            svc.resize_pilot(id, 8),
            Err(PilotError::ResizeInProgress(_))
        ));

        // ... and the pilot still serves while resizing
        let cu = job
            .submit_compute_unit(TaskSpec::KMeansStep {
                points: Arc::new(vec![0.1; 160]),
                dim: 8,
                model_key: "resizing".into(),
                centroids: 8,
            })
            .unwrap();
        assert_eq!(cu.wait(), crate::pilot::state::CuState::Done);

        // the transition lands once the clock passes the deadline
        clock.advance_to(plan.transition_s + 0.001);
        let st = svc.pilot_state(id).unwrap();
        assert_eq!(st.state, PilotState::Running);
        assert_eq!(st.resize_events, 1);
        assert_eq!(st.ready_at, None);

        // serverless scale-down is instant: no Resizing excursion
        let plan = svc.resize_pilot(id, 2).unwrap();
        assert_eq!(plan.transition_s, 0.0);
        assert_eq!(svc.pilot_state(id).unwrap().state, PilotState::Running);
        assert_eq!(svc.pilot_state(id).unwrap().parallelism, 2);

        // unknown pilots are a clean error
        assert!(matches!(
            svc.resize_pilot(9_999_999, 2),
            Err(PilotError::NoSuchPilot(_))
        ));
        job.finish();
        assert!(matches!(
            svc.resize_pilot(id, 4),
            Err(PilotError::NotRunning(PilotState::Done))
        ));
    }

    #[test]
    fn unified_interface_runs_same_workload_everywhere() {
        // the paper's interoperability claim: identical submission code on
        // serverless, HPC, and the edge
        let svc = service();
        for platform in [
            Platform::LOCAL,
            Platform::LAMBDA,
            Platform::DASK,
            Platform::EDGE,
        ] {
            let job = svc.submit_pilot(universal(platform)).unwrap();
            let cu = job
                .submit_compute_unit(TaskSpec::KMeansStep {
                    points: Arc::new(vec![0.1; 160]),
                    dim: 8,
                    model_key: format!("m-{}", platform.name()),
                    centroids: 8,
                })
                .unwrap();
            assert_eq!(cu.wait(), crate::pilot::state::CuState::Done, "{platform}");
            job.finish();
            assert_eq!(job.state(), PilotState::Done);
        }
    }

    #[test]
    fn kafka_and_dask_share_the_filesystem() {
        let svc = service();
        let fs_before = svc.shared_fs();
        let kafka = svc
            .submit_pilot(PilotDescription::new(Platform::KAFKA).with_parallelism(2))
            .unwrap();
        let _broker = kafka.broker().unwrap();
        // the broker's appends enter the same resource the service owns
        assert_eq!(fs_before.current_users(), 0);
        let g = fs_before.enter();
        assert_eq!(fs_before.current_users(), 1);
        drop(g);
    }

    #[test]
    fn submit_to_finished_pilot_fails() {
        let svc = service();
        let job = svc
            .submit_pilot(PilotDescription::new(Platform::LOCAL))
            .unwrap();
        job.finish();
        assert!(matches!(
            job.submit_compute_unit(TaskSpec::Sleep(0.0)),
            Err(PilotError::NotRunning(_))
        ));
    }

    #[test]
    fn unknown_platform_is_a_clean_error() {
        let svc = service();
        let err = svc
            .submit_pilot(PilotDescription::new(Platform::from_static("spark")))
            .unwrap_err();
        assert!(matches!(err, PilotError::NoPlugin(_)), "{err}");
    }

    #[test]
    fn dag_of_dependent_tasks() {
        // "the pilot abstraction can be used to ... compose complex DAGs":
        // stage 2 consumes stage 1 results.
        let svc = service();
        let job = svc
            .submit_pilot(PilotDescription::new(Platform::LOCAL).with_parallelism(4))
            .unwrap();
        let stage1: Vec<_> = (0..4)
            .map(|i| {
                job.submit_compute_unit(TaskSpec::Custom(Box::new(move || Ok(i as f64))))
                    .unwrap()
            })
            .collect();
        let sum: f64 = stage1
            .iter()
            .map(|cu| {
                cu.wait();
                cu.outcome().unwrap().value
            })
            .sum();
        let stage2 = job
            .submit_compute_unit(TaskSpec::Custom(Box::new(move || Ok(sum * 10.0))))
            .unwrap();
        stage2.wait();
        assert_eq!(stage2.outcome().unwrap().value, 60.0);
        job.finish();
    }

    /// The redesign's extensibility proof: a third-party platform becomes
    /// submittable by registering a plugin — zero service edits.  (The
    /// once-hypothetical flink plugin is a builtin now, so the stand-in
    /// third-party platform is storm.)
    struct StormPlugin;

    impl PlatformPlugin for StormPlugin {
        fn platform(&self) -> Platform {
            Platform::from_static("storm")
        }

        fn provision(
            &self,
            description: &PilotDescription,
            ctx: &crate::pilot::registry::ProvisionContext,
        ) -> Result<Arc<dyn PilotBackend>, PilotError> {
            Ok(Arc::new(crate::pilot::plugins::LocalBackend::new(
                description.parallelism,
                Arc::clone(&ctx.engine),
            )))
        }
    }

    #[test]
    fn third_party_plugin_needs_no_service_changes() {
        let mut registry = PluginRegistry::builtin();
        registry.register(Arc::new(StormPlugin)).unwrap();
        let svc = service().with_registry(Arc::new(registry));
        let job = svc
            .submit_pilot(PilotDescription::new(Platform::from_static("storm")))
            .unwrap();
        let cu = job
            .submit_compute_unit(TaskSpec::Custom(Box::new(|| Ok(3.0))))
            .unwrap();
        cu.wait();
        assert_eq!(cu.outcome().unwrap().value, 3.0);
        // a plugin that never opted into elasticity is cleanly rigid
        assert!(matches!(
            job.resize(8),
            Err(PilotError::ResizeUnsupported("storm"))
        ));
        job.finish();
    }
}
