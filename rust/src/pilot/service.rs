//! `PilotComputeService` — the Pilot-API facade (paper Fig 2's
//! Pilot-Manager): one entry point that provisions pilots on any supported
//! platform from a [`PilotDescription`] and hands back [`PilotJob`]s.

use super::description::{PilotDescription, Platform};
use super::job::{PilotError, PilotJob};
use super::plugins::{
    HpcBackend, KafkaBrokerBackend, KinesisBrokerBackend, LocalBackend, ServerlessBackend,
};
use crate::engine::StepEngine;
use crate::sim::{ContentionParams, SharedClock, SharedResource};
use std::sync::{Arc, Mutex};

/// Service-wide context shared by all pilots it creates.
pub struct PilotComputeService {
    clock: SharedClock,
    engine: Arc<dyn StepEngine>,
    /// The shared filesystem of the "HPC machine" this service fronts;
    /// Kafka pilots and Dask pilots created here contend on it together,
    /// mirroring the paper's co-deployment.
    shared_fs: Arc<SharedResource>,
    pilots: Mutex<Vec<PilotJob>>,
}

impl PilotComputeService {
    pub fn new(clock: SharedClock, engine: Arc<dyn StepEngine>) -> Self {
        Self {
            clock,
            engine,
            shared_fs: SharedResource::new(
                "lustre",
                ContentionParams::new(
                    super::plugins::hpc::DEFAULT_LUSTRE_ALPHA,
                    super::plugins::hpc::DEFAULT_LUSTRE_BETA,
                ),
            ),
            pilots: Mutex::new(Vec::new()),
        }
    }

    /// Override the shared-FS contention model (ablations; isolated FS).
    pub fn with_shared_fs(mut self, fs: Arc<SharedResource>) -> Self {
        self.shared_fs = fs;
        self
    }

    pub fn shared_fs(&self) -> Arc<SharedResource> {
        Arc::clone(&self.shared_fs)
    }

    pub fn clock(&self) -> SharedClock {
        Arc::clone(&self.clock)
    }

    /// Provision a pilot for `description` (paper: `submit_pilot`).
    pub fn submit_pilot(&self, description: PilotDescription) -> Result<PilotJob, PilotError> {
        description.validate()?;
        let backend: Arc<dyn super::job::PilotBackend> = match description.platform {
            Platform::Local => Arc::new(LocalBackend::new(
                description.parallelism,
                Arc::clone(&self.engine),
            )),
            Platform::Lambda => Arc::new(ServerlessBackend::provision(
                &description,
                Arc::clone(&self.engine),
                Arc::clone(&self.clock),
            )?),
            Platform::Dask => Arc::new(HpcBackend::provision(
                &description,
                Arc::clone(&self.engine),
                Some(Arc::clone(&self.shared_fs)),
            )?),
            Platform::Kinesis => Arc::new(KinesisBrokerBackend::provision(
                &description,
                Arc::clone(&self.clock),
            )?),
            Platform::Kafka => Arc::new(KafkaBrokerBackend::provision(
                &description,
                Arc::clone(&self.clock),
                Arc::clone(&self.shared_fs),
            )?),
        };
        let job = PilotJob::new(description, backend);
        self.pilots.lock().unwrap().push(job.clone());
        Ok(job)
    }

    /// All pilots created through this service.
    pub fn pilots(&self) -> Vec<PilotJob> {
        self.pilots.lock().unwrap().clone()
    }

    /// Cancel everything (teardown).
    pub fn shutdown(&self) {
        for p in self.pilots() {
            p.cancel();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CalibratedEngine;
    use crate::pilot::compute_unit::TaskSpec;
    use crate::pilot::state::PilotState;
    use crate::sim::WallClock;

    fn service() -> PilotComputeService {
        PilotComputeService::new(
            Arc::new(WallClock::new()),
            Arc::new(CalibratedEngine::new(1)),
        )
    }

    #[test]
    fn submits_pilots_on_every_platform() {
        let svc = service();
        for platform in [
            Platform::Local,
            Platform::Lambda,
            Platform::Dask,
            Platform::Kinesis,
            Platform::Kafka,
        ] {
            let job = svc
                .submit_pilot(PilotDescription::new(platform).with_parallelism(2))
                .unwrap();
            assert_eq!(job.state(), PilotState::Running, "{platform:?}");
            assert_eq!(job.platform(), platform);
        }
        assert_eq!(svc.pilots().len(), 5);
        svc.shutdown();
    }

    #[test]
    fn unified_interface_runs_same_workload_everywhere() {
        // the paper's interoperability claim: identical submission code on
        // serverless and HPC
        let svc = service();
        for platform in [Platform::Local, Platform::Lambda, Platform::Dask] {
            let job = svc
                .submit_pilot(PilotDescription::new(platform).with_parallelism(2))
                .unwrap();
            let cu = job
                .submit_compute_unit(TaskSpec::KMeansStep {
                    points: Arc::new(vec![0.1; 160]),
                    dim: 8,
                    model_key: format!("m-{}", platform.name()),
                    centroids: 8,
                })
                .unwrap();
            assert_eq!(cu.wait(), crate::pilot::state::CuState::Done, "{platform:?}");
            job.finish();
            assert_eq!(job.state(), PilotState::Done);
        }
    }

    #[test]
    fn kafka_and_dask_share_the_filesystem() {
        let svc = service();
        let fs_before = svc.shared_fs();
        let kafka = svc
            .submit_pilot(PilotDescription::new(Platform::Kafka).with_parallelism(2))
            .unwrap();
        let _broker = kafka.broker().unwrap();
        // the broker's appends enter the same resource the service owns
        assert_eq!(fs_before.current_users(), 0);
        let g = fs_before.enter();
        assert_eq!(fs_before.current_users(), 1);
        drop(g);
    }

    #[test]
    fn submit_to_finished_pilot_fails() {
        let svc = service();
        let job = svc
            .submit_pilot(PilotDescription::new(Platform::Local))
            .unwrap();
        job.finish();
        assert!(matches!(
            job.submit_compute_unit(TaskSpec::Sleep(0.0)),
            Err(PilotError::NotRunning(_))
        ));
    }

    #[test]
    fn dag_of_dependent_tasks() {
        // "the pilot abstraction can be used to ... compose complex DAGs":
        // stage 2 consumes stage 1 results.
        let svc = service();
        let job = svc
            .submit_pilot(PilotDescription::new(Platform::Local).with_parallelism(4))
            .unwrap();
        let stage1: Vec<_> = (0..4)
            .map(|i| {
                job.submit_compute_unit(TaskSpec::Custom(Box::new(move || Ok(i as f64))))
                    .unwrap()
            })
            .collect();
        let sum: f64 = stage1
            .iter()
            .map(|cu| {
                cu.wait();
                cu.outcome().unwrap().value
            })
            .sum();
        let stage2 = job
            .submit_compute_unit(TaskSpec::Custom(Box::new(move || Ok(sum * 10.0))))
            .unwrap();
        stage2.wait();
        assert_eq!(stage2.outcome().unwrap().value, 60.0);
        job.finish();
    }
}
