//! The platform plugin registry (paper Fig 2's plugin architecture, made
//! real): `PilotComputeService` resolves a [`PlatformPlugin`] by the
//! description's platform name instead of matching on an enum, so adding a
//! platform — cloud, HPC, or edge — is *only* a plugin registration, with
//! zero edits to the service or the drivers.
//!
//! A plugin owns four things for its platform:
//!
//! 1. **Naming/parsing** — the canonical [`Platform`] name plus aliases
//!    ([`PluginRegistry::parse`] consults the plugins, nobody else).
//! 2. **Description validation** — platform-specific constraints
//!    (Lambda's memory range, Dask's machine capacity, the edge device
//!    envelope) via [`PlatformPlugin::validate`].
//! 3. **Provisioning** — building the [`PilotBackend`] from a validated
//!    [`PilotDescription`] and the service's [`ProvisionContext`].
//! 4. **Elasticity** — the platform's live-resize semantics
//!    ([`PlatformPlugin::elasticity`]): whether pilots can change
//!    parallelism after provisioning, what one unit of scale-up/-down
//!    costs in transition time, and any hard capacity cap.  Backends
//!    realize the descriptor through
//!    [`PilotBackend::resize`](super::job::PilotBackend::resize).

use super::description::{DescriptionError, PilotDescription, Platform};
use super::job::{PilotBackend, PilotError};
use crate::engine::StepEngine;
use crate::sim::{SharedClock, SharedResource};
use std::sync::{Arc, OnceLock};

/// Service-owned resources a plugin may wire into its backend.
pub struct ProvisionContext {
    /// The step engine executing K-Means workloads (calibrated sim or PJRT).
    pub engine: Arc<dyn StepEngine>,
    /// The service's clock (simulated or wall time).
    pub clock: SharedClock,
    /// The shared filesystem of the "HPC machine" the service fronts;
    /// plugins that co-deploy on it (Kafka, Dask) contend here together.
    pub shared_fs: Arc<SharedResource>,
}

/// A platform's declared billing model: what one unit of parallelism
/// costs per hour of run time, and what a scale-up transition costs on
/// top.  Like [`Elasticity`]'s transition times these are *per-unit*
/// planning constants for the decision layer
/// ([`Objective`](crate::insight::Objective) weighs them against a
/// re-fit's scale-up recommendation before committing); they are not a
/// billing simulation.  Scale-*downs* are free on every modeled platform
/// (serverless containers just stop billing, HPC drains inside the
/// existing allocation, broker shard merges are control-plane-only), so
/// [`PriceModel::transition_dollars`] charges upward moves only.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PriceModel {
    /// Dollars per hour for one unit of parallelism kept running
    /// (one container, worker, shard, slot, or site).
    pub unit_dollars_per_hour: f64,
    /// One-time dollars to bring one additional unit online (billed
    /// cold-start init, allocation billing quantum, shard split).
    pub transition_dollars_per_unit: f64,
    /// The platform's native billing unit, for reports ("GB-s",
    /// "node-hour", "shard-hour", ...).
    pub billing_unit: &'static str,
}

impl PriceModel {
    /// An unpriced platform: every dollar figure is zero.  This is the
    /// *default* a plugin gets for free — the conformance suite insists
    /// every registered plugin overrides it with a real model.
    pub const fn free() -> Self {
        Self {
            unit_dollars_per_hour: 0.0,
            transition_dollars_per_unit: 0.0,
            billing_unit: "unpriced",
        }
    }

    /// A model billing `dollars` per unit-hour in the platform's native
    /// `unit`, with free transitions (compose with
    /// [`PriceModel::with_transition`]).
    pub const fn per_unit_hour(dollars: f64, unit: &'static str) -> Self {
        Self {
            unit_dollars_per_hour: dollars,
            transition_dollars_per_unit: 0.0,
            billing_unit: unit,
        }
    }

    /// Attach a one-time per-unit scale-up charge.
    pub const fn with_transition(mut self, dollars: f64) -> Self {
        self.transition_dollars_per_unit = dollars;
        self
    }

    /// Whether this is a real (non-default) price model.
    pub fn is_priced(&self) -> bool {
        self.unit_dollars_per_hour > 0.0
    }

    /// Run-rate in dollars per hour at `parallelism` units.
    pub fn run_rate_dollars_per_hour(&self, parallelism: usize) -> f64 {
        self.unit_dollars_per_hour * parallelism as f64
    }

    /// Dollars accrued keeping `parallelism` units up for `dt_s` seconds.
    pub fn interval_dollars(&self, parallelism: usize, dt_s: f64) -> f64 {
        self.run_rate_dollars_per_hour(parallelism) * (dt_s / 3600.0)
    }

    /// One-time dollars for the transition `from -> to`.  Only scale-up
    /// units are charged (see the type-level note on free scale-downs).
    pub fn transition_dollars(&self, from: usize, to: usize) -> f64 {
        self.transition_dollars_per_unit * to.saturating_sub(from) as f64
    }
}

impl Default for PriceModel {
    fn default() -> Self {
        Self::free()
    }
}

/// A platform's declared elasticity: how (and whether) a live pilot's
/// parallelism can change, and what the transition costs — in seconds
/// ([`Elasticity::scale_up_s`]) *and* in dollars ([`Elasticity::price`]).
/// The numbers are *per-unit* planning hints for the control layer; the
/// backend's [`PilotBackend::resize`](super::job::PilotBackend::resize)
/// commits the actual [`ResizePlan`](super::job::ResizePlan).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Elasticity {
    /// Whether live pilots of this platform support `resize` at all.
    pub resizable: bool,
    /// Seconds to bring one additional unit of parallelism online
    /// (container cold start, worker boot, shard split).
    pub scale_up_s: f64,
    /// Seconds to retire one unit (drain, merge); 0 = instant.
    pub scale_down_s: f64,
    /// Hard platform cap on parallelism (device envelope); `None` means
    /// unbounded as far as the platform is concerned.
    pub max_parallelism: Option<usize>,
    /// The platform's billing model, consumed by cost-aware objectives.
    pub price: PriceModel,
}

impl Elasticity {
    /// A platform whose pilots cannot change size after provisioning.
    pub fn rigid() -> Self {
        Self {
            resizable: false,
            scale_up_s: f64::INFINITY,
            scale_down_s: f64::INFINITY,
            max_parallelism: None,
            price: PriceModel::free(),
        }
    }

    /// A resizable platform with the given per-unit transition costs.
    pub fn elastic(scale_up_s: f64, scale_down_s: f64) -> Self {
        Self {
            resizable: true,
            scale_up_s,
            scale_down_s,
            max_parallelism: None,
            price: PriceModel::free(),
        }
    }

    /// Attach a hard capacity cap (e.g. the edge device's container
    /// count).
    pub fn with_cap(mut self, cap: usize) -> Self {
        self.max_parallelism = Some(cap);
        self
    }

    /// Attach the platform's billing model (builder leg; every built-in
    /// plugin declares one — enforced by `plugin_conformance`).
    pub fn with_price(mut self, price: PriceModel) -> Self {
        self.price = price;
        self
    }
}

/// One platform's provisioning plugin.
pub trait PlatformPlugin: Send + Sync {
    /// The canonical platform identifier this plugin registers.
    fn platform(&self) -> Platform;

    /// Additional names `parse` accepts for this platform.
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// Pilots of this platform expose a [`Broker`](crate::broker::Broker).
    fn provisions_broker(&self) -> bool {
        false
    }

    /// Pilots of this platform execute compute units.
    fn accepts_compute(&self) -> bool {
        true
    }

    /// Pilots of this platform expose a
    /// [`StreamProcessor`](super::processor::StreamProcessor) — i.e. they
    /// can anchor a mini-app scenario as its processing stage.  The
    /// mini-app's platform naming treats the registry as the single source
    /// of truth, so any plugin returning `true` here is immediately
    /// addressable from scenarios, sweeps, and TOML configs.
    fn streams(&self) -> bool {
        self.accepts_compute()
    }

    /// The platform's live-resize semantics.  Defaults to rigid; elastic
    /// platforms override with their transition-cost descriptor.
    fn elasticity(&self) -> Elasticity {
        Elasticity::rigid()
    }

    /// Platform-appropriate normalization, applied by the service (and by
    /// [`PluginRegistry::validate`]) *before* validation.  The default is
    /// identity; the edge plugin, for example, clamps container memory
    /// into its device envelope so the description shape every other
    /// platform accepts (cloud defaults included) provisions cleanly —
    /// mirroring how `EdgeSite::admit` clamps concurrency.
    fn normalize(&self, description: PilotDescription) -> PilotDescription {
        description
    }

    /// Platform-specific description constraints (the generic invariants
    /// are [`PilotDescription::validate`]'s job).  Runs on the
    /// [`PlatformPlugin::normalize`]d description.
    fn validate(&self, _description: &PilotDescription) -> Result<(), DescriptionError> {
        Ok(())
    }

    /// Provision a backend for a description.
    ///
    /// Contract: the service runs [`PilotDescription::validate`] and this
    /// plugin's [`PlatformPlugin::validate`] *before* calling `provision`,
    /// so implementations may assume a validated description and must not
    /// re-validate.  Callers invoking a plugin directly (tests, tools)
    /// are responsible for running `validate` first — though backends
    /// still fail closed on substrate-level constraint violations.
    fn provision(
        &self,
        description: &PilotDescription,
        ctx: &ProvisionContext,
    ) -> Result<Arc<dyn PilotBackend>, PilotError>;
}

#[derive(Debug, thiserror::Error)]
pub enum RegistryError {
    #[error("platform {platform:?} conflicts with registered plugin {with:?}")]
    Conflict { platform: String, with: String },
}

/// An ordered set of plugins; registration order is the iteration order.
#[derive(Default)]
pub struct PluginRegistry {
    plugins: Vec<Arc<dyn PlatformPlugin>>,
    /// Lazily built name index: lowercase name/alias → (plugin position,
    /// is-canonical).  `parse`/`get` run on every scenario build and every
    /// `PlatformKind::parse`, so the linear alias scan is hoisted into a
    /// process-lifetime cache (per registry; `register` invalidates it).
    /// BTreeMap keeps iteration deterministic (ps-lint R2).
    index: OnceLock<std::collections::BTreeMap<String, (usize, bool)>>,
}

impl PluginRegistry {
    /// A registry with no plugins (compose your own platform set).
    pub fn empty() -> Self {
        Self::default()
    }

    /// All built-in plugins: local, lambda, dask, kinesis, kafka, edge,
    /// flink.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        let builtins: Vec<Arc<dyn PlatformPlugin>> = vec![
            Arc::new(super::plugins::LocalPlugin),
            Arc::new(super::plugins::ServerlessPlugin),
            Arc::new(super::plugins::HpcPlugin),
            Arc::new(super::plugins::KinesisPlugin),
            Arc::new(super::plugins::KafkaPlugin),
            Arc::new(super::plugins::EdgePlugin),
            Arc::new(super::plugins::FlinkPlugin),
        ];
        for p in builtins {
            r.register(p).expect("builtin plugins have unique names");
        }
        r
    }

    /// Register a plugin; every name and alias must be new.
    pub fn register(&mut self, plugin: Arc<dyn PlatformPlugin>) -> Result<(), RegistryError> {
        let mut names: Vec<&'static str> = vec![plugin.platform().name()];
        names.extend_from_slice(plugin.aliases());
        for existing in &self.plugins {
            let mut taken: Vec<&'static str> = vec![existing.platform().name()];
            taken.extend_from_slice(existing.aliases());
            if names
                .iter()
                .any(|n| taken.iter().any(|t| t.eq_ignore_ascii_case(n)))
            {
                return Err(RegistryError::Conflict {
                    platform: plugin.platform().name().to_string(),
                    with: existing.platform().name().to_string(),
                });
            }
        }
        self.plugins.push(plugin);
        self.index.take(); // rebuilt lazily with the new plugin included
        Ok(())
    }

    fn index(&self) -> &std::collections::BTreeMap<String, (usize, bool)> {
        self.index.get_or_init(|| {
            let mut m = std::collections::BTreeMap::new();
            for (i, p) in self.plugins.iter().enumerate() {
                // register guarantees names and aliases are globally
                // unique (case-insensitively), so inserts never collide
                m.insert(p.platform().name().to_ascii_lowercase(), (i, true));
                for a in p.aliases() {
                    m.insert(a.to_ascii_lowercase(), (i, false));
                }
            }
            m
        })
    }

    /// The plugin registered for `platform`.  Matching is by canonical
    /// name, case-insensitively — the same identity rule `register` and
    /// `parse` use, so every lookup path agrees on what a platform is.
    pub fn get(&self, platform: Platform) -> Option<Arc<dyn PlatformPlugin>> {
        match self.index().get(&platform.name().to_ascii_lowercase()) {
            Some(&(i, true)) => Some(Arc::clone(&self.plugins[i])),
            _ => None,
        }
    }

    /// Resolve a user-supplied name or alias (case-insensitive).
    pub fn parse(&self, s: &str) -> Option<Platform> {
        self.index()
            .get(&s.to_ascii_lowercase())
            .map(|&(i, _)| self.plugins[i].platform())
    }

    /// Registered platforms, in registration order.
    pub fn platforms(&self) -> Vec<Platform> {
        self.plugins.iter().map(|p| p.platform()).collect()
    }

    pub fn len(&self) -> usize {
        self.plugins.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plugins.is_empty()
    }

    /// Full description validation: the generic invariants plus the
    /// owning plugin's platform-specific checks, applied to the plugin's
    /// normalized form of the description (what the service provisions).
    pub fn validate(&self, description: &PilotDescription) -> Result<(), DescriptionError> {
        description.validate()?;
        let plugin = self.get(description.platform).ok_or_else(|| {
            DescriptionError::UnknownPlatform(description.platform.name().to_string())
        })?;
        plugin.validate(&plugin.normalize(description.clone()))
    }
}

/// The process-wide registry of built-in plugins.  Services use it unless
/// given a custom registry via
/// [`PilotComputeService::with_registry`](super::service::PilotComputeService::with_registry).
pub fn default_registry() -> Arc<PluginRegistry> {
    static DEFAULT: OnceLock<Arc<PluginRegistry>> = OnceLock::new();
    Arc::clone(DEFAULT.get_or_init(|| Arc::new(PluginRegistry::builtin())))
}

#[cfg(test)]
mod tests {
    use super::*;

    struct FakePlugin(&'static str, &'static [&'static str]);

    impl PlatformPlugin for FakePlugin {
        fn platform(&self) -> Platform {
            Platform::from_static(self.0)
        }

        fn aliases(&self) -> &'static [&'static str] {
            self.1
        }

        fn provision(
            &self,
            description: &PilotDescription,
            ctx: &ProvisionContext,
        ) -> Result<Arc<dyn PilotBackend>, PilotError> {
            Ok(Arc::new(super::super::plugins::LocalBackend::new(
                description.parallelism,
                Arc::clone(&ctx.engine),
            )))
        }
    }

    #[test]
    fn builtin_registry_has_all_platforms() {
        let r = PluginRegistry::builtin();
        assert_eq!(r.len(), 7);
        for p in [
            Platform::LOCAL,
            Platform::LAMBDA,
            Platform::DASK,
            Platform::KINESIS,
            Platform::KAFKA,
            Platform::EDGE,
            Platform::FLINK,
        ] {
            assert!(r.get(p).is_some(), "{p} missing");
            assert_eq!(r.parse(p.name()), Some(p));
        }
        assert!(!r.is_empty());
    }

    #[test]
    fn parse_accepts_aliases_case_insensitively() {
        let r = PluginRegistry::builtin();
        assert_eq!(r.parse("SERVERLESS"), Some(Platform::LAMBDA));
        assert_eq!(r.parse("greengrass"), Some(Platform::EDGE));
        assert_eq!(r.parse("hpc"), Some(Platform::DASK));
        assert_eq!(r.parse("microbatch"), Some(Platform::FLINK));
        assert_eq!(r.parse("heron"), None);
    }

    #[test]
    fn builtin_elasticity_declared_per_platform() {
        let r = PluginRegistry::builtin();
        // every built-in platform is elastic...
        for p in r.platforms() {
            let e = r.get(p).unwrap().elasticity();
            assert!(e.resizable, "{p} must declare elasticity");
        }
        // ...with platform-true shapes: serverless down-scales instantly,
        // HPC pays a drain, the edge declares its device cap
        assert_eq!(
            r.get(Platform::LAMBDA).unwrap().elasticity().scale_down_s,
            0.0
        );
        assert!(r.get(Platform::DASK).unwrap().elasticity().scale_down_s > 0.0);
        assert_eq!(
            r.get(Platform::EDGE).unwrap().elasticity().max_parallelism,
            Some(crate::serverless::edge::EDGE_MAX_CONCURRENCY)
        );
        // a plugin that doesn't opt in stays rigid
        assert!(!FakePlugin("rigid", &[]).elasticity().resizable);
    }

    #[test]
    fn price_model_arithmetic_and_builder() {
        let p = PriceModel::per_unit_hour(0.10, "worker-hour").with_transition(0.02);
        assert!(p.is_priced());
        assert!((p.run_rate_dollars_per_hour(4) - 0.40).abs() < 1e-12);
        assert!((p.interval_dollars(4, 1800.0) - 0.20).abs() < 1e-12);
        assert!((p.transition_dollars(2, 5) - 0.06).abs() < 1e-12);
        // scale-downs are free on every modeled platform
        assert_eq!(p.transition_dollars(5, 2), 0.0);
        assert!(!PriceModel::free().is_priced());
        assert_eq!(PriceModel::default(), PriceModel::free());
        // builder legs compose and rigid/elastic start unpriced
        assert_eq!(Elasticity::rigid().price, PriceModel::free());
        let e = Elasticity::elastic(1.0, 0.0).with_cap(8).with_price(p);
        assert_eq!(e.price, p);
        assert_eq!(e.max_parallelism, Some(8));
        assert!(e.resizable);
    }

    #[test]
    fn get_uses_the_same_identity_rule_as_parse() {
        // a Platform differing only in case still resolves its plugin, so
        // parse/register/get never disagree about platform identity
        let r = PluginRegistry::builtin();
        assert!(r.get(Platform::from_static("LAMBDA")).is_some());
        assert!(r.get(Platform::from_static("Edge")).is_some());
        assert!(r.get(Platform::from_static("spark")).is_none());
    }

    #[test]
    fn duplicate_names_and_aliases_rejected() {
        let mut r = PluginRegistry::builtin();
        assert!(matches!(
            r.register(Arc::new(FakePlugin("lambda", &[]))),
            Err(RegistryError::Conflict { .. })
        ));
        // alias colliding with a registered canonical name
        assert!(r
            .register(Arc::new(FakePlugin("mybroker", &["kafka"])))
            .is_err());
        // fresh names are fine
        assert!(r.register(Arc::new(FakePlugin("samza", &["beam"]))).is_ok());
        assert_eq!(r.parse("beam"), Some(Platform::from_static("samza")));
    }

    #[test]
    fn name_index_rebuilds_after_late_registration() {
        let mut r = PluginRegistry::builtin();
        // force the lazy index to materialize...
        assert_eq!(r.parse("lambda"), Some(Platform::LAMBDA));
        // ...then register a new plugin: the cache must not go stale
        r.register(Arc::new(FakePlugin("samza", &["beam"]))).unwrap();
        assert_eq!(r.parse("beam"), Some(Platform::from_static("samza")));
        assert!(r.get(Platform::from_static("samza")).is_some());
        // aliases never resolve through `get` (canonical names only)
        assert!(r.get(Platform::from_static("beam")).is_none());
    }

    #[test]
    fn validate_requires_a_plugin() {
        let r = PluginRegistry::builtin();
        let d = PilotDescription::new(Platform::from_static("nonesuch"));
        assert!(matches!(
            r.validate(&d),
            Err(DescriptionError::UnknownPlatform(_))
        ));
    }

    #[test]
    fn empty_registry_knows_nothing() {
        let r = PluginRegistry::empty();
        assert!(r.is_empty());
        assert_eq!(r.parse("lambda"), None);
        assert!(r.get(Platform::LAMBDA).is_none());
    }
}
