//! `PilotDescription` — the normative, platform-agnostic resource spec.
//!
//! The paper: "the user needs to create a Pilot-Description, which provides
//! a normative way to specify resources for a streaming broker, e.g., the
//! number of topic shards for Kinesis and Kafka can be specified using the
//! same attribute" — and likewise parallelism/memory for the processing
//! platform, "while allowing the support for infrastructure-specific
//! capabilities, such as layers or memory limits on Lambda."

use crate::util::json::Json;

/// Target platform for a pilot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Platform {
    /// Kinesis-like broker (serverless).
    Kinesis,
    /// Kafka-like broker (HPC / cloud nodes).
    Kafka,
    /// Lambda-like FaaS processing.
    Lambda,
    /// Dask-like processing on HPC nodes.
    Dask,
    /// In-process thread pool (testing, bag-of-tasks).
    Local,
}

impl Platform {
    pub fn parse(s: &str) -> Option<Platform> {
        match s.to_ascii_lowercase().as_str() {
            "kinesis" => Some(Self::Kinesis),
            "kafka" => Some(Self::Kafka),
            "lambda" => Some(Self::Lambda),
            "dask" => Some(Self::Dask),
            "local" => Some(Self::Local),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Kinesis => "kinesis",
            Self::Kafka => "kafka",
            Self::Lambda => "lambda",
            Self::Dask => "dask",
            Self::Local => "local",
        }
    }

    pub fn is_broker(self) -> bool {
        matches!(self, Self::Kinesis | Self::Kafka)
    }
}

/// HPC machine selection for Dask pilots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MachineKind {
    Wrangler,
    Stampede2,
}

impl MachineKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "wrangler" => Some(Self::Wrangler),
            "stampede2" | "stampede2-knl" => Some(Self::Stampede2),
            _ => None,
        }
    }

    pub fn machine(self, max_nodes: usize) -> crate::hpc::Machine {
        match self {
            Self::Wrangler => crate::hpc::Machine::wrangler(max_nodes),
            Self::Stampede2 => crate::hpc::Machine::stampede2(max_nodes),
        }
    }
}

/// The normative resource description.
#[derive(Debug, Clone)]
pub struct PilotDescription {
    pub platform: Platform,
    /// Broker: number of shards/partitions. Processing: parallelism
    /// (one concurrent container / worker per unit) — the paper's single
    /// unified attribute.
    pub parallelism: usize,
    /// Processing memory per container/worker, MB (Lambda-specific knob).
    pub memory_mb: u32,
    /// Walltime limit, seconds.
    pub walltime_s: f64,
    /// HPC machine (Dask only).
    pub machine: MachineKind,
    /// Max nodes the HPC allocation may use.
    pub max_nodes: usize,
    /// Records per invocation batch (event-source mapping).
    pub batch_size: usize,
    /// Deployment package size, MB (Lambda cold starts).
    pub package_mb: f64,
    /// RNG seed for everything this pilot provisions.
    pub seed: u64,
}

impl Default for PilotDescription {
    fn default() -> Self {
        Self {
            platform: Platform::Local,
            parallelism: 4,
            memory_mb: 3008,
            walltime_s: 900.0,
            machine: MachineKind::Wrangler,
            max_nodes: 16,
            batch_size: 1,
            package_mb: 50.0,
            seed: 42,
        }
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum DescriptionError {
    #[error("invalid {field}: {reason}")]
    Invalid {
        field: &'static str,
        reason: String,
    },
    #[error("unknown platform {0:?}")]
    UnknownPlatform(String),
}

impl PilotDescription {
    pub fn new(platform: Platform) -> Self {
        Self {
            platform,
            ..Default::default()
        }
    }

    pub fn with_parallelism(mut self, p: usize) -> Self {
        self.parallelism = p;
        self
    }

    pub fn with_memory_mb(mut self, m: u32) -> Self {
        self.memory_mb = m;
        self
    }

    pub fn with_machine(mut self, m: MachineKind) -> Self {
        self.machine = m;
        self
    }

    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn validate(&self) -> Result<(), DescriptionError> {
        let inv = |field: &'static str, reason: String| DescriptionError::Invalid { field, reason };
        if self.parallelism == 0 {
            return Err(inv("parallelism", "must be > 0".into()));
        }
        if self.platform == Platform::Lambda {
            if !(crate::serverless::MIN_MEMORY_MB..=crate::serverless::MAX_MEMORY_MB)
                .contains(&self.memory_mb)
            {
                return Err(inv(
                    "memory_mb",
                    format!(
                        "{} outside Lambda range [{}, {}]",
                        self.memory_mb,
                        crate::serverless::MIN_MEMORY_MB,
                        crate::serverless::MAX_MEMORY_MB
                    ),
                ));
            }
            if self.walltime_s > crate::serverless::MAX_WALLTIME_S {
                return Err(inv(
                    "walltime_s",
                    format!("{} exceeds Lambda 15-minute cap", self.walltime_s),
                ));
            }
        }
        if self.platform == Platform::Dask {
            let machine = self.machine.machine(self.max_nodes);
            if self.parallelism > machine.max_workers() {
                return Err(inv(
                    "parallelism",
                    format!(
                        "{} workers exceed {} ({} nodes x {}/node)",
                        self.parallelism,
                        machine.max_workers(),
                        self.max_nodes,
                        machine.workers_per_node
                    ),
                ));
            }
        }
        if self.batch_size == 0 {
            return Err(inv("batch_size", "must be > 0".into()));
        }
        Ok(())
    }

    /// Parse from a config JSON/TOML object (see `util::tomlmini`).
    pub fn from_json(v: &Json) -> Result<Self, DescriptionError> {
        let mut d = PilotDescription::default();
        if let Some(p) = v.get("platform").as_str() {
            d.platform = Platform::parse(p)
                .ok_or_else(|| DescriptionError::UnknownPlatform(p.to_string()))?;
        }
        if let Some(x) = v.get("parallelism").as_usize() {
            d.parallelism = x;
        }
        if let Some(x) = v.get("memory_mb").as_usize() {
            d.memory_mb = x as u32;
        }
        if let Some(x) = v.get("walltime_s").as_f64() {
            d.walltime_s = x;
        }
        if let Some(m) = v.get("machine").as_str() {
            d.machine = MachineKind::parse(m).ok_or_else(|| DescriptionError::Invalid {
                field: "machine",
                reason: format!("unknown machine {m:?}"),
            })?;
        }
        if let Some(x) = v.get("max_nodes").as_usize() {
            d.max_nodes = x;
        }
        if let Some(x) = v.get("batch_size").as_usize() {
            d.batch_size = x;
        }
        if let Some(x) = v.get("package_mb").as_f64() {
            d.package_mb = x;
        }
        if let Some(x) = v.get("seed").as_i64() {
            d.seed = x as u64;
        }
        d.validate()?;
        Ok(d)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("platform", Json::from(self.platform.name())),
            ("parallelism", Json::from(self.parallelism)),
            ("memory_mb", Json::from(self.memory_mb as usize)),
            ("walltime_s", Json::from(self.walltime_s)),
            ("max_nodes", Json::from(self.max_nodes)),
            ("batch_size", Json::from(self.batch_size)),
            ("seed", Json::from(self.seed as i64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn platform_parse_roundtrip() {
        for p in [
            Platform::Kinesis,
            Platform::Kafka,
            Platform::Lambda,
            Platform::Dask,
            Platform::Local,
        ] {
            assert_eq!(Platform::parse(p.name()), Some(p));
        }
        assert_eq!(Platform::parse("spark"), None);
        assert!(Platform::Kinesis.is_broker());
        assert!(!Platform::Lambda.is_broker());
    }

    #[test]
    fn lambda_constraints() {
        let mut d = PilotDescription::new(Platform::Lambda);
        assert!(d.validate().is_ok());
        d.memory_mb = 64;
        assert!(d.validate().is_err());
        d.memory_mb = 1024;
        d.walltime_s = 2000.0;
        assert!(d.validate().is_err());
    }

    #[test]
    fn dask_capacity_constraint() {
        let mut d = PilotDescription::new(Platform::Dask);
        d.max_nodes = 1; // 12 workers max
        d.parallelism = 12;
        assert!(d.validate().is_ok());
        d.parallelism = 13;
        assert!(d.validate().is_err());
    }

    #[test]
    fn same_attribute_for_both_brokers() {
        // the paper's normative claim: one attribute, two brokers
        let k = PilotDescription::new(Platform::Kinesis).with_parallelism(8);
        let q = PilotDescription::new(Platform::Kafka).with_parallelism(8);
        assert_eq!(k.parallelism, q.parallelism);
        assert!(k.validate().is_ok() && q.validate().is_ok());
    }

    #[test]
    fn from_json() {
        let v = crate::util::json::parse(
            r#"{"platform": "lambda", "parallelism": 16, "memory_mb": 1792,
                "batch_size": 2, "seed": 7}"#,
        )
        .unwrap();
        let d = PilotDescription::from_json(&v).unwrap();
        assert_eq!(d.platform, Platform::Lambda);
        assert_eq!(d.parallelism, 16);
        assert_eq!(d.memory_mb, 1792);
        assert_eq!(d.batch_size, 2);
        assert_eq!(d.seed, 7);
    }

    #[test]
    fn from_json_rejects_bad() {
        let v = crate::util::json::parse(r#"{"platform": "spark"}"#).unwrap();
        assert!(matches!(
            PilotDescription::from_json(&v),
            Err(DescriptionError::UnknownPlatform(_))
        ));
        let v = crate::util::json::parse(r#"{"platform": "lambda", "memory_mb": 9999}"#).unwrap();
        assert!(PilotDescription::from_json(&v).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let d = PilotDescription::new(Platform::Dask).with_parallelism(24);
        let j = d.to_json();
        let d2 = PilotDescription::from_json(&j).unwrap();
        assert_eq!(d2.platform, Platform::Dask);
        assert_eq!(d2.parallelism, 24);
    }
}
