//! `PilotDescription` — the normative, platform-agnostic resource spec.
//!
//! The paper: "the user needs to create a Pilot-Description, which provides
//! a normative way to specify resources for a streaming broker, e.g., the
//! number of topic shards for Kinesis and Kafka can be specified using the
//! same attribute" — and likewise parallelism/memory for the processing
//! platform, "while allowing the support for infrastructure-specific
//! capabilities, such as layers or memory limits on Lambda."
//!
//! Platform-specific constraints (Lambda memory range, Dask machine
//! capacity, edge device envelopes) are *not* encoded here: each
//! [`PlatformPlugin`](super::registry::PlatformPlugin) owns the checks for
//! its platform via `PlatformPlugin::validate`, so a new platform never
//! requires touching this file.  [`PilotDescription::validate`] covers only
//! the platform-independent invariants.

use crate::util::json::Json;

/// A platform identifier: the interned name under which a
/// [`PlatformPlugin`](super::registry::PlatformPlugin) is registered.
///
/// This is deliberately *not* an enum — the set of platforms is owned by
/// the [`PluginRegistry`](super::registry::PluginRegistry), so third-party
/// plugins introduce new platforms without editing the pilot layer.  The
/// associated constants below name the built-in plugins' platforms.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Platform(&'static str);

impl Platform {
    /// Kinesis-like broker (serverless).
    pub const KINESIS: Platform = Platform("kinesis");
    /// Kafka-like broker (HPC / cloud nodes).
    pub const KAFKA: Platform = Platform("kafka");
    /// Lambda-like FaaS processing.
    pub const LAMBDA: Platform = Platform("lambda");
    /// Dask-like processing on HPC nodes.
    pub const DASK: Platform = Platform("dask");
    /// In-process thread pool (testing, bag-of-tasks).
    pub const LOCAL: Platform = Platform("local");
    /// Greengrass-class edge site: co-located local broker + constrained
    /// function fleet (paper §V future work).
    pub const EDGE: Platform = Platform("edge");
    /// Flink/Spark-Streaming-class micro-batch processing (ROADMAP
    /// follow-on): per-message scheduling-delay overhead, savepoint-based
    /// rescaling.
    pub const FLINK: Platform = Platform("flink");

    /// Identifier for a plugin-owned platform name.  Equality is by name,
    /// so `Platform::from_static("lambda") == Platform::LAMBDA`.
    pub const fn from_static(name: &'static str) -> Platform {
        Platform(name)
    }

    /// Resolve a user-facing name or alias against the default plugin
    /// registry (plugins own their naming — see
    /// [`PluginRegistry::parse`](super::registry::PluginRegistry::parse)).
    pub fn parse(s: &str) -> Option<Platform> {
        super::registry::default_registry().parse(s)
    }

    pub fn name(self) -> &'static str {
        self.0
    }

    /// Whether the default registry's plugin for this platform provisions
    /// a broker.
    pub fn is_broker(self) -> bool {
        super::registry::default_registry()
            .get(self)
            .is_some_and(|p| p.provisions_broker())
    }
}

impl std::fmt::Display for Platform {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.0)
    }
}

/// HPC machine selection for Dask pilots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MachineKind {
    Wrangler,
    Stampede2,
}

impl MachineKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "wrangler" => Some(Self::Wrangler),
            "stampede2" | "stampede2-knl" => Some(Self::Stampede2),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Wrangler => "wrangler",
            Self::Stampede2 => "stampede2",
        }
    }

    pub fn machine(self, max_nodes: usize) -> crate::hpc::Machine {
        match self {
            Self::Wrangler => crate::hpc::Machine::wrangler(max_nodes),
            Self::Stampede2 => crate::hpc::Machine::stampede2(max_nodes),
        }
    }
}

/// The normative resource description.
#[derive(Debug, Clone)]
pub struct PilotDescription {
    pub platform: Platform,
    /// Broker: number of shards/partitions. Processing: parallelism
    /// (one concurrent container / worker per unit) — the paper's single
    /// unified attribute.
    pub parallelism: usize,
    /// Processing memory per container/worker, MB (Lambda-specific knob).
    pub memory_mb: u32,
    /// Walltime limit, seconds.
    pub walltime_s: f64,
    /// HPC machine (Dask only).
    pub machine: MachineKind,
    /// Max nodes the HPC allocation may use.
    pub max_nodes: usize,
    /// Records per invocation batch (event-source mapping).
    pub batch_size: usize,
    /// Deployment package size, MB (Lambda cold starts).
    pub package_mb: f64,
    /// RNG seed for everything this pilot provisions.
    pub seed: u64,
    /// Platform-specific extension parameters ("infrastructure-specific
    /// capabilities" in the paper's wording), mirroring `Scenario::extra`:
    /// non-canonical sweep axes land here and the owning plugin looks its
    /// parameters up by name — e.g. the edge plugin provisions a
    /// multi-site fleet from `edge_sites`.  Unknown names are ignored, so
    /// descriptions stay platform-agnostic.
    pub extra: Vec<(String, u64)>,
}

impl Default for PilotDescription {
    fn default() -> Self {
        Self {
            platform: Platform::LOCAL,
            parallelism: 4,
            memory_mb: 3008,
            walltime_s: 900.0,
            machine: MachineKind::Wrangler,
            max_nodes: 16,
            batch_size: 1,
            package_mb: 50.0,
            seed: 42,
            extra: Vec::new(),
        }
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum DescriptionError {
    #[error("invalid {field}: {reason}")]
    Invalid {
        field: &'static str,
        reason: String,
    },
    #[error("unknown platform {0:?}")]
    UnknownPlatform(String),
}

impl DescriptionError {
    /// Convenience constructor plugins use for their platform checks.
    pub fn invalid(field: &'static str, reason: impl Into<String>) -> Self {
        Self::Invalid {
            field,
            reason: reason.into(),
        }
    }
}

impl PilotDescription {
    pub fn new(platform: Platform) -> Self {
        Self {
            platform,
            ..Default::default()
        }
    }

    pub fn with_parallelism(mut self, p: usize) -> Self {
        self.parallelism = p;
        self
    }

    pub fn with_memory_mb(mut self, m: u32) -> Self {
        self.memory_mb = m;
        self
    }

    pub fn with_machine(mut self, m: MachineKind) -> Self {
        self.machine = m;
        self
    }

    pub fn with_max_nodes(mut self, n: usize) -> Self {
        self.max_nodes = n;
        self
    }

    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Set (or replace) a platform-specific extension parameter.
    pub fn with_extra(mut self, name: impl Into<String>, value: u64) -> Self {
        let name = name.into();
        match self.extra.iter_mut().find(|(n, _)| *n == name) {
            Some(slot) => slot.1 = value,
            None => self.extra.push((name, value)),
        }
        self
    }

    /// Look up an extension parameter by name.
    pub fn extra_param(&self, name: &str) -> Option<u64> {
        self.extra.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    /// Platform-independent invariants only.  Platform-specific constraints
    /// live in each plugin's `validate` — use
    /// [`PluginRegistry::validate`](super::registry::PluginRegistry::validate)
    /// for the full check.
    pub fn validate(&self) -> Result<(), DescriptionError> {
        if self.parallelism == 0 {
            return Err(DescriptionError::invalid("parallelism", "must be > 0"));
        }
        if self.batch_size == 0 {
            return Err(DescriptionError::invalid("batch_size", "must be > 0"));
        }
        if !self.walltime_s.is_finite() || self.walltime_s <= 0.0 {
            return Err(DescriptionError::invalid("walltime_s", "must be > 0"));
        }
        if !self.package_mb.is_finite() || self.package_mb < 0.0 {
            return Err(DescriptionError::invalid("package_mb", "must be >= 0"));
        }
        Ok(())
    }

    /// Parse from a config JSON/TOML object (see `util::tomlmini`) against
    /// the default plugin registry.  Custom registries (third-party
    /// plugins) use [`PilotDescription::from_json_with`].
    pub fn from_json(v: &Json) -> Result<Self, DescriptionError> {
        Self::from_json_with(v, &super::registry::default_registry())
    }

    /// Parse against an explicit registry: platform naming and the full
    /// validation (generic + plugin) both consult `registry`, so configs
    /// naming third-party platforms load once their plugin is registered.
    pub fn from_json_with(
        v: &Json,
        registry: &super::registry::PluginRegistry,
    ) -> Result<Self, DescriptionError> {
        let mut d = PilotDescription::default();
        if let Some(p) = v.get("platform").as_str() {
            d.platform = registry
                .parse(p)
                .ok_or_else(|| DescriptionError::UnknownPlatform(p.to_string()))?;
        }
        if let Some(x) = v.get("parallelism").as_usize() {
            d.parallelism = x;
        }
        if let Some(x) = v.get("memory_mb").as_usize() {
            d.memory_mb = x as u32;
        }
        if let Some(x) = v.get("walltime_s").as_f64() {
            d.walltime_s = x;
        }
        if let Some(m) = v.get("machine").as_str() {
            d.machine = MachineKind::parse(m).ok_or_else(|| DescriptionError::Invalid {
                field: "machine",
                reason: format!("unknown machine {m:?}"),
            })?;
        }
        if let Some(x) = v.get("max_nodes").as_usize() {
            d.max_nodes = x;
        }
        if let Some(x) = v.get("batch_size").as_usize() {
            d.batch_size = x;
        }
        if let Some(x) = v.get("package_mb").as_f64() {
            d.package_mb = x;
        }
        if let Some(x) = v.get("seed").as_i64() {
            d.seed = x as u64;
        }
        if let Some(extras) = v.get("extra").as_obj() {
            for (name, value) in extras {
                let x = value.as_i64().ok_or_else(|| DescriptionError::Invalid {
                    field: "extra",
                    reason: format!("{name:?}: expected integer"),
                })?;
                d = d.with_extra(name.as_str(), x as u64);
            }
        }
        registry.validate(&d)?;
        Ok(d)
    }

    /// Full round-trip export: every field `from_json` reads is written, so
    /// a description survives serialization unchanged (a Dask description
    /// keeps its HPC machine; a Lambda description its package size).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("platform", Json::from(self.platform.name())),
            ("parallelism", Json::from(self.parallelism)),
            ("memory_mb", Json::from(self.memory_mb as usize)),
            ("walltime_s", Json::from(self.walltime_s)),
            ("machine", Json::from(self.machine.name())),
            ("max_nodes", Json::from(self.max_nodes)),
            ("batch_size", Json::from(self.batch_size)),
            ("package_mb", Json::from(self.package_mb)),
            ("seed", Json::from(self.seed as i64)),
            (
                "extra",
                Json::Obj(
                    self.extra
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::from(*v as usize)))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pilot::registry::default_registry;

    #[test]
    fn platform_parse_roundtrip() {
        for p in [
            Platform::KINESIS,
            Platform::KAFKA,
            Platform::LAMBDA,
            Platform::DASK,
            Platform::LOCAL,
            Platform::EDGE,
            Platform::FLINK,
        ] {
            assert_eq!(Platform::parse(p.name()), Some(p));
        }
        assert_eq!(Platform::parse("spark"), None);
        assert!(Platform::KINESIS.is_broker());
        assert!(!Platform::LAMBDA.is_broker());
        // interned names are compared by value
        assert_eq!(Platform::from_static("lambda"), Platform::LAMBDA);
    }

    #[test]
    fn generic_validation() {
        let mut d = PilotDescription::new(Platform::LAMBDA);
        assert!(d.validate().is_ok());
        d.parallelism = 0;
        assert!(d.validate().is_err());
        d.parallelism = 1;
        d.batch_size = 0;
        assert!(d.validate().is_err());
    }

    #[test]
    fn lambda_constraints_enforced_by_plugin() {
        // the Lambda-specific checks moved out of PilotDescription::validate
        // into the serverless plugin; the registry composes both
        let mut d = PilotDescription::new(Platform::LAMBDA);
        assert!(default_registry().validate(&d).is_ok());
        d.memory_mb = 64;
        assert!(d.validate().is_ok(), "generic validation knows no platform");
        assert!(default_registry().validate(&d).is_err());
        d.memory_mb = 1024;
        d.walltime_s = 2000.0;
        assert!(default_registry().validate(&d).is_err());
    }

    #[test]
    fn dask_capacity_constraint_enforced_by_plugin() {
        let mut d = PilotDescription::new(Platform::DASK);
        d.max_nodes = 1; // 12 workers max
        d.parallelism = 12;
        assert!(default_registry().validate(&d).is_ok());
        d.parallelism = 13;
        assert!(default_registry().validate(&d).is_err());
    }

    #[test]
    fn same_attribute_for_both_brokers() {
        // the paper's normative claim: one attribute, two brokers
        let k = PilotDescription::new(Platform::KINESIS).with_parallelism(8);
        let q = PilotDescription::new(Platform::KAFKA).with_parallelism(8);
        assert_eq!(k.parallelism, q.parallelism);
        assert!(default_registry().validate(&k).is_ok());
        assert!(default_registry().validate(&q).is_ok());
    }

    #[test]
    fn from_json() {
        let v = crate::util::json::parse(
            r#"{"platform": "lambda", "parallelism": 16, "memory_mb": 1792,
                "batch_size": 2, "seed": 7}"#,
        )
        .unwrap();
        let d = PilotDescription::from_json(&v).unwrap();
        assert_eq!(d.platform, Platform::LAMBDA);
        assert_eq!(d.parallelism, 16);
        assert_eq!(d.memory_mb, 1792);
        assert_eq!(d.batch_size, 2);
        assert_eq!(d.seed, 7);
    }

    #[test]
    fn from_json_with_respects_the_registry() {
        // the declarative path is not hard-wired to the default registry
        let v = crate::util::json::parse(r#"{"platform": "lambda"}"#).unwrap();
        let empty = crate::pilot::registry::PluginRegistry::empty();
        assert!(matches!(
            PilotDescription::from_json_with(&v, &empty),
            Err(DescriptionError::UnknownPlatform(_))
        ));
    }

    #[test]
    fn from_json_rejects_bad() {
        let v = crate::util::json::parse(r#"{"platform": "spark"}"#).unwrap();
        assert!(matches!(
            PilotDescription::from_json(&v),
            Err(DescriptionError::UnknownPlatform(_))
        ));
        let v = crate::util::json::parse(r#"{"platform": "lambda", "memory_mb": 9999}"#).unwrap();
        assert!(PilotDescription::from_json(&v).is_err());
    }

    #[test]
    fn json_roundtrip_preserves_every_field() {
        // regression: to_json used to drop `machine` and `package_mb`, so a
        // Dask description round-tripped onto the wrong HPC machine
        let mut d = PilotDescription::new(Platform::DASK)
            .with_parallelism(24)
            .with_machine(MachineKind::Stampede2)
            .with_max_nodes(32)
            .with_seed(9);
        d.memory_mb = 2048;
        d.walltime_s = 600.0;
        d.batch_size = 3;
        d.package_mb = 120.0;
        let d2 = PilotDescription::from_json(&d.to_json()).unwrap();
        assert_eq!(d2.platform, d.platform);
        assert_eq!(d2.parallelism, d.parallelism);
        assert_eq!(d2.memory_mb, d.memory_mb);
        assert_eq!(d2.walltime_s, d.walltime_s);
        assert_eq!(d2.machine, d.machine);
        assert_eq!(d2.max_nodes, d.max_nodes);
        assert_eq!(d2.batch_size, d.batch_size);
        assert_eq!(d2.package_mb, d.package_mb);
        assert_eq!(d2.seed, d.seed);
        assert!(d2.extra.is_empty());
    }

    #[test]
    fn extension_params_set_replace_and_roundtrip() {
        let d = PilotDescription::new(Platform::EDGE)
            .with_parallelism(2)
            .with_memory_mb(1024)
            .with_extra("edge_sites", 2)
            .with_extra("edge_sites", 4); // replaces in place
        assert_eq!(d.extra_param("edge_sites"), Some(4));
        assert_eq!(d.extra.len(), 1);
        assert_eq!(d.extra_param("nonesuch"), None);
        // extension params survive the JSON round trip
        let d2 = PilotDescription::from_json(&d.to_json()).unwrap();
        assert_eq!(d2.extra_param("edge_sites"), Some(4));
        // non-integer extension values are rejected, not dropped
        let bad = crate::util::json::parse(
            r#"{"platform": "edge", "memory_mb": 1024, "extra": {"edge_sites": "two"}}"#,
        )
        .unwrap();
        assert!(PilotDescription::from_json(&bad).is_err());
    }
}
