//! State machines for pilots and compute-units.
//!
//! The pilot abstraction's lifecycle (P* model, Luckow et al. 2012),
//! extended with the elastic control plane's `Resizing` state:
//! pilots move `New → Pending → Running → {Done, Failed, Canceled}` with
//! `Running ↔ Resizing` excursions while a live resize transition
//! (cold-starting containers, booting workers, repartitioning) completes;
//! compute-units move `New → Queued → Running → {Done, Failed, Canceled}`.
//! Transitions are validated — an illegal transition is a bug, not data.

use std::fmt;

/// Pilot (resource container) lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PilotState {
    New,
    /// Submitted to the resource manager (batch queue / provisioning).
    Pending,
    /// Resources are up; compute-units can run.
    Running,
    /// A live resize is in flight: the pilot keeps serving at its old
    /// capacity until the transition's sim-clock deadline passes.
    Resizing,
    Done,
    Failed,
    Canceled,
}

impl PilotState {
    pub fn is_terminal(self) -> bool {
        matches!(self, Self::Done | Self::Failed | Self::Canceled)
    }

    /// Whether the pilot accepts work in this state.  A `Resizing` pilot
    /// still serves — the previous capacity keeps draining while the new
    /// capacity comes up.
    pub fn is_serving(self) -> bool {
        matches!(self, Self::Running | Self::Resizing)
    }

    /// Whether `self -> next` is a legal transition.
    pub fn can_transition(self, next: PilotState) -> bool {
        use PilotState::*;
        matches!(
            (self, next),
            (New, Pending)
                | (New, Canceled)
                | (Pending, Running)
                | (Pending, Failed)
                | (Pending, Canceled)
                | (Running, Resizing)
                | (Running, Done)
                | (Running, Failed)
                | (Running, Canceled)
                | (Resizing, Running)
                | (Resizing, Done)
                | (Resizing, Failed)
                | (Resizing, Canceled)
        )
    }
}

impl fmt::Display for PilotState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Self::New => "new",
            Self::Pending => "pending",
            Self::Running => "running",
            Self::Resizing => "resizing",
            Self::Done => "done",
            Self::Failed => "failed",
            Self::Canceled => "canceled",
        };
        f.write_str(s)
    }
}

/// Compute-unit (task) lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CuState {
    New,
    Queued,
    Running,
    Done,
    Failed,
    Canceled,
}

impl CuState {
    pub fn is_terminal(self) -> bool {
        matches!(self, Self::Done | Self::Failed | Self::Canceled)
    }

    pub fn can_transition(self, next: CuState) -> bool {
        use CuState::*;
        matches!(
            (self, next),
            (New, Queued)
                | (New, Canceled)
                | (Queued, Running)
                | (Queued, Failed) // rejected at submission
                | (Queued, Canceled)
                | (Running, Done)
                | (Running, Failed)
                | (Running, Canceled)
        )
    }
}

impl fmt::Display for CuState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Self::New => "new",
            Self::Queued => "queued",
            Self::Running => "running",
            Self::Done => "done",
            Self::Failed => "failed",
            Self::Canceled => "canceled",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pilot_happy_path() {
        use PilotState::*;
        let path = [New, Pending, Running, Done];
        for w in path.windows(2) {
            assert!(w[0].can_transition(w[1]), "{} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn pilot_illegal_transitions() {
        use PilotState::*;
        assert!(!New.can_transition(Running)); // must go through Pending
        assert!(!Done.can_transition(Running));
        assert!(!Failed.can_transition(Pending));
        assert!(!Running.can_transition(Pending));
        assert!(!New.can_transition(Resizing)); // only live pilots resize
        assert!(!Pending.can_transition(Resizing));
        assert!(!Resizing.can_transition(Pending));
    }

    #[test]
    fn resize_excursion_returns_to_running() {
        use PilotState::*;
        assert!(Running.can_transition(Resizing));
        assert!(Resizing.can_transition(Running));
        // a resizing pilot can still be torn down mid-transition
        assert!(Resizing.can_transition(Canceled));
        assert!(Resizing.can_transition(Done));
        assert!(Resizing.can_transition(Failed));
        assert!(Resizing.is_serving() && Running.is_serving());
        assert!(!Pending.is_serving() && !Done.is_serving());
    }

    #[test]
    fn terminal_states_have_no_exits() {
        use PilotState::*;
        for s in [Done, Failed, Canceled] {
            assert!(s.is_terminal());
            for t in [New, Pending, Running, Resizing, Done, Failed, Canceled] {
                assert!(!s.can_transition(t));
            }
        }
    }

    #[test]
    fn cu_happy_path_and_cancel() {
        use CuState::*;
        assert!(New.can_transition(Queued));
        assert!(Queued.can_transition(Running));
        assert!(Running.can_transition(Done));
        assert!(Queued.can_transition(Canceled));
        assert!(!Done.can_transition(Running));
        assert!(!New.can_transition(Running));
    }
}
